//! Serving-engine walkthrough: compile-once/run-many plan caching and
//! pipelined batched execution over the heterogeneous stack.
//!
//! Serves two batches of ResNet-18 requests. The first batch is cold:
//! every offloaded conv node is lowered once (tiling, micro-kernel
//! generation, instruction-stream recording, weight packing into
//! device DRAM) and cached. The second batch is warm: pure replay —
//! the cache-hit counters prove lowering never runs again, and the
//! pipelined schedule overlaps CPU wall time with simulated VTA time.
//!
//! Run: `cargo run --release --example serving`

use vta::arch::VtaConfig;
use vta::exec::{CpuBackend, ServingEngine};
use vta::graph::resnet::{self, synth_input};
use vta::graph::{fuse, partition, PartitionPolicy};

fn main() -> anyhow::Result<()> {
    let cfg = VtaConfig::pynq();
    let (mut g, fused) = fuse(resnet::resnet18(1, 42)?)?;
    let (vta_n, cpu_n) = partition(&mut g, &PartitionPolicy::paper(&cfg));
    println!(
        "ResNet-18: {} nodes ({fused} ReLUs fused), {vta_n} on VTA, {cpu_n} on CPU",
        g.nodes.len()
    );

    let batch = 4;
    let mut engine = ServingEngine::new(&cfg, 512 << 20, CpuBackend::Native, 2, 64);
    let inputs: Vec<_> = (0..batch).map(|i| synth_input(7 + i as u64, 1, 3, 224, 224)).collect();

    // Cold: compiles once per unique (params, weights) conv node.
    let cold = engine.run_batch(&g, &inputs)?;
    println!(
        "\ncold batch of {batch}: cache misses {} / hits {}  →  {} compiled plans, {:.1} MB \
         device DRAM, host wall {:.2?}",
        cold.cache.misses,
        cold.cache.hits,
        engine.cached_plans(),
        engine.cache_dram_bytes() as f64 / 1e6,
        cold.host_wall
    );

    // Warm: replay only.
    let warm = engine.run_batch(&g, &inputs)?;
    assert_eq!(cold.outputs, warm.outputs, "caching must not change results");
    println!(
        "warm batch of {batch}: cache misses {} / hits {}, host wall {:.2?}",
        warm.cache.misses, warm.cache.hits, warm.host_wall
    );

    println!(
        "\nmodel time: naive serial {:.1} ms  →  pipelined {:.1} ms ({:.2}x); \
         throughput {:.1} inf/s; p50 {:.1} ms, p99 {:.1} ms",
        warm.serial_seconds * 1e3,
        warm.pipelined_seconds * 1e3,
        warm.speedup(),
        warm.throughput(),
        warm.latency_percentile(0.50) * 1e3,
        warm.latency_percentile(0.99) * 1e3
    );
    println!("\nlogits[..8] of request 0: {:?}", &warm.outputs[0].data()[..8]);
    Ok(())
}
