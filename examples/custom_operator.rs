//! Custom operator authoring against the raw runtime API (§3):
//! the vector-add of Listing 1, then a fused "scale-shift-clip"
//! activation — built directly from `VTALoadBuffer2D` / `VTAUopPush` /
//! dependence push/pop calls, the way TVM's lowered schedules do it.
//!
//! This is the "deep learning researchers" use case of §1.1: new
//! operators and data representations without touching the hardware.
//!
//! Run: `cargo run --release --example custom_operator`

use vta::arch::VtaConfig;
use vta::isa::{AluOpcode, AluUop, BufferId, Uop};
use vta::runtime::{CoreModule, Device, UopKernelBuilder, VtaRuntime};

fn main() -> anyhow::Result<()> {
    let cfg = VtaConfig::pynq();
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let lanes = cfg.gemm.batch * cfg.gemm.block_out; // i32 lanes per tile
    let n_tiles: u16 = 128;
    let n = n_tiles as usize * lanes;

    // Host data: two int32 vectors.
    let a_host: Vec<i32> = (0..n as i32).map(|i| i - 1000).collect();
    let b_host: Vec<i32> = (0..n as i32).map(|i| 3 * i % 257).collect();

    let a = rt.alloc_aligned(n * 4, cfg.acc_tile_bytes())?;
    let b = rt.alloc_aligned(n * 4, cfg.acc_tile_bytes())?;
    let c = rt.alloc_aligned(n, cfg.out_tile_bytes())?;
    rt.device.write_u32(a.addr, &a_host.iter().map(|&v| v as u32).collect::<Vec<_>>())?;
    rt.device.write_u32(b.addr, &b_host.iter().map(|&v| v as u32).collect::<Vec<_>>())?;

    // ---- operator: clip((A + B) >> 2, relu) -------------------------
    // Load A into register-file tiles [0, n), B into [n, 2n).
    let acc_tile = cfg.acc_tile_bytes();
    rt.ctx.load_buffer_2d(
        BufferId::Acc,
        0,
        (a.addr / acc_tile) as u32,
        1,
        n_tiles,
        n_tiles,
        [0; 4],
    );
    rt.ctx.load_buffer_2d(
        BufferId::Acc,
        n_tiles as u32,
        (b.addr / acc_tile) as u32,
        1,
        n_tiles,
        n_tiles,
        [0; 4],
    );

    // Micro-kernel: one ALU uop swept over all tiles (VTAUopLoopBegin /
    // VTAUopPush / VTAUopLoopEnd).
    let mut kb = UopKernelBuilder::new();
    kb.loop_begin(n_tiles, 1, 1, 0)?;
    kb.push(Uop::Alu(AluUop { dst_idx: 0, src_idx: n_tiles }))?;
    kb.loop_end()?;
    let kernel = kb.finish()?;
    let kid = rt.ctx.register_kernel(&kernel)?;

    // Tensor-tensor add, then tensor-scalar shift + ReLU clip.
    rt.ctx.push_alu(kid, &kernel, AluOpcode::Add, false, 0)?;
    rt.ctx.push_alu(kid, &kernel, AluOpcode::Shr, true, 2)?;
    rt.ctx.push_alu(kid, &kernel, AluOpcode::Max, true, 0)?;
    rt.ctx.push_alu(kid, &kernel, AluOpcode::Min, true, 127)?;

    // Explicit dependence edges around the store (Fig 12).
    rt.ctx.dep_push(CoreModule::Compute, CoreModule::Store)?;
    rt.ctx.dep_pop(CoreModule::Compute, CoreModule::Store)?;
    rt.ctx.store_buffer_2d(0, (c.addr / cfg.out_tile_bytes()) as u32, 1, n_tiles, n_tiles);

    let stats = rt.synchronize()?;
    println!(
        "custom op executed: {} cycles, {} ALU uops, {} bytes moved",
        stats.total_cycles,
        stats.alu_uops,
        stats.bytes_moved()
    );

    // Verify against the host.
    let got = rt.copy_out(&c)?;
    for i in 0..n {
        let expect = (((a_host[i] + b_host[i]) >> 2).clamp(0, 127)) as i8 as u8;
        assert_eq!(got[i], expect, "lane {i}");
    }
    println!("bit-exact against the host computation ✓");
    Ok(())
}
