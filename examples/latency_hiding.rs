//! Latency hiding (§2.3 / §4.3, Figs 4 & 14): run the same ResNet conv
//! layer with and without virtual threading and watch TLPP recover the
//! memory-access time.
//!
//! Run: `cargo run --release --example latency_hiding [layer]`
//! (layer = C1..C12; defaults to C6)

use vta::arch::VtaConfig;
use vta::compiler::{lower_conv2d, pack_activations, pack_weights};
use vta::graph::resnet::{self, TABLE1};
use vta::runtime::VtaRuntime;
use vta::util::XorShiftRng;

fn main() -> anyhow::Result<()> {
    let layer = std::env::args().nth(1).unwrap_or_else(|| "C6".into());
    let row = TABLE1
        .iter()
        .position(|(n, ..)| n.eq_ignore_ascii_case(&layer))
        .ok_or_else(|| anyhow::anyhow!("unknown layer {layer} (use C1..C12)"))?;
    let p = resnet::table1_params(row);
    let cfg = VtaConfig::pynq();

    let mut rng = XorShiftRng::new(3);
    let inp = vta::util::Tensor::from_vec(
        &[1, p.ic, p.h, p.w],
        rng.vec_i8(p.ic * p.h * p.w, -16, 16),
    )
    .unwrap();
    let wgt = vta::util::Tensor::from_vec(
        &[p.oc, p.ic, p.k, p.k],
        rng.vec_i8(p.oc * p.ic * p.k * p.k, -4, 4),
    )
    .unwrap();
    let ip = pack_activations(&cfg, &inp);
    let wp = pack_weights(&cfg, &wgt);

    println!(
        "{layer}: {}x{} {}→{} k{} s{}  ({:.2} GOPs, {:.1} ops/byte)\n",
        p.h,
        p.w,
        p.ic,
        p.oc,
        p.k,
        p.s,
        p.ops() as f64 / 1e9,
        p.arithmetic_intensity()
    );

    let mut results = Vec::new();
    for vt in [1, 2] {
        let mut rt = VtaRuntime::new(&cfg, 256 << 20);
        let out = lower_conv2d(&mut rt, &p, &ip, &wp, vt)?;
        let s = out.stats;
        println!(
            "virtual threads = {vt}: {:>9} cycles  util {:>3.0}%  \
             (gemm busy {:>8}, dram busy {:>8}, fetch stalls {})",
            s.total_cycles,
            s.compute_utilization() * 100.0,
            s.gemm_busy_cycles,
            s.dram_busy_cycles,
            s.fetch_stall_cycles
        );
        results.push(s);
    }

    let speedup = results[0].total_cycles as f64 / results[1].total_cycles as f64;
    println!(
        "\nlatency hiding: {:.2}x speedup; utilization {:.0}% → {:.0}% \
         (paper Fig 15: 70% → 88% aggregate)",
        speedup,
        results[0].compute_utilization() * 100.0,
        results[1].compute_utilization() * 100.0
    );
    // Identical work either way — only the schedule differs.
    assert_eq!(results[0].gemm_uops, results[1].gemm_uops);
    Ok(())
}
