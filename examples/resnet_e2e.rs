//! End-to-end driver (§5, Fig 16): quantized ResNet-18 inference on the
//! heterogeneous stack — conv layers on the VTA behavioral simulator
//! through the full compiler/runtime, CPU-resident operators on
//! AOT-compiled XLA/PJRT executables (falling back to native Rust when
//! `make artifacts` hasn't run).
//!
//! Prints the per-node breakdown and the CPU-only vs CPU+VTA
//! comparison, and verifies the two paths produce identical logits.
//!
//! Run: `cargo run --release --example resnet_e2e`

use std::time::Instant;
use vta::arch::VtaConfig;
use vta::exec::{CpuBackend, Executor, PjrtCache};
use vta::graph::resnet::{self, synth_input};
use vta::graph::{fuse, partition, Op, PartitionPolicy, Placement};
use vta::runtime::VtaRuntime;

fn backend() -> (CpuBackend, &'static str) {
    if std::path::Path::new("artifacts/.stamp").exists() {
        (CpuBackend::Pjrt(PjrtCache::new("artifacts").unwrap()), "XLA/PJRT artifacts")
    } else {
        (CpuBackend::Native, "native Rust (run `make artifacts` for the PJRT path)")
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = VtaConfig::pynq();
    let input = synth_input(7, 1, 3, 224, 224);
    let (mut g, fused) = fuse(resnet::resnet18(1, 42)?)?;
    println!(
        "ResNet-18, {} nodes after fusing {fused} ReLUs; {:.1} M int8 parameters",
        g.nodes.len(),
        g.param_bytes() as f64 / 1e6
    );

    // ---- CPU-only baseline -------------------------------------------
    let (cpu_backend, label) = backend();
    println!("CPU backend: {label}\n");
    partition(&mut g, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 512 << 20), cpu_backend);
    let t0 = Instant::now();
    let cpu_report = ex.run(&g, &input)?;
    let cpu_wall = t0.elapsed();
    let cpu_conv: f64 = cpu_report
        .nodes
        .iter()
        .filter(|n| n.kind == "conv2d")
        .map(|n| n.wall.as_secs_f64())
        .sum();
    println!(
        "CPU-only: {:.1} ms total ({:.1} ms in convolutions)",
        cpu_wall.as_secs_f64() * 1e3,
        cpu_conv * 1e3
    );

    // ---- hybrid CPU + VTA --------------------------------------------
    let (vta_n, cpu_n) = partition(&mut g, &PartitionPolicy::paper(&cfg));
    println!("\nhybrid partition: {vta_n} nodes on VTA, {cpu_n} on CPU");
    let (cpu_backend, _) = backend();
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 512 << 20), cpu_backend);
    let t0 = Instant::now();
    let report = ex.run(&g, &input)?;
    let host_wall = t0.elapsed();

    println!("\n{:<24} {:>5} {:>12} {:>12}", "node", "place", "cpu (ms)", "vta-sim (ms)");
    for n in &report.nodes {
        if matches!(n.kind, "input") {
            continue;
        }
        println!(
            "{:<24} {:>5} {:>12.3} {:>12.3}",
            n.name,
            if n.placement == Placement::Vta { "VTA" } else { "CPU" },
            n.wall.as_secs_f64() * 1e3,
            n.sim_seconds * 1e3
        );
    }

    let s = report.vta_stats();
    let vta_conv_s = report.vta_seconds();
    println!(
        "\nhybrid: CPU {:.1} ms + VTA-simulated {:.1} ms = {:.1} ms model time \
         (host wall {:.1?})",
        report.cpu_time().as_secs_f64() * 1e3,
        vta_conv_s * 1e3,
        report.total_seconds() * 1e3,
        host_wall
    );
    println!(
        "VTA: {} Mcycles, GEMM utilization {:.0}%, {:.1} MB DRAM traffic",
        s.total_cycles / 1_000_000,
        s.compute_utilization() * 100.0,
        s.bytes_moved() as f64 / 1e6
    );
    println!(
        "\nFig 16 shape: conv time {:.1} ms (CPU) → {:.1} ms (VTA): {:.1}x on offloaded convs; \
         end-to-end {:.1} ms → {:.1} ms ({:.1}x, Amdahl-limited by CPU ops)",
        cpu_conv * 1e3,
        vta_conv_s * 1e3,
        cpu_conv / vta_conv_s.max(1e-12),
        cpu_wall.as_secs_f64() * 1e3,
        report.total_seconds() * 1e3,
        cpu_wall.as_secs_f64() / report.total_seconds().max(1e-12)
    );

    // The two paths must agree bit-exactly.
    assert_eq!(report.output, cpu_report.output, "hybrid and CPU-only disagree");
    println!("\nhybrid logits == CPU-only logits ✓");
    let logits = report.output;
    let top = (0..1000)
        .max_by_key(|&i| logits.data()[i])
        .unwrap();
    println!("argmax(logits) = class {top} (synthetic weights)");

    // Sanity: all Table 1 configs ran.
    let missing = resnet::check_table1_coverage(&g);
    assert!(missing.is_empty(), "missing Table 1 configs: {missing:?}");
    let _ = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv2d { .. })).count();
    Ok(())
}
