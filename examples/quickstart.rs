//! Quickstart: lower one small quantized convolution through the full
//! VTA stack (planner → tensorize → runtime → behavioral simulator),
//! verify it against the host reference, and read the cycle report.
//!
//! Run: `cargo run --release --example quickstart`

use vta::arch::VtaConfig;
use vta::compiler::reference::conv2d_ref;
use vta::compiler::{
    lower_conv2d, pack_activations, pack_weights, unpack_outputs, Conv2dParams, Requant,
};
use vta::metrics::Roofline;
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

fn main() -> anyhow::Result<()> {
    // 1. Pick a hardware variant — the paper's Pynq design point.
    let cfg = VtaConfig::pynq();
    println!("{}\n", cfg.summary());

    // 2. A quantized conv workload: 32x32 image, 64→64 channels, 3x3.
    let p = Conv2dParams {
        h: 32,
        w: 32,
        ic: 64,
        oc: 64,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: true },
    };

    // 3. Synthesize int8 data and pack it into the tiled DRAM layout.
    let mut rng = XorShiftRng::new(1);
    let inp = Tensor::from_vec(&[1, 64, 32, 32], rng.vec_i8(64 * 32 * 32, -16, 16)).unwrap();
    let wgt = Tensor::from_vec(&[64, 64, 3, 3], rng.vec_i8(64 * 64 * 9, -4, 4)).unwrap();

    // 4. Lower and run on the behavioral simulator with latency hiding
    //    (2 virtual threads).
    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    let out = lower_conv2d(
        &mut rt,
        &p,
        &pack_activations(&cfg, &inp),
        &pack_weights(&cfg, &wgt),
        2,
    )?;

    // 5. Verify against the host oracle.
    let got = unpack_outputs(&cfg, &out.out, 1, p.oc, p.out_h(), p.out_w());
    let expect = conv2d_ref(&p, &inp, &wgt);
    assert_eq!(got, expect, "simulator must be bit-exact");
    println!("bit-exact against the host reference ✓\n");

    // 6. Read the performance counters.
    let s = &out.stats;
    let r = Roofline::of(&cfg);
    let pt = r.point("conv", p.ops(), p.arithmetic_intensity(), s);
    println!(
        "cycles: {} ({:.3} ms @ {:.0} MHz)",
        s.total_cycles,
        s.total_cycles as f64 / cfg.clock_hz * 1e3,
        cfg.clock_hz / 1e6
    );
    println!(
        "throughput: {:.2} GOPS ({:.0}% of the roofline at {:.1} ops/byte)",
        pt.gops,
        pt.efficiency * 100.0,
        pt.intensity
    );
    println!(
        "GEMM utilization: {:.0}%   DRAM busy: {:.0}%   traffic: {:.2} MB",
        s.compute_utilization() * 100.0,
        s.dram_utilization() * 100.0,
        s.bytes_moved() as f64 / 1e6
    );
    println!(
        "instructions: {} loads, {} gemm, {} alu, {} stores ({} GEMM uops)",
        s.insn_load, s.insn_gemm, s.insn_alu, s.insn_store, s.gemm_uops
    );
    Ok(())
}
