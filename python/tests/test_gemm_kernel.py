"""L1 Pallas GEMM kernel vs the pure-jnp oracle (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import gemm, ref


def run_case(m, k, n, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (m, k), dtype=np.int8)
    w = rng.integers(-128, 128, (n, k), dtype=np.int8)
    got = gemm.gemm(jnp.asarray(a), jnp.asarray(w), bm=bm, bn=bn, bk=bk)
    exp = ref.gemm_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_single_tile():
    run_case(16, 16, 16, 16, 16, 16, 0)


def test_multi_tile_grid():
    run_case(64, 48, 32, 16, 16, 16, 1)


def test_rectangular_blocks():
    run_case(32, 64, 32, 8, 16, 32, 2)


@pytest.mark.parametrize("block", [8, 16, 32])
def test_block_shape_sweep(block):
    # GEMM-core shape ablation (ISA fluidity, §2.2): the intrinsic works
    # at several hardware tile sizes.
    run_case(2 * block, 3 * block, 2 * block, block, block, block, block)


def test_extreme_values_accumulate_in_i32():
    # 128 * -128 * K must not overflow int32 for realistic K.
    m = k = n = 16
    a = np.full((m, k), -128, dtype=np.int8)
    w = np.full((n, k), 127, dtype=np.int8)
    got = gemm.gemm(jnp.asarray(a), jnp.asarray(w))
    assert np.asarray(got)[0, 0] == -128 * 127 * k


def test_untiled_shape_is_rejected():
    with pytest.raises(AssertionError):
        run_case(17, 16, 16, 16, 16, 16, 3)


@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 4),
    kt=st.integers(1, 4),
    nt=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_property_random_shapes(mt, kt, nt, seed):
    """Any tile-multiple shape matches the oracle exactly."""
    run_case(16 * mt, 16 * kt, 16 * nt, 16, 16, 16, seed)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_property_adversarial_values(data):
    """Hand-adversarial value distributions (all-min, all-max, sparse)."""
    m = k = n = 32
    kind = data.draw(st.sampled_from(["min", "max", "sparse", "alt"]))
    if kind == "min":
        a = np.full((m, k), -128, dtype=np.int8)
        w = np.full((n, k), -128, dtype=np.int8)
    elif kind == "max":
        a = np.full((m, k), 127, dtype=np.int8)
        w = np.full((n, k), 127, dtype=np.int8)
    elif kind == "sparse":
        rng = np.random.default_rng(data.draw(st.integers(0, 1000)))
        a = (rng.random((m, k)) < 0.05).astype(np.int8) * 127
        w = (rng.random((n, k)) < 0.05).astype(np.int8) * -128
    else:
        a = np.fromfunction(lambda i, j: ((i + j) % 2 * 2 - 1), (m, k)).astype(np.int8)
        w = np.fromfunction(lambda i, j: ((i * j) % 3 - 1), (n, k)).astype(np.int8)
    got = gemm.gemm(jnp.asarray(a), jnp.asarray(w))
    exp = ref.gemm_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
