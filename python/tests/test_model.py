"""L2 model tests: pallas-vs-lax conv equivalence, CPU-op semantics,
synthetic-weight determinism, full-model shape."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model, synth


def rand(rng, shape, lo=-8, hi=8, dtype=np.int8):
    return rng.integers(lo, hi + 1, shape, dtype=dtype)


# ----------------------------------------------------------------------
# qconv2d: pallas backend == lax backend == numpy mirror.
# ----------------------------------------------------------------------

@pytest.mark.parametrize(
    "c,oc,h,k,s",
    [
        (16, 16, 8, 3, 1),
        (16, 32, 9, 3, 2),
        (32, 16, 6, 1, 1),
        (3, 16, 12, 7, 2),  # C1-like shallow channels
        (16, 16, 7, 5, 2),
    ],
)
def test_conv_backends_agree(c, oc, h, k, s):
    rng = np.random.default_rng(c * 100 + oc + h + k + s)
    x = rand(rng, (1, c, h, h))
    w = rand(rng, (oc, c, k, k), -4, 4)
    lax_o = model.qconv2d(jnp.asarray(x), jnp.asarray(w), stride=s, shift=5, relu=False)
    pal_o = model.qconv2d(
        jnp.asarray(x), jnp.asarray(w), stride=s, shift=5, relu=False, backend="pallas"
    )
    np.testing.assert_array_equal(np.asarray(lax_o), np.asarray(pal_o))


def test_conv_matches_numpy_mirror():
    rng = np.random.default_rng(7)
    x = rand(rng, (1, 16, 6, 6))
    w = rand(rng, (16, 16, 3, 3), -4, 4)
    got = model.qconv2d(jnp.asarray(x), jnp.asarray(w), stride=1, shift=4, relu=True)
    exp = model.np_conv2d(x, w, 1, 4, True)
    np.testing.assert_array_equal(np.asarray(got), exp)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([1, 2]),
    k=st.sampled_from([1, 3, 5]),
    relu=st.booleans(),
    shift=st.integers(0, 8),
    seed=st.integers(0, 2**31),
)
def test_conv_property(s, k, relu, shift, seed):
    rng = np.random.default_rng(seed)
    h = int(rng.integers(max(k, s), 11))
    x = rand(rng, (1, 16, h, h))
    w = rand(rng, (16, 16, k, k), -3, 3)
    lax_o = model.qconv2d(jnp.asarray(x), jnp.asarray(w), stride=s, shift=shift, relu=relu)
    pal_o = model.qconv2d(
        jnp.asarray(x), jnp.asarray(w), stride=s, shift=shift, relu=relu, backend="pallas"
    )
    np.testing.assert_array_equal(np.asarray(lax_o), np.asarray(pal_o))


# ----------------------------------------------------------------------
# CPU-op semantics (twins of rust exec::cpu_ops).
# ----------------------------------------------------------------------

def test_maxpool_skips_out_of_bounds():
    x = np.full((1, 1, 2, 2), -5, dtype=np.int8)
    x[0, 0, 0, 1] = -3
    y = model.maxpool(jnp.asarray(x), k=3, s=2, pad=1)
    # all-negative inputs stay negative (zero padding would give 0)
    assert np.asarray(y)[0, 0, 0, 0] == -3


def test_gap_truncates_toward_zero():
    # (-7)/2 must be -3 (trunc), not -4 (floor): the Rust executor uses
    # integer division toward zero.
    x = np.zeros((1, 1, 1, 2), dtype=np.int8)
    x[0, 0, 0] = [-3, -4]
    y = model.global_avg_pool(jnp.asarray(x))
    assert np.asarray(y)[0, 0] == -3


def test_add_saturates():
    a = np.array([[120, -120]], dtype=np.int8)
    b = np.array([[60, -60]], dtype=np.int8)
    y = model.add_sat(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(y), [[127, -128]])


def test_same_padding_matches_rust_planner():
    # C1: k=7 s=2 h=224 → begin 2 end 3; C4: k=3 s=2 h=56 → begin 0 end 1
    assert model.same_padding(224, 7, 2) == (2, 3)
    assert model.same_padding(56, 3, 2) == (0, 1)
    assert model.same_padding(56, 1, 2) == (0, 0)
    assert model.same_padding(56, 3, 1) == (1, 1)


# ----------------------------------------------------------------------
# Synthetic data determinism (must mirror the Rust XorShiftRng).
# ----------------------------------------------------------------------

def test_xorshift_matches_rust_sequence():
    # First outputs of XorShiftRng::new(42), cross-checked against the
    # Rust implementation (identical algorithm and constants).
    r = synth.XorShiftRng(42)
    a = [r.next_u64() for _ in range(4)]
    r2 = synth.XorShiftRng(42)
    assert a == [r2.next_u64() for _ in range(4)]
    assert synth.XorShiftRng(0).next_u64() == synth.XorShiftRng(0x9E3779B97F4A7C15).next_u64()


def test_weight_order_matches_shapes():
    shapes = model.weight_shapes()
    assert [n for n, _ in shapes] == model.WEIGHT_ORDER
    assert len(shapes) == 22
    assert shapes[0] == ("conv1", (64, 3, 7, 7))
    assert shapes[-1] == ("fc", (1000, 512))
    # C3: stage-1 projection is 64→64 1x1.
    assert ("layer1.0.downsample", (64, 64, 1, 1)) in shapes


def test_synth_weights_cover_weight_order():
    ws = synth.resnet18_weights(42)
    for name, shape in model.weight_shapes():
        assert name in ws, f"missing {name}"
        assert ws[name].shape == shape, f"{name}: {ws[name].shape} != {shape}"
        assert ws[name].dtype == np.int8


# ----------------------------------------------------------------------
# Full model.
# ----------------------------------------------------------------------

def test_resnet18_forward_shape_runs():
    # Tiny sanity pass: random small weights on a cropped custom net is
    # not representative; run the real geometry once (lax backend).
    ws = {
        name: np.zeros(shape, dtype=np.int8) for name, shape in model.weight_shapes()
    }
    # make it non-trivial but cheap: identity-ish first filters
    ws["conv1"][:, :, 3, 3] = 1
    x = synth.synth_input(7, 1, 3, 224, 224)
    y = model.resnet18_forward(jnp.asarray(x), {k: jnp.asarray(v) for k, v in ws.items()})
    assert y.shape == (1, 1000)
    assert y.dtype == jnp.int8
