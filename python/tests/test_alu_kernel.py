"""L1 Pallas requant (tensor-ALU) kernel vs the oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import alu, ref


def run_case(acc: np.ndarray, shift: int, relu: bool, block: int = 256):
    got = alu.requant(jnp.asarray(acc), shift=shift, relu=relu, block=block)
    exp = ref.requant_ref(jnp.asarray(acc), shift, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


def test_basic_shift_clip():
    acc = np.array([1000, -1000, 64, -64, 0, 8191, -8192], dtype=np.int32)
    run_case(acc, 6, False)
    run_case(acc, 6, True)


def test_shift_zero_saturates():
    acc = np.array([300, -300, 127, -128], dtype=np.int32)
    run_case(acc, 0, False)


def test_arithmetic_shift_of_negatives():
    # -1 >> s stays -1 (arithmetic), never 0 (logical).
    acc = np.array([-1, -2, -3, -255], dtype=np.int32)
    run_case(acc, 4, False)


def test_non_multiple_length_padding_path():
    acc = np.arange(-500, 501, 7, dtype=np.int32)  # length 143
    run_case(acc, 3, False, block=64)


def test_multidimensional_input():
    acc = np.arange(-2048, 2048, dtype=np.int32).reshape(4, 32, 32)
    run_case(acc, 5, True)


@pytest.mark.parametrize("shift", [0, 1, 4, 7, 15])
@pytest.mark.parametrize("relu", [False, True])
def test_shift_relu_grid(shift, relu):
    rng = np.random.default_rng(shift * 2 + relu)
    acc = rng.integers(-(2**20), 2**20, (777,), dtype=np.int32)
    run_case(acc, shift, relu)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    shift=st.integers(0, 20),
    relu=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_property_random(n, shift, relu, seed):
    rng = np.random.default_rng(seed)
    acc = rng.integers(np.iinfo(np.int32).min, np.iinfo(np.int32).max, (n,), dtype=np.int32)
    run_case(acc, shift, relu)
