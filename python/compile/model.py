"""L2: the quantized ResNet-18 model and per-operator CPU kernels in JAX.

Bit-exact twins of the Rust semantics (``compiler::reference``,
``exec::cpu_ops``): int8 activations/weights, int32 accumulation,
arithmetic-shift requantization, saturating residual adds, truncating
global-average-pool division.

Two convolution backends:

* ``backend="lax"`` — ``lax.conv_general_dilated`` in int32. This is the
  CPU-resident operator path (the paper's ARM-side kernels), used for
  the per-op artifacts and the CPU-only baseline model.
* ``backend="pallas"`` — im2col (the L2 schedule step, playing the role
  of TVM's layout transform) feeding the L1 Pallas GEMM intrinsic and
  the Pallas requant ALU kernel. This is the path that lowers the
  paper's compute hot-spot through the kernel layer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import alu as alu_kernel
from .kernels import gemm as gemm_kernel
from .kernels import ref as kref


# ----------------------------------------------------------------------
# Padding geometry (must match rust `Conv2dParams::pad`).
# ----------------------------------------------------------------------

def same_padding(h: int, k: int, s: int) -> tuple[int, int]:
    """(pad_begin, pad_end) for SAME conv, mirroring the Rust planner."""
    oh = -(-h // s)
    total = max((oh - 1) * s + k - h, 0)
    pb = total // 2
    pe = max(total - pb, 0)
    return pb, pe


# ----------------------------------------------------------------------
# Quantized operators.
# ----------------------------------------------------------------------

def qconv2d(x, w, *, stride: int, shift: int, relu: bool, backend: str = "lax"):
    """int8 NCHW conv → int8, SAME padding, VTA requant epilogue."""
    k = w.shape[2]
    h = x.shape[2]
    pb, pe = same_padding(h, k, stride)
    if backend == "lax":
        acc = jax.lax.conv_general_dilated(
            x.astype(jnp.int32),
            w.astype(jnp.int32),
            window_strides=(stride, stride),
            padding=((pb, pe), (pb, pe)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        return kref.requant_ref(acc, shift, relu)
    if backend == "pallas":
        return _qconv2d_pallas(x, w, stride=stride, shift=shift, relu=relu, pb=pb, pe=pe)
    raise ValueError(f"unknown backend {backend!r}")


def _qconv2d_pallas(x, w, *, stride, shift, relu, pb, pe):
    """im2col + Pallas GEMM + Pallas requant (the L2 → L1 path)."""
    n, c, h, wd = x.shape
    oc, _, k, _ = w.shape
    oh = (h + pb + pe - k) // stride + 1
    ow = (wd + pb + pe - k) // stride + 1

    # L2 schedule step: extract (C*K*K)-wide patches (layout transform).
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.int8),
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding=((pb, pe), (pb, pe)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*K*K, OH, OW)
    m = n * oh * ow
    ckk = c * k * k
    a = patches.transpose(0, 2, 3, 1).reshape(m, ckk)
    wm = w.reshape(oc, ckk)

    # Pad every dimension to the 16-tile intrinsic (zero padding is
    # exact for integer dot products).
    pad_m, pad_k, pad_n = (-m) % 16, (-ckk) % 16, (-oc) % 16
    a = jnp.pad(a, ((0, pad_m), (0, pad_k)))
    wm = jnp.pad(wm, ((0, pad_n), (0, pad_k)))

    acc = gemm_kernel.gemm(a, wm)  # L1 intrinsic
    out = alu_kernel.requant(acc, shift=shift, relu=relu)  # L1 ALU
    out = out[:m, :oc].reshape(n, oh, ow, oc).transpose(0, 3, 1, 2)
    return out


def maxpool(x, *, k: int, s: int, pad: int):
    """int8 max pooling; padded taps hold i8::MIN (skipped in effect)."""
    return jax.lax.reduce_window(
        x,
        jnp.int8(-128),
        jax.lax.max,
        window_dimensions=(1, 1, k, k),
        window_strides=(1, 1, s, s),
        padding=((0, 0), (0, 0), (pad, pad), (pad, pad)),
    )


def global_avg_pool(x):
    """NCHW int8 → [N, C] int8, truncating (toward-zero) mean."""
    n, c, h, w = x.shape
    s = jnp.sum(x.astype(jnp.int32), axis=(2, 3))
    mean = jax.lax.div(s, jnp.int32(h * w))  # trunc toward zero, as in Rust
    return jnp.clip(mean, -128, 127).astype(jnp.int8)


def add_sat(a, b):
    """Saturating int8 residual addition."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, -128, 127).astype(jnp.int8)


def dense(x, w, *, shift: int, relu: bool):
    """int8 dense layer: requant(x @ w^T)."""
    return kref.matmul_requant_ref(x, w, shift, relu)


# ----------------------------------------------------------------------
# The full model.
# ----------------------------------------------------------------------

LAYER_SHIFT = 6  # mirror of graph::resnet::LAYER_SHIFT

#: Canonical parameter order of the full-model artifact: the creation
#: order of parametric nodes in ``graph::resnet::resnet18`` (the Rust
#: side feeds weights in exactly this order).
WEIGHT_ORDER: list[str] = (
    ["conv1"]
    + [
        f"layer{stage + 1}.{block}.{part}"
        for stage in range(4)
        for block in range(2)
        for part in (["conv1", "conv2", "downsample"] if block == 0 else ["conv1", "conv2"])
    ]
    + ["fc"]
)

#: Parameter shapes matching WEIGHT_ORDER.
def weight_shapes() -> list[tuple[str, tuple[int, ...]]]:
    shapes: list[tuple[str, tuple[int, ...]]] = [("conv1", (64, 3, 7, 7))]
    in_ch = 64
    for stage, out_ch in enumerate([64, 128, 256, 512]):
        for block in range(2):
            pre = f"layer{stage + 1}.{block}"
            shapes.append((f"{pre}.conv1", (out_ch, in_ch, 3, 3)))
            shapes.append((f"{pre}.conv2", (out_ch, out_ch, 3, 3)))
            if block == 0:
                shapes.append((f"{pre}.downsample", (out_ch, in_ch, 1, 1)))
            in_ch = out_ch
    shapes.append(("fc", (1000, 512)))
    return shapes


def resnet18_forward(x, weights: dict, *, backend: str = "lax"):
    """Quantized ResNet-18 forward pass, the fused-graph twin of
    ``graph::resnet::resnet18`` + ``graph::fusion::fuse``.

    ``weights`` maps Rust node names to OIHW int8 arrays (see
    ``synth.resnet18_weights``). Returns int8 logits ``[N, 1000]``.
    """
    sh = LAYER_SHIFT

    def conv(name, x, *, stride, relu):
        return qconv2d(x, weights[name], stride=stride, shift=sh, relu=relu, backend=backend)

    x = conv("conv1", x, stride=2, relu=True)
    x = maxpool(x, k=3, s=2, pad=1)

    in_ch = 64
    for stage, out_ch in enumerate([64, 128, 256, 512]):
        for block in range(2):
            stride = 2 if stage > 0 and block == 0 else 1
            pre = f"layer{stage + 1}.{block}"
            a = conv(f"{pre}.conv1", x, stride=stride, relu=True)
            b = conv(f"{pre}.conv2", a, stride=1, relu=False)
            if block == 0:
                short = conv(f"{pre}.downsample", x, stride=stride, relu=False)
            else:
                short = x
            x = jnp.maximum(add_sat(b, short), 0)  # add + relu
            in_ch = out_ch
    del in_ch

    x = global_avg_pool(x)
    return dense(x, weights["fc"], shift=sh, relu=False)


# ----------------------------------------------------------------------
# NumPy twins (used by pytest to validate the jnp ops independently).
# ----------------------------------------------------------------------

def np_requant(acc: np.ndarray, shift: int, relu: bool) -> np.ndarray:
    lo = 0 if relu else -128
    return np.clip(acc >> shift, lo, 127).astype(np.int8)


def np_conv2d(x: np.ndarray, w: np.ndarray, stride: int, shift: int, relu: bool) -> np.ndarray:
    n, c, h, wd = x.shape
    oc, _, k, _ = w.shape
    pb, _ = same_padding(h, k, stride)
    oh, ow = -(-h // stride), -(-wd // stride)
    out = np.zeros((n, oc, oh, ow), dtype=np.int8)
    xi = x.astype(np.int32)
    wi = w.astype(np.int32)
    for nn in range(n):
        for o in range(oc):
            for y in range(oh):
                for xx in range(ow):
                    acc = 0
                    for ky in range(k):
                        for kx in range(k):
                            iy = y * stride + ky - pb
                            ix = xx * stride + kx - pb
                            if 0 <= iy < h and 0 <= ix < wd:
                                acc += int(np.dot(xi[nn, :, iy, ix], wi[o, :, ky, kx]))
                    out[nn, o, y, xx] = np_requant(np.int32(acc), shift, relu)
    return out
