"""Deterministic synthetic tensors, bit-identical to the Rust side.

The Rust stack (`rust/src/util/rng.rs`, `rust/src/graph/resnet.rs`)
synthesizes int8 weights and inputs with a xorshift64* PRNG. This module
reimplements the exact same sequences so the JAX-lowered artifacts and
the Rust-native execution operate on identical data — the cross-language
equivalence tests depend on it.
"""

from __future__ import annotations

import numpy as np

MASK = (1 << 64) - 1


class XorShiftRng:
    """xorshift64* — mirrors ``rust/src/util/rng.rs``."""

    def __init__(self, seed: int) -> None:
        self.state = seed & MASK if seed != 0 else 0x9E3779B97F4A7C15

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & MASK
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK

    def next_below(self, n: int) -> int:
        return self.next_u64() % max(n, 1)

    def next_i8_in(self, lo: int, hi: int) -> int:
        span = hi - lo + 1
        return lo + self.next_below(span)

    def vec_i8(self, n: int, lo: int, hi: int) -> np.ndarray:
        return np.array([self.next_i8_in(lo, hi) for _ in range(n)], dtype=np.int8)


def synth_conv_weights(seed: int, oc: int, ic: int, k: int) -> np.ndarray:
    """Mirror of ``graph::resnet::synth_conv_weights`` (OIHW int8)."""
    rng = XorShiftRng(seed)
    return rng.vec_i8(oc * ic * k * k, -4, 4).reshape(oc, ic, k, k)


def synth_input(seed: int, n: int, c: int, h: int, w: int) -> np.ndarray:
    """Mirror of ``graph::resnet::synth_input`` (NCHW int8)."""
    rng = XorShiftRng(seed)
    return rng.vec_i8(n * c * h * w, -16, 16).reshape(n, c, h, w)


class SeedChain:
    """Mirror of the weight-seed LCG in ``graph::resnet::resnet18``."""

    def __init__(self, seed: int) -> None:
        self.state = seed & MASK

    def next(self) -> int:
        self.state = (self.state * 6364136223846793005 + 1442695040888963407) & MASK
        return self.state


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit hash — mirrored in Rust for the cross-language
    weight-equivalence check (``artifacts/weights_digest.txt``)."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & MASK
    return h


def resnet18_weights(seed: int = 42) -> dict[str, np.ndarray]:
    """All ResNet-18 parameter tensors, keyed by the Rust node names.

    Creation order must match ``graph::resnet::resnet18`` exactly:
    conv1, then per stage/block conv1, conv2, (projection for block 0),
    finally fc.
    """
    chain = SeedChain(seed)
    weights: dict[str, np.ndarray] = {}
    weights["conv1"] = synth_conv_weights(chain.next(), 64, 3, 7)
    in_ch, hw = 64, 56
    for stage, out_ch in enumerate([64, 128, 256, 512]):
        for block in range(2):
            stride = 2 if stage > 0 and block == 0 else 1
            pre = f"layer{stage + 1}.{block}"
            weights[f"{pre}.conv1"] = synth_conv_weights(chain.next(), out_ch, in_ch, 3)
            weights[f"{pre}.conv2"] = synth_conv_weights(chain.next(), out_ch, out_ch, 3)
            if block == 0:
                weights[f"{pre}.downsample"] = synth_conv_weights(
                    chain.next(), out_ch, in_ch, 1
                )
            in_ch = out_ch
            hw = -(-hw // stride)
    rng = XorShiftRng(chain.next())
    weights["fc"] = rng.vec_i8(512_000, -4, 4).reshape(1000, 512)
    return weights
