"""L1: the VTA tensor-ALU requant epilogue as a Pallas kernel.

Mirrors the three-instruction ALU sequence the Rust compiler emits after
every GEMM (SHR imm → MAX imm → MIN imm, Fig 8), fused into one
elementwise pass over register-file tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _requant_kernel(acc_ref, out_ref, *, shift: int, relu: bool):
    v = jnp.right_shift(acc_ref[...], jnp.int32(shift))  # ALU SHR
    lo = 0 if relu else -128
    v = jnp.maximum(v, lo)  # ALU MAX (ReLU when lo == 0)
    v = jnp.minimum(v, 127)  # ALU MIN
    out_ref[...] = v.astype(jnp.int8)  # narrowing acc → out buffer


@functools.partial(jax.jit, static_argnames=("shift", "relu", "block"))
def requant(acc, *, shift: int, relu: bool, block: int = 256):
    """Requantize an int32 accumulator tensor to int8.

    Flattens to 1D and sweeps ``block``-element tiles — the tensor ALU's
    vector-lane pass over register-file tiles (§2.5).
    """
    flat = acc.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = pl.pallas_call(
        functools.partial(_requant_kernel, shift=shift, relu=relu),
        grid=(flat.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.int8),
        interpret=True,
    )(flat)
    return out[:n].reshape(acc.shape)
