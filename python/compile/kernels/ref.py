"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Semantics are the VTA hardware's (bit-exact against the Rust simulator):
int8 operands, int32 accumulation, arithmetic-shift requantization with
saturation into the int8 output range.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(inp, wgt):
    """``acc[m, n] = sum_k inp[m, k] * wgt[n, k]`` in int32.

    ``inp``: (M, K) int8, ``wgt``: (N, K) int8 → (M, N) int32. The
    weight matrix is row-major over output features, matching the VTA
    weight-tile layout (Fig 7: ``wgt[o][k]``).
    """
    return jnp.dot(
        inp.astype(jnp.int32),
        wgt.astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )


def requant_ref(acc, shift: int, relu: bool):
    """VTA ALU requant epilogue: SHR + clip (Fig 8 / Rust `Requant`).

    ``acc``: int32 → int8. Arithmetic right shift, then clamp to
    ``[0, 127]`` (relu) or ``[-128, 127]``.
    """
    lo = 0 if relu else -128
    shifted = jnp.right_shift(acc, jnp.int32(shift))
    return jnp.clip(shifted, lo, 127).astype(jnp.int8)


def matmul_requant_ref(inp, wgt, shift: int, relu: bool):
    """Fused reference: requant(gemm(inp, wgt))."""
    return requant_ref(gemm_ref(inp, wgt), shift, relu)
