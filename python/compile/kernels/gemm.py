"""L1: the VTA GEMM-core intrinsic as a Pallas kernel.

The hardware computes, per cycle, one ``BATCH x BLOCK_IN x BLOCK_OUT``
int8 matmul accumulated into an int32 register-file tile (Fig 7). This
kernel expresses the same contraction as a Pallas grid:

* the grid's ``(m, n, k)`` axes mirror the two CISC loop levels plus the
  micro-op sequence over input-channel blocks;
* ``BlockSpec`` index maps stage ``(BM, BK)`` / ``(BN, BK)`` operand
  tiles into VMEM — the HBM→VMEM schedule standing in for the LOAD
  module's DRAM→SRAM DMA;
* the ``@pl.when(k == 0)`` zero-init is the GEMM reset micro-op, and the
  accumulation across the ``k`` grid dimension is the register-file
  accumulate.

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): on a real TPU the
dot below maps onto the MXU with int8 operands widening to int32 — the
same widening discipline as VTA's 8-bit GEMM core with 32-bit
accumulators. ``interpret=True`` is mandatory here: the CPU PJRT client
cannot execute Mosaic custom-calls, and correctness (not wallclock) is
what the interpret path validates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(inp_ref, wgt_ref, acc_ref):
    """One grid step: acc[BM, BN] += inp[BM, BK] @ wgt[BN, BK]^T."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _reset():  # the GEMM-reset micro-op
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = inp_ref[...].astype(jnp.int32)
    w = wgt_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a,
        w,
        (((1,), (1,)), ((), ())),  # contract the K axis of both
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(inp, wgt, *, bm: int = 16, bn: int = 16, bk: int = 16):
    """``acc[M, N] int32 = inp[M, K] i8 x wgt[N, K]^T i8`` via Pallas.

    ``bm``/``bn``/``bk`` are the VMEM tile sizes; defaults mirror the
    Pynq GEMM core (BLOCK_IN = BLOCK_OUT = 16). Dimensions must be
    multiples of the tile sizes (the compiler pads tensors first, just
    as the Rust layout pass pads channel blocks).
    """
    m, k = inp.shape
    n, k2 = wgt.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({n},{k}) not tiled by ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(inp, wgt)
