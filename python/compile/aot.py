"""AOT lowering: JAX → HLO **text** artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly.

Artifacts (all lowered with ``return_tuple=True``):

* per-operator CPU kernels, named by the scheme in
  ``rust/src/compiler/op.rs`` (each operator's ``VtaOp::artifact_name``;
  weights are runtime parameters, appended after the activations);
* ``resnet18_cpu`` — the full CPU-only quantized model, weights as
  parameters in ``model.WEIGHT_ORDER`` (the Fig 16 baseline);
* ``gemm_pallas_*`` / ``requant_pallas_*`` / ``conv_pallas_*`` — the L1
  Pallas kernels lowered standalone, which the Rust integration tests
  execute against the behavioral simulator.

Run: ``python -m compile.aot --out ../artifacts`` (from ``python/``).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import alu as alu_kernel
from .kernels import gemm as gemm_kernel

S8 = jnp.int8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, name: str, fn, *args) -> None:
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}.hlo.txt ({len(text) / 1024:.0f} KiB)")


def spec(shape, dtype=S8):
    return jax.ShapeDtypeStruct(shape, dtype)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--skip-resnet",
        action="store_true",
        help="skip the full-model artifact (fast dev builds)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    sh = model.LAYER_SHIFT
    print(f"lowering artifacts to {args.out}:")

    # ---- per-operator CPU kernels (weights as parameters) -------------
    # conv C1 with fused relu: conv_{h}_{ic}_{oc}_{k}_{s}_{relu}
    emit(
        args.out,
        "conv_224_3_64_7_2_1",
        lambda x, w: (model.qconv2d(x, w, stride=2, shift=sh, relu=True),),
        spec((1, 3, 224, 224)),
        spec((64, 3, 7, 7)),
    )
    emit(
        args.out,
        "maxpool_1x64x56x56_3_2",
        lambda x: (model.maxpool(x, k=3, s=2, pad=1),),
        spec((1, 64, 112, 112)),
    )
    for c, hw in [(64, 56), (128, 28), (256, 14), (512, 7)]:
        emit(
            args.out,
            f"add_1x{c}x{hw}x{hw}",
            lambda a, b: (model.add_sat(a, b),),
            spec((1, c, hw, hw)),
            spec((1, c, hw, hw)),
        )
    emit(args.out, "gap_1x512", lambda x: (model.global_avg_pool(x),), spec((1, 512, 7, 7)))
    emit(
        args.out,
        "dense_1_512_1000",
        lambda x, w: (model.dense(x, w, shift=sh, relu=False),),
        spec((1, 512)),
        spec((1000, 512)),
    )

    # ---- L1 Pallas kernels, standalone ---------------------------------
    emit(
        args.out,
        "gemm_pallas_64_64_64",
        lambda a, w: (gemm_kernel.gemm(a, w),),
        spec((64, 64)),
        spec((64, 64)),
    )
    emit(
        args.out,
        "requant_pallas_1024_6_1",
        lambda acc: (alu_kernel.requant(acc, shift=6, relu=True),),
        spec((1024,), jnp.int32),
    )
    # A pallas-backed conv (C2 geometry on a 14x14 crop): the L2→L1 path
    # in one artifact, cross-checked against the VTA simulator from Rust.
    emit(
        args.out,
        "conv_pallas_14_64_64_3_1",
        lambda x, w: (
            model.qconv2d(x, w, stride=1, shift=sh, relu=False, backend="pallas"),
        ),
        spec((1, 64, 14, 14)),
        spec((64, 64, 3, 3)),
    )

    # ---- the full CPU-only model --------------------------------------
    # Weights are PARAMETERS in model.WEIGHT_ORDER (HLO text elides
    # large constants as `constant({...})`, so baking them is not an
    # option — the Rust side synthesizes the identical tensors and feeds
    # them in order).
    if not args.skip_resnet:
        wspecs = [spec(s) for (_, s) in model.weight_shapes()]
        emit(
            args.out,
            "resnet18_cpu",
            _resnet_fn,
            spec((1, 3, 224, 224)),
            *wspecs,
        )

    # Cross-language weight-equivalence digest: the Rust integration
    # tests synthesize the same tensors and must reproduce these hashes.
    if not args.skip_resnet:
        from . import synth

        print("  hashing synthetic ResNet-18 weights (xorshift64*, seed 42)...")
        ws = synth.resnet18_weights(42)
        with open(os.path.join(args.out, "weights_digest.txt"), "w") as f:
            f.write(f"input {synth.fnv1a64(synth.synth_input(7, 1, 3, 224, 224).tobytes()):016x}\n")
            for name in model.WEIGHT_ORDER:
                f.write(f"{name} {synth.fnv1a64(ws[name].tobytes()):016x}\n")
        print("  weights_digest.txt")

    # Stamp for the Makefile.
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("done.")


def _resnet_fn(x, *ws):
    weights = dict(zip(model.WEIGHT_ORDER, ws))
    return (model.resnet18_forward(x, weights, backend="lax"),)


if __name__ == "__main__":
    main()
