//! Multi-device determinism: a pool of 1 / 2 / 4 accelerator replicas
//! driven by the dynamic-batching [`Scheduler`] must produce outputs
//! **bit-exact** with the single-device [`ServingEngine`] — on a
//! resnet-family graph and the style-transfer graph, across
//! virtual-thread modes (vt = 1 / 2) and partition policies (paper
//! conv-only rule vs offload-all). Execution is exact in this stack;
//! only the timing is modeled — pool size must never leak into
//! results.

use vta::arch::VtaConfig;
use vta::compiler::{Conv2dParams, MatmulParams, Requant};
use vta::exec::{CpuBackend, Scheduler, SchedulerOptions, ServingEngine};
use vta::graph::style::style_net;
use vta::graph::{partition, Graph, Op, PartitionPolicy};
use vta::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

fn conv_p(h: usize, ic: usize, oc: usize, relu: bool) -> Conv2dParams {
    Conv2dParams { h, w: h, ic, oc, k: 3, s: 1, requant: Requant { shift: 6, relu } }
}

/// A miniature ResNet: conv stem, two residual basic blocks, global
/// average pooling, dense classifier — the ResNet-18 topology at test
/// scale (16x16 input, 16 channels), deterministic in its weight seed.
fn mini_resnet(wseed: u64) -> Graph {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 3, 16, 16] }, &[]).unwrap();
    let stem = g.add("stem", Op::Conv2d { p: conv_p(16, 3, 16, true) }, &[x]).unwrap();
    g.set_weights(stem, rand_t(wseed, &[16, 3, 3, 3]));
    let mut cur = stem;
    for b in 0u64..2 {
        let c1 = g
            .add(&format!("b{b}c1"), Op::Conv2d { p: conv_p(16, 16, 16, true) }, &[cur])
            .unwrap();
        g.set_weights(c1, rand_t(wseed + 10 + b * 2, &[16, 16, 3, 3]));
        let c2 = g
            .add(&format!("b{b}c2"), Op::Conv2d { p: conv_p(16, 16, 16, false) }, &[c1])
            .unwrap();
        g.set_weights(c2, rand_t(wseed + 11 + b * 2, &[16, 16, 3, 3]));
        let add = g.add(&format!("b{b}add"), Op::Add, &[c2, cur]).unwrap();
        cur = g.add(&format!("b{b}relu"), Op::Relu, &[add]).unwrap();
    }
    let gap = g.add("gap", Op::GlobalAvgPool, &[cur]).unwrap();
    let p = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
    let fc = g.add("fc", Op::Dense { p }, &[gap]).unwrap();
    g.set_weights(fc, rand_t(wseed + 99, &[10, 16]));
    g
}

/// The shared matrix: for every (vt, policy) cell, serve the same
/// 6-request stream through the single-device engine (the reference)
/// and through pools of 1 / 2 / 4 replicas; every output must be
/// bit-identical, and the pool must have compiled each plan exactly
/// once.
fn check_pool_determinism<F: Fn() -> Graph>(name: &str, build: F, size: usize) {
    let cfg = VtaConfig::pynq();
    let inputs: Vec<_> = (0..6).map(|i| rand_t(3000 + i as u64, &[1, 3, size, size])).collect();
    for vt in [1usize, 2] {
        for offload_all in [false, true] {
            let mut g = build();
            let mut policy = if offload_all {
                PartitionPolicy::offload_all(&cfg)
            } else {
                PartitionPolicy::paper(&cfg)
            };
            policy.virtual_threads = vt;
            let (vta_nodes, _) = partition(&mut g, &policy);
            assert!(vta_nodes > 0, "{name} vt={vt} offload_all={offload_all}: nothing offloaded");

            // Single-device engine: the reference behavior.
            let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, vt, 64);
            let batch = eng.run_batch(&g, &inputs).unwrap();
            let expect = batch.outputs;
            let unique_plans = batch.cache.misses;

            for devices in [1usize, 2, 4] {
                let opts = SchedulerOptions {
                    devices,
                    max_batch: 2,
                    batch_deadline: 0.0,
                    cache_capacity: 64,
                    virtual_threads: vt,
                    dram_size: 256 << 20,
                };
                let mut sched = Scheduler::new(&cfg, CpuBackend::Native, opts);
                for input in &inputs {
                    sched.submit(0.0, input.clone());
                }
                let r = sched.run(&g).unwrap();
                assert_eq!(r.outputs.len(), inputs.len());
                for (i, out) in r.outputs.iter().enumerate() {
                    assert_eq!(
                        out, &expect[i],
                        "{name} vt={vt} offload_all={offload_all} devices={devices}: \
                         request {i} diverged from the single-device engine"
                    );
                }
                // The shared compile-once path: pool-level misses equal
                // the engine's unique-plan count, independent of pool
                // size.
                assert_eq!(
                    r.cache.misses, unique_plans,
                    "{name} vt={vt} offload_all={offload_all} devices={devices}: \
                     pool must compile each plan exactly once"
                );
            }
        }
    }
}

#[test]
fn resnet_pool_outputs_are_bit_exact_across_pool_sizes() {
    check_pool_determinism("mini-resnet", || mini_resnet(7), 16);
}

#[test]
fn style_pool_outputs_are_bit_exact_across_pool_sizes() {
    check_pool_determinism("style", || style_net(1, 16, 16, 42).unwrap(), 16);
}
