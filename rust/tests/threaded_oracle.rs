//! Real-threads oracle equivalence: the threaded pool (one OS worker
//! per replica, bounded queue, publish-barrier plan directory) must
//! reproduce the simulated-time [`Scheduler`] **bit-exactly** — on a
//! resnet-family graph and the style-transfer graph, across
//! virtual-thread modes (vt = 1 / 2), partition policies (paper
//! conv-only rule vs offload-all), and thread counts (1 / 2 / 4).
//! Execution is exact in this stack; real concurrency must never leak
//! into results, and the pool-level plan-directory counters (misses =
//! unique plans, compiled once per pool; hits = the rest of the
//! lookups) must land exactly where the simulated oracle's lockstep
//! caches do.

use vta::arch::VtaConfig;
use vta::compiler::{Conv2dParams, MatmulParams, Requant};
use vta::dse::TuningRecords;
use vta::exec::{
    serve_trace, CpuBackend, Scheduler, SchedulerOptions, ServingEngine, ThreadedOptions,
};
use vta::graph::style::style_net;
use vta::graph::{partition, Graph, Op, PartitionPolicy};
use vta::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

fn conv_p(h: usize, ic: usize, oc: usize, relu: bool) -> Conv2dParams {
    Conv2dParams { h, w: h, ic, oc, k: 3, s: 1, requant: Requant { shift: 6, relu } }
}

/// A miniature ResNet: conv stem, two residual basic blocks, global
/// average pooling, dense classifier — the ResNet-18 topology at test
/// scale (16x16 input, 16 channels), deterministic in its weight seed.
fn mini_resnet(wseed: u64) -> Graph {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 3, 16, 16] }, &[]).unwrap();
    let stem = g.add("stem", Op::Conv2d { p: conv_p(16, 3, 16, true) }, &[x]).unwrap();
    g.set_weights(stem, rand_t(wseed, &[16, 3, 3, 3]));
    let mut cur = stem;
    for b in 0u64..2 {
        let c1 = g
            .add(&format!("b{b}c1"), Op::Conv2d { p: conv_p(16, 16, 16, true) }, &[cur])
            .unwrap();
        g.set_weights(c1, rand_t(wseed + 10 + b * 2, &[16, 16, 3, 3]));
        let c2 = g
            .add(&format!("b{b}c2"), Op::Conv2d { p: conv_p(16, 16, 16, false) }, &[c1])
            .unwrap();
        g.set_weights(c2, rand_t(wseed + 11 + b * 2, &[16, 16, 3, 3]));
        let add = g.add(&format!("b{b}add"), Op::Add, &[c2, cur]).unwrap();
        cur = g.add(&format!("b{b}relu"), Op::Relu, &[add]).unwrap();
    }
    let gap = g.add("gap", Op::GlobalAvgPool, &[cur]).unwrap();
    let p = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
    let fc = g.add("fc", Op::Dense { p }, &[gap]).unwrap();
    g.set_weights(fc, rand_t(wseed + 99, &[10, 16]));
    g
}

/// The shared matrix: for every (vt, policy) cell, serve the same
/// 6-request trace through the single-device engine (the plan-count
/// reference), the simulated scheduler (the oracle), and threaded
/// pools of 1 / 2 / 4 workers. Every threaded output must be
/// bit-identical to the oracle's in submission order, and the plan
/// directory's hit/miss totals must equal the oracle's exactly.
fn check_threaded_oracle<F: Fn() -> Graph>(name: &str, build: F, size: usize) {
    let cfg = VtaConfig::pynq();
    let records = TuningRecords::new();
    let inputs: Vec<_> = (0..6).map(|i| rand_t(3000 + i as u64, &[1, 3, size, size])).collect();
    for vt in [1usize, 2] {
        for offload_all in [false, true] {
            let mut g = build();
            let mut policy = if offload_all {
                PartitionPolicy::offload_all(&cfg)
            } else {
                PartitionPolicy::paper(&cfg)
            };
            policy.virtual_threads = vt;
            let (vta_nodes, _) = partition(&mut g, &policy);
            assert!(vta_nodes > 0, "{name} vt={vt} offload_all={offload_all}: nothing offloaded");

            // Single-device engine: the unique-plan reference.
            let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, vt, 64);
            let batch = eng.run_batch(&g, &inputs).unwrap();
            let unique_plans = batch.cache.misses;

            // Simulated scheduler: the deterministic oracle.
            let opts = SchedulerOptions {
                devices: 1,
                max_batch: 2,
                batch_deadline: 0.0,
                cache_capacity: 64,
                virtual_threads: vt,
                dram_size: 256 << 20,
            };
            let mut sched = Scheduler::new(&cfg, CpuBackend::Native, opts);
            for input in &inputs {
                sched.submit(0.0, input.clone());
            }
            let oracle = sched.run(&g).unwrap();
            for (i, out) in oracle.outputs.iter().enumerate() {
                assert_eq!(
                    out, &batch.outputs[i],
                    "{name} vt={vt} offload_all={offload_all}: \
                     oracle diverged from the engine at request {i}"
                );
            }
            assert_eq!(
                oracle.cache.misses, unique_plans,
                "{name} vt={vt} offload_all={offload_all}: oracle must compile once per plan"
            );

            for threads in [1usize, 2, 4] {
                let mut topts = ThreadedOptions::new(threads);
                topts.virtual_threads = vt;
                topts.max_batch = 2;
                topts.dram_size = 256 << 20;
                let r = serve_trace(&cfg, &topts, &records, &g, &inputs).unwrap();

                // Bit-exactness, order-independent: outputs come back
                // keyed by submission id no matter which worker served
                // them or in what order they finished.
                assert_eq!(
                    r.outputs.len(),
                    inputs.len(),
                    "{name} vt={vt} offload_all={offload_all} threads={threads}: \
                     lost or duplicated responses"
                );
                for (i, out) in r.outputs.iter().enumerate() {
                    assert_eq!(
                        out, &oracle.outputs[i],
                        "{name} vt={vt} offload_all={offload_all} threads={threads}: \
                         request {i} diverged from the simulated oracle"
                    );
                }

                // Compile-once per pool: directory misses equal the
                // engine's unique-plan count regardless of how many
                // workers raced for the publish barrier — and the
                // hit/miss totals match the oracle's lockstep caches.
                assert_eq!(
                    r.cache.misses, unique_plans,
                    "{name} vt={vt} offload_all={offload_all} threads={threads}: \
                     pool must compile each plan exactly once"
                );
                assert_eq!(
                    (r.cache.misses, r.cache.hits),
                    (oracle.cache.misses, oracle.cache.hits),
                    "{name} vt={vt} offload_all={offload_all} threads={threads}: \
                     plan-directory counters fell out of step with the oracle"
                );
                assert_eq!(r.accepted, inputs.len() as u64);
                assert_eq!(r.rejected, 0, "closed-loop trace must shed nothing");
                let served: u64 = r.threads.iter().map(|t| t.requests).sum();
                assert_eq!(
                    served,
                    inputs.len() as u64,
                    "{name} threads={threads}: per-worker counters must sum to the trace"
                );
            }
        }
    }
}

#[test]
fn resnet_threaded_pool_matches_the_simulated_oracle() {
    check_threaded_oracle("mini-resnet", || mini_resnet(7), 16);
}

#[test]
fn style_threaded_pool_matches_the_simulated_oracle() {
    check_threaded_oracle("style", || style_net(1, 16, 16, 42).unwrap(), 16);
}
