//! Config-matrix equivalence: conv2d + dense + Add/ReLU + the
//! style-transfer operator classes (Upsample2x, Min/Shr requant-epilogue
//! steps) VTA-vs-reference checks across a sampled grid of hardware
//! variants (GEMM geometry, SRAM depths, virtual threads), so
//! DSE-generated configs are trusted end-to-end — not just the
//! hand-picked `pynq()` point.
//!
//! Method: one mixed graph (conv → conv → residual add → shr → min →
//! relu → upsample2x → gap → dense) sized relative to each variant's
//! GEMM geometry, executed twice — everything offloaded vs everything
//! on the CPU reference kernels — and compared bit-for-bit.

use vta::arch::{GemmShape, VtaConfig};
use vta::compiler::{Conv2dParams, MatmulParams, Requant};
use vta::exec::{CpuBackend, Executor};
use vta::graph::{partition, Graph, Op, PartitionPolicy, Placement};
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

/// The sampled config grid: GEMM shapes off the diagonal, shallow and
/// deep SRAM variants, both virtual-thread modes.
fn config_grid() -> Vec<(&'static str, VtaConfig, usize)> {
    fn variant(edit: fn(&mut VtaConfig)) -> VtaConfig {
        let mut c = VtaConfig::pynq();
        edit(&mut c);
        c
    }
    vec![
        ("pynq-vt2", VtaConfig::pynq(), 2),
        ("pynq-vt1", VtaConfig::pynq(), 1),
        (
            "gemm8x8-vt2",
            variant(|c| {
                c.gemm = GemmShape { batch: 1, block_in: 8, block_out: 8 };
                c.alu_lanes = 8;
            }),
            2,
        ),
        (
            "gemm32x32-vt1",
            variant(|c| {
                c.gemm = GemmShape { batch: 1, block_in: 32, block_out: 32 };
                c.alu_lanes = 32;
            }),
            1,
        ),
        (
            "gemm8x16-vt2",
            variant(|c| c.gemm = GemmShape { batch: 1, block_in: 8, block_out: 16 }),
            2,
        ),
        (
            "gemm16x8-vt1",
            variant(|c| {
                c.gemm = GemmShape { batch: 1, block_in: 16, block_out: 8 };
                c.alu_lanes = 8;
            }),
            1,
        ),
        (
            "shallow-srams-vt2",
            variant(|c| {
                c.inp_buf_bytes = 16 * 1024;
                c.wgt_buf_bytes = 128 * 1024;
                c.acc_buf_bytes = 64 * 1024;
                c.out_buf_bytes = 16 * 1024;
                c.uop_buf_bytes = 4 * 1024;
            }),
            2,
        ),
        (
            "deep-srams-vt2",
            variant(|c| {
                c.inp_buf_bytes = 64 * 1024;
                c.acc_buf_bytes = 256 * 1024;
                c.out_buf_bytes = 64 * 1024;
                c.uop_buf_bytes = 32 * 1024;
            }),
            2,
        ),
    ]
}

/// A mixed graph exercising every offloadable operator class, sized
/// relative to the variant's GEMM geometry so channel counts always
/// span multiple blocks.
fn mixed_graph(cfg: &VtaConfig, seed: u64) -> Graph {
    let ic = 2 * cfg.gemm.block_in;
    let oc = 2 * cfg.gemm.block_out;
    let rq = |relu: bool| Requant { shift: 4, relu };
    let mut rng = XorShiftRng::new(seed);

    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, ic, 8, 8] }, &[]).unwrap();
    let p1 = Conv2dParams { h: 8, w: 8, ic, oc, k: 3, s: 1, requant: rq(true) };
    let c1 = g.add("conv1", Op::Conv2d { p: p1 }, &[x]).unwrap();
    g.set_weights(c1, Tensor::from_vec(&[oc, ic, 3, 3], rng.vec_i8(oc * ic * 9, -3, 3)).unwrap());
    let p2 = Conv2dParams { h: 8, w: 8, ic: oc, oc, k: 3, s: 1, requant: rq(false) };
    let c2 = g.add("conv2", Op::Conv2d { p: p2 }, &[c1]).unwrap();
    g.set_weights(c2, Tensor::from_vec(&[oc, oc, 3, 3], rng.vec_i8(oc * oc * 9, -3, 3)).unwrap());
    let add = g.add("add", Op::Add, &[c2, c1]).unwrap();
    // The style-transfer requant epilogue in microcode (SHR then MIN),
    // a surviving ReLU, and the nearest-neighbor upsampling pass.
    let shr = g.add("shr", Op::ShrImm { shift: 1 }, &[add]).unwrap();
    let clamp = g.add("min", Op::MinImm { imm: 48 }, &[shr]).unwrap();
    let r = g.add("relu", Op::Relu, &[clamp]).unwrap();
    let up = g.add("up", Op::Upsample2x, &[r]).unwrap();
    let gap = g.add("gap", Op::GlobalAvgPool, &[up]).unwrap();
    let fcp = MatmulParams { m: 1, k: oc, n: 10, requant: Requant { shift: 2, relu: false } };
    let fc = g.add("fc", Op::Dense { p: fcp }, &[gap]).unwrap();
    g.set_weights(fc, Tensor::from_vec(&[10, oc], rng.vec_i8(10 * oc, -3, 3)).unwrap());
    g
}

#[test]
fn vta_matches_reference_across_the_config_grid() {
    for (name, cfg, vt) in config_grid() {
        assert!(cfg.validate().is_empty(), "{name}: invalid config");
        let seed = 9000 + vt as u64;
        let input_len = 2 * cfg.gemm.block_in * 64;
        let input = {
            let mut rng = XorShiftRng::new(seed + 1);
            Tensor::from_vec(
                &[1, 2 * cfg.gemm.block_in, 8, 8],
                rng.vec_i8(input_len, -8, 8),
            )
            .unwrap()
        };

        // CPU reference: every node on the host kernels.
        let mut g_ref = mixed_graph(&cfg, seed);
        partition(&mut g_ref, &PartitionPolicy::cpu_only());
        let mut cpu_ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        let expect = cpu_ex.run(&g_ref, &input).unwrap().output;

        // Offloaded: everything the registry can lower goes to the VTA.
        let mut g_vta = mixed_graph(&cfg, seed);
        let mut policy = PartitionPolicy::offload_all(&cfg);
        policy.virtual_threads = vt;
        let (vta_nodes, _) = partition(&mut g_vta, &policy);
        assert!(
            vta_nodes >= 7,
            "{name}: expected conv/add/shr/min/relu/upsample/dense offload, got {vta_nodes} VTA \
             nodes"
        );
        for node in &g_vta.nodes {
            let kind = node.op.kind();
            if matches!(kind, "conv2d" | "dense" | "upsample2x" | "min" | "shr") {
                assert_eq!(
                    node.placement,
                    Placement::Vta,
                    "{name}: {} must offload for the check to mean anything",
                    node.name
                );
            }
        }
        let mut vta_ex =
            Executor::with_virtual_threads(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native, vt);
        let got = vta_ex.run(&g_vta, &input).unwrap().output;

        assert_eq!(got, expect, "{name}: VTA execution diverged from the CPU reference");
    }
}

/// The same grid stays correct under *tuned* schedules: a conservative
/// explicit tiling applied through the serving engine's record path
/// produces the reference results on every variant (DSE-chosen
/// schedules are trusted, not just planner defaults).
#[test]
fn tuned_schedules_match_reference_across_the_config_grid() {
    use vta::compiler::{plan_conv2d_tuned, ScheduleChoice};
    use vta::dse::{RecordKey, TuningRecord, TuningRecords};
    use vta::exec::ServingEngine;

    for (name, cfg, vt) in config_grid() {
        let seed = 9100 + vt as u64;
        let mut g = mixed_graph(&cfg, seed);
        let mut policy = PartitionPolicy::offload_all(&cfg);
        policy.virtual_threads = vt;
        partition(&mut g, &policy);
        let input = {
            let mut rng = XorShiftRng::new(seed + 1);
            let c = 2 * cfg.gemm.block_in;
            Tensor::from_vec(&[1, c, 8, 8], rng.vec_i8(c * 64, -8, 8)).unwrap()
        };

        let mut cpu_ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        let mut g_ref = mixed_graph(&cfg, seed);
        partition(&mut g_ref, &PartitionPolicy::cpu_only());
        let expect = cpu_ex.run(&g_ref, &input).unwrap().output;

        // A deliberately non-default (single output-row strip) conv
        // schedule for every conv node that accepts it.
        let mut records = TuningRecords::new();
        let choice = ScheduleChoice::Conv2d { oc_t: 1, oh_t: 1, ow_t: 8 };
        let config_fp = vta::compiler::config_fingerprint(&cfg);
        for node in &g.nodes {
            if let Op::Conv2d { p } = &node.op {
                if plan_conv2d_tuned(&cfg, p, vt, Some(&choice)).is_ok() {
                    let sfp = vta::compiler::op_impl(&node.op).schedule_fingerprint(node);
                    records.insert(
                        RecordKey { config_fp, virtual_threads: vt, sched_fp: sfp },
                        TuningRecord { choice, cycles: 1 },
                    );
                }
            }
        }
        // Guard against a vacuous pass: the probe schedule must be
        // feasible on every grid variant, or the tuned path goes
        // untested there.
        assert!(
            !records.is_empty(),
            "{name}: the probe schedule planned on no conv node — tuned path untested"
        );
        let mut eng =
            ServingEngine::with_records(&cfg, 64 << 20, CpuBackend::Native, vt, 16, records);
        let got = eng.run_one(&g, &input).unwrap().output;
        assert_eq!(got, expect, "{name}: tuned serving diverged from the CPU reference");
        // And the tuned schedule actually reached a compiled plan.
        let applied = g.nodes.iter().any(|node| {
            node.op.kind() == "conv2d"
                && eng.cached_schedule(&eng.plan_key(&g, node)) == Some(choice)
        });
        assert!(applied, "{name}: no compiled conv carries the tuned schedule");
    }
}
