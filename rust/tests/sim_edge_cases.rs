//! Edge-case and failure-injection tests for the simulator substrate:
//! DMA geometry corners, dependence-token accounting, hazard-injection
//! properties, config presets.

use vta::arch::{load_config, parse_config_str, VtaConfig};
use vta::isa::*;
use vta::sim::{ExecMode, SimError, Simulator};
use vta::util::XorShiftRng;

fn no_deps() -> DepFlags {
    DepFlags::NONE
}

fn d(pop_prev: bool, pop_next: bool, push_prev: bool, push_next: bool) -> DepFlags {
    DepFlags { pop_prev, pop_next, push_prev, push_next }
}

fn mem(buffer: BufferId, deps: DepFlags, sram: u32, dram: u32, tiles: u16) -> MemInsn {
    MemInsn {
        deps,
        buffer,
        sram_base: sram,
        dram_base: dram,
        y_size: 1,
        x_size: tiles,
        x_stride: tiles,
        y_pad_top: 0,
        y_pad_bottom: 0,
        x_pad_left: 0,
        x_pad_right: 0,
    }
}

/// A pad-only load (zero payload rows) writes zeros and moves no DRAM
/// bytes.
#[test]
fn pad_only_load_is_free_on_the_port() {
    let mut s = Simulator::new(VtaConfig::pynq(), 1 << 20);
    // Pre-dirty the input buffer via a normal load.
    s.dram.write_i8(1024, &[7i8; 64]).unwrap();
    let dirty = mem(BufferId::Inp, no_deps(), 0, 64, 4);
    let pad_only = MemInsn {
        deps: no_deps(),
        buffer: BufferId::Inp,
        sram_base: 0,
        dram_base: 64,
        y_size: 0,
        x_size: 0,
        x_stride: 1,
        y_pad_top: 2,
        y_pad_bottom: 2,
        x_pad_left: 0,
        x_pad_right: 1,
    };
    assert_eq!(pad_only.dram_tiles(), 0);
    let stats = s
        .run(&[Instruction::Load(dirty), Instruction::Load(pad_only), Instruction::Finish(no_deps())])
        .unwrap();
    assert_eq!(stats.bytes_loaded, 64); // only the dirty load moved data
}

/// Zero-extent GEMM/ALU instructions retire without touching state.
#[test]
fn zero_extent_compute_is_a_noop() {
    let mut s = Simulator::new(VtaConfig::pynq(), 1 << 20);
    let g = GemmInsn {
        deps: no_deps(),
        reset: false,
        uop_begin: 0,
        uop_end: 0, // empty kernel range
        lp0: 0,
        lp1: 5,
        acc_factor0: 0,
        acc_factor1: 0,
        inp_factor0: 0,
        inp_factor1: 0,
        wgt_factor0: 0,
        wgt_factor1: 0,
    };
    let stats = s.run(&[Instruction::Gemm(g), Instruction::Finish(no_deps())]).unwrap();
    assert_eq!(stats.gemm_uops, 0);
}

/// Uop range beyond the cache depth is a typed error.
#[test]
fn uop_range_overflow_is_caught() {
    let mut s = Simulator::new(VtaConfig::pynq(), 1 << 20);
    let g = GemmInsn {
        deps: no_deps(),
        reset: true,
        uop_begin: 0,
        uop_end: 5000, // > 4096
        lp0: 1,
        lp1: 1,
        acc_factor0: 0,
        acc_factor1: 0,
        inp_factor0: 0,
        inp_factor1: 0,
        wgt_factor0: 0,
        wgt_factor1: 0,
    };
    assert!(matches!(
        s.run(&[Instruction::Gemm(g), Instruction::Finish(no_deps())]),
        Err(SimError::UopOutOfBounds { .. })
    ));
}

/// Property: injecting a missing-WAR fault into an otherwise correct
/// double-buffered stream is flagged by the hazard checker, for many
/// random phase counts.
#[test]
fn injected_war_races_are_detected() {
    let mut rng = XorShiftRng::new(0x5EED);
    for trial in 0..5 {
        let phases = 3 + rng.next_below(4) as usize;
        let drop_war = rng.next_below(2) == 1;

        let mut s = Simulator::new(VtaConfig::pynq(), 1 << 20);
        s.set_mode(ExecMode::CheckHazards);
        let uop = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
        s.dram.write_u32(0, &[uop]).unwrap();

        // Single-context phases: load INP tile 0, GEMM reads it; the
        // WAR edge (GEMM push_prev → next load pop_next) protects the
        // reuse. Dropping it must produce a WriteDuringRead/RAW hazard.
        let mut v = vec![Instruction::Load(mem(BufferId::Uop, no_deps(), 0, 0, 1))];
        for ph in 0..phases {
            let keep = !(drop_war && ph == phases / 2);
            v.push(Instruction::Load(mem(
                BufferId::Inp,
                d(false, ph > 0 && keep, false, true),
                0,
                64,
                1,
            )));
            v.push(Instruction::Gemm(GemmInsn {
                deps: d(true, false, true, false),
                reset: false,
                uop_begin: 0,
                uop_end: 1,
                lp0: 64, // long enough that the next load would overlap
                lp1: 8,
                acc_factor0: 0,
                acc_factor1: 0,
                inp_factor0: 0,
                inp_factor1: 0,
                wgt_factor0: 0,
                wgt_factor1: 0,
            }));
        }
        v.push(Instruction::Finish(no_deps()));
        // Dropping a pop leaves an unmatched push token: harmless.
        let _ = s.run(&v).unwrap();
        if drop_war {
            assert!(!s.hazards().is_empty(), "trial {trial}: dropped WAR not detected");
        } else {
            assert!(s.hazards().is_empty(), "trial {trial}: false positive {:?}", s.hazards());
        }
    }
}

/// Config presets in configs/ all parse, validate, and summarize.
#[test]
fn config_presets_load() {
    for name in ["pynq", "ultra96", "tiny"] {
        let path = format!("{}/configs/{name}.cfg", env!("CARGO_MANIFEST_DIR"));
        let cfg = load_config(Some(&path)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(cfg.validate().is_empty(), "{name} invalid");
        assert!(!cfg.summary().is_empty());
    }
    // The pynq preset must equal the built-in default.
    let path = format!("{}/configs/pynq.cfg", env!("CARGO_MANIFEST_DIR"));
    assert_eq!(load_config(Some(&path)).unwrap(), VtaConfig::pynq());
}

/// Simulated time scales linearly in the instruction stream for
/// independent work (sanity of the DES clock).
#[test]
fn independent_work_accumulates_linearly() {
    let cfg = parse_config_str("").unwrap();
    let run_n = |n: u32| {
        let mut s = Simulator::new(cfg.clone(), 1 << 20);
        let mut v = Vec::new();
        for i in 0..n {
            v.push(Instruction::Load(mem(BufferId::Inp, no_deps(), i % 512, 64, 1)));
        }
        v.push(Instruction::Finish(no_deps()));
        s.run(&v).unwrap().total_cycles
    };
    let (a, b) = (run_n(10), run_n(20));
    // Twice the loads should be roughly twice the port time (within the
    // fixed fetch/latency overheads).
    assert!(b > a, "{b} !> {a}");
    assert!((b as f64) < (a as f64) * 2.5, "superlinear: {a} → {b}");
}
