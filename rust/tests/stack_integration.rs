//! Rust-stack integration tests (no artifacts needed): the compiler →
//! runtime → simulator pipeline under non-default hardware variants,
//! hazard checking over full lowered kernels, and failure injection.

use vta::arch::{parse_config_str, VtaConfig};
use vta::compiler::plan::{MatmulParams, Requant};
use vta::compiler::reference::{conv2d_ref, matmul_ref};
use vta::compiler::{
    lower_conv2d, lower_matmul, pack_activations, pack_matrix_a, pack_matrix_w, pack_weights,
    unpack_matrix_c, unpack_outputs, Conv2dParams,
};
use vta::runtime::VtaRuntime;
use vta::sim::ExecMode;
use vta::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize], lo: i8, hi: i8) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), lo, hi)).unwrap()
}

fn check_conv(cfg: &VtaConfig, p: &Conv2dParams, vt: usize, seed: u64) {
    let inp = rand_t(seed, &[1, p.ic, p.h, p.w], -8, 8);
    let wgt = rand_t(seed + 1, &[p.oc, p.ic, p.k, p.k], -4, 4);
    let mut rt = VtaRuntime::new(cfg, 64 << 20);
    let out =
        lower_conv2d(&mut rt, p, &pack_activations(cfg, &inp), &pack_weights(cfg, &wgt), vt)
            .unwrap();
    let got = unpack_outputs(cfg, &out.out, 1, p.oc, p.out_h(), p.out_w());
    assert_eq!(got, conv2d_ref(p, &inp, &wgt), "cfg={cfg:?} p={p:?} vt={vt}");
}

/// Non-default hardware variants still produce bit-exact results
/// (the ISA/compiler co-fluidity claim of §2.2).
#[test]
fn conv_correct_on_alternate_gemm_shapes() {
    let rq = Requant { shift: 6, relu: false };
    let p = Conv2dParams { h: 10, w: 10, ic: 32, oc: 32, k: 3, s: 1, requant: rq };
    for gemm in ["1x8x8", "1x32x32", "1x16x32", "1x32x16"] {
        let cfg = parse_config_str(&format!("gemm = {gemm}")).unwrap();
        check_conv(&cfg, &p, 2, 99);
    }
}

/// BATCH > 1 variants exercise multi-row tiles end to end (matmul path;
/// batched conv is future work, as in the paper's batch-1 deployment).
#[test]
fn matmul_correct_with_batch_2() {
    let cfg = parse_config_str("gemm = 2x16x16").unwrap();
    let p = MatmulParams { m: 8, k: 48, n: 40, requant: Requant { shift: 5, relu: true } };
    let a = rand_t(5, &[p.m, p.k], -8, 8);
    let w = rand_t(6, &[p.n, p.k], -8, 8);
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let out =
        lower_matmul(&mut rt, &p, &pack_matrix_a(&cfg, &a), &pack_matrix_w(&cfg, &w), 2).unwrap();
    assert_eq!(unpack_matrix_c(&cfg, &out.out, p.m, p.n), matmul_ref(&p, &a, &w));
}

/// Tiny SRAM variant forces many groups/strips and uop-cache pressure;
/// results must stay exact while the cache records evictions.
#[test]
fn conv_correct_under_sram_pressure() {
    let cfg = parse_config_str(
        "inp_buf_kib = 4\nwgt_buf_kib = 16\nacc_buf_kib = 8\nout_buf_kib = 2\nuop_buf_kib = 1",
    )
    .unwrap();
    let rq = Requant { shift: 6, relu: false };
    let p = Conv2dParams { h: 12, w: 12, ic: 32, oc: 128, k: 3, s: 1, requant: rq };
    let inp = rand_t(7, &[1, p.ic, p.h, p.w], -8, 8);
    let wgt = rand_t(8, &[p.oc, p.ic, p.k, p.k], -4, 4);
    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    let out =
        lower_conv2d(&mut rt, &p, &pack_activations(&cfg, &inp), &pack_weights(&cfg, &wgt), 2)
            .unwrap();
    let got = unpack_outputs(&cfg, &out.out, 1, p.oc, p.out_h(), p.out_w());
    assert_eq!(got, conv2d_ref(&p, &inp, &wgt));
    assert!(out.plan.groups() > 1, "expected multiple weight groups");
}

/// The compiler-inserted dependence flags are hazard-free under the
/// simulator's checker for a full virtual-threaded conv (the Fig 14
/// lowering is race-free by construction).
#[test]
fn lowered_conv_stream_is_hazard_free() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    let p = Conv2dParams { h: 16, w: 16, ic: 32, oc: 32, k: 3, s: 1, requant: rq };
    let inp = rand_t(9, &[1, p.ic, p.h, p.w], -8, 8);
    let wgt = rand_t(10, &[p.oc, p.ic, p.k, p.k], -4, 4);
    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    rt.device.set_mode(ExecMode::CheckHazards);
    let _ =
        lower_conv2d(&mut rt, &p, &pack_activations(&cfg, &inp), &pack_weights(&cfg, &wgt), 2)
            .unwrap();
    assert!(
        rt.device.hazards().is_empty(),
        "compiler emitted a racy stream: {:?}",
        rt.device.hazards()
    );
}

/// The weight double-buffering schedule (perf pass P2) must stay
/// race-free across many groups: the WAR fence for a weight context
/// rides the first strip's regular dependence pop (compute-FIFO
/// monotonicity). Verified with the hazard checker on a multi-group
/// workload.
#[test]
fn multi_group_weight_double_buffering_is_hazard_free() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    // C7-like: 2+ weight groups under the halved budget.
    let p = Conv2dParams { h: 14, w: 14, ic: 128, oc: 256, k: 3, s: 2, requant: rq };
    let inp = rand_t(21, &[1, p.ic, p.h, p.w], -8, 8);
    let wgt = rand_t(22, &[p.oc, p.ic, p.k, p.k], -4, 4);
    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    rt.device.set_mode(ExecMode::CheckHazards);
    let out =
        lower_conv2d(&mut rt, &p, &pack_activations(&cfg, &inp), &pack_weights(&cfg, &wgt), 2)
            .unwrap();
    assert!(out.plan.groups() > 1, "expected multiple groups, got {:?}", out.plan);
    assert_eq!(out.plan.wgt_contexts, 2, "expected double-buffered weights: {:?}", out.plan);
    assert!(
        rt.device.hazards().is_empty(),
        "weight double-buffering raced: {:?}",
        rt.device.hazards()
    );
    let got = unpack_outputs(&cfg, &out.out, 1, p.oc, p.out_h(), p.out_w());
    assert_eq!(got, conv2d_ref(&p, &inp, &wgt));
}

/// Failure injection: a DRAM too small for the workload surfaces as a
/// typed allocation error, not a panic.
#[test]
fn oom_is_a_typed_error() {
    let cfg = VtaConfig::pynq();
    let rq = Requant { shift: 6, relu: false };
    let p = Conv2dParams { h: 56, w: 56, ic: 64, oc: 64, k: 3, s: 1, requant: rq };
    let inp = rand_t(11, &[1, p.ic, p.h, p.w], -8, 8);
    let wgt = rand_t(12, &[p.oc, p.ic, p.k, p.k], -4, 4);
    // 2 MiB of arenas + 100 KiB of heap: the 200 KiB input image
    // cannot be allocated.
    let mut rt = VtaRuntime::new(&cfg, (2 << 20) + (100 << 10));
    let err =
        lower_conv2d(&mut rt, &p, &pack_activations(&cfg, &inp), &pack_weights(&cfg, &wgt), 2)
            .unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("out of memory"), "unexpected error: {msg}");
}

/// Property: for random configs and shapes the full stack stays exact.
#[test]
fn property_random_configs_and_shapes() {
    let mut rng = XorShiftRng::new(0xFEED);
    for trial in 0..6usize {
        let block = [8usize, 16][rng.next_below(2) as usize];
        let cfg = parse_config_str(&format!("gemm = 1x{block}x{block}")).unwrap();
        let k = [1usize, 3][rng.next_below(2) as usize];
        let s = 1 + rng.next_below(2) as usize;
        let h = (k.max(s) + 3 + rng.next_below(6) as usize).min(12);
        let p = Conv2dParams {
            h,
            w: h,
            ic: block * (1 + rng.next_below(2) as usize),
            oc: block * (1 + rng.next_below(2) as usize),
            k,
            s,
            requant: Requant { shift: rng.next_below(8) as u8, relu: rng.next_below(2) == 1 },
        };
        check_conv(&cfg, &p, 1 + (trial % 2), 0xBEEF + trial as u64);
    }
}
