//! Pipeline-parallel determinism: splitting one model across pool
//! replicas (stage-per-replica, inter-stage DRAM handoff) must never
//! change results. The simulated [`PipelineScheduler`] must be
//! bit-exact against the single-replica [`ServingEngine`] across stage
//! counts (1 / 2 / 4), virtual-thread modes (vt = 1 / 2), and both
//! evaluation graphs (resnet-family and style transfer); the threaded
//! pipeline runtime must then match the simulated oracle bit-for-bit —
//! outputs *and* the per-stage plan-cache counters (each stage owns an
//! independent cache over its own subgraph, so hit/miss sequences are
//! deterministic). Finally, the roofline balancer must beat a
//! deliberately lopsided cut of the same depth on modeled makespan.

use vta::arch::VtaConfig;
use vta::compiler::{Conv2dParams, MatmulParams, Requant};
use vta::dse::TuningRecords;
use vta::exec::{
    run_pipeline_threaded, CpuBackend, PipelineOptions, PipelinePartition, PipelineScheduler,
    ServingEngine,
};
use vta::graph::style::style_net;
use vta::graph::{partition, Graph, Op, PartitionPolicy};
use vta::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

fn conv_p(h: usize, ic: usize, oc: usize, relu: bool) -> Conv2dParams {
    Conv2dParams { h, w: h, ic, oc, k: 3, s: 1, requant: Requant { shift: 6, relu } }
}

/// A miniature ResNet: conv stem, two residual basic blocks, global
/// average pooling, dense classifier (16x16 input, 16 channels) —
/// deep enough for a 4-stage split with residual edges crossing cuts.
fn mini_resnet(wseed: u64) -> Graph {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 3, 16, 16] }, &[]).unwrap();
    let stem = g.add("stem", Op::Conv2d { p: conv_p(16, 3, 16, true) }, &[x]).unwrap();
    g.set_weights(stem, rand_t(wseed, &[16, 3, 3, 3]));
    let mut cur = stem;
    for b in 0u64..2 {
        let c1 = g
            .add(&format!("b{b}c1"), Op::Conv2d { p: conv_p(16, 16, 16, true) }, &[cur])
            .unwrap();
        g.set_weights(c1, rand_t(wseed + 10 + b * 2, &[16, 16, 3, 3]));
        let c2 = g
            .add(&format!("b{b}c2"), Op::Conv2d { p: conv_p(16, 16, 16, false) }, &[c1])
            .unwrap();
        g.set_weights(c2, rand_t(wseed + 11 + b * 2, &[16, 16, 3, 3]));
        let add = g.add(&format!("b{b}add"), Op::Add, &[c2, cur]).unwrap();
        cur = g.add(&format!("b{b}relu"), Op::Relu, &[add]).unwrap();
    }
    let gap = g.add("gap", Op::GlobalAvgPool, &[cur]).unwrap();
    let p = MatmulParams { m: 1, k: 16, n: 10, requant: Requant { shift: 2, relu: false } };
    let fc = g.add("fc", Op::Dense { p }, &[gap]).unwrap();
    g.set_weights(fc, rand_t(wseed + 99, &[10, 16]));
    g
}

/// The shared matrix: for every (vt, k) cell, stream the same
/// 6-request trace through the single-replica engine (the reference),
/// the simulated pipeline scheduler over a balanced k-stage split, and
/// the threaded pipeline runtime over the same split. Outputs must be
/// bit-identical in submission order everywhere, and the threaded
/// per-stage cache / occupancy counters must equal the oracle's.
fn check_pipeline_oracle<F: Fn() -> Graph>(name: &str, build: F) {
    let cfg = VtaConfig::pynq();
    let records = TuningRecords::new();
    let inputs: Vec<_> = (0..6).map(|i| rand_t(4000 + i as u64, &[1, 3, 16, 16])).collect();
    for vt in [1usize, 2] {
        let mut g = build();
        let mut policy = PartitionPolicy::offload_all(&cfg);
        policy.virtual_threads = vt;
        let (vta_nodes, _) = partition(&mut g, &policy);
        assert!(vta_nodes > 0, "{name} vt={vt}: nothing offloaded");

        // Single-replica engine: the bit-exactness reference and the
        // unique-plan count.
        let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, vt, 64);
        let batch = eng.run_batch(&g, &inputs).unwrap();
        let unique_plans = batch.cache.misses;

        for k in [1usize, 2, 4] {
            let part = PipelinePartition::balanced(&cfg, &g, k);
            assert_eq!(part.len(), k, "{name}: graph too shallow for {k} stages");

            // Simulated pipeline: the deterministic oracle.
            let mut opts = PipelineOptions::new(k);
            opts.virtual_threads = vt;
            let mut sched = PipelineScheduler::new(&cfg, CpuBackend::Native, opts.clone());
            let oracle = sched.run(&g, &part, &inputs).unwrap();
            assert_eq!(oracle.outputs.len(), inputs.len());
            for (i, out) in oracle.outputs.iter().enumerate() {
                assert_eq!(
                    out, &batch.outputs[i],
                    "{name} vt={vt} k={k}: simulated pipeline diverged from the \
                     single-replica engine at request {i}"
                );
            }
            // Per-stage caches partition the plan-key space: compiles
            // across stages sum to the engine's unique plans, with no
            // plan compiled by two stages.
            let misses: u64 = oracle.cache.iter().map(|c| c.misses).sum();
            assert_eq!(
                misses, unique_plans,
                "{name} vt={vt} k={k}: stages must compile exactly the unique plans"
            );
            assert!(oracle.makespan_seconds > 0.0);

            // Threaded pipeline: one OS worker per stage, bounded
            // inter-stage queues — must reproduce the oracle exactly.
            let r = run_pipeline_threaded(&cfg, &opts, &records, &g, &part, &inputs).unwrap();
            assert_eq!(
                r.outputs.len(),
                inputs.len(),
                "{name} vt={vt} k={k}: lost or duplicated responses"
            );
            for (i, out) in r.outputs.iter().enumerate() {
                assert_eq!(
                    out, &oracle.outputs[i],
                    "{name} vt={vt} k={k}: threaded request {i} diverged from the oracle"
                );
            }
            // Per-stage plan-cache counters: identical FIFO request
            // order per stage in both disciplines → identical
            // hit/miss/eviction sequences.
            assert_eq!(
                r.cache, oracle.cache,
                "{name} vt={vt} k={k}: per-stage cache counters fell out of step"
            );
            // Per-stage occupancy/handoff counters: everything except
            // measured busy time is deterministic.
            assert_eq!(r.metrics.stages.len(), k);
            for (s, (t, o)) in r.metrics.stages.iter().zip(&oracle.metrics.stages).enumerate() {
                assert_eq!(t.nodes, o.nodes, "{name} vt={vt} k={k} stage {s}: node count");
                assert_eq!(t.requests, o.requests, "{name} vt={vt} k={k} stage {s}: requests");
                assert_eq!(
                    t.sim_cycles, o.sim_cycles,
                    "{name} vt={vt} k={k} stage {s}: simulated cycles"
                );
                assert_eq!(
                    (t.handoff_tensors, t.handoff_bytes),
                    (o.handoff_tensors, o.handoff_bytes),
                    "{name} vt={vt} k={k} stage {s}: handoff accounting"
                );
                assert_eq!(t.requests, inputs.len() as u64);
            }
            assert_eq!(r.latencies.len(), inputs.len());
        }
    }
}

#[test]
fn resnet_pipeline_matches_the_single_replica_oracle() {
    check_pipeline_oracle("mini-resnet", || mini_resnet(7));
}

#[test]
fn style_pipeline_matches_the_single_replica_oracle() {
    check_pipeline_oracle("style", || style_net(1, 16, 16, 42).unwrap());
}

/// The roofline balancer beats a deliberately lopsided split of the
/// same depth: its bottleneck stage is no slower, and the modeled
/// streaming makespan over a deep trace is no worse — strictly better
/// when the lopsided cut concentrates essentially the whole graph in
/// one stage.
#[test]
fn balanced_split_beats_lopsided_split_on_modeled_makespan() {
    let cfg = VtaConfig::pynq();
    let mut g = mini_resnet(7);
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));

    let balanced = PipelinePartition::balanced(&cfg, &g, 4);
    // Lopsided: three near-empty stages (one level each off the top),
    // everything else — both residual blocks and the classifier —
    // crammed into the last stage.
    let lopsided = PipelinePartition::from_cuts(&cfg, &g, &[1, 2, 3]);
    assert_eq!(balanced.len(), lopsided.len());

    assert!(
        balanced.bottleneck_seconds() < lopsided.bottleneck_seconds(),
        "balancer must shrink the bottleneck: {} vs {}",
        balanced.bottleneck_seconds(),
        lopsided.bottleneck_seconds()
    );
    for requests in [1usize, 4, 16] {
        let (b, l) = (balanced.modeled_makespan(requests), lopsided.modeled_makespan(requests));
        assert!(b <= l + 1e-12, "requests={requests}: balanced {b} worse than lopsided {l}");
    }
    // Streaming deep: the lopsided pipe degenerates to the serial
    // chain's rate, the balanced one amortizes toward its (smaller)
    // bottleneck — the gap must be strict.
    assert!(
        balanced.modeled_makespan(16) < lopsided.modeled_makespan(16),
        "deep-stream makespans must separate"
    );
}
