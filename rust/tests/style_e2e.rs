//! End-to-end golden equivalence for the style-transfer workload: the
//! full fast-style-transfer graph (down-convs → residual blocks →
//! `Upsample2x → Conv2d` resize-convolutions → microcoded `Shr`/`Min`
//! requant epilogue) executed through the heterogeneous stack must be
//! **bit-exact** against the CPU reference across virtual-thread modes,
//! partition policies, hardware variants, and the serving engine — the
//! acceptance scenario for opening the paper's second workload.

use vta::arch::{GemmShape, VtaConfig};
use vta::compiler::Requant;
use vta::exec::{CpuBackend, Executor, ServingEngine};
use vta::graph::style::{style_net, style_transfer};
use vta::graph::{partition, Graph, Op, PartitionPolicy, Placement};
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

fn synth_image(seed: u64, size: usize) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(&[1, 3, size, size], rng.vec_i8(3 * size * size, -16, 16)).unwrap()
}

/// CPU-only reference output for a freshly built style graph.
fn cpu_reference(cfg: &VtaConfig, size: usize, input: &Tensor<i8>) -> Tensor<i8> {
    let mut g = style_net(1, size, 16, 42).unwrap();
    partition(&mut g, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(cfg, 256 << 20), CpuBackend::Native);
    ex.run(&g, input).unwrap().output
}

/// The tentpole gate: style graph VTA-vs-reference, bit-exact, across
/// vt = 1 / vt = 2 and the paper-default vs offload-all partition
/// policies.
#[test]
fn style_graph_matches_reference_across_vt_and_policies() {
    let cfg = VtaConfig::pynq();
    let input = synth_image(1001, 32);
    let expect = cpu_reference(&cfg, 32, &input);

    for vt in [1usize, 2] {
        for offload_all in [false, true] {
            let mut g = style_net(1, 32, 16, 42).unwrap();
            let mut policy = if offload_all {
                PartitionPolicy::offload_all(&cfg)
            } else {
                PartitionPolicy::paper(&cfg)
            };
            policy.virtual_threads = vt;
            let (vta_nodes, _) = partition(&mut g, &policy);
            assert!(vta_nodes > 0, "vt={vt} offload_all={offload_all}: nothing offloaded");
            if offload_all {
                // The new operator classes must actually reach the VTA
                // for the equivalence to mean anything.
                for kind in ["upsample2x", "min", "shr", "add"] {
                    assert!(
                        g.nodes
                            .iter()
                            .any(|n| n.op.kind() == kind && n.placement == Placement::Vta),
                        "vt={vt}: no {kind} node placed on the VTA"
                    );
                }
            }
            let mut ex = Executor::with_virtual_threads(
                VtaRuntime::new(&cfg, 256 << 20),
                CpuBackend::Native,
                vt,
            );
            let got = ex.run(&g, &input).unwrap().output;
            assert_eq!(
                got, expect,
                "vt={vt} offload_all={offload_all}: style output diverged from the CPU reference"
            );
        }
    }
}

/// Acceptance criterion: the style graph runs through `ServingEngine`
/// with VTA offload and matches the CPU reference bit-exactly on two
/// hardware configs (the pynq point and an 8x8-GEMM variant).
#[test]
fn style_serving_matches_reference_on_two_configs() {
    let mut small = VtaConfig::pynq();
    small.gemm = GemmShape { batch: 1, block_in: 8, block_out: 8 };
    small.alu_lanes = 8;
    for (name, cfg) in [("pynq", VtaConfig::pynq()), ("gemm8x8", small)] {
        assert!(cfg.validate().is_empty(), "{name}: invalid config");
        let input = synth_image(1002, 32);
        let expect = cpu_reference(&cfg, 32, &input);

        let mut g = style_net(1, 32, 16, 42).unwrap();
        partition(&mut g, &PartitionPolicy::offload_all(&cfg));
        let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 2, 64);
        let r1 = eng.run_one(&g, &input).unwrap();
        assert_eq!(r1.output, expect, "{name}: served style output diverged");

        // The new operator classes are resident in the plan cache, and
        // a second (warm) request is pure replay.
        let kinds = eng.cached_kinds();
        assert_eq!(kinds.get("upsample2x"), Some(&2), "{name}: both upsamplings cached");
        assert_eq!(kinds.get("min"), Some(&1), "{name}: min plan cached");
        assert_eq!(kinds.get("shr"), Some(&1), "{name}: shr plan cached");
        let misses = eng.cache_stats().misses;
        let r2 = eng.run_one(&g, &input).unwrap();
        assert_eq!(r2.output, expect, "{name}: warm replay diverged");
        assert_eq!(eng.cache_stats().misses, misses, "{name}: warm request re-compiled");
    }
}

/// Style-graph nodes produce distinct `PlanKey` fingerprints from
/// shape-identical resnet-style nodes (same conv params, different
/// weights), while identical everything shares — and different op
/// kinds over the same tensor shape never collide.
#[test]
fn style_plan_keys_are_distinct_from_shape_identical_nodes() {
    let cfg = VtaConfig::pynq();
    let eng = ServingEngine::new(&cfg, 64 << 20, CpuBackend::Native, 2, 4);

    // Two graphs with the *same* conv params (the style net's down2
    // shape) but different weight streams — a style node and a
    // shape-identical "resnet" node.
    let p = vta::compiler::Conv2dParams {
        h: 16,
        w: 16,
        ic: 16,
        oc: 32,
        k: 3,
        s: 2,
        requant: Requant { shift: 6, relu: true },
    };
    let build = |wseed: u64| {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 16, 16] }, &[]).unwrap();
        let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
        let mut rng = XorShiftRng::new(wseed);
        g.set_weights(c, Tensor::from_vec(&[32, 16, 3, 3], rng.vec_i8(32 * 16 * 9, -4, 4)).unwrap());
        g
    };
    let style_g = build(7001);
    let resnet_g = build(7002);
    assert_ne!(
        eng.plan_key(&style_g, &style_g.nodes[1]),
        eng.plan_key(&resnet_g, &resnet_g.nodes[1]),
        "shape-identical nodes with different weights must not share a plan"
    );
    assert_eq!(
        eng.plan_key(&style_g, &style_g.nodes[1]),
        eng.plan_key(&style_g, &style_g.nodes[1]),
        "identical node must share its own plan"
    );

    // Different op kinds over the same tensor shape → different keys.
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let shr = g.add("shr", Op::ShrImm { shift: 1 }, &[x]).unwrap();
    let min = g.add("min", Op::MinImm { imm: 100 }, &[shr]).unwrap();
    let relu = g.add("relu", Op::Relu, &[min]).unwrap();
    let up = g.add("up", Op::Upsample2x, &[relu]).unwrap();
    let keys: Vec<_> = [shr, min, relu, up]
        .iter()
        .map(|&id| eng.plan_key(&g, &g.nodes[id]))
        .collect();
    for i in 0..keys.len() {
        for j in i + 1..keys.len() {
            assert_ne!(keys[i], keys[j], "op kinds {i} and {j} collide");
        }
    }
    // Two Min nodes with different immediates must not share a plan
    // (the immediate is baked into the sealed stream).
    let min2 = g.add("min2", Op::MinImm { imm: 50 }, &[x]).unwrap();
    assert_ne!(
        eng.plan_key(&g, &g.nodes[min]),
        eng.plan_key(&g, &g.nodes[min2]),
        "Min immediates must be part of the fingerprint"
    );
}

/// Mixed-workload serving: one engine serves the style graph and a
/// resnet-style residual block back to back; hit/miss/eviction
/// counters stay exact (one compile per unique plan key — the five
/// weight-free residual adds legitimately share one plan) and results
/// stay bit-identical.
#[test]
fn mixed_style_and_resnet_workloads_keep_cache_counters_exact() {
    use std::collections::HashSet;
    let cfg = VtaConfig::pynq();

    fn build_block(seed: u64) -> Graph {
        let p = vta::compiler::Conv2dParams {
            h: 8,
            w: 8,
            ic: 16,
            oc: 16,
            k: 3,
            s: 1,
            requant: Requant { shift: 6, relu: false },
        };
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let mut rng = XorShiftRng::new(seed);
        let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
        g.set_weights(
            c1,
            Tensor::from_vec(&[16, 16, 3, 3], rng.vec_i8(16 * 16 * 9, -4, 4)).unwrap(),
        );
        let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
        g.set_weights(
            c2,
            Tensor::from_vec(&[16, 16, 3, 3], rng.vec_i8(16 * 16 * 9, -4, 4)).unwrap(),
        );
        let add = g.add("add", Op::Add, &[c2, x]).unwrap();
        let _r = g.add("relu", Op::Relu, &[add]).unwrap();
        g
    }

    // Small style net (16x16) plus a residual block.
    let mut style_g = style_net(1, 16, 16, 42).unwrap();
    let style_vta = partition(&mut style_g, &PartitionPolicy::offload_all(&cfg)).0;
    let mut block_g = build_block(8001);
    let block_vta = partition(&mut block_g, &PartitionPolicy::offload_all(&cfg)).0;

    let style_in = synth_image(1003, 16);
    let block_in = {
        let mut rng = XorShiftRng::new(1004);
        Tensor::from_vec(&[1, 16, 8, 8], rng.vec_i8(16 * 64, -8, 8)).unwrap()
    };
    let style_expect = cpu_reference(&cfg, 16, &style_in);
    let block_expect = {
        let mut g = build_block(8001);
        partition(&mut g, &PartitionPolicy::cpu_only());
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
        ex.run(&g, &block_in).unwrap().output
    };

    let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 2, 64);
    // Expected compile counts: one per *unique* plan key, not per node
    // (the five residual adds share params, shape, and have no
    // weights, so they share one plan by design).
    let unique_keys = |eng: &ServingEngine, g: &Graph| -> usize {
        g.nodes
            .iter()
            .filter(|n| n.placement == Placement::Vta)
            .map(|n| eng.plan_key(g, n))
            .collect::<HashSet<_>>()
            .len()
    };
    let style_unique = unique_keys(&eng, &style_g);
    let block_unique = unique_keys(&eng, &block_g);
    assert!(style_unique < style_vta, "premise: the residual adds share a plan");

    let r_style = eng.run_one(&style_g, &style_in).unwrap();
    let s1 = eng.cache_stats();
    assert_eq!(r_style.output, style_expect, "style request diverged");
    assert_eq!(s1.misses as usize, style_unique, "one compile per unique style plan key");
    assert_eq!(s1.hits as usize, style_vta - style_unique, "shared plans hit");

    let r_block = eng.run_one(&block_g, &block_in).unwrap();
    let s2 = eng.cache_stats();
    assert_eq!(r_block.output, block_expect, "block request diverged");
    assert_eq!(
        (s2.misses - s1.misses) as usize,
        block_unique,
        "one compile per unique block plan key — no cross-graph collisions"
    );

    // Warm replays of both graphs: hits only, outputs unchanged.
    let r_style2 = eng.run_one(&style_g, &style_in).unwrap();
    let r_block2 = eng.run_one(&block_g, &block_in).unwrap();
    let s3 = eng.cache_stats();
    assert_eq!(r_style2.output, style_expect);
    assert_eq!(r_block2.output, block_expect);
    assert_eq!(s3.misses, s2.misses, "warm requests must not compile");
    assert_eq!(
        (s3.hits - s2.hits) as usize,
        style_vta + block_vta,
        "every warm lookup hits"
    );
    assert_eq!(s3.evictions, 0, "capacity 64 must not evict this working set");
}

/// A plan cache smaller than the style working set thrashes but stays
/// bit-exact (mixed op kinds evict cleanly, releasing DRAM).
#[test]
fn style_cache_eviction_stays_correct() {
    let cfg = VtaConfig::pynq();
    let input = synth_image(1005, 16);
    let expect = cpu_reference(&cfg, 16, &input);

    let mut g = style_net(1, 16, 16, 42).unwrap();
    let (vta_nodes, _) = partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 2, 4);
    let r1 = eng.run_one(&g, &input).unwrap();
    let r2 = eng.run_one(&g, &input).unwrap();
    assert_eq!(r1.output, expect);
    assert_eq!(r2.output, expect, "eviction must not corrupt style results");
    let s = eng.cache_stats();
    assert!(vta_nodes > 4, "premise: working set exceeds the cache");
    assert!(s.evictions > 0, "capacity 4 must thrash on {vta_nodes} plans: {s:?}");
    assert!(eng.cached_plans() <= 4);
}

/// The default style net is what the docs claim it is: the full
/// operator mix, with every conv-transpose expressed as
/// `Upsample2x → Conv2d`.
#[test]
fn style_graph_structure_is_as_documented() {
    let g = style_transfer(1, 42).unwrap();
    let count = |k: &str| g.nodes.iter().filter(|n| n.op.kind() == k).count();
    assert_eq!(count("conv2d"), 2 + 10 + 2 + 1, "down x2, res x10, up x2, out x1");
    assert_eq!(count("upsample2x"), 2);
    assert_eq!(count("add"), 5);
    assert_eq!(count("min"), 1);
    assert_eq!(count("shr"), 1);
    // Every Upsample2x feeds a stride-1 conv (resize-convolution).
    for n in &g.nodes {
        if let Op::Conv2d { p } = &n.op {
            let from_upsample = n
                .inputs
                .iter()
                .any(|&i| matches!(g.nodes[i].op, Op::Upsample2x));
            if from_upsample {
                assert_eq!(p.s, 1, "resize-convolution must be stride 1");
            }
        }
    }
    // Output shape is the input image shape.
    let out = g.nodes.last().unwrap();
    assert_eq!(out.shape, vec![1, 3, 32, 32]);
}
