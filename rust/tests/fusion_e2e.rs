//! End-to-end golden equivalence for deep operator fusion: graphs
//! rewritten by [`vta::graph::fuse`] into fused-chain
//! `Op::FusedConv2d` nodes (conv → residual add → ReLU, and
//! conv → shr → min) must stay **bit-exact** against both the unfused
//! graph and the CPU reference across virtual-thread modes and
//! partition policies — and fused plans must be first-class plan-cache
//! citizens: distinct `PlanKey`s from their unfused shape-twins, exact
//! hit/miss accounting, warm replays that never recompile.

use std::collections::HashSet;

use vta::arch::VtaConfig;
use vta::compiler::{FusedStep, Requant};
use vta::exec::{CpuBackend, Executor, ServingEngine};
use vta::graph::resnet::{resnet_mini, synth_input};
use vta::graph::style::style_net;
use vta::graph::{fuse, partition, Graph, Op, PartitionPolicy, Placement};
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

/// CPU-only output of a graph — the golden reference.
fn cpu_only_output(cfg: &VtaConfig, mut g: Graph, input: &Tensor<i8>) -> Tensor<i8> {
    partition(&mut g, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(cfg, 256 << 20), CpuBackend::Native);
    ex.run(&g, input).unwrap().output
}

fn policy_for(cfg: &VtaConfig, offload_all: bool, vt: usize) -> PartitionPolicy {
    let mut policy =
        if offload_all { PartitionPolicy::offload_all(cfg) } else { PartitionPolicy::paper(cfg) };
    policy.virtual_threads = vt;
    policy
}

/// The tentpole gate, conv-heavy workload: fused mini-resnet (two
/// residual blocks collapse into `conv+add+relu` chains) is bit-exact
/// against the unfused CPU reference across vt = 1 / vt = 2 and the
/// paper-default vs offload-all partition policies — and the fused
/// nodes genuinely execute on the VTA.
#[test]
fn fused_resnet_mini_matches_reference_across_vt_and_policies() {
    let cfg = VtaConfig::pynq();
    let input = synth_input(2001, 1, 3, 16, 16);
    let expect = cpu_only_output(&cfg, resnet_mini(1, 16, 42).unwrap(), &input);

    // The fused graph's CPU path (the registry reference for
    // `FusedConv2d`) agrees with the unfused reference too.
    let (fused_ref, n_ref) = fuse(resnet_mini(1, 16, 42).unwrap()).unwrap();
    assert_eq!(n_ref, 4, "both residual blocks must fuse their add and relu");
    assert_eq!(
        cpu_only_output(&cfg, fused_ref, &input),
        expect,
        "FusedConv2d CPU reference diverged from the unfused graph"
    );

    for vt in [1usize, 2] {
        for offload_all in [false, true] {
            let (mut g, n) = fuse(resnet_mini(1, 16, 42).unwrap()).unwrap();
            assert_eq!(n, 4, "vt={vt} offload_all={offload_all}: fusion count changed");
            let (vta_nodes, _) = partition(&mut g, &policy_for(&cfg, offload_all, vt));
            assert!(vta_nodes > 0, "vt={vt} offload_all={offload_all}: nothing offloaded");
            // Fused chains must actually reach the VTA for the
            // equivalence to mean anything (ic = 16 passes the paper
            // policy's min-IC rule too).
            assert_eq!(
                g.nodes
                    .iter()
                    .filter(|n| n.op.kind() == "fused_conv2d" && n.placement == Placement::Vta)
                    .count(),
                2,
                "vt={vt} offload_all={offload_all}: fused chains not placed on the VTA"
            );
            let mut ex = Executor::with_virtual_threads(
                VtaRuntime::new(&cfg, 256 << 20),
                CpuBackend::Native,
                vt,
            );
            let got = ex.run(&g, &input).unwrap().output;
            assert_eq!(
                got, expect,
                "vt={vt} offload_all={offload_all}: fused mini-resnet diverged from reference"
            );
        }
    }
}

/// The tentpole gate, ALU-heavy workload: fused style transfer (five
/// `conv+add` residual chains plus the `conv+shr+min` requant tail)
/// is bit-exact across vt and policies, and the rewrite produced
/// exactly the chain grammar the pass documents.
#[test]
fn fused_style_matches_reference_across_vt_and_policies() {
    let cfg = VtaConfig::pynq();
    let input = {
        let mut rng = XorShiftRng::new(2002);
        Tensor::from_vec(&[1, 3, 16, 16], rng.vec_i8(3 * 16 * 16, -16, 16)).unwrap()
    };
    let expect = cpu_only_output(&cfg, style_net(1, 16, 16, 42).unwrap(), &input);

    // Chain-shape audit on one fused instance: 5 residual chains, one
    // shr+min tail, nothing else.
    let (audit, n_audit) = fuse(style_net(1, 16, 16, 42).unwrap()).unwrap();
    assert_eq!(n_audit, 7, "5 residual adds + the shr and min of the requant tail");
    let tail = audit
        .nodes
        .iter()
        .find(|n| n.name == "out.conv+shr+min")
        .expect("requant tail fused under its documented name");
    let Op::FusedConv2d { steps, .. } = &tail.op else {
        panic!("tail is not a fused conv: {:?}", tail.op)
    };
    assert_eq!(steps[..], [FusedStep::ShrImm { shift: 1 }, FusedStep::MinImm { imm: 100 }]);
    let residual_chains = audit
        .nodes
        .iter()
        .filter(|n| {
            matches!(&n.op, Op::FusedConv2d { steps, .. } if steps[..] == [FusedStep::AddResidual])
        })
        .count();
    assert_eq!(residual_chains, 5, "every fast-style residual block fuses as conv+add");

    for vt in [1usize, 2] {
        for offload_all in [false, true] {
            let (mut g, n) = fuse(style_net(1, 16, 16, 42).unwrap()).unwrap();
            assert_eq!(n, 7, "vt={vt} offload_all={offload_all}: fusion count changed");
            let (vta_nodes, _) = partition(&mut g, &policy_for(&cfg, offload_all, vt));
            assert!(vta_nodes > 0, "vt={vt} offload_all={offload_all}: nothing offloaded");
            assert_eq!(
                g.nodes
                    .iter()
                    .filter(|n| n.op.kind() == "fused_conv2d" && n.placement == Placement::Vta)
                    .count(),
                6,
                "vt={vt} offload_all={offload_all}: fused chains not placed on the VTA"
            );
            let mut ex = Executor::with_virtual_threads(
                VtaRuntime::new(&cfg, 256 << 20),
                CpuBackend::Native,
                vt,
            );
            let got = ex.run(&g, &input).unwrap().output;
            assert_eq!(
                got, expect,
                "vt={vt} offload_all={offload_all}: fused style diverged from reference"
            );
        }
    }
}

/// One residual block: `in → c1 → c2 → add(+in) → relu`, the minimal
/// graph where fusion rewrites something.
fn residual_block(seed: u64) -> Graph {
    let p = vta::compiler::Conv2dParams {
        h: 8,
        w: 8,
        ic: 16,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: false },
    };
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
    let mut rng = XorShiftRng::new(seed);
    let c1 = g.add("c1", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c1, Tensor::from_vec(&[16, 16, 3, 3], rng.vec_i8(16 * 16 * 9, -4, 4)).unwrap());
    let c2 = g.add("c2", Op::Conv2d { p }, &[c1]).unwrap();
    g.set_weights(c2, Tensor::from_vec(&[16, 16, 3, 3], rng.vec_i8(16 * 16 * 9, -4, 4)).unwrap());
    let add = g.add("add", Op::Add, &[c2, x]).unwrap();
    let _r = g.add("relu", Op::Relu, &[add]).unwrap();
    g
}

/// Fused chains are first-class plan-cache citizens: a fused
/// `conv+add+relu` node keys **differently** from the unfused conv
/// with identical params and weights, the untouched upstream conv
/// **shares** its plan across the fused and unfused graph, and
/// hit/miss counters stay exact while one engine serves both variants
/// — across vt = 1 and vt = 2.
#[test]
fn fused_plan_keys_are_distinct_and_cache_counters_stay_exact() {
    let cfg = VtaConfig::pynq();
    let input = {
        let mut rng = XorShiftRng::new(2003);
        Tensor::from_vec(&[1, 16, 8, 8], rng.vec_i8(16 * 64, -8, 8)).unwrap()
    };
    let expect = cpu_only_output(&cfg, residual_block(9001), &input);

    for vt in [1usize, 2] {
        let mut unfused = residual_block(9001);
        let uf_vta = partition(&mut unfused, &policy_for(&cfg, true, vt)).0;
        assert_eq!(uf_vta, 4, "vt={vt}: offload-all places c1, c2, add, relu");

        let (mut fused, n) = fuse(residual_block(9001)).unwrap();
        assert_eq!(n, 2, "vt={vt}: the block's add and relu fuse into the conv");
        let f_vta = partition(&mut fused, &policy_for(&cfg, true, vt)).0;
        assert_eq!(f_vta, 2, "vt={vt}: offload-all places c1 and the fused chain");

        let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, vt, 64);
        let by_name = |g: &Graph, name: &str| -> usize {
            g.nodes.iter().position(|n| n.name == name).unwrap_or_else(|| panic!("{name}?"))
        };
        // Same conv params, same weights, same config — but the fused
        // chain must never collide with the plain conv's plan.
        let k_plain = eng.plan_key(&unfused, &unfused.nodes[by_name(&unfused, "c2")]);
        let k_fused = eng.plan_key(&fused, &fused.nodes[by_name(&fused, "c2+add+relu")]);
        assert_ne!(k_plain, k_fused, "vt={vt}: fused and unfused plans share a key");
        // The untouched upstream conv is byte-identical in both graphs
        // and legitimately shares one plan.
        assert_eq!(
            eng.plan_key(&unfused, &unfused.nodes[by_name(&unfused, "c1")]),
            eng.plan_key(&fused, &fused.nodes[by_name(&fused, "c1")]),
            "vt={vt}: identical conv must share its plan across graph variants"
        );
        // All four unfused keys are distinct (different weights /
        // different op kinds), so compile counts below are exact.
        let uf_unique = unfused
            .nodes
            .iter()
            .filter(|n| n.placement == Placement::Vta)
            .map(|n| eng.plan_key(&unfused, n))
            .collect::<HashSet<_>>()
            .len();
        assert_eq!(uf_unique, 4, "vt={vt}: unfused block plans must not collide");

        let r1 = eng.run_one(&unfused, &input).unwrap();
        let s1 = eng.cache_stats();
        assert_eq!(r1.output, expect, "vt={vt}: unfused request diverged");
        assert_eq!(s1.misses, 4, "vt={vt}: one compile per unfused plan");
        assert_eq!(s1.hits, 0, "vt={vt}: cold cache cannot hit");

        let r2 = eng.run_one(&fused, &input).unwrap();
        let s2 = eng.cache_stats();
        assert_eq!(r2.output, expect, "vt={vt}: fused request diverged");
        assert_eq!(s2.misses - s1.misses, 1, "vt={vt}: only the fused chain compiles");
        assert_eq!(s2.hits - s1.hits, 1, "vt={vt}: the shared c1 plan hits");

        // Warm replays of both variants: replay only, outputs stable.
        let r3 = eng.run_one(&unfused, &input).unwrap();
        let r4 = eng.run_one(&fused, &input).unwrap();
        let s3 = eng.cache_stats();
        assert_eq!(r3.output, expect);
        assert_eq!(r4.output, expect);
        assert_eq!(s3.misses, s2.misses, "vt={vt}: warm requests must not compile");
        assert_eq!(s3.hits - s2.hits, 6, "vt={vt}: every warm lookup hits (4 + 2)");
    }
}

/// The fused style graph runs through `ServingEngine`: all six fused
/// chains land in the plan cache under their own kind, the first
/// request matches the CPU reference, and a warm request is pure
/// replay.
#[test]
fn fused_style_serving_caches_fused_plans() {
    let cfg = VtaConfig::pynq();
    let input = {
        let mut rng = XorShiftRng::new(2004);
        Tensor::from_vec(&[1, 3, 16, 16], rng.vec_i8(3 * 16 * 16, -16, 16)).unwrap()
    };
    let expect = cpu_only_output(&cfg, style_net(1, 16, 16, 42).unwrap(), &input);

    let (mut g, n) = fuse(style_net(1, 16, 16, 42).unwrap()).unwrap();
    assert_eq!(n, 7);
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 2, 64);
    let r1 = eng.run_one(&g, &input).unwrap();
    assert_eq!(r1.output, expect, "served fused style diverged from reference");
    assert_eq!(
        eng.cached_kinds().get("fused_conv2d"),
        Some(&6),
        "all six fused chains cached under their own kind"
    );
    let misses = eng.cache_stats().misses;
    let r2 = eng.run_one(&g, &input).unwrap();
    assert_eq!(r2.output, expect, "warm fused replay diverged");
    assert_eq!(eng.cache_stats().misses, misses, "warm fused request re-compiled");
}
