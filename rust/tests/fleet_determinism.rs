//! Heterogeneous fleet determinism: mixed-config device pools driven
//! by the [`FleetScheduler`] must produce outputs **bit-exact** with
//! single-device [`ServingEngine`]s of each routed config — across
//! route policies, replica layouts, and virtual-thread modes — and the
//! real-threads fleet runtime must match the simulated oracle
//! (outputs, routes, per-group plan-cache counters). Execution is
//! exact in this stack; only the timing is modeled — neither fleet
//! composition nor routing may leak into results.
//!
//! The two-group fleet under test is the one the CLI example ships:
//! group 0 is a Pynq variant with half the tensor-ALU lanes (every
//! eltwise op strictly slower, conv work identical), group 1 is the
//! stock Pynq. Mixed traffic pairs a conv-bound resnet-mini class with
//! an eltwise-heavy style class, so the cost model has a real decision
//! to make.

use vta::arch::VtaConfig;
use vta::dse::TuningRecords;
use vta::exec::serve::fleet::{
    graph_model_seconds, modeled_fleet_makespan, serve_fleet_trace, FleetMember, FleetOptions,
    FleetScheduler, FleetSpec, FleetThreadedOptions, RoutePolicy, Router,
};
use vta::exec::{CpuBackend, Scheduler, SchedulerOptions, ServingEngine};
use vta::graph::resnet::resnet_mini;
use vta::graph::style::style_net;
use vta::graph::{partition, Graph, PartitionPolicy};
use vta::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

/// The ALU-starved variant: conv/GEMM identical to stock Pynq, eltwise
/// strictly slower (8 lanes instead of 16).
fn lanes8() -> VtaConfig {
    let mut c = VtaConfig::pynq();
    c.alu_lanes = 8;
    c
}

fn two_group_spec(d0: usize, d1: usize) -> FleetSpec {
    FleetSpec::new(vec![
        FleetMember { cfg: lanes8(), devices: d0 },
        FleetMember { cfg: VtaConfig::pynq(), devices: d1 },
    ])
}

/// Class 0: conv-bound (resnet-mini under the paper rule — its VTA
/// work is pure conv, so it models identically on both groups).
/// Class 1: eltwise-heavy (style net with everything offloaded — adds,
/// shifts, and clamps run on the tensor ALU, strictly slower on
/// group 0).
fn mixed_classes(vt: usize) -> Vec<Graph> {
    let cfg = VtaConfig::pynq();
    let mut conv_g = resnet_mini(1, 16, 42).unwrap();
    let mut conv_p = PartitionPolicy::paper(&cfg);
    conv_p.virtual_threads = vt;
    let (n, _) = partition(&mut conv_g, &conv_p);
    assert!(n > 0, "conv class offloaded nothing");
    let mut style_g = style_net(1, 16, 16, 42).unwrap();
    let mut style_p = PartitionPolicy::offload_all(&cfg);
    style_p.virtual_threads = vt;
    let (n, _) = partition(&mut style_g, &style_p);
    assert!(n > 0, "style class offloaded nothing");
    vec![conv_g, style_g]
}

/// Alternating mixed trace opening with the style class (class 1), so
/// a parity-pinned round-robin router genuinely disagrees with the
/// cost model.
fn alternating_classes(n: usize) -> Vec<usize> {
    (0..n).map(|i| 1 - i % 2).collect()
}

/// Serve an alternating mixed trace through the fleet, then replay
/// every request through a fresh single-device engine of its routed
/// group's exact config: outputs must be bit-identical and each
/// group's lockstep plan cache must have compiled exactly one plan set
/// per class it served.
fn check_fleet_vs_single_device(spec: &FleetSpec, policy: RoutePolicy, vt: usize, n_req: usize) {
    let label = format!("policy={policy:?} vt={vt} layout={:?}", spec.members.iter().map(|m| m.devices).collect::<Vec<_>>());
    let graphs_owned = mixed_classes(vt);
    let graphs: Vec<&Graph> = graphs_owned.iter().collect();
    let classes = alternating_classes(n_req);
    let inputs: Vec<_> = (0..n_req).map(|i| rand_t(3000 + i as u64, &[1, 3, 16, 16])).collect();

    let opts = FleetOptions {
        policy,
        max_batch: 2,
        batch_deadline: 0.0,
        cache_capacity: 64,
        virtual_threads: vt,
        dram_size: 256 << 20,
    };
    let mut sched = FleetScheduler::new(spec, CpuBackend::Native, opts);
    for (i, &c) in classes.iter().enumerate() {
        sched.submit(0.0, c, inputs[i].clone());
    }
    let r = sched.run(&graphs).unwrap();
    assert_eq!(r.outputs.len(), n_req, "{label}: lost requests");
    assert_eq!(r.classes, classes, "{label}: classes reordered");

    for (g, member) in spec.members.iter().enumerate() {
        let mut eng = ServingEngine::new(&member.cfg, 256 << 20, CpuBackend::Native, vt, 64);
        let mut expect_misses = 0u64;
        for (c, graph) in graphs.iter().enumerate() {
            let idxs: Vec<usize> =
                (0..n_req).filter(|&i| r.routes[i] == g && classes[i] == c).collect();
            if idxs.is_empty() {
                continue;
            }
            let batch: Vec<_> = idxs.iter().map(|&i| inputs[i].clone()).collect();
            let out = eng.run_batch(graph, &batch).unwrap();
            expect_misses += out.cache.misses;
            for (k, &i) in idxs.iter().enumerate() {
                assert_eq!(
                    out.outputs[k], r.outputs[i],
                    "{label}: request {i} (class {c}, group {g}) diverged from the \
                     single-device engine"
                );
            }
        }
        assert_eq!(
            r.group_cache[g].misses, expect_misses,
            "{label}: group {g} must compile each routed class's plans exactly once"
        );
    }
}

#[test]
fn fleet_outputs_are_bit_exact_across_layouts_policies_and_vt() {
    for vt in [1usize, 2] {
        for (d0, d1) in [(1usize, 1usize), (2, 2)] {
            for policy in [RoutePolicy::CostModel, RoutePolicy::RoundRobin] {
                check_fleet_vs_single_device(&two_group_spec(d0, d1), policy, vt, 8);
            }
        }
    }
}

#[test]
fn static_routing_pins_every_request_to_one_group() {
    check_fleet_vs_single_device(&two_group_spec(2, 1), RoutePolicy::Static(0), 2, 6);
    check_fleet_vs_single_device(&two_group_spec(2, 1), RoutePolicy::Static(1), 1, 6);
}

/// The real-threads fleet must match the simulated oracle bit for bit:
/// same outputs in submission order, same routes (routing is a pure
/// function of the class sequence), same per-group cache counters
/// (group-wise lockstep on both sides).
fn check_threaded_matches_oracle(spec: &FleetSpec, policy: RoutePolicy, vt: usize, n_req: usize) {
    let label = format!("policy={policy:?} vt={vt}");
    let graphs_owned = mixed_classes(vt);
    let graphs: Vec<&Graph> = graphs_owned.iter().collect();
    let classes = alternating_classes(n_req);
    let inputs: Vec<_> = (0..n_req).map(|i| rand_t(5000 + i as u64, &[1, 3, 16, 16])).collect();

    let opts = FleetOptions {
        policy,
        max_batch: 2,
        batch_deadline: 0.0,
        cache_capacity: 64,
        virtual_threads: vt,
        dram_size: 256 << 20,
    };
    let mut sched = FleetScheduler::new(spec, CpuBackend::Native, opts);
    for (i, &c) in classes.iter().enumerate() {
        sched.submit(0.0, c, inputs[i].clone());
    }
    let oracle = sched.run(&graphs).unwrap();

    let mut topts = FleetThreadedOptions::new(policy);
    topts.max_batch = 2;
    topts.cache_capacity = 64;
    topts.virtual_threads = vt;
    topts.dram_size = 256 << 20;
    let trace: Vec<(usize, Tensor<i8>)> =
        classes.iter().zip(&inputs).map(|(&c, t)| (c, t.clone())).collect();
    let threaded = serve_fleet_trace(spec, &topts, &TuningRecords::new(), &graphs, &trace).unwrap();

    assert_eq!(threaded.outputs.len(), oracle.outputs.len(), "{label}: lost requests");
    for (i, out) in threaded.outputs.iter().enumerate() {
        assert_eq!(out, &oracle.outputs[i], "{label}: threaded output {i} diverged");
    }
    assert_eq!(threaded.routes, oracle.routes, "{label}: threaded fleet routed differently");
    for (g, (t, s)) in threaded.group_cache.iter().zip(&oracle.group_cache).enumerate() {
        assert_eq!(
            (t.misses, t.hits),
            (s.misses, s.hits),
            "{label}: group {g} plan directory fell out of step with the oracle"
        );
    }
}

#[test]
fn threaded_fleet_matches_the_simulated_oracle() {
    for policy in [RoutePolicy::CostModel, RoutePolicy::RoundRobin] {
        check_threaded_matches_oracle(&two_group_spec(1, 1), policy, 1, 6);
        check_threaded_matches_oracle(&two_group_spec(2, 2), policy, 2, 8);
    }
}

/// A single-member fleet is the homogeneous pool: same outputs, same
/// compile-once cache counters as the classic [`Scheduler`] on the
/// identical trace.
#[test]
fn homogeneous_fleet_reduces_to_the_classic_pool() {
    let cfg = VtaConfig::pynq();
    let vt = 2;
    let graphs_owned = mixed_classes(vt);
    let g = &graphs_owned[0];
    let inputs: Vec<_> = (0..6).map(|i| rand_t(7000 + i as u64, &[1, 3, 16, 16])).collect();

    let spec = FleetSpec::homogeneous(&cfg, 2);
    let fopts = FleetOptions {
        policy: RoutePolicy::CostModel,
        max_batch: 2,
        batch_deadline: 0.0,
        cache_capacity: 64,
        virtual_threads: vt,
        dram_size: 256 << 20,
    };
    let mut fleet = FleetScheduler::new(&spec, CpuBackend::Native, fopts);
    for input in &inputs {
        fleet.submit(0.0, 0, input.clone());
    }
    let fr = fleet.run(&[g]).unwrap();
    assert!(fr.routes.iter().all(|&r| r == 0), "one group — every route must be 0");

    let popts = SchedulerOptions {
        devices: 2,
        max_batch: 2,
        batch_deadline: 0.0,
        cache_capacity: 64,
        virtual_threads: vt,
        dram_size: 256 << 20,
    };
    let mut pool = Scheduler::new(&cfg, CpuBackend::Native, popts);
    for input in &inputs {
        pool.submit(0.0, input.clone());
    }
    let pr = pool.run(g).unwrap();

    assert_eq!(fr.outputs.len(), pr.outputs.len());
    for (i, out) in fr.outputs.iter().enumerate() {
        assert_eq!(out, &pr.outputs[i], "homogeneous fleet output {i} diverged from the pool");
    }
    assert_eq!(
        (fr.group_cache[0].misses, fr.group_cache[0].hits),
        (pr.cache.misses, pr.cache.hits),
        "homogeneous fleet cache counters diverged from the pool"
    );
}

/// The routing win the CLI gate (`serve --fleet --require-routing-win`)
/// relies on: on the example two-group fleet, the cost model keeps
/// conv traffic on the ALU-starved group (a modeled tie, broken by
/// index) and sends eltwise-heavy traffic to the stock group, strictly
/// beating round-robin's parity routing on the modeled makespan.
#[test]
fn cost_model_routing_beats_round_robin_on_the_mixed_trace() {
    let vt = 2;
    let graphs_owned = mixed_classes(vt);
    let graphs: Vec<&Graph> = graphs_owned.iter().collect();
    let cfgs = [lanes8(), VtaConfig::pynq()];

    // Conv work models identically on both variants (the GEMM core is
    // unchanged); the style class is strictly slower on half the lanes.
    assert_eq!(
        graph_model_seconds(&cfgs[0], graphs[0]),
        graph_model_seconds(&cfgs[1], graphs[0]),
        "conv class must tie across the groups"
    );
    assert!(
        graph_model_seconds(&cfgs[0], graphs[1]) > graph_model_seconds(&cfgs[1], graphs[1]),
        "style class must be strictly slower on the ALU-starved group"
    );

    let router = Router::new(RoutePolicy::CostModel, &cfgs, &graphs);
    assert_eq!(router.best_group_for(0), 0, "conv tie must break to group 0");
    assert_eq!(router.best_group_for(1), 1, "style must prefer the stock group");

    let classes = alternating_classes(8);
    let cm_routes = Router::new(RoutePolicy::CostModel, &cfgs, &graphs).route_trace(&classes);
    let rr_routes = Router::new(RoutePolicy::RoundRobin, &cfgs, &graphs).route_trace(&classes);
    let devices = [1usize, 1];
    let cm = modeled_fleet_makespan(&cfgs, &devices, &graphs, &classes, &cm_routes);
    let rr = modeled_fleet_makespan(&cfgs, &devices, &graphs, &classes, &rr_routes);
    assert!(
        cm < rr,
        "cost-model routing ({cm:.6e} s) must strictly beat round-robin ({rr:.6e} s)"
    );
}

/// The committed example fleet (`examples/fleet_mixed.json`) is what
/// CI serves; it must keep loading, match the two-group shape the
/// docs describe, and re-serialize byte-identically.
#[test]
fn committed_example_fleet_spec_loads_and_matches() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/fleet_mixed.json");
    let spec = FleetSpec::load(path).unwrap();
    assert_eq!(spec.members.len(), 2);
    assert_eq!(spec.members[0].cfg, lanes8());
    assert_eq!(spec.members[0].devices, 1);
    assert_eq!(spec.members[1].cfg, VtaConfig::pynq());
    assert_eq!(spec.members[1].devices, 1);
    assert_eq!(
        spec.to_json(),
        std::fs::read_to_string(path).unwrap(),
        "examples/fleet_mixed.json drifted from the canonical serialization"
    );
}
