//! Threaded-pool stress: admission control under a deliberately tiny
//! queue, trailing partial-batch flush, graceful shutdown with
//! in-flight requests, repeated fresh-pool cycles, and a seeded
//! 200-request soak across 4 workers — no response may ever be lost,
//! duplicated, or bitwise wrong.
//!
//! The deterministic parts use `start_paused`: workers stay gated
//! until [`PoolHandle::resume`] (or shutdown), so queue contents are
//! exact at assertion time instead of racing the consumers.

use vta::arch::VtaConfig;
use vta::compiler::{Conv2dParams, Requant};
use vta::dse::TuningRecords;
use vta::exec::{
    run_threaded, serve_trace, CpuBackend, ServingEngine, SubmitRejected, ThreadedOptions,
};
use vta::graph::{partition, Graph, Op, PartitionPolicy};
use vta::util::{Tensor, XorShiftRng};

fn rand_t(seed: u64, shape: &[usize]) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), -8, 8)).unwrap()
}

/// The smallest serveable VTA graph: one 8x8 conv — cheap enough for a
/// 200-request soak in debug builds.
fn tiny_conv(wseed: u64) -> Graph {
    let mut g = Graph::new();
    let x = g.add("in", Op::Input { shape: vec![1, 3, 8, 8] }, &[]).unwrap();
    let p = Conv2dParams {
        h: 8,
        w: 8,
        ic: 3,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 6, relu: true },
    };
    let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
    g.set_weights(c, rand_t(wseed, &[16, 3, 3, 3]));
    g
}

/// Partitioned tiny graph plus the engine's reference outputs for the
/// given inputs (vt = 1, matching `ThreadedOptions::new`).
fn tiny_with_reference(inputs: &[Tensor<i8>]) -> (Graph, Vec<Tensor<i8>>, u64) {
    let cfg = VtaConfig::pynq();
    let mut g = tiny_conv(11);
    let mut policy = PartitionPolicy::paper(&cfg);
    policy.virtual_threads = 1;
    let (vta_nodes, _) = partition(&mut g, &policy);
    assert!(vta_nodes > 0, "tiny graph must offload its conv");
    let mut eng = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 1, 64);
    let batch = eng.run_batch(&g, inputs).unwrap();
    (g, batch.outputs, batch.cache.misses)
}

#[test]
fn queue_full_rejects_with_reason_then_drains_cleanly() {
    let cfg = VtaConfig::pynq();
    let inputs: Vec<_> = (0..5).map(|i| rand_t(900 + i as u64, &[1, 3, 8, 8])).collect();
    let (g, expect, _) = tiny_with_reference(&inputs);

    let mut opts = ThreadedOptions::new(2);
    opts.queue_capacity = 2;
    opts.start_paused = true;
    let ((), report) = run_threaded(&cfg, &opts, &TuningRecords::new(), &g, |handle| {
        // Workers are gated: the first two submissions fill the queue,
        // the rest must be rejected with the queue-full reason.
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        for input in &inputs {
            match handle.try_submit(input.clone()) {
                Ok(_) => accepted += 1,
                Err(e) => {
                    assert_eq!(e, SubmitRejected::QueueFull { capacity: 2 });
                    rejected += 1;
                }
            }
        }
        assert_eq!((accepted, rejected), (2, 3));
        assert_eq!(handle.queue_depth(), 2, "gated workers must not have consumed");
        assert_eq!(handle.accepted(), 2);
        assert_eq!(handle.rejected(), 3);
        // Ungate and wait the backlog out: rejection is not loss.
        handle.resume();
        handle.wait_all();
        assert_eq!(handle.completed(), 2);
    })
    .unwrap();

    assert_eq!(report.accepted, 2);
    assert_eq!(report.rejected, 3);
    assert_eq!(report.outputs.len(), 2, "both admitted requests answered");
    for (i, out) in report.outputs.iter().enumerate() {
        assert_eq!(out, &expect[i], "admitted request {i} must still be bit-exact");
    }
}

#[test]
fn trailing_partial_batch_flushes_on_shutdown_with_in_flight_requests() {
    let cfg = VtaConfig::pynq();
    let inputs: Vec<_> = (0..6).map(|i| rand_t(700 + i as u64, &[1, 3, 8, 8])).collect();
    let (g, expect, _) = tiny_with_reference(&inputs);

    // One gated worker, batches of 4, six queued requests: the driver
    // returns without waiting — shutdown must ungate the worker, flush
    // a full batch of 4 and the trailing partial batch of 2, and only
    // then join.
    let mut opts = ThreadedOptions::new(1);
    opts.max_batch = 4;
    opts.queue_capacity = 16;
    opts.start_paused = true;
    let ((), report) = run_threaded(&cfg, &opts, &TuningRecords::new(), &g, |handle| {
        for input in &inputs {
            handle.submit(input.clone()).unwrap();
        }
        // Deliberately no resume(), no wait_all(): everything is
        // in flight when the driver hands control back.
    })
    .unwrap();

    assert_eq!(report.outputs.len(), 6, "graceful drain must serve every queued request");
    for (i, out) in report.outputs.iter().enumerate() {
        assert_eq!(out, &expect[i], "request {i} diverged during shutdown drain");
    }
    let mut batch_sizes: Vec<usize> = report.completions.iter().map(|c| c.batch).collect();
    batch_sizes.sort_unstable();
    assert_eq!(
        batch_sizes,
        vec![2, 2, 4, 4, 4, 4],
        "one full batch of 4 plus the trailing partial batch of 2"
    );
    assert_eq!(report.threads.len(), 1);
    assert_eq!(report.threads[0].requests, 6);
    assert_eq!(report.threads[0].batches, 2);
    assert_eq!(report.threads[0].max_batch, 4);
}

#[test]
fn repeated_pool_cycles_are_identical() {
    let cfg = VtaConfig::pynq();
    let inputs: Vec<_> = (0..8).map(|i| rand_t(500 + i as u64, &[1, 3, 8, 8])).collect();
    let (g, expect, unique_plans) = tiny_with_reference(&inputs);

    let mut opts = ThreadedOptions::new(2);
    opts.max_batch = 3;
    let records = TuningRecords::new();
    // Every cycle builds a fresh pool: a cold directory must recompile
    // (compile-once per pool, not per process) and land on identical
    // outputs and counters each time.
    for cycle in 0..3 {
        let r = serve_trace(&cfg, &opts, &records, &g, &inputs).unwrap();
        assert_eq!(r.outputs.len(), inputs.len(), "cycle {cycle}: lost responses");
        for (i, out) in r.outputs.iter().enumerate() {
            assert_eq!(out, &expect[i], "cycle {cycle}: request {i} diverged");
        }
        assert_eq!(r.cache.misses, unique_plans, "cycle {cycle}: cold pool compiles once");
        assert_eq!(
            r.cache.hits + r.cache.misses,
            inputs.len() as u64,
            "cycle {cycle}: one VTA lookup per request on the tiny graph"
        );
    }
}

#[test]
fn seeded_soak_loses_and_duplicates_nothing() {
    let cfg = VtaConfig::pynq();
    const SOAK: usize = 200;
    const UNIQUE: usize = 8;
    let unique_inputs: Vec<_> =
        (0..UNIQUE).map(|i| rand_t(1234 + i as u64, &[1, 3, 8, 8])).collect();
    let (g, expect, unique_plans) = tiny_with_reference(&unique_inputs);

    let mut opts = ThreadedOptions::new(4);
    opts.queue_capacity = 32;
    opts.max_batch = 3;
    let ((), report) = run_threaded(&cfg, &opts, &TuningRecords::new(), &g, |handle| {
        for i in 0..SOAK {
            // Blocking submit: backpressure throttles the producer when
            // all four workers fall behind.
            handle.submit(unique_inputs[i % UNIQUE].clone()).unwrap();
            if i % 16 == 0 {
                handle.poll();
            }
        }
        handle.wait_all();
        assert_eq!(handle.accepted(), SOAK as u64);
        assert_eq!(handle.completed(), SOAK as u64);
    })
    .unwrap();

    // No lost, duplicated, or reordered responses: one output per
    // submission id, each bit-exact with the engine's answer for that
    // input.
    assert_eq!(report.outputs.len(), SOAK);
    assert_eq!(report.completions.len(), SOAK);
    for (i, out) in report.outputs.iter().enumerate() {
        assert_eq!(out, &expect[i % UNIQUE], "soak request {i} got the wrong answer");
    }
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), SOAK, "completion ids must be dense and unique");
    assert_eq!((ids[0], ids[SOAK - 1]), (0, SOAK as u64 - 1));

    let served: u64 = report.threads.iter().map(|t| t.requests).sum();
    assert_eq!(served, SOAK as u64, "per-worker counters must sum to the soak");
    assert_eq!(report.cache.misses, unique_plans, "soak compiles each plan once");
    assert_eq!(
        report.cache.hits + report.cache.misses,
        SOAK as u64,
        "one directory lookup per request on the tiny graph"
    );
    assert_eq!(report.rejected, 0, "blocking submits shed nothing");
}
