//! Cross-layer integration tests: the JAX-lowered artifacts (L1 Pallas
//! kernels + L2 model operators), executed through the Rust PJRT
//! runtime, must agree bit-exactly with the Rust-native stack (host
//! references and the VTA behavioral simulator).
//!
//! These tests need `make artifacts` AND the `pjrt` cargo feature
//! with the `xla` crate added to `[dependencies]` (the offline
//! default build stubs the XLA backend out); they also
//! skip (with a notice) when the artifact directory is missing so
//! plain `cargo test --features pjrt` stays green in a fresh checkout.
#![cfg(feature = "pjrt")]

use vta::arch::VtaConfig;
use vta::compiler::plan::{MatmulParams, Requant};
use vta::compiler::reference::{conv2d_ref, matmul_ref};
use vta::compiler::{
    lower_conv2d, lower_matmul, pack_activations, pack_matrix_a, pack_matrix_w, pack_weights,
    unpack_matrix_c, unpack_outputs, Conv2dParams,
};
use vta::exec::PjrtCache;
use vta::graph::resnet::LAYER_SHIFT;
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn artifacts_available() -> bool {
    let ok = std::path::Path::new(ARTIFACTS).join(".stamp").exists();
    if !ok {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn rand_t(seed: u64, shape: &[usize], lo: i8, hi: i8) -> Tensor<i8> {
    let mut rng = XorShiftRng::new(seed);
    Tensor::from_vec(shape, rng.vec_i8(shape.iter().product(), lo, hi)).unwrap()
}

/// FNV-1a 64-bit, mirror of `python/compile/synth.py::fnv1a64`.
fn fnv1a64(data: &[i8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in data {
        h ^= b as u8 as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// L1: the Pallas GEMM kernel artifact == Rust host reference AND the
/// VTA simulator's matmul path (after the same requant epilogue).
#[test]
fn pallas_gemm_artifact_matches_host_and_simulator() {
    if !artifacts_available() {
        return;
    }
    let mut cache = PjrtCache::new(ARTIFACTS).unwrap();
    let a = rand_t(1, &[64, 64], -16, 16);
    let w = rand_t(2, &[64, 64], -16, 16);

    // The artifact returns the raw int32 accumulator.
    let acc = cache.run_i32("gemm_pallas_64_64_64", &[&a, &w]).unwrap().remove(0);

    // Host int32 reference.
    let mut expect = vec![0i32; 64 * 64];
    for m in 0..64 {
        for n in 0..64 {
            let mut s = 0i32;
            for k in 0..64 {
                s += a.data()[m * 64 + k] as i32 * w.data()[n * 64 + k] as i32;
            }
            expect[m * 64 + n] = s;
        }
    }
    assert_eq!(acc.data(), &expect[..], "pallas GEMM accumulator vs host i32 reference");

    // Simulator path: same operands through lower_matmul; its int8
    // output must equal the requantized pallas accumulator.
    let rq = Requant { shift: 6, relu: false };
    let p = MatmulParams { m: 64, k: 64, n: 64, requant: rq };
    let cfg = VtaConfig::pynq();
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let got = lower_matmul(&mut rt, &p, &pack_matrix_a(&cfg, &a), &pack_matrix_w(&cfg, &w), 2)
        .unwrap();
    let got = unpack_matrix_c(&cfg, &got.out, 64, 64);
    assert_eq!(got, matmul_ref(&p, &a, &w), "simulator vs host reference");
    let requant_acc: Vec<i8> = acc.data().iter().map(|&v| rq.apply(v)).collect();
    assert_eq!(got.data(), &requant_acc[..], "simulator vs requantized pallas accumulator");
}

/// L1+L2: the Pallas-backed conv artifact == the Rust host reference ==
/// the VTA simulator, bit-exactly, on the C2-geometry crop.
#[test]
fn pallas_conv_artifact_matches_simulator() {
    if !artifacts_available() {
        return;
    }
    let p = Conv2dParams {
        h: 14,
        w: 14,
        ic: 64,
        oc: 64,
        k: 3,
        s: 1,
        requant: Requant { shift: LAYER_SHIFT, relu: false },
    };
    let x = rand_t(3, &[1, 64, 14, 14], -16, 16);
    let w = rand_t(4, &[64, 64, 3, 3], -4, 4);

    // PJRT path (JAX im2col + Pallas GEMM + Pallas requant).
    let mut cache = PjrtCache::new(ARTIFACTS).unwrap();
    let pjrt_out = cache.run_i8("conv_pallas_14_64_64_3_1", &[&x, &w]).unwrap().remove(0);

    // Host reference.
    let host = conv2d_ref(&p, &x, &w);
    assert_eq!(pjrt_out, host, "pallas artifact vs host reference");

    // VTA simulator through the full compiler/runtime stack.
    let cfg = VtaConfig::pynq();
    let mut rt = VtaRuntime::new(&cfg, 32 << 20);
    let sim =
        lower_conv2d(&mut rt, &p, &pack_activations(&cfg, &x), &pack_weights(&cfg, &w), 2)
            .unwrap();
    let sim_out = unpack_outputs(&cfg, &sim.out, 1, 64, 14, 14);
    assert_eq!(sim_out, host, "simulator vs host reference");
}

/// L2 per-operator artifacts == the Rust-native CPU kernels.
#[test]
fn cpu_op_artifacts_match_native_ops() {
    if !artifacts_available() {
        return;
    }
    let mut cache = PjrtCache::new(ARTIFACTS).unwrap();

    // conv C1 (asymmetric SAME padding + fused relu).
    let p = Conv2dParams {
        h: 224,
        w: 224,
        ic: 3,
        oc: 64,
        k: 7,
        s: 2,
        requant: Requant { shift: LAYER_SHIFT, relu: true },
    };
    let x = rand_t(10, &[1, 3, 224, 224], -16, 16);
    let w = rand_t(11, &[64, 3, 7, 7], -4, 4);
    let got = cache.run_i8("conv_224_3_64_7_2_1", &[&x, &w]).unwrap().remove(0);
    assert_eq!(got, conv2d_ref(&p, &x, &w), "conv C1 artifact");

    // maxpool.
    let x = rand_t(12, &[1, 64, 112, 112], -64, 64);
    let got = cache.run_i8("maxpool_1x64x56x56_3_2", &[&x]).unwrap().remove(0);
    assert_eq!(got, vta::exec::maxpool_i8(&x, 3, 2, 1), "maxpool artifact");

    // residual add (saturating).
    let a = rand_t(13, &[1, 64, 56, 56], -128, 127);
    let b = rand_t(14, &[1, 64, 56, 56], -128, 127);
    let got = cache.run_i8("add_1x64x56x56", &[&a, &b]).unwrap().remove(0);
    assert_eq!(got, vta::exec::add_i8(&a, &b), "add artifact");

    // global average pool (truncating division on negatives!).
    let x = rand_t(15, &[1, 512, 7, 7], -100, 100);
    let got = cache.run_i8("gap_1x512", &[&x]).unwrap().remove(0);
    assert_eq!(got, vta::exec::global_avg_pool_i8(&x), "gap artifact");

    // dense classifier.
    let p = MatmulParams {
        m: 1,
        k: 512,
        n: 1000,
        requant: Requant { shift: LAYER_SHIFT, relu: false },
    };
    let x = rand_t(16, &[1, 512], -64, 64);
    let w = rand_t(17, &[1000, 512], -4, 4);
    let got = cache.run_i8("dense_1_512_1000", &[&x, &w]).unwrap().remove(0);
    assert_eq!(got, vta::exec::dense_i8(&p, &x, &w), "dense artifact");
}

/// Synthetic weights: the Rust generators reproduce the Python-side
/// FNV-1a digests recorded at artifact-build time.
#[test]
fn synthetic_weights_match_python_digests() {
    if !artifacts_available() {
        return;
    }
    let digest_path = std::path::Path::new(ARTIFACTS).join("weights_digest.txt");
    let text = std::fs::read_to_string(digest_path).unwrap();
    let g = vta::graph::resnet::resnet18(1, 42).unwrap();
    let mut checked = 0;
    for line in text.lines() {
        let (name, hex) = line.split_once(' ').unwrap();
        let expect = u64::from_str_radix(hex, 16).unwrap();
        let data: Vec<i8> = if name == "input" {
            vta::graph::resnet::synth_input(7, 1, 3, 224, 224).into_vec()
        } else {
            let node = g
                .nodes
                .iter()
                .find(|n| n.name.trim_end_matches("+relu") == name)
                .unwrap_or_else(|| panic!("no node {name}"));
            g.weights(node.id).unwrap().clone().into_vec()
        };
        assert_eq!(fnv1a64(&data), expect, "digest mismatch for {name}");
        checked += 1;
    }
    assert_eq!(checked, 23, "expected input + 22 weight digests");
}

/// The full CPU-only model artifact == the Rust-native executor on the
/// same synthetic weights and input (the golden cross-language check).
/// Slow in debug builds — run with `cargo test --release` or `make test`.
#[test]
fn resnet18_cpu_artifact_matches_native_executor() {
    if !artifacts_available() {
        return;
    }
    if cfg!(debug_assertions) {
        eprintln!("SKIP: full-model equivalence runs in release only (cargo test --release)");
        return;
    }
    use vta::exec::{CpuBackend, Executor};
    use vta::graph::{fuse, partition, resnet, PartitionPolicy};

    let (mut g, _) = fuse(resnet::resnet18(1, 42).unwrap()).unwrap();
    partition(&mut g, &PartitionPolicy::cpu_only());
    let input = resnet::synth_input(7, 1, 3, 224, 224);

    // Native CPU-only execution.
    let cfg = VtaConfig::pynq();
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 64 << 20), CpuBackend::Native);
    let native = ex.run(&g, &input).unwrap().output;

    // PJRT full-model artifact: input + weights in WEIGHT_ORDER (the
    // graph's parametric-node creation order).
    let mut inputs: Vec<&Tensor<i8>> = vec![&input];
    let weight_refs: Vec<&Tensor<i8>> = g
        .nodes
        .iter()
        .filter_map(|n| g.weights(n.id))
        .collect();
    inputs.extend(weight_refs);
    let mut cache = PjrtCache::new(ARTIFACTS).unwrap();
    let pjrt_out = cache.run_i8("resnet18_cpu", &inputs).unwrap().remove(0);

    assert_eq!(pjrt_out, native, "cross-language ResNet-18 mismatch");
}
