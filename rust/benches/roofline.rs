//! Bench: Figure 15 — the roofline experiment with and without latency
//! hiding (virtual threading).
//!
//! For every ResNet conv layer this prints the roofline coordinates
//! (arithmetic intensity, attainable bound) and the achieved GOPS under
//! vt=1 (no latency hiding) and vt=2 (TVM virtual threading), plus the
//! aggregate compute-utilization lift the paper headlines (70% → 88%).
//!
//! Run: `cargo bench --bench roofline`

mod common;

use vta::arch::VtaConfig;
use vta::graph::resnet::{table1_params, TABLE1};
use vta::metrics::Roofline;

fn main() {
    let cfg = VtaConfig::pynq();
    let roof = Roofline::of(&cfg);
    println!(
        "# Fig 15: roofline of {} @ {:.0} MHz (peak {:.1} GOPS, DRAM {:.2} GB/s, knee {:.1} ops/byte)",
        cfg.gemm,
        cfg.clock_hz / 1e6,
        roof.peak_gops(),
        cfg.dram_gbytes_per_sec(),
        roof.knee_intensity()
    );
    println!(
        "{:<5} {:>9} {:>7} | {:>8} {:>6} {:>6} | {:>8} {:>6} {:>6} | {:>7}",
        "layer", "ops/byte", "bound", "vt1 GOPS", "eff%", "util%", "vt2 GOPS", "eff%", "util%", "lift"
    );

    let mut agg = [[0u64; 2]; 2]; // [vt-1][cycles, busy]
    let mut total_ops = 0u64;
    for (i, (name, ..)) in TABLE1.iter().enumerate() {
        if !common::selected(name) {
            continue;
        }
        let p = table1_params(i);
        let bound = roof.bound_ops_per_cycle(p.arithmetic_intensity()) * cfg.clock_hz / 1e9;
        let mut pts = Vec::new();
        for (vi, vt) in [1usize, 2].into_iter().enumerate() {
            let out = common::run_conv(&cfg, &p, vt, 42 + i as u64);
            agg[vi][0] += out.stats.total_cycles;
            agg[vi][1] += out.stats.gemm_busy_cycles;
            pts.push(roof.point(name, p.ops(), p.arithmetic_intensity(), &out.stats));
        }
        total_ops += p.ops();
        println!(
            "{:<5} {:>9.1} {:>7.2} | {:>8.2} {:>6.0} {:>6.0} | {:>8.2} {:>6.0} {:>6.0} | {:>6.2}x",
            name,
            p.arithmetic_intensity(),
            bound,
            pts[0].gops,
            pts[0].efficiency * 100.0,
            pts[0].utilization * 100.0,
            pts[1].gops,
            pts[1].efficiency * 100.0,
            pts[1].utilization * 100.0,
            pts[0].cycles as f64 / pts[1].cycles as f64
        );
    }

    if agg[0][0] > 0 {
        let util = |v: usize| agg[v][1] as f64 / agg[v][0] as f64 * 100.0;
        println!(
            "\naggregate compute utilization: {:.0}% (no latency hiding) → {:.0}% (virtual threading)",
            util(0),
            util(1)
        );
        println!("paper Fig 15 headline:          70%                      → 88%");
        println!(
            "aggregate GOPS: {:.2} → {:.2} ({:.2}x total-cycle speedup)",
            total_ops as f64 / agg[0][0] as f64 * cfg.clock_hz / 1e9,
            total_ops as f64 / agg[1][0] as f64 * cfg.clock_hz / 1e9,
            agg[0][0] as f64 / agg[1][0] as f64
        );
    }
}
