//! Bench: Figure 16 — end-to-end ResNet-18 inference, CPU-only vs
//! CPU+VTA, with the per-operator-class breakdown the paper stacks.
//!
//! The CPU side measures real wall time of this host's compiled kernels
//! (PJRT artifacts when available, native Rust otherwise); the VTA side
//! reports simulated accelerator time (cycles ÷ clock). Absolute values
//! differ from the Pynq testbed; the *shape* — conv dominates CPU-only,
//! offload removes it, residual CPU ops cap the end-to-end gain — is
//! the reproduction target.
//!
//! Run: `cargo bench --bench e2e_resnet [-- --json PATH]
//!       [--check BASELINE] [--pin BASELINE]`
//!
//! `--json` writes the run snapshot (`BENCH_resnet.json` schema);
//! `--check` diffs it against a committed baseline — deterministic
//! fields (offloaded node count, output fingerprint, simulated cycle
//! and DRAM-traffic totals) must match exactly, `null` baseline fields
//! are unpinned, measured wall-clock fields are schema-checked only;
//! `--pin` fills a baseline's `null` deterministic fields from the
//! current run (see `common::baseline` for the CI pin-then-check
//! flow).

#[allow(dead_code)] // this bench uses only the baseline half of common
mod common;

use common::baseline;
use std::collections::BTreeMap;
use std::time::Instant;
use vta::arch::VtaConfig;
use vta::exec::serve::fnv1a64;
use vta::exec::{CpuBackend, ExecReport, Executor, PjrtCache};
use vta::graph::resnet::{self, synth_input};
use vta::graph::{fuse, partition, PartitionPolicy, Placement};
use vta::runtime::VtaRuntime;

fn backend() -> CpuBackend {
    if std::path::Path::new("artifacts/.stamp").exists() {
        CpuBackend::Pjrt(PjrtCache::new("artifacts").unwrap())
    } else {
        CpuBackend::Native
    }
}

fn breakdown(report: &ExecReport) -> BTreeMap<&'static str, (f64, f64)> {
    let mut by_kind: BTreeMap<&'static str, (f64, f64)> = BTreeMap::new();
    for n in &report.nodes {
        let e = by_kind.entry(n.kind).or_default();
        e.0 += n.wall.as_secs_f64() * 1e3;
        e.1 += n.sim_seconds * 1e3;
    }
    by_kind
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json_path = baseline::flag_value(&argv, "--json");
    let check_path = baseline::flag_value(&argv, "--check");
    let pin_path = baseline::flag_value(&argv, "--pin");

    let cfg = VtaConfig::pynq();
    let input = synth_input(7, 1, 3, 224, 224);
    let (mut g, _) = fuse(resnet::resnet18(1, 42).unwrap()).unwrap();

    println!("# Fig 16: end-to-end ResNet-18 (batch 1, int8, synthetic weights)\n");

    // CPU-only.
    partition(&mut g, &PartitionPolicy::cpu_only());
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 512 << 20), backend());
    let t0 = Instant::now();
    let cpu_report = ex.run(&g, &input).unwrap();
    let cpu_total = t0.elapsed().as_secs_f64() * 1e3;

    // Hybrid.
    let (vta_nodes, _) = partition(&mut g, &PartitionPolicy::paper(&cfg));
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 512 << 20), backend());
    let hybrid_report = ex.run(&g, &input).unwrap();
    assert_eq!(hybrid_report.output, cpu_report.output, "paths disagree");

    println!("{:<10} {:>16} {:>16} {:>16}", "op class", "cpu-only (ms)", "hybrid cpu (ms)", "hybrid vta (ms)");
    let cpu_b = breakdown(&cpu_report);
    let hy_b = breakdown(&hybrid_report);
    for (kind, (cpu_ms, _)) in &cpu_b {
        if *kind == "input" {
            continue;
        }
        let (h_cpu, h_vta) = hy_b.get(kind).copied().unwrap_or_default();
        println!("{:<10} {:>16.1} {:>16.1} {:>16.1}", kind, cpu_ms, h_cpu, h_vta);
    }

    let cpu_conv = cpu_b.get("conv2d").map(|v| v.0).unwrap_or(0.0);
    let hybrid_total = hybrid_report.total_seconds() * 1e3;
    let vta_conv = hybrid_report.vta_seconds() * 1e3;
    let s = hybrid_report.vta_stats();
    println!(
        "\nCPU-only total: {cpu_total:.1} ms   hybrid model total: {hybrid_total:.1} ms \
         ({vta_nodes} conv layers offloaded)"
    );
    println!(
        "conv speedup on offloaded layers: {:.1}x (paper: ~40x on the A9)",
        cpu_conv / vta_conv.max(1e-9)
    );
    println!(
        "end-to-end speedup: {:.1}x (paper: >3 s → <0.5 s, Amdahl-limited)",
        cpu_total / hybrid_total.max(1e-9)
    );
    println!(
        "VTA aggregate: {} Mcycles, {:.0}% GEMM utilization, {:.1} MB DRAM traffic",
        s.total_cycles / 1_000_000,
        s.compute_utilization() * 100.0,
        s.bytes_moved() as f64 / 1e6
    );

    // ---- run snapshot: emit / diff BENCH_resnet.json ------------------
    // Deterministic: the partition decision, the model output, and the
    // simulated accelerator totals (cycles, DRAM traffic) — all derived
    // from integer simulation, identical on every host. Measured: this
    // host's wall clocks and the speedups computed from them.
    let output_fp = fnv1a64(hybrid_report.output.data().iter().map(|&v| v as u8));
    let snapshot = format!(
        "{{\n  \"schema\": 1,\n  \"workload\": \"resnet18-224\",\n  \
         \"deterministic\": {{\n    \"vta_nodes\": {vta_nodes},\n    \
         \"output_fp\": {output_fp},\n    \"total_cycles\": {},\n    \
         \"dram_bytes\": {},\n    \"gemm_utilization\": {:.6}\n  }},\n  \
         \"measured\": {{\n    \"cpu_only_ms\": {cpu_total:.1},\n    \
         \"hybrid_total_ms\": {hybrid_total:.1},\n    \
         \"conv_speedup\": {:.2},\n    \"e2e_speedup\": {:.2}\n  }}\n}}\n",
        s.total_cycles,
        s.bytes_moved(),
        s.compute_utilization(),
        cpu_conv / vta_conv.max(1e-9),
        cpu_total / hybrid_total.max(1e-9)
    );
    if let Some(path) = &json_path {
        std::fs::write(path, &snapshot).unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("\nwrote resnet snapshot to {path}");
    }
    if let Some(path) = &pin_path {
        baseline::pin_baseline("resnet", &snapshot, path);
    }
    if let Some(path) = &check_path {
        baseline::check_against_baseline("resnet", &snapshot, path);
    }
}
