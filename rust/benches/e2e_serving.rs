//! Bench: end-to-end ResNet-18 *serving* — the naive per-node serial
//! executor (re-lowers every VTA node on every inference) against the
//! batched, pipelined serving engine with a warm plan cache.
//!
//! Reports the two costs separately:
//!
//! * **host wall** — real time the host spends orchestrating (pack /
//!   lower / encode / simulate bookkeeping). The plan cache removes
//!   lowering and weight packing from this after the first request.
//! * **model time** — CPU wall + simulated VTA time per the paper's
//!   accounting; the pipelined schedule overlaps the two across
//!   requests (double-buffered), the serial baseline does not.
//!
//! Run: `cargo bench --bench e2e_serving [-- --batch N]`

use std::time::Instant;
use vta::arch::VtaConfig;
use vta::exec::{CpuBackend, Executor, Scheduler, SchedulerOptions, ServingEngine};
use vta::graph::resnet::{self, synth_input};
use vta::graph::{fuse, partition, style, Graph, PartitionPolicy};
use vta::runtime::VtaRuntime;
use vta::util::Tensor;

/// Drain the same 4-request stream through pools of 1, 2, and 4
/// replicas (dynamic batches of 1, all arrivals at t = 0): modeled
/// throughput must increase monotonically with pool size, and the
/// outputs must stay bit-identical to the single-device engine
/// (`expect_prefix`) and across pool sizes.
fn device_sweep(
    cfg: &VtaConfig,
    name: &str,
    g: &Graph,
    seed0: u64,
    size: usize,
    expect_prefix: &[Tensor<i8>],
) {
    let inputs: Vec<_> = (0..4).map(|i| synth_input(seed0 + i as u64, 1, 3, size, size)).collect();
    let mut reference: Option<Vec<Tensor<i8>>> = None;
    let mut last = 0.0f64;
    for devices in [1usize, 2, 4] {
        let opts = SchedulerOptions {
            devices,
            max_batch: 1,
            batch_deadline: 0.0,
            cache_capacity: 64,
            virtual_threads: 2,
            dram_size: 512 << 20,
        };
        let mut sched = Scheduler::new(cfg, CpuBackend::Native, opts);
        for input in &inputs {
            sched.submit(0.0, input.clone());
        }
        let r = sched.run(g).unwrap();
        match &reference {
            None => {
                for (a, b) in r.outputs.iter().zip(expect_prefix) {
                    assert_eq!(a, b, "{name}: pool diverged from the single-device engine");
                }
                reference = Some(r.outputs.clone());
            }
            Some(expect) => assert_eq!(&r.outputs, expect, "{name}: pool size changed outputs"),
        }
        let thr = r.throughput();
        assert!(
            thr > last,
            "{name}: modeled throughput must increase monotonically with pool size \
             ({devices} devices: {thr} vs previous {last})"
        );
        let utils: Vec<String> =
            (0..devices).map(|d| format!("{:.0}%", r.utilization(d) * 100.0)).collect();
        println!(
            "{name:<8} {devices:>8} {:>13.1} {:>17.1} {:>8} {:>8}  [{}]",
            r.makespan_seconds * 1e3,
            thr,
            r.cache.misses,
            r.batches.len(),
            utils.join(" ")
        );
        last = thr;
    }
}

fn main() {
    let batch: usize = std::env::args()
        .skip_while(|a| a != "--batch")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    let cfg = VtaConfig::pynq();
    let (mut g, _) = fuse(resnet::resnet18(1, 42).unwrap());
    let (vta_nodes, cpu_nodes) = partition(&mut g, &PartitionPolicy::paper(&cfg));
    let inputs: Vec<_> = (0..batch).map(|i| synth_input(7 + i as u64, 1, 3, 224, 224)).collect();
    println!(
        "# e2e serving: ResNet-18, batch {batch}, {vta_nodes} VTA nodes, {cpu_nodes} CPU nodes\n"
    );

    // ---- naive serial baseline: Executor per request ------------------
    let mut ex = Executor::new(VtaRuntime::new(&cfg, 512 << 20), CpuBackend::Native);
    let t0 = Instant::now();
    let mut naive_outputs = Vec::new();
    let mut naive_model = 0.0;
    for input in &inputs {
        let r = ex.run(&g, input).unwrap();
        naive_model += r.total_seconds();
        naive_outputs.push(r.output);
    }
    let naive_wall = t0.elapsed();
    println!(
        "naive serial executor:  host wall {naive_wall:>8.2?}   model {:.1} ms \
         (re-lowers {} conv nodes per request)",
        naive_model * 1e3,
        vta_nodes
    );

    // ---- serving engine: cold batch (compiles), warm batch (replays) --
    let mut engine = ServingEngine::new(&cfg, 512 << 20, CpuBackend::Native, 2, 64);
    let t0 = Instant::now();
    let cold = engine.run_batch(&g, &inputs).unwrap();
    let cold_wall = t0.elapsed();
    let t0 = Instant::now();
    let warm = engine.run_batch(&g, &inputs).unwrap();
    let warm_wall = t0.elapsed();

    for (a, b) in naive_outputs.iter().zip(&warm.outputs) {
        assert_eq!(a, b, "serving engine and serial executor disagree");
    }

    println!(
        "serving engine (cold):  host wall {cold_wall:>8.2?}   misses {} hits {}  \
         ({} plans, {:.1} MB DRAM)",
        cold.cache.misses,
        cold.cache.hits,
        engine.cached_plans(),
        engine.cache_dram_bytes() as f64 / 1e6
    );
    println!(
        "serving engine (warm):  host wall {warm_wall:>8.2?}   misses {} hits {}",
        warm.cache.misses, warm.cache.hits
    );
    assert_eq!(warm.cache.misses, 0, "warm batch must not re-lower");

    println!("\nend-to-end model time (batch of {batch}):");
    println!("  naive serial:        {:>10.1} ms", naive_model * 1e3);
    println!("  cached serial:       {:>10.1} ms", warm.serial_seconds * 1e3);
    println!(
        "  cached + pipelined:  {:>10.1} ms   ({:.2}x vs cached serial, {:.2}x vs naive)",
        warm.pipelined_seconds * 1e3,
        warm.speedup(),
        naive_model / warm.pipelined_seconds.max(1e-12)
    );
    assert!(
        warm.pipelined_seconds < naive_model,
        "pipelined serving must beat the naive serial path"
    );
    println!(
        "\nthroughput {:.1} inf/s; latency p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms; \
         host speedup warm-vs-naive {:.1}x",
        warm.throughput(),
        warm.latency_percentile(0.50) * 1e3,
        warm.latency_percentile(0.90) * 1e3,
        warm.latency_percentile(0.99) * 1e3,
        naive_wall.as_secs_f64() / warm_wall.as_secs_f64().max(1e-9)
    );

    // ---- op-generic offload: dense + ALU ops join the conv plans ------
    let (mut g2, _) = fuse(resnet::resnet18(1, 42).unwrap());
    let (vta2, cpu2) = partition(&mut g2, &PartitionPolicy::offload_all(&cfg));
    println!(
        "\n# offload-all policy (conv + dense + residual adds / ReLUs): \
         {vta2} VTA nodes, {cpu2} CPU nodes"
    );
    let mut engine2 = ServingEngine::new(&cfg, 512 << 20, CpuBackend::Native, 2, 64);
    let t0 = Instant::now();
    let cold2 = engine2.run_batch(&g2, &inputs).unwrap();
    let cold2_wall = t0.elapsed();
    let t0 = Instant::now();
    let warm2 = engine2.run_batch(&g2, &inputs).unwrap();
    let warm2_wall = t0.elapsed();
    for (a, b) in warm.outputs.iter().zip(&warm2.outputs) {
        assert_eq!(a, b, "offload-all changed model outputs");
    }
    assert_eq!(warm2.cache.misses, 0, "warm offload-all batch must not re-lower");
    let mut kinds: Vec<_> = engine2.cached_kinds().into_iter().collect();
    kinds.sort();
    let kinds: Vec<String> = kinds.iter().map(|(k, n)| format!("{k} x{n}")).collect();
    println!(
        "cold: host wall {cold2_wall:>8.2?}  misses {}  ({} plans: {})",
        cold2.cache.misses,
        engine2.cached_plans(),
        kinds.join(", ")
    );
    println!(
        "warm: host wall {warm2_wall:>8.2?}  hits {}  model serial {:.1} ms  \
         pipelined {:.1} ms ({:.2}x)",
        warm2.cache.hits,
        warm2.serial_seconds * 1e3,
        warm2.pipelined_seconds * 1e3,
        warm2.speedup()
    );

    // ---- style-transfer workload: the second end-to-end scenario ------
    let (mut gs, _) = fuse(style::style_transfer(1, 42).unwrap());
    let (vta_s, cpu_s) = partition(&mut gs, &PartitionPolicy::offload_all(&cfg));
    println!(
        "\n# style-transfer (32x32, offload-all: convs + adds + Min/Shr + Upsample2x): \
         {vta_s} VTA nodes, {cpu_s} CPU nodes"
    );
    let style_inputs: Vec<_> =
        (0..batch).map(|i| synth_input(50 + i as u64, 1, 3, 32, 32)).collect();
    let mut engine3 = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 2, 64);
    let t0 = Instant::now();
    let cold3 = engine3.run_batch(&gs, &style_inputs).unwrap();
    let cold3_wall = t0.elapsed();
    let t0 = Instant::now();
    let warm3 = engine3.run_batch(&gs, &style_inputs).unwrap();
    let warm3_wall = t0.elapsed();
    assert_eq!(warm3.cache.misses, 0, "warm style batch must not re-lower");
    for (a, b) in cold3.outputs.iter().zip(&warm3.outputs) {
        assert_eq!(a, b, "style cold and warm batches disagree");
    }
    // Per-request bit-exact equivalence with the serial executor.
    let mut ex3 = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
    for (i, input) in style_inputs.iter().enumerate() {
        let expect = ex3.run(&gs, input).unwrap().output;
        assert_eq!(warm3.outputs[i], expect, "style serving diverged from the serial executor");
    }
    let mut kinds3: Vec<_> = engine3.cached_kinds().into_iter().collect();
    kinds3.sort();
    let kinds3: Vec<String> = kinds3.iter().map(|(k, n)| format!("{k} x{n}")).collect();
    println!(
        "cold: host wall {cold3_wall:>8.2?}  misses {}  ({} plans: {})",
        cold3.cache.misses,
        engine3.cached_plans(),
        kinds3.join(", ")
    );
    println!(
        "warm: host wall {warm3_wall:>8.2?}  hits {}  model serial {:.1} ms  \
         pipelined {:.1} ms ({:.2}x); throughput {:.1} inf/s",
        warm3.cache.hits,
        warm3.serial_seconds * 1e3,
        warm3.pipelined_seconds * 1e3,
        warm3.speedup(),
        warm3.throughput()
    );

    // ---- device-scaling sweep: the multi-device scheduler -------------
    println!(
        "\n# device-scaling sweep: 4 requests through pools of 1/2/4 replicas \
         (compile-once per pool, least-loaded dispatch)"
    );
    println!(
        "{:<8} {:>8} {:>13} {:>17} {:>8} {:>8}  util/device",
        "model", "devices", "makespan ms", "throughput inf/s", "misses", "batches"
    );
    device_sweep(&cfg, "resnet", &g, 7, 224, &warm.outputs);
    device_sweep(&cfg, "style", &gs, 50, 32, &warm3.outputs);
}
