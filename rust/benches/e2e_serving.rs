//! Bench: end-to-end *serving* — the naive per-node serial executor
//! (re-lowers every VTA node on every inference) against the batched,
//! pipelined serving engine with a warm plan cache, the simulated
//! multi-device scheduler, and the real-threads pool under open-loop
//! load.
//!
//! Reports the two costs separately:
//!
//! * **host wall** — real time the host spends orchestrating (pack /
//!   lower / encode / simulate bookkeeping). The plan cache removes
//!   lowering and weight packing from this after the first request.
//! * **model time** — CPU wall + simulated VTA time per the paper's
//!   accounting; the pipelined schedule overlaps the two across
//!   requests (double-buffered), the serial baseline does not.
//!
//! The compile-storm section measures the concurrent JIT directly:
//! four weight-distinct style classes released at once against a cold
//! 4-replica pool, A/B'd between `serial_compile` (every plan lowered
//! under the directory lock — the pre-concurrent behavior) and the
//! claim-based concurrent path, with outputs and cache counters
//! asserted bit-equal across both modes. `--require-storm-speedup X`
//! turns the measured cold-start win into a CI gate.
//!
//! The threaded section measures *real* wall-clock concurrency: the
//! style trace through 1/2/4 worker threads (each run self-verified
//! bit-exactly against the simulated scheduler oracle, cache counters
//! included) and an open-loop Poisson ramp with per-step latency
//! percentiles and SLO attainment. The pipeline section then splits
//! the same model stage-per-replica (2 and 4 stages), verifies both
//! pipeline disciplines bit-exactly, and snapshots the deterministic
//! per-stage counters plus the modeled streaming speedup.
//!
//! Run: `cargo bench --bench e2e_serving [-- --batch N] [--fast]
//!       [--require-storm-speedup X]
//!       [--json PATH] [--check BASELINE] [--pin BASELINE]`
//!
//! `--fast` skips the ResNet-18 sections (CI speed); `--json` writes
//! the serving snapshot (`BENCH_serving.json` schema); `--check` diffs
//! the snapshot against a committed baseline — deterministic fields
//! must match exactly (a `null` baseline field is unpinned: reported,
//! not enforced), measured fields are schema-checked only; `--pin`
//! rewrites a baseline with its `null` deterministic fields filled
//! from the current run (see `common::baseline` for the CI flow).

#[allow(dead_code)] // this bench uses only the baseline half of common
mod common;

use common::baseline;
use std::time::Instant;
use vta::arch::VtaConfig;
use vta::dse::TuningRecords;
use vta::exec::serve::fleet::{
    run_fleet_threaded, FleetSpec, FleetThreadedOptions, FleetThreadedReport, RoutePolicy,
};
use vta::exec::serve::fnv1a64;
use vta::exec::{
    open_loop, run_pipeline_threaded, serve_trace, CpuBackend, Executor, LoadgenOptions,
    PipelineOptions, PipelinePartition, PipelineScheduler, Scheduler, SchedulerOptions,
    ServingEngine, ThreadedOptions, ThreadedReport,
};
use vta::graph::resnet::{self, synth_input};
use vta::graph::{fuse, partition, style, Graph, PartitionPolicy};
use vta::runtime::VtaRuntime;
use vta::util::Tensor;

/// Drain the same 4-request stream through pools of 1, 2, and 4
/// replicas (dynamic batches of 1, all arrivals at t = 0): modeled
/// throughput must increase monotonically with pool size, and the
/// outputs must stay bit-identical to the single-device engine
/// (`expect_prefix`) and across pool sizes.
fn device_sweep(
    cfg: &VtaConfig,
    name: &str,
    g: &Graph,
    seed0: u64,
    size: usize,
    expect_prefix: &[Tensor<i8>],
) {
    let inputs: Vec<_> = (0..4).map(|i| synth_input(seed0 + i as u64, 1, 3, size, size)).collect();
    let mut reference: Option<Vec<Tensor<i8>>> = None;
    let mut last = 0.0f64;
    for devices in [1usize, 2, 4] {
        let opts = SchedulerOptions {
            devices,
            max_batch: 1,
            batch_deadline: 0.0,
            cache_capacity: 64,
            virtual_threads: 2,
            dram_size: 512 << 20,
        };
        let mut sched = Scheduler::new(cfg, CpuBackend::Native, opts);
        for input in &inputs {
            sched.submit(0.0, input.clone());
        }
        let r = sched.run(g).unwrap();
        match &reference {
            None => {
                for (a, b) in r.outputs.iter().zip(expect_prefix) {
                    assert_eq!(a, b, "{name}: pool diverged from the single-device engine");
                }
                reference = Some(r.outputs.clone());
            }
            Some(expect) => assert_eq!(&r.outputs, expect, "{name}: pool size changed outputs"),
        }
        let thr = r.throughput();
        assert!(
            thr > last,
            "{name}: modeled throughput must increase monotonically with pool size \
             ({devices} devices: {thr} vs previous {last})"
        );
        let utils: Vec<String> =
            (0..devices).map(|d| format!("{:.0}%", r.utilization(d) * 100.0)).collect();
        println!(
            "{name:<8} {devices:>8} {:>13.1} {:>17.1} {:>8} {:>8}  [{}]",
            r.makespan_seconds * 1e3,
            thr,
            r.cache.misses,
            r.batches.len(),
            utils.join(" ")
        );
        last = thr;
    }
}

/// The ResNet-18 sections: naive serial vs cached/pipelined engine,
/// the widened offload boundary, and the resnet device sweep. Skipped
/// under `--fast` (CI runs the style + threaded sections only).
fn resnet_sections(cfg: &VtaConfig, batch: usize) {
    let (mut g, _) = fuse(resnet::resnet18(1, 42).unwrap()).unwrap();
    let (vta_nodes, cpu_nodes) = partition(&mut g, &PartitionPolicy::paper(cfg));
    let inputs: Vec<_> = (0..batch).map(|i| synth_input(7 + i as u64, 1, 3, 224, 224)).collect();
    println!(
        "# e2e serving: ResNet-18, batch {batch}, {vta_nodes} VTA nodes, {cpu_nodes} CPU nodes\n"
    );

    // ---- naive serial baseline: Executor per request ------------------
    let mut ex = Executor::new(VtaRuntime::new(cfg, 512 << 20), CpuBackend::Native);
    let t0 = Instant::now();
    let mut naive_outputs = Vec::new();
    let mut naive_model = 0.0;
    for input in &inputs {
        let r = ex.run(&g, input).unwrap();
        naive_model += r.total_seconds();
        naive_outputs.push(r.output);
    }
    let naive_wall = t0.elapsed();
    println!(
        "naive serial executor:  host wall {naive_wall:>8.2?}   model {:.1} ms \
         (re-lowers {} conv nodes per request)",
        naive_model * 1e3,
        vta_nodes
    );

    // ---- serving engine: cold batch (compiles), warm batch (replays) --
    let mut engine = ServingEngine::new(cfg, 512 << 20, CpuBackend::Native, 2, 64);
    let t0 = Instant::now();
    let cold = engine.run_batch(&g, &inputs).unwrap();
    let cold_wall = t0.elapsed();
    let t0 = Instant::now();
    let warm = engine.run_batch(&g, &inputs).unwrap();
    let warm_wall = t0.elapsed();

    for (a, b) in naive_outputs.iter().zip(&warm.outputs) {
        assert_eq!(a, b, "serving engine and serial executor disagree");
    }

    println!(
        "serving engine (cold):  host wall {cold_wall:>8.2?}   misses {} hits {}  \
         ({} plans, {:.1} MB DRAM)",
        cold.cache.misses,
        cold.cache.hits,
        engine.cached_plans(),
        engine.cache_dram_bytes() as f64 / 1e6
    );
    println!(
        "serving engine (warm):  host wall {warm_wall:>8.2?}   misses {} hits {}",
        warm.cache.misses, warm.cache.hits
    );
    assert_eq!(warm.cache.misses, 0, "warm batch must not re-lower");

    println!("\nend-to-end model time (batch of {batch}):");
    println!("  naive serial:        {:>10.1} ms", naive_model * 1e3);
    println!("  cached serial:       {:>10.1} ms", warm.serial_seconds * 1e3);
    println!(
        "  cached + pipelined:  {:>10.1} ms   ({:.2}x vs cached serial, {:.2}x vs naive)",
        warm.pipelined_seconds * 1e3,
        warm.speedup(),
        naive_model / warm.pipelined_seconds.max(1e-12)
    );
    assert!(
        warm.pipelined_seconds < naive_model,
        "pipelined serving must beat the naive serial path"
    );
    println!(
        "\nthroughput {:.1} inf/s; latency p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms; \
         host speedup warm-vs-naive {:.1}x",
        warm.throughput(),
        warm.latency_percentile(0.50) * 1e3,
        warm.latency_percentile(0.90) * 1e3,
        warm.latency_percentile(0.99) * 1e3,
        naive_wall.as_secs_f64() / warm_wall.as_secs_f64().max(1e-9)
    );

    // ---- op-generic offload: dense + ALU ops join the conv plans ------
    let (mut g2, _) = fuse(resnet::resnet18(1, 42).unwrap()).unwrap();
    let (vta2, cpu2) = partition(&mut g2, &PartitionPolicy::offload_all(cfg));
    println!(
        "\n# offload-all policy (conv + dense + residual adds / ReLUs): \
         {vta2} VTA nodes, {cpu2} CPU nodes"
    );
    let mut engine2 = ServingEngine::new(cfg, 512 << 20, CpuBackend::Native, 2, 64);
    let t0 = Instant::now();
    let cold2 = engine2.run_batch(&g2, &inputs).unwrap();
    let cold2_wall = t0.elapsed();
    let t0 = Instant::now();
    let warm2 = engine2.run_batch(&g2, &inputs).unwrap();
    let warm2_wall = t0.elapsed();
    for (a, b) in warm.outputs.iter().zip(&warm2.outputs) {
        assert_eq!(a, b, "offload-all changed model outputs");
    }
    assert_eq!(warm2.cache.misses, 0, "warm offload-all batch must not re-lower");
    let mut kinds: Vec<_> = engine2.cached_kinds().into_iter().collect();
    kinds.sort();
    let kinds: Vec<String> = kinds.iter().map(|(k, n)| format!("{k} x{n}")).collect();
    println!(
        "cold: host wall {cold2_wall:>8.2?}  misses {}  ({} plans: {})",
        cold2.cache.misses,
        engine2.cached_plans(),
        kinds.join(", ")
    );
    println!(
        "warm: host wall {warm2_wall:>8.2?}  hits {}  model serial {:.1} ms  \
         pipelined {:.1} ms ({:.2}x)",
        warm2.cache.hits,
        warm2.serial_seconds * 1e3,
        warm2.pipelined_seconds * 1e3,
        warm2.speedup()
    );

    // ---- device-scaling sweep: the multi-device scheduler -------------
    println!(
        "\n# resnet device-scaling sweep: 4 requests through pools of 1/2/4 replicas \
         (compile-once per pool, least-loaded dispatch)"
    );
    println!(
        "{:<8} {:>8} {:>13} {:>17} {:>8} {:>8}  util/device",
        "model", "devices", "makespan ms", "throughput inf/s", "misses", "batches"
    );
    device_sweep(cfg, "resnet", &g, 7, 224, &warm.outputs);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let batch: usize = argv
        .iter()
        .position(|a| a == "--batch")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let fast = argv.iter().any(|a| a == "--fast");
    let json_path = baseline::flag_value(&argv, "--json");
    let check_path = baseline::flag_value(&argv, "--check");
    let pin_path = baseline::flag_value(&argv, "--pin");
    let storm_gate: Option<f64> = baseline::flag_value(&argv, "--require-storm-speedup").map(|v| {
        v.parse().unwrap_or_else(|_| panic!("--require-storm-speedup wants a number, got {v}"))
    });

    let cfg = VtaConfig::pynq();
    if !fast {
        resnet_sections(&cfg, batch);
    }

    // ---- style-transfer workload: the second end-to-end scenario ------
    let (mut gs, _) = fuse(style::style_transfer(1, 42).unwrap()).unwrap();
    let (vta_s, cpu_s) = partition(&mut gs, &PartitionPolicy::offload_all(&cfg));
    println!(
        "\n# style-transfer (32x32, offload-all: convs + adds + Min/Shr + Upsample2x): \
         {vta_s} VTA nodes, {cpu_s} CPU nodes"
    );
    let style_inputs: Vec<_> =
        (0..batch).map(|i| synth_input(50 + i as u64, 1, 3, 32, 32)).collect();
    let mut engine3 = ServingEngine::new(&cfg, 256 << 20, CpuBackend::Native, 2, 64);
    let t0 = Instant::now();
    let cold3 = engine3.run_batch(&gs, &style_inputs).unwrap();
    let cold3_wall = t0.elapsed();
    let t0 = Instant::now();
    let warm3 = engine3.run_batch(&gs, &style_inputs).unwrap();
    let warm3_wall = t0.elapsed();
    assert_eq!(warm3.cache.misses, 0, "warm style batch must not re-lower");
    for (a, b) in cold3.outputs.iter().zip(&warm3.outputs) {
        assert_eq!(a, b, "style cold and warm batches disagree");
    }
    // Per-request bit-exact equivalence with the serial executor.
    let mut ex3 = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
    for (i, input) in style_inputs.iter().enumerate() {
        let expect = ex3.run(&gs, input).unwrap().output;
        assert_eq!(warm3.outputs[i], expect, "style serving diverged from the serial executor");
    }
    let mut kinds3: Vec<_> = engine3.cached_kinds().into_iter().collect();
    kinds3.sort();
    let kinds3: Vec<String> = kinds3.iter().map(|(k, n)| format!("{k} x{n}")).collect();
    println!(
        "cold: host wall {cold3_wall:>8.2?}  misses {}  ({} plans: {})",
        cold3.cache.misses,
        engine3.cached_plans(),
        kinds3.join(", ")
    );
    println!(
        "warm: host wall {warm3_wall:>8.2?}  hits {}  model serial {:.1} ms  \
         pipelined {:.1} ms ({:.2}x); throughput {:.1} inf/s",
        warm3.cache.hits,
        warm3.serial_seconds * 1e3,
        warm3.pipelined_seconds * 1e3,
        warm3.speedup(),
        warm3.throughput()
    );

    println!("\n# style device-scaling sweep: 4 requests through pools of 1/2/4 replicas");
    println!(
        "{:<8} {:>8} {:>13} {:>17} {:>8} {:>8}  util/device",
        "model", "devices", "makespan ms", "throughput inf/s", "misses", "batches"
    );
    device_sweep(&cfg, "style", &gs, 50, 32, &warm3.outputs);

    // ---- real threads: the style trace through 1/2/4 workers ----------
    // Oracle: the simulated scheduler drains the identical trace; every
    // threaded run must reproduce its outputs bit-exactly and land on
    // the same pool-level cache counters.
    let records = TuningRecords::new();
    let oracle_opts = SchedulerOptions {
        devices: 1,
        max_batch: 2,
        batch_deadline: 0.0,
        cache_capacity: 64,
        virtual_threads: 2,
        dram_size: 256 << 20,
    };
    let mut sched = Scheduler::new(&cfg, CpuBackend::Native, oracle_opts);
    for input in &style_inputs {
        sched.submit(0.0, input.clone());
    }
    let oracle = sched.run(&gs).unwrap();
    for (a, b) in oracle.outputs.iter().zip(&warm3.outputs) {
        assert_eq!(a, b, "oracle scheduler diverged from the serving engine");
    }

    let mut topts = ThreadedOptions::new(1);
    topts.virtual_threads = 2;
    topts.max_batch = 2;
    topts.dram_size = 256 << 20;
    println!("\n# threaded pool: the same style trace through real worker threads");
    println!(
        "{:>8} {:>12} {:>17} {:>8} {:>8}",
        "threads", "wall ms", "measured inf/s", "misses", "hits"
    );
    let mut thread_throughput: Vec<(usize, f64)> = Vec::new();
    let mut last_threaded: Option<ThreadedReport> = None;
    for threads in [1usize, 2, 4] {
        let mut o = topts.clone();
        o.threads = threads;
        let r = serve_trace(&cfg, &o, &records, &gs, &style_inputs).unwrap();
        assert_eq!(
            r.outputs.len(),
            oracle.outputs.len(),
            "threaded pool lost or duplicated responses"
        );
        for (i, out) in r.outputs.iter().enumerate() {
            assert_eq!(
                out, &oracle.outputs[i],
                "threaded pool ({threads} threads) diverged from the oracle at request {i}"
            );
        }
        assert_eq!(
            (r.cache.misses, r.cache.hits),
            (oracle.cache.misses, oracle.cache.hits),
            "threaded plan directory fell out of step with the oracle ({threads} threads)"
        );
        println!(
            "{threads:>8} {:>12.1} {:>17.1} {:>8} {:>8}",
            r.wall.as_secs_f64() * 1e3,
            r.throughput_rps(),
            r.cache.misses,
            r.cache.hits
        );
        thread_throughput.push((threads, r.throughput_rps()));
        last_threaded = Some(r);
    }
    let threaded = last_threaded.expect("thread sweep ran");
    println!("threaded outputs and cache counters match the simulated oracle bit-exactly");

    // ---- open-loop Poisson ramp against the 4-thread pool -------------
    let ramp_requests = if fast { 16 } else { 32 };
    let slo = 0.050;
    let lopts = LoadgenOptions::ramp(&[100.0, 400.0], ramp_requests, slo);
    let mut ramp_opts = topts.clone();
    ramp_opts.threads = 4;
    ramp_opts.queue_capacity = 16;
    let (load, _ramp) = vta::exec::run_threaded(&cfg, &ramp_opts, &records, &gs, |handle| {
        open_loop(handle, &lopts, |i| synth_input(50 + (i % 4), 1, 3, 32, 32))
    })
    .unwrap();
    println!("\n# open-loop ramp: 4 threads, queue 16, SLO {:.0} ms", slo * 1e3);
    println!(
        "{:>8} {:>8} {:>6} {:>9} {:>9} {:>9} {:>8} {:>10}",
        "qps", "offered", "shed", "p50 ms", "p99 ms", "p99.9 ms", "SLO %", "meas inf/s"
    );
    for s in &load.steps {
        println!(
            "{:>8.1} {:>8} {:>6} {:>9.2} {:>9.2} {:>9.2} {:>7.1}% {:>10.1}",
            s.qps,
            s.offered,
            s.rejected,
            s.p50 * 1e3,
            s.p99 * 1e3,
            s.p999 * 1e3,
            s.slo_attainment * 100.0,
            s.throughput_rps
        );
    }

    // ---- pipeline parallelism: one model split across the pool --------
    // The style graph split stage-per-replica (balanced on the roofline
    // model), streamed through both pipeline disciplines: simulated
    // (bit-exact vs the warm engine) and threaded (bit-exact vs the
    // simulated oracle, per-stage cache counters included). The modeled
    // K-stage streaming speedup over the 1-stage chain is deterministic
    // and lands in the snapshot's pinned section.
    println!("\n# pipeline parallelism: the style model split stage-per-replica");
    println!(
        "{:>8} {:>13} {:>15} {:>12} {:>17} {:>9}",
        "stages", "makespan ms", "modeled speedup", "wall ms", "measured inf/s", "compiles"
    );
    let serial_makespan =
        PipelinePartition::from_cuts(&cfg, &gs, &[]).modeled_makespan(style_inputs.len());
    let parts: Vec<(usize, PipelinePartition)> =
        [2usize, 4].iter().map(|&k| (k, PipelinePartition::balanced(&cfg, &gs, k))).collect();
    let mut pipeline_rows: Vec<(usize, &PipelinePartition, f64, f64, f64, Vec<u64>)> = Vec::new();
    for (k, part) in &parts {
        let k = *k;
        assert_eq!(part.len(), k, "style graph too shallow for {k} stages");
        let mut popts = PipelineOptions::new(k);
        popts.dram_size = 256 << 20;
        let mut ps = PipelineScheduler::new(&cfg, CpuBackend::Native, popts.clone());
        let piped = ps.run(&gs, part, &style_inputs).unwrap();
        for (i, out) in piped.outputs.iter().enumerate() {
            assert_eq!(
                out, &warm3.outputs[i],
                "{k}-stage pipeline diverged from the warm engine at request {i}"
            );
        }
        let tp = run_pipeline_threaded(&cfg, &popts, &records, &gs, part, &style_inputs).unwrap();
        for (i, out) in tp.outputs.iter().enumerate() {
            assert_eq!(
                out, &piped.outputs[i],
                "threaded {k}-stage pipeline diverged from the simulated oracle at request {i}"
            );
        }
        assert_eq!(
            tp.cache, piped.cache,
            "threaded {k}-stage per-stage cache counters fell out of step with the oracle"
        );
        let speedup = serial_makespan / part.modeled_makespan(style_inputs.len()).max(1e-12);
        let misses: Vec<u64> = piped.cache.iter().map(|c| c.misses).collect();
        println!(
            "{k:>8} {:>13.2} {:>14.2}x {:>12.1} {:>17.1} {:>9}",
            piped.makespan_seconds * 1e3,
            speedup,
            tp.wall.as_secs_f64() * 1e3,
            tp.throughput_rps(),
            misses.iter().sum::<u64>()
        );
        pipeline_rows.push((
            k,
            part,
            speedup,
            tp.wall.as_secs_f64() * 1e3,
            tp.throughput_rps(),
            misses,
        ));
    }
    assert!(
        pipeline_rows.iter().all(|(_, _, s, ..)| *s > 1.0),
        "splitting the style model across stages must model a streaming win"
    );
    println!("pipeline outputs and per-stage cache counters match the oracle bit-exactly");

    // ---- cold-start compile storm: concurrent vs serial JIT -----------
    // Four style classes share one architecture but carry different
    // weights: their conv plans are four disjoint key sets (the weight
    // image lives inside the plan), while the weightless eltwise plans
    // are shared keys. Submitted to a *paused* 4-replica pool and
    // released at once, all four workers hit a cold plan directory
    // together. `serial_compile` lowers every plan under the directory
    // lock (the pre-concurrent behavior); the concurrent path lowers
    // disjoint keys in parallel and parks only on another worker's
    // in-flight claim.
    let storm_graphs_owned: Vec<Graph> = (0..4)
        .map(|c| {
            let (mut g, _) =
                fuse(style::style_net(1, 16, 16, 900 + 17 * c as u64).unwrap()).unwrap();
            partition(&mut g, &PartitionPolicy::offload_all(&cfg));
            g
        })
        .collect();
    let storm_graphs: Vec<&Graph> = storm_graphs_owned.iter().collect();
    let storm_trace: Vec<(usize, Tensor<i8>)> =
        (0..4).map(|c| (c, synth_input(400 + c as u64, 1, 3, 16, 16))).collect();
    let storm_spec = FleetSpec::homogeneous(&cfg, 4);
    let storm_serial = storm_run(&storm_spec, &records, &storm_graphs, &storm_trace, true);
    let storm_conc = storm_run(&storm_spec, &records, &storm_graphs, &storm_trace, false);
    assert_eq!(
        storm_serial.outputs, storm_conc.outputs,
        "serial and concurrent compile modes must produce identical outputs"
    );
    assert_eq!(storm_serial.routes, storm_conc.routes, "compile mode must not affect routing");
    assert_eq!(
        storm_serial.group_cache, storm_conc.group_cache,
        "serial and concurrent compile modes must land on identical cache counters"
    );
    // Anchor both modes to the naive single-device serial executor.
    let mut storm_ex = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
    for (i, (c, input)) in storm_trace.iter().enumerate() {
        let expect = storm_ex.run(storm_graphs[*c], input).unwrap().output;
        assert_eq!(
            storm_conc.outputs[i], expect,
            "storm request {i} diverged from the serial executor"
        );
    }
    let storm_speedup =
        storm_serial.wall.as_secs_f64() / storm_conc.wall.as_secs_f64().max(1e-9);
    println!("\n# cold-start compile storm: 4 weight-distinct style classes, cold 4-replica pool");
    println!(
        "serial-compile: wall {:>8.1} ms  ({} directory locks, {} claim waits)",
        storm_serial.wall.as_secs_f64() * 1e3,
        storm_serial.contention.directory_locks,
        storm_serial.contention.claim_waits
    );
    println!(
        "concurrent JIT: wall {:>8.1} ms  ({} directory locks, {} claim waits)",
        storm_conc.wall.as_secs_f64() * 1e3,
        storm_conc.contention.directory_locks,
        storm_conc.contention.claim_waits
    );
    println!("storm speedup {storm_speedup:.2}x; outputs and counters bit-equal across modes");
    if let Some(need) = storm_gate {
        assert!(
            storm_speedup >= need,
            "cold-start storm speedup {storm_speedup:.2}x is below the required {need:.2}x"
        );
        println!("storm gate passed: {storm_speedup:.2}x >= {need:.2}x");
    }

    // ---- serving snapshot: emit / diff BENCH_serving.json -------------
    let snapshot = render_snapshot(
        vta_s,
        cpu_s,
        &style_inputs,
        &oracle.cache,
        &threaded,
        &thread_throughput,
        &load,
        &pipeline_rows,
        &storm_serial,
        &storm_conc,
        storm_speedup,
    );
    if let Some(path) = &json_path {
        std::fs::write(path, &snapshot).unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("\nwrote serving snapshot to {path}");
    }
    if let Some(path) = &pin_path {
        baseline::pin_baseline("serving", &snapshot, path);
    }
    if let Some(path) = &check_path {
        baseline::check_against_baseline("serving", &snapshot, path);
    }
}

/// One cold-start storm run: the trace is queued while the pool is
/// paused, then released to all four workers at once. `serial` picks
/// the compile discipline being A/B'd.
fn storm_run(
    spec: &FleetSpec,
    records: &TuningRecords,
    graphs: &[&Graph],
    trace: &[(usize, Tensor<i8>)],
    serial: bool,
) -> FleetThreadedReport {
    let mut fopts = FleetThreadedOptions::new(RoutePolicy::RoundRobin);
    fopts.max_batch = 1;
    fopts.virtual_threads = 2;
    fopts.cache_capacity = 256;
    fopts.dram_size = 256 << 20;
    fopts.start_paused = true;
    fopts.serial_compile = serial;
    let ((), report) = run_fleet_threaded(spec, &fopts, records, graphs, |handle| {
        for (class, input) in trace {
            handle.submit(*class, input.clone()).expect("storm queue open while paused");
        }
        handle.resume();
    })
    .unwrap();
    report
}

/// A latency percentile in milliseconds, or JSON `null` when the step
/// had no samples (the loadgen reports NaN then — the hand-rolled JSON
/// layer has no NaN, and `null` is the honest rendering).
fn ms_or_null(seconds: f64) -> String {
    if seconds.is_nan() {
        "null".to_string()
    } else {
        format!("{:.4}", seconds * 1e3)
    }
}

/// Render the `BENCH_serving.json` snapshot (schema 3: adds the
/// cold-start compile-storm section; schema 2 added the
/// pipeline-parallel section; ramp percentiles render `null` on
/// no-sample steps). The `deterministic` section must be
/// byte-reproducible across runs and hosts (counters, fingerprints,
/// node counts, modeled speedups); `measured` is wall-clock and
/// varies.
#[allow(clippy::too_many_arguments)]
fn render_snapshot(
    vta_nodes: usize,
    cpu_nodes: usize,
    inputs: &[Tensor<i8>],
    oracle_cache: &vta::exec::PlanCacheStats,
    threaded: &ThreadedReport,
    thread_throughput: &[(usize, f64)],
    load: &vta::exec::LoadReport,
    pipeline_rows: &[(usize, &PipelinePartition, f64, f64, f64, Vec<u64>)],
    storm_serial: &FleetThreadedReport,
    storm_conc: &FleetThreadedReport,
    storm_speedup: f64,
) -> String {
    let fps: Vec<String> = threaded
        .outputs
        .iter()
        .map(|t| fnv1a64(t.data().iter().map(|&v| v as u8)).to_string())
        .collect();
    let lookups = oracle_cache.hits + oracle_cache.misses;
    let hit_rate = if lookups == 0 { 0.0 } else { oracle_cache.hits as f64 / lookups as f64 };
    let thr: Vec<String> = thread_throughput
        .iter()
        .map(|(t, rps)| format!("      {{\"threads\": {t}, \"throughput_rps\": {rps:.3}}}"))
        .collect();
    let steps: Vec<String> = load
        .steps
        .iter()
        .map(|s| {
            format!(
                "      {{\"qps\": {:.3}, \"offered\": {}, \"shed\": {}, \"p50_ms\": {}, \
                 \"p99_ms\": {}, \"p999_ms\": {}, \"slo_attainment\": {:.4}, \
                 \"throughput_rps\": {:.3}}}",
                s.qps,
                s.offered,
                s.rejected,
                ms_or_null(s.p50),
                ms_or_null(s.p99),
                ms_or_null(s.p999),
                s.slo_attainment,
                s.throughput_rps
            )
        })
        .collect();
    let pipe_det: Vec<String> = pipeline_rows
        .iter()
        .map(|(k, part, speedup, _, _, misses)| {
            let nodes: Vec<String> =
                part.stages.iter().map(|s| s.nodes.len().to_string()).collect();
            let handoff: Vec<String> =
                part.stages.iter().map(|s| s.handoff_bytes.to_string()).collect();
            let misses: Vec<String> = misses.iter().map(|m| m.to_string()).collect();
            format!(
                "      {{\"stages\": {k}, \"per_stage_nodes\": [{}], \
                 \"per_stage_handoff_bytes\": [{}], \"per_stage_misses\": [{}], \
                 \"modeled_speedup\": {speedup:.4}}}",
                nodes.join(", "),
                handoff.join(", "),
                misses.join(", ")
            )
        })
        .collect();
    let pipe_meas: Vec<String> = pipeline_rows
        .iter()
        .map(|(k, _, _, wall_ms, rps, _)| {
            format!("      {{\"stages\": {k}, \"wall_ms\": {wall_ms:.1}, \"throughput_rps\": {rps:.3}}}")
        })
        .collect();
    let storm_cache = &storm_conc.group_cache[0];
    let storm_lookups = storm_cache.hits + storm_cache.misses;
    let storm_classes = {
        let mut cs = storm_conc.classes.clone();
        cs.sort_unstable();
        cs.dedup();
        cs.len()
    };
    let storm_fps: Vec<String> = storm_conc
        .outputs
        .iter()
        .map(|t| fnv1a64(t.data().iter().map(|&v| v as u8)).to_string())
        .collect();
    let storm_det = format!(
        "{{\"classes\": {}, \"requests\": {}, \"unique_plans\": {}, \"hits\": {}, \
         \"lookups\": {}, \"output_fp\": [{}]}}",
        storm_classes,
        storm_conc.outputs.len(),
        storm_cache.misses,
        storm_cache.hits,
        storm_lookups,
        storm_fps.join(", ")
    );
    let storm_meas = format!(
        "{{\"serial_wall_ms\": {:.1}, \"concurrent_wall_ms\": {:.1}, \"speedup\": {:.4}, \
         \"serial_directory_locks\": {}, \"concurrent_directory_locks\": {}, \
         \"concurrent_claim_waits\": {}}}",
        storm_serial.wall.as_secs_f64() * 1e3,
        storm_conc.wall.as_secs_f64() * 1e3,
        storm_speedup,
        storm_serial.contention.directory_locks,
        storm_conc.contention.directory_locks,
        storm_conc.contention.claim_waits
    );
    format!(
        "{{\n  \"schema\": 3,\n  \"workload\": \"style-transfer-32x32\",\n  \
         \"deterministic\": {{\n    \"requests\": {},\n    \"vta_nodes\": {},\n    \
         \"cpu_nodes\": {},\n    \"unique_plans\": {},\n    \"hits\": {},\n    \
         \"lookups\": {},\n    \"output_fp\": [{}],\n    \"pipeline\": [\n{}\n    ],\n    \
         \"storm\": {}\n  }},\n  \
         \"measured\": {{\n    \
         \"cache_hit_rate\": {:.6},\n    \"queue_wait_p50_ms\": {:.4},\n    \
         \"queue_wait_p99_ms\": {:.4},\n    \"service_p50_ms\": {:.4},\n    \
         \"service_p99_ms\": {:.4},\n    \"thread_sweep\": [\n{}\n    ],\n    \
         \"ramp\": [\n{}\n    ],\n    \"pipeline\": [\n{}\n    ],\n    \
         \"storm\": {}\n  }}\n}}\n",
        inputs.len(),
        vta_nodes,
        cpu_nodes,
        oracle_cache.misses,
        oracle_cache.hits,
        lookups,
        fps.join(", "),
        pipe_det.join(",\n"),
        storm_det,
        hit_rate,
        threaded.queue_wait.percentile(0.50) * 1e3,
        threaded.queue_wait.percentile(0.99) * 1e3,
        threaded.service.percentile(0.50) * 1e3,
        threaded.service.percentile(0.99) * 1e3,
        thr.join(",\n"),
        steps.join(",\n"),
        pipe_meas.join(",\n"),
        storm_meas
    )
}
