//! Snapshot-baseline plumbing shared by the bench binaries that keep a
//! committed JSON baseline (`BENCH_serving.json`,
//! `BENCH_ablations.json`).
//!
//! Every baseline-carrying bench speaks the same three flags:
//!
//! * `--json PATH`  — write the freshly rendered snapshot.
//! * `--check PATH` — diff against a baseline: every non-`null`
//!   `deterministic.*` field must match the current run **exactly**
//!   (a `null` baseline field is *unpinned*: reported, not enforced);
//!   `measured.*` keys are schema-checked only; the schema version
//!   must match.
//! * `--pin PATH`   — rewrite the baseline in place, filling every
//!   `null` deterministic field with the current run's value. Already
//!   pinned fields and the `measured` schema are left untouched, so
//!   pinning never weakens a baseline.
//!
//! CI composes them: run 1 `--check`s the committed baseline and
//! `--pin`s a scratch copy; run 2 `--check`s the scratch copy — so
//! *every* deterministic field is value-diffed across two fresh runs
//! even while the committed file still carries `null`s. A maintainer
//! pins the committed file for good with
//! `cargo bench --bench <name> -- ... --pin BENCH_<name>.json`.

#![allow(dead_code)] // each bench binary uses the subset it needs

use std::fmt::Write as _;
use vta::dse::records::json::{self, Value};

/// `--name PATH` lookup in a raw argv slice.
pub fn flag_value(argv: &[String], name: &str) -> Option<String> {
    argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1)).cloned()
}

/// Diff a freshly rendered snapshot against a committed baseline.
///
/// * `deterministic.*`: every non-`null` baseline field must match the
///   current run **exactly** — a mismatch fails the bench (and CI). A
///   `null` baseline field is *unpinned*: its current value is printed
///   so a maintainer can pin it, but nothing fails.
/// * `measured.*`: keys present in the baseline must exist in the
///   current snapshot (schema drift check); values are never compared.
pub fn check_against_baseline(kind: &str, snapshot: &str, baseline_path: &str) {
    let text = std::fs::read_to_string(baseline_path)
        .unwrap_or_else(|e| panic!("reading baseline {baseline_path}: {e}"));
    let base = json::parse(&text).unwrap_or_else(|e| panic!("baseline {baseline_path}: {e}"));
    let cur = json::parse(snapshot).expect("freshly rendered snapshot parses");

    let mut errors = Vec::new();
    let mut unpinned = Vec::new();
    diff_deterministic(
        "deterministic",
        base.get("deterministic").expect("baseline has a deterministic section"),
        cur.get("deterministic").expect("snapshot has a deterministic section"),
        &mut errors,
        &mut unpinned,
    );
    match (base.get("schema"), cur.get("schema")) {
        (Some(b), Some(c)) if b == c => {}
        (b, c) => errors.push(format!("schema version changed: {b:?} -> {c:?}")),
    }
    if let Some(Value::Obj(fields)) = base.get("measured") {
        let cm = cur.get("measured").expect("snapshot has a measured section");
        for (k, _) in fields {
            if cm.get(k).is_none() {
                errors.push(format!("measured.{k} disappeared from the snapshot"));
            }
        }
    }
    for path in &unpinned {
        println!("baseline: {path} is unpinned (null) — current value accepted");
    }
    if !errors.is_empty() {
        panic!("{kind} snapshot diverged from {baseline_path}:\n  {}", errors.join("\n  "));
    }
    println!("{kind} snapshot matches the committed baseline ({baseline_path})");
}

/// Rewrite the baseline at `baseline_path`, pinning every `null`
/// deterministic field to the current run's value. Non-`null` fields
/// (and everything outside `deterministic`) pass through unchanged.
pub fn pin_baseline(kind: &str, snapshot: &str, baseline_path: &str) {
    let text = std::fs::read_to_string(baseline_path)
        .unwrap_or_else(|e| panic!("reading baseline {baseline_path}: {e}"));
    let mut base = json::parse(&text).unwrap_or_else(|e| panic!("baseline {baseline_path}: {e}"));
    let cur = json::parse(snapshot).expect("freshly rendered snapshot parses");
    let cur_det = cur.get("deterministic").expect("snapshot has a deterministic section");

    let Value::Obj(fields) = &mut base else {
        panic!("baseline {baseline_path} is not a JSON object");
    };
    let det = fields
        .iter_mut()
        .find(|(k, _)| k == "deterministic")
        .map(|(_, v)| v)
        .unwrap_or_else(|| panic!("baseline {baseline_path} has no deterministic section"));
    let pinned = fill_nulls(det, cur_det);

    let mut out = String::new();
    render(&base, 0, &mut out);
    out.push('\n');
    std::fs::write(baseline_path, out).unwrap_or_else(|e| panic!("writing {baseline_path}: {e}"));
    println!("pinned {pinned} deterministic field(s) of the {kind} baseline ({baseline_path})");
}

/// Exact structural diff of the deterministic section. Baseline `null`
/// leaves a field unpinned; objects/arrays recurse; leaves must be
/// equal.
fn diff_deterministic(
    path: &str,
    base: &Value,
    cur: &Value,
    errors: &mut Vec<String>,
    unpinned: &mut Vec<String>,
) {
    match (base, cur) {
        (Value::Null, _) => unpinned.push(path.to_string()),
        (Value::Obj(bf), _) => {
            for (k, bv) in bf {
                match cur.get(k) {
                    Some(cv) => {
                        diff_deterministic(&format!("{path}.{k}"), bv, cv, errors, unpinned)
                    }
                    None => errors.push(format!("{path}.{k} missing from the current snapshot")),
                }
            }
        }
        (Value::Arr(bv), Value::Arr(cv)) => {
            if bv.len() != cv.len() {
                errors.push(format!("{path}: length {} -> {}", bv.len(), cv.len()));
            } else {
                for (i, (b, c)) in bv.iter().zip(cv).enumerate() {
                    diff_deterministic(&format!("{path}[{i}]"), b, c, errors, unpinned);
                }
            }
        }
        (b, c) => {
            if b != c {
                errors.push(format!("{path}: baseline {b:?} != current {c:?}"));
            }
        }
    }
}

/// Replace every `null` in `base` with the matching value from `cur`
/// (objects by key, equal-length arrays elementwise — a whole-`null`
/// array field pins wholesale). Returns the number of fields pinned.
fn fill_nulls(base: &mut Value, cur: &Value) -> usize {
    match base {
        Value::Null => {
            *base = cur.clone();
            1
        }
        Value::Obj(fields) => fields
            .iter_mut()
            .filter_map(|(k, v)| cur.get(k).map(|c| fill_nulls(v, c)))
            .sum(),
        Value::Arr(items) => match cur {
            Value::Arr(c) if c.len() == items.len() => {
                items.iter_mut().zip(c).map(|(b, c)| fill_nulls(b, c)).sum()
            }
            _ => 0,
        },
        _ => 0,
    }
}

/// True for values that render on one line inside an array.
fn is_scalar(v: &Value) -> bool {
    !matches!(v, Value::Obj(_) | Value::Arr(_))
}

/// Render a parsed [`Value`] back to JSON: 2-space indent, objects and
/// arrays-of-containers multiline, scalar arrays inline — enough to
/// rewrite a pinned baseline readably, not a general serializer.
fn render(v: &Value, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    match v {
        Value::Obj(fields) if fields.is_empty() => out.push_str("{}"),
        Value::Obj(fields) => {
            out.push_str("{\n");
            for (i, (k, val)) in fields.iter().enumerate() {
                let _ = write!(out, "{}\"{}\": ", "  ".repeat(indent + 1), escape(k));
                render(val, indent + 1, out);
                out.push_str(if i + 1 < fields.len() { ",\n" } else { "\n" });
            }
            out.push_str(&pad);
            out.push('}');
        }
        Value::Arr(items) if items.is_empty() => out.push_str("[]"),
        Value::Arr(items) if items.iter().all(is_scalar) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                render(item, indent, out);
            }
            out.push(']');
        }
        Value::Arr(items) => {
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                out.push_str(&"  ".repeat(indent + 1));
                render(item, indent + 1, out);
                out.push_str(if i + 1 < items.len() { ",\n" } else { "\n" });
            }
            out.push_str(&pad);
            out.push(']');
        }
        Value::Str(s) => {
            let _ = write!(out, "\"{}\"", escape(s));
        }
        Value::Num(n) => {
            let _ = write!(out, "{n}");
        }
        // `{:?}` prints the shortest decimal that round-trips — always
        // a valid JSON number for finite floats (the parser rejects
        // non-finite ones on the way in).
        Value::Float(x) => {
            let _ = write!(out, "{x:?}");
        }
        Value::Bool(b) => {
            let _ = write!(out, "{b}");
        }
        Value::Null => out.push_str("null"),
    }
}

/// The two escapes the in-tree JSON parser understands.
fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}
