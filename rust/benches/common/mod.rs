//! Shared helpers for the `harness = false` bench binaries.

pub mod baseline;

use vta::arch::VtaConfig;
use vta::compiler::{lower_conv2d, pack_activations, pack_weights, Conv2dOutput, Conv2dParams};
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

/// Synthesize data and run one conv layer through the full stack.
pub fn run_conv(cfg: &VtaConfig, p: &Conv2dParams, vt: usize, seed: u64) -> Conv2dOutput {
    let mut rng = XorShiftRng::new(seed);
    let inp =
        Tensor::from_vec(&[1, p.ic, p.h, p.w], rng.vec_i8(p.ic * p.h * p.w, -16, 16)).unwrap();
    let wgt = Tensor::from_vec(
        &[p.oc, p.ic, p.k, p.k],
        rng.vec_i8(p.oc * p.ic * p.k * p.k, -4, 4),
    )
    .unwrap();
    let mut rt = VtaRuntime::new(cfg, 512 << 20);
    lower_conv2d(&mut rt, p, &pack_activations(cfg, &inp), &pack_weights(cfg, &wgt), vt)
        .expect("bench conv lowering")
}

/// Filter from argv: `cargo bench --bench X -- <filter>`. The snapshot
/// flags (`--json/--check/--pin PATH`) and their path values are not
/// filters and are skipped.
pub fn arg_filter() -> Option<String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0usize;
    while i < argv.len() {
        let a = &argv[i];
        if matches!(a.as_str(), "--json" | "--check" | "--pin" | "--batch") {
            i += 2;
            continue;
        }
        if !a.starts_with('-') {
            return Some(a.clone());
        }
        i += 1;
    }
    None
}

/// True when the bench name matches the CLI filter (or no filter given).
pub fn selected(name: &str) -> bool {
    match arg_filter() {
        None => true,
        Some(f) => name.contains(&f),
    }
}
