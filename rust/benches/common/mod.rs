//! Shared helpers for the `harness = false` bench binaries.

use vta::arch::VtaConfig;
use vta::compiler::{lower_conv2d, pack_activations, pack_weights, Conv2dOutput, Conv2dParams};
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

/// Synthesize data and run one conv layer through the full stack.
pub fn run_conv(cfg: &VtaConfig, p: &Conv2dParams, vt: usize, seed: u64) -> Conv2dOutput {
    let mut rng = XorShiftRng::new(seed);
    let inp =
        Tensor::from_vec(&[1, p.ic, p.h, p.w], rng.vec_i8(p.ic * p.h * p.w, -16, 16)).unwrap();
    let wgt = Tensor::from_vec(
        &[p.oc, p.ic, p.k, p.k],
        rng.vec_i8(p.oc * p.ic * p.k * p.k, -4, 4),
    )
    .unwrap();
    let mut rt = VtaRuntime::new(cfg, 512 << 20);
    lower_conv2d(&mut rt, p, &pack_activations(cfg, &inp), &pack_weights(cfg, &wgt), vt)
        .expect("bench conv lowering")
}

/// Filter from argv: `cargo bench --bench X -- <filter>`.
pub fn arg_filter() -> Option<String> {
    std::env::args().skip(1).find(|a| !a.starts_with('-'))
}

/// True when the bench name matches the CLI filter (or no filter given).
pub fn selected(name: &str) -> bool {
    match arg_filter() {
        None => true,
        Some(f) => name.contains(&f),
    }
}
