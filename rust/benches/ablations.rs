//! Bench: ablations of the design choices DESIGN.md calls out.
//!
//! * `tlpp`       — A1: task-level pipeline parallelism (decoupled
//!                  access-execute vs serialized), per-layer (§2.3 Fig 4)
//! * `uop_cache`  — A2: micro-op cache size sweep, LRU hit/miss/eviction
//!                  behavior (§3.2)
//! * `queues`     — A3: command-queue depth sweep (§2.4 "sized deep
//!                  enough")
//! * `gemm_shape` — A4: GEMM-core shape sweep (§2.2 ISA fluidity)
//! * `dram`       — extra: DRAM bandwidth sensitivity (roofline knee)
//! * `frontier`   — A5: DSE frontier replay — search a small budget of
//!                  hardware variants + tuned schedules, then replay
//!                  the found frontier against the pynq baseline
//! * `style`      — A6: style-transfer offload boundary — cpu-only vs
//!                  paper vs offload-all placement of the style graph,
//!                  bit-exact outputs across all three
//! * `pool`       — A7: dynamic-batching knobs over a 4-replica device
//!                  pool — max_batch x deadline sweep on the style
//!                  graph: batching trades p50 latency for modeled
//!                  throughput, outputs bit-exact across every setting
//! * `fleet`      — A8: heterogeneous fleet vs homogeneous pools — the
//!                  same mixed conv+eltwise trace through a two-group
//!                  fleet under cost-model vs round-robin routing and
//!                  through same-budget homogeneous pools; outputs
//!                  bit-exact across every composition and policy
//! * `fusion`     — A9: deep operator fusion — mini-resnet and style
//!                  with their epilogue chains fused into
//!                  `FusedConv2d` nodes vs the same graphs unfused,
//!                  total simulated cycles compared at identical
//!                  placement, outputs bit-exact against the CPU
//!                  reference; `--require-fusion-improvement` turns
//!                  the cycle win into a hard gate
//!
//! Run: `cargo bench --bench ablations [-- <name>]
//!       [--json PATH] [--check BASELINE] [--pin BASELINE]
//!       [--require-fusion-improvement]`
//!
//! The snapshot flags cover the `fleet` and `fusion` sections (both
//! are force-run when a snapshot flag is present, whatever the filter)
//! and speak the `BENCH_ablations.json` schema (version 2:
//! `deterministic`/`measured` each split into `fleet` and `fusion`
//! subsections) — `--check` enforces every pinned (non-`null`)
//! deterministic field, `--pin` fills the `null` ones from the current
//! run (see `common::baseline` for the CI flow).

mod common;

use common::baseline;

use vta::arch::{parse_config_str, VtaConfig};
use vta::compiler::{lower_conv2d, pack_activations, pack_weights, Conv2dParams, Requant};
use vta::graph::resnet::table1_params;
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

fn main() {
    if common::selected("tlpp") {
        tlpp();
    }
    if common::selected("uop_cache") {
        uop_cache();
    }
    if common::selected("queues") {
        queues();
    }
    if common::selected("gemm_shape") {
        gemm_shape();
    }
    if common::selected("dram") {
        dram();
    }
    if common::selected("frontier") {
        frontier();
    }
    if common::selected("style") {
        style();
    }
    if common::selected("pool") {
        pool();
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json_path = baseline::flag_value(&argv, "--json");
    let check_path = baseline::flag_value(&argv, "--check");
    let pin_path = baseline::flag_value(&argv, "--pin");
    let want_snapshot = json_path.is_some() || check_path.is_some() || pin_path.is_some();
    // The snapshot spans both baseline-carrying sections, so a snapshot
    // flag force-runs them even when the filter names only one.
    let mut fleet_parts = None;
    if common::selected("fleet") || want_snapshot {
        fleet_parts = Some(fleet());
    }
    let mut fusion_parts = None;
    if common::selected("fusion") || want_snapshot {
        fusion_parts = Some(fusion());
    }
    if want_snapshot {
        let snapshot = render_snapshot(
            fleet_parts.as_ref().expect("fleet section force-run for snapshots"),
            fusion_parts.as_ref().expect("fusion section force-run for snapshots"),
        );
        if let Some(path) = &json_path {
            std::fs::write(path, &snapshot).unwrap_or_else(|e| panic!("writing {path}: {e}"));
            println!("wrote ablations snapshot to {path}");
        }
        if let Some(path) = &pin_path {
            baseline::pin_baseline("ablations", &snapshot, path);
        }
        if let Some(path) = &check_path {
            baseline::check_against_baseline("ablations", &snapshot, path);
        }
    }
}

/// Compose the `BENCH_ablations.json` document (schema 2) from the
/// fleet and fusion sections' (deterministic, measured) fragments.
fn render_snapshot(fleet: &(String, String), fusion: &(String, String)) -> String {
    format!(
        "{{\n  \"schema\": 2,\n  \"workload\": \"fleet-mixed-16x16 + fusion-16x16\",\n  \
         \"deterministic\": {{\n    \"fleet\": {},\n    \"fusion\": {}\n  }},\n  \
         \"measured\": {{\n    \"fleet\": {},\n    \"fusion\": {}\n  }}\n}}\n",
        fleet.0, fusion.0, fleet.1, fusion.1
    )
}

/// One fleet ablation run, reduced to what the table and the
/// `BENCH_ablations.json` snapshot need.
struct FleetRun {
    /// Modeled makespan (seconds) of the routed trace.
    modeled: f64,
    /// Simulated makespan (seconds) from the fleet scheduler.
    sim: f64,
    /// Per-request routed group.
    routes: Vec<usize>,
    /// Per-group plan-cache misses / hits.
    misses: Vec<u64>,
    hits: Vec<u64>,
    /// Host wall clock of the simulated run (measured, varies).
    host_wall_ms: f64,
    /// FNV-1a fingerprints of the outputs, in submission order.
    fps: Vec<u64>,
}

/// A8: heterogeneous fleet vs homogeneous pools — a balanced
/// conv+eltwise trace (resnet-mini under the paper rule, style net
/// fully offloaded, both 16x16) through the example two-group fleet
/// (half-lane ALU variant + stock pynq) under cost-model and
/// round-robin routing, and through two-device homogeneous pools of
/// each variant alone. Composition and routing shape timing, never
/// results: outputs are bit-exact across every run, and cost-model
/// routing must strictly beat round-robin on the modeled makespan —
/// the same inequality `serve --fleet --require-routing-win` gates
/// on. Returns the fleet section's (deterministic, measured) snapshot
/// fragments.
fn fleet() -> (String, String) {
    use vta::exec::serve::fleet::{
        modeled_fleet_makespan, FleetMember, FleetOptions, FleetScheduler, FleetSpec, RoutePolicy,
    };
    use vta::exec::serve::fnv1a64;
    use vta::exec::CpuBackend;
    use vta::graph::resnet::{resnet_mini, synth_input};
    use vta::graph::style::style_net;
    use vta::graph::{partition, Graph, PartitionPolicy};

    println!(
        "# A8: heterogeneous fleet vs homogeneous pools — mixed resnet-mini + style, \
         16x16, 8 requests"
    );
    let pynq = VtaConfig::pynq();
    let mut lanes8 = pynq.clone();
    lanes8.alu_lanes = 8;

    // The two traffic classes (vt=2): conv-bound (resnet-mini, convs
    // only — models identically on both variants) and eltwise-heavy
    // (style net fully offloaded — strictly slower on half the lanes).
    let vt = 2usize;
    let mut conv_g = resnet_mini(1, 16, 42).expect("resnet-mini graph");
    let mut conv_p = PartitionPolicy::paper(&pynq);
    conv_p.virtual_threads = vt;
    partition(&mut conv_g, &conv_p);
    let mut style_g = style_net(1, 16, 16, 42).expect("style graph");
    let mut style_p = PartitionPolicy::offload_all(&pynq);
    style_p.virtual_threads = vt;
    partition(&mut style_g, &style_p);
    let graphs: Vec<&Graph> = vec![&conv_g, &style_g];

    // Balanced alternating trace opening with style (class 1):
    // round-robin's parity then lands style on the narrow-ALU group.
    let n_req = 8usize;
    let classes: Vec<usize> = (0..n_req).map(|i| 1 - i % 2).collect();
    let inputs: Vec<Tensor<i8>> =
        (0..n_req).map(|i| synth_input(90 + i as u64, 1, 3, 16, 16)).collect();

    let hetero = FleetSpec::new(vec![
        FleetMember { cfg: lanes8.clone(), devices: 1 },
        FleetMember { cfg: pynq.clone(), devices: 1 },
    ]);
    let runs: [(&str, FleetSpec, RoutePolicy); 4] = [
        ("hetero 1+1", hetero.clone(), RoutePolicy::CostModel),
        ("hetero 1+1", hetero, RoutePolicy::RoundRobin),
        ("homog lanes8 x2", FleetSpec::homogeneous(&lanes8, 2), RoutePolicy::CostModel),
        ("homog pynq x2", FleetSpec::homogeneous(&pynq, 2), RoutePolicy::CostModel),
    ];

    println!(
        "{:<16} {:<11} {:>11} {:>13} {:>8} {:>14}",
        "composition", "routing", "modeled ms", "makespan ms", "batches", "routes/group"
    );
    let mut outputs: Option<Vec<Tensor<i8>>> = None;
    let mut results: Vec<FleetRun> = Vec::new();
    for (name, spec, policy) in runs {
        let opts = FleetOptions {
            policy,
            max_batch: 2,
            batch_deadline: 0.0,
            cache_capacity: 64,
            virtual_threads: vt,
            dram_size: 256 << 20,
        };
        let mut sched = FleetScheduler::new(&spec, CpuBackend::Native, opts);
        for (i, &c) in classes.iter().enumerate() {
            sched.submit(0.0, c, inputs[i].clone());
        }
        let group_cfgs = sched.group_configs();
        let group_devices = sched.group_devices();
        let r = sched.run(&graphs).expect("fleet run");
        let modeled =
            modeled_fleet_makespan(&group_cfgs, &group_devices, &graphs, &classes, &r.routes);
        let spread: Vec<usize> = (0..group_devices.len())
            .map(|g| r.routes.iter().filter(|&&x| x == g).count())
            .collect();
        println!(
            "{name:<16} {:<11} {:>11.3} {:>13.3} {:>8} {:>14}",
            format!("{policy:?}"),
            modeled * 1e3,
            r.makespan_seconds * 1e3,
            r.batches.len(),
            format!("{spread:?}")
        );
        match &outputs {
            None => outputs = Some(r.outputs.clone()),
            Some(expect) => assert_eq!(
                &r.outputs, expect,
                "{name} ({policy:?}): fleet composition/routing changed outputs"
            ),
        }
        results.push(FleetRun {
            modeled,
            sim: r.makespan_seconds,
            routes: r.routes.clone(),
            misses: r.group_cache.iter().map(|c| c.misses).collect(),
            hits: r.group_cache.iter().map(|c| c.hits).collect(),
            host_wall_ms: r.host_wall.as_secs_f64() * 1e3,
            fps: r
                .outputs
                .iter()
                .map(|t| fnv1a64(t.data().iter().map(|&v| v as u8)))
                .collect(),
        });
    }
    let (cm, rr) = (&results[0], &results[1]);
    assert!(
        cm.modeled < rr.modeled,
        "cost-model routing must strictly beat round-robin on the modeled makespan: \
         {:.6e} vs {:.6e}",
        cm.modeled,
        rr.modeled
    );
    println!(
        "outputs bit-exact across all compositions and policies; cost-model routing beats \
         round-robin {:.2}x modeled ({:.2}x simulated)\n",
        rr.modeled / cm.modeled,
        rr.sim / cm.sim.max(1e-12)
    );
    render_fleet_fragments(&classes, cm, rr)
}

/// Render the fleet section's snapshot fragments from the
/// heterogeneous cost-model and round-robin runs. Deterministic fields
/// are counters, routes, fingerprints, and modeled/simulated times
/// (pure functions of the trace — both timing models are exact
/// arithmetic); `measured` is host wall clock.
fn render_fleet_fragments(classes: &[usize], cm: &FleetRun, rr: &FleetRun) -> (String, String) {
    let join = |v: &[usize]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ");
    let join64 = |v: &[u64]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ");
    let ns = |s: f64| (s * 1e9).round() as u64;
    let det = format!(
        "{{\"requests\": {}, \"groups\": {}, \"classes\": [{}], \"cost_routes\": [{}], \
         \"roundrobin_routes\": [{}], \"cost_beats_roundrobin\": {}, \"group_misses\": [{}], \
         \"group_hits\": [{}], \"output_fp\": [{}], \"modeled_cost_ns\": {}, \
         \"modeled_roundrobin_ns\": {}, \"sim_cost_ns\": {}, \"sim_roundrobin_ns\": {}}}",
        classes.len(),
        cm.misses.len(),
        join(classes),
        join(&cm.routes),
        join(&rr.routes),
        cm.modeled < rr.modeled,
        join64(&cm.misses),
        join64(&cm.hits),
        join64(&cm.fps),
        ns(cm.modeled),
        ns(rr.modeled),
        ns(cm.sim),
        ns(rr.sim),
    );
    let measured = format!("{{\"sim_host_wall_ms\": {:.4}}}", cm.host_wall_ms);
    (det, measured)
}

/// A9: deep operator fusion — mini-resnet (conv→add→relu block tails)
/// and the style net (conv→add residual chains plus the conv→shr→min
/// requant tail) with epilogue chains fused into `FusedConv2d` nodes,
/// against the *same* graphs unfused at the *same* placement
/// (offload-all, vt=2, so the unfused adds/relus/shr/min run on the
/// device too — the comparison isolates the fusion rewrite, not the
/// placement). Outputs are bit-exact against the CPU reference in all
/// four runs; total simulated cycles are compared per workload, and
/// `--require-fusion-improvement` turns `fused < unfused` into a hard
/// gate (the same win the CI fusion-smoke job pins). Returns the
/// fusion section's (deterministic, measured) snapshot fragments.
fn fusion() -> (String, String) {
    use vta::exec::serve::fnv1a64;
    use vta::exec::{CpuBackend, Executor};
    use vta::graph::resnet::{resnet_mini, synth_input};
    use vta::graph::style::style_net;
    use vta::graph::{fuse, partition, Graph, PartitionPolicy};

    println!("# A9: deep operator fusion — fused vs unfused chains (16x16, offload-all, vt=2)");
    let cfg = VtaConfig::pynq();
    let require = std::env::args().any(|a| a == "--require-fusion-improvement");
    let host_t0 = std::time::Instant::now();
    let vt = 2usize;

    let build = |which: usize| -> Graph {
        match which {
            0 => resnet_mini(1, 16, 42).expect("resnet-mini graph"),
            _ => style_net(1, 16, 16, 42).expect("style graph"),
        }
    };
    let names = ["resnet-mini", "style"];
    let inputs = [synth_input(70, 1, 3, 16, 16), synth_input(71, 1, 3, 16, 16)];

    println!(
        "{:<12} {:>6} {:>14} {:>14} {:>8}",
        "workload", "fused", "unfused cyc", "fused cyc", "win"
    );
    let mut nodes_fused = Vec::new();
    let mut unfused_cycles = Vec::new();
    let mut fused_cycles = Vec::new();
    let mut improves = Vec::new();
    let mut fps = Vec::new();
    for w in 0..names.len() {
        let mut policy = PartitionPolicy::offload_all(&cfg);
        policy.virtual_threads = vt;

        let mut g_cpu = build(w);
        partition(&mut g_cpu, &PartitionPolicy::cpu_only());
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
        let golden = ex.run(&g_cpu, &inputs[w]).expect("cpu reference run").output;

        let mut g_un = build(w);
        partition(&mut g_un, &policy);
        let mut ex =
            Executor::with_virtual_threads(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native, vt);
        let r_un = ex.run(&g_un, &inputs[w]).expect("unfused run");
        assert_eq!(r_un.output, golden, "{}: unfused output diverged", names[w]);
        let un_cyc = r_un.vta_stats().total_cycles;

        let (mut g_f, n) = fuse(build(w)).expect("fuse");
        partition(&mut g_f, &policy);
        let mut ex =
            Executor::with_virtual_threads(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native, vt);
        let r_f = ex.run(&g_f, &inputs[w]).expect("fused run");
        assert_eq!(r_f.output, golden, "{}: fused output diverged", names[w]);
        let f_cyc = r_f.vta_stats().total_cycles;

        let improved = f_cyc < un_cyc;
        println!(
            "{:<12} {:>6} {:>14} {:>14} {:>7.2}x",
            names[w],
            n,
            un_cyc,
            f_cyc,
            un_cyc as f64 / f_cyc.max(1) as f64
        );
        if require {
            assert!(
                improved,
                "{}: --require-fusion-improvement, but fused {} >= unfused {} cycles",
                names[w], f_cyc, un_cyc
            );
        }
        nodes_fused.push(n as u64);
        unfused_cycles.push(un_cyc);
        fused_cycles.push(f_cyc);
        improves.push(improved);
        fps.push(fnv1a64(golden.data().iter().map(|&v| v as u8)));
    }
    println!("outputs bit-exact vs the CPU reference in all runs\n");

    let join64 = |v: &[u64]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ");
    let joinb = |v: &[bool]| v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ");
    let det = format!(
        "{{\"workloads\": [\"resnet-mini\", \"style\"], \"nodes_fused\": [{}], \
         \"unfused_cycles\": [{}], \"fused_cycles\": [{}], \"fusion_improves\": [{}], \
         \"output_fp\": [{}]}}",
        join64(&nodes_fused),
        join64(&unfused_cycles),
        join64(&fused_cycles),
        joinb(&improves),
        join64(&fps),
    );
    let measured =
        format!("{{\"host_wall_ms\": {:.4}}}", host_t0.elapsed().as_secs_f64() * 1e3);
    (det, measured)
}

/// A7: dynamic-batching knobs over a device pool — how `max_batch` and
/// the simulated `batch_deadline` shape batching, latency, and modeled
/// throughput on a fixed 4-replica pool serving a 1 ms-spaced request
/// stream, with outputs bit-exact across every setting.
fn pool() {
    use vta::exec::{CpuBackend, Scheduler, SchedulerOptions};
    use vta::graph::style::style_transfer;
    use vta::graph::{fuse, partition, PartitionPolicy};

    println!("# A7: dynamic batching over a 4-replica pool — style 32x32, 16 requests 1 ms apart");
    let cfg = VtaConfig::pynq();
    let (mut g, _) = fuse(style_transfer(1, 42).expect("style graph")).expect("fuse");
    partition(&mut g, &PartitionPolicy::offload_all(&cfg));
    let inputs: Vec<_> =
        (0..16).map(|i| vta::graph::resnet::synth_input(80 + i as u64, 1, 3, 32, 32)).collect();
    println!(
        "{:>9} {:>12} {:>8} {:>13} {:>12} {:>10} {:>10}",
        "max_batch", "deadline ms", "batches", "makespan ms", "thr inf/s", "p50 ms", "p99 ms"
    );
    let mut outputs: Option<Vec<Tensor<i8>>> = None;
    for (max_batch, deadline_ms) in [(1usize, 0.0f64), (4, 0.0), (4, 4.0), (8, 8.0)] {
        let opts = SchedulerOptions {
            devices: 4,
            max_batch,
            batch_deadline: deadline_ms * 1e-3,
            cache_capacity: 64,
            virtual_threads: 2,
            dram_size: 256 << 20,
        };
        let mut sched = Scheduler::new(&cfg, CpuBackend::Native, opts);
        for (i, input) in inputs.iter().enumerate() {
            sched.submit(i as f64 * 1e-3, input.clone());
        }
        let r = sched.run(&g).expect("pool run");
        match &outputs {
            None => outputs = Some(r.outputs.clone()),
            Some(expect) => {
                assert_eq!(&r.outputs, expect, "batching knobs must not change outputs")
            }
        }
        println!(
            "{max_batch:>9} {deadline_ms:>12.1} {:>8} {:>13.1} {:>12.1} {:>10.1} {:>10.1}",
            r.batches.len(),
            r.makespan_seconds * 1e3,
            r.throughput(),
            r.latency_percentile(0.50) * 1e3,
            r.latency_percentile(0.99) * 1e3
        );
    }
    println!();
}

/// A6: style-transfer offload boundary — how much of the
/// fast-style-transfer graph's model time moves to the accelerator as
/// the partition policy widens from the paper's conv-only rule to
/// offload-all (convs + adds + Min/Shr epilogue + Upsample2x), with
/// bit-exact outputs across all three placements.
fn style() {
    use vta::exec::{CpuBackend, Executor};
    use vta::graph::style::style_transfer;
    use vta::graph::{fuse, partition, PartitionPolicy, Placement};

    println!("# A6: style-transfer offload boundary (32x32, vt=2)");
    let cfg = VtaConfig::pynq();
    let input = vta::graph::resnet::synth_input(11, 1, 3, 32, 32);
    let policies: [(&str, PartitionPolicy); 3] = [
        ("cpu-only", PartitionPolicy::cpu_only()),
        ("paper (convs)", PartitionPolicy::paper(&cfg)),
        ("offload-all", PartitionPolicy::offload_all(&cfg)),
    ];
    println!(
        "{:<15} {:>4} {:>4} {:>12} {:>12} {:>12}",
        "policy", "vta", "cpu", "cpu wall ms", "sim ms", "model ms"
    );
    let mut outputs = Vec::new();
    for (name, policy) in policies {
        let (mut g, _) = fuse(style_transfer(1, 42).expect("style graph")).expect("fuse");
        let (vta_n, cpu_n) = partition(&mut g, &policy);
        let mut ex = Executor::new(VtaRuntime::new(&cfg, 256 << 20), CpuBackend::Native);
        let report = ex.run(&g, &input).expect("style run");
        println!(
            "{:<15} {:>4} {:>4} {:>12.3} {:>12.3} {:>12.3}",
            name,
            vta_n,
            cpu_n,
            report.cpu_time().as_secs_f64() * 1e3,
            report.vta_seconds() * 1e3,
            report.total_seconds() * 1e3
        );
        if name == "offload-all" {
            let upsampled = report
                .nodes
                .iter()
                .filter(|n| n.kind == "upsample2x" && n.placement == Placement::Vta)
                .count();
            assert!(upsampled > 0, "offload-all must place Upsample2x on the VTA");
        }
        outputs.push(report.output);
    }
    assert!(
        outputs.windows(2).all(|w| w[0] == w[1]),
        "placement must not change style outputs"
    );
    println!();
}

/// A5: design-space exploration — search, then replay the frontier.
/// Every replay re-measures the candidate's workloads from scratch
/// (fresh runtime, same deterministic lowering), confirming the
/// search's scores are reproducible.
fn frontier() {
    use vta::dse::{
        eval_conv2d, eval_eltwise, eval_matmul, eval_upsample2x, run_dse, suite, DseOptions,
        Workload,
    };

    println!("# A5: DSE frontier replay — tiny suite, budget 10");
    let mut opts = DseOptions::new(suite("tiny").expect("tiny suite"));
    opts.budget = 10;
    opts.tune_trials = 4;
    opts.seed = 0xF407;
    opts.top_k = 3;
    let report = run_dse(&opts).expect("dse run");
    println!(
        "evaluated {} candidates ({} infeasible); baseline (pynq defaults) {} cycles",
        report.evaluated, report.infeasible, report.baseline.total_cycles
    );

    println!(
        "{:>4} {:>9} {:>14} {:>14} {:>8}",
        "rank", "gemm", "search cycles", "replay cycles", "vs pynq"
    );
    for (rank, cand) in report.frontier.iter().enumerate() {
        // Replay: re-measure each workload with the recorded schedule.
        let mut replay_total = 0u64;
        for (w, s) in opts.workloads.iter().zip(&cand.scores) {
            let cycles = match w {
                Workload::Conv2d { p, .. } => {
                    eval_conv2d(&cand.cfg, p, opts.virtual_threads, s.choice.as_ref(), 17)
                        .expect("frontier conv replays")
                }
                Workload::Dense { p, .. } => {
                    eval_matmul(&cand.cfg, p, opts.virtual_threads, s.choice.as_ref(), 19)
                        .expect("frontier dense replays")
                }
                Workload::Eltwise { kind, len, .. } => {
                    eval_eltwise(&cand.cfg, *kind, *len, opts.virtual_threads, 23)
                        .expect("frontier eltwise replays")
                }
                Workload::Upsample2x { c, h, w, .. } => {
                    eval_upsample2x(&cand.cfg, *c, *h, *w, opts.virtual_threads, 29)
                        .expect("frontier upsample replays")
                }
            };
            assert_eq!(cycles, s.cycles, "replay must reproduce the search measurement");
            replay_total += cycles;
        }
        println!(
            "{:>4} {:>9} {:>14} {:>14} {:>7.2}x",
            rank + 1,
            format!("{}", cand.cfg.gemm),
            cand.total_cycles,
            replay_total,
            report.baseline.total_cycles as f64 / replay_total as f64
        );
    }
    println!();
}

/// A1: latency hiding per layer class (bandwidth-bound 1x1 vs
/// compute-bound 3x3).
fn tlpp() {
    println!("# A1: task-level pipeline parallelism (vt=1 serialized vs vt=2 decoupled)");
    let cfg = VtaConfig::pynq();
    println!("{:<5} {:>12} {:>12} {:>8} {:>8} {:>8}", "layer", "vt1 cycles", "vt2 cycles", "speedup", "util1%", "util2%");
    for i in [1usize, 2, 4, 8, 11] {
        // C2 (3x3), C3 (1x1), C5 (1x1 s2), C9 (3x3), C12 (3x3 deep)
        let p = table1_params(i);
        let a = common::run_conv(&cfg, &p, 1, 7).stats;
        let b = common::run_conv(&cfg, &p, 2, 7).stats;
        println!(
            "{:<5} {:>12} {:>12} {:>7.2}x {:>8.0} {:>8.0}",
            vta::graph::resnet::TABLE1[i].0,
            a.total_cycles,
            b.total_cycles,
            a.total_cycles as f64 / b.total_cycles as f64,
            a.compute_utilization() * 100.0,
            b.compute_utilization() * 100.0
        );
    }
    println!();
}

/// A2: micro-op cache capacity sweep on a kernel-diverse workload.
fn uop_cache() {
    println!("# A2: micro-op cache (LRU) size sweep — C12 (many kernels, 11 groups)");
    let p = table1_params(11); // C12
    let mut rng = XorShiftRng::new(9);
    let inp =
        Tensor::from_vec(&[1, p.ic, p.h, p.w], rng.vec_i8(p.ic * p.h * p.w, -16, 16)).unwrap();
    let wgt = Tensor::from_vec(
        &[p.oc, p.ic, p.k, p.k],
        rng.vec_i8(p.oc * p.ic * p.k * p.k, -4, 4),
    )
    .unwrap();
    println!("{:>10} {:>8} {:>8} {:>10} {:>12}", "uop KiB", "hits", "misses", "evictions", "cycles");
    for kib in [2usize, 4, 8, 16, 32] {
        let cfg = parse_config_str(&format!("uop_buf_kib = {kib}")).unwrap();
        let mut rt = VtaRuntime::new(&cfg, 256 << 20);
        let out = lower_conv2d(&mut rt, &p, &pack_activations(&cfg, &inp), &pack_weights(&cfg, &wgt), 2);
        match out {
            Ok(o) => println!(
                "{:>10} {:>8} {:>8} {:>10} {:>12}",
                kib, rt.ctx.uops.hits, rt.ctx.uops.misses, rt.ctx.uops.evictions, o.stats.total_cycles
            ),
            Err(e) => println!("{kib:>10} plan failed: {e}"),
        }
    }
    println!();
}

/// A3: command-queue depth sweep — shallow queues stall fetch (§2.4).
fn queues() {
    println!("# A3: command-queue depth sweep — C2 (many small instructions)");
    let p = table1_params(1); // C2
    println!("{:>7} {:>12} {:>14} {:>8}", "depth", "cycles", "fetch stalls", "util%");
    for depth in [2usize, 4, 8, 16, 64, 512] {
        let cfg = parse_config_str(&format!("cmd_queue_depth = {depth}")).unwrap();
        let s = common::run_conv(&cfg, &p, 2, 11).stats;
        println!(
            "{:>7} {:>12} {:>14} {:>8.0}",
            depth,
            s.total_cycles,
            s.fetch_stall_cycles,
            s.compute_utilization() * 100.0
        );
    }
    println!();
}

/// A4: GEMM-core shape sweep at iso-workload — the hardware-software
/// co-design space of §2.2.
fn gemm_shape() {
    println!("# A4: GEMM core shape sweep — C6 (28x28, 128→128, 3x3)");
    let rq = Requant { shift: 6, relu: false };
    let p = Conv2dParams { h: 28, w: 28, ic: 128, oc: 128, k: 3, s: 1, requant: rq };
    println!(
        "{:>9} {:>10} {:>12} {:>8} {:>8} {:>10}",
        "shape", "peak GOPS", "cycles", "GOPS", "util%", "eff vs peak"
    );
    for shape in ["1x8x8", "1x16x16", "1x32x32", "2x16x16"] {
        let cfg = parse_config_str(&format!("gemm = {shape}")).unwrap();
        // BATCH 2 needs an even-batch workload; skip it for conv (batch 1).
        if cfg.gemm.batch != 1 {
            println!("{shape:>9} (batch>1: conv batch-1 workload not applicable)");
            continue;
        }
        let s = common::run_conv(&cfg, &p, 2, 13).stats;
        let gops = p.ops() as f64 / s.total_cycles as f64 * cfg.clock_hz / 1e9;
        println!(
            "{:>9} {:>10.1} {:>12} {:>8.2} {:>8.0} {:>9.0}%",
            shape,
            cfg.peak_gops(),
            s.total_cycles,
            gops,
            s.compute_utilization() * 100.0,
            gops / cfg.peak_gops() * 100.0
        );
    }
    println!();
}

/// DRAM bandwidth sensitivity: moves the roofline knee across the layer
/// population.
fn dram() {
    println!("# DRAM bandwidth sweep — C3 (1x1, bandwidth-bound) vs C12 (3x3, compute-bound)");
    println!("{:>12} {:>14} {:>14}", "B/cycle", "C3 util%", "C12 util%");
    for bpc in [4usize, 8, 16, 32, 64] {
        let cfg = parse_config_str(&format!("dram.bytes_per_cycle = {bpc}")).unwrap();
        let c3 = common::run_conv(&cfg, &table1_params(2), 2, 17).stats;
        let c12 = common::run_conv(&cfg, &table1_params(11), 2, 17).stats;
        println!(
            "{:>12} {:>14.0} {:>14.0}",
            bpc,
            c3.compute_utilization() * 100.0,
            c12.compute_utilization() * 100.0
        );
    }
    println!();
}
