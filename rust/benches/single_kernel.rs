//! Bench: Table 1 — the single-kernel experiment.
//!
//! Regenerates the paper's Table 1 rows plus, for each conv layer, the
//! simulated performance of the lowered kernel (cycles, GOPS, GEMM
//! utilization) and the host-side compile+simulate wall time — then
//! the non-conv operator classes the registry lowers: the Dense
//! classifier on the GEMM intrinsic and ALU-class elementwise kernels
//! (residual add, ReLU) on the tensor-ALU micro-op path.
//!
//! Run: `cargo bench --bench single_kernel`

mod common;

use std::time::Instant;
use vta::arch::VtaConfig;
use vta::compiler::{
    compile_dense, compile_eltwise, pack_acc_i32, pack_matrix_a, pack_matrix_w, EltwiseKind,
    MatmulParams, Requant,
};
use vta::graph::resnet::{table1_params, TABLE1};
use vta::metrics::Roofline;
use vta::runtime::VtaRuntime;
use vta::util::{Tensor, XorShiftRng};

fn main() {
    let cfg = VtaConfig::pynq();
    let roof = Roofline::of(&cfg);
    println!(
        "# Table 1: ResNet-18 conv2d operators on VTA ({} @ {:.0} MHz, vt=2)",
        cfg.gemm,
        cfg.clock_hz / 1e6
    );
    println!(
        "{:<5} {:>8} {:>9} {:>3} {:>2} | {:>8} {:>9} {:>10} {:>7} {:>6} {:>6} | {:>9}",
        "name", "H,W", "IC,OC", "K", "S", "GOPs", "ops/byte", "cycles", "sim ms", "GOPS", "util%", "host ms"
    );
    let mut total_cycles = 0u64;
    let mut total_ops = 0u64;
    for (i, (name, h, ic, oc, k, s)) in TABLE1.iter().enumerate() {
        if !common::selected(name) {
            continue;
        }
        let p = table1_params(i);
        let t0 = Instant::now();
        let out = common::run_conv(&cfg, &p, 2, 42 + i as u64);
        let host = t0.elapsed();
        let pt = roof.point(name, p.ops(), p.arithmetic_intensity(), &out.stats);
        println!(
            "{:<5} {:>8} {:>9} {:>3} {:>2} | {:>8.3} {:>9.1} {:>10} {:>7.2} {:>6.2} {:>6.0} | {:>9.1}",
            name,
            format!("{h}"),
            format!("{ic},{oc}"),
            k,
            s,
            p.ops() as f64 / 1e9,
            p.arithmetic_intensity(),
            out.stats.total_cycles,
            out.stats.total_cycles as f64 / cfg.clock_hz * 1e3,
            pt.gops,
            pt.utilization * 100.0,
            host.as_secs_f64() * 1e3
        );
        total_cycles += out.stats.total_cycles;
        total_ops += p.ops();
    }
    if total_cycles > 0 {
        println!(
            "\naggregate: {:.2} GOPS over all selected layers ({:.1}% of {:.1} GOPS peak)",
            total_ops as f64 / total_cycles as f64 * cfg.clock_hz / 1e9,
            total_ops as f64 / total_cycles as f64 / cfg.gemm.ops_per_cycle() as f64 * 100.0,
            cfg.peak_gops()
        );
    }

    non_conv_kernels(&cfg);
}

/// The operator classes beyond conv2d that the registry lowers: the
/// FC classifier on the GEMM intrinsic, and elementwise add / ReLU on
/// the tensor ALU (compile-once, replayed).
fn non_conv_kernels(cfg: &VtaConfig) {
    println!(
        "\n# Non-conv operator kernels (compile-once / run-many, {} @ {:.0} MHz, vt=2)",
        cfg.gemm,
        cfg.clock_hz / 1e6
    );
    println!(
        "{:<22} {:>12} {:>10} {:>8} {:>10} {:>10}",
        "kernel", "elems/MACs", "cycles", "sim ms", "GOPS", "compile ms"
    );
    let mut rng = XorShiftRng::new(77);
    let mut rt = VtaRuntime::new(cfg, 256 << 20);

    // Dense: the ResNet-18 classifier (512 → 1000).
    let p = MatmulParams { m: 1, k: 512, n: 1000, requant: Requant { shift: 6, relu: false } };
    let w = Tensor::from_vec(&[p.n, p.k], rng.vec_i8(p.n * p.k, -4, 4)).unwrap();
    let a = Tensor::from_vec(&[p.m, p.k], rng.vec_i8(p.m * p.k, -16, 16)).unwrap();
    let t0 = Instant::now();
    let dense = compile_dense(&mut rt, &p, &pack_matrix_w(cfg, &w), 2).unwrap();
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (_, s) = dense.execute(&mut rt, &[pack_matrix_a(cfg, &a)]).unwrap();
    println!(
        "{:<22} {:>12} {:>10} {:>8.3} {:>10.2} {:>10.1}",
        "dense 512->1000",
        p.m * p.k * p.n,
        s.total_cycles,
        s.total_cycles as f64 / cfg.clock_hz * 1e3,
        p.ops() as f64 / s.total_cycles as f64 * cfg.clock_hz / 1e9,
        compile_ms
    );
    dense.free(&mut rt).unwrap();

    // ALU elementwise kernels over a mid-network activation tensor.
    let shape = [1usize, 64, 56, 56];
    let len: usize = shape.iter().product();
    let x = Tensor::from_vec(&shape, rng.vec_i8(len, -100, 100)).unwrap();
    let y = Tensor::from_vec(&shape, rng.vec_i8(len, -100, 100)).unwrap();
    let alu_cases = [
        ("add 1x64x56x56", EltwiseKind::AddSat),
        ("relu 1x64x56x56", EltwiseKind::Relu),
        ("shr 1x64x56x56", EltwiseKind::ShrImm(1)),
        ("min 1x64x56x56", EltwiseKind::MinImm(100)),
    ];
    for (name, kind) in alu_cases {
        let t0 = Instant::now();
        let k = compile_eltwise(&mut rt, kind, len, 2).unwrap();
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        let packed = match kind {
            EltwiseKind::AddSat => vec![pack_acc_i32(cfg, &x), pack_acc_i32(cfg, &y)],
            _ => vec![pack_acc_i32(cfg, &x)],
        };
        let (_, s) = k.execute(&mut rt, &packed).unwrap();
        println!(
            "{:<22} {:>12} {:>10} {:>8.3} {:>10.2} {:>10.1}",
            name,
            len,
            s.total_cycles,
            s.total_cycles as f64 / cfg.clock_hz * 1e3,
            len as f64 / s.total_cycles as f64 * cfg.clock_hz / 1e9,
            compile_ms
        );
        k.free(&mut rt).unwrap();
    }
}
