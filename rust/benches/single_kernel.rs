//! Bench: Table 1 — the single-kernel conv2d experiment.
//!
//! Regenerates the paper's Table 1 rows plus, for each layer, the
//! simulated performance of the lowered kernel (cycles, GOPS, GEMM
//! utilization) and the host-side compile+simulate wall time.
//!
//! Run: `cargo bench --bench single_kernel`

mod common;

use std::time::Instant;
use vta::arch::VtaConfig;
use vta::graph::resnet::{table1_params, TABLE1};
use vta::metrics::Roofline;

fn main() {
    let cfg = VtaConfig::pynq();
    let roof = Roofline::of(&cfg);
    println!(
        "# Table 1: ResNet-18 conv2d operators on VTA ({} @ {:.0} MHz, vt=2)",
        cfg.gemm,
        cfg.clock_hz / 1e6
    );
    println!(
        "{:<5} {:>8} {:>9} {:>3} {:>2} | {:>8} {:>9} {:>10} {:>7} {:>6} {:>6} | {:>9}",
        "name", "H,W", "IC,OC", "K", "S", "GOPs", "ops/byte", "cycles", "sim ms", "GOPS", "util%", "host ms"
    );
    let mut total_cycles = 0u64;
    let mut total_ops = 0u64;
    for (i, (name, h, ic, oc, k, s)) in TABLE1.iter().enumerate() {
        if !common::selected(name) {
            continue;
        }
        let p = table1_params(i);
        let t0 = Instant::now();
        let out = common::run_conv(&cfg, &p, 2, 42 + i as u64);
        let host = t0.elapsed();
        let pt = roof.point(name, p.ops(), p.arithmetic_intensity(), &out.stats);
        println!(
            "{:<5} {:>8} {:>9} {:>3} {:>2} | {:>8.3} {:>9.1} {:>10} {:>7.2} {:>6.2} {:>6.0} | {:>9.1}",
            name,
            format!("{h}"),
            format!("{ic},{oc}"),
            k,
            s,
            p.ops() as f64 / 1e9,
            p.arithmetic_intensity(),
            out.stats.total_cycles,
            out.stats.total_cycles as f64 / cfg.clock_hz * 1e3,
            pt.gops,
            pt.utilization * 100.0,
            host.as_secs_f64() * 1e3
        );
        total_cycles += out.stats.total_cycles;
        total_ops += p.ops();
    }
    if total_cycles > 0 {
        println!(
            "\naggregate: {:.2} GOPS over all selected layers ({:.1}% of {:.1} GOPS peak)",
            total_ops as f64 / total_cycles as f64 * cfg.clock_hz / 1e9,
            total_ops as f64 / total_cycles as f64 / cfg.gemm.ops_per_cycle() as f64 * 100.0,
            cfg.peak_gops()
        );
    }
}
