//! First-fit free-list allocator with neighbor coalescing.
//!
//! Shared by the DRAM buffer manager (§3.2 "Dynamic Memory Allocation")
//! and the micro-op cache's SRAM residency manager (which layers LRU
//! eviction on top).

use std::collections::BTreeMap;
use thiserror::Error;

/// Allocation errors.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum AllocError {
    #[error("out of memory: requested {requested} bytes, largest free block {largest}")]
    OutOfMemory { requested: usize, largest: usize },
    #[error("free of unknown address {0:#x}")]
    UnknownAddress(usize),
    #[error("alignment {0} is not a power of two")]
    BadAlignment(usize),
}

/// First-fit allocator over a `[0, size)` address range.
pub struct FreeListAllocator {
    size: usize,
    /// Free blocks: start → length, disjoint, coalesced.
    free: BTreeMap<usize, usize>,
    /// Live allocations: start → length.
    live: BTreeMap<usize, usize>,
}

impl FreeListAllocator {
    /// A fresh allocator over `size` units.
    pub fn new(size: usize) -> Self {
        let mut free = BTreeMap::new();
        if size > 0 {
            free.insert(0, size);
        }
        FreeListAllocator { size, free, live: BTreeMap::new() }
    }

    /// Total capacity.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Units currently allocated.
    pub fn used(&self) -> usize {
        self.live.values().sum()
    }

    /// Largest free block (diagnostics / OOM reporting).
    pub fn largest_free(&self) -> usize {
        self.free.values().copied().max().unwrap_or(0)
    }

    /// Allocate `len` units aligned to `align` (power of two). First-fit.
    pub fn alloc(&mut self, len: usize, align: usize) -> Result<usize, AllocError> {
        if !align.is_power_of_two() {
            return Err(AllocError::BadAlignment(align));
        }
        let mut chosen: Option<(usize, usize, usize)> = None; // (block_start, block_len, alloc_start)
        for (&start, &flen) in &self.free {
            let aligned = (start + align - 1) & !(align - 1);
            let pad = aligned - start;
            if flen >= pad + len {
                chosen = Some((start, flen, aligned));
                break;
            }
        }
        let Some((start, flen, aligned)) = chosen else {
            return Err(AllocError::OutOfMemory { requested: len, largest: self.largest_free() });
        };
        self.free.remove(&start);
        // Leading pad stays free.
        if aligned > start {
            self.free.insert(start, aligned - start);
        }
        // Trailing remainder stays free.
        let end = aligned + len;
        let block_end = start + flen;
        if block_end > end {
            self.free.insert(end, block_end - end);
        }
        self.live.insert(aligned, len);
        Ok(aligned)
    }

    /// Free a previous allocation, coalescing with neighbors.
    pub fn free(&mut self, addr: usize) -> Result<(), AllocError> {
        let Some(len) = self.live.remove(&addr) else {
            return Err(AllocError::UnknownAddress(addr));
        };
        let mut start = addr;
        let mut end = addr + len;
        // Coalesce with predecessor.
        if let Some((&pstart, &plen)) = self.free.range(..addr).next_back() {
            if pstart + plen == start {
                self.free.remove(&pstart);
                start = pstart;
            }
        }
        // Coalesce with successor.
        if let Some(&slen) = self.free.get(&end) {
            self.free.remove(&end);
            end += slen;
        }
        self.free.insert(start, end - start);
        Ok(())
    }

    /// Drop every allocation (used by cache flushes).
    pub fn reset(&mut self) {
        self.free.clear();
        self.live.clear();
        if self.size > 0 {
            self.free.insert(0, self.size);
        }
    }
}
