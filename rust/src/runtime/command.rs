//! The command context: instruction-stream construction plus the
//! explicit dependence API (§3.2, Fig 12).
//!
//! This is the equivalent of `VTATLSCommandHandle()`: lowered schedules
//! call `load_buffer_2d` / `push_gemm` / `push_alu` / `store_buffer_2d`
//! interleaved with `dep_push` / `dep_pop`, then `synchronize()` seals
//! the stream with a FINISH sentinel and executes it on a device.

use super::uop_kernel::{UopCache, UopError, UopKernel};
use super::{Device, DramAllocator, DramBuffer};
use crate::arch::VtaConfig;
use crate::isa::{
    AluInsn, AluOpcode, BufferId, DepFlags, GemmInsn, Instruction, MemInsn,
};
use crate::sim::{SimError, SimStats};
use thiserror::Error;

/// The three instruction-executing modules, as seen by the dependence
/// API (fetch is not a dependence endpoint).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreModule {
    Load,
    Compute,
    Store,
}

impl CoreModule {
    fn index(self) -> usize {
        self as usize
    }
}

/// Runtime errors.
#[derive(Debug, Error)]
pub enum RuntimeError {
    #[error("dep_push({0:?} -> {1:?}) is not an adjacent-module edge")]
    BadDepEdge(CoreModule, CoreModule),
    #[error("dep_push({0:?} -> {1:?}) with no prior instruction on {0:?}")]
    NoProducer(CoreModule, CoreModule),
    #[error("uop kernel error: {0}")]
    Uop(#[from] UopError),
    #[error("field overflow lowering to the ISA: {0}")]
    Isa(#[from] crate::isa::IsaError),
    #[error("simulation failed: {0}")]
    Sim(#[from] SimError),
    #[error("allocation failed: {0}")]
    Alloc(#[from] super::AllocError),
    #[error(
        "uop kernel arena exhausted: {need} uop words at tile {tile} exceed the arena limit {limit}"
    )]
    UopArenaFull { tile: u32, need: usize, limit: u32 },
}

/// Which neighbor a dependence edge touches.
fn edge(from: CoreModule, to: CoreModule) -> Option<bool /* from's next? */> {
    match (from, to) {
        (CoreModule::Load, CoreModule::Compute) => Some(true),
        (CoreModule::Compute, CoreModule::Store) => Some(true),
        (CoreModule::Compute, CoreModule::Load) => Some(false),
        (CoreModule::Store, CoreModule::Compute) => Some(false),
        _ => None,
    }
}

/// Routing: which module executes an instruction (must match the
/// simulator's fetch rules, §2.4).
fn module_of(insn: &Instruction) -> CoreModule {
    match insn {
        Instruction::Load(m) => match m.buffer {
            BufferId::Inp | BufferId::Wgt => CoreModule::Load,
            _ => CoreModule::Compute,
        },
        Instruction::Store(_) => CoreModule::Store,
        _ => CoreModule::Compute,
    }
}

/// Instruction-stream builder with dependence tracking.
pub struct CommandContext {
    cfg: VtaConfig,
    insns: Vec<Instruction>,
    /// Index of the most recent instruction routed to each module.
    last_of: [Option<usize>; 3],
    /// Pops to apply to the *next* instruction of each module:
    /// (pop_prev, pop_next).
    pending_pop: [(bool, bool); 3],
    /// Micro-op cache residency manager.
    pub uops: UopCache,
    /// DRAM write-cursor for freshly generated kernels (uop tiles).
    uop_dram_next: u32,
    /// Exclusive upper bound (uop tiles) of the kernel arena, when this
    /// context records into a bounded per-plan arena.
    uop_dram_limit: Option<u32>,
    /// Kernel words destined for the DRAM arena: (uop-tile address,
    /// words). Drained (written to the device) by `synchronize`;
    /// retained — and snapshotted into every stream — by `seal`, so
    /// each sealed stream is individually replayable.
    kernel_writes: Vec<(u32, Vec<u32>)>,
}

impl CommandContext {
    /// New context for an architecture. `uop_dram_tile` is the DRAM
    /// region (in 4-byte uop tiles) where generated kernels are cached.
    pub fn new(cfg: &VtaConfig, uop_dram_tile: u32) -> Self {
        CommandContext {
            cfg: cfg.clone(),
            insns: Vec::new(),
            last_of: [None; 3],
            pending_pop: [(false, false); 3],
            uops: UopCache::new(cfg.uop_depth()),
            uop_dram_next: uop_dram_tile,
            uop_dram_limit: None,
            kernel_writes: Vec::new(),
        }
    }

    /// New context whose generated kernels must fit in a bounded DRAM
    /// arena of `arena_uops` uop tiles starting at `uop_dram_tile`.
    ///
    /// This is the recording context used by the compile-once path
    /// ([`crate::compiler::compile_conv2d`]): each compiled plan gets
    /// its own arena slice from the DRAM allocator, so plans never
    /// overwrite each other's kernel words.
    pub fn with_arena(cfg: &VtaConfig, uop_dram_tile: u32, arena_uops: usize) -> Self {
        let mut ctx = Self::new(cfg, uop_dram_tile);
        ctx.uop_dram_limit = Some(uop_dram_tile + arena_uops as u32);
        ctx
    }

    /// Architecture this stream targets.
    pub fn config(&self) -> &VtaConfig {
        &self.cfg
    }

    /// Number of instructions queued so far.
    pub fn len(&self) -> usize {
        self.insns.len()
    }

    /// True when no instructions are queued.
    pub fn is_empty(&self) -> bool {
        self.insns.is_empty()
    }

    /// Append an instruction, applying pending pops for its module.
    pub fn push(&mut self, mut insn: Instruction) {
        let m = module_of(&insn);
        let (pp, pn) = std::mem::take(&mut self.pending_pop[m.index()]);
        {
            let deps = insn.deps_mut();
            deps.pop_prev |= pp;
            deps.pop_next |= pn;
        }
        self.last_of[m.index()] = Some(self.insns.len());
        self.insns.push(insn);
    }

    // ------------------------------------------------------------------
    // Explicit dependence API (Fig 12).
    // ------------------------------------------------------------------

    /// `VTADepPush(from, to)`: the most recent `from`-module instruction
    /// will push a token toward `to` when it completes.
    pub fn dep_push(&mut self, from: CoreModule, to: CoreModule) -> Result<(), RuntimeError> {
        let Some(is_next) = edge(from, to) else {
            return Err(RuntimeError::BadDepEdge(from, to));
        };
        let Some(idx) = self.last_of[from.index()] else {
            return Err(RuntimeError::NoProducer(from, to));
        };
        let deps = self.insns[idx].deps_mut();
        if is_next {
            deps.push_next = true;
        } else {
            deps.push_prev = true;
        }
        Ok(())
    }

    /// `VTADepPop(from, to)`: the *next* `to`-module instruction will
    /// wait for a token from `from` before executing.
    pub fn dep_pop(&mut self, from: CoreModule, to: CoreModule) -> Result<(), RuntimeError> {
        if edge(from, to).is_none() {
            return Err(RuntimeError::BadDepEdge(from, to));
        }
        // For the consumer, `from` is its prev neighbor iff `from`
        // precedes `to` in pipeline order.
        let is_prev = (from.index()) < (to.index());
        let slot = &mut self.pending_pop[to.index()];
        if is_prev {
            slot.0 = true;
        } else {
            slot.1 = true;
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Buffer movement (VTALoadBuffer2D / VTAStoreBuffer2D).
    // ------------------------------------------------------------------

    /// `VTALoadBuffer2D`: 2D strided load with optional padding.
    /// `dram_tile` addresses DRAM in tiles of the target buffer's tile
    /// size.
    #[allow(clippy::too_many_arguments)]
    pub fn load_buffer_2d(
        &mut self,
        buffer: BufferId,
        sram_base: u32,
        dram_tile: u32,
        y_size: u16,
        x_size: u16,
        x_stride: u16,
        pads: [u8; 4], // top, bottom, left, right
    ) {
        self.push(Instruction::Load(MemInsn {
            deps: DepFlags::NONE,
            buffer,
            sram_base,
            dram_base: dram_tile,
            y_size,
            x_size,
            x_stride,
            y_pad_top: pads[0],
            y_pad_bottom: pads[1],
            x_pad_left: pads[2],
            x_pad_right: pads[3],
        }));
    }

    /// `VTAStoreBuffer2D`: drain output-buffer tiles to DRAM.
    pub fn store_buffer_2d(
        &mut self,
        sram_base: u32,
        dram_tile: u32,
        y_size: u16,
        x_size: u16,
        x_stride: u16,
    ) {
        self.push(Instruction::Store(MemInsn {
            deps: DepFlags::NONE,
            buffer: BufferId::Out,
            sram_base,
            dram_base: dram_tile,
            y_size,
            x_size,
            x_stride,
            y_pad_top: 0,
            y_pad_bottom: 0,
            x_pad_left: 0,
            x_pad_right: 0,
        }));
    }

    // ------------------------------------------------------------------
    // Compute (VTAPushGEMMOp / VTAPushALUOp).
    // ------------------------------------------------------------------

    /// Register a generated kernel: writes its words to the DRAM kernel
    /// arena at synchronize time and returns its cache id.
    pub fn register_kernel(&mut self, kernel: &UopKernel) -> Result<usize, RuntimeError> {
        let tile = self.uop_dram_next;
        if let Some(limit) = self.uop_dram_limit {
            if tile + kernel.words.len() as u32 > limit {
                return Err(RuntimeError::UopArenaFull {
                    tile,
                    need: kernel.words.len(),
                    limit,
                });
            }
        }
        let id = self.uops.register(tile, kernel.words.len())?;
        // Only advance the arena for genuinely new registrations.
        if self.kernel_writes.iter().all(|(t, _)| *t != tile) {
            self.kernel_writes.push((tile, kernel.words.clone()));
            self.uop_dram_next += kernel.words.len() as u32;
        }
        Ok(id)
    }

    /// `VTAPushGEMMOp`: ensure the kernel is resident (possibly emitting
    /// a LOAD.UOP) and append a GEMM instruction running it.
    pub fn push_gemm(
        &mut self,
        kernel_id: usize,
        kernel: &UopKernel,
        reset: bool,
    ) -> Result<(), RuntimeError> {
        let mut loads = Vec::new();
        let offset = self.uops.ensure_resident(kernel_id, &mut loads)?;
        for l in loads {
            self.push(l);
        }
        let (lp0, lp1) = kernel.loop_extents();
        let (d0, d1, s0, s1, w0, w1) = kernel.factors();
        let n = kernel.words.len() as u16;
        self.push(Instruction::Gemm(GemmInsn {
            deps: DepFlags::NONE,
            reset,
            uop_begin: offset as u16,
            uop_end: offset as u16 + n,
            lp0,
            lp1,
            acc_factor0: d0,
            acc_factor1: d1,
            inp_factor0: s0,
            inp_factor1: s1,
            wgt_factor0: w0,
            wgt_factor1: w1,
        }));
        Ok(())
    }

    /// `VTAPushALUOp`: like `push_gemm` for the tensor ALU.
    pub fn push_alu(
        &mut self,
        kernel_id: usize,
        kernel: &UopKernel,
        op: AluOpcode,
        use_imm: bool,
        imm: i16,
    ) -> Result<(), RuntimeError> {
        let mut loads = Vec::new();
        let offset = self.uops.ensure_resident(kernel_id, &mut loads)?;
        for l in loads {
            self.push(l);
        }
        let (lp0, lp1) = kernel.loop_extents();
        let (d0, d1, s0, s1, _, _) = kernel.factors();
        let n = kernel.words.len() as u16;
        self.push(Instruction::Alu(AluInsn {
            deps: DepFlags::NONE,
            op,
            use_imm,
            imm,
            uop_begin: offset as u16,
            uop_end: offset as u16 + n,
            lp0,
            lp1,
            dst_factor0: d0,
            dst_factor1: d1,
            src_factor0: s0,
            src_factor1: s1,
        }));
        Ok(())
    }

    // ------------------------------------------------------------------
    // Synchronization (VTASynchronize).
    // ------------------------------------------------------------------

    /// Seal the stream (FINISH waits for the last store if any), write
    /// pending uop kernels to device DRAM, round-trip the stream through
    /// its binary encoding (the form the fetch module DMA-reads), and
    /// execute it on `device`. The context is left empty, ready for the
    /// next stream; the uop cache's residency state carries over.
    pub fn synchronize(&mut self, device: &mut dyn Device) -> Result<SimStats, RuntimeError> {
        // FINISH waits on the store module when the stream stored
        // anything that nothing else waits on.
        let mut finish = DepFlags::NONE;
        if let Some(idx) = self.last_of[CoreModule::Store.index()] {
            let deps = self.insns[idx].deps_mut();
            if !deps.push_prev {
                deps.push_prev = true;
            }
            finish.pop_next = true;
        }
        self.push(Instruction::Finish(finish));

        // Write generated kernels to the device's DRAM kernel arena.
        for (tile, words) in self.kernel_writes.drain(..) {
            device.write_u32(tile as usize * 4, &words)?;
        }

        // Binary round-trip: encode exactly what the fetch module would
        // DMA from DRAM, then decode it back.
        let bytes = Instruction::encode_stream(&self.insns)?;
        let decoded = Instruction::decode_stream(&bytes)?;
        debug_assert_eq!(decoded, self.insns);

        let stats = device.run(&decoded)?;
        self.insns.clear();
        self.last_of = [None; 3];
        self.pending_pop = [(false, false); 3];
        Ok(stats)
    }

    /// Seal the pending stream into a replayable [`SealedStream`]
    /// *without* executing it.
    ///
    /// Performs the same finalization as [`Self::synchronize`] (FINISH
    /// sentinel, binary round-trip through the fetch-module encoding)
    /// but hands the stream to the caller instead of a device. Two
    /// properties make each sealed stream individually replayable, in
    /// any order relative to other streams:
    ///
    /// * the micro-op cache's *residency* is reset at every seal, so
    ///   any stream recorded afterwards re-emits a `LOAD.UOP` for
    ///   every kernel it uses; and
    /// * the stream carries **every** kernel word registered on this
    ///   context so far (not just the ones since the last seal), so
    ///   its `LOAD.UOP`s never read DRAM that only an earlier stream
    ///   would have written. Rewriting a few KiB of kernel words per
    ///   replay is the price of order-independence.
    ///
    /// The instruction/dependence state is left empty for the next
    /// stream; registrations and the kernel-word log persist.
    pub fn seal(&mut self) -> Result<SealedStream, RuntimeError> {
        let mut finish = DepFlags::NONE;
        if let Some(idx) = self.last_of[CoreModule::Store.index()] {
            let deps = self.insns[idx].deps_mut();
            if !deps.push_prev {
                deps.push_prev = true;
            }
            finish.pop_next = true;
        }
        self.push(Instruction::Finish(finish));

        let kernel_writes: Vec<(u32, Vec<u32>)> = self.kernel_writes.clone();
        let bytes = Instruction::encode_stream(&self.insns)?;
        let insns = Instruction::decode_stream(&bytes)?;
        debug_assert_eq!(insns, self.insns);

        self.insns.clear();
        self.last_of = [None; 3];
        self.pending_pop = [(false, false); 3];
        self.uops.reset_residency();
        Ok(SealedStream { insns, kernel_writes })
    }

    /// Borrow the pending stream (testing / inspection).
    pub fn pending(&self) -> &[Instruction] {
        &self.insns
    }
}

/// A finalized, replayable instruction stream — the run-many half of
/// the compile-once/run-many split.
///
/// Produced by [`CommandContext::seal`]; owns everything a replay
/// needs besides the data buffers: the decoded instruction stream
/// (FINISH-terminated, already round-tripped through the binary
/// encoding) and the generated kernel words destined for the plan's
/// DRAM uop arena. [`SealedStream::run`] is idempotent with respect to
/// device state outside the stream's own buffers, so a cached plan can
/// replay it once per inference.
#[derive(Clone, Debug)]
pub struct SealedStream {
    insns: Vec<Instruction>,
    kernel_writes: Vec<(u32, Vec<u32>)>,
}

impl SealedStream {
    /// Number of instructions (including the FINISH sentinel).
    pub fn len(&self) -> usize {
        self.insns.len()
    }

    /// True when the stream holds no instructions.
    pub fn is_empty(&self) -> bool {
        self.insns.is_empty()
    }

    /// The instruction stream (inspection / tests).
    pub fn insns(&self) -> &[Instruction] {
        &self.insns
    }

    /// Execute the stream on `device`: (re)write the generated kernel
    /// words to the stream's DRAM arena, then run to completion.
    pub fn run(&self, device: &mut dyn Device) -> Result<SimStats, RuntimeError> {
        for (tile, words) in &self.kernel_writes {
            device.write_u32(*tile as usize * 4, words)?;
        }
        Ok(device.run(&self.insns)?)
    }
}

/// Convenience holder tying a device, allocator, and command context
/// together — what `VTATLSCommandHandle` hands out.
pub struct VtaRuntime {
    pub ctx: CommandContext,
    pub dram: DramAllocator,
    pub device: SimDeviceBox,
}

/// Boxed simulator device (the only device in this release; an FPGA
/// device would implement [`Device`] the same way).
pub type SimDeviceBox = super::SimDevice;

impl VtaRuntime {
    /// Build a runtime over a fresh simulator with `dram_size` bytes.
    /// The first `uop_arena` bytes after the 1 MiB instruction region
    /// are reserved for generated micro-kernels.
    pub fn new(cfg: &VtaConfig, dram_size: usize) -> Self {
        const UOP_ARENA_BASE: usize = 1 << 20; // kernels live at 1 MiB
        const UOP_ARENA_BYTES: usize = 1 << 20;
        let ctx = CommandContext::new(cfg, (UOP_ARENA_BASE / 4) as u32);
        let device = super::SimDevice::new(cfg.clone(), dram_size);
        let dram = DramAllocator::new(dram_size, UOP_ARENA_BASE + UOP_ARENA_BYTES);
        VtaRuntime { ctx, dram, device }
    }

    /// Allocate a DRAM buffer.
    pub fn alloc(&mut self, len: usize) -> Result<DramBuffer, RuntimeError> {
        Ok(self.dram.alloc(len)?)
    }

    /// Allocate a DRAM buffer aligned to `align` bytes (rounded up to a
    /// power of two). Tile-addressed DMA targets must use their tile
    /// size here.
    pub fn alloc_aligned(&mut self, len: usize, align: usize) -> Result<DramBuffer, RuntimeError> {
        Ok(self.dram.alloc_aligned(len, align.next_power_of_two())?)
    }

    /// Copy host data into a DRAM buffer (`VTABufferCopy`, host→device).
    pub fn copy_in(&mut self, buf: &DramBuffer, data: &[u8]) -> Result<(), RuntimeError> {
        self.device.write(buf.addr, data)?;
        Ok(())
    }

    /// Copy DRAM out to the host (`VTABufferCopy`, device→host).
    pub fn copy_out(&mut self, buf: &DramBuffer) -> Result<Vec<u8>, RuntimeError> {
        Ok(self.device.read(buf.addr, buf.len)?)
    }

    /// Run the pending stream.
    pub fn synchronize(&mut self) -> Result<SimStats, RuntimeError> {
        self.ctx.synchronize(&mut self.device)
    }
}
