//! The device pool: N independent accelerator replicas of one
//! `VtaConfig`, each a full [`VtaRuntime`] (own simulator, own DRAM,
//! own command context) — the hardware substrate of the multi-device
//! serving runtime in [`crate::exec::serve`].
//!
//! Replicas are *identical by construction*: same config, same DRAM
//! size, same fresh allocator state. The serving layer exploits that
//! to compile a plan **once per pool** and byte-replicate it
//! ([`crate::compiler::CompiledNode::replicate_to`]) onto every other
//! replica — provided it drives every replica's allocator through the
//! same allocation/eviction sequence, which the pool-lockstep plan
//! caches guarantee. The pool itself is policy-free: it owns the
//! replicas and hands out disjoint mutable borrows; queueing,
//! batching, and dispatch live in the scheduler.

use super::VtaRuntime;
use crate::arch::VtaConfig;

/// N independent `SimDevice` + `VtaRuntime` replicas of one hardware
/// variant.
pub struct DevicePool {
    cfg: VtaConfig,
    replicas: Vec<VtaRuntime>,
}

impl DevicePool {
    /// Build `devices` fresh replicas of `cfg`, each with `dram_size`
    /// bytes of device DRAM.
    pub fn new(cfg: &VtaConfig, dram_size: usize, devices: usize) -> Self {
        assert!(devices >= 1, "a device pool needs at least one replica");
        DevicePool {
            cfg: cfg.clone(),
            replicas: (0..devices).map(|_| VtaRuntime::new(cfg, dram_size)).collect(),
        }
    }

    /// The hardware variant every replica implements.
    pub fn config(&self) -> &VtaConfig {
        &self.cfg
    }

    /// Number of replicas.
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    /// Always false (construction requires at least one replica); here
    /// for the conventional `len`/`is_empty` pair.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Mutable access to replica `i`.
    pub fn device_mut(&mut self, i: usize) -> &mut VtaRuntime {
        &mut self.replicas[i]
    }

    /// Mutable access to every replica (lockstep cache maintenance).
    pub fn devices_mut(&mut self) -> &mut [VtaRuntime] {
        &mut self.replicas
    }

    /// Disjoint mutable borrows of **all** replicas at once — the
    /// threaded serving runtime hands one to each worker thread
    /// (`VtaRuntime` is plain owned data, hence `Send`; scoped threads
    /// borrow the replicas for the lifetime of the pool run).
    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, VtaRuntime> {
        self.replicas.iter_mut()
    }

    /// Disjoint mutable borrows of replicas `a` and `b` (`a != b`) —
    /// the plan-replication path reads source DRAM while writing the
    /// destination.
    pub fn pair_mut(&mut self, a: usize, b: usize) -> (&mut VtaRuntime, &mut VtaRuntime) {
        assert_ne!(a, b, "pair_mut needs two distinct replicas");
        if a < b {
            let (lo, hi) = self.replicas.split_at_mut(b);
            (&mut lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.replicas.split_at_mut(a);
            (&mut hi[0], &mut lo[b])
        }
    }
}
