//! The device pool: N independent accelerator replicas, each a full
//! [`VtaRuntime`] (own simulator, own DRAM, own command context) — the
//! hardware substrate of the multi-device serving runtime in
//! [`crate::exec::serve`].
//!
//! Two shapes exist. The general one is [`HeterogeneousPool`]: every
//! replica carries its **own** `VtaConfig`, and construction groups
//! replicas that share a config (by structural equality) into
//! [`ConfigGroup`]s. Replicas *within* a group are identical by
//! construction — same config, same DRAM size, same fresh allocator
//! state — so the serving layer can compile a plan **once per group**
//! and byte-replicate it
//! ([`crate::compiler::CompiledNode::replicate_to`]) onto the other
//! group members, provided it drives every member's allocator through
//! the same allocation/eviction sequence (the group-lockstep plan
//! caches guarantee that). Replication across *groups* is never valid:
//! compiled streams bake in config-dependent tiling and buffer
//! layouts.
//!
//! [`DevicePool`] is the homogeneous special case — N replicas of one
//! config, i.e. a heterogeneous pool with exactly one group — kept as
//! a thin wrapper because the single-config scheduler and threaded
//! runtime want the simpler API.
//!
//! The pool itself is policy-free: it owns the replicas and hands out
//! disjoint mutable borrows; queueing, batching, routing, and dispatch
//! live in the scheduler / router layers.

use super::VtaRuntime;
use crate::arch::VtaConfig;

/// The replicas of a [`HeterogeneousPool`] that share one `VtaConfig`
/// (structural equality). Plan byte-replication is valid exactly
/// within one group.
#[derive(Clone, Debug)]
pub struct ConfigGroup {
    /// The hardware variant every member implements.
    pub cfg: VtaConfig,
    /// Global replica indices of the members, in construction order.
    pub members: Vec<usize>,
}

/// N independent `SimDevice` + `VtaRuntime` replicas with per-replica
/// hardware configs, grouped by config equality.
pub struct HeterogeneousPool {
    groups: Vec<ConfigGroup>,
    replicas: Vec<VtaRuntime>,
    /// `group_of[replica] -> group index`.
    group_of: Vec<usize>,
}

impl HeterogeneousPool {
    /// Build one fresh replica per entry of `cfgs`, each with
    /// `dram_size` bytes of device DRAM. Consecutive *and*
    /// non-consecutive repeats of a config land in the same group;
    /// groups are ordered by first appearance.
    pub fn new(cfgs: &[VtaConfig], dram_size: usize) -> Self {
        assert!(!cfgs.is_empty(), "a device pool needs at least one replica");
        let mut groups: Vec<ConfigGroup> = Vec::new();
        let mut group_of = Vec::with_capacity(cfgs.len());
        for (i, cfg) in cfgs.iter().enumerate() {
            match groups.iter().position(|g| &g.cfg == cfg) {
                Some(gi) => {
                    groups[gi].members.push(i);
                    group_of.push(gi);
                }
                None => {
                    group_of.push(groups.len());
                    groups.push(ConfigGroup { cfg: cfg.clone(), members: vec![i] });
                }
            }
        }
        HeterogeneousPool {
            groups,
            replicas: cfgs.iter().map(|cfg| VtaRuntime::new(cfg, dram_size)).collect(),
            group_of,
        }
    }

    /// Total number of replicas across all groups.
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    /// Always false (construction requires at least one replica); here
    /// for the conventional `len`/`is_empty` pair.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// The config groups, ordered by first appearance.
    pub fn groups(&self) -> &[ConfigGroup] {
        &self.groups
    }

    /// Number of distinct config groups.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// The group index replica `i` belongs to.
    pub fn group_of(&self, i: usize) -> usize {
        self.group_of[i]
    }

    /// The hardware variant of replica `i`.
    pub fn config_of(&self, i: usize) -> &VtaConfig {
        &self.groups[self.group_of[i]].cfg
    }

    /// Mutable access to replica `i` (global index).
    pub fn device_mut(&mut self, i: usize) -> &mut VtaRuntime {
        &mut self.replicas[i]
    }

    /// Mutable access to every replica (lockstep cache maintenance
    /// walks a group's members through this slice).
    pub fn devices_mut(&mut self) -> &mut [VtaRuntime] {
        &mut self.replicas
    }

    /// Disjoint mutable borrows of **all** replicas at once — the
    /// threaded serving runtime hands one to each worker thread
    /// (`VtaRuntime` is plain owned data, hence `Send`; scoped threads
    /// borrow the replicas for the lifetime of the pool run).
    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, VtaRuntime> {
        self.replicas.iter_mut()
    }

    /// Disjoint mutable borrows of replicas `a` and `b` (`a != b`) —
    /// the plan-replication path reads source DRAM while writing the
    /// destination. Callers replicate only within a config group; the
    /// pool does not enforce that here because the borrow itself is
    /// config-agnostic.
    pub fn pair_mut(&mut self, a: usize, b: usize) -> (&mut VtaRuntime, &mut VtaRuntime) {
        assert_ne!(a, b, "pair_mut needs two distinct replicas");
        if a < b {
            let (lo, hi) = self.replicas.split_at_mut(b);
            (&mut lo[a], &mut hi[0])
        } else {
            let (lo, hi) = self.replicas.split_at_mut(a);
            (&mut hi[0], &mut lo[b])
        }
    }
}

/// N independent `SimDevice` + `VtaRuntime` replicas of **one**
/// hardware variant — a [`HeterogeneousPool`] with exactly one config
/// group.
pub struct DevicePool {
    inner: HeterogeneousPool,
}

impl DevicePool {
    /// Build `devices` fresh replicas of `cfg`, each with `dram_size`
    /// bytes of device DRAM.
    pub fn new(cfg: &VtaConfig, dram_size: usize, devices: usize) -> Self {
        assert!(devices >= 1, "a device pool needs at least one replica");
        let cfgs = vec![cfg.clone(); devices];
        DevicePool { inner: HeterogeneousPool::new(&cfgs, dram_size) }
    }

    /// The hardware variant every replica implements.
    pub fn config(&self) -> &VtaConfig {
        &self.inner.groups()[0].cfg
    }

    /// Number of replicas.
    pub fn len(&self) -> usize {
        self.inner.len()
    }

    /// Always false (construction requires at least one replica); here
    /// for the conventional `len`/`is_empty` pair.
    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Mutable access to replica `i`.
    pub fn device_mut(&mut self, i: usize) -> &mut VtaRuntime {
        self.inner.device_mut(i)
    }

    /// Mutable access to every replica (lockstep cache maintenance).
    pub fn devices_mut(&mut self) -> &mut [VtaRuntime] {
        self.inner.devices_mut()
    }

    /// Disjoint mutable borrows of **all** replicas at once — see
    /// [`HeterogeneousPool::iter_mut`].
    pub fn iter_mut(&mut self) -> std::slice::IterMut<'_, VtaRuntime> {
        self.inner.iter_mut()
    }

    /// Disjoint mutable borrows of replicas `a` and `b` (`a != b`) —
    /// the plan-replication path reads source DRAM while writing the
    /// destination.
    pub fn pair_mut(&mut self, a: usize, b: usize) -> (&mut VtaRuntime, &mut VtaRuntime) {
        self.inner.pair_mut(a, b)
    }
}
