//! Micro-op kernel generation and caching (§3.2 "Micro-Op Kernel
//! Generation").
//!
//! A [`UopKernelBuilder`] mirrors the `VTAUopLoopBegin` / `VTAUopPush` /
//! `VTAUopLoopEnd` API: the loop structure is captured as the CISC
//! instruction's two affine loops, and the pushes between Begin/End
//! become the micro-op sequence. Each finished kernel is written once
//! to DRAM ("generated once and cached in DRAM throughout the entire
//! lifetime of the program") and the [`UopCache`] manages which kernels
//! are resident in the on-chip micro-op SRAM with an LRU policy,
//! emitting `LOAD.UOP` instructions on misses.

use super::{AllocError, FreeListAllocator};
use crate::isa::{DepFlags, Instruction, IsaError, MemInsn, Uop};
use std::collections::HashMap;
use thiserror::Error;

/// Errors from kernel construction / caching.
#[derive(Debug, Error)]
pub enum UopError {
    #[error("VTAUopLoopBegin nested more than 2 levels")]
    TooManyLoops,
    #[error("VTAUopLoopEnd without a matching Begin")]
    UnbalancedEnd,
    #[error("kernel has no micro-ops")]
    EmptyKernel,
    #[error("kernel with {uops} uops exceeds micro-op SRAM depth {depth}")]
    KernelTooLarge { uops: usize, depth: usize },
    #[error("unknown kernel id {0}")]
    UnknownKernel(usize),
    #[error(transparent)]
    Isa(#[from] IsaError),
    #[error(transparent)]
    Alloc(#[from] AllocError),
}

/// One captured affine loop level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoopLevel {
    pub extent: u16,
    pub dst_factor: u16,
    pub src_factor: u16,
    pub wgt_factor: u16,
}

/// A finished micro-op kernel: the uop words plus the loop structure
/// that the CISC instruction will carry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UopKernel {
    /// Encoded 32-bit micro-ops.
    pub words: Vec<u32>,
    /// Up to two loop levels (outer first). Missing levels behave as
    /// extent-1 loops.
    pub loops: Vec<LoopLevel>,
}

impl UopKernel {
    /// Loop extents padded to exactly two levels `(lp0, lp1)`.
    pub fn loop_extents(&self) -> (u16, u16) {
        match self.loops.len() {
            0 => (1, 1),
            1 => (self.loops[0].extent, 1),
            _ => (self.loops[0].extent, self.loops[1].extent),
        }
    }

    /// Affine factors `(dst0, dst1, src0, src1, wgt0, wgt1)`.
    pub fn factors(&self) -> (u16, u16, u16, u16, u16, u16) {
        let get = |i: usize| self.loops.get(i).copied().unwrap_or(LoopLevel {
            extent: 1,
            dst_factor: 0,
            src_factor: 0,
            wgt_factor: 0,
        });
        let l0 = get(0);
        let l1 = get(1);
        (l0.dst_factor, l1.dst_factor, l0.src_factor, l1.src_factor, l0.wgt_factor, l1.wgt_factor)
    }

    /// Total micro-op executions implied by the loop nest.
    pub fn executions(&self) -> u64 {
        let (lp0, lp1) = self.loop_extents();
        lp0 as u64 * lp1 as u64 * self.words.len() as u64
    }
}

/// Builder mirroring `VTAUopLoopBegin`/`VTAUopPush`/`VTAUopLoopEnd`.
pub struct UopKernelBuilder {
    loops: Vec<LoopLevel>,
    open: usize,
    words: Vec<u32>,
}

impl UopKernelBuilder {
    /// Start a new kernel.
    pub fn new() -> Self {
        UopKernelBuilder { loops: Vec::new(), open: 0, words: Vec::new() }
    }

    /// `VTAUopLoopBegin(extent, dst_factor, src_factor, wgt_factor)`.
    pub fn loop_begin(
        &mut self,
        extent: u16,
        dst_factor: u16,
        src_factor: u16,
        wgt_factor: u16,
    ) -> Result<(), UopError> {
        if self.loops.len() >= 2 {
            return Err(UopError::TooManyLoops);
        }
        self.loops.push(LoopLevel { extent, dst_factor, src_factor, wgt_factor });
        self.open += 1;
        Ok(())
    }

    /// `VTAUopLoopEnd()`.
    pub fn loop_end(&mut self) -> Result<(), UopError> {
        if self.open == 0 {
            return Err(UopError::UnbalancedEnd);
        }
        self.open -= 1;
        Ok(())
    }

    /// `VTAUopPush` — append one micro-op.
    pub fn push(&mut self, uop: Uop) -> Result<(), UopError> {
        self.words.push(uop.encode()?);
        Ok(())
    }

    /// Finish the kernel.
    pub fn finish(self) -> Result<UopKernel, UopError> {
        if self.words.is_empty() {
            return Err(UopError::EmptyKernel);
        }
        Ok(UopKernel { words: self.words, loops: self.loops })
    }
}

impl Default for UopKernelBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A kernel registered with the cache (DRAM-resident).
struct CachedKernel {
    /// DRAM address of the kernel's uop words, in *uop tiles* (4 B).
    dram_tile: u32,
    n_uops: usize,
    /// SRAM offset when resident.
    resident_at: Option<u32>,
    last_use: u64,
}

/// LRU residency manager for the on-chip micro-op cache.
///
/// `ensure_resident` returns the kernel's SRAM offset, appending a
/// `LOAD.UOP` instruction to `out` when the kernel has to be brought
/// on-chip (evicting least-recently-used kernels as needed).
pub struct UopCache {
    sram: FreeListAllocator,
    kernels: Vec<CachedKernel>,
    /// kernel-id by DRAM tile (for duplicate registration checks).
    by_dram: HashMap<u32, usize>,
    clock: u64,
    /// Cumulative counters (ablation A2 reads these).
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl UopCache {
    /// A cache over a micro-op SRAM of `depth` uops.
    pub fn new(depth: usize) -> Self {
        UopCache {
            sram: FreeListAllocator::new(depth),
            kernels: Vec::new(),
            by_dram: HashMap::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Register a kernel already written to DRAM at `dram_tile`
    /// (tile = one 4-byte uop). Returns its kernel id.
    pub fn register(&mut self, dram_tile: u32, n_uops: usize) -> Result<usize, UopError> {
        if n_uops == 0 {
            return Err(UopError::EmptyKernel);
        }
        if n_uops > self.sram.size() {
            return Err(UopError::KernelTooLarge { uops: n_uops, depth: self.sram.size() });
        }
        if let Some(&id) = self.by_dram.get(&dram_tile) {
            return Ok(id);
        }
        let id = self.kernels.len();
        self.kernels.push(CachedKernel { dram_tile, n_uops, resident_at: None, last_use: 0 });
        self.by_dram.insert(dram_tile, id);
        Ok(id)
    }

    /// Forget all residency state (the SRAM allocator and every
    /// kernel's resident offset) without dropping the registrations.
    ///
    /// Used when sealing a replayable stream: the next stream recorded
    /// against this cache must re-emit a `LOAD.UOP` for every kernel it
    /// uses, so the stream stays self-contained no matter what ran on
    /// the device in between (the counters are left untouched).
    pub fn reset_residency(&mut self) {
        self.sram.reset();
        for k in &mut self.kernels {
            k.resident_at = None;
        }
    }

    /// Make kernel `id` resident; returns its SRAM uop offset. Emits a
    /// `LOAD.UOP` into `out` on a miss.
    pub fn ensure_resident(
        &mut self,
        id: usize,
        out: &mut Vec<Instruction>,
    ) -> Result<u32, UopError> {
        self.clock += 1;
        let clock = self.clock;
        if id >= self.kernels.len() {
            return Err(UopError::UnknownKernel(id));
        }
        if let Some(off) = self.kernels[id].resident_at {
            self.kernels[id].last_use = clock;
            self.hits += 1;
            return Ok(off);
        }
        self.misses += 1;
        let n_uops = self.kernels[id].n_uops;
        // Evict LRU kernels until the allocation fits.
        let offset = loop {
            match self.sram.alloc(n_uops, 1) {
                Ok(off) => break off as u32,
                Err(_) => {
                    let lru = self
                        .kernels
                        .iter()
                        .enumerate()
                        .filter(|(_, k)| k.resident_at.is_some())
                        .min_by_key(|(_, k)| k.last_use)
                        .map(|(i, _)| i);
                    let Some(victim) = lru else {
                        return Err(UopError::KernelTooLarge {
                            uops: n_uops,
                            depth: self.sram.size(),
                        });
                    };
                    let off = self.kernels[victim].resident_at.take().unwrap();
                    self.sram.free(off as usize)?;
                    self.evictions += 1;
                }
            }
        };
        self.kernels[id].resident_at = Some(offset);
        self.kernels[id].last_use = clock;
        out.push(Instruction::Load(MemInsn {
            deps: DepFlags::NONE, // compute-module FIFO order suffices
            buffer: crate::isa::BufferId::Uop,
            sram_base: offset,
            dram_base: self.kernels[id].dram_tile,
            y_size: 1,
            x_size: n_uops as u16,
            x_stride: n_uops as u16,
            y_pad_top: 0,
            y_pad_bottom: 0,
            x_pad_left: 0,
            x_pad_right: 0,
        }));
        Ok(offset)
    }
}
