//! The VTA runtime (§3): the layer a lowered schedule calls into.
//!
//! Mirrors the C++ JIT runtime API of the paper:
//!
//! * [`DramAllocator`] — `VTABufferAlloc`/`VTABufferFree`/`VTABufferCopy`:
//!   physically-contiguous DRAM buffer management.
//! * [`UopKernel`] / [`UopCache`] — `VTAUopLoopBegin`/`VTAUopPush`/
//!   `VTAUopLoopEnd`: micro-kernel generation, DRAM-resident kernel
//!   caching, and LRU residency management of the on-chip micro-op cache.
//! * [`CommandContext`] — `VTALoadBuffer2D`/`VTAStoreBuffer2D`/
//!   `VTAPushGEMMOp`/`VTAPushALUOp` plus the explicit dependence API
//!   `VTADepPush`/`VTADepPop` (§3.2, Fig 12).
//! * [`CommandContext::synchronize`] — `VTASynchronize`: finalize the
//!   stream (FINISH sentinel), hand off to the device, wait for
//!   completion.
//! * [`DevicePool`] / [`HeterogeneousPool`] — N independent runtime
//!   replicas (of one variant, or grouped per-replica variants): the
//!   substrate of the multi-device serving runtime
//!   ([`crate::exec::serve`]).

mod alloc;
mod command;
mod device;
mod pool;
mod uop_kernel;

pub use alloc::{AllocError, FreeListAllocator};
pub use command::{CommandContext, CoreModule, RuntimeError, SealedStream, VtaRuntime};
pub use device::{Device, SimDevice};
pub use pool::{ConfigGroup, DevicePool, HeterogeneousPool};
pub use uop_kernel::{UopCache, UopError, UopKernel, UopKernelBuilder};

/// A DRAM buffer handle returned by the allocator: physically
/// contiguous, so the accelerator can DMA from `addr` directly (§3.2
/// "Dynamic Memory Allocation").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DramBuffer {
    /// Physical byte address in accelerator DRAM.
    pub addr: usize,
    /// Size in bytes.
    pub len: usize,
}

/// DRAM allocator wrapping the free-list core with VTA-flavoured naming.
pub struct DramAllocator {
    inner: FreeListAllocator,
}

impl DramAllocator {
    /// Manage `size` bytes of DRAM, reserving the first `reserved`
    /// bytes (instruction stream + uop kernel area, managed separately).
    pub fn new(size: usize, reserved: usize) -> Self {
        let mut inner = FreeListAllocator::new(size);
        if reserved > 0 {
            inner.alloc(reserved, 1).expect("reserving DRAM prefix");
        }
        DramAllocator { inner }
    }

    /// Allocate a physically contiguous buffer (64-byte aligned, like
    /// the runtime's cache-line alignment).
    pub fn alloc(&mut self, len: usize) -> Result<DramBuffer, AllocError> {
        self.alloc_aligned(len, 64)
    }

    /// Allocate with an explicit alignment. DMA-addressed buffers must
    /// be aligned to their *tile size* — LOAD/STORE `dram_base` fields
    /// are in tile units (§2.2), so a misaligned buffer is unaddressable
    /// by the accelerator.
    pub fn alloc_aligned(&mut self, len: usize, align: usize) -> Result<DramBuffer, AllocError> {
        let addr = self.inner.alloc(len.max(1), align.max(64))?;
        Ok(DramBuffer { addr, len })
    }

    /// Release a buffer.
    pub fn free(&mut self, buf: DramBuffer) -> Result<(), AllocError> {
        self.inner.free(buf.addr)
    }

    /// Bytes currently allocated.
    pub fn used(&self) -> usize {
        self.inner.used()
    }
}

#[cfg(test)]
mod tests;
