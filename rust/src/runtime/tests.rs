use super::uop_kernel::*;
use super::*;
use crate::arch::VtaConfig;
use crate::isa::*;

// ---------------------------------------------------------------------
// Free-list allocator.
// ---------------------------------------------------------------------

#[test]
fn alloc_first_fit_and_coalesce() {
    let mut a = FreeListAllocator::new(1024);
    let x = a.alloc(100, 1).unwrap();
    let y = a.alloc(200, 1).unwrap();
    let z = a.alloc(300, 1).unwrap();
    assert_eq!((x, y, z), (0, 100, 300));
    assert_eq!(a.used(), 600);
    // Free the middle, then the first: blocks must coalesce so a
    // 300-unit allocation fits in the front hole.
    a.free(y).unwrap();
    a.free(x).unwrap();
    let w = a.alloc(300, 1).unwrap();
    assert_eq!(w, 0);
}

#[test]
fn alloc_respects_alignment() {
    let mut a = FreeListAllocator::new(1024);
    let _ = a.alloc(10, 1).unwrap();
    let x = a.alloc(16, 64).unwrap();
    assert_eq!(x % 64, 0);
    assert!(a.alloc(16, 63).is_err()); // not a power of two
}

#[test]
fn alloc_oom_reports_largest_block() {
    let mut a = FreeListAllocator::new(128);
    let x = a.alloc(64, 1).unwrap();
    match a.alloc(100, 1) {
        Err(AllocError::OutOfMemory { requested: 100, largest: 64 }) => {}
        other => panic!("unexpected: {other:?}"),
    }
    a.free(x).unwrap();
    assert_eq!(a.alloc(100, 1).unwrap(), 0);
}

#[test]
fn double_free_is_an_error() {
    let mut a = FreeListAllocator::new(64);
    let x = a.alloc(8, 1).unwrap();
    a.free(x).unwrap();
    assert!(matches!(a.free(x), Err(AllocError::UnknownAddress(_))));
}

// ---------------------------------------------------------------------
// Uop kernels.
// ---------------------------------------------------------------------

#[test]
fn kernel_builder_captures_loops_and_uops() {
    let mut b = UopKernelBuilder::new();
    b.loop_begin(4, 2, 1, 0).unwrap();
    b.loop_begin(3, 1, 0, 1).unwrap();
    b.push(Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 })).unwrap();
    b.push(Uop::Gemm(GemmUop { acc_idx: 1, inp_idx: 1, wgt_idx: 0 })).unwrap();
    b.loop_end().unwrap();
    b.loop_end().unwrap();
    let k = b.finish().unwrap();
    assert_eq!(k.words.len(), 2);
    assert_eq!(k.loop_extents(), (4, 3));
    assert_eq!(k.factors(), (2, 1, 1, 0, 0, 1));
    assert_eq!(k.executions(), 24);
}

#[test]
fn kernel_builder_rejects_nesting_and_empty() {
    let mut b = UopKernelBuilder::new();
    b.loop_begin(1, 0, 0, 0).unwrap();
    b.loop_begin(1, 0, 0, 0).unwrap();
    assert!(matches!(b.loop_begin(1, 0, 0, 0), Err(UopError::TooManyLoops)));

    let mut b = UopKernelBuilder::new();
    assert!(matches!(b.loop_end(), Err(UopError::UnbalancedEnd)));
    assert!(matches!(UopKernelBuilder::new().finish(), Err(UopError::EmptyKernel)));
}

#[test]
fn uop_cache_hits_misses_and_lru_eviction() {
    // Cache of 8 uops; three 4-uop kernels can't all be resident.
    let mut c = UopCache::new(8);
    let k0 = c.register(0, 4).unwrap();
    let k1 = c.register(100, 4).unwrap();
    let k2 = c.register(200, 4).unwrap();

    let mut out = Vec::new();
    c.ensure_resident(k0, &mut out).unwrap();
    c.ensure_resident(k1, &mut out).unwrap();
    assert_eq!(out.len(), 2); // two LOAD.UOPs
    assert_eq!((c.hits, c.misses, c.evictions), (0, 2, 0));

    // k0 again: hit, no new load.
    c.ensure_resident(k0, &mut out).unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(c.hits, 1);

    // k2: must evict the LRU (k1, since k0 was just touched).
    c.ensure_resident(k2, &mut out).unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(c.evictions, 1);

    // k0 must still be resident.
    c.ensure_resident(k0, &mut out).unwrap();
    assert_eq!(out.len(), 3);

    // k1 was evicted: miss again.
    c.ensure_resident(k1, &mut out).unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn uop_cache_rejects_oversized_kernel() {
    let mut c = UopCache::new(8);
    assert!(matches!(c.register(0, 9), Err(UopError::KernelTooLarge { .. })));
}

#[test]
fn uop_cache_duplicate_registration_is_idempotent() {
    let mut c = UopCache::new(16);
    let a = c.register(0, 4).unwrap();
    let b = c.register(0, 4).unwrap();
    assert_eq!(a, b);
}

// ---------------------------------------------------------------------
// Command context + dependence API.
// ---------------------------------------------------------------------

fn cfg() -> VtaConfig {
    VtaConfig::pynq()
}

#[test]
fn dep_push_sets_flags_on_producer() {
    let mut ctx = CommandContext::new(&cfg(), 1 << 18);
    ctx.load_buffer_2d(BufferId::Inp, 0, 0, 1, 1, 1, [0; 4]);
    ctx.dep_push(CoreModule::Load, CoreModule::Compute).unwrap();
    match ctx.pending()[0] {
        Instruction::Load(m) => assert!(m.deps.push_next),
        _ => panic!(),
    }
}

#[test]
fn dep_pop_applies_to_next_consumer_instruction() {
    let mut ctx = CommandContext::new(&cfg(), 1 << 18);
    ctx.load_buffer_2d(BufferId::Inp, 0, 0, 1, 1, 1, [0; 4]);
    ctx.dep_push(CoreModule::Load, CoreModule::Compute).unwrap();
    ctx.dep_pop(CoreModule::Load, CoreModule::Compute).unwrap();
    // Next compute instruction (an acc load) must pop_prev.
    ctx.load_buffer_2d(BufferId::Acc, 0, 1024, 1, 1, 1, [0; 4]);
    match ctx.pending()[1] {
        Instruction::Load(m) => {
            assert_eq!(m.buffer, BufferId::Acc);
            assert!(m.deps.pop_prev);
        }
        _ => panic!(),
    }
}

#[test]
fn dep_api_rejects_nonadjacent_edges() {
    let mut ctx = CommandContext::new(&cfg(), 1 << 18);
    assert!(matches!(
        ctx.dep_push(CoreModule::Load, CoreModule::Store),
        Err(RuntimeError::BadDepEdge(..))
    ));
    assert!(matches!(
        ctx.dep_pop(CoreModule::Store, CoreModule::Load),
        Err(RuntimeError::BadDepEdge(..))
    ));
}

#[test]
fn dep_push_without_producer_fails() {
    let mut ctx = CommandContext::new(&cfg(), 1 << 18);
    assert!(matches!(
        ctx.dep_push(CoreModule::Load, CoreModule::Compute),
        Err(RuntimeError::NoProducer(..))
    ));
}

/// End-to-end: the vector-add example of §3 (Listing 1) lowered by hand
/// through the runtime API, run on the simulator device.
#[test]
fn listing1_vector_add_runs() {
    let cfg = cfg();
    let mut rt = VtaRuntime::new(&cfg, 8 << 20);

    // Two 64-tile int32 vectors A (into acc 0..64) and B (acc 64..128).
    let n_tiles = 64u16;
    let lanes = cfg.gemm.batch * cfg.gemm.block_out; // 16 i32 per tile
    let a_host: Vec<i32> = (0..n_tiles as usize * lanes).map(|i| i as i32).collect();
    let b_host: Vec<i32> =
        (0..n_tiles as usize * lanes).map(|i| (2 * i) as i32).collect();
    let a = rt.alloc(a_host.len() * 4).unwrap();
    let b = rt.alloc(b_host.len() * 4).unwrap();
    let c = rt.alloc(n_tiles as usize * lanes).unwrap(); // int8 out
    rt.device.write_u32(a.addr, unsafe { std::mem::transmute::<&[i32], &[u32]>(&a_host[..]) }).unwrap();
    rt.device.write_u32(b.addr, unsafe { std::mem::transmute::<&[i32], &[u32]>(&b_host[..]) }).unwrap();

    // acc tile addressing: DRAM tile = byte / acc_tile_bytes.
    let acc_tile_bytes = cfg.acc_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();

    // produce A_buf / B_buf: load both vectors into the register file.
    rt.ctx.load_buffer_2d(
        BufferId::Acc,
        0,
        (a.addr / acc_tile_bytes) as u32,
        1,
        n_tiles,
        n_tiles,
        [0; 4],
    );
    rt.ctx.load_buffer_2d(
        BufferId::Acc,
        n_tiles as u32,
        (b.addr / acc_tile_bytes) as u32,
        1,
        n_tiles,
        n_tiles,
        [0; 4],
    );

    // produce C_buf: VTAUopLoopBegin(64,1,1,0); VTAUopPush(...); End.
    let mut kb = UopKernelBuilder::new();
    kb.loop_begin(n_tiles, 1, 1, 0).unwrap();
    kb.push(Uop::Alu(AluUop { dst_idx: 0, src_idx: n_tiles })).unwrap();
    kb.loop_end().unwrap();
    let kernel = kb.finish().unwrap();
    let kid = rt.ctx.register_kernel(&kernel).unwrap();
    rt.ctx.push_alu(kid, &kernel, AluOpcode::Add, false, 0).unwrap();

    // dep edges around the store, as in Listing 1.
    rt.ctx.dep_push(CoreModule::Compute, CoreModule::Store).unwrap();
    rt.ctx.dep_pop(CoreModule::Compute, CoreModule::Store).unwrap();
    rt.ctx.store_buffer_2d(0, (c.addr / out_tile_bytes) as u32, 1, n_tiles, n_tiles);

    let stats = rt.synchronize().unwrap();
    assert_eq!(stats.insn_alu, 1);
    assert_eq!(stats.alu_uops, 64);

    // C = int8(A + B).
    let got = rt.copy_out(&c).unwrap();
    for i in 0..a_host.len() {
        let expect = (a_host[i] + b_host[i]) as i8 as u8;
        assert_eq!(got[i], expect, "lane {i}");
    }
}

/// The uop cache emits LOAD.UOP on miss and skips it on hit, across
/// two synchronized streams (DRAM-cached kernels survive synchronize).
#[test]
fn kernel_cache_survives_synchronize() {
    let cfg = cfg();
    let mut rt = VtaRuntime::new(&cfg, 4 << 20);

    let mut kb = UopKernelBuilder::new();
    kb.loop_begin(4, 1, 1, 0).unwrap();
    kb.push(Uop::Alu(AluUop { dst_idx: 0, src_idx: 0 })).unwrap();
    kb.loop_end().unwrap();
    let kernel = kb.finish().unwrap();
    let kid = rt.ctx.register_kernel(&kernel).unwrap();

    rt.ctx.push_alu(kid, &kernel, AluOpcode::Add, true, 1).unwrap();
    let n1 = rt.ctx.pending().len();
    assert_eq!(n1, 2); // LOAD.UOP + ALU
    rt.synchronize().unwrap();

    rt.ctx.push_alu(kid, &kernel, AluOpcode::Add, true, 1).unwrap();
    assert_eq!(rt.ctx.pending().len(), 1); // resident: ALU only
    rt.synchronize().unwrap();
    assert_eq!(rt.ctx.uops.hits, 1);
    assert_eq!(rt.ctx.uops.misses, 1);
}

#[test]
fn dram_allocator_wrapper() {
    let mut d = DramAllocator::new(1 << 20, 4096);
    let b = d.alloc(1000).unwrap();
    assert!(b.addr >= 4096, "reserved prefix must not be handed out");
    assert_eq!(b.addr % 64, 0);
    d.free(b).unwrap();
}
