//! Device abstraction: anything that can accept a VTA instruction
//! stream and share DRAM with the host. The behavioral simulator is the
//! only implementation in this release; a memory-mapped FPGA device
//! would slot in behind the same trait (§2.4's control registers map to
//! `run`).

use crate::arch::VtaConfig;
use crate::isa::Instruction;
use crate::sim::{ExecMode, Hazard, SimError, SimStats, Simulator};

/// A VTA execution device with host-visible DRAM.
pub trait Device {
    /// Execute one instruction stream to completion (the fetch-module
    /// control-register handshake of §2.4 collapsed into a call).
    fn run(&mut self, insns: &[Instruction]) -> Result<SimStats, SimError>;

    /// Host write into device DRAM.
    fn write(&mut self, addr: usize, data: &[u8]) -> Result<(), SimError>;

    /// Host read from device DRAM.
    fn read(&self, addr: usize, len: usize) -> Result<Vec<u8>, SimError>;

    /// Host write of 32-bit words (uop kernels, acc init).
    fn write_u32(&mut self, addr: usize, data: &[u32]) -> Result<(), SimError>;
}

/// The behavioral-simulator device.
pub struct SimDevice {
    sim: Simulator,
}

impl SimDevice {
    /// New simulator device with `dram_size` bytes.
    pub fn new(cfg: VtaConfig, dram_size: usize) -> Self {
        SimDevice { sim: Simulator::new(cfg, dram_size) }
    }

    /// Enable hazard checking on subsequent runs.
    pub fn set_mode(&mut self, mode: ExecMode) {
        self.sim.set_mode(mode);
    }

    /// Hazards recorded by the last run (empty in `Normal` mode).
    pub fn hazards(&self) -> &[Hazard] {
        self.sim.hazards()
    }

    /// Direct simulator access (tests, benches).
    pub fn simulator_mut(&mut self) -> &mut Simulator {
        &mut self.sim
    }
}

impl Device for SimDevice {
    fn run(&mut self, insns: &[Instruction]) -> Result<SimStats, SimError> {
        self.sim.run(insns)
    }

    fn write(&mut self, addr: usize, data: &[u8]) -> Result<(), SimError> {
        self.sim.dram.write(addr, data)
    }

    fn read(&self, addr: usize, len: usize) -> Result<Vec<u8>, SimError> {
        Ok(self.sim.dram.read(addr, len)?.to_vec())
    }

    fn write_u32(&mut self, addr: usize, data: &[u32]) -> Result<(), SimError> {
        self.sim.dram.write_u32(addr, data)
    }
}
