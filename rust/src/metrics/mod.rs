//! Roofline accounting (§5, Fig 15) and serving-runtime counters.
//!
//! The roofline model bounds attainable throughput by
//! `min(peak_compute, bandwidth x arithmetic_intensity)`. The paper
//! plots each ResNet conv layer's measured GOPS against this envelope,
//! with and without latency hiding.
//!
//! The pool counters ([`PoolMetrics`], [`QueueDepthGauge`],
//! [`DeviceCounter`]) are the observability side of the multi-device
//! serving runtime: the scheduler ([`crate::exec::serve::Scheduler`])
//! samples queue depth at every dispatch and accounts per-device busy
//! time, batches, requests, and simulated cycles, so pool utilization
//! and queueing behavior are first-class outputs, not log grep.

use crate::arch::VtaConfig;
use crate::sim::SimStats;

/// One point on the roofline plot.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    /// Workload label (e.g. "C2").
    pub name: String,
    /// Arithmetic intensity, ops per DRAM byte (workload-intrinsic).
    pub intensity: f64,
    /// Achieved throughput in GOPS (from simulated cycles).
    pub gops: f64,
    /// Fraction of the roofline bound attained at this intensity.
    pub efficiency: f64,
    /// GEMM-core busy fraction (the paper's "compute utilization").
    pub utilization: f64,
    /// Total simulated cycles.
    pub cycles: u64,
}

/// Roofline evaluator for a VTA variant.
pub struct Roofline {
    /// Peak compute in ops/cycle.
    pub peak_ops_per_cycle: f64,
    /// DRAM bandwidth in bytes/cycle.
    pub bytes_per_cycle: f64,
    /// Clock (Hz), for GOPS conversion.
    pub clock_hz: f64,
}

impl Roofline {
    /// Build from an architecture config.
    pub fn of(cfg: &VtaConfig) -> Self {
        Roofline {
            peak_ops_per_cycle: cfg.gemm.ops_per_cycle() as f64,
            bytes_per_cycle: cfg.dram.bytes_per_cycle,
            clock_hz: cfg.clock_hz,
        }
    }

    /// Attainable ops/cycle at a given arithmetic intensity.
    pub fn bound_ops_per_cycle(&self, intensity: f64) -> f64 {
        self.peak_ops_per_cycle.min(self.bytes_per_cycle * intensity)
    }

    /// Peak GOPS of the machine.
    pub fn peak_gops(&self) -> f64 {
        self.peak_ops_per_cycle * self.clock_hz / 1e9
    }

    /// The knee: intensity at which the workload turns compute-bound.
    pub fn knee_intensity(&self) -> f64 {
        self.peak_ops_per_cycle / self.bytes_per_cycle
    }

    /// Evaluate one measured workload.
    ///
    /// `ops` is the workload's intrinsic op count, `intensity` its
    /// ops/byte (from minimal traffic), `stats` the simulator output.
    pub fn point(&self, name: &str, ops: u64, intensity: f64, stats: &SimStats) -> RooflinePoint {
        let cycles = stats.total_cycles.max(1);
        let ops_per_cycle = ops as f64 / cycles as f64;
        let gops = ops_per_cycle * self.clock_hz / 1e9;
        RooflinePoint {
            name: name.to_string(),
            intensity,
            gops,
            efficiency: ops_per_cycle / self.bound_ops_per_cycle(intensity),
            utilization: stats.compute_utilization(),
            cycles: stats.total_cycles,
        }
    }
}

// ---------------------------------------------------------------------
// Serving-pool counters.
// ---------------------------------------------------------------------

/// Queue-depth gauge: `(simulated time, waiting requests)` samples
/// recorded by the scheduler at every batch dispatch, in
/// non-decreasing time order.
#[derive(Clone, Debug, Default)]
pub struct QueueDepthGauge {
    samples: Vec<(f64, usize)>,
}

impl QueueDepthGauge {
    /// Record the queue depth observed at simulated time `t`.
    pub fn record(&mut self, t: f64, depth: usize) {
        self.samples.push((t, depth));
    }

    /// The raw samples, in record order.
    pub fn samples(&self) -> &[(f64, usize)] {
        &self.samples
    }

    /// Deepest observed queue.
    pub fn max_depth(&self) -> usize {
        self.samples.iter().map(|&(_, d)| d).max().unwrap_or(0)
    }

    /// Time-weighted mean depth over the observation window: between
    /// consecutive samples the depth is the earlier sample's. Falls
    /// back to the plain mean when the window is degenerate (fewer
    /// than two samples, or zero elapsed time).
    pub fn mean_depth(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let span = self.samples.last().unwrap().0 - self.samples[0].0;
        if self.samples.len() < 2 || span <= 0.0 {
            let sum: usize = self.samples.iter().map(|&(_, d)| d).sum();
            return sum as f64 / self.samples.len() as f64;
        }
        let mut weighted = 0.0;
        for w in self.samples.windows(2) {
            weighted += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        weighted / span
    }
}

/// Per-device counters accumulated by the scheduler.
#[derive(Clone, Debug, Default)]
pub struct DeviceCounter {
    /// Simulated seconds this device spent serving batches.
    pub busy_seconds: f64,
    /// Batches dispatched to this device.
    pub batches: u64,
    /// Requests served by this device.
    pub requests: u64,
    /// Total simulated accelerator cycles executed on this device.
    pub sim_cycles: u64,
}

impl DeviceCounter {
    /// Account one dispatched batch.
    pub fn record_batch(&mut self, requests: usize, busy_seconds: f64, sim_cycles: u64) {
        self.busy_seconds += busy_seconds;
        self.batches += 1;
        self.requests += requests as u64;
        self.sim_cycles += sim_cycles;
    }

    /// Busy fraction of an observation span (clamped to [0, 1]).
    pub fn utilization(&self, span_seconds: f64) -> f64 {
        if span_seconds <= 0.0 {
            0.0
        } else {
            (self.busy_seconds / span_seconds).min(1.0)
        }
    }
}

/// The scheduler's exported counters: one queue gauge plus one
/// [`DeviceCounter`] per pool replica.
#[derive(Clone, Debug, Default)]
pub struct PoolMetrics {
    /// Queue depth sampled at every dispatch.
    pub queue: QueueDepthGauge,
    /// Per-device counters, indexed by replica.
    pub devices: Vec<DeviceCounter>,
}

impl PoolMetrics {
    /// Fresh counters for a pool of `devices` replicas.
    pub fn new(devices: usize) -> Self {
        PoolMetrics {
            queue: QueueDepthGauge::default(),
            devices: vec![DeviceCounter::default(); devices],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::VtaConfig;

    #[test]
    fn pynq_roofline_shape() {
        let r = Roofline::of(&VtaConfig::pynq());
        assert!((r.peak_gops() - 51.2).abs() < 1e-9);
        // knee = 512 ops/cycle ÷ 16 B/cycle = 32 ops/byte
        assert!((r.knee_intensity() - 32.0).abs() < 1e-9);
        // Below the knee: bandwidth-bound.
        assert!(r.bound_ops_per_cycle(8.0) < r.peak_ops_per_cycle);
        // Above: compute-bound.
        assert_eq!(r.bound_ops_per_cycle(100.0), r.peak_ops_per_cycle);
    }

    #[test]
    fn point_efficiency_is_bounded() {
        let cfg = VtaConfig::pynq();
        let r = Roofline::of(&cfg);
        let mut stats = crate::sim::SimStats::default();
        stats.total_cycles = 1000;
        stats.gemm_busy_cycles = 700;
        // 1000 cycles at 512 ops/cycle peak → 512_000 ops max.
        let pt = r.point("x", 256_000, 100.0, &stats);
        assert!((pt.gops - 25.6).abs() < 1e-9);
        assert!((pt.efficiency - 0.5).abs() < 1e-9);
        assert!((pt.utilization - 0.7).abs() < 1e-9);
    }

    #[test]
    fn queue_depth_gauge_max_and_time_weighted_mean() {
        let mut q = QueueDepthGauge::default();
        assert_eq!(q.max_depth(), 0);
        assert_eq!(q.mean_depth(), 0.0);

        // Depth 4 for 1s, depth 2 for 3s, final sample closes the
        // window: mean = (4·1 + 2·3) / 4 = 2.5.
        q.record(0.0, 4);
        q.record(1.0, 2);
        q.record(4.0, 0);
        assert_eq!(q.max_depth(), 4);
        assert!((q.mean_depth() - 2.5).abs() < 1e-12);
        assert_eq!(q.samples().len(), 3);

        // Degenerate window (all samples at one instant): plain mean.
        let mut flat = QueueDepthGauge::default();
        flat.record(0.0, 3);
        flat.record(0.0, 1);
        assert_eq!(flat.max_depth(), 3);
        assert!((flat.mean_depth() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn device_counters_accumulate_and_bound_utilization() {
        let mut m = PoolMetrics::new(2);
        m.devices[0].record_batch(4, 0.5, 1000);
        m.devices[0].record_batch(2, 0.25, 500);
        m.devices[1].record_batch(1, 0.1, 100);
        assert_eq!(m.devices[0].batches, 2);
        assert_eq!(m.devices[0].requests, 6);
        assert_eq!(m.devices[0].sim_cycles, 1500);
        assert!((m.devices[0].busy_seconds - 0.75).abs() < 1e-12);
        // Utilization over a 1s span; clamped at 1, zero-span safe.
        assert!((m.devices[0].utilization(1.0) - 0.75).abs() < 1e-12);
        assert!((m.devices[1].utilization(1.0) - 0.1).abs() < 1e-12);
        assert_eq!(m.devices[0].utilization(0.0), 0.0);
        assert_eq!(m.devices[0].utilization(0.5), 1.0);
    }
}
