//! Roofline accounting (§5, Fig 15) and serving-runtime counters.
//!
//! The roofline model bounds attainable throughput by
//! `min(peak_compute, bandwidth x arithmetic_intensity)`. The paper
//! plots each ResNet conv layer's measured GOPS against this envelope,
//! with and without latency hiding.
//!
//! The pool counters ([`PoolMetrics`], [`QueueDepthGauge`],
//! [`DeviceCounter`]) are the observability side of the multi-device
//! serving runtime: the scheduler ([`crate::exec::serve::Scheduler`])
//! samples queue depth at every dispatch and accounts per-device busy
//! time, batches, requests, and simulated cycles, so pool utilization
//! and queueing behavior are first-class outputs, not log grep.

use crate::arch::VtaConfig;
use crate::sim::SimStats;

/// One point on the roofline plot.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    /// Workload label (e.g. "C2").
    pub name: String,
    /// Arithmetic intensity, ops per DRAM byte (workload-intrinsic).
    pub intensity: f64,
    /// Achieved throughput in GOPS (from simulated cycles).
    pub gops: f64,
    /// Fraction of the roofline bound attained at this intensity.
    pub efficiency: f64,
    /// GEMM-core busy fraction (the paper's "compute utilization").
    pub utilization: f64,
    /// Total simulated cycles.
    pub cycles: u64,
}

/// Roofline evaluator for a VTA variant.
pub struct Roofline {
    /// Peak compute in ops/cycle.
    pub peak_ops_per_cycle: f64,
    /// DRAM bandwidth in bytes/cycle.
    pub bytes_per_cycle: f64,
    /// Clock (Hz), for GOPS conversion.
    pub clock_hz: f64,
}

impl Roofline {
    /// Build from an architecture config.
    pub fn of(cfg: &VtaConfig) -> Self {
        Roofline {
            peak_ops_per_cycle: cfg.gemm.ops_per_cycle() as f64,
            bytes_per_cycle: cfg.dram.bytes_per_cycle,
            clock_hz: cfg.clock_hz,
        }
    }

    /// Attainable ops/cycle at a given arithmetic intensity.
    pub fn bound_ops_per_cycle(&self, intensity: f64) -> f64 {
        self.peak_ops_per_cycle.min(self.bytes_per_cycle * intensity)
    }

    /// Peak GOPS of the machine.
    pub fn peak_gops(&self) -> f64 {
        self.peak_ops_per_cycle * self.clock_hz / 1e9
    }

    /// The knee: intensity at which the workload turns compute-bound.
    pub fn knee_intensity(&self) -> f64 {
        self.peak_ops_per_cycle / self.bytes_per_cycle
    }

    /// Evaluate one measured workload.
    ///
    /// `ops` is the workload's intrinsic op count, `intensity` its
    /// ops/byte (from minimal traffic), `stats` the simulator output.
    pub fn point(&self, name: &str, ops: u64, intensity: f64, stats: &SimStats) -> RooflinePoint {
        let cycles = stats.total_cycles.max(1);
        let ops_per_cycle = ops as f64 / cycles as f64;
        let gops = ops_per_cycle * self.clock_hz / 1e9;
        RooflinePoint {
            name: name.to_string(),
            intensity,
            gops,
            efficiency: ops_per_cycle / self.bound_ops_per_cycle(intensity),
            utilization: stats.compute_utilization(),
            cycles: stats.total_cycles,
        }
    }
}

// ---------------------------------------------------------------------
// Serving-pool counters.
// ---------------------------------------------------------------------

/// Queue-depth gauge: `(simulated time, waiting requests)` samples
/// recorded by the scheduler at every batch dispatch, in
/// non-decreasing time order.
#[derive(Clone, Debug, Default)]
pub struct QueueDepthGauge {
    samples: Vec<(f64, usize)>,
}

impl QueueDepthGauge {
    /// Record the queue depth observed at simulated time `t`.
    pub fn record(&mut self, t: f64, depth: usize) {
        self.samples.push((t, depth));
    }

    /// The raw samples, in record order.
    pub fn samples(&self) -> &[(f64, usize)] {
        &self.samples
    }

    /// Deepest observed queue.
    pub fn max_depth(&self) -> usize {
        self.samples.iter().map(|&(_, d)| d).max().unwrap_or(0)
    }

    /// Time-weighted mean depth over the observation window: between
    /// consecutive samples the depth is the earlier sample's. Falls
    /// back to the plain mean when the window is degenerate (fewer
    /// than two samples, or zero elapsed time).
    pub fn mean_depth(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let span = self.samples.last().unwrap().0 - self.samples[0].0;
        if self.samples.len() < 2 || span <= 0.0 {
            let sum: usize = self.samples.iter().map(|&(_, d)| d).sum();
            return sum as f64 / self.samples.len() as f64;
        }
        let mut weighted = 0.0;
        for w in self.samples.windows(2) {
            weighted += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        weighted / span
    }
}

/// Per-device counters accumulated by the scheduler.
///
/// Devices in a heterogeneous fleet run different hardware variants,
/// so each counter carries its device's config fingerprint
/// ([`crate::compiler::config_fingerprint`]) — per-device utilization
/// can then be grouped by variant instead of assuming every replica is
/// the same machine. Homogeneous pools leave it at the default 0 or
/// set every device to the one shared fingerprint; fleet runtimes set
/// it per replica.
#[derive(Clone, Debug, Default)]
pub struct DeviceCounter {
    /// Fingerprint of the [`VtaConfig`] this device runs (0 = unset).
    pub config_fingerprint: u64,
    /// Simulated seconds this device spent serving batches.
    pub busy_seconds: f64,
    /// Batches dispatched to this device.
    pub batches: u64,
    /// Requests served by this device.
    pub requests: u64,
    /// Total simulated accelerator cycles executed on this device.
    pub sim_cycles: u64,
}

impl DeviceCounter {
    /// Account one dispatched batch.
    pub fn record_batch(&mut self, requests: usize, busy_seconds: f64, sim_cycles: u64) {
        self.busy_seconds += busy_seconds;
        self.batches += 1;
        self.requests += requests as u64;
        self.sim_cycles += sim_cycles;
    }

    /// Busy fraction of an observation span (clamped to [0, 1]).
    pub fn utilization(&self, span_seconds: f64) -> f64 {
        if span_seconds <= 0.0 {
            0.0
        } else {
            (self.busy_seconds / span_seconds).min(1.0)
        }
    }
}

/// Wall-clock latency histogram for the threaded serving runtime:
/// power-of-two microsecond buckets plus exact count / sum / min / max,
/// so queue-wait and service-time distributions can be accumulated
/// online without retaining per-request samples. Percentile queries
/// interpolate inside the covering bucket and clamp to the exact
/// observed `[min, max]` — an empty histogram reports zero everywhere,
/// and a single-sample histogram reports that sample at every
/// percentile (the two edge cases the unit tests pin down).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// `buckets[b]` counts samples with `floor(log2(micros)) == b`
    /// (sub-microsecond samples land in bucket 0; the last bucket is
    /// open-ended).
    buckets: [u64; LATENCY_BUCKETS],
    count: u64,
    sum_seconds: f64,
    min_seconds: f64,
    max_seconds: f64,
}

/// 48 power-of-two buckets: 1 µs up to ~2^47 µs (≈ 4.5 years) — wide
/// enough that the open-ended tail bucket is never hit in practice.
const LATENCY_BUCKETS: usize = 48;

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0; LATENCY_BUCKETS],
            count: 0,
            sum_seconds: 0.0,
            min_seconds: 0.0,
            max_seconds: 0.0,
        }
    }
}

impl LatencyHistogram {
    fn bucket_of(seconds: f64) -> usize {
        let micros = (seconds.max(0.0) * 1e6) as u64;
        (63 - micros.max(1).leading_zeros() as usize).min(LATENCY_BUCKETS - 1)
    }

    /// Record one latency sample (seconds; negatives clamp to zero).
    pub fn record(&mut self, seconds: f64) {
        let s = seconds.max(0.0);
        if self.count == 0 {
            self.min_seconds = s;
            self.max_seconds = s;
        } else {
            self.min_seconds = self.min_seconds.min(s);
            self.max_seconds = self.max_seconds.max(s);
        }
        self.count += 1;
        self.sum_seconds += s;
        self.buckets[Self::bucket_of(s)] += 1;
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds (zero when empty).
    pub fn mean_seconds(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_seconds / self.count as f64
        }
    }

    /// Smallest recorded sample (zero when empty).
    pub fn min_seconds(&self) -> f64 {
        self.min_seconds
    }

    /// Largest recorded sample (zero when empty).
    pub fn max_seconds(&self) -> f64 {
        self.max_seconds
    }

    /// Approximate percentile `p` ∈ [0, 1] in seconds: the sample at
    /// rank `ceil(p·count)` located by cumulative bucket counts, read
    /// off as the bucket midpoint and clamped to the exact observed
    /// range. Zero when empty; exact with a single sample.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= rank {
                let lo = (1u64 << b) as f64 * 1e-6;
                let mid = lo * 1.5;
                return mid.clamp(self.min_seconds, self.max_seconds);
            }
        }
        self.max_seconds
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            self.min_seconds = other.min_seconds;
            self.max_seconds = other.max_seconds;
        } else {
            self.min_seconds = self.min_seconds.min(other.min_seconds);
            self.max_seconds = self.max_seconds.max(other.max_seconds);
        }
        self.count += other.count;
        self.sum_seconds += other.sum_seconds;
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }
}

/// Per-worker-thread counters of the threaded serving runtime — the
/// real-time analogue of [`DeviceCounter`] (which accounts *simulated*
/// busy seconds under the simulated-time scheduler).
#[derive(Clone, Debug, Default)]
pub struct ThreadCounter {
    /// Requests this worker served.
    pub requests: u64,
    /// Batches this worker pulled off the shared queue.
    pub batches: u64,
    /// Wall-clock time spent serving (outside the queue wait).
    pub busy: std::time::Duration,
    /// Largest batch this worker pulled (≤ the configured `max_batch`;
    /// trailing partial batches at stream end make smaller ones
    /// common).
    pub max_batch: usize,
    /// Times this worker blocked on another worker's in-flight plan
    /// compile instead of compiling itself (cold-start contention;
    /// zero at steady state).
    pub claim_waits: u64,
}

impl ThreadCounter {
    /// Account one batch of `requests` served in `busy` wall time.
    pub fn record_batch(&mut self, requests: usize, busy: std::time::Duration) {
        self.requests += requests as u64;
        self.batches += 1;
        self.busy += busy;
        self.max_batch = self.max_batch.max(requests);
    }
}

/// Contention observables of the threaded serving runtimes — the
/// counters that say *why* a decontended hot path matters, surfaced in
/// [`crate::exec::serve::ThreadedReport`] and the fleet report. All
/// three are cheap relaxed-atomic or per-thread sums; recording them
/// never takes a lock.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ContentionStats {
    /// Submissions shed because the bounded queue was at capacity
    /// (admission-control backpressure).
    pub queue_full: u64,
    /// Worker blocks on another worker's in-flight plan compile
    /// (same-key compile races during cold start).
    pub claim_waits: u64,
    /// Plan-directory short-lock acquisitions (misses, installs,
    /// evictions — steady-state hits acquire none).
    pub directory_locks: u64,
}

impl ContentionStats {
    /// Accumulate another runtime's counters (fleet groups, pipeline
    /// stages) into this one.
    pub fn merge(&mut self, other: &ContentionStats) {
        self.queue_full += other.queue_full;
        self.claim_waits += other.claim_waits;
        self.directory_locks += other.directory_locks;
    }
}

/// The scheduler's exported counters: one queue gauge plus one
/// [`DeviceCounter`] per pool replica. Replicas need not be identical
/// — the fleet runtimes stamp each device's `config_fingerprint` so
/// mixed pools stay attributable per variant.
#[derive(Clone, Debug, Default)]
pub struct PoolMetrics {
    /// Queue depth sampled at every dispatch.
    pub queue: QueueDepthGauge,
    /// Per-device counters, indexed by replica.
    pub devices: Vec<DeviceCounter>,
}

impl PoolMetrics {
    /// Fresh counters for a pool of `devices` replicas.
    pub fn new(devices: usize) -> Self {
        PoolMetrics {
            queue: QueueDepthGauge::default(),
            devices: vec![DeviceCounter::default(); devices],
        }
    }
}

// ---------------------------------------------------------------------
// Pipeline-parallel counters.
// ---------------------------------------------------------------------

/// Per-pipeline-stage counters for graph-level pipeline parallelism
/// ([`crate::exec::serve::PipelineScheduler`]): one stage = one pool
/// replica executing a contiguous slice of the graph's ASAP levels.
/// Alongside the busy accounting this tracks the stage's *handoff*
/// traffic — the boundary tensors relayed downstream through DRAM,
/// the only cross-device traffic pipeline parallelism introduces.
///
/// Everything except `busy_seconds` is deterministic (a function of
/// the graph, the partition, and the request count), so the
/// determinism suite asserts the threaded runtime's counters equal
/// the simulated oracle's field by field.
#[derive(Clone, Debug, Default)]
pub struct StageCounter {
    /// Graph nodes owned by this stage.
    pub nodes: u64,
    /// Requests that passed through this stage.
    pub requests: u64,
    /// Seconds this stage spent executing (simulated wall + sim time
    /// under the simulated scheduler; measured wall under threads).
    pub busy_seconds: f64,
    /// Simulated accelerator cycles executed by this stage.
    pub sim_cycles: u64,
    /// Boundary tensors handed downstream (0 for the last stage).
    pub handoff_tensors: u64,
    /// Bytes handed downstream (int8: one byte per element).
    pub handoff_bytes: u64,
}

impl StageCounter {
    /// Account one request through this stage: `busy_seconds` of stage
    /// execution, `sim_cycles` on the accelerator, and the downstream
    /// handoff (`tensors` live values, `bytes` total).
    pub fn record_request(&mut self, busy_seconds: f64, sim_cycles: u64, tensors: u64, bytes: u64) {
        self.requests += 1;
        self.busy_seconds += busy_seconds;
        self.sim_cycles += sim_cycles;
        self.handoff_tensors += tensors;
        self.handoff_bytes += bytes;
    }

    /// Busy fraction of an observation span (clamped to [0, 1]) — the
    /// stage's *occupancy*. A balanced pipeline under streaming load
    /// pushes every stage's occupancy toward the bottleneck stage's.
    pub fn occupancy(&self, span_seconds: f64) -> f64 {
        if span_seconds <= 0.0 {
            0.0
        } else {
            (self.busy_seconds / span_seconds).min(1.0)
        }
    }
}

/// The pipeline runtimes' exported counters: one [`StageCounter`] per
/// stage, in pipeline order.
#[derive(Clone, Debug, Default)]
pub struct PipelineMetrics {
    /// Per-stage counters, indexed by stage (= replica).
    pub stages: Vec<StageCounter>,
}

impl PipelineMetrics {
    /// Fresh counters for a `stages`-deep pipeline.
    pub fn new(stages: usize) -> Self {
        PipelineMetrics { stages: vec![StageCounter::default(); stages] }
    }

    /// Total bytes handed between stages over the run.
    pub fn handoff_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.handoff_bytes).sum()
    }

    /// Per-stage occupancy over a common span (reporting convenience).
    pub fn occupancies(&self, span_seconds: f64) -> Vec<f64> {
        self.stages.iter().map(|s| s.occupancy(span_seconds)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::VtaConfig;

    #[test]
    fn pynq_roofline_shape() {
        let r = Roofline::of(&VtaConfig::pynq());
        assert!((r.peak_gops() - 51.2).abs() < 1e-9);
        // knee = 512 ops/cycle ÷ 16 B/cycle = 32 ops/byte
        assert!((r.knee_intensity() - 32.0).abs() < 1e-9);
        // Below the knee: bandwidth-bound.
        assert!(r.bound_ops_per_cycle(8.0) < r.peak_ops_per_cycle);
        // Above: compute-bound.
        assert_eq!(r.bound_ops_per_cycle(100.0), r.peak_ops_per_cycle);
    }

    #[test]
    fn point_efficiency_is_bounded() {
        let cfg = VtaConfig::pynq();
        let r = Roofline::of(&cfg);
        let mut stats = crate::sim::SimStats::default();
        stats.total_cycles = 1000;
        stats.gemm_busy_cycles = 700;
        // 1000 cycles at 512 ops/cycle peak → 512_000 ops max.
        let pt = r.point("x", 256_000, 100.0, &stats);
        assert!((pt.gops - 25.6).abs() < 1e-9);
        assert!((pt.efficiency - 0.5).abs() < 1e-9);
        assert!((pt.utilization - 0.7).abs() < 1e-9);
    }

    #[test]
    fn queue_depth_gauge_max_and_time_weighted_mean() {
        let mut q = QueueDepthGauge::default();
        assert_eq!(q.max_depth(), 0);
        assert_eq!(q.mean_depth(), 0.0);

        // Depth 4 for 1s, depth 2 for 3s, final sample closes the
        // window: mean = (4·1 + 2·3) / 4 = 2.5.
        q.record(0.0, 4);
        q.record(1.0, 2);
        q.record(4.0, 0);
        assert_eq!(q.max_depth(), 4);
        assert!((q.mean_depth() - 2.5).abs() < 1e-12);
        assert_eq!(q.samples().len(), 3);

        // Degenerate window (all samples at one instant): plain mean.
        let mut flat = QueueDepthGauge::default();
        flat.record(0.0, 3);
        flat.record(0.0, 1);
        assert_eq!(flat.max_depth(), 3);
        assert!((flat.mean_depth() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn latency_histogram_empty_reports_zero_everywhere() {
        // The empty-queue edge case: a pool that never saw a request
        // must report zeros, not NaNs or panics.
        let h = LatencyHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_seconds(), 0.0);
        assert_eq!(h.min_seconds(), 0.0);
        assert_eq!(h.max_seconds(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(0.999), 0.0);
    }

    #[test]
    fn latency_histogram_single_sample_is_exact_at_every_percentile() {
        let mut h = LatencyHistogram::default();
        h.record(0.0042);
        assert_eq!(h.count(), 1);
        assert!((h.mean_seconds() - 0.0042).abs() < 1e-12);
        assert_eq!(h.min_seconds(), 0.0042);
        assert_eq!(h.max_seconds(), 0.0042);
        // min == max, so the bucket-midpoint estimate clamps to the
        // exact sample at every percentile.
        for p in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile(p), 0.0042, "p={p}");
        }
    }

    #[test]
    fn latency_histogram_percentiles_are_ordered_and_bounded() {
        let mut h = LatencyHistogram::default();
        // 99 fast samples at 1 ms, one slow outlier at 1 s.
        for _ in 0..99 {
            h.record(0.001);
        }
        h.record(1.0);
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(0.50);
        let p99 = h.percentile(0.99);
        let p999 = h.percentile(0.999);
        assert!(p50 <= p99 && p99 <= p999, "p50={p50} p99={p99} p999={p999}");
        assert!(p50 >= h.min_seconds() && p999 <= h.max_seconds());
        // The p99.9 must see the outlier's bucket, not the fast mode.
        assert!(p999 > 0.1, "p999={p999} should reflect the 1 s outlier");
        // Negative samples clamp to zero instead of corrupting state.
        h.record(-1.0);
        assert_eq!(h.min_seconds(), 0.0);
    }

    #[test]
    fn latency_histogram_merge_matches_combined_recording() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        let mut both = LatencyHistogram::default();
        for &s in &[0.001, 0.002, 0.004] {
            a.record(s);
            both.record(s);
        }
        for &s in &[0.0005, 0.080] {
            b.record(s);
            both.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert!((a.mean_seconds() - both.mean_seconds()).abs() < 1e-12);
        assert_eq!(a.min_seconds(), both.min_seconds());
        assert_eq!(a.max_seconds(), both.max_seconds());
        for p in [0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(p), both.percentile(p), "p={p}");
        }
        // Merging an empty histogram is a no-op.
        let before = a.clone();
        a.merge(&LatencyHistogram::default());
        assert_eq!(a.count(), before.count());
        assert_eq!(a.min_seconds(), before.min_seconds());
    }

    #[test]
    fn thread_counters_accumulate_batches() {
        use std::time::Duration;
        let mut t = ThreadCounter::default();
        assert_eq!(t.requests, 0);
        assert_eq!(t.max_batch, 0);
        t.record_batch(2, Duration::from_millis(10));
        t.record_batch(4, Duration::from_millis(30));
        t.record_batch(1, Duration::from_millis(5)); // trailing partial batch
        assert_eq!(t.requests, 7);
        assert_eq!(t.batches, 3);
        assert_eq!(t.max_batch, 4);
        assert_eq!(t.busy, Duration::from_millis(45));
        // Claim waits are set once from the worker's exec state, not
        // per batch.
        assert_eq!(t.claim_waits, 0);
        t.claim_waits = 3;
        assert_eq!(t.claim_waits, 3);
    }

    #[test]
    fn contention_stats_merge_sums_fields() {
        let mut total = ContentionStats::default();
        assert_eq!(total, ContentionStats { queue_full: 0, claim_waits: 0, directory_locks: 0 });
        total.merge(&ContentionStats { queue_full: 2, claim_waits: 1, directory_locks: 10 });
        total.merge(&ContentionStats { queue_full: 0, claim_waits: 4, directory_locks: 7 });
        assert_eq!(total, ContentionStats { queue_full: 2, claim_waits: 5, directory_locks: 17 });
        // Merging a default is a no-op.
        let before = total;
        total.merge(&ContentionStats::default());
        assert_eq!(total, before);
    }

    #[test]
    fn stage_counters_accumulate_and_bound_occupancy() {
        let mut m = PipelineMetrics::new(2);
        m.stages[0].nodes = 5;
        m.stages[0].record_request(0.5, 1000, 2, 4096);
        m.stages[0].record_request(0.25, 500, 2, 4096);
        m.stages[1].record_request(0.1, 100, 0, 0); // last stage: no handoff
        assert_eq!(m.stages[0].requests, 2);
        assert_eq!(m.stages[0].sim_cycles, 1500);
        assert_eq!(m.stages[0].handoff_tensors, 4);
        assert_eq!(m.stages[0].handoff_bytes, 8192);
        assert_eq!(m.stages[1].handoff_bytes, 0);
        assert_eq!(m.handoff_bytes(), 8192);
        assert!((m.stages[0].occupancy(1.0) - 0.75).abs() < 1e-12);
        assert_eq!(m.stages[0].occupancy(0.0), 0.0);
        assert_eq!(m.stages[0].occupancy(0.5), 1.0); // clamped
        let occ = m.occupancies(1.0);
        assert_eq!(occ.len(), 2);
        assert!((occ[1] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn device_counters_accumulate_and_bound_utilization() {
        let mut m = PoolMetrics::new(2);
        m.devices[0].record_batch(4, 0.5, 1000);
        m.devices[0].record_batch(2, 0.25, 500);
        m.devices[1].record_batch(1, 0.1, 100);
        assert_eq!(m.devices[0].batches, 2);
        assert_eq!(m.devices[0].requests, 6);
        assert_eq!(m.devices[0].sim_cycles, 1500);
        assert!((m.devices[0].busy_seconds - 0.75).abs() < 1e-12);
        // Utilization over a 1s span; clamped at 1, zero-span safe.
        assert!((m.devices[0].utilization(1.0) - 0.75).abs() < 1e-12);
        assert!((m.devices[1].utilization(1.0) - 0.1).abs() < 1e-12);
        assert_eq!(m.devices[0].utilization(0.0), 0.0);
        assert_eq!(m.devices[0].utilization(0.5), 1.0);
    }
}
