//! Roofline accounting (§5, Fig 15).
//!
//! The roofline model bounds attainable throughput by
//! `min(peak_compute, bandwidth x arithmetic_intensity)`. The paper
//! plots each ResNet conv layer's measured GOPS against this envelope,
//! with and without latency hiding.

use crate::arch::VtaConfig;
use crate::sim::SimStats;

/// One point on the roofline plot.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    /// Workload label (e.g. "C2").
    pub name: String,
    /// Arithmetic intensity, ops per DRAM byte (workload-intrinsic).
    pub intensity: f64,
    /// Achieved throughput in GOPS (from simulated cycles).
    pub gops: f64,
    /// Fraction of the roofline bound attained at this intensity.
    pub efficiency: f64,
    /// GEMM-core busy fraction (the paper's "compute utilization").
    pub utilization: f64,
    /// Total simulated cycles.
    pub cycles: u64,
}

/// Roofline evaluator for a VTA variant.
pub struct Roofline {
    /// Peak compute in ops/cycle.
    pub peak_ops_per_cycle: f64,
    /// DRAM bandwidth in bytes/cycle.
    pub bytes_per_cycle: f64,
    /// Clock (Hz), for GOPS conversion.
    pub clock_hz: f64,
}

impl Roofline {
    /// Build from an architecture config.
    pub fn of(cfg: &VtaConfig) -> Self {
        Roofline {
            peak_ops_per_cycle: cfg.gemm.ops_per_cycle() as f64,
            bytes_per_cycle: cfg.dram.bytes_per_cycle,
            clock_hz: cfg.clock_hz,
        }
    }

    /// Attainable ops/cycle at a given arithmetic intensity.
    pub fn bound_ops_per_cycle(&self, intensity: f64) -> f64 {
        self.peak_ops_per_cycle.min(self.bytes_per_cycle * intensity)
    }

    /// Peak GOPS of the machine.
    pub fn peak_gops(&self) -> f64 {
        self.peak_ops_per_cycle * self.clock_hz / 1e9
    }

    /// The knee: intensity at which the workload turns compute-bound.
    pub fn knee_intensity(&self) -> f64 {
        self.peak_ops_per_cycle / self.bytes_per_cycle
    }

    /// Evaluate one measured workload.
    ///
    /// `ops` is the workload's intrinsic op count, `intensity` its
    /// ops/byte (from minimal traffic), `stats` the simulator output.
    pub fn point(&self, name: &str, ops: u64, intensity: f64, stats: &SimStats) -> RooflinePoint {
        let cycles = stats.total_cycles.max(1);
        let ops_per_cycle = ops as f64 / cycles as f64;
        let gops = ops_per_cycle * self.clock_hz / 1e9;
        RooflinePoint {
            name: name.to_string(),
            intensity,
            gops,
            efficiency: ops_per_cycle / self.bound_ops_per_cycle(intensity),
            utilization: stats.compute_utilization(),
            cycles: stats.total_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::VtaConfig;

    #[test]
    fn pynq_roofline_shape() {
        let r = Roofline::of(&VtaConfig::pynq());
        assert!((r.peak_gops() - 51.2).abs() < 1e-9);
        // knee = 512 ops/cycle ÷ 16 B/cycle = 32 ops/byte
        assert!((r.knee_intensity() - 32.0).abs() < 1e-9);
        // Below the knee: bandwidth-bound.
        assert!(r.bound_ops_per_cycle(8.0) < r.peak_ops_per_cycle);
        // Above: compute-bound.
        assert_eq!(r.bound_ops_per_cycle(100.0), r.peak_ops_per_cycle);
    }

    #[test]
    fn point_efficiency_is_bounded() {
        let cfg = VtaConfig::pynq();
        let r = Roofline::of(&cfg);
        let mut stats = crate::sim::SimStats::default();
        stats.total_cycles = 1000;
        stats.gemm_busy_cycles = 700;
        // 1000 cycles at 512 ops/cycle peak → 512_000 ops max.
        let pt = r.point("x", 256_000, 100.0, &stats);
        assert!((pt.gops - 25.6).abs() < 1e-9);
        assert!((pt.efficiency - 0.5).abs() < 1e-9);
        assert!((pt.utilization - 0.7).abs() < 1e-9);
    }
}
