use super::*;

#[test]
fn pynq_peak_matches_paper() {
    // §5: "theoretical peak throughput of this flavor of the VTA design
    // lies around 51 GOPS/s" — 16x16 MACs * 2 ops * 100 MHz = 51.2 GOPS.
    let c = VtaConfig::pynq();
    assert!((c.peak_gops() - 51.2).abs() < 1e-9, "peak = {}", c.peak_gops());
}

#[test]
fn bandwidth_derivation_matches_section_2_6() {
    // §2.6: BATCH=2, BLOCK_IN=16, BLOCK_OUT=16 @ 200MHz →
    // 51.2 Gb/s input, 409.6 Gb/s weight, 204.8 Gb/s register file.
    let c = VtaConfig::bandwidth_example();
    assert!((c.inp_bandwidth_gbps() - 51.2).abs() < 1e-9);
    assert!((c.wgt_bandwidth_gbps() - 409.6).abs() < 1e-9);
    assert!((c.acc_bandwidth_gbps() - 204.8).abs() < 1e-9);
}

#[test]
fn pynq_buffer_depths() {
    let c = VtaConfig::pynq();
    // 1x16 int8 input tile = 16 B → 32 kB holds 2048 tiles.
    assert_eq!(c.inp_tile_bytes(), 16);
    assert_eq!(c.inp_depth(), 2048);
    // 16x16 int8 weight tile = 256 B → 256 kB holds 1024 tiles.
    assert_eq!(c.wgt_tile_bytes(), 256);
    assert_eq!(c.wgt_depth(), 1024);
    // 1x16 int32 acc tile = 64 B → 128 kB holds 2048 tiles.
    assert_eq!(c.acc_tile_bytes(), 64);
    assert_eq!(c.acc_depth(), 2048);
    // 4-byte uops → 16 kB holds 4096 uops.
    assert_eq!(c.uop_depth(), 4096);
}

#[test]
fn default_config_is_valid() {
    assert!(VtaConfig::pynq().validate().is_empty());
    assert!(VtaConfig::bandwidth_example().validate().is_empty());
}

#[test]
fn validate_catches_bad_configs() {
    let mut c = VtaConfig::pynq();
    c.gemm.block_in = 0;
    assert!(!c.validate().is_empty());

    let mut c = VtaConfig::pynq();
    c.inp_bits = 7;
    assert!(!c.validate().is_empty());

    let mut c = VtaConfig::pynq();
    c.dram.bytes_per_cycle = 0.0;
    assert!(!c.validate().is_empty());
}

#[test]
fn parse_roundtrip() {
    let text = r#"
        # larger core
        gemm = 2x16x32
        clock_mhz = 200
        wgt_buf_kib = 512
        dram.bytes_per_cycle = 16
        dram.latency = 200
    "#;
    let c = parse_config_str(text).unwrap();
    assert_eq!(c.gemm, GemmShape { batch: 2, block_in: 16, block_out: 32 });
    assert_eq!(c.clock_hz, 200e6);
    assert_eq!(c.wgt_buf_bytes, 512 * 1024);
    assert_eq!(c.dram.bytes_per_cycle, 16.0);
    assert_eq!(c.dram.latency, 200);
    // untouched keys keep Pynq defaults
    assert_eq!(c.inp_buf_bytes, 32 * 1024);
}

#[test]
fn parse_rejects_unknown_keys_and_garbage() {
    assert!(parse_config_str("gemm.blocc_in = 16").is_err());
    assert!(parse_config_str("gemm.block_in 16").is_err());
    assert!(parse_config_str("gemm.block_in = banana").is_err());
    assert!(parse_config_str("gemm = 1x16").is_err());
    // a config that parses but fails validation
    assert!(parse_config_str("gemm.batch = 0").is_err());
}

#[test]
fn comments_and_blank_lines_ignored() {
    let c = parse_config_str("\n# only comments\n   \n").unwrap();
    assert_eq!(c, VtaConfig::pynq());
}

#[test]
fn dram_occupancy() {
    let d = DramModel { bytes_per_cycle: 32.0, latency: 100 };
    assert_eq!(d.occupancy(0), 0);
    assert_eq!(d.occupancy(1), 1);
    assert_eq!(d.occupancy(32), 1);
    assert_eq!(d.occupancy(33), 2);
    assert_eq!(d.occupancy(64 * 32), 64);
}
