//! The `VtaConfig` structure: the single source of truth for a VTA variant.

use std::fmt;

/// Shape of the single-cycle GEMM tensor intrinsic (§2.5, Fig 7).
///
/// One GEMM micro-op computes, per cycle:
/// `acc[BATCH, BLOCK_OUT] += inp[BATCH, BLOCK_IN] x wgt[BLOCK_OUT, BLOCK_IN]^T`
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    /// Rows of the input / accumulator tile (paper: `BATCH`).
    pub batch: usize,
    /// Contraction dimension (paper: `BLOCK_IN`).
    pub block_in: usize,
    /// Columns of the accumulator tile (paper: `BLOCK_OUT`).
    pub block_out: usize,
}

impl GemmShape {
    /// Multiply-accumulates performed per cycle.
    pub const fn macs_per_cycle(&self) -> usize {
        self.batch * self.block_in * self.block_out
    }

    /// Integer ops per cycle (1 MAC = 2 ops, the convention used in the
    /// paper's "51 GOPS" figure).
    pub const fn ops_per_cycle(&self) -> usize {
        2 * self.macs_per_cycle()
    }
}

impl fmt::Display for GemmShape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}", self.batch, self.block_in, self.block_out)
    }
}

/// DRAM timing model shared by all DMA masters (§2.6, §6 of DESIGN.md).
///
/// A single memory port: transfers serialize and occupy the port for
/// `ceil(bytes / bytes_per_cycle)` cycles after an initial `latency`
/// cycles. This is what produces the bandwidth roof in Fig 15.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramModel {
    /// Sustained DRAM bandwidth in bytes per *accelerator* cycle.
    pub bytes_per_cycle: f64,
    /// Fixed latency (cycles) to the first beat of a DMA burst.
    pub latency: u64,
}

impl DramModel {
    /// Port occupancy (cycles) of a transfer of `bytes`, excluding the
    /// fixed latency.
    pub fn occupancy(&self, bytes: usize) -> u64 {
        if bytes == 0 {
            return 0;
        }
        (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// A complete VTA hardware variant.
///
/// Defaults mirror the paper's Pynq design point (§5 "Platform"):
/// 16x16 GEMM core @ 100 MHz, int8 inputs/weights, int32 accumulators,
/// 32 kB input / 256 kB weight / 128 kB accumulator / 16 kB micro-op
/// buffers → 51.2 GOPS peak.
#[derive(Clone, Debug, PartialEq)]
pub struct VtaConfig {
    /// GEMM core tensor intrinsic shape.
    pub gemm: GemmShape,
    /// Input / weight element width in bits (paper: 8).
    pub inp_bits: usize,
    /// Weight element width in bits (paper: 8).
    pub wgt_bits: usize,
    /// Accumulator (register-file) element width in bits (paper: 32).
    pub acc_bits: usize,
    /// Output element width in bits (results stored to DRAM; paper: 8).
    pub out_bits: usize,
    /// Input buffer capacity in bytes (paper: 32 kB).
    pub inp_buf_bytes: usize,
    /// Weight buffer capacity in bytes (paper: 256 kB).
    pub wgt_buf_bytes: usize,
    /// Accumulator register file capacity in bytes (paper: 128 kB).
    pub acc_buf_bytes: usize,
    /// Output buffer capacity in bytes.
    pub out_buf_bytes: usize,
    /// Micro-op cache capacity in bytes (paper: 16 kB).
    pub uop_buf_bytes: usize,
    /// Accelerator clock in Hz (paper: 100 MHz on Pynq).
    pub clock_hz: f64,
    /// Shared DRAM port model.
    pub dram: DramModel,
    /// Command-queue depth in instructions (§2.4: "sized to be deep
    /// enough to allow for a wide execution window").
    pub cmd_queue_depth: usize,
    /// Dependence-token FIFO depth.
    pub dep_queue_depth: usize,
    /// Tensor ALU initiation interval (§2.5: "at least 2").
    pub alu_ii: u64,
    /// Scalar ALU lanes; a full `BATCH x BLOCK_OUT` 32-bit tensor op is
    /// issued as vector sub-ops over this many lanes (§2.5: "performed
    /// via vector-vector operations over multiple cycles").
    pub alu_lanes: usize,
}

impl Default for VtaConfig {
    fn default() -> Self {
        Self::pynq()
    }
}

impl VtaConfig {
    /// The paper's Pynq evaluation design point (§5).
    pub fn pynq() -> Self {
        VtaConfig {
            gemm: GemmShape { batch: 1, block_in: 16, block_out: 16 },
            inp_bits: 8,
            wgt_bits: 8,
            acc_bits: 32,
            out_bits: 8,
            inp_buf_bytes: 32 * 1024,
            wgt_buf_bytes: 256 * 1024,
            acc_buf_bytes: 128 * 1024,
            out_buf_bytes: 32 * 1024,
            uop_buf_bytes: 16 * 1024,
            clock_hz: 100e6,
            // Pynq DDR3 over one 64-bit AXI HP port, shared with the
            // CPU: ~1.6 GB/s effective for strided 2D DMA at 100 MHz
            // fabric clock → 16 B/cycle; ~200 cycle first-beat latency.
            // (Theoretical port peak is higher; short 2D bursts and
            // arbitration cut sustained throughput roughly in half,
            // which also puts the roofline knee at 32 ops/byte —
            // between the 1x1 and 3x3 ResNet layers, as in Fig 15.)
            dram: DramModel { bytes_per_cycle: 16.0, latency: 200 },
            cmd_queue_depth: 512,
            dep_queue_depth: 512,
            alu_ii: 2,
            alu_lanes: 16,
        }
    }

    /// The §2.6 bandwidth-derivation design point: BATCH=2, 200 MHz.
    pub fn bandwidth_example() -> Self {
        let mut c = Self::pynq();
        c.gemm = GemmShape { batch: 2, block_in: 16, block_out: 16 };
        c.clock_hz = 200e6;
        c
    }

    // ---- derived element/tile geometry -------------------------------

    /// Bytes of one input tile `BATCH x BLOCK_IN`.
    pub fn inp_tile_bytes(&self) -> usize {
        self.gemm.batch * self.gemm.block_in * self.inp_bits / 8
    }

    /// Bytes of one weight tile `BLOCK_OUT x BLOCK_IN`.
    pub fn wgt_tile_bytes(&self) -> usize {
        self.gemm.block_out * self.gemm.block_in * self.wgt_bits / 8
    }

    /// Bytes of one accumulator tile `BATCH x BLOCK_OUT`.
    pub fn acc_tile_bytes(&self) -> usize {
        self.gemm.batch * self.gemm.block_out * self.acc_bits / 8
    }

    /// Bytes of one output tile `BATCH x BLOCK_OUT` (narrowed results).
    pub fn out_tile_bytes(&self) -> usize {
        self.gemm.batch * self.gemm.block_out * self.out_bits / 8
    }

    /// Bytes of one encoded micro-op.
    pub fn uop_bytes(&self) -> usize {
        4
    }

    // ---- derived SRAM depths (in tiles / uops) -----------------------

    /// Input buffer depth, in tiles.
    pub fn inp_depth(&self) -> usize {
        self.inp_buf_bytes / self.inp_tile_bytes()
    }

    /// Weight buffer depth, in tiles.
    pub fn wgt_depth(&self) -> usize {
        self.wgt_buf_bytes / self.wgt_tile_bytes()
    }

    /// Register file depth, in accumulator tiles.
    pub fn acc_depth(&self) -> usize {
        self.acc_buf_bytes / self.acc_tile_bytes()
    }

    /// Output buffer depth, in output tiles.
    pub fn out_depth(&self) -> usize {
        self.out_buf_bytes / self.out_tile_bytes()
    }

    /// Micro-op cache depth, in micro-ops.
    pub fn uop_depth(&self) -> usize {
        self.uop_buf_bytes / self.uop_bytes()
    }

    // ---- §2.6 bandwidth derivation -----------------------------------

    /// Peak throughput in ops/s (1 MAC = 2 ops).
    pub fn peak_ops_per_sec(&self) -> f64 {
        self.gemm.ops_per_cycle() as f64 * self.clock_hz
    }

    /// Peak throughput in GOPS.
    pub fn peak_gops(&self) -> f64 {
        self.peak_ops_per_sec() / 1e9
    }

    /// DRAM bandwidth in GB/s implied by the DRAM model and clock.
    pub fn dram_gbytes_per_sec(&self) -> f64 {
        self.dram.bytes_per_cycle * self.clock_hz / 1e9
    }

    /// Required input-buffer read bandwidth (Gb/s) to keep the GEMM core
    /// busy — §2.6: 51.2 Gb/s at the BATCH=2 200 MHz design point.
    pub fn inp_bandwidth_gbps(&self) -> f64 {
        (self.gemm.batch * self.gemm.block_in * self.inp_bits) as f64 * self.clock_hz / 1e9
    }

    /// Required weight-buffer read bandwidth (Gb/s) — §2.6: 409.6 Gb/s.
    pub fn wgt_bandwidth_gbps(&self) -> f64 {
        (self.gemm.block_out * self.gemm.block_in * self.wgt_bits) as f64 * self.clock_hz / 1e9
    }

    /// Required register-file bandwidth (Gb/s), per direction — §2.6:
    /// 204.8 Gb/s (one `BATCH x BLOCK_OUT` int32 tile per cycle; the
    /// paper counts a single port direction).
    pub fn acc_bandwidth_gbps(&self) -> f64 {
        (self.gemm.batch * self.gemm.block_out * self.acc_bits) as f64 * self.clock_hz / 1e9
    }

    /// Validate internal consistency; returns a human-readable list of
    /// problems (empty if the configuration is sound).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        for (name, v) in [
            ("gemm.batch", self.gemm.batch),
            ("gemm.block_in", self.gemm.block_in),
            ("gemm.block_out", self.gemm.block_out),
            ("alu_lanes", self.alu_lanes),
        ] {
            if v == 0 {
                errs.push(format!("{name} must be non-zero"));
            }
        }
        for (name, bits) in [
            ("inp_bits", self.inp_bits),
            ("wgt_bits", self.wgt_bits),
            ("out_bits", self.out_bits),
        ] {
            if !matches!(bits, 8 | 16 | 32) {
                errs.push(format!("{name} must be one of 8/16/32, got {bits}"));
            }
        }
        if self.acc_bits != 32 {
            errs.push(format!("acc_bits must be 32, got {}", self.acc_bits));
        }
        if self.gemm.batch != 0
            && self.inp_tile_bytes() != 0
            && self.inp_buf_bytes % self.inp_tile_bytes() != 0
        {
            errs.push("inp_buf_bytes not a multiple of the input tile".into());
        }
        if self.dram.bytes_per_cycle <= 0.0 {
            errs.push("dram.bytes_per_cycle must be positive".into());
        }
        if self.cmd_queue_depth == 0 || self.dep_queue_depth == 0 {
            errs.push("queue depths must be non-zero".into());
        }
        if self.alu_ii == 0 {
            errs.push("alu_ii must be >= 1".into());
        }
        errs
    }

    /// Human-readable summary (the `vta info` CLI command).
    pub fn summary(&self) -> String {
        format!(
            "VTA variant: GEMM {} @ {:.0} MHz\n\
             peak: {:.1} GOPS   DRAM: {:.2} GB/s ({} B/cyc, {} cyc latency)\n\
             buffers: inp {} kB ({} tiles), wgt {} kB ({} tiles), \
             acc {} kB ({} tiles), out {} kB ({} tiles), uop {} kB ({} uops)\n\
             SRAM bandwidth to keep GEMM busy: inp {:.1} Gb/s, wgt {:.1} Gb/s, acc {:.1} Gb/s",
            self.gemm,
            self.clock_hz / 1e6,
            self.peak_gops(),
            self.dram_gbytes_per_sec(),
            self.dram.bytes_per_cycle,
            self.dram.latency,
            self.inp_buf_bytes / 1024,
            self.inp_depth(),
            self.wgt_buf_bytes / 1024,
            self.wgt_depth(),
            self.acc_buf_bytes / 1024,
            self.acc_depth(),
            self.out_buf_bytes / 1024,
            self.out_depth(),
            self.uop_buf_bytes / 1024,
            self.uop_depth(),
            self.inp_bandwidth_gbps(),
            self.wgt_bandwidth_gbps(),
            self.acc_bandwidth_gbps(),
        )
    }
}
