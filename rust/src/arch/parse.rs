//! A small `key = value` configuration-file parser for VTA variants.
//!
//! The stack is offline-buildable with no serde dependency, so configs use
//! a flat INI-like format (comments with `#`, one `key = value` per line):
//!
//! ```text
//! # 16x16 Pynq design point
//! gemm.batch     = 1
//! gemm.block_in  = 16
//! gemm.block_out = 16
//! clock_mhz      = 100
//! inp_buf_kib    = 32
//! wgt_buf_kib    = 256
//! acc_buf_kib    = 128
//! uop_buf_kib    = 16
//! dram.bytes_per_cycle = 32
//! dram.latency   = 150
//! ```
//!
//! Unknown keys are an error (catching typos beats silently ignoring
//! them); omitted keys inherit from [`VtaConfig::pynq`].

use super::{GemmShape, VtaConfig};
use anyhow::{bail, Context, Result};

/// Parse a config string into a [`VtaConfig`], starting from the Pynq
/// defaults.
pub fn parse_config_str(text: &str) -> Result<VtaConfig> {
    let mut cfg = VtaConfig::pynq();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("line {}: expected `key = value`, got {raw:?}", lineno + 1))?;
        let key = key.trim();
        let value = value.trim();
        apply_key(&mut cfg, key, value)
            .with_context(|| format!("line {}: key {key:?}", lineno + 1))?;
    }
    let errs = cfg.validate();
    if !errs.is_empty() {
        bail!("invalid config: {}", errs.join("; "));
    }
    Ok(cfg)
}

fn parse_usize(v: &str) -> Result<usize> {
    v.parse::<usize>().with_context(|| format!("not an unsigned integer: {v:?}"))
}

fn parse_f64(v: &str) -> Result<f64> {
    v.parse::<f64>().with_context(|| format!("not a number: {v:?}"))
}

fn apply_key(cfg: &mut VtaConfig, key: &str, value: &str) -> Result<()> {
    match key {
        "gemm.batch" => cfg.gemm.batch = parse_usize(value)?,
        "gemm.block_in" => cfg.gemm.block_in = parse_usize(value)?,
        "gemm.block_out" => cfg.gemm.block_out = parse_usize(value)?,
        "gemm" => {
            // Shorthand: `gemm = 1x16x16`.
            let parts: Vec<&str> = value.split('x').collect();
            if parts.len() != 3 {
                bail!("expected BATCHxBLOCK_INxBLOCK_OUT, got {value:?}");
            }
            cfg.gemm = GemmShape {
                batch: parse_usize(parts[0])?,
                block_in: parse_usize(parts[1])?,
                block_out: parse_usize(parts[2])?,
            };
        }
        "inp_bits" => cfg.inp_bits = parse_usize(value)?,
        "wgt_bits" => cfg.wgt_bits = parse_usize(value)?,
        "acc_bits" => cfg.acc_bits = parse_usize(value)?,
        "out_bits" => cfg.out_bits = parse_usize(value)?,
        "inp_buf_kib" => cfg.inp_buf_bytes = parse_usize(value)? * 1024,
        "wgt_buf_kib" => cfg.wgt_buf_bytes = parse_usize(value)? * 1024,
        "acc_buf_kib" => cfg.acc_buf_bytes = parse_usize(value)? * 1024,
        "out_buf_kib" => cfg.out_buf_bytes = parse_usize(value)? * 1024,
        "uop_buf_kib" => cfg.uop_buf_bytes = parse_usize(value)? * 1024,
        "clock_mhz" => cfg.clock_hz = parse_f64(value)? * 1e6,
        "dram.bytes_per_cycle" => cfg.dram.bytes_per_cycle = parse_f64(value)?,
        "dram.latency" => cfg.dram.latency = parse_usize(value)? as u64,
        "cmd_queue_depth" => cfg.cmd_queue_depth = parse_usize(value)?,
        "dep_queue_depth" => cfg.dep_queue_depth = parse_usize(value)?,
        "alu_ii" => cfg.alu_ii = parse_usize(value)? as u64,
        "alu_lanes" => cfg.alu_lanes = parse_usize(value)?,
        other => bail!("unknown config key {other:?}"),
    }
    Ok(())
}

/// Load a config from a file path, or return the Pynq default when `path`
/// is `None`.
pub fn load_config(path: Option<&str>) -> Result<VtaConfig> {
    match path {
        None => Ok(VtaConfig::pynq()),
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading config file {p}"))?;
            parse_config_str(&text)
        }
    }
}
