//! VTA architecture description.
//!
//! VTA is a *parameterizable* design (§2.2: "the VTA ISA changes as VTA's
//! architectural parameters are modified"). Everything downstream — ISA
//! field widths, SRAM depths, the compiler's tiling factors, the
//! simulator's timing — derives from [`VtaConfig`].

mod config;
mod parse;

pub use config::{DramModel, GemmShape, VtaConfig};
pub use parse::{load_config, parse_config_str};

#[cfg(test)]
mod tests;
