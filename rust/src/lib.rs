//! # VTA: Versatile Tensor Accelerator — an open hardware-software stack
//!
//! A full-stack reproduction of *"VTA: An Open Hardware-Software Stack for
//! Deep Learning"* (Moreau et al., 2018) — published as *"A Hardware-Software
//! Blueprint for Flexible Deep Learning Specialization"*.
//!
//! The stack, bottom-up:
//!
//! * [`arch`] — the parameterizable hardware architecture description
//!   (`VtaConfig`): GEMM core shape, buffer sizes, clock, DRAM model.
//! * [`isa`] — the two-level ISA: 128-bit CISC instructions
//!   (LOAD/GEMM/ALU/STORE with dependence flags) and 32-bit RISC micro-ops.
//! * [`sim`] — a cycle-approximate, functionally exact behavioral simulator
//!   of the four-module VTA pipeline (fetch / load / compute / store) with
//!   dependence-token dataflow execution and a hazard checker.
//! * [`runtime`] — the JIT runtime: DRAM buffer management, instruction
//!   stream construction, micro-kernel generation + LRU caching, explicit
//!   dependence push/pop, CPU<->VTA synchronization.
//! * [`compiler`] — the TVM-like schedule lowering layer: tiling, memory
//!   scopes, tensorization onto the GEMM intrinsic and the tensor ALU,
//!   virtual-threading based latency hiding, and the unified operator
//!   API ([`compiler::op`]): the `VtaOp` trait + registry every
//!   downstream layer dispatches through.
//! * [`graph`] — the NNVM-like graph IR: operators, quantization, fusion,
//!   registry-driven CPU/VTA partitioning, and the ResNet-18 and fast
//!   style-transfer workload builders.
//! * [`dse`] — design-space exploration and autotuning: hardware
//!   candidates under an FPGA resource model, measured schedule tuning
//!   per (config, operator), and the JSON tuning-record store the
//!   serving engine consults at compile time.
//! * [`exec`] — the graph executor that co-schedules VTA kernels on the
//!   simulator and CPU-resident operators on XLA/PJRT executables compiled
//!   ahead-of-time from JAX (see `python/compile/`).
//! * [`exec::serve`] — the serving runtime: a JIT compiled-plan cache
//!   (compile-once/run-many lowering via [`compiler::compiled`]), a
//!   pipelined, batched single-device engine, and a multi-device
//!   scheduler (request queue, dynamic batching, least-loaded
//!   dispatch) over a [`runtime::DevicePool`] of accelerator replicas.
//! * [`metrics`] — roofline accounting: GOPS, arithmetic intensity,
//!   utilization.
//!
//! A bottom-up architectural walk of the whole stack — including the
//! dependence-token pipeline and the plan-cache/serving flow — lives
//! in `docs/ARCHITECTURE.md` at the repository root.

pub mod arch;
pub mod compiler;
pub mod dse;
pub mod exec;
pub mod graph;
pub mod isa;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod util;

pub use arch::VtaConfig;
