//! Behavioral DRAM: a flat byte array with bounds-checked typed access.
//!
//! The VTA runtime allocates *physically contiguous* buffers (§3.2) and
//! hands the accelerator raw physical addresses; the simulator mirrors
//! that with plain byte offsets.

use super::SimError;

/// Flat DRAM image shared by the CPU (runtime) and the accelerator
/// (simulator DMA masters).
pub struct Dram {
    bytes: Vec<u8>,
}

impl Dram {
    /// Allocate a DRAM of `size` bytes, zero-initialized.
    pub fn new(size: usize) -> Self {
        Dram { bytes: vec![0; size] }
    }

    /// Total capacity in bytes.
    pub fn size(&self) -> usize {
        self.bytes.len()
    }

    fn check(&self, addr: usize, len: usize) -> Result<(), SimError> {
        if addr.checked_add(len).map_or(true, |end| end > self.bytes.len()) {
            return Err(SimError::DramOutOfBounds { addr, len, size: self.bytes.len() });
        }
        Ok(())
    }

    /// Borrow a byte slice.
    pub fn read(&self, addr: usize, len: usize) -> Result<&[u8], SimError> {
        self.check(addr, len)?;
        Ok(&self.bytes[addr..addr + len])
    }

    /// Write a byte slice.
    pub fn write(&mut self, addr: usize, data: &[u8]) -> Result<(), SimError> {
        self.check(addr, data.len())?;
        self.bytes[addr..addr + data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Read `n` i8 elements.
    pub fn read_i8(&self, addr: usize, n: usize) -> Result<&[i8], SimError> {
        let b = self.read(addr, n)?;
        // Safety: i8 and u8 have identical layout.
        Ok(unsafe { std::slice::from_raw_parts(b.as_ptr() as *const i8, n) })
    }

    /// Write i8 elements.
    pub fn write_i8(&mut self, addr: usize, data: &[i8]) -> Result<(), SimError> {
        let b = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
        self.write(addr, b)
    }

    /// Read `n` little-endian i32 elements.
    pub fn read_i32(&self, addr: usize, n: usize) -> Result<Vec<i32>, SimError> {
        let b = self.read(addr, n * 4)?;
        Ok(b.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Write little-endian i32 elements.
    pub fn write_i32(&mut self, addr: usize, data: &[i32]) -> Result<(), SimError> {
        self.check(addr, data.len() * 4)?;
        for (i, v) in data.iter().enumerate() {
            self.bytes[addr + 4 * i..addr + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }

    /// Read `n` little-endian u32 words (micro-ops).
    pub fn read_u32(&self, addr: usize, n: usize) -> Result<Vec<u32>, SimError> {
        let b = self.read(addr, n * 4)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Write little-endian u32 words.
    pub fn write_u32(&mut self, addr: usize, data: &[u32]) -> Result<(), SimError> {
        self.check(addr, data.len() * 4)?;
        for (i, v) in data.iter().enumerate() {
            self.bytes[addr + 4 * i..addr + 4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(())
    }
}
