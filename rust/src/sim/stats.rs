//! Simulation statistics: the raw material for the paper's roofline
//! (Fig 15) and utilization claims.

/// Aggregate counters produced by one simulator run.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// Total simulated cycles (time of the FINISH instruction retiring).
    pub total_cycles: u64,
    /// Cycles the GEMM core spent executing micro-ops.
    pub gemm_busy_cycles: u64,
    /// Cycles the tensor ALU spent executing micro-ops.
    pub alu_busy_cycles: u64,
    /// Cycles the load module's DMA was occupied.
    pub load_busy_cycles: u64,
    /// Cycles the store module's DMA was occupied.
    pub store_busy_cycles: u64,
    /// Cycles the shared DRAM port was occupied (all masters).
    pub dram_busy_cycles: u64,
    /// Cycles the fetch module stalled on a full command queue.
    pub fetch_stall_cycles: u64,
    /// Instructions executed, by class.
    pub insn_load: u64,
    pub insn_store: u64,
    pub insn_gemm: u64,
    pub insn_alu: u64,
    /// GEMM micro-ops executed (1 tile-matmul each).
    pub gemm_uops: u64,
    /// ALU micro-ops executed (1 tile op each).
    pub alu_uops: u64,
    /// Bytes moved DRAM→SRAM (input + weight + acc + uop loads).
    pub bytes_loaded: u64,
    /// Bytes moved SRAM→DRAM (stores).
    pub bytes_stored: u64,
    /// Dependence tokens pushed, by queue: [l2c, c2l, c2s, s2c].
    pub tokens_pushed: [u64; 4],
}

impl SimStats {
    /// Multiply-accumulate operations executed by the GEMM core.
    pub fn macs(&self, macs_per_uop: usize) -> u64 {
        self.gemm_uops * macs_per_uop as u64
    }

    /// Fraction of total cycles the GEMM core was busy — the paper's
    /// "peak compute utilization" metric (Fig 15: 70% → 88%).
    pub fn compute_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.gemm_busy_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Fraction of total cycles the DRAM port was busy.
    pub fn dram_utilization(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.dram_busy_cycles as f64 / self.total_cycles as f64
        }
    }

    /// Total DRAM traffic in bytes.
    pub fn bytes_moved(&self) -> u64 {
        self.bytes_loaded + self.bytes_stored
    }

    /// Merge another run's counters into this one (used by multi-layer
    /// aggregation in the end-to-end benchmark).
    pub fn merge(&mut self, other: &SimStats) {
        self.total_cycles += other.total_cycles;
        self.gemm_busy_cycles += other.gemm_busy_cycles;
        self.alu_busy_cycles += other.alu_busy_cycles;
        self.load_busy_cycles += other.load_busy_cycles;
        self.store_busy_cycles += other.store_busy_cycles;
        self.dram_busy_cycles += other.dram_busy_cycles;
        self.fetch_stall_cycles += other.fetch_stall_cycles;
        self.insn_load += other.insn_load;
        self.insn_store += other.insn_store;
        self.insn_gemm += other.insn_gemm;
        self.insn_alu += other.insn_alu;
        self.gemm_uops += other.gemm_uops;
        self.alu_uops += other.alu_uops;
        self.bytes_loaded += other.bytes_loaded;
        self.bytes_stored += other.bytes_stored;
        for i in 0..4 {
            self.tokens_pushed[i] += other.tokens_pushed[i];
        }
    }
}
