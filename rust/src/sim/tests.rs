use super::*;
use crate::arch::VtaConfig;
use crate::isa::*;

/// DRAM layout used by the hand-built streams below (tile indices).
/// uop kernel @ byte 0, input tiles @ 1024, weight tiles @ 2048,
/// accumulator init @ 8192, outputs @ 3072.
const UOP_DRAM: u32 = 0; // uop tiles are 4 B → byte 0
const INP_DRAM: u32 = 64; // inp tiles are 16 B → byte 1024
const WGT_DRAM: u32 = 8; // wgt tiles are 256 B → byte 2048
const OUT_DRAM: u32 = 192; // out tiles are 16 B → byte 3072

fn sim() -> Simulator {
    Simulator::new(VtaConfig::pynq(), 1 << 20)
}

fn mem(buffer: BufferId, deps: DepFlags, sram_base: u32, dram_base: u32, tiles: u16) -> MemInsn {
    MemInsn {
        deps,
        buffer,
        sram_base,
        dram_base,
        y_size: 1,
        x_size: tiles,
        x_stride: tiles,
        y_pad_top: 0,
        y_pad_bottom: 0,
        x_pad_left: 0,
        x_pad_right: 0,
    }
}

fn no_deps() -> DepFlags {
    DepFlags::NONE
}

fn d(pop_prev: bool, pop_next: bool, push_prev: bool, push_next: bool) -> DepFlags {
    DepFlags { pop_prev, pop_next, push_prev, push_next }
}

/// One-uop GEMM over tile 0: acc[0] += inp[0] x wgt[0]^T.
fn gemm1(deps: DepFlags, reset: bool) -> GemmInsn {
    GemmInsn {
        deps,
        reset,
        uop_begin: 0,
        uop_end: 1,
        lp0: 1,
        lp1: 1,
        acc_factor0: 0,
        acc_factor1: 0,
        inp_factor0: 0,
        inp_factor1: 0,
        wgt_factor0: 0,
        wgt_factor1: 0,
    }
}

/// Build the canonical single-tile matmul stream with correct deps.
fn single_tile_stream() -> Vec<Instruction> {
    vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 1)),
        Instruction::Gemm(gemm1(no_deps(), true)), // reset acc
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 1)),
        Instruction::Load(mem(BufferId::Wgt, d(false, false, false, true), 0, WGT_DRAM, 1)),
        Instruction::Gemm(gemm1(d(true, false, false, true), false)),
        Instruction::Store(mem(BufferId::Out, d(true, false, true, false), 0, OUT_DRAM, 1)),
        Instruction::Finish(d(false, true, false, false)),
    ]
}

fn seed_single_tile(s: &mut Simulator) -> (Vec<i8>, Vec<i8>) {
    let uop = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    s.dram.write_u32(0, &[uop]).unwrap();
    let inp: Vec<i8> = (0..16).map(|i| i as i8 - 8).collect();
    let wgt: Vec<i8> = (0..256).map(|i| ((i * 7) % 23) as i8 - 11).collect();
    s.dram.write_i8(1024, &inp).unwrap();
    s.dram.write_i8(2048, &wgt).unwrap();
    (inp, wgt)
}

fn reference_out(inp: &[i8], wgt: &[i8]) -> Vec<i8> {
    (0..16)
        .map(|o| {
            let mut acc = 0i32;
            for k in 0..16 {
                acc += inp[k] as i32 * wgt[o * 16 + k] as i32;
            }
            acc as i8
        })
        .collect()
}

#[test]
fn single_tile_matmul_matches_reference() {
    let mut s = sim();
    let (inp, wgt) = seed_single_tile(&mut s);
    let stats = s.run(&single_tile_stream()).unwrap();
    let got = s.dram.read_i8(3072, 16).unwrap().to_vec();
    assert_eq!(got, reference_out(&inp, &wgt));
    assert_eq!(stats.insn_gemm, 2); // reset + multiply
    assert_eq!(stats.gemm_uops, 2);
    assert_eq!(stats.insn_load, 3);
    assert_eq!(stats.insn_store, 1);
    assert!(stats.total_cycles > 0);
}

#[test]
fn load_with_padding_zeroes_edges() {
    let mut s = sim();
    // 2x2 payload with 1-tile padding all around → 4x4 tiles in SRAM.
    s.dram.write_i8(1024, &[1i8; 64]).unwrap(); // 4 input tiles of 16 bytes
    let insn = MemInsn {
        deps: no_deps(),
        buffer: BufferId::Inp,
        sram_base: 0,
        dram_base: 64,
        y_size: 2,
        x_size: 2,
        x_stride: 2,
        y_pad_top: 1,
        y_pad_bottom: 1,
        x_pad_left: 1,
        x_pad_right: 1,
    };
    assert_eq!(insn.sram_tiles(), 16);
    assert_eq!(insn.dram_tiles(), 4);
    let stream = vec![Instruction::Load(insn), Instruction::Finish(no_deps())];
    let stats = s.run(&stream).unwrap();
    // Only the payload crosses the DRAM port (Fig 9: padding is free).
    assert_eq!(stats.bytes_loaded, 64);
    // Check SRAM via a GEMM that reads tiles — instead, verify through
    // a second run: store is only possible from OUT, so use the
    // engine's internal state via the public run result of a compute.
    // Simplest: load a payload tile into acc via LOAD.ACC and compare.
    // (Padding correctness is asserted end-to-end in compiler tests.)
}

#[test]
fn alu_relu_and_shift_semantics() {
    let mut s = sim();
    // acc[0] loaded from DRAM, then SHR 2 and ReLU (MAX 0), then store.
    let acc_init: Vec<i32> = (0..16).map(|i| (i - 8) * 100).collect();
    s.dram.write_i32(4096, &acc_init).unwrap();
    let uop = Uop::Alu(AluUop { dst_idx: 0, src_idx: 0 }).encode().unwrap();
    s.dram.write_u32(0, &[uop]).unwrap();

    let alu = |op: AluOpcode, imm: i16, deps: DepFlags| {
        Instruction::Alu(AluInsn {
            deps,
            op,
            use_imm: true,
            imm,
            uop_begin: 0,
            uop_end: 1,
            lp0: 1,
            lp1: 1,
            dst_factor0: 0,
            dst_factor1: 0,
            src_factor0: 0,
            src_factor1: 0,
        })
    };
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, 0, 1)),
        // LOAD.ACC: tile index = byte 4096 / 64 B per acc tile = 64.
        Instruction::Load(mem(BufferId::Acc, no_deps(), 0, 64, 1)),
        alu(AluOpcode::Shr, 2, no_deps()),
        alu(AluOpcode::Max, 0, d(false, false, false, true)),
        Instruction::Store(mem(BufferId::Out, d(true, false, true, false), 0, OUT_DRAM, 1)),
        Instruction::Finish(d(false, true, false, false)),
    ];
    let stats = s.run(&stream).unwrap();
    let got = s.dram.read_i8(3072, 16).unwrap().to_vec();
    let expect: Vec<i8> =
        acc_init.iter().map(|&v| ((v >> 2).max(0)) as i8).collect();
    assert_eq!(got, expect);
    assert_eq!(stats.insn_alu, 2);
    // ALU initiation interval 2 (§2.5): 2 uops * II(2) * 1 lane-pass.
    assert_eq!(stats.alu_busy_cycles, 4);
}

#[test]
fn deadlock_is_detected() {
    let mut s = sim();
    seed_single_tile(&mut s);
    // GEMM pops a RAW token that nothing pushes.
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 1)),
        Instruction::Gemm(gemm1(d(true, false, false, false), false)),
        Instruction::Finish(no_deps()),
    ];
    match s.run(&stream) {
        Err(SimError::Deadlock { compute_pc, .. }) => assert_eq!(compute_pc, 1),
        other => panic!("expected deadlock, got {other:?}"),
    }
}

#[test]
fn missing_finish_is_rejected() {
    let mut s = sim();
    let stream = vec![Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 1))];
    assert!(matches!(s.run(&stream), Err(SimError::MissingFinish)));
}

#[test]
fn store_from_non_out_buffer_is_illegal() {
    let mut s = sim();
    let stream = vec![
        Instruction::Store(mem(BufferId::Inp, no_deps(), 0, 0, 1)),
        Instruction::Finish(no_deps()),
    ];
    assert!(matches!(s.run(&stream), Err(SimError::IllegalInstruction { .. })));
}

#[test]
fn sram_bounds_are_enforced() {
    let mut s = sim();
    let insn = mem(BufferId::Inp, no_deps(), 2040, INP_DRAM, 100); // 2048-tile buffer
    let stream = vec![Instruction::Load(insn), Instruction::Finish(no_deps())];
    assert!(matches!(s.run(&stream), Err(SimError::SramOutOfBounds { .. })));
}

#[test]
fn dram_bounds_are_enforced() {
    let mut s = Simulator::new(VtaConfig::pynq(), 1024);
    let insn = mem(BufferId::Inp, no_deps(), 0, 63, 2); // bytes 1008..1040 > 1024
    let stream = vec![Instruction::Load(insn), Instruction::Finish(no_deps())];
    assert!(matches!(s.run(&stream), Err(SimError::DramOutOfBounds { .. })));
}

#[test]
fn hazard_checker_flags_missing_raw_dep() {
    let mut s = sim();
    s.set_mode(ExecMode::CheckHazards);
    seed_single_tile(&mut s);
    // Store does NOT wait for the GEMM (no pop_prev): Fig 5's
    // "store reads the result before it is computed".
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 1)),
        Instruction::Gemm(gemm1(no_deps(), true)),
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 1)),
        Instruction::Load(mem(BufferId::Wgt, d(false, false, false, true), 0, WGT_DRAM, 1)),
        Instruction::Gemm(gemm1(d(true, false, false, true), false)),
        Instruction::Store(mem(BufferId::Out, no_deps(), 0, OUT_DRAM, 1)), // missing pop_prev!
        Instruction::Finish(no_deps()),
    ];
    // The FINISH no longer waits on the store token; the store pushes
    // nothing. Stream still terminates.
    let _ = s.run(&stream).unwrap();
    // The tracker observes the conflict when the *second* access lands,
    // so the same Fig 5 race surfaces as ReadBeforeWrite or
    // WriteDuringRead depending on which access the engine scheduled
    // first. Either way it must involve the store module and the
    // output buffer.
    assert!(
        s.hazards().iter().any(|h| h.buffer == BufferId::Out
            && (h.first.0 == HazardModule::Store || h.second.0 == HazardModule::Store)),
        "expected a hazard on the output buffer, got {:?}",
        s.hazards()
    );
}

#[test]
fn hazard_checker_clean_on_correct_stream() {
    let mut s = sim();
    s.set_mode(ExecMode::CheckHazards);
    seed_single_tile(&mut s);
    let _ = s.run(&single_tile_stream()).unwrap();
    assert!(s.hazards().is_empty(), "unexpected hazards: {:?}", s.hazards());
}

/// Fig 4: with dependence-decoupled modules, loads of phase N+1 overlap
/// compute of phase N, so the pipelined stream is strictly faster than
/// the serialized one (where a WAR dependence from compute back to the
/// load module forces loads to wait) while producing identical results.
#[test]
fn task_level_pipeline_parallelism_hides_latency() {
    let cfg = VtaConfig::pynq();

    // Two phases in distinct buffer contexts (double buffering).
    let build = |serialize: bool| -> Vec<Instruction> {
        let mut v = vec![Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 2))];
        for phase in 0..2u16 {
            let k = phase as u32;
            // Phase k>0's input load waits on the previous GEMM in the
            // serialized stream (pops the WAR token it pushes).
            let inp = mem(
                BufferId::Inp,
                d(false, serialize && phase > 0, false, false),
                k * 64,
                INP_DRAM,
                64,
            );
            let wgt = mem(BufferId::Wgt, d(false, false, false, true), k, WGT_DRAM + k, 1);
            let g = GemmInsn {
                deps: d(true, false, serialize, true),
                reset: false,
                uop_begin: phase,
                uop_end: phase + 1,
                lp0: 64,
                lp1: 8, // 512 uop executions → long compute
                acc_factor0: 0,
                acc_factor1: 0,
                inp_factor0: 0,
                inp_factor1: 0,
                wgt_factor0: 0,
                wgt_factor1: 0,
            };
            let st = mem(BufferId::Out, d(true, false, false, false), k, OUT_DRAM + k, 1);
            v.push(Instruction::Load(inp));
            v.push(Instruction::Load(wgt));
            v.push(Instruction::Gemm(g));
            v.push(Instruction::Store(st));
        }
        v.push(Instruction::Finish(no_deps()));
        v
    };

    let seed = |s: &mut Simulator| {
        let u0 = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
        let u1 = Uop::Gemm(GemmUop { acc_idx: 1, inp_idx: 64, wgt_idx: 1 }).encode().unwrap();
        s.dram.write_u32(0, &[u0, u1]).unwrap();
    };

    let mut s1 = Simulator::new(cfg.clone(), 1 << 20);
    seed(&mut s1);
    let pipelined = s1.run(&build(false)).unwrap();

    let mut s2 = Simulator::new(cfg, 1 << 20);
    seed(&mut s2);
    let serial = s2.run(&build(true)).unwrap();

    assert!(
        pipelined.total_cycles < serial.total_cycles,
        "pipelined {} !< serial {}",
        pipelined.total_cycles,
        serial.total_cycles
    );
    // Identical work in both schedules.
    assert_eq!(pipelined.gemm_uops, serial.gemm_uops);
}

// ---------------------------------------------------------------------
// Hazard-model streams: deliberate RAW/WAR dependence-token patterns
// across the load / compute / store queues. Ordering is proven
// *functionally*: if the simulator executed past a hazard, the stored
// results would be the wrong operand's product.
// ---------------------------------------------------------------------

/// WAR across load↔compute: a second input load overwrites the tile a
/// GEMM is still reading, fenced by the compute→load WAR token. The
/// first result must be computed from the first operand.
#[test]
fn war_token_orders_input_reload_behind_compute() {
    let mut s = sim();
    let u0 = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    let u1 = Uop::Gemm(GemmUop { acc_idx: 1, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    s.dram.write_u32(0, &[u0, u1]).unwrap();
    let a: Vec<i8> = (0..16).map(|i| i as i8 - 8).collect();
    let b: Vec<i8> = (0..16).map(|i| 7 - i as i8).collect();
    let wgt: Vec<i8> = (0..256).map(|i| ((i * 5) % 17) as i8 - 8).collect();
    s.dram.write_i8(1024, &a).unwrap();
    s.dram.write_i8(1040, &b).unwrap();
    s.dram.write_i8(2048, &wgt).unwrap();

    let reset = GemmInsn {
        lp0: 2,
        acc_factor0: 1,
        reset: true,
        deps: no_deps(),
        ..gemm1(no_deps(), true)
    };
    let gemm_at = |uop: u16, deps: DepFlags| {
        Instruction::Gemm(GemmInsn { uop_begin: uop, uop_end: uop + 1, ..gemm1(deps, false) })
    };
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 2)),
        Instruction::Gemm(reset),
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 1)), // tile0 ← A
        Instruction::Load(mem(BufferId::Wgt, d(false, false, false, true), 0, WGT_DRAM, 1)),
        // acc0 += A x W; WAR token back to the load module.
        gemm_at(0, d(true, false, true, false)),
        // tile0 ← B: must wait for the WAR token (the GEMM still reads
        // tile0), then RAW-signal the second GEMM.
        Instruction::Load(mem(BufferId::Inp, d(false, true, false, true), 0, INP_DRAM + 1, 1)),
        // acc1 += B x W; RAW token to the store.
        gemm_at(1, d(true, false, false, true)),
        Instruction::Store(mem(BufferId::Out, d(true, false, true, false), 0, OUT_DRAM, 2)),
        Instruction::Finish(d(false, true, false, false)),
    ];
    let stats = s.run(&stream).unwrap();

    let got = s.dram.read_i8(3072, 32).unwrap().to_vec();
    assert_eq!(&got[..16], reference_out(&a, &wgt), "acc0 must see operand A, not the reload");
    assert_eq!(&got[16..], reference_out(&b, &wgt), "acc1 must see operand B");
    // Token traffic: [l2c, c2l, c2s, s2c].
    assert_eq!(stats.tokens_pushed, [2, 1, 1, 1]);
}

/// RAW + WAR chained across all three queues: the out/acc tile is
/// reused by a second phase that must wait for the store→compute WAR
/// token before overwriting it. Neither phase may deadlock or reorder.
#[test]
fn store_war_token_orders_accumulator_reuse() {
    let mut s = sim();
    let u0 = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    let u1 = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 1, wgt_idx: 0 }).encode().unwrap();
    s.dram.write_u32(0, &[u0, u1]).unwrap();
    let a: Vec<i8> = (0..16).map(|i| (i as i8 % 5) - 2).collect();
    let b: Vec<i8> = (0..16).map(|i| 3 - (i as i8 % 7)).collect();
    let mut inp = a.clone();
    inp.extend_from_slice(&b);
    let wgt: Vec<i8> = (0..256).map(|i| ((i * 11) % 13) as i8 - 6).collect();
    s.dram.write_i8(1024, &inp).unwrap();
    s.dram.write_i8(2048, &wgt).unwrap();

    let gemm_at = |uop: u16, deps: DepFlags| {
        Instruction::Gemm(GemmInsn { uop_begin: uop, uop_end: uop + 1, ..gemm1(deps, false) })
    };
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 2)),
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 2)),
        Instruction::Load(mem(BufferId::Wgt, d(false, false, false, true), 0, WGT_DRAM, 1)),
        Instruction::Gemm(gemm1(no_deps(), true)), // reset acc0
        gemm_at(0, d(true, false, false, true)),   // acc0 = A x W → RAW to store
        Instruction::Store(mem(BufferId::Out, d(true, false, true, false), 0, OUT_DRAM, 1)),
        // Phase 2 reset overwrites acc0/out0: must pop the store's WAR
        // token first (the Fig 5 write-during-read scenario, fenced).
        Instruction::Gemm(GemmInsn { deps: d(false, true, false, false), ..gemm1(no_deps(), true) }),
        gemm_at(1, d(false, false, false, true)), // acc0 = B x W → RAW to store
        Instruction::Store(mem(BufferId::Out, d(true, false, true, false), 0, OUT_DRAM + 1, 1)),
        Instruction::Finish(d(false, true, false, false)),
    ];
    let stats = s.run(&stream).unwrap();

    let got = s.dram.read_i8(3072, 32).unwrap().to_vec();
    assert_eq!(&got[..16], reference_out(&a, &wgt), "phase 1 store must precede the acc reuse");
    assert_eq!(&got[16..], reference_out(&b, &wgt), "phase 2 must see operand B");
    assert_eq!(stats.tokens_pushed, [1, 0, 2, 2]);
    // The deep chain retired without deadlock and executed everything.
    assert_eq!(stats.insn_gemm, 4);
    assert_eq!(stats.insn_store, 2);
}

/// The same reload pattern with the WAR token deliberately omitted:
/// the stream must still terminate (no deadlock), and the hazard
/// checker must flag the race on the input buffer.
#[test]
fn hazard_checker_flags_missing_war_on_input_reload() {
    let mut s = sim();
    s.set_mode(ExecMode::CheckHazards);
    let u0 = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    let u1 = Uop::Gemm(GemmUop { acc_idx: 1, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    s.dram.write_u32(0, &[u0, u1]).unwrap();
    s.dram.write_i8(1024, &[1i8; 32]).unwrap();
    s.dram.write_i8(2048, &[2i8; 256]).unwrap();

    let gemm_at = |uop: u16, deps: DepFlags| {
        Instruction::Gemm(GemmInsn { uop_begin: uop, uop_end: uop + 1, ..gemm1(deps, false) })
    };
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 2)),
        Instruction::Gemm(GemmInsn {
            lp0: 2,
            acc_factor0: 1,
            reset: true,
            deps: no_deps(),
            ..gemm1(no_deps(), true)
        }),
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 1)),
        Instruction::Load(mem(BufferId::Wgt, d(false, false, false, true), 0, WGT_DRAM, 1)),
        gemm_at(0, d(true, false, false, false)),
        // Missing pop_next: overwrites tile0 while the GEMM may still
        // be reading it.
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM + 1, 1)),
        gemm_at(1, no_deps()),
        Instruction::Store(mem(BufferId::Out, no_deps(), 0, OUT_DRAM, 2)),
        Instruction::Finish(no_deps()),
    ];
    let _ = s.run(&stream).unwrap();
    assert!(
        s.hazards().iter().any(|h| h.buffer == BufferId::Inp),
        "expected a race on the input buffer, got {:?}",
        s.hazards()
    );
}

#[test]
fn gemm_affine_loop_indexing() {
    // 2x2 grid of accumulator tiles computed from strided uop bases:
    // acc[i0*2 + i1] += inp[i1] x wgt[i0].
    let mut s = sim();
    let uop = Uop::Gemm(GemmUop { acc_idx: 0, inp_idx: 0, wgt_idx: 0 }).encode().unwrap();
    s.dram.write_u32(0, &[uop]).unwrap();
    let inp: Vec<i8> = (0..32).map(|i| (i % 5) as i8).collect(); // 2 tiles
    let wgt: Vec<i8> = (0..512).map(|i| (i % 3) as i8 - 1).collect(); // 2 tiles
    s.dram.write_i8(1024, &inp).unwrap();
    s.dram.write_i8(2048, &wgt).unwrap();

    let g = GemmInsn {
        deps: d(true, false, false, true),
        reset: false,
        uop_begin: 0,
        uop_end: 1,
        lp0: 2,
        lp1: 2,
        acc_factor0: 2,
        acc_factor1: 1,
        inp_factor0: 0,
        inp_factor1: 1,
        wgt_factor0: 1,
        wgt_factor1: 0,
    };
    let reset = GemmInsn { lp0: 4, acc_factor0: 1, deps: no_deps(), reset: true, ..g };
    let stream = vec![
        Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 1)),
        Instruction::Gemm(reset),
        Instruction::Load(mem(BufferId::Inp, no_deps(), 0, INP_DRAM, 2)),
        Instruction::Load(mem(BufferId::Wgt, d(false, false, false, true), 0, WGT_DRAM, 2)),
        Instruction::Gemm(g),
        Instruction::Store(mem(BufferId::Out, d(true, false, true, false), 0, OUT_DRAM, 4)),
        Instruction::Finish(d(false, true, false, false)),
    ];
    let _ = s.run(&stream).unwrap();
    let got = s.dram.read_i8(3072, 64).unwrap().to_vec();

    // Reference.
    let mut expect = vec![0i8; 64];
    for i0 in 0..2 {
        for i1 in 0..2 {
            let acc_t = i0 * 2 + i1;
            for o in 0..16 {
                let mut sum = 0i32;
                for k in 0..16 {
                    sum += inp[i1 * 16 + k] as i32 * wgt[i0 * 256 + o * 16 + k] as i32;
                }
                expect[acc_t * 16 + o] = sum as i8;
            }
        }
    }
    assert_eq!(got, expect);
}

#[test]
fn fetch_backpressure_with_tiny_queue() {
    // A queue of depth 2 forces fetch stalls but must not deadlock.
    let mut cfg = VtaConfig::pynq();
    cfg.cmd_queue_depth = 2;
    let mut s = Simulator::new(cfg, 1 << 20);
    seed_single_tile(&mut s);
    let mut stream = vec![Instruction::Load(mem(BufferId::Uop, no_deps(), 0, UOP_DRAM, 1))];
    // Many independent loads into distinct input tiles.
    for i in 0..32u32 {
        stream.push(Instruction::Load(mem(BufferId::Inp, no_deps(), i, INP_DRAM, 1)));
    }
    stream.push(Instruction::Finish(no_deps()));
    let stats = s.run(&stream).unwrap();
    assert_eq!(stats.insn_load, 33);
    assert!(stats.fetch_stall_cycles > 0, "expected fetch stalls with depth-2 queue");
}
