//! Simulator error types. The hardware would hang or corrupt state;
//! the simulator turns every such condition into a typed error.

use crate::isa::{BufferId, IsaError};
use thiserror::Error;

/// Errors raised during simulation.
#[derive(Debug, Error)]
pub enum SimError {
    #[error("DRAM access out of bounds: addr={addr:#x} len={len} size={size:#x}")]
    DramOutOfBounds { addr: usize, len: usize, size: usize },

    #[error("{buffer:?} SRAM access out of bounds: tile {tile} + {count} > depth {depth}")]
    SramOutOfBounds { buffer: BufferId, tile: usize, count: usize, depth: usize },

    #[error("micro-op cache access out of bounds: uop {index} >= depth {depth}")]
    UopOutOfBounds { index: usize, depth: usize },

    #[error("illegal instruction routed to {module}: {detail}")]
    IllegalInstruction { module: &'static str, detail: String },

    #[error(
        "dependence deadlock after {executed} instructions: \
         load@{load_pc} compute@{compute_pc} store@{store_pc} \
         (pending tokens: l2c={l2c} c2l={c2l} c2s={c2s} s2c={s2c})"
    )]
    Deadlock {
        executed: usize,
        load_pc: usize,
        compute_pc: usize,
        store_pc: usize,
        l2c: usize,
        c2l: usize,
        c2s: usize,
        s2c: usize,
    },

    #[error("instruction stream has no FINISH sentinel")]
    MissingFinish,

    #[error("ISA error: {0}")]
    Isa(#[from] IsaError),
}
