//! Functional semantics of the compute core (§2.5): the GEMM core and
//! the tensor ALU, executing micro-op sequences inside the two-level
//! nested loop with affine index generation (Figs 7–8).

use super::dma::SramState;
use super::SimError;
use crate::arch::VtaConfig;
use crate::isa::{AluInsn, BufferId, GemmInsn, Uop};

/// Index ranges touched by a GEMM/ALU instruction, used both for bounds
/// hoisting (the hot loop runs unchecked) and hazard tracking.
pub struct TouchedRanges {
    pub acc_lo: usize,
    pub acc_hi: usize, // inclusive
    pub src_lo: usize,
    pub src_hi: usize,
    pub wgt_lo: usize,
    pub wgt_hi: usize,
}

fn affine_range(
    base_lo: usize,
    base_hi: usize,
    f0: usize,
    lp0: usize,
    f1: usize,
    lp1: usize,
) -> (usize, usize) {
    let lo = base_lo;
    let hi = base_hi + f0 * lp0.saturating_sub(1) + f1 * lp1.saturating_sub(1);
    (lo, hi)
}

/// Execute a GEMM instruction: for every (i0, i1, uop), one
/// `acc[dst] += inp[src] x wgt[w]^T` tile operation — or a tile reset
/// when `insn.reset` (Fig 7). Every accumulator write is mirrored,
/// narrowed to the output element type, into the output buffer (§2.5:
/// "as new results are being written to the register file, they
/// concurrently get flushed to the output buffer").
///
/// Returns the ranges touched (for hazard tracking).
pub fn exec_gemm(
    cfg: &VtaConfig,
    insn: &GemmInsn,
    sram: &mut SramState,
) -> Result<TouchedRanges, SimError> {
    let n_uops = insn.uop_end.saturating_sub(insn.uop_begin) as usize;
    let (lp0, lp1) = (insn.lp0 as usize, insn.lp1 as usize);

    // Hoisted bounds check: compute the min/max base indices over the
    // micro-op range once, then validate the affine extremes.
    if insn.uop_end as usize > sram.uop.len() {
        return Err(SimError::UopOutOfBounds { index: insn.uop_end as usize, depth: sram.uop.len() });
    }
    let (mut acc_lo, mut acc_hi) = (usize::MAX, 0usize);
    let (mut inp_lo, mut inp_hi) = (usize::MAX, 0usize);
    let (mut wgt_lo, mut wgt_hi) = (usize::MAX, 0usize);
    for w in &sram.uop[insn.uop_begin as usize..insn.uop_end as usize] {
        let u = Uop::decode_gemm(*w);
        acc_lo = acc_lo.min(u.acc_idx as usize);
        acc_hi = acc_hi.max(u.acc_idx as usize);
        inp_lo = inp_lo.min(u.inp_idx as usize);
        inp_hi = inp_hi.max(u.inp_idx as usize);
        wgt_lo = wgt_lo.min(u.wgt_idx as usize);
        wgt_hi = wgt_hi.max(u.wgt_idx as usize);
    }
    if n_uops == 0 || lp0 == 0 || lp1 == 0 {
        return Ok(TouchedRanges { acc_lo: 0, acc_hi: 0, src_lo: 0, src_hi: 0, wgt_lo: 0, wgt_hi: 0 });
    }
    let (acc_lo, acc_hi) =
        affine_range(acc_lo, acc_hi, insn.acc_factor0 as usize, lp0, insn.acc_factor1 as usize, lp1);
    let (inp_lo, inp_hi) =
        affine_range(inp_lo, inp_hi, insn.inp_factor0 as usize, lp0, insn.inp_factor1 as usize, lp1);
    let (wgt_lo, wgt_hi) =
        affine_range(wgt_lo, wgt_hi, insn.wgt_factor0 as usize, lp0, insn.wgt_factor1 as usize, lp1);

    let acc_depth = sram.depth(BufferId::Acc);
    let inp_depth = sram.depth(BufferId::Inp);
    let wgt_depth = sram.depth(BufferId::Wgt);
    if acc_hi >= acc_depth {
        return Err(SimError::SramOutOfBounds { buffer: BufferId::Acc, tile: acc_hi, count: 1, depth: acc_depth });
    }
    if !insn.reset {
        if inp_hi >= inp_depth {
            return Err(SimError::SramOutOfBounds { buffer: BufferId::Inp, tile: inp_hi, count: 1, depth: inp_depth });
        }
        if wgt_hi >= wgt_depth {
            return Err(SimError::SramOutOfBounds { buffer: BufferId::Wgt, tile: wgt_hi, count: 1, depth: wgt_depth });
        }
    }

    let batch = cfg.gemm.batch;
    let block_in = cfg.gemm.block_in;
    let block_out = cfg.gemm.block_out;
    let acc_tile = sram.acc_tile;
    let inp_tile = sram.inp_tile;
    let wgt_tile = sram.wgt_tile;

    // Decode the micro-op kernel once, outside the loop nest.
    let uops: Vec<crate::isa::GemmUop> = sram.uop
        [insn.uop_begin as usize..insn.uop_end as usize]
        .iter()
        .map(|w| Uop::decode_gemm(*w))
        .collect();

    // Hot loop. Bounds were hoisted and validated above (the affine
    // extremes of every index are in range), so the inner loops use
    // unchecked accesses — this is the simulator's dominant cost on
    // real workloads (ResNet-18 executes ~1.8 G MACs here).
    let inp_ptr = sram.inp.as_ptr();
    let wgt_ptr = sram.wgt.as_ptr();
    let acc_ptr = sram.acc.as_mut_ptr();
    let out_ptr = sram.out.as_mut_ptr();
    for i0 in 0..lp0 {
        let acc_o = i0 * insn.acc_factor0 as usize;
        let inp_o = i0 * insn.inp_factor0 as usize;
        let wgt_o = i0 * insn.wgt_factor0 as usize;
        for i1 in 0..lp1 {
            let acc_oo = acc_o + i1 * insn.acc_factor1 as usize;
            let inp_oo = inp_o + i1 * insn.inp_factor1 as usize;
            let wgt_oo = wgt_o + i1 * insn.wgt_factor1 as usize;
            for u in &uops {
                let dst = (u.acc_idx as usize + acc_oo) * acc_tile;
                if insn.reset {
                    sram.acc[dst..dst + acc_tile].fill(0);
                    sram.out[dst..dst + acc_tile].fill(0);
                    continue;
                }
                let src = (u.inp_idx as usize + inp_oo) * inp_tile;
                let wgt = (u.wgt_idx as usize + wgt_oo) * wgt_tile;
                // One tile matmul: acc[b][o] += sum_k inp[b][k] * wgt[o][k]
                unsafe {
                    for b in 0..batch {
                        let a = std::slice::from_raw_parts(inp_ptr.add(src + b * block_in), block_in);
                        for o in 0..block_out {
                            let w = std::slice::from_raw_parts(
                                wgt_ptr.add(wgt + o * block_in),
                                block_in,
                            );
                            let mut sum = 0i32;
                            for kk in 0..block_in {
                                sum += *a.get_unchecked(kk) as i32 * *w.get_unchecked(kk) as i32;
                            }
                            let acc_cell = acc_ptr.add(dst + b * block_out + o);
                            *acc_cell = (*acc_cell).wrapping_add(sum);
                        }
                    }
                    // Mirror narrowed results into the output buffer.
                    for e in 0..acc_tile {
                        *out_ptr.add(dst + e) = *acc_ptr.add(dst + e) as i8;
                    }
                }
            }
        }
    }

    Ok(TouchedRanges { acc_lo, acc_hi, src_lo: inp_lo, src_hi: inp_hi, wgt_lo, wgt_hi })
}

/// Execute an ALU instruction: element-wise tensor-tensor or
/// tensor-scalar operations over register-file tiles (Fig 8).
pub fn exec_alu(
    _cfg: &VtaConfig,
    insn: &AluInsn,
    sram: &mut SramState,
) -> Result<TouchedRanges, SimError> {
    let n_uops = insn.uop_end.saturating_sub(insn.uop_begin) as usize;
    let (lp0, lp1) = (insn.lp0 as usize, insn.lp1 as usize);
    if insn.uop_end as usize > sram.uop.len() {
        return Err(SimError::UopOutOfBounds { index: insn.uop_end as usize, depth: sram.uop.len() });
    }
    if n_uops == 0 || lp0 == 0 || lp1 == 0 {
        return Ok(TouchedRanges { acc_lo: 0, acc_hi: 0, src_lo: 0, src_hi: 0, wgt_lo: 0, wgt_hi: 0 });
    }

    let (mut dst_lo, mut dst_hi) = (usize::MAX, 0usize);
    let (mut src_lo, mut src_hi) = (usize::MAX, 0usize);
    let uops: Vec<crate::isa::AluUop> = sram.uop
        [insn.uop_begin as usize..insn.uop_end as usize]
        .iter()
        .map(|w| Uop::decode_alu(*w))
        .collect();
    for u in &uops {
        dst_lo = dst_lo.min(u.dst_idx as usize);
        dst_hi = dst_hi.max(u.dst_idx as usize);
        src_lo = src_lo.min(u.src_idx as usize);
        src_hi = src_hi.max(u.src_idx as usize);
    }
    let (dst_lo, dst_hi) =
        affine_range(dst_lo, dst_hi, insn.dst_factor0 as usize, lp0, insn.dst_factor1 as usize, lp1);
    let (src_lo, src_hi) =
        affine_range(src_lo, src_hi, insn.src_factor0 as usize, lp0, insn.src_factor1 as usize, lp1);

    let acc_depth = sram.depth(BufferId::Acc);
    if dst_hi >= acc_depth {
        return Err(SimError::SramOutOfBounds { buffer: BufferId::Acc, tile: dst_hi, count: 1, depth: acc_depth });
    }
    if !insn.use_imm && src_hi >= acc_depth {
        return Err(SimError::SramOutOfBounds { buffer: BufferId::Acc, tile: src_hi, count: 1, depth: acc_depth });
    }

    let acc_tile = sram.acc_tile;
    let imm = insn.imm as i32;
    for i0 in 0..lp0 {
        let dst_o = i0 * insn.dst_factor0 as usize;
        let src_o = i0 * insn.src_factor0 as usize;
        for i1 in 0..lp1 {
            let dst_oo = dst_o + i1 * insn.dst_factor1 as usize;
            let src_oo = src_o + i1 * insn.src_factor1 as usize;
            for u in &uops {
                let dst = (u.dst_idx as usize + dst_oo) * acc_tile;
                if insn.use_imm {
                    for e in 0..acc_tile {
                        let v = insn.op.apply(sram.acc[dst + e], imm);
                        sram.acc[dst + e] = v;
                        sram.out[dst + e] = v as i8;
                    }
                } else {
                    let src = (u.src_idx as usize + src_oo) * acc_tile;
                    for e in 0..acc_tile {
                        let v = insn.op.apply(sram.acc[dst + e], sram.acc[src + e]);
                        sram.acc[dst + e] = v;
                        sram.out[dst + e] = v as i8;
                    }
                }
            }
        }
    }

    Ok(TouchedRanges { acc_lo: dst_lo, acc_hi: dst_hi, src_lo, src_hi, wgt_lo: 0, wgt_hi: 0 })
}
