//! Cycle-approximate, functionally bit-exact behavioral simulator of the
//! VTA hardware (§2, Figs 2–9).
//!
//! Four modules — `fetch`, `load`, `compute`, `store` — execute a linear
//! CISC instruction stream as a dataflow pipeline synchronized by
//! dependence-token FIFOs (§2.3). The simulator is a discrete-event
//! model at CISC-instruction granularity:
//!
//! * **Functional semantics** are exact: int8 x int8 → int32 GEMM tiles,
//!   tensor-ALU ops, 2D strided DMA with on-the-fly padding.
//! * **Timing** follows the micro-architecture: one GEMM micro-op per
//!   cycle (Fig 7), tensor-ALU initiation interval ≥ 2 (§2.5), a shared
//!   DRAM port with fixed latency + occupancy (§2.6), finite command
//!   queues with fetch back-pressure (§2.4), and dependence tokens that
//!   gate module start times (Fig 6).
//!
//! A [`hazard::HazardTracker`] can flag RAW/WAR races in streams whose
//! dependence flags were deliberately omitted — reproducing the Fig 5
//! erroneous-execution scenarios as a checkable property.

mod compute;
mod dma;
mod dram;
mod engine;
mod error;
mod hazard;
mod stats;

pub use dram::Dram;
pub use engine::{ExecMode, Simulator};
pub use error::SimError;
pub use hazard::{Hazard, HazardKind, Module as HazardModule};
pub use stats::SimStats;

#[cfg(test)]
mod tests;
