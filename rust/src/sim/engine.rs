//! The discrete-event engine: four modules executing concurrently as a
//! dataflow pipeline synchronized by dependence-token FIFOs (§2.3–2.4,
//! Figs 4–6).
//!
//! Every candidate action (fetch a burst, route an instruction, execute
//! a module's next command) is given a feasible start time; the engine
//! repeatedly executes the earliest. Timing rules are documented in
//! DESIGN.md §6.

use super::compute::{exec_alu, exec_gemm};
use super::dma::{exec_load, exec_store, SramState};
use super::hazard::{HazardTracker, Module};
use super::{Dram, Hazard, SimError, SimStats};
use crate::arch::VtaConfig;
use crate::isa::{BufferId, Instruction};

/// Fixed pipeline fill/drain overhead charged per compute instruction.
const COMPUTE_OVERHEAD: u64 = 4;
/// Instructions fetched per DRAM burst by the fetch module.
const FETCH_BURST: usize = 32;
/// Decode/route cost per instruction (cycles).
const DECODE_COST: u64 = 1;

/// Execution-mode switches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Normal execution: trust the dependence flags.
    Normal,
    /// Track per-tile access intervals and record RAW/WAR races
    /// (reproduces Fig 5's erroneous-execution scenarios).
    CheckHazards,
}

/// One entry in a module's command queue.
struct Cmd {
    insn: Instruction,
    /// Time the fetch module pushed it.
    push_time: u64,
    /// Time the consuming module started it (fills in as it executes;
    /// used to model queue-slot back-pressure on fetch).
    start_time: Option<u64>,
}

/// A dependence-token FIFO; tokens are information-less (§2.3) so only
/// their push timestamps are stored.
#[derive(Default)]
struct TokenQueue {
    push_times: Vec<u64>,
    popped: usize,
    max_occupancy: usize,
}

impl TokenQueue {
    fn push(&mut self, t: u64) {
        self.push_times.push(t);
        self.max_occupancy = self.max_occupancy.max(self.push_times.len() - self.popped);
    }

    /// Time the next unpopped token becomes available, or None if the
    /// producer has not pushed it yet.
    fn peek(&self) -> Option<u64> {
        self.push_times.get(self.popped).copied()
    }

    fn pop(&mut self) -> u64 {
        let t = self.push_times[self.popped];
        self.popped += 1;
        t
    }

    fn pending(&self) -> usize {
        self.push_times.len() - self.popped
    }
}

/// Identifiers for the three execution modules (fetch is handled
/// separately).
#[derive(Clone, Copy, PartialEq, Eq)]
enum ModId {
    Load = 0,
    Compute = 1,
    Store = 2,
}

/// The VTA behavioral simulator.
///
/// Holds the DRAM image and the on-chip state; [`Simulator::run`]
/// executes one instruction stream to the FINISH sentinel and returns
/// the cycle-level statistics.
pub struct Simulator {
    cfg: VtaConfig,
    pub dram: Dram,
    sram: SramState,
    mode: ExecMode,
    hazards: Vec<Hazard>,
}

impl Simulator {
    /// Create a simulator with `dram_size` bytes of DRAM.
    pub fn new(cfg: VtaConfig, dram_size: usize) -> Self {
        let sram = SramState::new(&cfg);
        Simulator { cfg, dram: Dram::new(dram_size), sram, mode: ExecMode::Normal, hazards: Vec::new() }
    }

    /// Switch execution mode (hazard checking costs time and memory).
    pub fn set_mode(&mut self, mode: ExecMode) {
        self.mode = mode;
    }

    /// Architecture configuration.
    pub fn config(&self) -> &VtaConfig {
        &self.cfg
    }

    /// Hazards recorded by the last `CheckHazards` run.
    pub fn hazards(&self) -> &[Hazard] {
        &self.hazards
    }

    /// Reset on-chip state (SRAMs) without touching DRAM.
    pub fn reset_sram(&mut self) {
        self.sram = SramState::new(&self.cfg);
    }

    /// Execute an instruction stream until FINISH; returns statistics.
    ///
    /// The stream must contain exactly one FINISH sentinel as its last
    /// instruction (the runtime's `synchronize()` guarantees this).
    pub fn run(&mut self, insns: &[Instruction]) -> Result<SimStats, SimError> {
        match insns.last() {
            Some(Instruction::Finish(_)) => {}
            _ => return Err(SimError::MissingFinish),
        }

        let mut stats = SimStats::default();
        let mut tracker = HazardTracker::new(
            self.mode == ExecMode::CheckHazards,
            [
                self.sram.depth(BufferId::Uop),
                self.sram.depth(BufferId::Wgt),
                self.sram.depth(BufferId::Inp),
                self.sram.depth(BufferId::Acc),
                self.sram.depth(BufferId::Out),
            ],
        );

        // Dependence-token queues (Fig 6): indices into `tokens`:
        // 0 = load→compute RAW, 1 = compute→load WAR,
        // 2 = compute→store RAW, 3 = store→compute WAR.
        let mut tokens: [TokenQueue; 4] = Default::default();
        const L2C: usize = 0;
        const C2L: usize = 1;
        const C2S: usize = 2;
        const S2C: usize = 3;

        // Command queues.
        let mut queues: [Vec<Cmd>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let mut pcs = [0usize; 3]; // per-module next-command index
        let mut free = [0u64; 3]; // per-module next-free time

        // Fetch state.
        let mut fetch_next = 0usize; // next instruction to route
        let mut fetch_free = 0u64;
        let mut burst_avail: Vec<u64> = Vec::new(); // per-burst availability time
        let insn_bytes = crate::isa::INSN_BYTES;

        // Shared DRAM port.
        let mut port_free = 0u64;

        let mut executed = 0usize;
        let mut done_time: Option<u64> = None;

        loop {
            // ---------------- candidate generation ----------------
            // (action, t_start); action: 0 = fetch burst, 1 = route,
            // 2..=4 = execute module (ModId = action - 2).
            let mut best: Option<(usize, u64)> = None;
            let mut consider = |action: usize, t: u64| {
                if best.map_or(true, |(_, bt)| t < bt) {
                    best = Some((action, t));
                }
            };

            if fetch_next < insns.len() {
                let burst = fetch_next / FETCH_BURST;
                if burst >= burst_avail.len() {
                    // Need to fetch this burst from DRAM first.
                    consider(0, fetch_free.max(port_free));
                } else {
                    // Route the next instruction, if its queue has room.
                    let q = route(&insns[fetch_next]).ok_or_else(|| SimError::IllegalInstruction {
                        module: "fetch",
                        detail: format!("unroutable instruction {:?}", insns[fetch_next]),
                    })?;
                    let qi = q as usize;
                    let n = queues[qi].len();
                    let slot_free = if n < self.cfg.cmd_queue_depth {
                        Some(0u64)
                    } else {
                        // The slot frees when the consumer *starts* the
                        // (n - depth)-th entry of this queue.
                        queues[qi][n - self.cfg.cmd_queue_depth].start_time
                    };
                    if let Some(sf) = slot_free {
                        let ready = fetch_free.max(burst_avail[burst]).max(sf);
                        consider(1, ready);
                    }
                }
            }

            for (mi, m) in [ModId::Load, ModId::Compute, ModId::Store].into_iter().enumerate() {
                let pc = pcs[mi];
                if pc >= queues[mi].len() {
                    continue;
                }
                let cmd = &queues[mi][pc];
                let deps = cmd.insn.deps();
                // Which token queues does this module pop from?
                let (pop_prev_q, pop_next_q) = match m {
                    ModId::Load => (None, Some(C2L)),
                    ModId::Compute => (Some(L2C), Some(S2C)),
                    ModId::Store => (Some(C2S), None),
                };
                let mut t = free[mi].max(cmd.push_time);
                let mut feasible = true;
                if deps.pop_prev {
                    match pop_prev_q.and_then(|q| tokens[q].peek()) {
                        Some(tt) => t = t.max(tt),
                        None => feasible = false,
                    }
                }
                if deps.pop_next {
                    match pop_next_q.and_then(|q| tokens[q].peek()) {
                        Some(tt) => t = t.max(tt),
                        None => feasible = false,
                    }
                }
                // DMA instructions contend for the shared DRAM port.
                if feasible {
                    if is_dma(&cmd.insn) {
                        t = t.max(port_free);
                    }
                    consider(2 + mi, t);
                }
            }

            // ---------------- dispatch ----------------
            let Some((action, t_start)) = best else {
                let all_drained = fetch_next >= insns.len()
                    && (0..3).all(|mi| pcs[mi] >= queues[mi].len());
                if done_time.is_some() && all_drained {
                    break;
                }
                return Err(SimError::Deadlock {
                    executed,
                    load_pc: pcs[0],
                    compute_pc: pcs[1],
                    store_pc: pcs[2],
                    l2c: tokens[L2C].pending(),
                    c2l: tokens[C2L].pending(),
                    c2s: tokens[C2S].pending(),
                    s2c: tokens[S2C].pending(),
                });
            };

            match action {
                0 => {
                    // Fetch one burst of instructions over the DRAM port.
                    let burst = burst_avail.len();
                    let first = burst * FETCH_BURST;
                    let count = FETCH_BURST.min(insns.len() - first);
                    let bytes = count * insn_bytes;
                    let occ = self.cfg.dram.occupancy(bytes);
                    let t_done = t_start + self.cfg.dram.latency + occ;
                    port_free = t_start + occ;
                    stats.dram_busy_cycles += occ;
                    burst_avail.push(t_done);
                    fetch_free = t_start; // fetch itself only waited for the port
                }
                1 => {
                    // Route one instruction into its command queue.
                    let insn = insns[fetch_next];
                    let q = route(&insn).unwrap() as usize;
                    let t_done = t_start + DECODE_COST;
                    // Stall accounting: time spent waiting on a full queue.
                    let burst = fetch_next / FETCH_BURST;
                    let unblocked = fetch_free.max(burst_avail[burst]);
                    stats.fetch_stall_cycles += t_start.saturating_sub(unblocked);
                    queues[q].push(Cmd { insn, push_time: t_done, start_time: None });
                    fetch_next += 1;
                    fetch_free = t_done;
                }
                mi2 => {
                    let mi = mi2 - 2;
                    let m = [ModId::Load, ModId::Compute, ModId::Store][mi];
                    let pc = pcs[mi];
                    let insn = queues[mi][pc].insn;
                    let deps = insn.deps();

                    // Pop incoming tokens.
                    match m {
                        ModId::Load => {
                            if deps.pop_next {
                                tokens[C2L].pop();
                            }
                        }
                        ModId::Compute => {
                            if deps.pop_prev {
                                tokens[L2C].pop();
                            }
                            if deps.pop_next {
                                tokens[S2C].pop();
                            }
                        }
                        ModId::Store => {
                            if deps.pop_prev {
                                tokens[C2S].pop();
                            }
                        }
                    }

                    // Execute functionally + compute duration.
                    let duration = self.execute(m, &insn, t_start, &mut stats, &mut tracker)?;
                    let t_finish = t_start + duration;
                    if is_dma(&insn) {
                        // DMA occupies the shared port for its occupancy
                        // portion (latency overlaps with other traffic).
                        let occ = duration.saturating_sub(self.cfg.dram.latency);
                        port_free = t_start + occ;
                        stats.dram_busy_cycles += occ;
                    }

                    // Push outgoing tokens at finish time.
                    match m {
                        ModId::Load => {
                            if deps.push_next {
                                tokens[L2C].push(t_finish);
                                stats.tokens_pushed[L2C] += 1;
                            }
                        }
                        ModId::Compute => {
                            if deps.push_prev {
                                tokens[C2L].push(t_finish);
                                stats.tokens_pushed[C2L] += 1;
                            }
                            if deps.push_next {
                                tokens[C2S].push(t_finish);
                                stats.tokens_pushed[C2S] += 1;
                            }
                        }
                        ModId::Store => {
                            if deps.push_prev {
                                tokens[S2C].push(t_finish);
                                stats.tokens_pushed[S2C] += 1;
                            }
                        }
                    }

                    queues[mi][pc].start_time = Some(t_start);
                    pcs[mi] += 1;
                    free[mi] = t_finish;
                    executed += 1;
                    if matches!(insn, Instruction::Finish(_)) {
                        done_time = Some(t_finish);
                    }
                }
            }

            if done_time.is_some() && fetch_next >= insns.len() {
                // All instructions routed and FINISH retired; remaining
                // modules may still have queued work only if the stream
                // was malformed — check all PCs drained.
                let all_drained =
                    (0..3).all(|mi| pcs[mi] >= queues[mi].len());
                if all_drained {
                    break;
                }
            }
        }

        stats.total_cycles = done_time.unwrap_or(0).max(free[0]).max(free[1]).max(free[2]);
        self.hazards = tracker_into_hazards(tracker);
        Ok(stats)
    }

    /// Functionally execute one instruction on module `m` and return its
    /// duration in cycles.
    fn execute(
        &mut self,
        m: ModId,
        insn: &Instruction,
        t_start: u64,
        stats: &mut SimStats,
        tracker: &mut HazardTracker,
    ) -> Result<u64, SimError> {
        let hmod = match m {
            ModId::Load => Module::Load,
            ModId::Compute => Module::Compute,
            ModId::Store => Module::Store,
        };
        match insn {
            Instruction::Load(mem) => {
                let bytes = exec_load(&self.cfg, mem, &self.dram, &mut self.sram)?;
                stats.insn_load += 1;
                stats.bytes_loaded += bytes;
                let occ = self.cfg.dram.occupancy(bytes as usize);
                let duration = self.cfg.dram.latency + occ.max(1);
                match m {
                    ModId::Load => stats.load_busy_cycles += duration,
                    _ => {}
                }
                tracker.write(
                    hmod,
                    mem.buffer,
                    mem.sram_base as usize,
                    mem.sram_tiles(),
                    t_start,
                    t_start + duration,
                );
                Ok(duration)
            }
            Instruction::Store(mem) => {
                let bytes = exec_store(&self.cfg, mem, &mut self.dram, &self.sram)?;
                stats.insn_store += 1;
                stats.bytes_stored += bytes;
                let occ = self.cfg.dram.occupancy(bytes as usize);
                let duration = self.cfg.dram.latency + occ.max(1);
                stats.store_busy_cycles += duration;
                tracker.read(
                    hmod,
                    BufferId::Out,
                    mem.sram_base as usize,
                    mem.dram_tiles(),
                    t_start,
                    t_start + duration,
                );
                Ok(duration)
            }
            Instruction::Gemm(g) => {
                let ranges = exec_gemm(&self.cfg, g, &mut self.sram)?;
                let uops = g.uop_executions();
                stats.insn_gemm += 1;
                stats.gemm_uops += uops;
                stats.gemm_busy_cycles += uops;
                let duration = uops + COMPUTE_OVERHEAD;
                let t_end = t_start + duration;
                if !g.reset {
                    tracker.read(hmod, BufferId::Inp, ranges.src_lo, ranges.src_hi - ranges.src_lo + 1, t_start, t_end);
                    tracker.read(hmod, BufferId::Wgt, ranges.wgt_lo, ranges.wgt_hi - ranges.wgt_lo + 1, t_start, t_end);
                }
                tracker.write(hmod, BufferId::Acc, ranges.acc_lo, ranges.acc_hi - ranges.acc_lo + 1, t_start, t_end);
                tracker.write(hmod, BufferId::Out, ranges.acc_lo, ranges.acc_hi - ranges.acc_lo + 1, t_start, t_end);
                Ok(duration)
            }
            Instruction::Alu(a) => {
                let ranges = exec_alu(&self.cfg, a, &mut self.sram)?;
                let uops = a.uop_executions();
                stats.insn_alu += 1;
                stats.alu_uops += uops;
                // §2.5: II >= 2 and wide tensors are processed as
                // multi-cycle vector ops.
                let vec_factor =
                    (self.cfg.gemm.batch * self.cfg.gemm.block_out).div_ceil(self.cfg.alu_lanes)
                        as u64;
                let cycles = uops * self.cfg.alu_ii * vec_factor;
                stats.alu_busy_cycles += cycles;
                let duration = cycles + COMPUTE_OVERHEAD;
                let t_end = t_start + duration;
                if !a.use_imm {
                    tracker.read(hmod, BufferId::Acc, ranges.src_lo, ranges.src_hi - ranges.src_lo + 1, t_start, t_end);
                }
                tracker.write(hmod, BufferId::Acc, ranges.acc_lo, ranges.acc_hi - ranges.acc_lo + 1, t_start, t_end);
                tracker.write(hmod, BufferId::Out, ranges.acc_lo, ranges.acc_hi - ranges.acc_lo + 1, t_start, t_end);
                Ok(duration)
            }
            Instruction::Finish(_) => Ok(1),
        }
    }
}

/// Fetch-module routing rules (§2.4).
fn route(insn: &Instruction) -> Option<ModId> {
    match insn {
        Instruction::Load(m) => match m.buffer {
            BufferId::Inp | BufferId::Wgt => Some(ModId::Load),
            BufferId::Uop | BufferId::Acc => Some(ModId::Compute),
            BufferId::Out => None,
        },
        Instruction::Store(_) => Some(ModId::Store),
        Instruction::Gemm(_) | Instruction::Alu(_) | Instruction::Finish(_) => Some(ModId::Compute),
    }
}

fn is_dma(insn: &Instruction) -> bool {
    matches!(insn, Instruction::Load(_) | Instruction::Store(_))
}

fn tracker_into_hazards(tracker: HazardTracker) -> Vec<Hazard> {
    tracker.hazards().to_vec()
}
