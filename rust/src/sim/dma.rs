//! Functional semantics of the on-chip SRAM state and the 2D strided
//! DMA performed by LOAD/STORE instructions (Fig 9), including the
//! dynamic padding the load module inserts on the fly.

use super::{Dram, SimError};
use crate::arch::VtaConfig;
use crate::isa::{BufferId, MemInsn};

/// All data-specialized SRAMs of one VTA instance (§2.6), as flat
/// tile-major vectors.
pub struct SramState {
    /// Input buffer: `inp_depth` tiles of `batch x block_in` i8.
    pub inp: Vec<i8>,
    /// Weight buffer: `wgt_depth` tiles of `block_out x block_in` i8.
    pub wgt: Vec<i8>,
    /// Register file: `acc_depth` tiles of `batch x block_out` i32.
    pub acc: Vec<i32>,
    /// Output buffer: `out_depth` tiles of `batch x block_out` i8
    /// (narrowed copies of register-file writes, §2.5).
    pub out: Vec<i8>,
    /// Micro-op cache: `uop_depth` 32-bit micro-ops.
    pub uop: Vec<u32>,
    /// Elements per tile, cached from the config.
    pub inp_tile: usize,
    pub wgt_tile: usize,
    pub acc_tile: usize,
}

impl SramState {
    /// Allocate SRAMs per the architecture config.
    pub fn new(cfg: &VtaConfig) -> Self {
        let inp_tile = cfg.gemm.batch * cfg.gemm.block_in;
        let wgt_tile = cfg.gemm.block_out * cfg.gemm.block_in;
        let acc_tile = cfg.gemm.batch * cfg.gemm.block_out;
        SramState {
            inp: vec![0; cfg.inp_depth() * inp_tile],
            wgt: vec![0; cfg.wgt_depth() * wgt_tile],
            acc: vec![0; cfg.acc_depth() * acc_tile],
            out: vec![0; cfg.out_depth() * acc_tile],
            uop: vec![0; cfg.uop_depth()],
            inp_tile,
            wgt_tile,
            acc_tile,
        }
    }

    /// Tile depth of a buffer.
    pub fn depth(&self, buffer: BufferId) -> usize {
        match buffer {
            BufferId::Inp => self.inp.len() / self.inp_tile,
            BufferId::Wgt => self.wgt.len() / self.wgt_tile,
            BufferId::Acc => self.acc.len() / self.acc_tile,
            BufferId::Out => self.out.len() / self.acc_tile,
            BufferId::Uop => self.uop.len(),
        }
    }
}

fn check_sram(buffer: BufferId, base: usize, count: usize, depth: usize) -> Result<(), SimError> {
    if base.checked_add(count).map_or(true, |end| end > depth) {
        return Err(SimError::SramOutOfBounds { buffer, tile: base, count, depth });
    }
    Ok(())
}

/// Execute a LOAD instruction's data movement: a 2D strided DMA read
/// from DRAM with zero-padding inserted around the payload (Fig 9).
///
/// Returns the number of bytes that crossed the DRAM port (padding is
/// generated on-chip and is free).
pub fn exec_load(
    cfg: &VtaConfig,
    insn: &MemInsn,
    dram: &Dram,
    sram: &mut SramState,
) -> Result<u64, SimError> {
    let (elem_bytes, tile_elems): (usize, usize) = match insn.buffer {
        BufferId::Inp => (1, sram.inp_tile),
        BufferId::Wgt => (1, sram.wgt_tile),
        BufferId::Acc => (4, sram.acc_tile),
        BufferId::Uop => (4, 1),
        BufferId::Out => {
            return Err(SimError::IllegalInstruction {
                module: "load",
                detail: "LOAD targeting the output buffer".into(),
            })
        }
    };
    let tile_bytes = tile_elems * elem_bytes;
    let depth = sram.depth(insn.buffer);
    check_sram(insn.buffer, insn.sram_base as usize, insn.sram_tiles(), depth)?;

    let row_tiles = insn.sram_row_tiles();
    let mut dst_tile = insn.sram_base as usize;
    let mut moved = 0u64;

    // Leading pad rows.
    for _ in 0..insn.y_pad_top {
        fill_zero(sram, insn.buffer, dst_tile, row_tiles, tile_elems);
        dst_tile += row_tiles;
    }
    // Payload rows with left/right pad.
    for y in 0..insn.y_size as usize {
        fill_zero(sram, insn.buffer, dst_tile, insn.x_pad_left as usize, tile_elems);
        dst_tile += insn.x_pad_left as usize;

        let dram_tile = insn.dram_base as usize + y * insn.x_stride as usize;
        let dram_addr = dram_tile * tile_bytes;
        let n_tiles = insn.x_size as usize;
        copy_in(sram, insn.buffer, dst_tile, dram, dram_addr, n_tiles, tile_elems)?;
        dst_tile += n_tiles;
        moved += (n_tiles * tile_bytes) as u64;

        fill_zero(sram, insn.buffer, dst_tile, insn.x_pad_right as usize, tile_elems);
        dst_tile += insn.x_pad_right as usize;
    }
    // Trailing pad rows.
    for _ in 0..insn.y_pad_bottom {
        fill_zero(sram, insn.buffer, dst_tile, row_tiles, tile_elems);
        dst_tile += row_tiles;
    }
    let _ = cfg;
    Ok(moved)
}

/// Execute a STORE instruction: 2D strided DMA write of output-buffer
/// tiles to DRAM. Padding fields are ignored (stores never pad).
///
/// Returns bytes moved across the DRAM port.
pub fn exec_store(
    cfg: &VtaConfig,
    insn: &MemInsn,
    dram: &mut Dram,
    sram: &SramState,
) -> Result<u64, SimError> {
    if insn.buffer != BufferId::Out {
        return Err(SimError::IllegalInstruction {
            module: "store",
            detail: format!("STORE from {:?} (only the output buffer is drainable)", insn.buffer),
        });
    }
    let tile_elems = sram.acc_tile;
    let tile_bytes = tile_elems * cfg.out_bits / 8;
    let total_tiles = insn.y_size as usize * insn.x_size as usize;
    check_sram(BufferId::Out, insn.sram_base as usize, total_tiles, sram.depth(BufferId::Out))?;

    let mut src_tile = insn.sram_base as usize;
    let mut moved = 0u64;
    for y in 0..insn.y_size as usize {
        let dram_tile = insn.dram_base as usize + y * insn.x_stride as usize;
        let dram_addr = dram_tile * tile_bytes;
        let n = insn.x_size as usize * tile_elems;
        dram.write_i8(dram_addr, &sram.out[src_tile * tile_elems..src_tile * tile_elems + n])?;
        src_tile += insn.x_size as usize;
        moved += (insn.x_size as usize * tile_bytes) as u64;
    }
    Ok(moved)
}

fn fill_zero(sram: &mut SramState, buffer: BufferId, tile: usize, tiles: usize, tile_elems: usize) {
    if tiles == 0 {
        return;
    }
    match buffer {
        BufferId::Inp => sram.inp[tile * tile_elems..(tile + tiles) * tile_elems].fill(0),
        BufferId::Wgt => sram.wgt[tile * tile_elems..(tile + tiles) * tile_elems].fill(0),
        BufferId::Acc => sram.acc[tile * tile_elems..(tile + tiles) * tile_elems].fill(0),
        BufferId::Uop => sram.uop[tile..tile + tiles].fill(0),
        BufferId::Out => sram.out[tile * tile_elems..(tile + tiles) * tile_elems].fill(0),
    }
}

fn copy_in(
    sram: &mut SramState,
    buffer: BufferId,
    tile: usize,
    dram: &Dram,
    dram_addr: usize,
    tiles: usize,
    tile_elems: usize,
) -> Result<(), SimError> {
    if tiles == 0 {
        return Ok(());
    }
    let n = tiles * tile_elems;
    match buffer {
        BufferId::Inp => {
            let src = dram.read_i8(dram_addr, n)?;
            sram.inp[tile * tile_elems..tile * tile_elems + n].copy_from_slice(src);
        }
        BufferId::Wgt => {
            let src = dram.read_i8(dram_addr, n)?;
            sram.wgt[tile * tile_elems..tile * tile_elems + n].copy_from_slice(src);
        }
        BufferId::Acc => {
            let src = dram.read_i32(dram_addr, n)?;
            sram.acc[tile * tile_elems..tile * tile_elems + n].copy_from_slice(&src);
        }
        BufferId::Uop => {
            let src = dram.read_u32(dram_addr, n)?;
            sram.uop[tile..tile + n].copy_from_slice(&src);
        }
        BufferId::Out => unreachable!("checked by exec_load"),
    }
    Ok(())
}
