//! RAW/WAR hazard detection (§2.3, Fig 5).
//!
//! The hardware does *not* detect races — an instruction stream with
//! missing dependence flags silently corrupts SRAM. The simulator, in
//! `ExecMode::CheckHazards`, records the time interval during which each
//! instruction reads/writes each SRAM tile and flags overlapping
//! conflicting accesses from *different* hardware modules. Tests inject
//! streams with deliberately omitted flags and assert the tracker
//! reports exactly the Fig 5 scenarios.

use crate::isa::BufferId;

/// Which hardware module performed an access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Module {
    Load,
    Compute,
    Store,
}

/// Kind of detected race.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HazardKind {
    /// Consumer read overlapped (or preceded) the producer's write —
    /// missing RAW dependence.
    ReadBeforeWrite,
    /// Producer overwrote data while (or before) the consumer was still
    /// reading it — missing WAR dependence.
    WriteDuringRead,
    /// Two modules wrote the same tile concurrently.
    WriteDuringWrite,
}

/// A detected hazard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Hazard {
    pub kind: HazardKind,
    pub buffer: BufferId,
    pub tile: usize,
    /// The two conflicting accesses: (module, start, end).
    pub first: (Module, u64, u64),
    pub second: (Module, u64, u64),
}

#[derive(Clone, Copy, Debug)]
struct Access {
    module: Module,
    start: u64,
    end: u64,
}

/// Per-buffer, per-tile last-access bookkeeping.
pub struct HazardTracker {
    enabled: bool,
    last_write: Vec<Vec<Option<Access>>>,
    last_read: Vec<Vec<Option<Access>>>,
    hazards: Vec<Hazard>,
    /// Cap on recorded hazards to bound memory on badly broken streams.
    max_records: usize,
}

fn buf_index(buffer: BufferId) -> usize {
    buffer as usize
}

impl HazardTracker {
    /// `depths[b]` is the tile count of buffer `b` (indexed by
    /// `BufferId as usize`). Pass `enabled = false` for a zero-overhead
    /// no-op tracker.
    pub fn new(enabled: bool, depths: [usize; 5]) -> Self {
        let mk = |on: bool| -> Vec<Vec<Option<Access>>> {
            if on {
                depths.iter().map(|&d| vec![None; d]).collect()
            } else {
                Vec::new()
            }
        };
        HazardTracker {
            enabled,
            last_write: mk(enabled),
            last_read: mk(enabled),
            hazards: Vec::new(),
            max_records: 64,
        }
    }

    fn overlap(a: &Access, b: &Access) -> bool {
        // Two accesses conflict when their [start, end) intervals
        // intersect. Accesses by the same module are serialized by the
        // module's FIFO execution and never race.
        a.module != b.module && a.start < b.end && b.start < a.end
    }

    fn record(&mut self, h: Hazard) {
        if self.hazards.len() < self.max_records {
            self.hazards.push(h);
        }
    }

    /// Record a read of `tiles` tiles starting at `tile` in `buffer`
    /// during `[start, end)`.
    pub fn read(&mut self, module: Module, buffer: BufferId, tile: usize, tiles: usize, start: u64, end: u64) {
        if !self.enabled {
            return;
        }
        let b = buf_index(buffer);
        let acc = Access { module, start, end };
        for t in tile..(tile + tiles).min(self.last_write[b].len()) {
            if let Some(w) = self.last_write[b][t] {
                if Self::overlap(&w, &acc) {
                    self.record(Hazard {
                        kind: HazardKind::ReadBeforeWrite,
                        buffer,
                        tile: t,
                        first: (w.module, w.start, w.end),
                        second: (module, start, end),
                    });
                }
            }
            self.last_read[b][t] = Some(acc);
        }
    }

    /// Record a write.
    pub fn write(&mut self, module: Module, buffer: BufferId, tile: usize, tiles: usize, start: u64, end: u64) {
        if !self.enabled {
            return;
        }
        let b = buf_index(buffer);
        let acc = Access { module, start, end };
        for t in tile..(tile + tiles).min(self.last_write[b].len()) {
            if let Some(r) = self.last_read[b][t] {
                if Self::overlap(&r, &acc) {
                    self.record(Hazard {
                        kind: HazardKind::WriteDuringRead,
                        buffer,
                        tile: t,
                        first: (r.module, r.start, r.end),
                        second: (module, start, end),
                    });
                }
            }
            if let Some(w) = self.last_write[b][t] {
                if Self::overlap(&w, &acc) {
                    self.record(Hazard {
                        kind: HazardKind::WriteDuringWrite,
                        buffer,
                        tile: t,
                        first: (w.module, w.start, w.end),
                        second: (module, start, end),
                    });
                }
            }
            self.last_write[b][t] = Some(acc);
        }
    }

    /// Detected hazards, in detection order.
    pub fn hazards(&self) -> &[Hazard] {
        &self.hazards
    }
}
