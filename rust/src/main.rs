//! `vta` — the command-line launcher for the VTA stack.
//!
//! Subcommands:
//! * `info [--config FILE]` — print the architecture summary and the
//!   §2.6 bandwidth derivation.
//! * `resnet [--cpu-only] [--vt N] [--pjrt] [--offload-dense]
//!   [--offload-alu] [--config FILE]` — run ResNet-18 inference
//!   end-to-end and print the Fig 16 breakdown.
//! * `conv <C1..C12> [--vt N] [--config FILE]` — run one Table 1 layer
//!   and print its roofline point (Fig 15).
//! * `style [--size N] [--vt N] [--offload-all] [--cpu-only]
//!   [--config FILE]` — run the fast style-transfer net end-to-end
//!   (down-convs → residual blocks → upsample+conv → microcoded
//!   requant epilogue) and verify the output against the CPU
//!   reference bit-exactly.
//! * `serve [--model resnet|style] [--batch N] [--vt N] [--cache N]
//!   [--devices N] [--max-batch N] [--batch-deadline MS]
//!   [--require-scaling X] [--offload-all] [--records FILE]
//!   [--config FILE]` — serve requests through the plan-caching,
//!   pipelined serving engine (tuned schedules loaded from a `vta dse`
//!   record store), print the serial-vs-pipelined comparison, then
//!   drain the same traffic through the multi-device scheduler
//!   (`--devices` replicas, dynamic batching) and self-verify the pool
//!   outputs bit-exactly against the single-device engine. With
//!   `--threads N` the same trace also runs through the **real-threads**
//!   pool (one OS worker per replica, bounded queue, shared plan
//!   directory), self-verified bit-exactly against the simulated
//!   scheduler oracle; `--qps LIST` then drives an open-loop Poisson
//!   ramp and prints per-step latency percentiles and SLO attainment,
//!   and `--require-speedup X` gates measured multi-thread throughput
//!   against the 1-thread baseline. With `--pipeline-stages K` the
//!   model is instead **split across K replicas** (stage-per-replica
//!   pipeline parallelism, boundary tensors handed off through DRAM),
//!   self-verified bit-exactly against the single-replica engine in
//!   both the simulated and real-threads disciplines;
//!   `--require-pipeline-speedup X` gates the modeled K-stage
//!   streaming speedup over the 1-stage chain. With `--fleet SPEC.json` the
//!   command instead serves a **heterogeneous fleet**: mixed traffic
//!   (`--model mixed` pairs a conv-bound resnet-mini class with an
//!   ALU-bound style class) routed across mixed-config device groups
//!   by `--route cost|roundrobin|static:G`, self-verified bit-exactly
//!   against per-config single-device engines and the threaded fleet
//!   runtime; `--require-routing-win` gates cost-model vs round-robin
//!   modeled makespan.
//! * `dse [--budget N] [--tune-trials N] [--seed N] [--top N]
//!   [--devices N] [--workload tiny|resnet] [--records FILE]
//!   [--require-improvement]` — design-space exploration: search
//!   hardware variants under a Zynq-7020 resource budget plus
//!   per-operator schedule tuning — candidates scored at pool level
//!   with `--devices` replicas — report the frontier with roofline
//!   placement, persist the tuning records. With `--fleet OUT.json
//!   [--fleet-devices N] [--fleet-budget B,D,L]` the frontier also
//!   feeds a fleet-composition search (multisets of variants under a
//!   fleet-wide resource budget, scored by mixed-traffic modeled
//!   makespan) and the winning spec is written for `vta serve
//!   --fleet`; `--require-fleet-improvement` gates it against the
//!   best homogeneous pool.
//! * `table1` — print Table 1.
//!
//! (Hand-rolled argument parsing: the offline vendor set has no clap —
//! see DESIGN.md §2.)

use std::process::ExitCode;
use vta::arch::{load_config, VtaConfig};
use vta::compiler::{lower_conv2d, pack_activations, pack_weights};
use vta::dse::{
    interleave_classes, run_dse, run_fleet_dse, DseOptions, FleetDseOptions, ResourceBudget,
    TuningRecords,
};
use vta::exec::serve::fleet::{
    modeled_fleet_makespan, serve_fleet_trace, FleetOptions, FleetScheduler, FleetSpec,
    FleetThreadedOptions, RoutePolicy, Router,
};
use vta::exec::{
    open_loop, run_pipeline_threaded, run_threaded, serve_trace, CpuBackend, Executor,
    LoadgenOptions, PipelineOptions, PipelinePartition, PipelineScheduler, PjrtCache, Scheduler,
    SchedulerOptions, ServingEngine, ThreadedOptions,
};
use vta::graph::resnet::{self, synth_input, TABLE1};
use vta::graph::{fuse, partition, style, PartitionPolicy, Placement};
use vta::metrics::Roofline;
use vta::runtime::VtaRuntime;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

struct Flags {
    config: Option<String>,
    vt: usize,
    cpu_only: bool,
    pjrt: bool,
    batch: usize,
    cache: usize,
    devices: usize,
    max_batch: usize,
    batch_deadline_ms: f64,
    require_scaling: Option<f64>,
    threads: usize,
    queue: usize,
    qps: Vec<f64>,
    qps_requests: usize,
    slo_ms: f64,
    require_speedup: Option<f64>,
    serial_compile: bool,
    pipeline_stages: usize,
    require_pipeline_speedup: Option<f64>,
    offload_dense: bool,
    offload_alu: bool,
    offload_upsample: bool,
    model: String,
    size: usize,
    records: Option<String>,
    budget: usize,
    tune_trials: usize,
    seed: u64,
    top: usize,
    workload: String,
    require_improvement: bool,
    fleet: Option<String>,
    fleet_devices: usize,
    fleet_budget: Option<(usize, usize, usize)>,
    route: String,
    require_routing_win: bool,
    require_fleet_improvement: bool,
    positional: Vec<String>,
}

fn parse_flags(args: &[String]) -> anyhow::Result<Flags> {
    let mut f = Flags {
        config: None,
        vt: 2,
        cpu_only: false,
        pjrt: false,
        batch: 4,
        cache: 64,
        devices: 1,
        max_batch: 8,
        batch_deadline_ms: 1.0,
        require_scaling: None,
        threads: 0,
        queue: 64,
        qps: Vec::new(),
        qps_requests: 32,
        slo_ms: 50.0,
        require_speedup: None,
        serial_compile: false,
        pipeline_stages: 0,
        require_pipeline_speedup: None,
        offload_dense: false,
        offload_alu: false,
        offload_upsample: false,
        model: "resnet".to_string(),
        size: 32,
        records: None,
        budget: 16,
        tune_trials: 4,
        seed: 0xD5E,
        top: 5,
        workload: "resnet".to_string(),
        require_improvement: false,
        fleet: None,
        fleet_devices: 2,
        fleet_budget: None,
        route: "cost".to_string(),
        require_routing_win: false,
        require_fleet_improvement: false,
        positional: Vec::new(),
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                i += 1;
                f.config = Some(
                    args.get(i).ok_or_else(|| anyhow::anyhow!("--config needs a path"))?.clone(),
                );
            }
            "--vt" => {
                i += 1;
                f.vt = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--vt needs 1 or 2"))?
                    .parse()?;
                anyhow::ensure!(f.vt == 1 || f.vt == 2, "--vt needs 1 or 2, got {}", f.vt);
            }
            "--batch" => {
                i += 1;
                f.batch = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--batch needs a count"))?
                    .parse()?;
            }
            "--cache" => {
                i += 1;
                f.cache = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--cache needs a plan count"))?
                    .parse()?;
            }
            "--devices" => {
                i += 1;
                f.devices = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--devices needs a replica count"))?
                    .parse()?;
                anyhow::ensure!(f.devices >= 1, "--devices needs at least 1, got {}", f.devices);
            }
            "--max-batch" => {
                i += 1;
                f.max_batch = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--max-batch needs a request count"))?
                    .parse()?;
                anyhow::ensure!(f.max_batch >= 1, "--max-batch needs at least 1");
            }
            "--batch-deadline" => {
                i += 1;
                f.batch_deadline_ms = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--batch-deadline needs simulated ms"))?
                    .parse()?;
                anyhow::ensure!(
                    f.batch_deadline_ms >= 0.0 && f.batch_deadline_ms.is_finite(),
                    "--batch-deadline must be a finite non-negative simulated ms value"
                );
            }
            "--require-scaling" => {
                i += 1;
                let x: f64 = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--require-scaling needs a factor"))?
                    .parse()?;
                anyhow::ensure!(
                    x > 0.0 && x.is_finite(),
                    "--require-scaling must be a positive factor"
                );
                f.require_scaling = Some(x);
            }
            "--threads" => {
                i += 1;
                f.threads = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--threads needs a worker count"))?
                    .parse()?;
            }
            "--queue" => {
                i += 1;
                f.queue = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--queue needs a capacity"))?
                    .parse()?;
                anyhow::ensure!(f.queue >= 1, "--queue needs at least 1 slot");
            }
            "--qps" => {
                i += 1;
                let spec = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--qps needs a comma-separated rate list"))?;
                f.qps = spec
                    .split(',')
                    .map(|s| s.trim().parse::<f64>())
                    .collect::<Result<Vec<_>, _>>()?;
                anyhow::ensure!(
                    !f.qps.is_empty() && f.qps.iter().all(|&q| q > 0.0 && q.is_finite()),
                    "--qps rates must be positive and finite"
                );
            }
            "--qps-requests" => {
                i += 1;
                f.qps_requests = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--qps-requests needs a count"))?
                    .parse()?;
                anyhow::ensure!(f.qps_requests >= 1, "--qps-requests needs at least 1");
            }
            "--slo" => {
                i += 1;
                f.slo_ms = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--slo needs milliseconds"))?
                    .parse()?;
                anyhow::ensure!(
                    f.slo_ms > 0.0 && f.slo_ms.is_finite(),
                    "--slo must be positive finite milliseconds"
                );
            }
            "--require-speedup" => {
                i += 1;
                let x: f64 = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--require-speedup needs a factor"))?
                    .parse()?;
                anyhow::ensure!(
                    x > 0.0 && x.is_finite(),
                    "--require-speedup must be a positive factor"
                );
                f.require_speedup = Some(x);
            }
            "--serial-compile" => {
                f.serial_compile = true;
            }
            "--pipeline-stages" => {
                i += 1;
                f.pipeline_stages = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--pipeline-stages needs a stage count"))?
                    .parse()?;
                anyhow::ensure!(
                    f.pipeline_stages >= 1,
                    "--pipeline-stages needs at least 1 stage"
                );
            }
            "--require-pipeline-speedup" => {
                i += 1;
                let x: f64 = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--require-pipeline-speedup needs a factor"))?
                    .parse()?;
                anyhow::ensure!(
                    x > 0.0 && x.is_finite(),
                    "--require-pipeline-speedup must be a positive factor"
                );
                f.require_pipeline_speedup = Some(x);
            }
            "--records" => {
                i += 1;
                f.records = Some(
                    args.get(i).ok_or_else(|| anyhow::anyhow!("--records needs a path"))?.clone(),
                );
            }
            "--budget" => {
                i += 1;
                f.budget = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--budget needs a candidate count"))?
                    .parse()?;
            }
            "--tune-trials" => {
                i += 1;
                f.tune_trials = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--tune-trials needs a count"))?
                    .parse()?;
            }
            "--seed" => {
                i += 1;
                f.seed =
                    args.get(i).ok_or_else(|| anyhow::anyhow!("--seed needs a value"))?.parse()?;
            }
            "--top" => {
                i += 1;
                f.top =
                    args.get(i).ok_or_else(|| anyhow::anyhow!("--top needs a count"))?.parse()?;
            }
            "--workload" => {
                i += 1;
                f.workload = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--workload needs a suite name"))?
                    .clone();
            }
            "--model" => {
                i += 1;
                f.model = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--model needs resnet or style"))?
                    .clone();
            }
            "--size" => {
                i += 1;
                f.size = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--size needs a pixel count"))?
                    .parse()?;
                anyhow::ensure!(
                    f.size >= 4 && f.size % 4 == 0,
                    "--size must be a positive multiple of 4, got {}",
                    f.size
                );
            }
            "--fleet" => {
                i += 1;
                f.fleet = Some(
                    args.get(i).ok_or_else(|| anyhow::anyhow!("--fleet needs a spec path"))?.clone(),
                );
            }
            "--fleet-devices" => {
                i += 1;
                f.fleet_devices = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--fleet-devices needs a replica count"))?
                    .parse()?;
                anyhow::ensure!(f.fleet_devices >= 1, "--fleet-devices needs at least 1");
            }
            "--fleet-budget" => {
                i += 1;
                let spec = args.get(i).ok_or_else(|| {
                    anyhow::anyhow!("--fleet-budget needs BRAM18,DSP,LUT counts")
                })?;
                let parts: Vec<usize> = spec
                    .split(',')
                    .map(|s| s.trim().parse::<usize>())
                    .collect::<Result<Vec<_>, _>>()?;
                anyhow::ensure!(
                    parts.len() == 3,
                    "--fleet-budget needs exactly BRAM18,DSP,LUT (got {} value(s))",
                    parts.len()
                );
                f.fleet_budget = Some((parts[0], parts[1], parts[2]));
            }
            "--route" => {
                i += 1;
                f.route = args
                    .get(i)
                    .ok_or_else(|| anyhow::anyhow!("--route needs cost|roundrobin|static:G"))?
                    .clone();
            }
            "--require-routing-win" => f.require_routing_win = true,
            "--require-fleet-improvement" => f.require_fleet_improvement = true,
            "--require-improvement" => f.require_improvement = true,
            "--cpu-only" => f.cpu_only = true,
            "--pjrt" => f.pjrt = true,
            "--offload-dense" => f.offload_dense = true,
            "--offload-alu" => f.offload_alu = true,
            "--offload-upsample" => f.offload_upsample = true,
            "--offload-all" => {
                f.offload_dense = true;
                f.offload_alu = true;
                f.offload_upsample = true;
            }
            other if other.starts_with("--") => anyhow::bail!("unknown flag {other}"),
            other => f.positional.push(other.to_string()),
        }
        i += 1;
    }
    Ok(f)
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    let cfg = load_config(flags.config.as_deref())?;
    match cmd.as_str() {
        "info" => cmd_info(&cfg),
        "table1" => cmd_table1(),
        "conv" => cmd_conv(&cfg, &flags),
        "resnet" => cmd_resnet(&cfg, &flags),
        "style" => cmd_style(&cfg, &flags),
        "serve" => cmd_serve(&cfg, &flags),
        "dse" => cmd_dse(&cfg, &flags),
        other => {
            print_usage();
            anyhow::bail!("unknown command {other}")
        }
    }
}

fn print_usage() {
    eprintln!(
        "usage: vta <command> [flags]\n\
         commands:\n\
         \x20 info                      print the architecture summary\n\
         \x20 table1                    print the paper's Table 1\n\
         \x20 conv <C1..C12>            run one conv layer on the simulator\n\
         \x20 resnet                    run ResNet-18 end to end\n\
         \x20 style                     run the fast style-transfer net end to end (verifies vs CPU)\n\
         \x20 serve                     batched serving (plan cache + pipeline; --model resnet|style)\n\
         \x20 dse                       design-space exploration + schedule autotuning\n\
         flags:\n\
         \x20 --config FILE             VTA variant config (key = value)\n\
         \x20 --vt N                    virtual threads (1 = no latency hiding, 2 = default)\n\
         \x20 --model NAME              serve: graph to serve, resnet | style (default resnet); with --fleet also mixed (resnet-mini + style classes)\n\
         \x20 --size N                  style: input resolution, multiple of 4 (default 32)\n\
         \x20 --batch N                 serve: requests per batch (default 4)\n\
         \x20 --cache N                 serve: plan-cache capacity in plans (default 64)\n\
         \x20 --devices N               serve: accelerator replicas in the pool; dse: pool size candidates are scored for (default 1)\n\
         \x20 --max-batch N             serve: dynamic-batching batch-size cap (default 8)\n\
         \x20 --batch-deadline MS       serve: dynamic-batching deadline in simulated ms (default 1.0)\n\
         \x20 --require-scaling X       serve: exit nonzero unless the pool models >= X x the 1-device throughput\n\
         \x20 --threads N               serve: real worker threads (0 = simulated pool only, default 0)\n\
         \x20 --queue N                 serve: threaded request-queue capacity (default 64)\n\
         \x20 --qps LIST                serve: open-loop ramp rates, comma-separated (e.g. 50,200,800)\n\
         \x20 --qps-requests N          serve: arrivals offered per ramp step (default 32)\n\
         \x20 --slo MS                  serve: latency SLO for ramp attainment, wall ms (default 50)\n\
         \x20 --require-speedup X       serve: exit nonzero unless N threads measure >= X x the 1-thread throughput\n\
         \x20 --serial-compile          serve: compile plans under the directory lock (A/B baseline for concurrent JIT)\n\
         \x20 --pipeline-stages K       serve: split the model across K replicas (stage-per-replica pipeline parallelism)\n\
         \x20 --require-pipeline-speedup X  serve: exit nonzero unless the K-stage pipeline models >= X x the 1-stage makespan\n\
         \x20 --fleet FILE              serve: serve across the FleetSpec's mixed-config groups; dse: search fleet compositions and write the winner here\n\
         \x20 --route POLICY            serve --fleet: cost | roundrobin | static:G (default cost)\n\
         \x20 --require-routing-win     serve --fleet: exit nonzero unless cost-model routing beats round-robin on modeled makespan\n\
         \x20 --fleet-devices N         dse --fleet: total replicas across the fleet (default 2)\n\
         \x20 --fleet-budget B,D,L      dse --fleet: fleet-wide BRAM18,DSP,LUT budget (default N Zynq-7020 boards)\n\
         \x20 --require-fleet-improvement  dse --fleet: exit nonzero unless the best fleet matches/beats the best homogeneous pool\n\
         \x20 --records FILE            serve: load tuned schedules; dse: persist them\n\
         \x20 --budget N                dse: hardware candidates to evaluate (default 16)\n\
         \x20 --tune-trials N           dse: schedule candidates per (config, op) (default 4)\n\
         \x20 --seed N                  dse: search seed (default 3422)\n\
         \x20 --top N                   dse: frontier size to report (default 5)\n\
         \x20 --workload SUITE          dse: tiny | resnet | style (default resnet)\n\
         \x20 --require-improvement     dse: exit nonzero unless the frontier matches/beats the baseline\n\
         \x20 --offload-dense           resnet/style/serve: lower Dense layers onto the VTA too\n\
         \x20 --offload-alu             resnet/style/serve: lower adds / ReLUs / Min / Shr onto the tensor ALU\n\
         \x20 --offload-upsample        style/serve: lower Upsample2x onto the strided-store pass\n\
         \x20 --offload-all             shorthand for --offload-dense --offload-alu --offload-upsample\n\
         \x20 --cpu-only                resnet/style: keep every operator on the CPU\n\
         \x20 --pjrt                    resnet: run CPU ops on XLA artifacts (needs `make artifacts`)"
    );
}

fn cmd_info(cfg: &VtaConfig) -> anyhow::Result<()> {
    println!("{}", cfg.summary());
    let r = Roofline::of(cfg);
    println!(
        "roofline: knee at {:.1} ops/byte; bandwidth roof {:.2} GB/s",
        r.knee_intensity(),
        cfg.dram_gbytes_per_sec()
    );
    Ok(())
}

fn cmd_table1() -> anyhow::Result<()> {
    println!(
        "{:<5} {:>9} {:>9} {:>6} {:>6} {:>9} {:>9}",
        "name", "H,W", "IC,OC", "K", "S", "GOPs", "ops/byte"
    );
    for i in 0..TABLE1.len() {
        let (name, h, ic, oc, k, s) = TABLE1[i];
        let p = resnet::table1_params(i);
        println!(
            "{:<5} {:>9} {:>9} {:>6} {:>6} {:>9.3} {:>9.1}",
            name,
            format!("{h},{h}"),
            format!("{ic},{oc}"),
            k,
            s,
            p.ops() as f64 / 1e9,
            p.arithmetic_intensity()
        );
    }
    Ok(())
}

fn cmd_conv(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    let name = flags
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("conv needs a layer name (C1..C12)"))?;
    let row = TABLE1
        .iter()
        .position(|(n, ..)| n.eq_ignore_ascii_case(name))
        .ok_or_else(|| anyhow::anyhow!("unknown layer {name}"))?;
    let p = resnet::table1_params(row);
    let inp = synth_input(1, 1, p.ic, p.h, p.w);
    let wgt = resnet::synth_conv_weights(row as u64 + 100, p.oc, p.ic, p.k);

    let mut rt = VtaRuntime::new(cfg, 512 << 20);
    let t0 = std::time::Instant::now();
    let out = lower_conv2d(
        &mut rt,
        &p,
        &pack_activations(cfg, &inp),
        &pack_weights(cfg, &wgt),
        flags.vt,
    )?;
    let host = t0.elapsed();
    let r = Roofline::of(cfg);
    let pt = r.point(name, p.ops(), p.arithmetic_intensity(), &out.stats);
    println!(
        "{name}: {} cycles ({:.3} ms simulated @ {:.0} MHz), {:.2} GOPS \
         ({:.0}% of roofline, {:.0}% GEMM utilization), vt={}",
        pt.cycles,
        pt.cycles as f64 / cfg.clock_hz * 1e3,
        cfg.clock_hz / 1e6,
        pt.gops,
        pt.efficiency * 100.0,
        pt.utilization * 100.0,
        flags.vt
    );
    println!(
        "  plan: oc_t={} oh_t={} ow_t={} groups={} strips/group={}; \
         DRAM {:.2} MB moved; host lowering {host:.1?}",
        out.plan.oc_t,
        out.plan.oh_t,
        out.plan.ow_t,
        out.plan.groups(),
        out.plan.strips(),
        out.stats.bytes_moved() as f64 / 1e6,
    );
    Ok(())
}

/// Partition policy from the CLI flags: the paper's rule, optionally
/// widened to Dense / ALU offload.
fn build_policy(cfg: &VtaConfig, flags: &Flags) -> PartitionPolicy {
    if flags.cpu_only {
        return PartitionPolicy::cpu_only();
    }
    let mut policy = PartitionPolicy::paper(cfg);
    policy.virtual_threads = flags.vt;
    policy.offload_dense = flags.offload_dense;
    policy.offload_alu = flags.offload_alu;
    policy.offload_upsample = flags.offload_upsample;
    policy
}

/// The one place the CLI's style graph is constructed (geometry, base
/// channels, weight seed): `vta style` and `vta serve --model style`
/// must serve the identical network.
fn build_style(flags: &Flags) -> anyhow::Result<(vta::graph::Graph, usize)> {
    Ok(fuse(style::style_net(1, flags.size, 16, 42)?)?)
}

/// Build the graph selected by `--model`, plus its display name and
/// input channel/size geometry (shared by `serve`).
fn build_model(flags: &Flags) -> anyhow::Result<(vta::graph::Graph, usize, String, usize)> {
    match flags.model.as_str() {
        "resnet" => {
            let (g, fused) = fuse(resnet::resnet18(1, 42)?)?;
            Ok((g, fused, "ResNet-18".to_string(), 224))
        }
        "style" => {
            let (g, fused) = build_style(flags)?;
            Ok((g, fused, format!("style-transfer {0}x{0}", flags.size), flags.size))
        }
        "mixed" => anyhow::bail!("--model mixed needs --fleet (mixed traffic is fleet-only)"),
        other => anyhow::bail!("unknown --model {other} (expected resnet|style)"),
    }
}

fn cmd_serve(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    if flags.fleet.is_some() {
        anyhow::ensure!(
            flags.pipeline_stages == 0,
            "--pipeline-stages does not combine with --fleet"
        );
        return cmd_serve_fleet(cfg, flags);
    }
    if flags.pipeline_stages > 0 {
        return cmd_serve_pipeline(cfg, flags);
    }
    anyhow::ensure!(
        flags.require_pipeline_speedup.is_none(),
        "--require-pipeline-speedup needs --pipeline-stages"
    );
    let (mut g, fused, model_name, size) = build_model(flags)?;
    let (vta_n, cpu_n) = partition(&mut g, &build_policy(cfg, flags));
    println!(
        "serving {model_name}: {} nodes ({fused} fused), {vta_n} on VTA, {cpu_n} on CPU; \
         batch {}, vt={}, plan cache {} plans",
        g.nodes.len(),
        flags.batch,
        flags.vt,
        flags.cache
    );

    // Tuned schedules from a prior `vta dse` run, applied at compile
    // time to every matching (config, operator) pair.
    let records = match &flags.records {
        Some(path) => {
            let r = TuningRecords::load(path)?;
            println!("loaded {} tuning record(s) from {path}", r.len());
            r
        }
        None => TuningRecords::new(),
    };
    let mut engine = ServingEngine::with_records(
        cfg,
        512 << 20,
        CpuBackend::Native,
        flags.vt,
        flags.cache,
        records.clone(),
    );
    if engine.tuned_records() > 0 {
        let tuned_nodes = g
            .nodes
            .iter()
            .filter(|n| n.placement == Placement::Vta && engine.tuned_schedule(n).is_some())
            .count();
        println!("tuned schedules apply to {tuned_nodes} VTA node(s)");
    }
    let inputs: Vec<_> =
        (0..flags.batch).map(|i| synth_input(7 + i as u64, 1, 3, size, size)).collect();

    // Cold batch: every unique VTA node compiles exactly once.
    let t0 = std::time::Instant::now();
    let cold = engine.run_batch(&g, &inputs)?;
    let cold_wall = t0.elapsed();
    println!(
        "\ncold batch: host wall {cold_wall:.2?}; plan cache misses {} (one per unique VTA \
         node), hits {}, {} plans resident ({:.1} MB device DRAM)",
        cold.cache.misses,
        cold.cache.hits,
        engine.cached_plans(),
        engine.cache_dram_bytes() as f64 / 1e6
    );
    let mut kinds: Vec<_> = engine.cached_kinds().into_iter().collect();
    kinds.sort();
    let kinds: Vec<String> = kinds.iter().map(|(k, n)| format!("{k} x{n}")).collect();
    println!("resident plan kinds: {}", kinds.join(", "));

    // Warm batch: pure replay — lowering never runs again.
    let t0 = std::time::Instant::now();
    let warm = engine.run_batch(&g, &inputs)?;
    let warm_wall = t0.elapsed();
    for (a, b) in cold.outputs.iter().zip(&warm.outputs) {
        anyhow::ensure!(a == b, "cold and warm batches disagree");
    }
    println!(
        "warm batch: host wall {warm_wall:.2?} ({:.1}x less host work than cold); \
         misses {}, hits {} (all lookups hit)",
        cold_wall.as_secs_f64() / warm_wall.as_secs_f64().max(1e-9),
        warm.cache.misses,
        warm.cache.hits
    );

    println!(
        "\nend-to-end model time, batch of {}:\n\
         \x20 naive serial (per-node, no overlap): {:.1} ms\n\
         \x20 pipelined (CPU/VTA overlap, double-buffered): {:.1} ms  ({:.2}x)",
        flags.batch,
        warm.serial_seconds * 1e3,
        warm.pipelined_seconds * 1e3,
        warm.speedup()
    );
    println!(
        "throughput {:.1} inf/s; latency p50 {:.1} ms, p90 {:.1} ms, p99 {:.1} ms",
        warm.throughput(),
        warm.latency_percentile(0.50) * 1e3,
        warm.latency_percentile(0.90) * 1e3,
        warm.latency_percentile(0.99) * 1e3
    );

    // ---- multi-device pool: the same model through the scheduler ------
    // With N > 1 replicas, serve exactly N full dynamic batches
    // (N x max_batch requests, all arriving at t = 0) so every replica
    // has work and the 1-device comparison is well-conditioned; with
    // one device, reuse the engine's batch size.
    let pool_n = if flags.devices > 1 { flags.devices * flags.max_batch } else { flags.batch };
    let pool_inputs: Vec<_> =
        (0..pool_n).map(|i| synth_input(7 + i as u64, 1, 3, size, size)).collect();
    let opts = SchedulerOptions {
        devices: flags.devices,
        max_batch: flags.max_batch,
        batch_deadline: flags.batch_deadline_ms * 1e-3,
        cache_capacity: flags.cache,
        virtual_threads: flags.vt,
        dram_size: 512 << 20,
    };
    let mut sched =
        Scheduler::with_records(cfg, CpuBackend::Native, opts.clone(), records.clone());
    for input in &pool_inputs {
        sched.submit(0.0, input.clone());
    }
    let pool = sched.run(&g)?;
    println!(
        "\npool of {} device(s): {} requests in {} batch(es) (max-batch {}, deadline {} ms); \
         plan-cache misses {} (compile-once per pool), makespan {:.1} ms, \
         modeled throughput {:.1} inf/s",
        flags.devices,
        pool_n,
        pool.batches.len(),
        flags.max_batch,
        flags.batch_deadline_ms,
        pool.cache.misses,
        pool.makespan_seconds * 1e3,
        pool.throughput()
    );
    let utils: Vec<String> =
        (0..flags.devices).map(|d| format!("d{d} {:.0}%", pool.utilization(d) * 100.0)).collect();
    println!(
        "per-device utilization: {}; queue depth max {} / mean {:.1}; \
         latency p50 {:.1} ms, p99 {:.1} ms",
        utils.join(", "),
        pool.metrics.queue.max_depth(),
        pool.metrics.queue.mean_depth(),
        pool.latency_percentile(0.50) * 1e3,
        pool.latency_percentile(0.99) * 1e3
    );

    // Self-verification, part 1: pool requests share the engine's synth
    // seeds, so every overlapping request must match the single-device
    // engine bit-exactly.
    for (i, out) in pool.outputs.iter().take(warm.outputs.len()).enumerate() {
        anyhow::ensure!(
            out == &warm.outputs[i],
            "pool output {i} diverged from the single-device engine"
        );
    }

    if flags.devices > 1 {
        // Self-verification, part 2: drain the identical request stream
        // through a 1-replica pool — outputs must be bit-identical and
        // the modeled makespan gives the device-scaling factor.
        let mut base_opts = opts;
        base_opts.devices = 1;
        let mut base = Scheduler::with_records(cfg, CpuBackend::Native, base_opts, records.clone());
        for input in &pool_inputs {
            base.submit(0.0, input.clone());
        }
        let one = base.run(&g)?;
        for (i, out) in one.outputs.iter().enumerate() {
            anyhow::ensure!(out == &pool.outputs[i], "pool size changed outputs (request {i})");
        }
        let scaling = one.makespan_seconds / pool.makespan_seconds.max(1e-12);
        println!(
            "device scaling: 1-device makespan {:.1} ms -> {}-device {:.1} ms \
             ({:.2}x modeled throughput)",
            one.makespan_seconds * 1e3,
            flags.devices,
            pool.makespan_seconds * 1e3,
            scaling
        );
        println!("pool outputs match the single-device engine bit-exactly");
        if let Some(need) = flags.require_scaling {
            anyhow::ensure!(
                scaling >= need,
                "pool scaling {scaling:.2}x is below the required {need:.2}x"
            );
            println!("scaling gate passed: {scaling:.2}x >= {need:.2}x");
        }
    } else if let Some(need) = flags.require_scaling {
        anyhow::bail!("--require-scaling {need} needs --devices > 1");
    }

    // ---- real threads: the same trace through the threaded pool -------
    if flags.threads > 0 {
        cmd_serve_threaded(cfg, flags, &g, &pool_inputs, &pool, &records, size)?;
    } else {
        anyhow::ensure!(
            flags.qps.is_empty() && flags.require_speedup.is_none(),
            "--qps and --require-speedup need --threads > 0"
        );
    }
    Ok(())
}

/// The `--threads` leg of `vta serve`: replay the pool trace through
/// the real-threads runtime, self-verify bit-exactly against the
/// simulated scheduler oracle, then (optionally) drive an open-loop
/// Poisson ramp and gate measured thread scaling.
fn cmd_serve_threaded(
    cfg: &VtaConfig,
    flags: &Flags,
    g: &vta::graph::Graph,
    pool_inputs: &[vta::util::Tensor<i8>],
    oracle: &vta::exec::PoolReport,
    records: &TuningRecords,
    size: usize,
) -> anyhow::Result<()> {
    let mut topts = ThreadedOptions::new(flags.threads);
    topts.queue_capacity = flags.queue;
    topts.max_batch = flags.max_batch;
    topts.cache_capacity = flags.cache;
    topts.virtual_threads = flags.vt;
    topts.dram_size = 512 << 20;
    topts.serial_compile = flags.serial_compile;

    let report = serve_trace(cfg, &topts, records, g, pool_inputs)?;
    println!(
        "\nthreaded pool of {} worker(s): {} requests, wall {:.2?}, \
         measured throughput {:.1} inf/s; plan directory misses {} / hits {}",
        flags.threads,
        pool_inputs.len(),
        report.wall,
        report.throughput_rps(),
        report.cache.misses,
        report.cache.hits
    );
    println!(
        "queue wait p50 {:.2} ms / p99 {:.2} ms; service p50 {:.2} ms / p99 {:.2} ms",
        report.queue_wait.percentile(0.50) * 1e3,
        report.queue_wait.percentile(0.99) * 1e3,
        report.service.percentile(0.50) * 1e3,
        report.service.percentile(0.99) * 1e3
    );
    let per_thread: Vec<String> = report
        .threads
        .iter()
        .enumerate()
        .map(|(t, c)| format!("t{t} {}req/{}batch", c.requests, c.batches))
        .collect();
    println!("per-thread: {}", per_thread.join(", "));
    println!(
        "contention: {} queue-full rejection(s), {} compile-claim wait(s), \
         {} directory lock acquisition(s)",
        report.contention.queue_full,
        report.contention.claim_waits,
        report.contention.directory_locks
    );

    // Oracle equivalence: the simulated scheduler served this exact
    // trace above — outputs must be bit-identical in submission order
    // and pool-level cache counters must agree.
    anyhow::ensure!(
        report.outputs.len() == oracle.outputs.len(),
        "threaded pool answered {} of {} requests",
        report.outputs.len(),
        oracle.outputs.len()
    );
    for (i, out) in report.outputs.iter().enumerate() {
        anyhow::ensure!(
            out == &oracle.outputs[i],
            "threaded output {i} diverged from the simulated scheduler oracle"
        );
    }
    anyhow::ensure!(
        report.cache.misses == oracle.cache.misses && report.cache.hits == oracle.cache.hits,
        "threaded plan directory ({} misses / {} hits) fell out of step with the \
         oracle ({} misses / {} hits)",
        report.cache.misses,
        report.cache.hits,
        oracle.cache.misses,
        oracle.cache.hits
    );
    println!("threaded outputs and cache counters match the simulated oracle bit-exactly");

    // Measured thread scaling (wall-clock, so only meaningful on a
    // multi-core host — CI gates it, laptops just print it).
    if let Some(need) = flags.require_speedup {
        anyhow::ensure!(flags.threads > 1, "--require-speedup {need} needs --threads > 1");
        let mut one = topts.clone();
        one.threads = 1;
        let base = serve_trace(cfg, &one, records, g, pool_inputs)?;
        let speedup = base.wall.as_secs_f64() / report.wall.as_secs_f64().max(1e-9);
        println!(
            "thread scaling: 1 thread {:.2?} -> {} threads {:.2?} ({speedup:.2}x measured)",
            base.wall, flags.threads, report.wall
        );
        anyhow::ensure!(
            speedup >= need,
            "measured thread speedup {speedup:.2}x is below the required {need:.2}x"
        );
        println!("speedup gate passed: {speedup:.2}x >= {need:.2}x");
    }

    // Open-loop Poisson ramp against a fresh pool.
    if !flags.qps.is_empty() {
        let lopts = LoadgenOptions::ramp(&flags.qps, flags.qps_requests, flags.slo_ms * 1e-3);
        let (load, ramp_report) = run_threaded(cfg, &topts, records, g, |handle| {
            open_loop(handle, &lopts, |i| synth_input(7 + i, 1, 3, size, size))
        })?;
        println!("\nopen-loop ramp ({} step(s), SLO {:.0} ms):", load.steps.len(), flags.slo_ms);
        println!(
            "{:>8} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8} {:>10}",
            "qps", "offered", "shed", "p50 ms", "p99 ms", "p99.9 ms", "SLO %", "meas inf/s"
        );
        for s in &load.steps {
            println!(
                "{:>8.1} {:>8} {:>9} {:>9.2} {:>9.2} {:>9.2} {:>7.1}% {:>10.1}",
                s.qps,
                s.offered,
                s.rejected,
                s.p50 * 1e3,
                s.p99 * 1e3,
                s.p999 * 1e3,
                s.slo_attainment * 100.0,
                s.throughput_rps
            );
        }
        println!(
            "ramp totals: {} offered, {} shed, {} plan compiles across the pool",
            load.offered(),
            load.rejected(),
            ramp_report.cache.misses
        );
    }
    Ok(())
}

/// The `--pipeline-stages` leg of `vta serve`: split one model across
/// K pool replicas (stage-per-replica, boundary tensors handed off
/// through DRAM), self-verify the simulated pipeline bit-exactly
/// against the single-replica engine, gate the modeled K-stage
/// streaming speedup over the 1-stage chain, then run the identical
/// split through the real-threads pipeline runtime and check it
/// reproduces the oracle — outputs *and* per-stage plan-cache
/// counters.
fn cmd_serve_pipeline(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    let k = flags.pipeline_stages;
    let (mut g, fused, model_name, size) = build_model(flags)?;
    let (vta_n, cpu_n) = partition(&mut g, &build_policy(cfg, flags));
    println!(
        "pipeline-serving {model_name}: {} nodes ({fused} fused), {vta_n} on VTA, \
         {cpu_n} on CPU; {k} stage(s), {} streamed request(s), vt={}",
        g.nodes.len(),
        flags.batch,
        flags.vt
    );
    let records = match &flags.records {
        Some(path) => {
            let r = TuningRecords::load(path)?;
            println!("loaded {} tuning record(s) from {path}", r.len());
            r
        }
        None => TuningRecords::new(),
    };

    // Roofline-balanced stage split (the balancer clamps to the
    // graph's depth — refuse rather than silently serve fewer stages).
    let part = PipelinePartition::balanced(cfg, &g, k);
    anyhow::ensure!(
        part.len() == k,
        "{model_name} has only {} pipelineable level(s) — too shallow for {k} stages",
        part.len()
    );
    println!();
    for line in part.describe() {
        println!("{line}");
    }

    let inputs: Vec<_> =
        (0..flags.batch).map(|i| synth_input(7 + i as u64, 1, 3, size, size)).collect();

    // Reference: the single-replica serving engine on the same trace.
    let mut engine = ServingEngine::with_records(
        cfg,
        512 << 20,
        CpuBackend::Native,
        flags.vt,
        flags.cache,
        records.clone(),
    );
    let reference = engine.run_batch(&g, &inputs)?;

    // Simulated pipeline: the deterministic oracle.
    let mut opts = PipelineOptions::new(k);
    opts.virtual_threads = flags.vt;
    opts.cache_capacity = flags.cache;
    opts.queue_capacity = flags.queue;
    let mut sched =
        PipelineScheduler::with_records(cfg, CpuBackend::Native, opts.clone(), records.clone());
    let piped = sched.run(&g, &part, &inputs)?;
    for (i, out) in piped.outputs.iter().enumerate() {
        anyhow::ensure!(
            out == &reference.outputs[i],
            "pipelined output {i} diverged from the single-replica engine"
        );
    }
    let compiles: u64 = piped.cache.iter().map(|c| c.misses).sum();
    println!(
        "\nsimulated pipeline: {} request(s) streamed, makespan {:.2} ms, modeled \
         throughput {:.1} inf/s; {compiles} plan compile(s) split across the stages; \
         outputs match the single-replica engine bit-exactly",
        inputs.len(),
        piped.makespan_seconds * 1e3,
        piped.throughput()
    );

    // The pipeline win, on the deterministic roofline model: streaming
    // the trace through K balanced stages vs the 1-stage serial chain.
    let serial = PipelinePartition::from_cuts(cfg, &g, &[]);
    let n = inputs.len().max(1);
    let (one, kst) = (serial.modeled_makespan(n), part.modeled_makespan(n));
    let speedup = one / kst.max(1e-12);
    println!(
        "modeled stream of {n}: 1 stage {:.2} ms -> {k} stage(s) {:.2} ms ({speedup:.2}x); \
         steady-state bottleneck {:.2} ms/request",
        one * 1e3,
        kst * 1e3,
        part.bottleneck_seconds() * 1e3
    );
    if let Some(need) = flags.require_pipeline_speedup {
        anyhow::ensure!(k > 1, "--require-pipeline-speedup {need} needs --pipeline-stages > 1");
        anyhow::ensure!(
            speedup >= need,
            "modeled pipeline speedup {speedup:.2}x is below the required {need:.2}x"
        );
        println!("pipeline speedup gate passed: {speedup:.2}x >= {need:.2}x");
    }

    // Real threads: one worker per stage, bounded inter-stage queues —
    // must reproduce the oracle bit-for-bit.
    let threaded = run_pipeline_threaded(cfg, &opts, &records, &g, &part, &inputs)?;
    for (i, out) in threaded.outputs.iter().enumerate() {
        anyhow::ensure!(
            out == &piped.outputs[i],
            "threaded pipeline output {i} diverged from the simulated oracle"
        );
    }
    anyhow::ensure!(
        threaded.cache == piped.cache,
        "threaded per-stage plan caches fell out of step with the oracle ({:?} vs {:?})",
        threaded.cache,
        piped.cache
    );
    println!(
        "\nthreaded pipeline: wall {:.2?}, measured throughput {:.1} inf/s; outputs and \
         per-stage cache counters match the simulated oracle bit-exactly",
        threaded.wall,
        threaded.throughput_rps()
    );
    let span = threaded.wall.as_secs_f64();
    for (s, c) in threaded.metrics.stages.iter().enumerate() {
        println!(
            "  stage {s}: {} node(s), {} request(s), occupancy {:.0}%, \
             handoff {} tensor(s) / {} B per request",
            c.nodes,
            c.requests,
            c.occupancy(span) * 100.0,
            part.stages[s].carries.len(),
            part.stages[s].handoff_bytes
        );
    }
    Ok(())
}

/// Workload classes of `serve --fleet` / `dse --fleet`, per `--model`.
///
/// `mixed` is the pair the fleet exists for: `resnet_mini` partitioned
/// under the paper rule (its VTA work is pure conv — GEMM-bound) plus
/// `style_net` with the ALU chain offloaded (eltwise-bound). The
/// per-class policies are pinned rather than taken from `--offload-*`,
/// and the conv class is deliberately **not** fused: fusing its block
/// tails (or offloading its adds) would put residual-add ALU passes on
/// the conv class too and erase the GEMM-vs-ALU contrast the routing
/// decision is meant to exercise. The style class ships fused (via
/// [`build_style`]) — its epilogue chains still run on the tensor ALU
/// inside the fused nodes, so it stays the lane-sensitive class.
/// `resnet` / `style` run single-class traffic through the fleet.
/// Returns class-aligned (partitioned graphs, names, input sizes).
fn build_fleet_classes(
    cfg: &VtaConfig,
    flags: &Flags,
) -> anyhow::Result<(Vec<vta::graph::Graph>, Vec<String>, Vec<usize>)> {
    match flags.model.as_str() {
        "mixed" => {
            let mut conv_g = resnet::resnet_mini(1, flags.size, 42)?;
            let mut conv_p = PartitionPolicy::paper(cfg);
            conv_p.virtual_threads = flags.vt;
            partition(&mut conv_g, &conv_p);
            let (mut style_g, _) = build_style(flags)?;
            let mut style_p = PartitionPolicy::offload_all(cfg);
            style_p.virtual_threads = flags.vt;
            partition(&mut style_g, &style_p);
            Ok((
                vec![conv_g, style_g],
                vec![
                    format!("resnet-mini {0}x{0}", flags.size),
                    format!("style {0}x{0}", flags.size),
                ],
                vec![flags.size, flags.size],
            ))
        }
        "resnet" | "style" => {
            let (mut g, _, name, size) = build_model(flags)?;
            partition(&mut g, &build_policy(cfg, flags));
            Ok((vec![g], vec![name], vec![size]))
        }
        other => anyhow::bail!("unknown --model {other} (expected mixed|resnet|style)"),
    }
}

/// Split `total` requests as evenly as possible over `classes` classes
/// (remainder to the later classes, mirroring [`interleave_classes`]'
/// later-class tie-break), each class serving at least one request.
fn split_requests(total: usize, classes: usize) -> Vec<usize> {
    let total = total.max(classes);
    let base = total / classes;
    let rem = total % classes;
    (0..classes).map(|c| base + usize::from(c >= classes - rem)).collect()
}

/// One-line description of a fleet member / config group.
fn describe_config(cfg: &VtaConfig) -> String {
    format!(
        "{} @ {:.0} MHz, ALU {} lane(s)/ii={}",
        cfg.gemm,
        cfg.clock_hz / 1e6,
        cfg.alu_lanes,
        cfg.alu_ii
    )
}

/// The `--fleet` leg of `vta serve`: load a [`FleetSpec`], route a
/// classed trace across its config groups with `--route`, then prove
/// the heterogeneous runtimes exact — every request bit-identical to
/// a single-device engine of its routed group's config, and the
/// real-threads fleet bit-identical (outputs, routes, per-group cache
/// counters) to the simulated oracle. `--require-routing-win` gates
/// cost-model routing strictly beating round-robin on the modeled
/// makespan both sides of the stack agree on.
fn cmd_serve_fleet(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    anyhow::ensure!(
        flags.qps.is_empty() && flags.require_speedup.is_none() && flags.require_scaling.is_none(),
        "--qps / --require-speedup / --require-scaling apply to the homogeneous pool, not --fleet"
    );
    let path = flags.fleet.as_deref().unwrap();
    let spec = FleetSpec::load(path)?;
    spec.validate().map_err(|e| anyhow::anyhow!("invalid fleet spec {path}: {e}"))?;
    let policy = RoutePolicy::parse(&flags.route)?;

    let (class_graphs, class_names, class_sizes) = build_fleet_classes(cfg, flags)?;
    let graphs: Vec<&vta::graph::Graph> = class_graphs.iter().collect();

    // Trace: one full dynamic batch per device, classes split evenly
    // and proportionally interleaved (the interleave opens with the
    // *later* class, so a parity-pinned round-robin baseline does not
    // accidentally route like the cost model).
    let total = spec.total_devices() * flags.max_batch;
    let counts = split_requests(total, graphs.len());
    let classes = interleave_classes(&counts);
    let inputs: Vec<vta::util::Tensor<i8>> = classes
        .iter()
        .enumerate()
        .map(|(i, &c)| synth_input(7 + i as u64, 1, 3, class_sizes[c], class_sizes[c]))
        .collect();

    let records = match &flags.records {
        Some(path) => {
            let r = TuningRecords::load(path)?;
            println!("loaded {} tuning record(s) from {path}", r.len());
            r
        }
        None => TuningRecords::new(),
    };

    let fopts = FleetOptions {
        policy,
        max_batch: flags.max_batch,
        batch_deadline: flags.batch_deadline_ms * 1e-3,
        cache_capacity: flags.cache,
        virtual_threads: flags.vt,
        dram_size: 512 << 20,
    };
    let mut sched = FleetScheduler::with_records(&spec, CpuBackend::Native, fopts.clone(), records.clone());
    println!(
        "fleet of {} device(s) in {} config group(s) from {path} (route {:?}):",
        sched.devices(),
        sched.group_count(),
        policy
    );
    let group_cfgs = sched.group_configs();
    let group_devices = sched.group_devices();
    for (g, (gc, nd)) in group_cfgs.iter().zip(&group_devices).enumerate() {
        println!("  group {g}: {nd} device(s), {}", describe_config(gc));
    }
    let mix: Vec<String> = class_names
        .iter()
        .zip(&counts)
        .map(|(n, c)| format!("{c}x {n}"))
        .collect();
    println!("traffic: {} request(s) — {}; vt={}", classes.len(), mix.join(", "), flags.vt);

    for (i, &c) in classes.iter().enumerate() {
        sched.submit(0.0, c, inputs[i].clone());
    }
    let report = sched.run(&graphs)?;

    // Who went where.
    let mut routed = vec![vec![0usize; group_cfgs.len()]; graphs.len()];
    for (&c, &g) in report.classes.iter().zip(&report.routes) {
        routed[c][g] += 1;
    }
    for (c, name) in class_names.iter().enumerate() {
        let spread: Vec<String> =
            routed[c].iter().enumerate().map(|(g, n)| format!("g{g}:{n}")).collect();
        println!("routes for {name}: {}", spread.join(" "));
    }
    println!(
        "simulated fleet: {} batch(es), makespan {:.2} ms, modeled throughput {:.1} inf/s",
        report.batches.len(),
        report.makespan_seconds * 1e3,
        report.throughput()
    );
    for (g, stats) in report.group_cache.iter().enumerate() {
        println!(
            "  group {g} plan cache: {} miss(es) / {} hit(s) (lockstep across its replicas)",
            stats.misses, stats.hits
        );
    }
    let utils: Vec<String> = (0..sched.devices())
        .map(|d| {
            format!(
                "d{d}[{:08x}] {:.0}%",
                report.metrics.devices[d].config_fingerprint & 0xffff_ffff,
                report.utilization(d) * 100.0
            )
        })
        .collect();
    println!("per-device utilization (config fp): {}", utils.join(", "));

    // Self-verification, part 1: every request must be bit-identical
    // to a single-device ServingEngine built from its routed group's
    // exact config — heterogeneity must not change a single answer.
    for (g, gcfg) in group_cfgs.iter().enumerate() {
        let mut engine = ServingEngine::with_records(
            gcfg,
            512 << 20,
            CpuBackend::Native,
            flags.vt,
            flags.cache,
            records.clone(),
        );
        for (c, graph) in graphs.iter().enumerate() {
            let idxs: Vec<usize> = (0..classes.len())
                .filter(|&i| report.routes[i] == g && report.classes[i] == c)
                .collect();
            if idxs.is_empty() {
                continue;
            }
            let batch: Vec<_> = idxs.iter().map(|&i| inputs[i].clone()).collect();
            let out = engine.run_batch(graph, &batch)?;
            for (k, &i) in idxs.iter().enumerate() {
                anyhow::ensure!(
                    out.outputs[k] == report.outputs[i],
                    "fleet output {i} (class {c}, group {g}) diverged from the single-device engine"
                );
            }
        }
    }
    println!("fleet outputs match per-config single-device engines bit-exactly");

    // Self-verification, part 2: the same trace through the
    // real-threads fleet — outputs, routes, and per-group plan-cache
    // counters must all match the simulated oracle.
    let mut topts = FleetThreadedOptions::new(policy);
    topts.queue_capacity = flags.queue;
    topts.max_batch = flags.max_batch;
    topts.cache_capacity = flags.cache;
    topts.virtual_threads = flags.vt;
    topts.dram_size = 512 << 20;
    topts.serial_compile = flags.serial_compile;
    let trace: Vec<(usize, vta::util::Tensor<i8>)> =
        classes.iter().zip(&inputs).map(|(&c, t)| (c, t.clone())).collect();
    let threaded = serve_fleet_trace(&spec, &topts, &records, &graphs, &trace)?;
    anyhow::ensure!(
        threaded.outputs.len() == report.outputs.len(),
        "threaded fleet answered {} of {} requests",
        threaded.outputs.len(),
        report.outputs.len()
    );
    for (i, out) in threaded.outputs.iter().enumerate() {
        anyhow::ensure!(
            out == &report.outputs[i],
            "threaded fleet output {i} diverged from the simulated oracle"
        );
    }
    anyhow::ensure!(
        threaded.routes == report.routes,
        "threaded fleet routed the trace differently from the simulated oracle"
    );
    for (g, (t, s)) in threaded.group_cache.iter().zip(&report.group_cache).enumerate() {
        anyhow::ensure!(
            t.misses == s.misses && t.hits == s.hits,
            "group {g} plan directory ({} misses / {} hits) fell out of step with the \
             oracle ({} misses / {} hits)",
            t.misses,
            t.hits,
            s.misses,
            s.hits
        );
    }
    println!(
        "threaded fleet ({} worker(s), wall {:.2?}, {:.1} inf/s) matches the simulated \
         oracle bit-exactly (outputs, routes, per-group caches)",
        spec.total_devices(),
        threaded.wall,
        threaded.throughput_rps()
    );
    println!(
        "fleet contention: {} queue-full rejection(s), {} compile-claim wait(s), \
         {} directory lock acquisition(s)",
        threaded.contention.queue_full,
        threaded.contention.claim_waits,
        threaded.contention.directory_locks
    );

    // The routing ablation: the same trace under cost-model and
    // round-robin routing, scored by the modeled makespan both `dse
    // --fleet` and this gate optimize.
    let cm_routes = Router::new(RoutePolicy::CostModel, &group_cfgs, &graphs).route_trace(&classes);
    let rr_routes =
        Router::new(RoutePolicy::RoundRobin, &group_cfgs, &graphs).route_trace(&classes);
    let cm = modeled_fleet_makespan(&group_cfgs, &group_devices, &graphs, &classes, &cm_routes);
    let rr = modeled_fleet_makespan(&group_cfgs, &group_devices, &graphs, &classes, &rr_routes);
    println!(
        "modeled makespan: cost-model routing {:.3} ms vs round-robin {:.3} ms ({:.2}x)",
        cm * 1e3,
        rr * 1e3,
        rr / cm.max(1e-12)
    );
    if flags.require_routing_win {
        anyhow::ensure!(
            sched.group_count() >= 2,
            "--require-routing-win needs a fleet with >= 2 config groups (got {})",
            sched.group_count()
        );
        // Simulated round-robin run for visibility alongside the gate.
        let mut rr_opts = fopts;
        rr_opts.policy = RoutePolicy::RoundRobin;
        let mut rr_sched =
            FleetScheduler::with_records(&spec, CpuBackend::Native, rr_opts, records.clone());
        for (i, &c) in classes.iter().enumerate() {
            rr_sched.submit(0.0, c, inputs[i].clone());
        }
        let rr_report = rr_sched.run(&graphs)?;
        println!(
            "simulated makespan: {:?} routing {:.2} ms vs round-robin {:.2} ms",
            policy,
            report.makespan_seconds * 1e3,
            rr_report.makespan_seconds * 1e3
        );
        anyhow::ensure!(
            cm < rr,
            "cost-model routing ({:.3} ms modeled) does not beat round-robin ({:.3} ms)",
            cm * 1e3,
            rr * 1e3
        );
        println!("routing gate passed: cost-model beats round-robin by {:.2}x", rr / cm);
    }
    Ok(())
}

/// `vta dse`: budgeted random + greedy-refine search over hardware
/// variants and per-operator schedules; prints the top-k frontier with
/// roofline placement and optionally persists the tuning records.
/// `--config` sets the baseline variant the search must match or beat
/// (and which enters the search tuned, as candidate zero).
fn cmd_dse(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    let workloads = vta::dse::suite(&flags.workload)?;
    let names: Vec<&str> = workloads.iter().map(|w| w.name()).collect();
    println!(
        "DSE: budget {} candidates, {} tune trials/op, vt={}, seed {}, suite {:?} ({}), \
         scored for a pool of {} device(s)",
        flags.budget,
        flags.tune_trials,
        flags.vt,
        flags.seed,
        flags.workload,
        names.join(", "),
        flags.devices
    );
    let mut opts = DseOptions::new(workloads);
    opts.baseline = cfg.clone();
    opts.budget = flags.budget;
    opts.tune_trials = flags.tune_trials;
    opts.virtual_threads = flags.vt;
    opts.seed = flags.seed;
    opts.top_k = flags.top;
    opts.pool_devices = flags.devices;

    let t0 = std::time::Instant::now();
    let report = run_dse(&opts)?;
    println!(
        "evaluated {} candidate(s) ({} infeasible) in {:.1?}\n",
        report.evaluated,
        report.infeasible,
        t0.elapsed()
    );

    let base = &report.baseline;
    println!(
        "baseline ({} @ {:.0} MHz, default schedules): {} total cycles over the suite",
        base.cfg.gemm,
        base.cfg.clock_hz / 1e6,
        base.total_cycles
    );
    if flags.devices > 1 {
        println!(
            "pool objective ({} devices, least-loaded): baseline makespan {} cycles; \
             candidates rank by pool makespan",
            flags.devices, base.pool_cycles
        );
    }
    println!(
        "{:<4} {:>9} {:>14} {:>8} {:>22} {:>8} {:>6} {:>7}",
        "rank", "gemm", "total cycles", "vs base", "buffers i/w/a/o/u kB", "bram18", "dsp", "tuned"
    );
    for (rank, cand) in report.frontier.iter().enumerate() {
        let c = &cand.cfg;
        let tuned = cand.scores.iter().filter(|s| s.choice.is_some()).count();
        println!(
            "{:<4} {:>9} {:>14} {:>7.2}x {:>22} {:>8} {:>6} {:>7}",
            rank + 1,
            format!("{}", c.gemm),
            cand.total_cycles,
            base.total_cycles as f64 / cand.total_cycles as f64,
            format!(
                "{}/{}/{}/{}/{}",
                c.inp_buf_bytes / 1024,
                c.wgt_buf_bytes / 1024,
                c.acc_buf_bytes / 1024,
                c.out_buf_bytes / 1024,
                c.uop_buf_bytes / 1024
            ),
            cand.usage.bram18,
            cand.usage.dsp,
            tuned
        );
    }

    // Roofline placement of the best candidate, per workload.
    let best = report.best();
    let roof = Roofline::of(&best.cfg);
    println!(
        "\nbest candidate roofline (peak {:.1} GOPS, knee {:.1} ops/byte):",
        roof.peak_gops(),
        roof.knee_intensity()
    );
    println!(
        "{:<8} {:>12} {:>12} {:>8} {:>30}",
        "workload", "cycles", "baseline", "speedup", "tuned schedule"
    );
    for (s, b) in best.scores.iter().zip(&base.scores) {
        println!(
            "{:<8} {:>12} {:>12} {:>7.2}x {:>30}",
            s.name,
            s.cycles,
            b.cycles,
            b.cycles as f64 / s.cycles as f64,
            match s.choice {
                Some(c) => format!("{c:?}"),
                None => "planner default".to_string(),
            }
        );
    }
    println!(
        "\nbest candidate resources: {} BRAM18, {} DSP, {} LUT (Zynq-7020 budget: 280/220/53200)",
        best.usage.bram18, best.usage.dsp, best.usage.lut
    );

    if let Some(path) = &flags.records {
        let store = report.export_records();
        store.save(path)?;
        println!(
            "persisted {} tuning record(s) to {path} — replay with `vta serve --records {path}`",
            store.len()
        );
    }

    // ---- fleet allocation: compose the frontier, don't just rank it ----
    if let Some(path) = &flags.fleet {
        let mut candidates: Vec<VtaConfig> =
            report.frontier.iter().map(|c| c.cfg.clone()).collect();
        candidates.push(cfg.clone());
        let (class_graphs, class_names, _) = build_fleet_classes(cfg, flags)?;
        let graphs: Vec<&vta::graph::Graph> = class_graphs.iter().collect();
        let per_class =
            split_requests(flags.fleet_devices * flags.max_batch, graphs.len());
        let mut fopts = FleetDseOptions::new(flags.fleet_devices, per_class.clone());
        fopts.virtual_threads = flags.vt;
        if let Some((bram18, dsp, lut)) = flags.fleet_budget {
            fopts.budget = ResourceBudget { bram18, dsp, lut };
        }
        let names: Vec<String> = class_names
            .iter()
            .zip(&per_class)
            .map(|(n, c)| format!("{c}x {n}"))
            .collect();
        println!(
            "\nfleet allocation: up to {} device(s), budget {}/{}/{} BRAM18/DSP/LUT, \
             traffic {}",
            flags.fleet_devices,
            fopts.budget.bram18,
            fopts.budget.dsp,
            fopts.budget.lut,
            names.join(" + ")
        );
        let freport = run_fleet_dse(&candidates, &graphs, &fopts)?;
        println!(
            "enumerated {} composition(s) over {} candidate config(s) ({} infeasible)",
            freport.evaluated, freport.candidates, freport.infeasible
        );
        let best = &freport.best;
        println!("best fleet (modeled makespan {:.3} ms cost-routed, {:.3} ms round-robin):",
            best.cost_makespan * 1e3,
            best.roundrobin_makespan * 1e3
        );
        for m in &best.spec.members {
            println!("  {} x {}", m.devices, describe_config(&m.cfg));
        }
        println!(
            "  resources {}/{}/{} BRAM18/DSP/LUT{}",
            best.usage.bram18,
            best.usage.dsp,
            best.usage.lut,
            if best.homogeneous { " (homogeneous)" } else { " (mixed-config)" }
        );
        let homog = &freport.best_homogeneous;
        println!(
            "best homogeneous pool: {} x {} — modeled makespan {:.3} ms ({:.2}x vs fleet)",
            homog.spec.members[0].devices,
            describe_config(&homog.spec.members[0].cfg),
            homog.cost_makespan * 1e3,
            homog.cost_makespan / best.cost_makespan.max(1e-12)
        );
        best.spec.save(path)?;
        println!(
            "wrote the winning FleetSpec to {path} — serve it with \
             `vta serve --fleet {path} --model mixed`"
        );
        if flags.require_fleet_improvement && !freport.improved() {
            anyhow::bail!(
                "best fleet ({:.6} ms) does not match the best homogeneous pool ({:.6} ms)",
                best.cost_makespan * 1e3,
                homog.cost_makespan * 1e3
            );
        }
        if flags.require_fleet_improvement {
            println!("fleet gate passed: best fleet matches/beats the best homogeneous pool");
        }
    } else {
        anyhow::ensure!(
            !flags.require_fleet_improvement,
            "--require-fleet-improvement needs --fleet OUT.json"
        );
    }

    if flags.require_improvement && !report.improved() {
        anyhow::bail!(
            "no candidate matched the baseline: best pool makespan {} > baseline {}",
            report.best().pool_cycles,
            report.baseline.pool_cycles
        );
    }
    Ok(())
}

fn cmd_resnet(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    let (mut g, fused) = fuse(resnet::resnet18(1, 42)?)?;
    let (vta_n, cpu_n) = partition(&mut g, &build_policy(cfg, flags));
    println!("ResNet-18: {} nodes ({fused} fused), {vta_n} on VTA, {cpu_n} on CPU", g.nodes.len());

    let cpu = if flags.pjrt {
        CpuBackend::Pjrt(PjrtCache::new("artifacts")?)
    } else {
        CpuBackend::Native
    };
    let mut ex = Executor::with_virtual_threads(VtaRuntime::new(cfg, 512 << 20), cpu, flags.vt);
    let input = synth_input(7, 1, 3, 224, 224);
    let t0 = std::time::Instant::now();
    let report = ex.run(&g, &input)?;
    let wall = t0.elapsed();

    println!(
        "\n{:<22} {:>6} {:>5} {:>12} {:>12} {:>8}",
        "node", "kind", "place", "cpu wall", "sim (ms)", "GOPs"
    );
    for n in &report.nodes {
        if n.kind == "input" {
            continue;
        }
        println!(
            "{:<22} {:>6} {:>5} {:>12.3?} {:>12.3} {:>8.3}",
            n.name,
            n.kind,
            match n.placement {
                Placement::Vta => "VTA",
                _ => "CPU",
            },
            n.wall,
            n.sim_seconds * 1e3,
            n.ops as f64 / 1e9
        );
    }
    println!(
        "\ntotals: cpu {:.3?}, vta-simulated {:.3} ms, model total {:.3} ms (host wall {wall:.2?})",
        report.cpu_time(),
        report.vta_seconds() * 1e3,
        report.total_seconds() * 1e3
    );
    let s = report.vta_stats();
    if s.total_cycles > 0 {
        println!(
            "vta: {} cycles, GEMM utilization {:.0}%, {:.1} MB DRAM traffic",
            s.total_cycles,
            s.compute_utilization() * 100.0,
            s.bytes_moved() as f64 / 1e6
        );
    }
    Ok(())
}

/// `vta style`: run the fast style-transfer network end-to-end, print
/// the per-node Fig 16-style breakdown, and verify the heterogeneous
/// output against the CPU reference bit-exactly — the acceptance check
/// that the microcode ISA absorbed the new operator classes
/// (Upsample2x, Min, Shr) without variant-matching regressions.
fn cmd_style(cfg: &VtaConfig, flags: &Flags) -> anyhow::Result<()> {
    let (mut g, fused) = build_style(flags)?;
    let (vta_n, cpu_n) = partition(&mut g, &build_policy(cfg, flags));
    println!(
        "style-transfer ({0}x{0}): {1} nodes ({fused} fused), {vta_n} on VTA, {cpu_n} on CPU",
        flags.size,
        g.nodes.len()
    );

    let cpu = if flags.pjrt {
        CpuBackend::Pjrt(PjrtCache::new("artifacts")?)
    } else {
        CpuBackend::Native
    };
    let mut ex = Executor::with_virtual_threads(VtaRuntime::new(cfg, 512 << 20), cpu, flags.vt);
    let input = synth_input(7, 1, 3, flags.size, flags.size);
    let t0 = std::time::Instant::now();
    let report = ex.run(&g, &input)?;
    let wall = t0.elapsed();

    println!(
        "\n{:<14} {:>10} {:>5} {:>12} {:>12} {:>8}",
        "node", "kind", "place", "cpu wall", "sim (ms)", "MOPs"
    );
    for n in &report.nodes {
        if n.kind == "input" {
            continue;
        }
        println!(
            "{:<14} {:>10} {:>5} {:>12.3?} {:>12.3} {:>8.3}",
            n.name,
            n.kind,
            match n.placement {
                Placement::Vta => "VTA",
                _ => "CPU",
            },
            n.wall,
            n.sim_seconds * 1e3,
            n.ops as f64 / 1e6
        );
    }
    println!(
        "\ntotals: cpu {:.3?}, vta-simulated {:.3} ms, model total {:.3} ms (host wall {wall:.2?})",
        report.cpu_time(),
        report.vta_seconds() * 1e3,
        report.total_seconds() * 1e3
    );

    // Golden check: the heterogeneous run must be bit-identical to the
    // CPU-only reference.
    let (mut g_ref, _) = build_style(flags)?;
    partition(&mut g_ref, &PartitionPolicy::cpu_only());
    let mut cpu_ex = Executor::new(VtaRuntime::new(cfg, 512 << 20), CpuBackend::Native);
    let expect = cpu_ex.run(&g_ref, &input)?.output;
    anyhow::ensure!(
        report.output == expect,
        "heterogeneous style output diverged from the CPU reference"
    );
    println!("output matches the CPU reference bit-exactly");
    Ok(())
}
