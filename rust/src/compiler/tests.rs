use super::layout::*;
use super::plan::*;
use super::reference::*;
use super::*;
use crate::arch::VtaConfig;
use crate::runtime::VtaRuntime;
use crate::util::{Tensor, XorShiftRng};

fn rq() -> Requant {
    Requant { shift: 6, relu: false }
}

fn random_nchw(rng: &mut XorShiftRng, shape: &[usize]) -> Tensor<i8> {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, rng.vec_i8(n, -5, 5)).unwrap()
}

// ---------------------------------------------------------------------
// Layout pack/unpack.
// ---------------------------------------------------------------------

#[test]
fn activation_pack_unpack_roundtrip() {
    let cfg = VtaConfig::pynq();
    let mut rng = XorShiftRng::new(1);
    for (c, h, w) in [(16, 4, 5), (3, 7, 7), (48, 2, 3)] {
        let t = random_nchw(&mut rng, &[1, c, h, w]);
        let packed = pack_activations(&cfg, &t);
        assert_eq!(packed.len(), blocks(c, 16) * h * w * 16);
        let back = unpack_activations(&cfg, &packed, 1, c, h, w);
        assert_eq!(back, t);
    }
}

#[test]
fn weight_pack_pads_partial_blocks_with_zero() {
    let cfg = VtaConfig::pynq();
    let mut rng = XorShiftRng::new(2);
    let t = random_nchw(&mut rng, &[20, 3, 3, 3]); // 20 oc → 2 blocks, 3 ic → 1 block
    let packed = pack_weights(&cfg, &t);
    assert_eq!(packed.len(), 2 * 1 * 3 * 3 * 256);
    // Tile (ob=1, ib=0, kh=0, kw=0), row oo=15 maps to ochan 31 > 19: zero.
    let tile = (1 * 1 * 3 + 0) * 3 + 0;
    assert!(packed[tile * 256 + 15 * 16..tile * 256 + 16 * 16].iter().all(|&v| v == 0));
    // ichan 3..16 of a real output channel: zero.
    let tile0 = 0;
    assert!(packed[tile0 * 256 + 3..tile0 * 256 + 16].iter().all(|&v| v == 0));
}

#[test]
fn matrix_pack_roundtrip() {
    let cfg = VtaConfig::pynq();
    let mut rng = XorShiftRng::new(3);
    let a = random_nchw(&mut rng, &[4, 40]);
    let packed = pack_matrix_a(&cfg, &a.clone().reshape(&[4, 40]).unwrap());
    assert_eq!(packed.len(), 4 * 3 * 16); // 4 rows x 3 k-blocks x 16
    // spot-check element (2, 17): tile 2*3+1, lane 1.
    assert_eq!(packed[(2 * 3 + 1) * 16 + 1], a.at(&[2, 17]).unwrap());
}

// ---------------------------------------------------------------------
// Planner.
// ---------------------------------------------------------------------

fn table1() -> Vec<(&'static str, Conv2dParams)> {
    let q = rq();
    vec![
        ("C1", Conv2dParams { h: 224, w: 224, ic: 3, oc: 64, k: 7, s: 2, requant: q }),
        ("C2", Conv2dParams { h: 56, w: 56, ic: 64, oc: 64, k: 3, s: 1, requant: q }),
        ("C3", Conv2dParams { h: 56, w: 56, ic: 64, oc: 64, k: 1, s: 1, requant: q }),
        ("C4", Conv2dParams { h: 56, w: 56, ic: 64, oc: 128, k: 3, s: 2, requant: q }),
        ("C5", Conv2dParams { h: 56, w: 56, ic: 64, oc: 128, k: 1, s: 2, requant: q }),
        ("C6", Conv2dParams { h: 28, w: 28, ic: 128, oc: 128, k: 3, s: 1, requant: q }),
        ("C7", Conv2dParams { h: 28, w: 28, ic: 128, oc: 256, k: 3, s: 2, requant: q }),
        ("C8", Conv2dParams { h: 28, w: 28, ic: 128, oc: 256, k: 1, s: 2, requant: q }),
        ("C9", Conv2dParams { h: 14, w: 14, ic: 256, oc: 256, k: 3, s: 1, requant: q }),
        ("C10", Conv2dParams { h: 14, w: 14, ic: 256, oc: 512, k: 3, s: 2, requant: q }),
        ("C11", Conv2dParams { h: 14, w: 14, ic: 256, oc: 512, k: 1, s: 2, requant: q }),
        ("C12", Conv2dParams { h: 7, w: 7, ic: 512, oc: 512, k: 3, s: 1, requant: q }),
    ]
}

#[test]
fn planner_handles_every_table1_layer() {
    let cfg = VtaConfig::pynq();
    for vt in [1, 2] {
        for (name, p) in table1() {
            let plan = plan_conv2d(&cfg, &p, vt)
                .unwrap_or_else(|e| panic!("{name} vt={vt}: {e}"));
            // Capacity invariants.
            assert!(plan.acc_tiles() <= cfg.acc_depth() / vt, "{name} acc");
            assert!(plan.inp_tiles() <= cfg.inp_depth() / vt, "{name} inp");
            assert!(plan.wgt_tiles(p.k) <= cfg.wgt_depth(), "{name} wgt");
            assert!(plan.main_uops(p.k) <= cfg.uop_depth(), "{name} uop");
            // Full coverage.
            assert_eq!(plan.oh, p.out_h());
            assert_eq!(plan.ow, p.out_w());
        }
    }
}

#[test]
fn planner_output_geometry_matches_table1() {
    // Spot checks of SAME geometry from the paper's Table 1.
    let p = &table1()[0].1; // C1: 224/2 = 112
    // SAME with k=7,s=2 needs total padding 5 → begin pad 2 (the
    // trailing row is covered by the load module's dynamic bottom pad).
    assert_eq!((p.out_h(), p.out_w(), p.pad()), (112, 112, 2));
    let p = &table1()[3].1; // C4: 56/2 = 28, k3 s2
    assert_eq!((p.out_h(), p.out_w()), (28, 28));
    let p = &table1()[10].1; // C11: 1x1 s2 → no pad
    assert_eq!((p.out_h(), p.out_w(), p.pad()), (7, 7, 0));
}

#[test]
fn planner_rejects_impossible_configs() {
    let mut cfg = VtaConfig::pynq();
    cfg.wgt_buf_bytes = 2 * cfg.wgt_tile_bytes(); // 2-tile weight buffer
    let p = Conv2dParams { h: 8, w: 8, ic: 64, oc: 16, k: 3, s: 1, requant: rq() };
    assert!(matches!(plan_conv2d(&cfg, &p, 1), Err(PlanError::WeightsDontFit { .. })));
}

#[test]
fn matmul_planner_rejects_bad_batch() {
    let cfg = VtaConfig::bandwidth_example(); // BATCH = 2
    let p = MatmulParams { m: 3, k: 32, n: 32, requant: rq() };
    assert!(matches!(plan_matmul(&cfg, &p, 1), Err(PlanError::BadBatch { .. })));
}

// ---------------------------------------------------------------------
// Lowered conv2d vs reference (the core correctness property).
// ---------------------------------------------------------------------

fn run_conv_case(p: &Conv2dParams, vt: usize, seed: u64) {
    let cfg = VtaConfig::pynq();
    let mut rng = XorShiftRng::new(seed);
    let inp = random_nchw(&mut rng, &[1, p.ic, p.h, p.w]);
    let wgt = random_nchw(&mut rng, &[p.oc, p.ic, p.k, p.k]);

    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    let out = lower_conv2d(
        &mut rt,
        p,
        &pack_activations(&cfg, &inp),
        &pack_weights(&cfg, &wgt),
        vt,
    )
    .unwrap();
    let got = unpack_outputs(&cfg, &out.out, 1, p.oc, p.out_h(), p.out_w());
    let expect = conv2d_ref(p, &inp, &wgt);
    assert_eq!(got, expect, "conv mismatch (vt={vt}, p={p:?})");
}

#[test]
fn conv_3x3_small_matches_reference() {
    let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 16, k: 3, s: 1, requant: rq() };
    run_conv_case(&p, 1, 10);
    run_conv_case(&p, 2, 11);
}

#[test]
fn conv_1x1_matches_reference() {
    let p = Conv2dParams { h: 6, w: 6, ic: 32, oc: 32, k: 1, s: 1, requant: rq() };
    run_conv_case(&p, 2, 12);
}

#[test]
fn conv_strided_matches_reference() {
    let p = Conv2dParams { h: 12, w: 12, ic: 16, oc: 32, k: 3, s: 2, requant: rq() };
    run_conv_case(&p, 2, 13);
}

#[test]
fn conv_7x7_stride2_padded_channels_matches_reference() {
    // C1-like: 3 input channels padded to one block, 7x7 stride 2.
    let p = Conv2dParams { h: 20, w: 20, ic: 3, oc: 16, k: 7, s: 2, requant: rq() };
    run_conv_case(&p, 1, 14);
    run_conv_case(&p, 2, 15);
}

#[test]
fn conv_relu_requant_matches_reference() {
    let p = Conv2dParams {
        h: 8,
        w: 8,
        ic: 16,
        oc: 16,
        k: 3,
        s: 1,
        requant: Requant { shift: 4, relu: true },
    };
    run_conv_case(&p, 2, 16);
}

/// Property sweep: randomized conv shapes, both threading modes.
#[test]
fn conv_property_sweep() {
    let mut rng = XorShiftRng::new(0xABCD);
    for trial in 0..8 {
        let k = [1usize, 3, 5][rng.next_below(3) as usize];
        let s = 1 + rng.next_below(2) as usize;
        let h = (k + s + 2 + rng.next_below(8) as usize).min(14);
        let p = Conv2dParams {
            h,
            w: h,
            ic: 16 * (1 + rng.next_below(2) as usize),
            oc: 16 * (1 + rng.next_below(2) as usize),
            k,
            s,
            requant: Requant { shift: rng.next_below(8) as u8, relu: rng.next_below(2) == 1 },
        };
        let vt = 1 + (trial % 2);
        run_conv_case(&p, vt, 100 + trial as u64);
    }
}

/// Virtual threading must not change results, only timing.
#[test]
fn virtual_threading_is_semantically_transparent_and_faster() {
    let cfg = VtaConfig::pynq();
    let p = Conv2dParams { h: 28, w: 28, ic: 64, oc: 64, k: 3, s: 1, requant: rq() };
    let mut rng = XorShiftRng::new(77);
    let inp = random_nchw(&mut rng, &[1, p.ic, p.h, p.w]);
    let wgt = random_nchw(&mut rng, &[p.oc, p.ic, p.k, p.k]);
    let ip = pack_activations(&cfg, &inp);
    let wp = pack_weights(&cfg, &wgt);

    let mut rt1 = VtaRuntime::new(&cfg, 64 << 20);
    let o1 = lower_conv2d(&mut rt1, &p, &ip, &wp, 1).unwrap();
    let mut rt2 = VtaRuntime::new(&cfg, 64 << 20);
    let o2 = lower_conv2d(&mut rt2, &p, &ip, &wp, 2).unwrap();

    assert_eq!(o1.out, o2.out, "virtual threading changed results");
    assert_eq!(o1.stats.gemm_uops, o2.stats.gemm_uops);
    assert!(
        o2.stats.total_cycles < o1.stats.total_cycles,
        "latency hiding did not help: vt2 {} !< vt1 {}",
        o2.stats.total_cycles,
        o1.stats.total_cycles
    );
}

// ---------------------------------------------------------------------
// Compile-once / run-many (the plan-cache substrate).
// ---------------------------------------------------------------------

/// A compiled conv2d replays correctly across many inputs: every
/// execution matches the host reference, and the simulated timing is
/// identical run to run (the streams are deterministic).
#[test]
fn compiled_conv_replays_across_inputs() {
    let cfg = VtaConfig::pynq();
    let p = Conv2dParams { h: 10, w: 10, ic: 16, oc: 32, k: 3, s: 1, requant: rq() };
    let mut rng = XorShiftRng::new(31);
    let wgt = random_nchw(&mut rng, &[p.oc, p.ic, p.k, p.k]);

    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    let compiled = compile_conv2d(&mut rt, &p, &pack_weights(&cfg, &wgt), 2).unwrap();
    assert!(!compiled.streams.is_empty());

    let mut cycles = Vec::new();
    for seed in 0..3u64 {
        let mut rng = XorShiftRng::new(40 + seed);
        let inp = random_nchw(&mut rng, &[1, p.ic, p.h, p.w]);
        let (out, stats) = compiled.execute(&mut rt, &[pack_activations(&cfg, &inp)]).unwrap();
        let got = unpack_outputs(&cfg, &out, 1, p.oc, p.out_h(), p.out_w());
        assert_eq!(got, conv2d_ref(&p, &inp, &wgt), "replay {seed} diverged");
        cycles.push(stats.total_cycles);
    }
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "replay timing drifted: {cycles:?}");
    compiled.free(&mut rt).unwrap();
}

/// The compiled path and the one-shot lowering path are equivalent:
/// identical outputs AND identical simulated cycle counts.
#[test]
fn compiled_conv_matches_lower_conv2d() {
    let cfg = VtaConfig::pynq();
    let p = Conv2dParams { h: 12, w: 12, ic: 32, oc: 16, k: 3, s: 2, requant: rq() };
    let mut rng = XorShiftRng::new(51);
    let inp = random_nchw(&mut rng, &[1, p.ic, p.h, p.w]);
    let wgt = random_nchw(&mut rng, &[p.oc, p.ic, p.k, p.k]);
    let ip = pack_activations(&cfg, &inp);
    let wp = pack_weights(&cfg, &wgt);

    let mut rt1 = VtaRuntime::new(&cfg, 64 << 20);
    let one_shot = lower_conv2d(&mut rt1, &p, &ip, &wp, 2).unwrap();

    let mut rt2 = VtaRuntime::new(&cfg, 64 << 20);
    let compiled = compile_conv2d(&mut rt2, &p, &wp, 2).unwrap();
    let (out, stats) = compiled.execute(&mut rt2, &[ip.clone()]).unwrap();

    assert_eq!(out, one_shot.out, "compiled vs one-shot output");
    assert_eq!(
        stats.total_cycles, one_shot.stats.total_cycles,
        "compiled vs one-shot timing"
    );
    assert_eq!(stats.gemm_uops, one_shot.stats.gemm_uops);
}

/// Plans that drain between groups compile into multiple sealed
/// streams (one per group) and still replay correctly — the
/// self-containment property of sealed streams.
#[test]
fn compiled_conv_drain_groups_replays() {
    let mut cfg = VtaConfig::pynq();
    // A huge first-beat latency makes double-buffered weight groups
    // load-latency-bound, so the planner falls back to draining the
    // pipeline between groups (the C12-on-Pynq regime).
    cfg.dram.latency = 100_000;
    let p = Conv2dParams { h: 8, w: 8, ic: 128, oc: 256, k: 3, s: 1, requant: rq() };
    let plan = plan_conv2d(&cfg, &p, 2).unwrap();
    assert!(plan.drain_groups, "test premise: this config must drain between groups");
    assert!(plan.groups() > 1);

    let mut rng = XorShiftRng::new(61);
    let inp = random_nchw(&mut rng, &[1, p.ic, p.h, p.w]);
    let wgt = random_nchw(&mut rng, &[p.oc, p.ic, p.k, p.k]);

    let mut rt = VtaRuntime::new(&cfg, 128 << 20);
    let compiled = compile_conv2d(&mut rt, &p, &pack_weights(&cfg, &wgt), 2).unwrap();
    assert_eq!(compiled.streams.len(), plan.groups(), "one sealed stream per drained group");

    let expect = conv2d_ref(&p, &inp, &wgt);
    for _ in 0..2 {
        let (out, _) = compiled.execute(&mut rt, &[pack_activations(&cfg, &inp)]).unwrap();
        assert_eq!(unpack_outputs(&cfg, &out, 1, p.oc, p.out_h(), p.out_w()), expect);
    }
    compiled.free(&mut rt).unwrap();
}

/// Freeing a compiled plan returns every byte of its DRAM residency.
#[test]
fn compiled_conv_free_releases_dram() {
    let cfg = VtaConfig::pynq();
    let p = Conv2dParams { h: 8, w: 8, ic: 16, oc: 16, k: 3, s: 1, requant: rq() };
    let mut rng = XorShiftRng::new(71);
    let wgt = random_nchw(&mut rng, &[p.oc, p.ic, p.k, p.k]);

    let mut rt = VtaRuntime::new(&cfg, 64 << 20);
    let used0 = rt.dram.used();
    let compiled = compile_conv2d(&mut rt, &p, &pack_weights(&cfg, &wgt), 2).unwrap();
    assert!(rt.dram.used() > used0, "plan holds DRAM residency");
    compiled.free(&mut rt).unwrap();
    assert_eq!(rt.dram.used(), used0, "free leaked DRAM");
}

// ---------------------------------------------------------------------
// Lowered matmul vs reference.
// ---------------------------------------------------------------------

fn run_matmul_case(p: &MatmulParams, vt: usize, seed: u64) {
    let cfg = VtaConfig::pynq();
    let mut rng = XorShiftRng::new(seed);
    let a = random_nchw(&mut rng, &[p.m, p.k]);
    let w = random_nchw(&mut rng, &[p.n, p.k]);
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let out =
        lower_matmul(&mut rt, p, &pack_matrix_a(&cfg, &a), &pack_matrix_w(&cfg, &w), vt).unwrap();
    let got = unpack_matrix_c(&cfg, &out.out, p.m, p.n);
    assert_eq!(got, matmul_ref(p, &a, &w), "matmul mismatch (vt={vt}, p={p:?})");
}

#[test]
fn matmul_square_matches_reference() {
    let p = MatmulParams { m: 8, k: 64, n: 64, requant: rq() };
    run_matmul_case(&p, 1, 20);
    run_matmul_case(&p, 2, 21);
}

#[test]
fn matmul_ragged_dims_match_reference() {
    // K and N not multiples of the block sizes → zero-padded tiles.
    let p = MatmulParams { m: 4, k: 40, n: 50, requant: rq() };
    run_matmul_case(&p, 2, 22);
}

#[test]
fn matmul_fc_shape_matches_reference() {
    // ResNet-18 classifier: 512 → 1000 (batch of 2 rows).
    let p = MatmulParams { m: 2, k: 512, n: 1000, requant: Requant { shift: 7, relu: false } };
    run_matmul_case(&p, 2, 23);
}

// ---------------------------------------------------------------------
// Compiled dense (the Dense-offload path).
// ---------------------------------------------------------------------

/// The compiled dense path matches both the one-shot matmul lowering
/// (bytes and cycles) and the host reference, and replays across
/// inputs.
#[test]
fn compiled_dense_matches_lower_matmul_and_reference() {
    let cfg = VtaConfig::pynq();
    let p = MatmulParams { m: 4, k: 40, n: 50, requant: rq() };
    let mut rng = XorShiftRng::new(81);
    let w = random_nchw(&mut rng, &[p.n, p.k]);
    let wp = pack_matrix_w(&cfg, &w);

    let mut rt = VtaRuntime::new(&cfg, 32 << 20);
    let compiled = compile_dense(&mut rt, &p, &wp, 2).unwrap();
    assert!(!compiled.streams.is_empty());

    for seed in 0..3u64 {
        let mut rng = XorShiftRng::new(90 + seed);
        let a = random_nchw(&mut rng, &[p.m, p.k]);
        let ap = pack_matrix_a(&cfg, &a);

        let mut rt1 = VtaRuntime::new(&cfg, 32 << 20);
        let one_shot = lower_matmul(&mut rt1, &p, &ap, &wp, 2).unwrap();

        let (out, stats) = compiled.execute(&mut rt, &[ap]).unwrap();
        assert_eq!(out, one_shot.out, "compiled vs one-shot dense output (seed {seed})");
        assert_eq!(stats.gemm_uops, one_shot.stats.gemm_uops);
        let got = unpack_matrix_c(&cfg, &out, p.m, p.n);
        assert_eq!(got, matmul_ref(&p, &a, &w), "replay {seed} diverged from reference");
    }
    compiled.free(&mut rt).unwrap();
}

/// Freeing a compiled dense plan returns every byte of its DRAM
/// residency.
#[test]
fn compiled_dense_free_releases_dram() {
    let cfg = VtaConfig::pynq();
    let p = MatmulParams { m: 1, k: 64, n: 32, requant: rq() };
    let mut rng = XorShiftRng::new(83);
    let w = random_nchw(&mut rng, &[p.n, p.k]);

    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let used0 = rt.dram.used();
    let compiled = compile_dense(&mut rt, &p, &pack_matrix_w(&cfg, &w), 2).unwrap();
    assert!(rt.dram.used() > used0, "plan holds DRAM residency");
    compiled.free(&mut rt).unwrap();
    assert_eq!(rt.dram.used(), used0, "free leaked DRAM");
}

// ---------------------------------------------------------------------
// Elementwise operators on the tensor-ALU path.
// ---------------------------------------------------------------------

fn random_wide(rng: &mut XorShiftRng, shape: &[usize]) -> Tensor<i8> {
    // Wide range so saturating adds actually saturate.
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, rng.vec_i8(n, -120, 120)).unwrap()
}

/// Saturating ALU add matches the host semantics across a tensor big
/// enough to strip-mine over multiple register-file chunks and both
/// contexts — including lanes that saturate.
#[test]
fn compiled_eltwise_add_matches_reference() {
    let cfg = VtaConfig::pynq();
    let shape = [1usize, 64, 32, 32]; // 65536 lanes → 4096 tiles → 8 strips
    let mut rng = XorShiftRng::new(91);
    let a = random_wide(&mut rng, &shape);
    let b = random_wide(&mut rng, &shape);

    for vt in [1usize, 2] {
        let mut rt = VtaRuntime::new(&cfg, 64 << 20);
        let compiled =
            compile_eltwise(&mut rt, EltwiseKind::AddSat, a.len(), vt).unwrap();
        let packed = vec![pack_acc_i32(&cfg, &a), pack_acc_i32(&cfg, &b)];
        let (out, stats) = compiled.execute(&mut rt, &packed).unwrap();
        let got = unpack_eltwise(&out, &shape);
        assert_eq!(got, add_i8(&a, &b), "ALU add diverged from reference (vt={vt})");
        assert!(stats.alu_uops > 0, "the ALU must have executed micro-ops");
        compiled.free(&mut rt).unwrap();
    }
}

/// ALU ReLU matches the host semantics, replays across inputs with
/// identical timing, and handles a ragged tail (length not a multiple
/// of the tile lanes).
#[test]
fn compiled_eltwise_relu_matches_reference() {
    let cfg = VtaConfig::pynq();
    let shape = [1usize, 3, 21, 21]; // 1323 lanes: ragged tail tile
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let len: usize = shape.iter().product();
    let compiled = compile_eltwise(&mut rt, EltwiseKind::Relu, len, 2).unwrap();

    let mut cycles = Vec::new();
    for seed in 0..3u64 {
        let mut rng = XorShiftRng::new(95 + seed);
        let x = random_wide(&mut rng, &shape);
        let (out, stats) = compiled.execute(&mut rt, &[pack_acc_i32(&cfg, &x)]).unwrap();
        assert_eq!(unpack_eltwise(&out, &shape), relu_i8(&x), "replay {seed} diverged");
        cycles.push(stats.total_cycles);
    }
    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "replay timing drifted: {cycles:?}");
    compiled.free(&mut rt).unwrap();
}

/// Eltwise planning respects register-file budgets: the strip shrinks
/// with operand count and virtual threading, and the whole tensor is
/// covered.
#[test]
fn eltwise_plan_respects_budgets() {
    let cfg = VtaConfig::pynq();
    let lanes = cfg.gemm.batch * cfg.gemm.block_out;
    let plan2 = plan_eltwise(&cfg, 100_000, 2, 2).unwrap();
    let plan1 = plan_eltwise(&cfg, 100_000, 1, 1).unwrap();
    assert_eq!(plan2.tiles, 100_000usize.div_ceil(lanes));
    // Two operands, two contexts: a quarter of the addressable file.
    assert!(plan2.chunk * 2 * 2 <= cfg.acc_depth().min(1 << 11));
    assert!(plan1.chunk >= plan2.chunk);
    assert!(plan2.chunk >= 1);
}

/// The Min/Shr requant-epilogue kinds match their host oracles — the
/// `Min` / `Shr` ALU opcodes driven end to end through the microcode
/// path, across both threading modes, including negative inputs
/// (arithmetic shift) and saturating immediates.
#[test]
fn compiled_eltwise_min_and_shr_match_reference() {
    let cfg = VtaConfig::pynq();
    let shape = [1usize, 8, 9, 9]; // 648 lanes: ragged tail tile
    let mut rng = XorShiftRng::new(97);
    let x = random_wide(&mut rng, &shape);

    for vt in [1usize, 2] {
        for (kind, expect) in [
            (EltwiseKind::MinImm(100), min_imm_i8(&x, 100)),
            (EltwiseKind::MinImm(-3), min_imm_i8(&x, -3)),
            (EltwiseKind::ShrImm(0), shr_imm_i8(&x, 0)),
            (EltwiseKind::ShrImm(3), shr_imm_i8(&x, 3)),
        ] {
            let mut rt = VtaRuntime::new(&cfg, 16 << 20);
            let compiled = compile_eltwise(&mut rt, kind, x.len(), vt).unwrap();
            let (out, stats) = compiled.execute(&mut rt, &[pack_acc_i32(&cfg, &x)]).unwrap();
            assert_eq!(
                unpack_eltwise(&out, &shape),
                expect,
                "{kind:?} diverged from reference (vt={vt})"
            );
            assert!(stats.alu_uops > 0);
            compiled.free(&mut rt).unwrap();
        }
    }
}

/// Fuzz the eltwise strip-mining over tensor lengths that are NOT
/// multiples of the lane count or the register-file chunk, on a
/// deliberately shallow register file so short tensors still span
/// multiple strips and both contexts — every kind, both threading
/// modes, compared lane-for-lane against the host oracles.
#[test]
fn eltwise_strip_mining_fuzz_over_ragged_lengths() {
    // 32-tile register file / out buffer: the per-context chunk is at
    // most 16 tiles at vt=2 (8 for two operands), so lengths of a few
    // hundred lanes strip-mine several times over.
    let mut cfg = VtaConfig::pynq();
    cfg.acc_buf_bytes = 32 * cfg.acc_tile_bytes();
    cfg.out_buf_bytes = 32 * cfg.out_tile_bytes();
    let lanes = cfg.gemm.batch * cfg.gemm.block_out;

    let mut rng = XorShiftRng::new(0x7A11);
    let mut lengths = vec![1, lanes - 1, lanes, lanes + 1, 8 * lanes - 1, 16 * lanes + 7];
    for _ in 0..6 {
        lengths.push(1 + rng.next_below(40 * lanes as u64) as usize);
    }
    for &len in &lengths {
        let shape = [len];
        let a = Tensor::from_vec(&shape, rng.vec_i8(len, -120, 120)).unwrap();
        let b = Tensor::from_vec(&shape, rng.vec_i8(len, -120, 120)).unwrap();
        for vt in [1usize, 2] {
            let cases: [(EltwiseKind, Tensor<i8>, usize); 4] = [
                (EltwiseKind::AddSat, add_i8(&a, &b), 2),
                (EltwiseKind::Relu, relu_i8(&a), 1),
                (EltwiseKind::MinImm(37), min_imm_i8(&a, 37), 1),
                (EltwiseKind::ShrImm(2), shr_imm_i8(&a, 2), 1),
            ];
            for (kind, expect, operands) in cases {
                let plan = plan_eltwise(&cfg, len, operands, vt).unwrap();
                let mut rt = VtaRuntime::new(&cfg, 16 << 20);
                let compiled = compile_eltwise(&mut rt, kind, len, vt).unwrap();
                let packed: Vec<Vec<i8>> = if operands == 2 {
                    vec![pack_acc_i32(&cfg, &a), pack_acc_i32(&cfg, &b)]
                } else {
                    vec![pack_acc_i32(&cfg, &a)]
                };
                let (out, _) = compiled.execute(&mut rt, &packed).unwrap();
                assert_eq!(
                    unpack_eltwise(&out, &shape),
                    expect,
                    "{kind:?} len={len} vt={vt} (tiles={}, chunk={}) diverged",
                    plan.tiles,
                    plan.chunk
                );
                compiled.free(&mut rt).unwrap();
            }
        }
    }
}

/// Regression: a tail strip shorter than the register-file chunk —
/// crossing a context boundary so the final partial strip lands on
/// context 1 — stays bit-exact (the tail kernel's loop extent must be
/// the tail length, not the chunk).
#[test]
fn eltwise_tail_strip_on_second_context_is_exact() {
    let mut cfg = VtaConfig::pynq();
    cfg.acc_buf_bytes = 32 * cfg.acc_tile_bytes();
    cfg.out_buf_bytes = 32 * cfg.out_tile_bytes();
    let lanes = cfg.gemm.batch * cfg.gemm.block_out;
    // vt=2, one operand → chunk = 16 tiles. One full strip on context
    // 0, then a ragged 3-tile, 1-lane-short tail strip on context 1.
    let len = (16 + 3) * lanes - 1;
    let shape = [len];
    let mut rng = XorShiftRng::new(0x7A12);
    let x = Tensor::from_vec(&shape, rng.vec_i8(len, -120, 120)).unwrap();
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let compiled = compile_eltwise(&mut rt, EltwiseKind::ShrImm(1), len, 2).unwrap();
    let (out, _) = compiled.execute(&mut rt, &[pack_acc_i32(&cfg, &x)]).unwrap();
    assert_eq!(unpack_eltwise(&out, &shape), shr_imm_i8(&x, 1));
    compiled.free(&mut rt).unwrap();
}

// ---------------------------------------------------------------------
// Nearest-neighbor upsampling (the strided store/copy pass).
// ---------------------------------------------------------------------

/// The compiled Upsample2x pass matches the host oracle across shapes
/// (ragged channel blocks included), both threading modes, and strips
/// that span both SRAM contexts on a shallow register file.
#[test]
fn compiled_upsample2x_matches_reference() {
    let cfg = VtaConfig::pynq();
    let mut rng = XorShiftRng::new(0x0521);
    for (c, h, w) in [(16usize, 4, 5), (3, 7, 7), (48, 2, 3), (16, 8, 8)] {
        let x = random_nchw(&mut rng, &[1, c, h, w]);
        let expect = upsample2x_i8(&x);
        for vt in [1usize, 2] {
            let mut rt = VtaRuntime::new(&cfg, 16 << 20);
            let compiled = compile_upsample2x(&mut rt, 1, c, h, w, vt).unwrap();
            let (out, stats) = compiled.execute(&mut rt, &[pack_acc_nchw(&cfg, &x)]).unwrap();
            let got = unpack_outputs(&cfg, &out, 1, c, 2 * h, 2 * w);
            assert_eq!(got, expect, "upsample {c}x{h}x{w} vt={vt} diverged");
            assert!(stats.alu_uops > 0, "the identity ALU pass must have run");
            compiled.free(&mut rt).unwrap();
        }
    }
}

/// On a shallow register file the pass strip-mines across both
/// contexts (several strips) and still matches the oracle, with
/// deterministic replay timing.
#[test]
fn upsample2x_strip_mines_on_shallow_register_file() {
    let mut cfg = VtaConfig::pynq();
    cfg.acc_buf_bytes = 16 * cfg.acc_tile_bytes();
    cfg.out_buf_bytes = 16 * cfg.out_tile_bytes();
    let (c, h, w) = (32usize, 6, 4); // cb=2 → 12 rows of 4 tiles
    let plan = plan_upsample2x(&cfg, 1, c, h, w, 2).unwrap();
    assert!(
        plan.rows_per_strip < plan.rows(),
        "premise: the pass must take multiple strips to rotate contexts"
    );
    let mut rng = XorShiftRng::new(0x0522);
    let x = random_nchw(&mut rng, &[1, c, h, w]);
    let mut rt = VtaRuntime::new(&cfg, 16 << 20);
    let compiled = compile_upsample2x(&mut rt, 1, c, h, w, 2).unwrap();
    let mut cycles = Vec::new();
    for _ in 0..2 {
        let (out, stats) = compiled.execute(&mut rt, &[pack_acc_nchw(&cfg, &x)]).unwrap();
        assert_eq!(unpack_outputs(&cfg, &out, 1, c, 2 * h, 2 * w), upsample2x_i8(&x));
        cycles.push(stats.total_cycles);
    }
    assert_eq!(cycles[0], cycles[1], "replay timing drifted");
    compiled.free(&mut rt).unwrap();
}

/// Rows wider than the per-context register-file budget are rejected
/// at planning time (the node falls back to the CPU), and batch
/// mismatches are caught.
#[test]
fn upsample2x_plan_rejects_infeasible_geometry() {
    let mut tiny = VtaConfig::pynq();
    tiny.acc_buf_bytes = 4 * tiny.acc_tile_bytes();
    assert!(matches!(
        plan_upsample2x(&tiny, 1, 16, 4, 16, 2),
        Err(PlanError::UpsampleRowDoesntFit { .. })
    ));
    let two_batch = VtaConfig::bandwidth_example(); // BATCH = 2
    assert!(matches!(
        plan_upsample2x(&two_batch, 1, 16, 4, 4, 1),
        Err(PlanError::BadBatch { .. })
    ));
}
