//! Dense matmul lowering onto the GEMM intrinsic — the Fig 13 example
//! workload (`C[M,N] = A[M,K] x W[N,K]^T`, int8 in / int8 requantized
//! out), sharing the strip pipeline with conv2d.
//!
//! Layouts:
//! * A DRAM: tile `m_row * KB + k_b` (`B x BI` tiles; `m_row` counts
//!   BATCH-row groups)
//! * W DRAM: tile `n_b * KB + k_b` (`BO x BI` tiles)
//! * C DRAM: tile `m_row * NB + n_b` (`B x BO` tiles)
//!
//! Strip SRAM: a strip covers `m_t` row groups for `n_t` output blocks;
//! acc index `ctx + n_i * m_t + m` so each `n_i` plane stores as one 2D
//! STORE with DRAM stride `NB`.
//!
//! Like conv2d, the emission core ([`emit_matmul`]) is target-agnostic:
//! it writes into any [`CommandContext`] and invokes a caller-supplied
//! *boundary* action at the end of every weight group (matmul always
//! synchronizes between groups). The two callers are [`lower_matmul`]
//! (execute immediately — the one-shot path) and
//! [`crate::compiler::compile_dense`] (seal into replayable streams —
//! the plan-cache path that puts Dense layers on the VTA).

use super::conv2d::CompileError;
use super::plan::{plan_matmul_tuned, MatmulParams, MatmulPlan, ScheduleChoice};
use super::virtual_thread::StripPipeline;
use crate::isa::{AluOpcode, AluUop, BufferId, GemmUop, Uop};
use crate::runtime::{CommandContext, RuntimeError, UopKernel, UopKernelBuilder, VtaRuntime};
use crate::sim::SimStats;
use std::collections::HashMap;

/// Result of a lowered matmul run.
#[derive(Debug)]
pub struct MatmulOutput {
    pub stats: SimStats,
    /// Packed output tiles (`m_row * NB + n_b`).
    pub out: Vec<i8>,
    pub plan: MatmulPlan,
}

/// Tile-granular DRAM base addresses of a matmul's three data images.
#[derive(Clone, Copy, Debug)]
pub(crate) struct MatmulDramBase {
    pub a: u32,
    pub w: u32,
    pub c: u32,
}

/// Emit the full matmul instruction stream for `plan` into `ctx`,
/// calling `boundary` at the end of every weight group (the stream
/// must be finalized there: group g+1's weights overwrite group g's
/// weight-buffer residency). The boundary action either
/// executes-and-merges (one-shot lowering) or seals a replayable
/// stream (plan compilation).
pub(crate) fn emit_matmul<F>(
    ctx: &mut CommandContext,
    p: &MatmulParams,
    plan: &MatmulPlan,
    base: MatmulDramBase,
    mut boundary: F,
) -> Result<(), CompileError>
where
    F: FnMut(&mut CommandContext) -> Result<(), CompileError>,
{
    let cfg = ctx.config().clone();
    let virtual_threads = plan.contexts;
    let m_rows = p.m / cfg.gemm.batch;

    // Context strides use the ISA-addressable depth (see plan.rs). The
    // acc stride is additionally bounded by the OUT depth — compute
    // writes mirror into the out buffer at the same index (see
    // compiler::alu and the conv2d emitter).
    let inp_ctx_stride = cfg.inp_depth().min(1 << 11) / 2;
    let acc_ctx_stride = cfg.acc_depth().min(cfg.out_depth()).min(1 << 11) / 2;

    // Kernel cache: (kind, context, m_cur, n_cur) → (id, kernel).
    let mut kernels: HashMap<(u8, usize, usize, usize), (usize, UopKernel)> = HashMap::new();

    let groups = plan.nb.div_ceil(plan.n_t);
    for g in 0..groups {
        let n0 = g * plan.n_t;
        let n_cur_g = plan.n_t.min(plan.nb - n0);
        let mut pipe = StripPipeline::new(virtual_threads);

        // Group-resident weights: n_cur_g x KB tiles, contiguous.
        let wtiles = n_cur_g * plan.kb;
        ctx.load_buffer_2d(
            BufferId::Wgt,
            0,
            base.w + (n0 * plan.kb) as u32,
            1,
            wtiles as u16,
            wtiles as u16,
            [0; 4],
        );

        let mut m0 = 0;
        while m0 < m_rows {
            let m_cur = plan.m_t.min(m_rows - m0);
            let tok = pipe.begin();
            let inp_off = if tok.context == 1 { inp_ctx_stride } else { 0 };
            let acc_off = if tok.context == 1 { acc_ctx_stride } else { 0 };

            // Loads: m_cur row groups of A, contiguous tiles.
            pipe.loads_prologue(ctx, tok)?;
            let atiles = m_cur * plan.kb;
            ctx.load_buffer_2d(
                BufferId::Inp,
                inp_off as u32,
                base.a + (m0 * plan.kb) as u32,
                1,
                atiles as u16,
                atiles as u16,
                [0; 4],
            );
            pipe.loads_epilogue(ctx)?;

            pipe.compute_prologue(ctx, tok)?;

            // Reset: one uop swept over (m_cur, n_cur_g).
            let rkey = (1u8, tok.context, m_cur, n_cur_g);
            let (rid, rk) = get_kernel(&mut kernels, ctx, rkey, |b| {
                b.loop_begin(m_cur as u16, 1, 0, 0)?;
                b.loop_begin(n_cur_g as u16, m_cur as u16, 0, 0)?;
                b.push(Uop::Gemm(GemmUop { acc_idx: acc_off as u16, inp_idx: 0, wgt_idx: 0 }))?;
                b.loop_end()?;
                b.loop_end()?;
                Ok(())
            })?;
            ctx.push_gemm(rid, &rk, true)?;

            // Main: reduce over k blocks.
            let kb = plan.kb;
            let mkey = (0u8, tok.context, m_cur, n_cur_g);
            let (mid, mk) = get_kernel(&mut kernels, ctx, mkey, |b| {
                b.loop_begin(m_cur as u16, 1, kb as u16, 0)?;
                b.loop_begin(n_cur_g as u16, m_cur as u16, 0, kb as u16)?;
                for k_b in 0..kb {
                    b.push(Uop::Gemm(GemmUop {
                        acc_idx: acc_off as u16,
                        inp_idx: (inp_off + k_b) as u16,
                        wgt_idx: k_b as u16,
                    }))?;
                }
                b.loop_end()?;
                b.loop_end()?;
                Ok(())
            })?;
            ctx.push_gemm(mid, &mk, false)?;
            pipe.gemm_epilogue(ctx)?;

            // Requantize.
            let n_acc = m_cur * n_cur_g;
            let akey = (2u8, tok.context, m_cur, n_cur_g);
            let (aid, ak) = get_kernel(&mut kernels, ctx, akey, |b| {
                b.loop_begin(n_acc as u16, 1, 1, 0)?;
                b.push(Uop::Alu(AluUop { dst_idx: acc_off as u16, src_idx: acc_off as u16 }))?;
                b.loop_end()?;
                Ok(())
            })?;
            let rq = p.requant;
            let op = if rq.relu { AluOpcode::RqRelu } else { AluOpcode::Rq };
            ctx.push_alu(aid, &ak, op, true, rq.shift as i16)?;
            pipe.alu_epilogue(ctx)?;

            // Stores: per n_i plane, m_cur rows of 1 tile, stride NB.
            for n_i in 0..n_cur_g {
                ctx.store_buffer_2d(
                    (acc_off + n_i * m_cur) as u32,
                    base.c + (m0 * plan.nb + n0 + n_i) as u32,
                    m_cur as u16,
                    1,
                    plan.nb as u16,
                );
            }
            pipe.stores_epilogue(ctx)?;
            m0 += m_cur;
        }

        boundary(ctx)?;
    }
    Ok(())
}

/// Lower, execute, and read back `C = requant(A x W^T)` — the one-shot
/// path (re-plans and re-emits on every call; the serving layer uses
/// [`crate::compiler::compile_dense`] to pay the cost once).
pub fn lower_matmul(
    rt: &mut VtaRuntime,
    p: &MatmulParams,
    a_packed: &[i8],
    w_packed: &[i8],
    virtual_threads: usize,
) -> Result<MatmulOutput, CompileError> {
    lower_matmul_tuned(rt, p, a_packed, w_packed, virtual_threads, None)
}

/// [`lower_matmul`] with an optional tuned schedule override — the
/// DSE tuner's measurement path ([`crate::dse::tune`]).
pub fn lower_matmul_tuned(
    rt: &mut VtaRuntime,
    p: &MatmulParams,
    a_packed: &[i8],
    w_packed: &[i8],
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<MatmulOutput, CompileError> {
    let cfg = rt.ctx.config().clone();
    let plan = plan_matmul_tuned(&cfg, p, virtual_threads, schedule)?;
    let m_rows = p.m / cfg.gemm.batch;

    let out_tile_bytes = cfg.out_tile_bytes();
    let a_buf = rt.alloc_aligned(a_packed.len(), cfg.inp_tile_bytes())?;
    let w_buf = rt.alloc_aligned(w_packed.len(), cfg.wgt_tile_bytes())?;
    let out_tiles = m_rows * plan.nb;
    let out_buf = rt.alloc_aligned(out_tiles * out_tile_bytes, out_tile_bytes)?;
    rt.copy_in(&a_buf, cast_i8(a_packed))?;
    rt.copy_in(&w_buf, cast_i8(w_packed))?;

    let base = MatmulDramBase {
        a: (a_buf.addr / cfg.inp_tile_bytes()) as u32,
        w: (w_buf.addr / cfg.wgt_tile_bytes()) as u32,
        c: (out_buf.addr / cfg.out_tile_bytes()) as u32,
    };

    let mut stats = SimStats::default();
    {
        let VtaRuntime { ctx, device, .. } = rt;
        emit_matmul(ctx, p, &plan, base, |ctx| {
            stats.merge(&ctx.synchronize(&mut *device)?);
            Ok(())
        })?;
    }

    let out_bytes = rt.copy_out(&out_buf)?;
    let out: Vec<i8> = out_bytes.iter().map(|&b| b as i8).collect();
    rt.dram.free(a_buf)?;
    rt.dram.free(w_buf)?;
    rt.dram.free(out_buf)?;
    Ok(MatmulOutput { stats, out, plan })
}

fn get_kernel(
    cache: &mut HashMap<(u8, usize, usize, usize), (usize, UopKernel)>,
    ctx: &mut CommandContext,
    key: (u8, usize, usize, usize),
    build: impl FnOnce(&mut UopKernelBuilder) -> Result<(), crate::runtime::UopError>,
) -> Result<(usize, UopKernel), CompileError> {
    if let Some((id, k)) = cache.get(&key) {
        return Ok((*id, k.clone()));
    }
    let mut b = UopKernelBuilder::new();
    build(&mut b).map_err(RuntimeError::Uop)?;
    let kernel = b.finish().map_err(RuntimeError::Uop)?;
    let id = ctx.register_kernel(&kernel)?;
    cache.insert(key, (id, kernel.clone()));
    Ok((id, kernel))
}

fn cast_i8(v: &[i8]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}
