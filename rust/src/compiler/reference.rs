//! Host-side golden models: plain int8 implementations of **every**
//! graph operator, with the same int32 accumulation and shift-clip
//! requantization the hardware performs. Every lowered kernel is
//! validated against these oracles (and the oracles themselves against
//! the JAX `ref.py` via the PJRT integration tests). They double as
//! the native CPU execution path of the heterogeneous executor
//! (re-exported through `crate::exec`), which is what makes
//! [`crate::compiler::op::VtaOp::reference`] both "how the CPU runs
//! this op" and "what the accelerator must match".

use super::plan::{Conv2dParams, MatmulParams};
use crate::graph::Graph;
use crate::util::Tensor;

/// Reference conv2d: `NCHW` int8 input, `OIHW` int8 weights, SAME
/// padding, stride `s`, int32 accumulate, requantize to int8.
pub fn conv2d_ref(p: &Conv2dParams, inp: &Tensor<i8>, wgt: &Tensor<i8>) -> Tensor<i8> {
    let [n, c, h, w] = [inp.shape()[0], inp.shape()[1], inp.shape()[2], inp.shape()[3]];
    assert_eq!(c, p.ic);
    assert_eq!(wgt.shape(), &[p.oc, p.ic, p.k, p.k]);
    let (oh, ow, pad) = (p.out_h(), p.out_w(), p.pad());
    let mut out = Tensor::zeros(&[n, p.oc, oh, ow]);
    let src = inp.data();
    let wd = wgt.data();
    let dst = out.data_mut();
    for nn in 0..n {
        for o in 0..p.oc {
            for y in 0..oh {
                for x in 0..ow {
                    let mut acc = 0i32;
                    for ci in 0..c {
                        for ky in 0..p.k {
                            for kx in 0..p.k {
                                let iy = (y * p.s + ky) as isize - pad as isize;
                                let ix = (x * p.s + kx) as isize - pad as isize;
                                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                    let sv = src[((nn * c + ci) * h + iy as usize) * w
                                        + ix as usize] as i32;
                                    let wv =
                                        wd[((o * c + ci) * p.k + ky) * p.k + kx] as i32;
                                    acc += sv * wv;
                                }
                            }
                        }
                    }
                    dst[((nn * p.oc + o) * oh + y) * ow + x] = p.requant.apply(acc);
                }
            }
        }
    }
    out
}

/// Reference matmul: `C[M,N] = requant(A[M,K] x W[N,K]^T)`.
pub fn matmul_ref(p: &MatmulParams, a: &Tensor<i8>, w: &Tensor<i8>) -> Tensor<i8> {
    assert_eq!(a.shape(), &[p.m, p.k]);
    assert_eq!(w.shape(), &[p.n, p.k]);
    let mut out = Tensor::zeros(&[p.m, p.n]);
    let (ad, wd) = (a.data(), w.data());
    let dst = out.data_mut();
    for m in 0..p.m {
        for n in 0..p.n {
            let mut acc = 0i32;
            for k in 0..p.k {
                acc += ad[m * p.k + k] as i32 * wd[n * p.k + k] as i32;
            }
            dst[m * p.n + n] = p.requant.apply(acc);
        }
    }
    out
}

/// Max pooling over NCHW int8. Out-of-bounds taps are skipped (taps
/// initialize at `i8::MIN`), matching the JAX model's `-inf`-padded
/// `reduce_window`.
pub fn maxpool_i8(x: &Tensor<i8>, k: usize, s: usize, pad: usize) -> Tensor<i8> {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let oh = (h + 2 * pad - k) / s + 1;
    let ow = (w + 2 * pad - k) / s + 1;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let src = x.data();
    let dst = out.data_mut();
    for nn in 0..n {
        for cc in 0..c {
            let plane = (nn * c + cc) * h * w;
            for y in 0..oh {
                for xx in 0..ow {
                    let mut m = i8::MIN;
                    for ky in 0..k {
                        for kx in 0..k {
                            let iy = (y * s + ky) as isize - pad as isize;
                            let ix = (xx * s + kx) as isize - pad as isize;
                            if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                m = m.max(src[plane + iy as usize * w + ix as usize]);
                            }
                        }
                    }
                    dst[((nn * c + cc) * oh + y) * ow + xx] = m;
                }
            }
        }
    }
    out
}

/// Global average pooling NCHW → [N, C], round-to-nearest-even-free
/// integer mean (truncating division, matching the JAX model).
pub fn global_avg_pool_i8(x: &Tensor<i8>) -> Tensor<i8> {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let mut out = Tensor::zeros(&[n, c]);
    let src = x.data();
    let dst = out.data_mut();
    let area = (h * w) as i32;
    for nn in 0..n {
        for cc in 0..c {
            let plane = (nn * c + cc) * h * w;
            let sum: i32 = src[plane..plane + h * w].iter().map(|&v| v as i32).sum();
            dst[nn * c + cc] = (sum / area).clamp(-128, 127) as i8;
        }
    }
    out
}

/// Saturating int8 element-wise addition (residual connections) — the
/// oracle for the ALU-path `AddSat` operator.
pub fn add_i8(a: &Tensor<i8>, b: &Tensor<i8>) -> Tensor<i8> {
    assert_eq!(a.shape(), b.shape());
    let mut out = Tensor::zeros(a.shape());
    for (o, (&x, &y)) in out.data_mut().iter_mut().zip(a.data().iter().zip(b.data())) {
        *o = Graph::saturating_add(x, y);
    }
    out
}

/// ReLU — the oracle for the ALU-path `Relu` operator.
pub fn relu_i8(x: &Tensor<i8>) -> Tensor<i8> {
    let mut out = Tensor::zeros(x.shape());
    for (o, &v) in out.data_mut().iter_mut().zip(x.data()) {
        *o = v.max(0);
    }
    out
}

/// Dense layer `[M, K] x [N, K]^T → [M, N]` with requantization.
pub fn dense_i8(p: &MatmulParams, x: &Tensor<i8>, w: &Tensor<i8>) -> Tensor<i8> {
    matmul_ref(p, x, w)
}

/// Nearest-neighbor 2x upsampling over NCHW — the oracle for the
/// strided-store `Upsample2x` operator.
pub fn upsample2x_i8(x: &Tensor<i8>) -> Tensor<i8> {
    let [n, c, h, w] = [x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]];
    let (oh, ow) = (2 * h, 2 * w);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let src = x.data();
    let dst = out.data_mut();
    for plane in 0..n * c {
        let (sp, dp) = (plane * h * w, plane * oh * ow);
        for y in 0..oh {
            for xx in 0..ow {
                dst[dp + y * ow + xx] = src[sp + (y / 2) * w + xx / 2];
            }
        }
    }
    out
}

/// Element-wise minimum with a broadcast immediate — the oracle for
/// the ALU-path `MinImm` operator. The narrowing mirrors the
/// hardware's out-buffer write (`as i8`), exact whenever `imm` is in
/// the int8 range.
pub fn min_imm_i8(x: &Tensor<i8>, imm: i16) -> Tensor<i8> {
    let mut out = Tensor::zeros(x.shape());
    for (o, &v) in out.data_mut().iter_mut().zip(x.data()) {
        *o = (v as i32).min(imm as i32) as i8;
    }
    out
}

/// Element-wise arithmetic shift-right by an immediate — the oracle
/// for the ALU-path `ShrImm` operator (the shift masks to 5 bits,
/// exactly as the tensor ALU does).
pub fn shr_imm_i8(x: &Tensor<i8>, shift: u8) -> Tensor<i8> {
    let s = (shift & 31) as u32;
    let mut out = Tensor::zeros(x.shape());
    for (o, &v) in out.data_mut().iter_mut().zip(x.data()) {
        *o = ((v as i32) >> s) as i8;
    }
    out
}
