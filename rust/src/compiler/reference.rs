//! Host-side golden models: plain NCHW int8 conv2d / matmul with the
//! same int32 accumulation and shift-clip requantization the hardware
//! performs. Every lowered kernel is validated against these oracles
//! (and the oracles themselves against the JAX `ref.py` via the PJRT
//! integration tests).

use super::plan::{Conv2dParams, MatmulParams};
use crate::util::Tensor;

/// Reference conv2d: `NCHW` int8 input, `OIHW` int8 weights, SAME
/// padding, stride `s`, int32 accumulate, requantize to int8.
pub fn conv2d_ref(p: &Conv2dParams, inp: &Tensor<i8>, wgt: &Tensor<i8>) -> Tensor<i8> {
    let [n, c, h, w] = [inp.shape()[0], inp.shape()[1], inp.shape()[2], inp.shape()[3]];
    assert_eq!(c, p.ic);
    assert_eq!(wgt.shape(), &[p.oc, p.ic, p.k, p.k]);
    let (oh, ow, pad) = (p.out_h(), p.out_w(), p.pad());
    let mut out = Tensor::zeros(&[n, p.oc, oh, ow]);
    let src = inp.data();
    let wd = wgt.data();
    let dst = out.data_mut();
    for nn in 0..n {
        for o in 0..p.oc {
            for y in 0..oh {
                for x in 0..ow {
                    let mut acc = 0i32;
                    for ci in 0..c {
                        for ky in 0..p.k {
                            for kx in 0..p.k {
                                let iy = (y * p.s + ky) as isize - pad as isize;
                                let ix = (x * p.s + kx) as isize - pad as isize;
                                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                    let sv = src[((nn * c + ci) * h + iy as usize) * w
                                        + ix as usize] as i32;
                                    let wv =
                                        wd[((o * c + ci) * p.k + ky) * p.k + kx] as i32;
                                    acc += sv * wv;
                                }
                            }
                        }
                    }
                    dst[((nn * p.oc + o) * oh + y) * ow + x] = p.requant.apply(acc);
                }
            }
        }
    }
    out
}

/// Reference matmul: `C[M,N] = requant(A[M,K] x W[N,K]^T)`.
pub fn matmul_ref(p: &MatmulParams, a: &Tensor<i8>, w: &Tensor<i8>) -> Tensor<i8> {
    assert_eq!(a.shape(), &[p.m, p.k]);
    assert_eq!(w.shape(), &[p.n, p.k]);
    let mut out = Tensor::zeros(&[p.m, p.n]);
    let (ad, wd) = (a.data(), w.data());
    let dst = out.data_mut();
    for m in 0..p.m {
        for n in 0..p.n {
            let mut acc = 0i32;
            for k in 0..p.k {
                acc += ad[m * p.k + k] as i32 * wd[n * p.k + k] as i32;
            }
            dst[m * p.n + n] = p.requant.apply(acc);
        }
    }
    out
}
