//! TVM-like schedule lowering for VTA (§4).
//!
//! The three scheduling primitives the paper contributes are realized
//! here, specialized to the VTA backend:
//!
//! * **Explicit memory management** (§4.1): [`layout`] packs tensors
//!   into the NCHWnc tiled layout of the data-specialized SRAMs, and
//!   the planners assign every buffer to a memory scope with explicit
//!   capacity accounting.
//! * **Tensorization** (§4.2): [`conv2d`] and [`matmul`] lower loop
//!   nests onto the `BATCH x BLOCK_IN x BLOCK_OUT` GEMM intrinsic via
//!   micro-op kernels with affine index compression.
//! * **Latency hiding** (§4.3): [`virtual_thread`] interleaves the
//!   lowered stream across SRAM contexts and inserts the explicit
//!   RAW/WAR dependence push/pops of Fig 14.
//!
//! On top of those, [`compiled`] splits lowering into a compile-once
//! phase (plan + pack weights + record replayable instruction streams)
//! and a run-many phase — the substrate of the serving layer's plan
//! cache ([`crate::exec::serve`]).

pub mod compiled;
pub mod conv2d;
pub mod layout;
pub mod matmul;
pub mod plan;
pub mod reference;
pub mod virtual_thread;

pub use compiled::{compile_conv2d, CompiledConv2d, CompiledNode};
pub use conv2d::{lower_conv2d, CompileError, Conv2dOutput};
pub use layout::{
    pack_activations, pack_matrix_a, pack_matrix_w, pack_weights, unpack_activations,
    unpack_matrix_c, unpack_outputs,
};
pub use matmul::{lower_matmul, MatmulOutput};
pub use plan::{Conv2dParams, Conv2dPlan, MatmulParams, MatmulPlan, PlanError, Requant};
pub use virtual_thread::StripPipeline;

#[cfg(test)]
mod tests;
