//! TVM-like schedule lowering for VTA (§4).
//!
//! The three scheduling primitives the paper contributes are realized
//! here, specialized to the VTA backend:
//!
//! * **Explicit memory management** (§4.1): [`layout`] packs tensors
//!   into the NCHWnc tiled layout of the data-specialized SRAMs, and
//!   the planners assign every buffer to a memory scope with explicit
//!   capacity accounting.
//! * **Tensorization** (§4.2): [`conv2d`] and [`matmul`] lower loop
//!   nests onto the `BATCH x BLOCK_IN x BLOCK_OUT` GEMM intrinsic via
//!   micro-op kernels with affine index compression; [`alu`] lowers
//!   elementwise operators onto the tensor-ALU micro-op path, and
//!   [`upsample`] lowers nearest-neighbor 2x upsampling as a strided
//!   store/copy pass (the style-transfer resize-convolution block).
//! * **Latency hiding** (§4.3): [`virtual_thread`] interleaves the
//!   lowered stream across SRAM contexts and inserts the explicit
//!   RAW/WAR dependence push/pops of Fig 14.
//!
//! On top of those, [`compiled`] splits lowering into a compile-once
//! phase (plan + pack constants + record replayable instruction
//! streams) and a run-many phase, and [`op`] exposes the whole thing
//! through one uniform interface: the [`VtaOp`] trait and the operator
//! registry. The executor, the serving layer's plan cache
//! ([`crate::exec::serve`]), and the partition pass all dispatch
//! through the registry — adding an operator never touches them.

pub mod alu;
pub mod compiled;
pub mod conv2d;
pub mod layout;
pub mod matmul;
pub mod op;
pub mod plan;
pub mod reference;
pub mod upsample;
pub mod virtual_thread;

pub use alu::EltwiseKind;
pub use compiled::{
    compile_conv2d, compile_conv2d_fused, compile_conv2d_tuned, compile_dense,
    compile_dense_tuned, compile_eltwise, compile_upsample2x, prepare_conv2d_chain,
    prepare_dense_tuned, prepare_eltwise, prepare_upsample2x, CompiledNode, PlanBlueprint,
    PreparedPlan,
};
pub use conv2d::{lower_conv2d, lower_conv2d_tuned, CompileError, Conv2dOutput};
pub use layout::{
    pack_acc_i32, pack_acc_nchw, pack_activations, pack_matrix_a, pack_matrix_w, pack_weights,
    unpack_activations, unpack_eltwise, unpack_matrix_c, unpack_outputs,
};
pub use matmul::{lower_matmul, lower_matmul_tuned, MatmulOutput};
pub use op::{
    config_fingerprint, execute_compiled, fnv1a64, lookup, op_impl, weights_fingerprint, VtaOp,
    REGISTRY,
};
pub use plan::{
    plan_conv2d, plan_conv2d_fused, plan_conv2d_tuned, plan_eltwise, plan_matmul,
    plan_matmul_tuned, plan_upsample2x, Conv2dParams, Conv2dPlan, EltwisePlan, FusedStep,
    MatmulParams, MatmulPlan, PlanError, Requant, ScheduleChoice, UpsamplePlan,
};
pub use virtual_thread::StripPipeline;

#[cfg(test)]
mod tests;
