//! Compile-once / run-many lowering (§3's "JIT compiler" applied at
//! whole-node granularity), for **every** registered operator.
//!
//! The one-shot paths ([`lower_conv2d`](super::lower_conv2d),
//! [`lower_matmul`](super::lower_matmul)) re-plan, re-pack, re-emit
//! and re-encode on every invocation — fine for one-shot benchmarks,
//! wasteful for serving, where the same (operator params, constants,
//! `VtaConfig`) triple runs on every inference. The `compile_*`
//! functions here perform all input-independent work exactly once and
//! return a [`CompiledNode`]:
//!
//! * persistent DRAM buffers for every variable input and the output
//!   image (constants — packed weights — are copied in at compile
//!   time),
//! * a private DRAM micro-kernel arena, and
//! * one or more [`SealedStream`]s — finalized, replayable instruction
//!   streams (one per drain/group boundary; a single stream for most
//!   plans).
//!
//! Executing the node ([`CompiledNode::execute`]) is then just: copy
//! the packed inputs into the resident buffers, replay the streams,
//! copy the output tiles back. Each stream was recorded against a
//! fresh residency state, so it re-loads every micro-kernel it uses
//! and can be replayed in any order relative to other compiled nodes
//! sharing the device.
//!
//! The serving layer ([`crate::exec::serve`]) caches these under
//! (config, virtual threads, operator fingerprint) keys — the paper's
//! micro-kernel LRU cache, extended to whole-node plans. Operator
//! implementations ([`crate::compiler::op`]) decide which `compile_*`
//! entry point serves which graph node.

use super::alu::{emit_eltwise, EltwiseDramBase, EltwiseKind};
use super::conv2d::{bytes_of_i8, emit_conv2d, CompileError, ConvDramBase};
use super::matmul::{emit_matmul, MatmulDramBase};
use super::plan::{
    plan_conv2d_fused, plan_eltwise, plan_matmul_tuned, plan_upsample2x, Conv2dParams, FusedStep,
    MatmulParams, ScheduleChoice,
};
use super::upsample::{emit_upsample2x, UpsampleDramBase};
use crate::arch::VtaConfig;
use crate::graph::Op;
use crate::runtime::{CommandContext, Device, DramBuffer, RuntimeError, SealedStream, VtaRuntime};
use crate::sim::SimStats;

/// Bytes of DRAM reserved per compiled GEMM-class node for generated
/// micro-kernel words. Generously sized: a node's distinct kernels are
/// bounded by a few strip-shape variants, each at most one micro-op
/// SRAM deep (16 KiB on the Pynq point); overflow is caught by the
/// recording context's arena bound, not silently overwritten.
const NODE_UOP_ARENA_BYTES: usize = 256 * 1024;

/// Bytes of DRAM reserved per compiled elementwise node: its kernels
/// are single micro-ops (one per context and tail length).
const ELTWISE_UOP_ARENA_BYTES: usize = 16 * 1024;

/// A graph node compiled for a specific `VtaConfig` (+ constants):
/// everything input-independent, done once. Operator-agnostic — the
/// unit the serving layer's plan cache stores.
#[derive(Debug)]
pub struct CompiledNode {
    /// The graph operator this artifact implements (carries the shape
    /// parameters the unpack step needs).
    pub op: Op,
    /// The tuned schedule this artifact was lowered with, if any
    /// (`None` = the planner's greedy default). Introspection for the
    /// serving layer's tuned-record tests and the `vta serve` report.
    pub schedule: Option<ScheduleChoice>,
    /// Replayable instruction streams, in execution order (one per
    /// drain/group boundary).
    pub streams: Vec<SealedStream>,
    /// One DRAM buffer per variable input, in graph-input order; the
    /// packed image handed to [`Self::execute`] must match each
    /// buffer's size exactly.
    inp_bufs: Vec<DramBuffer>,
    /// Output image.
    out_buf: DramBuffer,
    /// Buffers whose contents were baked in at compile time (packed
    /// weights) plus the private micro-kernel arena.
    baked_bufs: Vec<DramBuffer>,
    /// Every DRAM allocation above with the alignment it was made
    /// with, **in allocation order** — the record [`Self::replicate_to`]
    /// replays to reproduce the identical DRAM layout on a replica
    /// device (sealed streams bake tile addresses in, so a replica's
    /// buffers must land at the same addresses).
    layout: Vec<(DramBuffer, usize)>,
}

impl CompiledNode {
    /// Expected packed size (bytes) of variable input `i`.
    pub fn inp_bytes(&self, i: usize) -> usize {
        self.inp_bufs[i].len
    }

    /// Total DRAM resident bytes held by this plan (buffers + arena).
    pub fn dram_bytes(&self) -> usize {
        self.inp_bufs.iter().map(|b| b.len).sum::<usize>()
            + self.out_buf.len
            + self.baked_bufs.iter().map(|b| b.len).sum::<usize>()
    }

    /// Total instructions across all streams (reporting).
    pub fn insn_count(&self) -> usize {
        self.streams.iter().map(|s| s.len()).sum()
    }

    /// Run the compiled node on one set of packed input images;
    /// returns the packed output image and the merged simulation
    /// statistics.
    pub fn execute(
        &self,
        rt: &mut VtaRuntime,
        packed_inputs: &[Vec<i8>],
    ) -> Result<(Vec<i8>, SimStats), CompileError> {
        assert_eq!(
            packed_inputs.len(),
            self.inp_bufs.len(),
            "input count mismatch for compiled {:?}",
            self.op
        );
        for (buf, packed) in self.inp_bufs.iter().zip(packed_inputs) {
            assert_eq!(
                packed.len(),
                buf.len,
                "packed input size mismatch for compiled {:?}",
                self.op
            );
            rt.copy_in(buf, bytes_of_i8(packed))?;
        }
        let mut stats = SimStats::default();
        for stream in &self.streams {
            stats.merge(&stream.run(&mut rt.device)?);
        }
        let out_bytes = rt.copy_out(&self.out_buf)?;
        let out: Vec<i8> = out_bytes.iter().map(|&b| b as i8).collect();
        Ok((out, stats))
    }

    /// Release the plan's DRAM residency (cache eviction).
    ///
    /// Frees in **layout order** — the same order the buffers were
    /// allocated (and the order [`free_reserved_layout`] releases a
    /// not-yet-materialized reservation) — so every replica's free-list
    /// history stays identical whether it evicts a finished plan or a
    /// reservation whose lowering it never observed.
    pub fn free(self, rt: &mut VtaRuntime) -> Result<(), CompileError> {
        for (buf, _) in self.layout {
            rt.dram.free(buf)?;
        }
        Ok(())
    }

    /// Clone this compiled plan onto a replica runtime of the *same*
    /// `VtaConfig` — the device pool's shared compile-once path:
    /// lowering (planning, packing, emission, sealing) ran exactly
    /// once, on the source device; every replica gets the finished
    /// artifact for the price of a byte copy.
    ///
    /// Replays the plan's DRAM allocation sequence on `dst` (same
    /// sizes, alignments, order) and copies the baked buffers' packed
    /// constants from the source device; the sealed streams — which
    /// bake DRAM tile addresses in — then replay verbatim. This is
    /// only sound when `dst`'s allocator history matches the source's
    /// (the pool drives every per-device plan cache through the same
    /// insert/evict sequence); a diverged layout is reported as
    /// [`CompileError::ReplicaDiverged`], never silently mis-addressed.
    ///
    /// Variable-input and output images need no copy: every
    /// [`Self::execute`] overwrites them, and every
    /// [`SealedStream::run`] rewrites the kernel arena.
    pub fn replicate_to(
        &self,
        src: &VtaRuntime,
        dst: &mut VtaRuntime,
    ) -> Result<CompiledNode, CompileError> {
        self.replay_layout(dst)?;
        for buf in &self.baked_bufs {
            let bytes = src.device.read(buf.addr, buf.len).map_err(RuntimeError::Sim)?;
            dst.device.write(buf.addr, &bytes).map_err(RuntimeError::Sim)?;
        }
        Ok(self.clone_artifact())
    }

    /// Detach this plan into a device-independent [`PlanBlueprint`]:
    /// the sealed streams, the DRAM layout record, and a byte image of
    /// every baked buffer read back from the compiling device `src`.
    /// The blueprint is what the threaded serving runtime publishes
    /// through its shared plan directory — unlike [`Self::replicate_to`]
    /// it needs no live borrow of the source runtime at materialize
    /// time, so worker threads can install plans compiled by their
    /// peers without any cross-thread device access.
    pub fn blueprint(&self, src: &VtaRuntime) -> Result<PlanBlueprint, CompileError> {
        let mut baked_images = Vec::with_capacity(self.baked_bufs.len());
        for buf in &self.baked_bufs {
            baked_images.push(src.device.read(buf.addr, buf.len).map_err(RuntimeError::Sim)?);
        }
        Ok(PlanBlueprint { node: self.clone_artifact(), baked_images })
    }

    /// Replay the plan's allocation sequence on `dst`, asserting every
    /// buffer lands at the address the sealed streams baked in. On any
    /// failure the allocations already made are unwound, leaving
    /// `dst`'s allocator untouched.
    fn replay_layout(&self, dst: &mut VtaRuntime) -> Result<(), CompileError> {
        let mut allocated: Vec<DramBuffer> = Vec::with_capacity(self.layout.len());
        for &(buf, align) in &self.layout {
            let got = match dst.alloc_aligned(buf.len, align) {
                Ok(b) => b,
                Err(e) => {
                    for b in allocated {
                        let _ = dst.dram.free(b);
                    }
                    return Err(e.into());
                }
            };
            if got.addr != buf.addr {
                for b in allocated {
                    let _ = dst.dram.free(b);
                }
                let _ = dst.dram.free(got);
                return Err(CompileError::ReplicaDiverged { expected: buf.addr, got: got.addr });
            }
            allocated.push(got);
        }
        Ok(())
    }

    /// A handle-level copy of the artifact (streams + buffer handles;
    /// no device state).
    fn clone_artifact(&self) -> CompiledNode {
        CompiledNode {
            op: self.op.clone(),
            schedule: self.schedule,
            streams: self.streams.clone(),
            inp_bufs: self.inp_bufs.clone(),
            out_buf: self.out_buf,
            baked_bufs: self.baked_bufs.clone(),
            layout: self.layout.clone(),
        }
    }
}

/// A compiled plan detached from its device: sealed streams, the DRAM
/// layout record, and byte images of the baked buffers (packed weights
/// + micro-kernel arena contents). Plain owned data — `Send + Sync` —
/// so the threaded serving runtime can publish one through a shared
/// directory and let every worker materialize it onto its own replica.
///
/// Materialization is only sound when the destination allocator's
/// history matches the compiling replica's — the same lockstep
/// precondition as [`CompiledNode::replicate_to`], enforced the same
/// way (address check, [`CompileError::ReplicaDiverged`]).
#[derive(Debug)]
pub struct PlanBlueprint {
    node: CompiledNode,
    /// Contents of each `baked_bufs[i]`, read from the compiling device.
    baked_images: Vec<Vec<u8>>,
}

impl PlanBlueprint {
    /// Total DRAM bytes the materialized plan will hold resident.
    pub fn dram_bytes(&self) -> usize {
        self.node.dram_bytes()
    }

    /// The operator the plan implements.
    pub fn op(&self) -> &Op {
        &self.node.op
    }

    /// Instantiate the plan on `dst`: replay the allocation sequence
    /// (same sizes, alignments, order — addresses must match, else
    /// [`CompileError::ReplicaDiverged`]) and write the baked byte
    /// images. Variable inputs and the output need no initialization;
    /// every [`CompiledNode::execute`] overwrites them.
    pub fn materialize(&self, dst: &mut VtaRuntime) -> Result<CompiledNode, CompileError> {
        self.node.replay_layout(dst)?;
        for (buf, image) in self.node.baked_bufs.iter().zip(&self.baked_images) {
            if let Err(e) = dst.device.write(buf.addr, image).map_err(RuntimeError::Sim) {
                for &(b, _) in &self.node.layout {
                    let _ = dst.dram.free(b);
                }
                return Err(e.into());
            }
        }
        Ok(self.node.clone_artifact())
    }

    /// Instantiate the plan into DRAM buffers that were **already
    /// reserved** from the plan's published allocation requirements
    /// (the threaded runtime's deferred-materialization path: a replica
    /// reserves the layout while the owning worker is still lowering,
    /// then fills it in here once the blueprint is published).
    ///
    /// The reservation must coincide exactly with the layout the sealed
    /// streams baked in — same addresses, same sizes — else the replica
    /// diverged from the publish log and the error is surfaced rather
    /// than mis-addressed.
    pub fn materialize_reserved(
        &self,
        dst: &mut VtaRuntime,
        bufs: &[DramBuffer],
    ) -> Result<CompiledNode, CompileError> {
        debug_assert_eq!(bufs.len(), self.node.layout.len(), "reservation shape mismatch");
        for (&got, &(want, _)) in bufs.iter().zip(&self.node.layout) {
            if got.addr != want.addr || got.len != want.len {
                return Err(CompileError::ReplicaDiverged { expected: want.addr, got: got.addr });
            }
        }
        for (buf, image) in self.node.baked_bufs.iter().zip(&self.baked_images) {
            dst.device.write(buf.addr, image).map_err(RuntimeError::Sim)?;
        }
        Ok(self.node.clone_artifact())
    }
}

/// The reserve/lower split of a plan compile: everything that can run
/// **outside** the serving runtime's directory lock, packaged around
/// the one decision that must be published under it — the DRAM
/// allocation requirements.
///
/// `prepare_*` does the input-independent planning and constant packing
/// up front and captures the expensive emission step as a closure;
/// [`Self::reqs`] is what a plan directory appends to its event log so
/// every replica can reserve the identical layout immediately, and
/// [`Self::lower_into`] runs the emission against the reserved buffers
/// with no lock held. [`Self::finish`] is the one-shot convenience
/// (allocate + lower) that keeps the classic `compile_*` entry points
/// byte-identical in behavior.
pub struct PreparedPlan {
    reqs: Vec<(usize, usize)>,
    #[allow(clippy::type_complexity)]
    lower: Box<dyn FnOnce(&mut VtaRuntime, &[DramBuffer]) -> Result<CompiledNode, CompileError> + Send>,
}

impl PreparedPlan {
    fn new<F>(reqs: Vec<(usize, usize)>, lower: F) -> Self
    where
        F: FnOnce(&mut VtaRuntime, &[DramBuffer]) -> Result<CompiledNode, CompileError>
            + Send
            + 'static,
    {
        PreparedPlan { reqs, lower: Box::new(lower) }
    }

    /// DRAM allocation requirements `(len, align)`, in layout order —
    /// the reservation a plan directory publishes so replicas replay
    /// the identical allocator history without waiting for the lower.
    pub fn reqs(&self) -> &[(usize, usize)] {
        &self.reqs
    }

    /// Lower into buffers the caller already allocated (one per entry
    /// of [`Self::reqs`], same order). On error the buffers are left
    /// allocated — the caller owns the unwinding, because on a pool the
    /// release must be sequenced against the shared event log.
    pub fn lower_into(
        self,
        rt: &mut VtaRuntime,
        bufs: &[DramBuffer],
    ) -> Result<CompiledNode, CompileError> {
        debug_assert_eq!(bufs.len(), self.reqs.len(), "one buffer per requirement");
        (self.lower)(rt, bufs)
    }

    /// Allocate the buffer group and lower into it — the single-device
    /// path. A failed lower releases the group, leaving the allocator
    /// untouched (the same guarantee the pre-split `compile_*` bodies
    /// gave).
    pub fn finish(self, rt: &mut VtaRuntime) -> Result<CompiledNode, CompileError> {
        let bufs = alloc_group(rt, &self.reqs)?;
        match (self.lower)(rt, &bufs) {
            Ok(node) => Ok(node),
            Err(e) => {
                free_group(rt, &bufs);
                Err(e)
            }
        }
    }
}

/// Release a reserved-but-never-materialized layout, in layout order —
/// the eviction twin of [`CompiledNode::free`] for replicas that
/// reserved a plan's buffers and saw it evicted before the blueprint
/// arrived.
pub(crate) fn free_reserved_layout(
    rt: &mut VtaRuntime,
    bufs: &[DramBuffer],
) -> Result<(), CompileError> {
    for &b in bufs {
        rt.dram.free(b)?;
    }
    Ok(())
}

/// Allocate a plan's DRAM buffers as one atomic group: on any failure
/// the already-made allocations are released, so a failed compile
/// never perturbs the runtime's allocator state. Single-device, a
/// partial-alloc leak would merely drain DRAM across requests; on a
/// device pool it would silently diverge replica 0's allocator history
/// from the other replicas' and poison every later
/// [`CompiledNode::replicate_to`].
pub(crate) fn alloc_group(
    rt: &mut VtaRuntime,
    reqs: &[(usize, usize)],
) -> Result<Vec<DramBuffer>, CompileError> {
    let mut bufs: Vec<DramBuffer> = Vec::with_capacity(reqs.len());
    for &(len, align) in reqs {
        match rt.alloc_aligned(len, align) {
            Ok(b) => bufs.push(b),
            Err(e) => {
                free_group(rt, &bufs);
                return Err(e.into());
            }
        }
    }
    Ok(bufs)
}

/// Best-effort release of a buffer group (error-path unwinding).
pub(crate) fn free_group(rt: &mut VtaRuntime, bufs: &[DramBuffer]) {
    for &b in bufs {
        let _ = rt.dram.free(b);
    }
}

/// Compile one conv2d layer into a reusable [`CompiledNode`].
///
/// `wgt_packed` is the tiled weight image from
/// [`super::pack_weights`]; it is copied into device DRAM here, once.
/// `virtual_threads` ∈ {1, 2} toggles latency hiding, exactly as in
/// [`super::lower_conv2d`]. The two paths produce identical outputs;
/// simulated timing is also identical for single-stream plans (the
/// common case). Plans that drain between groups re-emit `LOAD.UOP`s
/// at every stream boundary — the price of order-independent replay —
/// so their compiled path simulates a handful more micro-kernel loads
/// than the one-shot path, which keeps residency across its
/// synchronize calls.
pub fn compile_conv2d(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    wgt_packed: &[i8],
    virtual_threads: usize,
) -> Result<CompiledNode, CompileError> {
    compile_conv2d_tuned(rt, p, wgt_packed, virtual_threads, None)
}

/// [`compile_conv2d`] with an optional tuned schedule override — the
/// path the serving engine takes when the tuning-record store
/// ([`crate::dse::records`]) knows a better tiling for this
/// (config, operator) pair.
pub fn compile_conv2d_tuned(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    wgt_packed: &[i8],
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<CompiledNode, CompileError> {
    compile_conv2d_chain(rt, p, &[], wgt_packed, virtual_threads, schedule)
}

/// Compile a conv2d with a fused epilogue chain
/// ([`crate::graph::Op::FusedConv2d`]) into one [`CompiledNode`]: one
/// instruction stream, one ACC residency per strip, the residual
/// operand (when the chain carries an
/// [`FusedStep::AddResidual`]) DMA'd into the upper half of each
/// context's accumulator span and added on the tensor ALU — no
/// intermediate store/load between the conv and its epilogues. With an
/// empty chain this *is* [`compile_conv2d_tuned`].
pub fn compile_conv2d_fused(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    steps: &[FusedStep],
    wgt_packed: &[i8],
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<CompiledNode, CompileError> {
    compile_conv2d_chain(rt, p, steps, wgt_packed, virtual_threads, schedule)
}

fn compile_conv2d_chain(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    steps: &[FusedStep],
    wgt_packed: &[i8],
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<CompiledNode, CompileError> {
    let cfg = rt.ctx.config().clone();
    prepare_conv2d_chain(&cfg, p, steps, wgt_packed.to_vec(), virtual_threads, schedule)?
        .finish(rt)
}

/// The reserve/lower split of [`compile_conv2d_fused`]: planning and
/// the allocation-requirement computation run here (no runtime access,
/// so no lock needed on a shared pool); weight copy-in, emission and
/// sealing are captured in the returned [`PreparedPlan`]'s lower step.
pub fn prepare_conv2d_chain(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    steps: &[FusedStep],
    wgt_packed: Vec<i8>,
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<PreparedPlan, CompileError> {
    let plan = plan_conv2d_fused(cfg, p, steps, virtual_threads, schedule)?;
    let residual = steps.contains(&FusedStep::AddResidual);

    let inp_tile_bytes = cfg.inp_tile_bytes();
    let wgt_tile_bytes = cfg.wgt_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();
    let acc_tile_bytes = cfg.acc_tile_bytes();
    let icb = p.ic.div_ceil(cfg.gemm.block_in);
    let inp_bytes = icb * p.h * p.w * inp_tile_bytes;
    let out_tiles = plan.ocb * plan.oh * plan.ow;

    // The residual image shares the output's tile order
    // (`(oc_b * OH + oh) * OW + ow`) at accumulator granularity —
    // [`super::pack_acc_nchw`] with a single batch row group.
    let mut alloc_reqs = vec![
        (inp_bytes, inp_tile_bytes),
        (wgt_packed.len(), wgt_tile_bytes),
        (out_tiles * out_tile_bytes, out_tile_bytes),
    ];
    if residual {
        alloc_reqs.push((out_tiles * acc_tile_bytes, acc_tile_bytes));
    }
    alloc_reqs.push((NODE_UOP_ARENA_BYTES, 4));

    let cfg = cfg.clone();
    let p = *p;
    let steps = steps.to_vec();
    let schedule = schedule.copied();
    Ok(PreparedPlan::new(alloc_reqs, move |rt, bufs| {
        let (inp_buf, wgt_buf, out_buf) = (bufs[0], bufs[1], bufs[2]);
        let res_buf = residual.then(|| bufs[3]);
        let uop_buf = *bufs.last().expect("arena allocated");
        rt.copy_in(&wgt_buf, bytes_of_i8(&wgt_packed))?;

        let base = ConvDramBase {
            inp: (inp_buf.addr / inp_tile_bytes) as u32,
            wgt: (wgt_buf.addr / wgt_tile_bytes) as u32,
            out: (out_buf.addr / out_tile_bytes) as u32,
            res: res_buf.map(|b| (b.addr / acc_tile_bytes) as u32),
        };

        // Record into a dedicated context over this node's private
        // kernel arena; every drain boundary seals one self-contained
        // stream.
        let mut ctx =
            CommandContext::with_arena(&cfg, (uop_buf.addr / 4) as u32, NODE_UOP_ARENA_BYTES / 4);
        let mut streams = Vec::new();
        emit_conv2d(&mut ctx, &p, &plan, base, &steps, |ctx| {
            streams.push(ctx.seal()?);
            Ok(())
        })?;

        let op = if steps.is_empty() {
            Op::Conv2d { p }
        } else {
            Op::FusedConv2d { p, steps: steps.clone() }
        };
        let mut inp_bufs = vec![inp_buf];
        inp_bufs.extend(res_buf);
        let mut layout = vec![
            (inp_buf, inp_tile_bytes),
            (wgt_buf, wgt_tile_bytes),
            (out_buf, out_tile_bytes),
        ];
        layout.extend(res_buf.map(|b| (b, acc_tile_bytes)));
        layout.push((uop_buf, 4));
        Ok(CompiledNode {
            op,
            schedule,
            streams,
            inp_bufs,
            out_buf,
            baked_bufs: vec![wgt_buf, uop_buf],
            layout,
        })
    }))
}

/// Compile one dense (matmul) layer into a reusable [`CompiledNode`] —
/// the compile-once twin of [`super::lower_matmul`], and the path that
/// puts `Op::Dense` nodes on the VTA.
///
/// `wgt_packed` is the tiled `(N, K)` weight image from
/// [`super::pack_matrix_w`]. One sealed stream per weight group
/// (matmul always synchronizes between groups).
pub fn compile_dense(
    rt: &mut VtaRuntime,
    p: &MatmulParams,
    wgt_packed: &[i8],
    virtual_threads: usize,
) -> Result<CompiledNode, CompileError> {
    compile_dense_tuned(rt, p, wgt_packed, virtual_threads, None)
}

/// [`compile_dense`] with an optional tuned schedule override.
pub fn compile_dense_tuned(
    rt: &mut VtaRuntime,
    p: &MatmulParams,
    wgt_packed: &[i8],
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<CompiledNode, CompileError> {
    let cfg = rt.ctx.config().clone();
    prepare_dense_tuned(&cfg, p, wgt_packed.to_vec(), virtual_threads, schedule)?.finish(rt)
}

/// The reserve/lower split of [`compile_dense_tuned`] (see
/// [`prepare_conv2d_chain`]).
pub fn prepare_dense_tuned(
    cfg: &VtaConfig,
    p: &MatmulParams,
    wgt_packed: Vec<i8>,
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<PreparedPlan, CompileError> {
    let plan = plan_matmul_tuned(cfg, p, virtual_threads, schedule)?;
    let m_rows = p.m / cfg.gemm.batch;

    let inp_tile_bytes = cfg.inp_tile_bytes();
    let wgt_tile_bytes = cfg.wgt_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();
    let a_bytes = m_rows * plan.kb * inp_tile_bytes;
    let out_tiles = m_rows * plan.nb;

    let alloc_reqs = vec![
        (a_bytes, inp_tile_bytes),
        (wgt_packed.len(), wgt_tile_bytes),
        (out_tiles * out_tile_bytes, out_tile_bytes),
        (NODE_UOP_ARENA_BYTES, 4),
    ];

    let cfg = cfg.clone();
    let p = *p;
    let schedule = schedule.copied();
    Ok(PreparedPlan::new(alloc_reqs, move |rt, bufs| {
        let (a_buf, w_buf, out_buf, uop_buf) = (bufs[0], bufs[1], bufs[2], bufs[3]);
        rt.copy_in(&w_buf, bytes_of_i8(&wgt_packed))?;

        let base = MatmulDramBase {
            a: (a_buf.addr / inp_tile_bytes) as u32,
            w: (w_buf.addr / wgt_tile_bytes) as u32,
            c: (out_buf.addr / out_tile_bytes) as u32,
        };

        let mut ctx =
            CommandContext::with_arena(&cfg, (uop_buf.addr / 4) as u32, NODE_UOP_ARENA_BYTES / 4);
        let mut streams = Vec::new();
        emit_matmul(&mut ctx, &p, &plan, base, |ctx| {
            streams.push(ctx.seal()?);
            Ok(())
        })?;

        Ok(CompiledNode {
            op: Op::Dense { p },
            schedule,
            streams,
            inp_bufs: vec![a_buf],
            out_buf,
            baked_bufs: vec![w_buf, uop_buf],
            layout: vec![
                (a_buf, inp_tile_bytes),
                (w_buf, wgt_tile_bytes),
                (out_buf, out_tile_bytes),
                (uop_buf, 4),
            ],
        })
    }))
}

/// Compile one elementwise tensor-ALU operator over `len` int8
/// elements into a reusable [`CompiledNode`] (saturating Add or ReLU —
/// see [`crate::compiler::alu`]). No constants: the only baked buffer
/// is the micro-kernel arena.
pub fn compile_eltwise(
    rt: &mut VtaRuntime,
    kind: EltwiseKind,
    len: usize,
    virtual_threads: usize,
) -> Result<CompiledNode, CompileError> {
    let cfg = rt.ctx.config().clone();
    prepare_eltwise(&cfg, kind, len, virtual_threads)?.finish(rt)
}

/// The reserve/lower split of [`compile_eltwise`] (see
/// [`prepare_conv2d_chain`]).
pub fn prepare_eltwise(
    cfg: &VtaConfig,
    kind: EltwiseKind,
    len: usize,
    virtual_threads: usize,
) -> Result<PreparedPlan, CompileError> {
    let plan = plan_eltwise(cfg, len, kind.operands(), virtual_threads)?;

    let acc_tile_bytes = cfg.acc_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();
    let mut alloc_reqs =
        vec![(plan.tiles * acc_tile_bytes, acc_tile_bytes); kind.operands()];
    alloc_reqs.push((plan.tiles * out_tile_bytes, out_tile_bytes));
    alloc_reqs.push((ELTWISE_UOP_ARENA_BYTES, 4));

    let cfg = cfg.clone();
    Ok(PreparedPlan::new(alloc_reqs, move |_rt, bufs| {
        let inp_bufs: Vec<DramBuffer> = bufs[..kind.operands()].to_vec();
        let out_buf = bufs[kind.operands()];
        let uop_buf = bufs[kind.operands() + 1];

        let base = EltwiseDramBase {
            inputs: inp_bufs.iter().map(|b| (b.addr / acc_tile_bytes) as u32).collect(),
            out: (out_buf.addr / out_tile_bytes) as u32,
        };

        let mut ctx = CommandContext::with_arena(
            &cfg,
            (uop_buf.addr / 4) as u32,
            ELTWISE_UOP_ARENA_BYTES / 4,
        );
        let mut streams = Vec::new();
        emit_eltwise(&mut ctx, kind, &plan, &base, |ctx| {
            streams.push(ctx.seal()?);
            Ok(())
        })?;

        let mut layout: Vec<(DramBuffer, usize)> =
            inp_bufs.iter().map(|&b| (b, acc_tile_bytes)).collect();
        layout.push((out_buf, out_tile_bytes));
        layout.push((uop_buf, 4));
        Ok(CompiledNode {
            op: kind.graph_op(),
            schedule: None,
            streams,
            inp_bufs,
            out_buf,
            baked_bufs: vec![uop_buf],
            layout,
        })
    }))
}

/// Compile one nearest-neighbor 2x upsampling over an `[n, c, h, w]`
/// input into a reusable [`CompiledNode`] — a strided store/copy pass
/// ([`crate::compiler::upsample`]). No constants; like the elementwise
/// path, the only baked buffer is the micro-kernel arena.
pub fn compile_upsample2x(
    rt: &mut VtaRuntime,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    virtual_threads: usize,
) -> Result<CompiledNode, CompileError> {
    let cfg = rt.ctx.config().clone();
    prepare_upsample2x(&cfg, n, c, h, w, virtual_threads)?.finish(rt)
}

/// The reserve/lower split of [`compile_upsample2x`] (see
/// [`prepare_conv2d_chain`]).
pub fn prepare_upsample2x(
    cfg: &VtaConfig,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    virtual_threads: usize,
) -> Result<PreparedPlan, CompileError> {
    let plan = plan_upsample2x(cfg, n, c, h, w, virtual_threads)?;

    let acc_tile_bytes = cfg.acc_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();
    let alloc_reqs = vec![
        (plan.in_tiles() * acc_tile_bytes, acc_tile_bytes),
        (plan.out_tiles() * out_tile_bytes, out_tile_bytes),
        (ELTWISE_UOP_ARENA_BYTES, 4),
    ];

    let cfg = cfg.clone();
    Ok(PreparedPlan::new(alloc_reqs, move |_rt, bufs| {
        let (inp_buf, out_buf, uop_buf) = (bufs[0], bufs[1], bufs[2]);

        let base = UpsampleDramBase {
            inp: (inp_buf.addr / acc_tile_bytes) as u32,
            out: (out_buf.addr / out_tile_bytes) as u32,
        };

        let mut ctx = CommandContext::with_arena(
            &cfg,
            (uop_buf.addr / 4) as u32,
            ELTWISE_UOP_ARENA_BYTES / 4,
        );
        let mut streams = Vec::new();
        emit_upsample2x(&mut ctx, &plan, base, |ctx| {
            streams.push(ctx.seal()?);
            Ok(())
        })?;

        Ok(CompiledNode {
            op: Op::Upsample2x,
            schedule: None,
            streams,
            inp_bufs: vec![inp_buf],
            out_buf,
            baked_bufs: vec![uop_buf],
            layout: vec![(inp_buf, acc_tile_bytes), (out_buf, out_tile_bytes), (uop_buf, 4)],
        })
    }))
}
