//! Compile-once / run-many lowering (§3's "JIT compiler" applied at
//! whole-node granularity).
//!
//! [`lower_conv2d`](super::lower_conv2d) re-plans, re-packs, re-emits
//! and re-encodes on every invocation — fine for one-shot benchmarks,
//! wasteful for serving, where the same (operator params, weights,
//! `VtaConfig`) triple runs on every inference. [`compile_conv2d`]
//! performs all input-independent work exactly once and returns a
//! [`CompiledConv2d`]:
//!
//! * the tiling plan,
//! * persistent DRAM buffers for the input, weight, and output images
//!   (weights are packed and copied in at compile time),
//! * a private DRAM micro-kernel arena, and
//! * one or more [`SealedStream`]s — finalized, replayable instruction
//!   streams (one per drain boundary; a single stream for most plans).
//!
//! Executing the node ([`CompiledConv2d::execute`]) is then just: copy
//! the packed input into the resident input buffer, replay the
//! streams, copy the output tiles back. Each stream was recorded
//! against a fresh residency state, so it re-loads every micro-kernel
//! it uses and can be replayed in any order relative to other compiled
//! nodes sharing the device.
//!
//! The serving layer ([`crate::exec::serve`]) caches these under
//! (config, params, weights) keys — the paper's micro-kernel LRU
//! cache, extended to whole-node plans.

use super::conv2d::{bytes_of_i8, emit_conv2d, CompileError, ConvDramBase};
use super::plan::{plan_conv2d, Conv2dParams, Conv2dPlan};
use crate::runtime::{CommandContext, DramBuffer, SealedStream, VtaRuntime};
use crate::sim::SimStats;

/// Bytes of DRAM reserved per compiled node for generated micro-kernel
/// words. Generously sized: a node's distinct kernels are bounded by a
/// few strip-shape variants, each at most one micro-op SRAM deep
/// (16 KiB on the Pynq point); overflow is caught by the recording
/// context's arena bound, not silently overwritten.
const NODE_UOP_ARENA_BYTES: usize = 256 * 1024;

/// A conv2d compiled for a specific `VtaConfig` + weight image:
/// everything input-independent, done once.
#[derive(Debug)]
pub struct CompiledConv2d {
    /// The workload this plan implements.
    pub params: Conv2dParams,
    /// The tiling in force.
    pub plan: Conv2dPlan,
    /// Replayable instruction streams, in execution order (one per
    /// drain boundary).
    pub streams: Vec<SealedStream>,
    inp_buf: DramBuffer,
    wgt_buf: DramBuffer,
    out_buf: DramBuffer,
    uop_buf: DramBuffer,
    /// Expected packed-input image size (bytes).
    inp_bytes: usize,
}

impl CompiledConv2d {
    /// Packed-input image size this plan expects (bytes), as produced
    /// by [`super::pack_activations`] for a batch-1 NCHW input.
    pub fn inp_bytes(&self) -> usize {
        self.inp_bytes
    }

    /// Total DRAM resident bytes held by this plan (buffers + arena).
    pub fn dram_bytes(&self) -> usize {
        self.inp_buf.len + self.wgt_buf.len + self.out_buf.len + self.uop_buf.len
    }

    /// Total instructions across all streams (reporting).
    pub fn insn_count(&self) -> usize {
        self.streams.iter().map(|s| s.len()).sum()
    }

    /// Run the compiled node on one packed input image; returns the
    /// packed output tiles and the merged simulation statistics.
    pub fn execute(
        &self,
        rt: &mut VtaRuntime,
        inp_packed: &[i8],
    ) -> Result<(Vec<i8>, SimStats), CompileError> {
        assert_eq!(
            inp_packed.len(),
            self.inp_bytes,
            "packed input size mismatch for compiled conv2d {:?}",
            self.params
        );
        rt.copy_in(&self.inp_buf, bytes_of_i8(inp_packed))?;
        let mut stats = SimStats::default();
        for stream in &self.streams {
            stats.merge(&stream.run(&mut rt.device)?);
        }
        let out_bytes = rt.copy_out(&self.out_buf)?;
        let out: Vec<i8> = out_bytes.iter().map(|&b| b as i8).collect();
        Ok((out, stats))
    }

    /// Release the plan's DRAM residency (cache eviction).
    pub fn free(self, rt: &mut VtaRuntime) -> Result<(), CompileError> {
        rt.dram.free(self.inp_buf)?;
        rt.dram.free(self.wgt_buf)?;
        rt.dram.free(self.out_buf)?;
        rt.dram.free(self.uop_buf)?;
        Ok(())
    }
}

/// Compile one conv2d layer into a reusable [`CompiledConv2d`].
///
/// `wgt_packed` is the tiled weight image from
/// [`super::pack_weights`]; it is copied into device DRAM here, once.
/// `virtual_threads` ∈ {1, 2} toggles latency hiding, exactly as in
/// [`super::lower_conv2d`]. The two paths produce identical outputs;
/// simulated timing is also identical for single-stream plans (the
/// common case). Plans that drain between groups re-emit `LOAD.UOP`s
/// at every stream boundary — the price of order-independent replay —
/// so their compiled path simulates a handful more micro-kernel loads
/// than the one-shot path, which keeps residency across its
/// synchronize calls.
pub fn compile_conv2d(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    wgt_packed: &[i8],
    virtual_threads: usize,
) -> Result<CompiledConv2d, CompileError> {
    let cfg = rt.ctx.config().clone();
    let plan = plan_conv2d(&cfg, p, virtual_threads)?;

    let inp_tile_bytes = cfg.inp_tile_bytes();
    let wgt_tile_bytes = cfg.wgt_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();
    let icb = p.ic.div_ceil(cfg.gemm.block_in);
    let inp_bytes = icb * p.h * p.w * inp_tile_bytes;
    let out_tiles = plan.ocb * plan.oh * plan.ow;

    let inp_buf = rt.alloc_aligned(inp_bytes, inp_tile_bytes)?;
    let wgt_buf = rt.alloc_aligned(wgt_packed.len(), wgt_tile_bytes)?;
    let out_buf = rt.alloc_aligned(out_tiles * out_tile_bytes, out_tile_bytes)?;
    let uop_buf = rt.alloc_aligned(NODE_UOP_ARENA_BYTES, 4)?;
    rt.copy_in(&wgt_buf, bytes_of_i8(wgt_packed))?;

    let base = ConvDramBase {
        inp: (inp_buf.addr / inp_tile_bytes) as u32,
        wgt: (wgt_buf.addr / wgt_tile_bytes) as u32,
        out: (out_buf.addr / out_tile_bytes) as u32,
    };

    // Record into a dedicated context over this node's private kernel
    // arena; every drain boundary seals one self-contained stream.
    let mut ctx =
        CommandContext::with_arena(&cfg, (uop_buf.addr / 4) as u32, NODE_UOP_ARENA_BYTES / 4);
    let mut streams = Vec::new();
    emit_conv2d(&mut ctx, p, &plan, base, |ctx| {
        streams.push(ctx.seal()?);
        Ok(())
    })?;

    Ok(CompiledConv2d { params: *p, plan, streams, inp_buf, wgt_buf, out_buf, uop_buf, inp_bytes })
}

/// A compiled graph node — the unit the serving layer's plan cache
/// stores. Conv2d is the only VTA-resident operator today; the enum
/// leaves room for matmul (dense offload) and fused subgraphs.
#[derive(Debug)]
pub enum CompiledNode {
    Conv2d(CompiledConv2d),
}

impl CompiledNode {
    /// DRAM resident bytes.
    pub fn dram_bytes(&self) -> usize {
        match self {
            CompiledNode::Conv2d(c) => c.dram_bytes(),
        }
    }

    /// Release DRAM residency.
    pub fn free(self, rt: &mut VtaRuntime) -> Result<(), CompileError> {
        match self {
            CompiledNode::Conv2d(c) => c.free(rt),
        }
    }
}
