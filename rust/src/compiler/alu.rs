//! Elementwise operators on the tensor-ALU micro-op path (§2.5).
//!
//! The paper's microcode-ISA "can be extended for higher operator
//! coverage"; this module proves the software side of that claim by
//! lowering whole-tensor elementwise operators — saturating residual
//! addition and standalone ReLU — onto ALU micro-ops, the same way
//! `examples/custom_operator.rs` hand-builds a vector add:
//!
//! * operands are widened host-side to the int32 accumulator layout
//!   ([`super::layout::pack_acc_i32`]) and DMA'd into register-file
//!   contexts (ACC loads execute on the *compute* module, so loads and
//!   ALU ops of one strip serialize in program order — no RAW tokens
//!   needed within a strip),
//! * one looped ALU micro-op sweeps the strip
//!   (`acc[dst] = op(acc[dst], acc[src] | imm)`; every write is
//!   mirrored, narrowed, into the output buffer), and
//! * the strips rotate across SRAM contexts with the usual
//!   compute↔store WAR/RAW tokens, so stores of strip *i* overlap
//!   compute of strip *i + 1* under virtual threading.
//!
//! `AddSat` is ADD followed by an `Rq` clamp with a zero shift —
//! bit-exact saturating int8 addition. `Relu` is a single MAX with a
//! zero immediate. `MinImm` / `ShrImm` are single MIN / SHR ops with a
//! broadcast immediate — the two halves of a requantization epilogue
//! (scale, clamp) expressed in microcode instead of CPU fixups.

use super::conv2d::CompileError;
use super::plan::{EltwisePlan, FusedStep, Requant};
use super::virtual_thread::StripPipeline;
use crate::graph::Op;
use crate::isa::{AluOpcode, AluUop, BufferId, Uop};
use crate::runtime::{CommandContext, RuntimeError, UopKernel, UopKernelBuilder};
use std::collections::HashMap;

/// Which elementwise operator an ALU-path plan implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EltwiseKind {
    /// Saturating int8 tensor-tensor addition (residual connections).
    AddSat,
    /// ReLU: max with a zero immediate.
    Relu,
    /// Element-wise minimum with a broadcast immediate — the `MIN`
    /// opcode, the clamping half of a microcoded requant epilogue.
    MinImm(i16),
    /// Element-wise arithmetic shift-right by an immediate — the `SHR`
    /// opcode, the scaling half of a microcoded requant epilogue.
    ShrImm(u8),
}

impl EltwiseKind {
    /// Number of variable input tensors.
    pub fn operands(&self) -> usize {
        match self {
            EltwiseKind::AddSat => 2,
            EltwiseKind::Relu | EltwiseKind::MinImm(_) | EltwiseKind::ShrImm(_) => 1,
        }
    }

    /// The graph operator this kind implements.
    pub fn graph_op(&self) -> Op {
        match self {
            EltwiseKind::AddSat => Op::Add,
            EltwiseKind::Relu => Op::Relu,
            EltwiseKind::MinImm(imm) => Op::MinImm { imm: *imm },
            EltwiseKind::ShrImm(shift) => Op::ShrImm { shift: *shift },
        }
    }
}

/// Tile-granular DRAM base addresses of an elementwise node's images:
/// operand images in accumulator tiles, output in out-buffer tiles.
#[derive(Clone, Debug)]
pub(crate) struct EltwiseDramBase {
    pub inputs: Vec<u32>,
    pub out: u32,
}

/// Emit the full elementwise instruction stream for `plan` into `ctx`,
/// calling `boundary` once at the end (the stream has no intermediate
/// drain points). Mirrors the shape of
/// [`super::conv2d::emit_conv2d`] / [`super::matmul::emit_matmul`].
pub(crate) fn emit_eltwise<F>(
    ctx: &mut CommandContext,
    kind: EltwiseKind,
    plan: &EltwisePlan,
    base: &EltwiseDramBase,
    mut boundary: F,
) -> Result<(), CompileError>
where
    F: FnMut(&mut CommandContext) -> Result<(), CompileError>,
{
    let cfg = ctx.config().clone();
    debug_assert_eq!(base.inputs.len(), kind.operands());

    // Context stride, bounded by the ISA-addressable depth (see
    // plan.rs) of BOTH the register file and the output buffer: every
    // ALU write is mirrored into the out buffer at the same index, so
    // an ACC-only stride would overflow a shallower out SRAM.
    let acc_ctx_stride = cfg.acc_depth().min(cfg.out_depth()).min(1 << 11) / 2;

    // Kernel cache: (context, strip length) → (id, kernel). The kernel
    // is a single micro-op swept over the strip; ADD and the Rq clamp
    // share it (the opcode/immediate live in the CISC instruction).
    let mut kernels: HashMap<(usize, usize), (usize, UopKernel)> = HashMap::new();
    let mut pipe = StripPipeline::new(plan.contexts);

    let mut t0 = 0usize;
    while t0 < plan.tiles {
        let t_cur = plan.chunk.min(plan.tiles - t0);
        let tok = pipe.begin();
        let off = if tok.context == 1 { acc_ctx_stride } else { 0 };

        // WAR against the previous strip on this context: the pop
        // attaches to the first compute-module instruction below (the
        // first ACC load).
        pipe.compute_prologue(ctx, tok)?;

        // Operand loads into the register file. Operand j lives at
        // [off + j * chunk, off + j * chunk + t_cur).
        for (j, &inp) in base.inputs.iter().enumerate() {
            ctx.load_buffer_2d(
                BufferId::Acc,
                (off + j * plan.chunk) as u32,
                inp + t0 as u32,
                1,
                t_cur as u16,
                t_cur as u16,
                [0; 4],
            );
        }

        // Tensor-tensor kinds read operand B at `off + chunk`;
        // immediate-only kinds keep src == dst (the field is unused but
        // still encoded in the 11-bit micro-op index).
        let src_base = if kind.operands() > 1 { off + plan.chunk } else { off };
        let (kid, kernel) = get_kernel(
            &mut kernels,
            ctx,
            (tok.context, t_cur),
            off as u16,
            src_base as u16,
            t_cur as u16,
        )?;

        match kind {
            EltwiseKind::AddSat => {
                // Tensor-tensor ADD (int32, cannot overflow for int8
                // operands), then clamp into the int8 range: Rq with a
                // zero shift is `clamp(a >> 0, -128, 127)` — exactly
                // `Graph::saturating_add`. The final ALU write narrows
                // into the output buffer.
                ctx.push_alu(kid, &kernel, AluOpcode::Add, false, 0)?;
                ctx.push_alu(kid, &kernel, AluOpcode::Rq, true, 0)?;
            }
            EltwiseKind::Relu => {
                ctx.push_alu(kid, &kernel, AluOpcode::Max, true, 0)?;
            }
            EltwiseKind::MinImm(imm) => {
                // Single MIN with the broadcast immediate; the write
                // narrows into the output buffer (exact whenever `imm`
                // is in the int8 range — the oracle mirrors the wrap
                // otherwise).
                ctx.push_alu(kid, &kernel, AluOpcode::Min, true, imm)?;
            }
            EltwiseKind::ShrImm(shift) => {
                // Arithmetic shift-right; int8 inputs stay in range, so
                // the narrowing out-buffer write is always exact.
                ctx.push_alu(kid, &kernel, AluOpcode::Shr, true, shift as i16)?;
            }
        }
        pipe.alu_epilogue(ctx)?;

        ctx.store_buffer_2d(off as u32, base.out + t0 as u32, 1, t_cur as u16, t_cur as u16);
        pipe.stores_epilogue(ctx)?;

        t0 += t_cur;
    }
    boundary(ctx)?;
    Ok(())
}

/// Append a fused conv chain's ALU epilogue to the current strip's
/// instruction stream ([`crate::graph::Op::FusedConv2d`]): the conv's
/// own requant first, then one pass per [`FusedStep`], every pass
/// sweeping the same resident accumulator tiles. Intermediate values
/// never leave the register file — the out-buffer mirror of each pass
/// is simply overwritten by the next, and the stores read the last
/// pass's narrowed result. That is the whole point of the fusion: no
/// store/load round trip between chain links.
///
/// Bit-exactness against the unfused node sequence: ALU ops update the
/// accumulator in place, so after `Rq`/`RqRelu` the register file
/// holds the conv's int8 result widened to int32 — exactly what
/// [`super::layout::pack_acc_i32`] would have reloaded for a
/// standalone eltwise node. Each step then reuses the standalone
/// lowering verbatim (see [`emit_eltwise`]): `AddResidual` is a
/// tensor-tensor ADD + a zero-shift `Rq` clamp
/// (`Graph::saturating_add`), `Relu` is MAX 0, `ShrImm`/`MinImm` are
/// single SHR/MIN ops with a broadcast immediate.
///
/// `main` is the strip's dst == src sweep kernel; `res` (dst = conv
/// tiles, src = residual region) is required iff `steps` carries an
/// `AddResidual`.
pub(crate) fn push_fused_epilogue(
    ctx: &mut CommandContext,
    rq: Requant,
    steps: &[FusedStep],
    main: (usize, &UopKernel),
    res: Option<(usize, &UopKernel)>,
) -> Result<(), CompileError> {
    let (mid, mk) = main;
    let rq_op = if rq.relu { AluOpcode::RqRelu } else { AluOpcode::Rq };
    ctx.push_alu(mid, mk, rq_op, true, rq.shift as i16)?;
    for step in steps {
        match step {
            FusedStep::AddResidual => {
                let (rid, rk) = res.expect("residual kernel for AddResidual step");
                ctx.push_alu(rid, rk, AluOpcode::Add, false, 0)?;
                ctx.push_alu(mid, mk, AluOpcode::Rq, true, 0)?;
            }
            FusedStep::Relu => ctx.push_alu(mid, mk, AluOpcode::Max, true, 0)?,
            FusedStep::ShrImm { shift } => {
                ctx.push_alu(mid, mk, AluOpcode::Shr, true, *shift as i16)?
            }
            FusedStep::MinImm { imm } => ctx.push_alu(mid, mk, AluOpcode::Min, true, *imm)?,
        }
    }
    Ok(())
}

/// One-uop strip kernel, cached per (context, strip length). Shared
/// with the upsampling pass ([`super::upsample`]), whose identity
/// sweep uses `src == dst`.
pub(crate) fn get_kernel(
    cache: &mut HashMap<(usize, usize), (usize, UopKernel)>,
    ctx: &mut CommandContext,
    key: (usize, usize),
    dst: u16,
    src: u16,
    extent: u16,
) -> Result<(usize, UopKernel), CompileError> {
    if let Some((id, k)) = cache.get(&key) {
        return Ok((*id, k.clone()));
    }
    let mut b = UopKernelBuilder::new();
    b.loop_begin(extent, 1, 1, 0).map_err(RuntimeError::Uop)?;
    b.push(Uop::Alu(AluUop { dst_idx: dst, src_idx: src })).map_err(RuntimeError::Uop)?;
    b.loop_end().map_err(RuntimeError::Uop)?;
    let kernel = b.finish().map_err(RuntimeError::Uop)?;
    let id = ctx.register_kernel(&kernel)?;
    cache.insert(key, (id, kernel.clone()));
    Ok((id, kernel))
}
