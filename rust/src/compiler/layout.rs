//! Tiled tensor layouts (§4.1).
//!
//! VTA's data-specialized SRAMs impose tiled layouts on DRAM tensors:
//!
//! * **Activations** `NCHW` → `N/B, C/BI, H, W` tiles of `B x BI` int8
//!   (with `B = BATCH = 1` in the Pynq design, a tile is one pixel's
//!   16-channel slice). Tile index: `((n_b*CB + c_b)*H + h)*W + w`.
//! * **Weights** `OIHW` → `O/BO, I/BI, KH, KW` tiles of `BO x BI` int8.
//!   Tile index: `((o_b*IB + i_b)*KH + kh)*KW + kw`.
//!
//! Channel counts that are not multiples of the block size are
//! zero-padded (e.g. ResNet C1's 3 input channels pad to 16) — padding
//! channels contribute zero to every dot product, preserving results.

use crate::arch::VtaConfig;
use crate::util::Tensor;

/// Blocks needed to cover `c` channels at block size `b`.
pub fn blocks(c: usize, b: usize) -> usize {
    c.div_ceil(b)
}

/// Pack an `NCHW` int8 activation tensor into VTA tile order.
///
/// Output is a flat i8 vector of `N/B * ceil(C/BI) * H * W` tiles, each
/// `B*BI` elements (B = `cfg.gemm.batch`). `N` must be a multiple of B.
pub fn pack_activations(cfg: &VtaConfig, t: &Tensor<i8>) -> Vec<i8> {
    let (bi, b) = (cfg.gemm.block_in, cfg.gemm.batch);
    let [n, c, h, w] = [t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]];
    assert_eq!(n % b, 0, "batch {n} not a multiple of BATCH {b}");
    let cb = blocks(c, bi);
    let tile = b * bi;
    let mut out = vec![0i8; (n / b) * cb * h * w * tile];
    let src = t.data();
    for nb in 0..n / b {
        for cb_i in 0..cb {
            for y in 0..h {
                for x in 0..w {
                    let t_idx = ((nb * cb + cb_i) * h + y) * w + x;
                    for bb in 0..b {
                        for ci in 0..bi {
                            let cc = cb_i * bi + ci;
                            if cc < c {
                                let s = (((nb * b + bb) * c + cc) * h + y) * w + x;
                                out[t_idx * tile + bb * bi + ci] = src[s];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Inverse of [`pack_activations`]: unpack tiles back to `NCHW`,
/// dropping channel padding.
pub fn unpack_activations(
    cfg: &VtaConfig,
    packed: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
) -> Tensor<i8> {
    unpack_nchw(packed, n, c, h, w, cfg.gemm.batch, cfg.gemm.block_in)
}

/// Unpack conv *outputs*: these are tiled in `BATCH x BLOCK_OUT`
/// channel blocks (the accumulator tile shape), which differs from the
/// input layout whenever `BLOCK_OUT != BLOCK_IN`.
pub fn unpack_outputs(
    cfg: &VtaConfig,
    packed: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
) -> Tensor<i8> {
    unpack_nchw(packed, n, c, h, w, cfg.gemm.batch, cfg.gemm.block_out)
}

fn unpack_nchw(
    packed: &[i8],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    b: usize,
    bi: usize,
) -> Tensor<i8> {
    let cb = blocks(c, bi);
    let tile = b * bi;
    let mut out = Tensor::zeros(&[n, c, h, w]);
    let dst = out.data_mut();
    for nb in 0..n / b {
        for cb_i in 0..cb {
            for y in 0..h {
                for x in 0..w {
                    let t_idx = ((nb * cb + cb_i) * h + y) * w + x;
                    for bb in 0..b {
                        for ci in 0..bi {
                            let cc = cb_i * bi + ci;
                            if cc < c {
                                let d = (((nb * b + bb) * c + cc) * h + y) * w + x;
                                dst[d] = packed[t_idx * tile + bb * bi + ci];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Pack an `OIHW` int8 weight tensor into VTA tile order
/// (`BO x BI` tiles; rows are output channels, matching the GEMM
/// core's `wgt[o][k]` addressing). Output-channel unpacking is the
/// same tile order read back.
pub fn pack_weights(cfg: &VtaConfig, t: &Tensor<i8>) -> Vec<i8> {
    let (bi, bo) = (cfg.gemm.block_in, cfg.gemm.block_out);
    let [o, i, kh, kw] = [t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]];
    let ob = blocks(o, bo);
    let ib = blocks(i, bi);
    let tile = bo * bi;
    let mut out = vec![0i8; ob * ib * kh * kw * tile];
    let src = t.data();
    for ob_i in 0..ob {
        for ib_i in 0..ib {
            for y in 0..kh {
                for x in 0..kw {
                    let t_idx = ((ob_i * ib + ib_i) * kh + y) * kw + x;
                    for oo in 0..bo {
                        for ii in 0..bi {
                            let (ochan, ichan) = (ob_i * bo + oo, ib_i * bi + ii);
                            if ochan < o && ichan < i {
                                let s = ((ochan * i + ichan) * kh + y) * kw + x;
                                out[t_idx * tile + oo * bi + ii] = src[s];
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Pack a row-major `(M, K)` int8 matrix into input tiles for matmul:
/// tile index `m_b * KB + k_b`, each tile `B x BI` (rows are the M/B
/// batch rows).
pub fn pack_matrix_a(cfg: &VtaConfig, t: &Tensor<i8>) -> Vec<i8> {
    let (bi, b) = (cfg.gemm.block_in, cfg.gemm.batch);
    let [m, k] = [t.shape()[0], t.shape()[1]];
    assert_eq!(m % b, 0, "M {m} not a multiple of BATCH {b}");
    let kb = blocks(k, bi);
    let tile = b * bi;
    let mut out = vec![0i8; (m / b) * kb * tile];
    let src = t.data();
    for mb in 0..m / b {
        for kb_i in 0..kb {
            let t_idx = mb * kb + kb_i;
            for bb in 0..b {
                for ki in 0..bi {
                    let kk = kb_i * bi + ki;
                    if kk < k {
                        out[t_idx * tile + bb * bi + ki] = src[(mb * b + bb) * k + kk];
                    }
                }
            }
        }
    }
    out
}

/// Pack a row-major `(N, K)` int8 matrix (already transposed: rows are
/// output features) into weight tiles: tile index `n_b * KB + k_b`,
/// each `BO x BI`.
pub fn pack_matrix_w(cfg: &VtaConfig, t: &Tensor<i8>) -> Vec<i8> {
    let (bi, bo) = (cfg.gemm.block_in, cfg.gemm.block_out);
    let [n, k] = [t.shape()[0], t.shape()[1]];
    let nb = blocks(n, bo);
    let kb = blocks(k, bi);
    let tile = bo * bi;
    let mut out = vec![0i8; nb * kb * tile];
    let src = t.data();
    for nb_i in 0..nb {
        for kb_i in 0..kb {
            let t_idx = nb_i * kb + kb_i;
            for ni in 0..bo {
                for ki in 0..bi {
                    let (nn, kk) = (nb_i * bo + ni, kb_i * bi + ki);
                    if nn < n && kk < k {
                        out[t_idx * tile + ni * bi + ki] = src[nn * k + kk];
                    }
                }
            }
        }
    }
    out
}

/// Widen an int8 tensor into the int32 accumulator-tile layout the
/// tensor-ALU path consumes ([`crate::compiler::alu`]): the tensor is
/// flattened, zero-padded to whole `BATCH x BLOCK_OUT` tiles, and each
/// lane becomes a little-endian i32 (the element type of the register
/// file, as `DramState::read_i32` assembles it).
pub fn pack_acc_i32(cfg: &VtaConfig, t: &Tensor<i8>) -> Vec<i8> {
    let lanes = cfg.gemm.batch * cfg.gemm.block_out;
    let tiles = t.len().div_ceil(lanes).max(1);
    let mut out = vec![0i8; tiles * lanes * 4];
    for (i, &v) in t.data().iter().enumerate() {
        for (j, b) in (v as i32).to_le_bytes().iter().enumerate() {
            out[i * 4 + j] = *b as i8;
        }
    }
    out
}

/// Widen an `NCHW` int8 activation tensor into the int32
/// accumulator-tile layout the upsampling path consumes
/// ([`crate::compiler::upsample`]): channel blocks of
/// `BATCH x BLOCK_OUT` lanes per pixel, tile index
/// `((n_b * CB + c_b) * H + y) * W + x` — the output-buffer tiling
/// that [`unpack_outputs`] reads back, widened to the register file's
/// i32 lanes. Channel padding lanes are zero.
pub fn pack_acc_nchw(cfg: &VtaConfig, t: &Tensor<i8>) -> Vec<i8> {
    let (bo, b) = (cfg.gemm.block_out, cfg.gemm.batch);
    let [n, c, h, w] = [t.shape()[0], t.shape()[1], t.shape()[2], t.shape()[3]];
    assert_eq!(n % b, 0, "batch {n} not a multiple of BATCH {b}");
    let cb = blocks(c, bo);
    let tile = b * bo;
    let mut out = vec![0i8; (n / b) * cb * h * w * tile * 4];
    let src = t.data();
    for nb in 0..n / b {
        for cb_i in 0..cb {
            for y in 0..h {
                for x in 0..w {
                    let t_idx = ((nb * cb + cb_i) * h + y) * w + x;
                    for bb in 0..b {
                        for ci in 0..bo {
                            let cc = cb_i * bo + ci;
                            if cc < c {
                                let s = (((nb * b + bb) * c + cc) * h + y) * w + x;
                                let lane = t_idx * tile + bb * bo + ci;
                                for (j, byte) in (src[s] as i32).to_le_bytes().iter().enumerate() {
                                    out[lane * 4 + j] = *byte as i8;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Inverse of the elementwise output image: the first
/// `shape.product()` int8 lanes of the packed output tiles (padding
/// lanes dropped).
pub fn unpack_eltwise(packed: &[i8], shape: &[usize]) -> Tensor<i8> {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, packed[..n].to_vec()).expect("shape covers the unpacked lanes")
}

/// Unpack matmul output tiles (`m_b * NB + n_b`, `B x BO` i8) back to a
/// row-major `(M, N)` matrix.
pub fn unpack_matrix_c(cfg: &VtaConfig, packed: &[i8], m: usize, n: usize) -> Tensor<i8> {
    let (bo, b) = (cfg.gemm.block_out, cfg.gemm.batch);
    let nb = blocks(n, bo);
    let tile = b * bo;
    let mut out = Tensor::zeros(&[m, n]);
    let dst = out.data_mut();
    for mb in 0..m / b {
        for nb_i in 0..nb {
            let t_idx = mb * nb + nb_i;
            for bb in 0..b {
                for ni in 0..bo {
                    let nn = nb_i * bo + ni;
                    if nn < n {
                        dst[(mb * b + bb) * n + nn] = packed[t_idx * tile + bb * bo + ni];
                    }
                }
            }
        }
    }
    out
}
