//! Conv2d lowering onto the VTA GEMM intrinsic (§4.2 tensorization +
//! §4.3 virtual threading), mirroring Fig 13's schedule pipeline:
//! tile → cache in scoped buffers → tensorize → (virtual-thread) lower
//! to runtime calls.
//!
//! Data layouts (see [`crate::compiler::layout`]):
//! * input  DRAM: tile `(ic_b * H + ih) * W + iw`
//! * weight DRAM: tile `((oc_b * ICB + ic_b) * K + kh) * K + kw`
//! * output DRAM: tile `(oc_b * OH + oh) * OW + ow`
//!
//! Strip-local SRAM layouts:
//! * input  SRAM: `ctx_off + ic_b * (ih_span * iw_tiles) + ih * iw_tiles + iw`
//! * weight SRAM: group-resident, same order as DRAM within the group
//! * acc/out SRAM: `ctx_off + (oc_i * oh_t + oh) * ow_t + ow`
//!   (oc-major so each `(oc_i)` plane stores as one 2D STORE)
//!
//! The emission core ([`emit_conv2d`]) is target-agnostic: it writes
//! into any [`CommandContext`] and invokes a caller-supplied *boundary*
//! action wherever the stream must be finalized (per group when the
//! plan drains between groups, once at the end otherwise). The two
//! callers are [`lower_conv2d`] (execute immediately on the runtime's
//! device — the one-shot path) and
//! [`crate::compiler::compile_conv2d`] (seal into replayable streams —
//! the plan-cache path).

use super::alu::push_fused_epilogue;
use super::plan::{
    plan_conv2d_tuned, Conv2dParams, Conv2dPlan, FusedStep, PlanError, ScheduleChoice,
};
use super::virtual_thread::StripPipeline;
use crate::isa::{AluUop, BufferId, GemmUop, Uop};
use crate::runtime::{
    CommandContext, RuntimeError, UopKernel, UopKernelBuilder, VtaRuntime,
};
use crate::sim::SimStats;
use std::collections::HashMap;
use thiserror::Error;

/// Compilation errors.
#[derive(Debug, Error)]
pub enum CompileError {
    #[error("planning failed: {0}")]
    Plan(#[from] PlanError),
    #[error("runtime error: {0}")]
    Runtime(#[from] RuntimeError),
    #[error("allocation error: {0}")]
    Alloc(#[from] crate::runtime::AllocError),
    #[error("op {0} cannot run on the VTA device")]
    NotOffloadable(&'static str),
    #[error("missing weights")]
    MissingWeights,
    #[error(
        "replica DRAM layout diverged: expected a buffer at {expected:#x}, allocator returned \
         {got:#x} — pool caches were not driven in lockstep"
    )]
    ReplicaDiverged { expected: usize, got: usize },
    #[error("shared compile failed on the owning worker: {0}")]
    ClaimFailed(String),
}

/// Result of running a lowered conv2d on the device.
#[derive(Debug)]
pub struct Conv2dOutput {
    /// Merged simulation statistics over all instruction streams.
    pub stats: SimStats,
    /// Packed output tiles (`(oc_b * OH + oh) * OW + ow`).
    pub out: Vec<i8>,
    /// The tiling that was used.
    pub plan: Conv2dPlan,
}

/// Kernel-cache key: every distinct (context, strip shape, group width)
/// combination needs its own micro-op kernel.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct KernelKey {
    kind: u8, // 0 = main, 1 = reset, 2 = alu, 3 = fused residual add
    context: u8,
    wgt_ctx: u8,
    oh_cur: u16,
    ow_cur: u16,
    oc_cur: u16,
}

struct KernelSet {
    kernels: HashMap<KernelKey, (usize, UopKernel)>,
}

impl KernelSet {
    fn new() -> Self {
        KernelSet { kernels: HashMap::new() }
    }

    fn get_or_build(
        &mut self,
        ctx: &mut CommandContext,
        key: KernelKey,
        build: impl FnOnce() -> Result<UopKernel, RuntimeError>,
    ) -> Result<(usize, UopKernel), CompileError> {
        if let Some((id, k)) = self.kernels.get(&key) {
            return Ok((*id, k.clone()));
        }
        let kernel = build()?;
        let id = ctx.register_kernel(&kernel)?;
        self.kernels.insert(key, (id, kernel.clone()));
        Ok((id, kernel))
    }
}

/// Tile-granular DRAM base addresses of a conv2d's data images.
/// `res` is the fused residual operand's ACC-tile-granular image
/// (`Some` only for fused chains carrying an `AddResidual` step).
#[derive(Clone, Copy, Debug)]
pub(crate) struct ConvDramBase {
    pub inp: u32,
    pub wgt: u32,
    pub out: u32,
    pub res: Option<u32>,
}

/// Emit the full conv2d instruction stream for `plan` into `ctx`,
/// calling `boundary` wherever the stream must be finalized: after
/// every group when the plan drains between groups, once at the very
/// end otherwise. The boundary action either executes-and-merges
/// (one-shot lowering) or seals a replayable stream (plan compilation).
pub(crate) fn emit_conv2d<F>(
    ctx: &mut CommandContext,
    p: &Conv2dParams,
    plan: &Conv2dPlan,
    base: ConvDramBase,
    steps: &[FusedStep],
    mut boundary: F,
) -> Result<(), CompileError>
where
    F: FnMut(&mut CommandContext) -> Result<(), CompileError>,
{
    let cfg = ctx.config().clone();
    let virtual_threads = plan.contexts;
    let k = p.k;

    // Context strides use the ISA-addressable depth (see plan.rs). The
    // acc stride is additionally bounded by the OUT depth: every
    // compute write mirrors, narrowed, into the out buffer at the same
    // index, so a DSE-sampled variant with a shallower out SRAM must
    // not stride past it (same rule as compiler::alu).
    let inp_ctx_stride = cfg.inp_depth().min(1 << 11) / 2;
    let acc_ctx_stride = cfg.acc_depth().min(cfg.out_depth()).min(1 << 11) / 2;
    let wgt_ctx_stride = cfg.wgt_depth().min(1 << 10) / 2;

    let mut kernels = KernelSet::new();
    let span = |t: usize| (t - 1) * p.s + k;

    // One stream across all groups: a group's weights are loaded as the
    // *first load of its first strip*, so the regular strip WAR token
    // covers the weight-context reuse (compute-module FIFO monotonicity
    // means any later GEMM's token implies every GEMM of the previous
    // occupant group has retired). Only the drain_groups fallback
    // synchronizes per group.
    let mut pipe = StripPipeline::new(virtual_threads);
    for g in 0..plan.groups() {
        let oc0 = g * plan.oc_t;
        let oc_cur = plan.oc_t.min(plan.ocb - oc0);
        let wgt_ctx = g % plan.wgt_contexts;
        let wgt_tiles = oc_cur * plan.icb * k * k;
        let mut wgt_load = Some(WgtLoad {
            sram_base: (wgt_ctx * wgt_ctx_stride) as u32,
            dram_tile: base.wgt + (oc0 * plan.icb * k * k) as u32,
            tiles: wgt_tiles as u16,
        });

        let mut oh0 = 0;
        while oh0 < plan.oh {
            let oh_cur = plan.oh_t.min(plan.oh - oh0);
            let mut ow0 = 0;
            while ow0 < plan.ow {
                let ow_cur = plan.ow_t.min(plan.ow - ow0);
                emit_strip(
                    ctx,
                    &mut kernels,
                    &mut pipe,
                    p,
                    plan,
                    StripGeom {
                        g,
                        oc0,
                        oc_cur,
                        oh0,
                        oh_cur,
                        ow0,
                        ow_cur,
                        ih_span: span(oh_cur),
                        iw_tiles: span(ow_cur),
                    },
                    wgt_load.take(),
                    (wgt_ctx * wgt_ctx_stride) as u16,
                    base,
                    steps,
                    inp_ctx_stride,
                    acc_ctx_stride,
                )?;
                ow0 += ow_cur;
            }
            oh0 += oh_cur;
        }

        if plan.drain_groups {
            boundary(ctx)?;
            pipe = StripPipeline::new(virtual_threads);
        }
    }
    if !plan.drain_groups {
        boundary(ctx)?;
    }
    Ok(())
}

/// Lower, execute, and read back one conv2d layer — the one-shot path
/// (re-plans, re-emits, and re-simulates on every call; the serving
/// layer's plan cache uses [`crate::compiler::compile_conv2d`] to pay
/// the lowering cost once instead).
///
/// `inp_packed` / `wgt_packed` are the tiled DRAM images produced by
/// [`super::layout::pack_activations`] / [`super::layout::pack_weights`].
/// `virtual_threads` ∈ {1, 2} toggles latency hiding.
pub fn lower_conv2d(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    inp_packed: &[i8],
    wgt_packed: &[i8],
    virtual_threads: usize,
) -> Result<Conv2dOutput, CompileError> {
    lower_conv2d_tuned(rt, p, inp_packed, wgt_packed, virtual_threads, None)
}

/// [`lower_conv2d`] with an optional tuned schedule override — the
/// DSE tuner's measurement path ([`crate::dse::tune`]).
pub fn lower_conv2d_tuned(
    rt: &mut VtaRuntime,
    p: &Conv2dParams,
    inp_packed: &[i8],
    wgt_packed: &[i8],
    virtual_threads: usize,
    schedule: Option<&ScheduleChoice>,
) -> Result<Conv2dOutput, CompileError> {
    let cfg = rt.ctx.config().clone();
    let plan = plan_conv2d_tuned(&cfg, p, virtual_threads, schedule)?;

    // DRAM images (aligned to their tile sizes: dram_base fields are
    // tile-granular).
    let inp_tile_bytes = cfg.inp_tile_bytes();
    let wgt_tile_bytes = cfg.wgt_tile_bytes();
    let out_tile_bytes = cfg.out_tile_bytes();
    let inp_buf = rt.alloc_aligned(inp_packed.len(), inp_tile_bytes)?;
    let wgt_buf = rt.alloc_aligned(wgt_packed.len(), wgt_tile_bytes)?;
    let out_tiles = plan.ocb * plan.oh * plan.ow;
    let out_buf = rt.alloc_aligned(out_tiles * out_tile_bytes, out_tile_bytes)?;
    rt.copy_in(&inp_buf, bytes_of_i8(inp_packed))?;
    rt.copy_in(&wgt_buf, bytes_of_i8(wgt_packed))?;
    let base = ConvDramBase {
        inp: (inp_buf.addr / inp_tile_bytes) as u32,
        wgt: (wgt_buf.addr / wgt_tile_bytes) as u32,
        out: (out_buf.addr / out_tile_bytes) as u32,
        res: None,
    };

    let mut stats = SimStats::default();
    {
        let VtaRuntime { ctx, device, .. } = rt;
        emit_conv2d(ctx, p, &plan, base, &[], |ctx| {
            stats.merge(&ctx.synchronize(&mut *device)?);
            Ok(())
        })?;
    }

    let out_bytes = rt.copy_out(&out_buf)?;
    let out: Vec<i8> = out_bytes.iter().map(|&b| b as i8).collect();
    // Release DRAM so repeated layers don't leak.
    rt.dram.free(inp_buf)?;
    rt.dram.free(wgt_buf)?;
    rt.dram.free(out_buf)?;
    Ok(Conv2dOutput { stats, out, plan })
}

struct StripGeom {
    g: usize,
    oc0: usize,
    oc_cur: usize,
    oh0: usize,
    oh_cur: usize,
    ow0: usize,
    ow_cur: usize,
    ih_span: usize,
    iw_tiles: usize,
}

/// Pending weight load for a group's first strip.
struct WgtLoad {
    sram_base: u32,
    dram_tile: u32,
    tiles: u16,
}

#[allow(clippy::too_many_arguments)]
fn emit_strip(
    ctx: &mut CommandContext,
    kernels: &mut KernelSet,
    pipe: &mut StripPipeline,
    p: &Conv2dParams,
    plan: &Conv2dPlan,
    geom: StripGeom,
    wgt_load: Option<WgtLoad>,
    wgt_base: u16,
    base: ConvDramBase,
    steps: &[FusedStep],
    inp_ctx_stride: usize,
    acc_ctx_stride: usize,
) -> Result<(), CompileError> {
    let (inp_dram0, out_dram0) = (base.inp, base.out);
    let tok = pipe.begin();
    let c = tok.context;
    let inp_off = if c == 1 { inp_ctx_stride } else { 0 };
    let acc_off = if c == 1 { acc_ctx_stride } else { 0 };
    // Fused residual operand: resident in the upper half of the
    // context's ACC span (the fused planner halved the strip budget to
    // keep this half free).
    let res_off = acc_off + acc_ctx_stride / plan.contexts;
    let k = p.k;
    let plane = geom.ih_span * geom.iw_tiles;

    // ---- loads --------------------------------------------------------
    pipe.loads_prologue(ctx, tok)?;
    if let Some(wl) = wgt_load {
        // First load of the group's first strip: the strip's WAR pop
        // (attached to this instruction) also fences the weight-context
        // reuse, by compute-FIFO monotonicity.
        ctx.load_buffer_2d(BufferId::Wgt, wl.sram_base, wl.dram_tile, 1, wl.tiles, wl.tiles, [0; 4]);
    }
    let ih_lo = geom.oh0 as isize * p.s as isize - plan.pad as isize;
    let iw_lo = geom.ow0 as isize * p.s as isize - plan.pad as isize;
    let vy0 = ih_lo.max(0) as usize;
    let vy1 = ((ih_lo + geom.ih_span as isize).min(p.h as isize)) as usize;
    let vx0 = iw_lo.max(0) as usize;
    let vx1 = ((iw_lo + geom.iw_tiles as isize).min(p.w as isize)) as usize;
    let pads = [
        (vy0 as isize - ih_lo) as u8,                           // y top
        ((ih_lo + geom.ih_span as isize) - vy1 as isize) as u8, // y bottom
        (vx0 as isize - iw_lo) as u8,                           // x left
        ((iw_lo + geom.iw_tiles as isize) - vx1 as isize) as u8, // x right
    ];
    // When the strip needs no spatial padding and spans full contiguous
    // rows, all input planes coalesce into ONE 2D DMA (y = planes,
    // x = rows*W, stride = H*W): this removes icb-1 per-burst DRAM
    // latencies per strip — decisive for the 1x1 layers.
    let coalesce = pads == [0; 4]
        && geom.iw_tiles == p.w
        && plane == (vy1 - vy0) * geom.iw_tiles
        && (vy1 - vy0) * p.w <= u16::MAX as usize;
    if coalesce {
        ctx.load_buffer_2d(
            BufferId::Inp,
            inp_off as u32,
            inp_dram0 + (vy0 * p.w) as u32,
            plan.icb as u16,
            ((vy1 - vy0) * p.w) as u16,
            (p.h * p.w) as u16,
            [0; 4],
        );
    } else {
        for ic_b in 0..plan.icb {
            ctx.load_buffer_2d(
                BufferId::Inp,
                (inp_off + ic_b * plane) as u32,
                inp_dram0 + ((ic_b * p.h + vy0) * p.w + vx0) as u32,
                (vy1 - vy0) as u16,
                (vx1 - vx0) as u16,
                p.w as u16,
                pads,
            );
        }
    }
    pipe.loads_epilogue(ctx)?;

    // ---- compute ------------------------------------------------------
    pipe.compute_prologue(ctx, tok)?;

    // Fused residual: load the matching output-shaped ACC tiles into
    // the upper half of the context span. ACC loads execute on the
    // compute module, so program order alone serializes them against
    // this strip's GEMM/ALU ops, and the strip's WAR pop (attached to
    // this first compute instruction when the context is reused)
    // fences against the previous occupant's stores.
    if let Some(res_dram0) = base.res {
        for oc_i in 0..geom.oc_cur {
            ctx.load_buffer_2d(
                BufferId::Acc,
                (res_off + oc_i * geom.oh_cur * geom.ow_cur) as u32,
                res_dram0
                    + (((geom.oc0 + oc_i) * plan.oh + geom.oh0) * plan.ow + geom.ow0) as u32,
                geom.oh_cur as u16,
                geom.ow_cur as u16,
                plan.ow as u16,
                [0; 4],
            );
        }
    }

    let kkey = |kind: u8| KernelKey {
        kind,
        context: c as u8,
        wgt_ctx: (wgt_base != 0) as u8,
        oh_cur: geom.oh_cur as u16,
        ow_cur: geom.ow_cur as u16,
        oc_cur: geom.oc_cur as u16,
    };

    // Reset kernel: zero every acc tile of the strip.
    let (rid, rk) = kernels.get_or_build(ctx, kkey(1), || {
        let mut b = UopKernelBuilder::new();
        b.loop_begin(geom.oh_cur as u16, geom.ow_cur as u16, 0, 0).map_err(RuntimeError::Uop)?;
        b.loop_begin(geom.ow_cur as u16, 1, 0, 0).map_err(RuntimeError::Uop)?;
        for oc_i in 0..geom.oc_cur {
            b.push(Uop::Gemm(GemmUop {
                acc_idx: (acc_off + oc_i * geom.oh_cur * geom.ow_cur) as u16,
                inp_idx: 0,
                wgt_idx: 0,
            }))
            .map_err(RuntimeError::Uop)?;
        }
        b.loop_end().map_err(RuntimeError::Uop)?;
        b.loop_end().map_err(RuntimeError::Uop)?;
        b.finish().map_err(RuntimeError::Uop)
    })?;
    ctx.push_gemm(rid, &rk, true)?;

    // Main kernel: the tensorized reduction over (oc_i, ic_b, kh, kw).
    let icb = plan.icb;
    let iw_tiles = geom.iw_tiles;
    let (mid, mk) = kernels.get_or_build(ctx, kkey(0), || {
        let mut b = UopKernelBuilder::new();
        b.loop_begin(
            geom.oh_cur as u16,
            geom.ow_cur as u16,
            (p.s * iw_tiles) as u16,
            0,
        )
        .map_err(RuntimeError::Uop)?;
        b.loop_begin(geom.ow_cur as u16, 1, p.s as u16, 0).map_err(RuntimeError::Uop)?;
        for oc_i in 0..geom.oc_cur {
            for ic_b in 0..icb {
                for kh in 0..k {
                    for kw in 0..k {
                        b.push(Uop::Gemm(GemmUop {
                            acc_idx: (acc_off + oc_i * geom.oh_cur * geom.ow_cur) as u16,
                            inp_idx: (inp_off + ic_b * plane + kh * iw_tiles + kw) as u16,
                            wgt_idx: wgt_base + (((oc_i * icb + ic_b) * k + kh) * k + kw) as u16,
                        }))
                        .map_err(RuntimeError::Uop)?;
                    }
                }
            }
        }
        b.loop_end().map_err(RuntimeError::Uop)?;
        b.loop_end().map_err(RuntimeError::Uop)?;
        b.finish().map_err(RuntimeError::Uop)
    })?;
    ctx.push_gemm(mid, &mk, false)?;
    pipe.gemm_epilogue(ctx)?;

    // Requantize on the tensor ALU — then, for fused chains, append
    // the epilogue steps as further ALU passes over the same resident
    // tiles (one ACC residency; every pass overwrites the out-buffer
    // mirror at the same indices, so stores read the last pass's
    // narrowed result — see `push_fused_epilogue`).
    let n_acc = geom.oc_cur * geom.oh_cur * geom.ow_cur;
    let (aid, ak) = kernels.get_or_build(ctx, kkey(2), || {
        let mut b = UopKernelBuilder::new();
        b.loop_begin(n_acc as u16, 1, 1, 0).map_err(RuntimeError::Uop)?;
        b.push(Uop::Alu(AluUop { dst_idx: acc_off as u16, src_idx: acc_off as u16 }))
            .map_err(RuntimeError::Uop)?;
        b.loop_end().map_err(RuntimeError::Uop)?;
        b.finish().map_err(RuntimeError::Uop)
    })?;
    let res_kernel = if steps.contains(&FusedStep::AddResidual) {
        Some(kernels.get_or_build(ctx, kkey(3), || {
            let mut b = UopKernelBuilder::new();
            b.loop_begin(n_acc as u16, 1, 1, 0).map_err(RuntimeError::Uop)?;
            b.push(Uop::Alu(AluUop { dst_idx: acc_off as u16, src_idx: res_off as u16 }))
                .map_err(RuntimeError::Uop)?;
            b.loop_end().map_err(RuntimeError::Uop)?;
            b.finish().map_err(RuntimeError::Uop)
        })?)
    } else {
        None
    };
    push_fused_epilogue(ctx, p.requant, steps, (aid, &ak), res_kernel.as_ref().map(|(id, k)| (*id, k)))?;
    pipe.alu_epilogue(ctx)?;

    // ---- stores -------------------------------------------------------
    for oc_i in 0..geom.oc_cur {
        ctx.store_buffer_2d(
            (acc_off + oc_i * geom.oh_cur * geom.ow_cur) as u32,
            out_dram0
                + (((geom.oc0 + oc_i) * plan.oh + geom.oh0) * plan.ow + geom.ow0) as u32,
            geom.oh_cur as u16,
            geom.ow_cur as u16,
            plan.ow as u16,
        );
    }
    pipe.stores_epilogue(ctx)?;
    let _ = geom.g;
    Ok(())
}

/// Reinterpret an i8 slice as bytes (DRAM copies).
pub(crate) fn bytes_of_i8(v: &[i8]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len()) }
}
