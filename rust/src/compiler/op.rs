//! The unified operator-lowering API: the [`VtaOp`] trait and the
//! operator registry.
//!
//! The paper's flexibility claim rests on the microcode-ISA
//! "implement[ing] a wide variety of operators with single-cycle
//! tensor-tensor operations" (§2.5). This module turns that claim into
//! an *open* software interface: every graph operator is described by
//! one [`VtaOp`] implementation that knows how to
//!
//! * decide whether a node can be lowered onto a given hardware
//!   variant ([`VtaOp::offloadable`]) and whether the partition policy
//!   wants it there ([`VtaOp::offload_policy`], [`VtaOp::cost`]),
//! * fingerprint everything its compiled artifact depends on
//!   ([`VtaOp::fingerprint`] — the plan-cache key material),
//! * compile once into a replayable [`CompiledNode`]
//!   ([`VtaOp::compile`]) and move data in and out of the packed DRAM
//!   images ([`VtaOp::pack_inputs`] / [`VtaOp::unpack_output`]), and
//! * compute the host-side reference semantics ([`VtaOp::reference`])
//!   — the CPU execution path *and* the verification oracle.
//!
//! The executor, the serving engine, and the partition pass dispatch
//! through [`op_impl`] instead of matching on `Op` variants, so adding
//! an operator is purely additive: implement the trait, register the
//! unit struct in [`REGISTRY`], done. `docs/ARCHITECTURE.md` has a
//! worked "add your own operator" walkthrough.

use super::compiled::{
    prepare_conv2d_chain, prepare_dense_tuned, prepare_eltwise, prepare_upsample2x, CompiledNode,
    PreparedPlan,
};
use super::conv2d::CompileError;
use super::layout::{
    pack_acc_i32, pack_acc_nchw, pack_activations, pack_matrix_a, pack_weights, unpack_eltwise,
    unpack_matrix_c, unpack_outputs,
};
use super::plan::{
    plan_conv2d, plan_conv2d_fused, plan_eltwise, plan_matmul, plan_upsample2x, FusedStep,
    ScheduleChoice,
};
use super::reference;
use super::EltwiseKind;
use crate::arch::VtaConfig;
use crate::graph::{Graph, Node, Op, PartitionPolicy};
use crate::runtime::VtaRuntime;
use crate::sim::SimStats;
use crate::util::Tensor;

// ---------------------------------------------------------------------
// Fingerprints (plan-cache key material).
// ---------------------------------------------------------------------

/// FNV-1a 64-bit over a byte stream (same constants as
/// `python/compile/synth.py::fnv1a64`).
pub fn fnv1a64(data: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// Fingerprint of a `VtaConfig`: plans compiled for one hardware
/// variant are never served to another (cross-config isolation).
pub fn config_fingerprint(cfg: &VtaConfig) -> u64 {
    fnv1a64(format!("{cfg:?}").into_bytes())
}

/// Fingerprint of a weight tensor (shape + contents).
pub fn weights_fingerprint(w: &Tensor<i8>) -> u64 {
    let shape = w.shape().iter().flat_map(|d| (*d as u64).to_le_bytes());
    let data = w.data().iter().map(|&v| v as u8);
    fnv1a64(shape.chain(data))
}

// ---------------------------------------------------------------------
// The operator trait.
// ---------------------------------------------------------------------

/// One graph operator's contract with the VTA stack.
///
/// Implementations are stateless unit structs; per-node parameters
/// arrive through the [`Node`] (and its [`Op`] variant — the *only*
/// place `Op` variants are matched is inside the operator's own
/// implementation). All methods take `&self` so the trait stays
/// object-safe and the registry can hold `&'static dyn VtaOp`.
pub trait VtaOp: Sync {
    /// Registry key; must equal [`Op::kind`] of the variants served.
    fn kind(&self) -> &'static str;

    /// True for the graph-input placeholder — the runner injects the
    /// request tensor instead of executing anything.
    fn is_input(&self) -> bool {
        false
    }

    /// Capability: can this node be lowered onto the accelerator under
    /// `cfg` with `virtual_threads` SRAM contexts? (Planning
    /// feasibility, not policy — vt=1 has twice the per-context budget
    /// of vt=2, so the answer depends on how the node will actually be
    /// lowered.)
    fn offloadable(&self, _cfg: &VtaConfig, _node: &Node, _virtual_threads: usize) -> bool {
        false
    }

    /// Preference: does the partition `policy` want this (offloadable)
    /// node on the VTA?
    fn offload_policy(&self, _node: &Node, _policy: &PartitionPolicy) -> bool {
        false
    }

    /// Integer-op cost estimate, used by the partition pass (nodes
    /// under `policy.min_offload_ops` stay on the CPU) and for Amdahl
    /// accounting.
    fn cost(&self, node: &Node) -> u64 {
        node.op.ops(&node.shape)
    }

    /// Fingerprint of everything the compiled artifact depends on
    /// besides the hardware config and virtual-thread count: operator
    /// parameters, output shape, and any baked-in constants (weights).
    ///
    /// The default hashes the `Op` debug form, the inferred output
    /// shape, and the node's weight image (when present) — sufficient
    /// for every built-in operator.
    fn fingerprint(&self, g: &Graph, node: &Node) -> u64 {
        let wfp = g.weights(node.id).map(weights_fingerprint).unwrap_or(0);
        fnv1a64(format!("{:?}|{:?}|{wfp:016x}", node.op, node.shape).into_bytes())
    }

    /// Fingerprint of everything the *schedule* depends on: operator
    /// parameters and output shape, but **not** the weights — the
    /// tuning-record key material ([`crate::dse::records`]). Two nodes
    /// with identical params share a tuned schedule even when their
    /// weight images differ, so records produced by `vta dse` on
    /// synthetic workloads apply to real serving graphs.
    fn schedule_fingerprint(&self, node: &Node) -> u64 {
        fnv1a64(format!("{:?}|{:?}", node.op, node.shape).into_bytes())
    }

    /// XLA/PJRT artifact name for the CPU backend (naming scheme shared
    /// with `python/compile/aot.py`); `None` when no artifact exists
    /// for this operator class.
    fn artifact_name(&self, _node: &Node) -> Option<String> {
        None
    }

    // -----------------------------------------------------------------
    // Fusion capability (drives `graph::fuse` — the pass matches on
    // these methods, never on `Op` variants).
    // -----------------------------------------------------------------

    /// Can a trailing standalone `Relu` fold into this operator's
    /// requant epilogue (`Requant::relu` → the `RQ_RELU` opcode)?
    fn folds_relu(&self) -> bool {
        false
    }

    /// Can this operator anchor a fused epilogue chain (become the
    /// conv of an [`Op::FusedConv2d`])?
    fn anchors_fusion(&self) -> bool {
        false
    }

    /// If this operator can ride a fused chain as an epilogue, the
    /// [`FusedStep`] describing its tensor-ALU pass; `None` breaks the
    /// chain.
    fn fuse_step(&self, _op: &Op) -> Option<FusedStep> {
        None
    }

    /// Compile-once, reserve half: plan the lowering, pack the node's
    /// constants, and pin down the DRAM allocation requirements —
    /// *without* touching a runtime, so a pool scheduler can run this
    /// outside (or with) its directory lock and compile distinct plans
    /// concurrently. The returned [`PreparedPlan`] carries the
    /// allocation request list plus the runtime half (constant
    /// copy-in, emission, stream sealing) as a deferred lower step.
    ///
    /// `schedule` is an optional tuned tiling from the DSE record
    /// store ([`crate::dse`]); operators without tunable schedules
    /// ignore it. The default refuses — CPU-resident operators report
    /// [`CompileError::NotOffloadable`].
    fn prepare(
        &self,
        _cfg: &VtaConfig,
        _g: &Graph,
        _node: &Node,
        _virtual_threads: usize,
        _schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        Err(CompileError::NotOffloadable(self.kind()))
    }

    /// Compile-once: perform all input-independent lowering (plan,
    /// pack + copy constants into DRAM residency, record + seal the
    /// instruction streams) and return the replayable artifact —
    /// [`Self::prepare`] followed by [`PreparedPlan::finish`] on `rt`.
    fn compile(
        &self,
        rt: &mut VtaRuntime,
        g: &Graph,
        node: &Node,
        virtual_threads: usize,
        schedule: Option<&ScheduleChoice>,
    ) -> Result<CompiledNode, CompileError> {
        let cfg = rt.ctx.config().clone();
        self.prepare(&cfg, g, node, virtual_threads, schedule)?.finish(rt)
    }

    /// Run-many, input half: pack the node's variable inputs into the
    /// DRAM images the compiled plan expects (one image per graph
    /// input, in input order).
    fn pack_inputs(&self, _cfg: &VtaConfig, _inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        Vec::new()
    }

    /// Run-many, output half: unpack the compiled plan's output image
    /// into the node's output tensor.
    fn unpack_output(
        &self,
        _cfg: &VtaConfig,
        _compiled: &CompiledNode,
        _packed: &[i8],
        _inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        unreachable!("operator {} does not compile to the VTA", self.kind())
    }

    /// Host-side reference semantics: the CPU-native execution path
    /// and the oracle every lowered path is verified against.
    fn reference(
        &self,
        g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError>;
}

/// Run a compiled node on concrete input tensors: pack → replay the
/// sealed streams → unpack. The shared run-many path of the serial
/// executor and the serving engine.
pub fn execute_compiled(
    entry: &dyn VtaOp,
    compiled: &CompiledNode,
    rt: &mut VtaRuntime,
    inputs: &[&Tensor<i8>],
) -> Result<(Tensor<i8>, SimStats), CompileError> {
    let cfg = rt.ctx.config().clone();
    let packed = entry.pack_inputs(&cfg, inputs);
    let (out_packed, stats) = compiled.execute(rt, &packed)?;
    Ok((entry.unpack_output(&cfg, compiled, &out_packed, inputs), stats))
}

// ---------------------------------------------------------------------
// The registry.
// ---------------------------------------------------------------------

/// Every registered operator implementation. Order is presentation
/// order only; lookup is by [`VtaOp::kind`].
pub static REGISTRY: &[&'static dyn VtaOp] = &[
    &InputVta,
    &Conv2dVta,
    &FusedConvVta,
    &DenseVta,
    &AddVta,
    &ReluVta,
    &MinVta,
    &ShrVta,
    &UpsampleVta,
    &MaxPoolVta,
    &GapVta,
];

/// Look up an operator implementation by kind string.
pub fn lookup(kind: &str) -> Option<&'static dyn VtaOp> {
    REGISTRY.iter().copied().find(|e| e.kind() == kind)
}

/// The implementation serving a graph operator. Every [`Op`] variant
/// has a registered implementation, so this is total.
pub fn op_impl(op: &Op) -> &'static dyn VtaOp {
    lookup(op.kind()).expect("every operator kind is registered")
}

// ---------------------------------------------------------------------
// Built-in operator implementations.
// ---------------------------------------------------------------------

fn shape_tag(s: &[usize]) -> String {
    s.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
}

fn numel(node: &Node) -> usize {
    node.shape.iter().product()
}

/// Graph-input placeholder: never executes; the runner injects the
/// request tensor.
pub struct InputVta;

impl VtaOp for InputVta {
    fn kind(&self) -> &'static str {
        "input"
    }

    fn is_input(&self) -> bool {
        true
    }

    fn reference(
        &self,
        _g: &Graph,
        _node: &Node,
        _inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        unreachable!("graph inputs are injected by the runner")
    }
}

/// 2D convolution on the GEMM intrinsic (§4.2) — the flagship
/// tensorized operator.
pub struct Conv2dVta;

impl VtaOp for Conv2dVta {
    fn kind(&self) -> &'static str {
        "conv2d"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        match &node.op {
            Op::Conv2d { p } => plan_conv2d(cfg, p, virtual_threads).is_ok(),
            _ => false,
        }
    }

    fn offload_policy(&self, node: &Node, policy: &PartitionPolicy) -> bool {
        match &node.op {
            Op::Conv2d { p } => p.ic >= policy.min_conv_ic,
            _ => false,
        }
    }

    fn folds_relu(&self) -> bool {
        true
    }

    fn anchors_fusion(&self) -> bool {
        true
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        let Op::Conv2d { p } = &node.op else { return None };
        Some(format!(
            "conv_{}_{}_{}_{}_{}_{}",
            p.h, p.ic, p.oc, p.k, p.s, p.requant.relu as u8
        ))
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        g: &Graph,
        node: &Node,
        virtual_threads: usize,
        schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        let Op::Conv2d { p } = &node.op else {
            return Err(CompileError::NotOffloadable(self.kind()));
        };
        let w = g.weights(node.id).ok_or(CompileError::MissingWeights)?;
        let wp = pack_weights(cfg, w);
        prepare_conv2d_chain(cfg, p, &[], wp, virtual_threads, schedule)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_activations(cfg, inputs[0])]
    }

    fn unpack_output(
        &self,
        cfg: &VtaConfig,
        compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        let Op::Conv2d { p } = &compiled.op else {
            unreachable!("conv2d artifact carries conv2d params")
        };
        unpack_outputs(cfg, packed, inputs[0].shape()[0], p.oc, p.out_h(), p.out_w())
    }

    fn reference(
        &self,
        g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        let Op::Conv2d { p } = &node.op else {
            unreachable!("conv2d entry serves conv2d nodes")
        };
        let w = g.weights(node.id).ok_or(CompileError::MissingWeights)?;
        Ok(reference::conv2d_ref(p, inputs[0], w))
    }
}

/// A conv with a fused epilogue chain ([`Op::FusedConv2d`], produced
/// by [`crate::graph::fuse`]): the whole chain compiles into one
/// `CompiledNode` — one ACC residency, the residual DMA'd into the
/// accumulator and added on the tensor ALU, epilogue passes appended
/// to the same microcode stream, no intermediate store/load.
pub struct FusedConvVta;

impl FusedConvVta {
    /// The residual image rides the output's ACC-tile order, which
    /// matches [`pack_acc_nchw`] only when the batch fills exactly one
    /// GEMM batch row group.
    fn residual_ok(cfg: &VtaConfig, node: &Node, steps: &[FusedStep]) -> bool {
        !steps.contains(&FusedStep::AddResidual) || node.shape[0] == cfg.gemm.batch
    }
}

impl VtaOp for FusedConvVta {
    fn kind(&self) -> &'static str {
        "fused_conv2d"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        match &node.op {
            Op::FusedConv2d { p, steps } => {
                Self::residual_ok(cfg, node, steps)
                    && plan_conv2d_fused(cfg, p, steps, virtual_threads, None).is_ok()
            }
            _ => false,
        }
    }

    fn offload_policy(&self, node: &Node, policy: &PartitionPolicy) -> bool {
        match &node.op {
            Op::FusedConv2d { p, .. } => p.ic >= policy.min_conv_ic,
            _ => false,
        }
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        g: &Graph,
        node: &Node,
        virtual_threads: usize,
        schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        let Op::FusedConv2d { p, steps } = &node.op else {
            return Err(CompileError::NotOffloadable(self.kind()));
        };
        if !Self::residual_ok(cfg, node, steps) {
            return Err(CompileError::NotOffloadable(self.kind()));
        }
        let w = g.weights(node.id).ok_or(CompileError::MissingWeights)?;
        let wp = pack_weights(cfg, w);
        prepare_conv2d_chain(cfg, p, steps, wp, virtual_threads, schedule)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        let mut packed = vec![pack_activations(cfg, inputs[0])];
        if let Some(res) = inputs.get(1) {
            // Residual: int8 values widened into the int32 accumulator
            // layout, ACC-tile order matching the conv's output tiles.
            packed.push(pack_acc_nchw(cfg, res));
        }
        packed
    }

    fn unpack_output(
        &self,
        cfg: &VtaConfig,
        compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        let Op::FusedConv2d { p, .. } = &compiled.op else {
            unreachable!("fused conv artifact carries fused conv params")
        };
        unpack_outputs(cfg, packed, inputs[0].shape()[0], p.oc, p.out_h(), p.out_w())
    }

    fn reference(
        &self,
        g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        let Op::FusedConv2d { p, steps } = &node.op else {
            unreachable!("fused conv entry serves fused conv nodes")
        };
        let w = g.weights(node.id).ok_or(CompileError::MissingWeights)?;
        let mut out = reference::conv2d_ref(p, inputs[0], w);
        for step in steps {
            out = match step {
                FusedStep::AddResidual => reference::add_i8(&out, inputs[1]),
                FusedStep::Relu => reference::relu_i8(&out),
                FusedStep::ShrImm { shift } => reference::shr_imm_i8(&out, *shift),
                FusedStep::MinImm { imm } => reference::min_imm_i8(&out, *imm),
            };
        }
        Ok(out)
    }
}

/// Dense / fully-connected layer on the GEMM intrinsic — the Fig 13
/// matmul workload, compile-once via [`compile_dense`].
pub struct DenseVta;

impl VtaOp for DenseVta {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        match &node.op {
            Op::Dense { p } => plan_matmul(cfg, p, virtual_threads).is_ok(),
            _ => false,
        }
    }

    fn offload_policy(&self, _node: &Node, policy: &PartitionPolicy) -> bool {
        policy.offload_dense
    }

    fn folds_relu(&self) -> bool {
        true
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        let Op::Dense { p } = &node.op else { return None };
        Some(format!("dense_{}_{}_{}", p.m, p.k, p.n))
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        g: &Graph,
        node: &Node,
        virtual_threads: usize,
        schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        let Op::Dense { p } = &node.op else {
            return Err(CompileError::NotOffloadable(self.kind()));
        };
        let w = g.weights(node.id).ok_or(CompileError::MissingWeights)?;
        let wp = super::layout::pack_matrix_w(cfg, w);
        prepare_dense_tuned(cfg, p, wp, virtual_threads, schedule)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_matrix_a(cfg, inputs[0])]
    }

    fn unpack_output(
        &self,
        cfg: &VtaConfig,
        compiled: &CompiledNode,
        packed: &[i8],
        _inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        let Op::Dense { p } = &compiled.op else {
            unreachable!("dense artifact carries matmul params")
        };
        unpack_matrix_c(cfg, packed, p.m, p.n)
    }

    fn reference(
        &self,
        g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        let Op::Dense { p } = &node.op else {
            unreachable!("dense entry serves dense nodes")
        };
        let w = g.weights(node.id).ok_or(CompileError::MissingWeights)?;
        Ok(reference::dense_i8(p, inputs[0], w))
    }
}

/// Saturating residual addition on the tensor-ALU micro-op path
/// (tensor-tensor ADD, then an Rq clamp into the int8 range).
pub struct AddVta;

impl VtaOp for AddVta {
    fn kind(&self) -> &'static str {
        "add"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        plan_eltwise(cfg, numel(node), EltwiseKind::AddSat.operands(), virtual_threads).is_ok()
    }

    fn offload_policy(&self, _node: &Node, policy: &PartitionPolicy) -> bool {
        policy.offload_alu
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        Some(format!("add_{}", shape_tag(&node.shape)))
    }

    fn fuse_step(&self, _op: &Op) -> Option<FusedStep> {
        Some(FusedStep::AddResidual)
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        _g: &Graph,
        node: &Node,
        virtual_threads: usize,
        _schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        prepare_eltwise(cfg, EltwiseKind::AddSat, numel(node), virtual_threads)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_acc_i32(cfg, inputs[0]), pack_acc_i32(cfg, inputs[1])]
    }

    fn unpack_output(
        &self,
        _cfg: &VtaConfig,
        _compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        unpack_eltwise(packed, inputs[0].shape())
    }

    fn reference(
        &self,
        _g: &Graph,
        _node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        Ok(reference::add_i8(inputs[0], inputs[1]))
    }
}

/// Standalone ReLU on the tensor-ALU micro-op path (MAX with a zero
/// immediate). Most ReLUs fuse into their producer's requant epilogue;
/// the survivors (after residual adds) can still offload.
pub struct ReluVta;

impl VtaOp for ReluVta {
    fn kind(&self) -> &'static str {
        "relu"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        plan_eltwise(cfg, numel(node), EltwiseKind::Relu.operands(), virtual_threads).is_ok()
    }

    fn offload_policy(&self, _node: &Node, policy: &PartitionPolicy) -> bool {
        policy.offload_alu
    }

    fn fuse_step(&self, _op: &Op) -> Option<FusedStep> {
        Some(FusedStep::Relu)
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        _g: &Graph,
        node: &Node,
        virtual_threads: usize,
        _schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        prepare_eltwise(cfg, EltwiseKind::Relu, numel(node), virtual_threads)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_acc_i32(cfg, inputs[0])]
    }

    fn unpack_output(
        &self,
        _cfg: &VtaConfig,
        _compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        unpack_eltwise(packed, inputs[0].shape())
    }

    fn reference(
        &self,
        _g: &Graph,
        _node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        Ok(reference::relu_i8(inputs[0]))
    }
}

/// Element-wise minimum with a broadcast immediate on the tensor-ALU
/// micro-op path (a single `MIN`) — the clamping half of a
/// requantization epilogue expressed in microcode instead of a CPU
/// fixup.
pub struct MinVta;

impl VtaOp for MinVta {
    fn kind(&self) -> &'static str {
        "min"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        plan_eltwise(cfg, numel(node), 1, virtual_threads).is_ok()
    }

    fn offload_policy(&self, _node: &Node, policy: &PartitionPolicy) -> bool {
        policy.offload_alu
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        Some(format!("min_{}", shape_tag(&node.shape)))
    }

    fn fuse_step(&self, op: &Op) -> Option<FusedStep> {
        let Op::MinImm { imm } = op else { return None };
        Some(FusedStep::MinImm { imm: *imm })
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        _g: &Graph,
        node: &Node,
        virtual_threads: usize,
        _schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        let Op::MinImm { imm } = &node.op else {
            return Err(CompileError::NotOffloadable(self.kind()));
        };
        prepare_eltwise(cfg, EltwiseKind::MinImm(*imm), numel(node), virtual_threads)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_acc_i32(cfg, inputs[0])]
    }

    fn unpack_output(
        &self,
        _cfg: &VtaConfig,
        _compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        unpack_eltwise(packed, inputs[0].shape())
    }

    fn reference(
        &self,
        _g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        let Op::MinImm { imm } = &node.op else {
            unreachable!("min entry serves min nodes")
        };
        Ok(reference::min_imm_i8(inputs[0], *imm))
    }
}

/// Element-wise arithmetic shift-right on the tensor-ALU micro-op path
/// (a single `SHR`) — the scaling half of a microcoded requantization
/// epilogue.
pub struct ShrVta;

impl VtaOp for ShrVta {
    fn kind(&self) -> &'static str {
        "shr"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        plan_eltwise(cfg, numel(node), 1, virtual_threads).is_ok()
    }

    fn offload_policy(&self, _node: &Node, policy: &PartitionPolicy) -> bool {
        policy.offload_alu
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        Some(format!("shr_{}", shape_tag(&node.shape)))
    }

    fn fuse_step(&self, op: &Op) -> Option<FusedStep> {
        let Op::ShrImm { shift } = op else { return None };
        Some(FusedStep::ShrImm { shift: *shift })
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        _g: &Graph,
        node: &Node,
        virtual_threads: usize,
        _schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        let Op::ShrImm { shift } = &node.op else {
            return Err(CompileError::NotOffloadable(self.kind()));
        };
        prepare_eltwise(cfg, EltwiseKind::ShrImm(*shift), numel(node), virtual_threads)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_acc_i32(cfg, inputs[0])]
    }

    fn unpack_output(
        &self,
        _cfg: &VtaConfig,
        _compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        unpack_eltwise(packed, inputs[0].shape())
    }

    fn reference(
        &self,
        _g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        let Op::ShrImm { shift } = &node.op else {
            unreachable!("shr entry serves shr nodes")
        };
        Ok(reference::shr_imm_i8(inputs[0], *shift))
    }
}

/// Nearest-neighbor 2x upsampling as a strided store/copy pass over
/// register-file contexts — the style-transfer resize-convolution
/// block (`Upsample2x → Conv2d` replaces a stride-2 transposed
/// convolution, reusing the conv2d emission core unchanged).
pub struct UpsampleVta;

impl VtaOp for UpsampleVta {
    fn kind(&self) -> &'static str {
        "upsample2x"
    }

    fn offloadable(&self, cfg: &VtaConfig, node: &Node, virtual_threads: usize) -> bool {
        // `node.shape` is the doubled output; the input is half the
        // spatial size in each dimension.
        let s = &node.shape;
        matches!(&node.op, Op::Upsample2x)
            && plan_upsample2x(cfg, s[0], s[1], s[2] / 2, s[3] / 2, virtual_threads).is_ok()
    }

    fn offload_policy(&self, _node: &Node, policy: &PartitionPolicy) -> bool {
        policy.offload_upsample
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        Some(format!("upsample2x_{}", shape_tag(&node.shape)))
    }

    fn prepare(
        &self,
        cfg: &VtaConfig,
        _g: &Graph,
        node: &Node,
        virtual_threads: usize,
        _schedule: Option<&ScheduleChoice>,
    ) -> Result<PreparedPlan, CompileError> {
        if !matches!(&node.op, Op::Upsample2x) {
            return Err(CompileError::NotOffloadable(self.kind()));
        }
        let s = &node.shape;
        prepare_upsample2x(cfg, s[0], s[1], s[2] / 2, s[3] / 2, virtual_threads)
    }

    fn pack_inputs(&self, cfg: &VtaConfig, inputs: &[&Tensor<i8>]) -> Vec<Vec<i8>> {
        vec![pack_acc_nchw(cfg, inputs[0])]
    }

    fn unpack_output(
        &self,
        cfg: &VtaConfig,
        _compiled: &CompiledNode,
        packed: &[i8],
        inputs: &[&Tensor<i8>],
    ) -> Tensor<i8> {
        let s = inputs[0].shape();
        unpack_outputs(cfg, packed, s[0], s[1], 2 * s[2], 2 * s[3])
    }

    fn reference(
        &self,
        _g: &Graph,
        _node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        Ok(reference::upsample2x_i8(inputs[0]))
    }
}

/// Max pooling — CPU-resident (the paper's evaluation keeps it on the
/// ARM core).
pub struct MaxPoolVta;

impl VtaOp for MaxPoolVta {
    fn kind(&self) -> &'static str {
        "maxpool"
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        let Op::MaxPool { k, s, .. } = &node.op else { return None };
        Some(format!("maxpool_{}_{}_{}", shape_tag(&node.shape), k, s))
    }

    fn reference(
        &self,
        _g: &Graph,
        node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        let Op::MaxPool { k, s, pad } = &node.op else {
            unreachable!("maxpool entry serves maxpool nodes")
        };
        Ok(reference::maxpool_i8(inputs[0], *k, *s, *pad))
    }
}

/// Global average pooling — CPU-resident.
pub struct GapVta;

impl VtaOp for GapVta {
    fn kind(&self) -> &'static str {
        "gap"
    }

    fn artifact_name(&self, node: &Node) -> Option<String> {
        Some(format!("gap_{}", shape_tag(&node.shape)))
    }

    fn reference(
        &self,
        _g: &Graph,
        _node: &Node,
        inputs: &[&Tensor<i8>],
    ) -> Result<Tensor<i8>, CompileError> {
        Ok(reference::global_avg_pool_i8(inputs[0]))
    }
}
