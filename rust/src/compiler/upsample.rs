//! Nearest-neighbor 2x upsampling on the VTA — the style-transfer
//! scenario's "transposed convolution" building block, and the proof
//! that the two-level ISA absorbs a *data-movement* operator without
//! new hardware (§2.5: the microcode ISA "can be extended for higher
//! operator coverage").
//!
//! Fast style-transfer networks replace their stride-2 transposed
//! convolutions with resize-convolution: nearest-neighbor upsample
//! followed by a stride-1 conv (`Upsample2x → Conv2d`, which reuses
//! the existing `emit_conv2d` core unchanged). The upsample itself
//! lowers as a **strided store/copy pass** over register-file
//! contexts:
//!
//! * input pixels arrive in the channel-blocked accumulator layout
//!   ([`super::layout::pack_acc_nchw`]) and DMA into the register file
//!   (ACC loads execute on the *compute* module, so a strip's load and
//!   ALU op serialize in program order — no RAW tokens needed within a
//!   strip),
//! * one looped `SHR`-by-zero ALU micro-op sweeps the strip — an
//!   identity on the int32 lanes whose only job is mirroring every
//!   tile, narrowed back to int8, into the output buffer, and
//! * each input row then drains through **four 2D strided stores**:
//!   two x-duplicating stores (`x_stride = 2`, DRAM offsets 0 and 1)
//!   for each of the output rows `2y` and `2y + 1` — nearest-neighbor
//!   duplication done entirely by the store engine's address
//!   generator, at zero data-path cost.
//!
//! Strips rotate across SRAM contexts with the usual compute↔store
//! WAR/RAW tokens, so the stores of strip *i* overlap the load + ALU
//! pass of strip *i + 1* under virtual threading.

use super::alu::get_kernel;
use super::conv2d::CompileError;
use super::plan::UpsamplePlan;
use super::virtual_thread::StripPipeline;
use crate::isa::{AluOpcode, BufferId};
use crate::runtime::{CommandContext, UopKernel};
use std::collections::HashMap;

/// Tile-granular DRAM base addresses of an upsampling node's images:
/// input in accumulator tiles, output in out-buffer tiles.
#[derive(Clone, Copy, Debug)]
pub(crate) struct UpsampleDramBase {
    pub inp: u32,
    pub out: u32,
}

/// Emit the full upsampling instruction stream for `plan` into `ctx`,
/// calling `boundary` once at the end (the stream has no intermediate
/// drain points). Mirrors the shape of [`super::alu::emit_eltwise`].
pub(crate) fn emit_upsample2x<F>(
    ctx: &mut CommandContext,
    plan: &UpsamplePlan,
    base: UpsampleDramBase,
    mut boundary: F,
) -> Result<(), CompileError>
where
    F: FnMut(&mut CommandContext) -> Result<(), CompileError>,
{
    let cfg = ctx.config().clone();

    // Context stride, bounded by the ISA-addressable depth of BOTH the
    // register file and the output buffer (every ALU write is mirrored
    // into the out buffer at the same index — see compiler::alu).
    let acc_ctx_stride = cfg.acc_depth().min(cfg.out_depth()).min(1 << 11) / 2;
    let (h, w) = (plan.h, plan.w);
    let (oh, ow) = (2 * h, 2 * w);

    // Kernel cache: (context, strip tiles) → (id, kernel).
    let mut kernels: HashMap<(usize, usize), (usize, UopKernel)> = HashMap::new();
    let mut pipe = StripPipeline::new(plan.contexts);

    let rows = plan.rows();
    let mut r0 = 0usize;
    while r0 < rows {
        let r_cur = plan.rows_per_strip.min(rows - r0);
        let tok = pipe.begin();
        let off = if tok.context == 1 { acc_ctx_stride } else { 0 };
        let strip_tiles = r_cur * w;

        // WAR against the previous strip on this context: the pop
        // attaches to the first compute-module instruction (the ACC
        // load below).
        pipe.compute_prologue(ctx, tok)?;
        ctx.load_buffer_2d(
            BufferId::Acc,
            off as u32,
            base.inp + (r0 * w) as u32,
            1,
            strip_tiles as u16,
            strip_tiles as u16,
            [0; 4],
        );

        // Identity pass: SHR by a zero immediate mirrors every lane,
        // narrowed back to int8, into the output buffer (src == dst —
        // the shared one-uop strip kernel of the eltwise path).
        let (kid, kernel) = get_kernel(
            &mut kernels,
            ctx,
            (tok.context, strip_tiles),
            off as u16,
            off as u16,
            strip_tiles as u16,
        )?;
        ctx.push_alu(kid, &kernel, AluOpcode::Shr, true, 0)?;
        pipe.alu_epilogue(ctx)?;

        // Four duplicating stores per input row: x-duplication via
        // `x_stride = 2` at DRAM offsets 0 / 1, for output rows 2y and
        // 2y + 1 (`block` enumerates (batch-row, channel-block) pairs).
        for r in 0..r_cur {
            let row = r0 + r;
            let (block, y) = (row / h, row % h);
            let out_row = base.out + ((block * oh + 2 * y) * ow) as u32;
            for dy in 0..2u32 {
                for dx in 0..2u32 {
                    ctx.store_buffer_2d(
                        (off + r * w) as u32,
                        out_row + dy * ow as u32 + dx,
                        w as u16,
                        1,
                        2,
                    );
                }
            }
        }
        pipe.stores_epilogue(ctx)?;
        r0 += r_cur;
    }
    boundary(ctx)?;
    Ok(())
}
