//! Tiling planners: choose loop tiles that satisfy every SRAM capacity
//! and ISA field-width constraint (§4.2's "loop tiling to match the
//! shape of the tensor intrinsic", plus the memory-scope capacity
//! accounting of §4.1).

use crate::arch::VtaConfig;
use thiserror::Error;

/// Planning failures (a workload that cannot be tiled onto the given
/// VTA variant).
#[derive(Debug, Error, PartialEq)]
pub enum PlanError {
    #[error("weights for even one output block ({tiles} tiles) exceed the weight SRAM ({depth})")]
    WeightsDontFit { tiles: usize, depth: usize },
    #[error("one input row span ({tiles} tiles) exceeds the input SRAM budget ({depth})")]
    InputsDontFit { tiles: usize, depth: usize },
    #[error("micro-kernel of {uops} uops exceeds the micro-op SRAM ({depth})")]
    KernelDoesntFit { uops: usize, depth: usize },
    #[error(
        "register file cannot hold one tile per operand ({operands} operands, {budget} tile budget)"
    )]
    RegisterFileDoesntFit { operands: usize, budget: usize },
    #[error("batch {n} is not a multiple of the hardware BATCH {b}")]
    BadBatch { n: usize, b: usize },
    #[error("{what} {v} exceeds the {bits}-bit ISA field")]
    FieldWidth { what: &'static str, v: usize, bits: u32 },
    #[error("schedule choice for {got} does not apply to {op}")]
    WrongSchedule { got: &'static str, op: &'static str },
    #[error("tuned schedule infeasible: {0}")]
    InfeasibleSchedule(String),
    #[error("upsample row of {tiles} tiles exceeds the per-context register-file budget ({budget})")]
    UpsampleRowDoesntFit { tiles: usize, budget: usize },
}

/// A schedule override found by design-space exploration
/// ([`crate::dse`]): explicit tile sizes replacing the planner's greedy
/// defaults. Persisted in the tuning-record store and applied at
/// compile time by [`plan_conv2d_tuned`] / [`plan_matmul_tuned`], which
/// validate the choice against every SRAM-capacity and ISA-field
/// constraint before the emitters see it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScheduleChoice {
    /// Conv2d strip shape: output-channel blocks per weight group and
    /// output rows / columns per strip.
    Conv2d { oc_t: usize, oh_t: usize, ow_t: usize },
    /// Matmul strip shape: M row-groups per strip and N blocks per
    /// weight group.
    Matmul { m_t: usize, n_t: usize },
}

impl ScheduleChoice {
    /// Operator class this choice tunes (matches [`crate::graph::Op::kind`]).
    pub fn kind(&self) -> &'static str {
        match self {
            ScheduleChoice::Conv2d { .. } => "conv2d",
            ScheduleChoice::Matmul { .. } => "dense",
        }
    }
}

/// One post-GEMM epilogue step of a fused conv chain
/// ([`crate::graph::Op::FusedConv2d`]), applied in the accumulator
/// while the conv's tiles are still resident — no store/load round
/// trip between steps. Each variant maps to one (or two, for the
/// saturating residual add) tensor-ALU micro-coded passes appended to
/// the strip's instruction stream by
/// [`crate::compiler::alu::push_fused_epilogue`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FusedStep {
    /// Saturating add of a residual tensor (the fused node's second
    /// input), loaded into the upper half of the context's ACC span.
    AddResidual,
    /// Clip at zero.
    Relu,
    /// Arithmetic right shift by an immediate.
    ShrImm { shift: u8 },
    /// Clamp from above by an immediate.
    MinImm { imm: i16 },
}

/// Requantization applied by the tensor ALU after accumulation
/// (shift-based fixed-point, clipped into the int8 output range).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Requant {
    /// Arithmetic right shift applied to the int32 accumulator.
    pub shift: u8,
    /// Apply ReLU (clip at 0 instead of -128).
    pub relu: bool,
}

impl Requant {
    /// Reference semantics of the requantization (shared by host-side
    /// oracles).
    pub fn apply(&self, acc: i32) -> i8 {
        let v = acc >> self.shift;
        let lo = if self.relu { 0 } else { -128 };
        v.clamp(lo, 127) as i8
    }
}

/// A 2D convolution workload (Table 1 row).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Conv2dParams {
    /// Input spatial size.
    pub h: usize,
    pub w: usize,
    /// Input / output channels.
    pub ic: usize,
    pub oc: usize,
    /// Kernel size and stride (square).
    pub k: usize,
    pub s: usize,
    /// Requantization of the int32 accumulator into int8.
    pub requant: Requant,
}

impl Conv2dParams {
    /// "SAME" padding on each side (paper Table 1: all ops use SAME).
    pub fn pad(&self) -> usize {
        // For odd k this is (k-1)/2; general SAME formula.
        let oh = self.out_h();
        (((oh - 1) * self.s + self.k).saturating_sub(self.h)) / 2
    }

    /// Output height (SAME: ceil(h / s)).
    pub fn out_h(&self) -> usize {
        self.h.div_ceil(self.s)
    }

    /// Output width.
    pub fn out_w(&self) -> usize {
        self.w.div_ceil(self.s)
    }

    /// Multiply-accumulates of the whole layer.
    pub fn macs(&self) -> u64 {
        (self.out_h() * self.out_w() * self.oc * self.ic * self.k * self.k) as u64
    }

    /// Integer ops (2 per MAC), the roofline numerator.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Minimal DRAM traffic in bytes: input + weights + output, each
    /// touched once (the roofline's arithmetic-intensity denominator).
    pub fn min_bytes(&self) -> u64 {
        let inp = self.h * self.w * self.ic;
        let wgt = self.oc * self.ic * self.k * self.k;
        let out = self.out_h() * self.out_w() * self.oc;
        (inp + wgt + out) as u64
    }

    /// Arithmetic intensity in ops/byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.ops() as f64 / self.min_bytes() as f64
    }
}

/// A fully resolved conv2d tiling for a given [`VtaConfig`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Conv2dPlan {
    /// Input/output channel blocks.
    pub icb: usize,
    pub ocb: usize,
    /// Output-channel blocks per group (weight-buffer resident set).
    pub oc_t: usize,
    /// Output rows / columns per strip.
    pub oh_t: usize,
    pub ow_t: usize,
    /// SRAM contexts (1 = no virtual threading, 2 = Fig 14 interleave).
    pub contexts: usize,
    /// Input rows covered by one strip.
    pub ih_span: usize,
    /// Input tiles per strip row (covers ow_t outputs).
    pub iw_tiles: usize,
    /// Derived output spatial size.
    pub oh: usize,
    pub ow: usize,
    /// SAME padding.
    pub pad: usize,
    /// Weight-buffer contexts: 2 = groups double-buffer their weights
    /// so weight DMA overlaps the previous group's compute (the §2.3
    /// latency-hiding discipline applied to the weight stream).
    pub wgt_contexts: usize,
    /// Fall back to a pipeline drain between groups (only when a single
    /// group's weights exceed half the weight SRAM under vt=2).
    pub drain_groups: bool,
}

impl Conv2dPlan {
    /// Accumulator tiles per strip (per context).
    pub fn acc_tiles(&self) -> usize {
        self.oc_t * self.oh_t * self.ow_t
    }

    /// Input tiles per strip (per context).
    pub fn inp_tiles(&self) -> usize {
        self.icb * self.ih_span * self.iw_tiles
    }

    /// Weight tiles per group.
    pub fn wgt_tiles(&self, k: usize) -> usize {
        self.oc_t * self.icb * k * k
    }

    /// Micro-ops in the main GEMM kernel.
    pub fn main_uops(&self, k: usize) -> usize {
        self.oc_t * self.icb * k * k
    }

    /// Number of output-channel groups.
    pub fn groups(&self) -> usize {
        self.ocb.div_ceil(self.oc_t)
    }

    /// Number of strips per group (full strips + remainder).
    pub fn strips(&self) -> usize {
        self.oh.div_ceil(self.oh_t) * self.ow.div_ceil(self.ow_t)
    }
}

/// Plan a conv2d tiling. `virtual_threads` ∈ {1, 2} selects latency
/// hiding (§4.3); the per-context budgets halve with 2 threads.
pub fn plan_conv2d(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    virtual_threads: usize,
) -> Result<Conv2dPlan, PlanError> {
    plan_conv2d_default(cfg, p, virtual_threads, false)
}

/// Plan a conv2d tiling with an optional tuned [`ScheduleChoice`]
/// override. `None` (and a non-conv choice is an error) falls back to
/// the greedy default; `Some(Conv2d { .. })` validates the explicit
/// tile sizes against every capacity and field-width constraint.
pub fn plan_conv2d_tuned(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    virtual_threads: usize,
    choice: Option<&ScheduleChoice>,
) -> Result<Conv2dPlan, PlanError> {
    match choice {
        None => plan_conv2d_default(cfg, p, virtual_threads, false),
        Some(ScheduleChoice::Conv2d { oc_t, oh_t, ow_t }) => {
            conv2d_plan_from_choice(cfg, p, virtual_threads, false, *oc_t, *oh_t, *ow_t)
        }
        Some(other) => Err(PlanError::WrongSchedule { got: other.kind(), op: "conv2d" }),
    }
}

/// Plan a fused conv2d chain ([`crate::graph::Op::FusedConv2d`]): the
/// conv's tiling, with the per-context accumulator budget halved when
/// the chain carries a residual add (the residual operand is resident
/// in the upper half of the context's ACC span for the whole strip).
/// The epilogue steps themselves cost no SRAM — they are extra ALU
/// passes over the already-resident accumulator tiles.
pub fn plan_conv2d_fused(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    steps: &[FusedStep],
    virtual_threads: usize,
    choice: Option<&ScheduleChoice>,
) -> Result<Conv2dPlan, PlanError> {
    let residual = steps.contains(&FusedStep::AddResidual);
    let plan = match choice {
        None => plan_conv2d_default(cfg, p, virtual_threads, residual)?,
        Some(ScheduleChoice::Conv2d { oc_t, oh_t, ow_t }) => {
            conv2d_plan_from_choice(cfg, p, virtual_threads, residual, *oc_t, *oh_t, *ow_t)?
        }
        Some(other) => return Err(PlanError::WrongSchedule { got: other.kind(), op: "conv2d" }),
    };
    if residual {
        // The residual-add micro-kernel's src index addresses the upper
        // half of the context span; belt-and-braces against the 11-bit
        // uop field (always holds by construction: offset + tiles ≤ D).
        let d = cfg.acc_depth().min(cfg.out_depth()).min(1 << 11);
        check_width(
            "uop residual index",
            (virtual_threads - 1) * d / 2 + d / (2 * virtual_threads) + plan.acc_tiles(),
            1 << 11,
        )?;
    }
    Ok(plan)
}

/// The ISA-clamped SRAM depths and per-context budgets shared by both
/// conv2d planners.
///
/// The Fig 3 micro-op encoding fixes index fields at 11 bits (acc/inp)
/// and 10 bits (wgt); buffers deeper than that are only partially
/// addressable by a micro-op base index, so the usable depths clamp to
/// the encodable range (a real VTA regenerates the ISA widths with the
/// hardware — we keep the published encoding). Budgets are
/// per-context: they halve under 2 virtual threads, and the acc budget
/// is additionally bounded by the OUT depth because every compute
/// write mirrors into the out buffer at the same index.
struct ConvBudgets {
    inp_depth: usize,
    acc_depth: usize,
    wgt_depth: usize,
    inp_budget: usize,
    acc_budget: usize,
}

fn conv_budgets(cfg: &VtaConfig, virtual_threads: usize, residual: bool) -> ConvBudgets {
    let inp_depth = cfg.inp_depth().min(1 << 11);
    let acc_depth = cfg.acc_depth().min(1 << 11);
    let out_depth = cfg.out_depth().min(1 << 11);
    let wgt_depth = cfg.wgt_depth().min(1 << 10);
    // A fused residual add keeps the residual operand resident in the
    // upper half of the context's ACC span, halving the strip budget.
    // The OUT-depth bound is unaffected: only the conv's own tiles
    // mirror into the out buffer.
    let res_div = if residual { 2 } else { 1 };
    ConvBudgets {
        inp_depth,
        acc_depth,
        wgt_depth,
        inp_budget: inp_depth / virtual_threads,
        acc_budget: (acc_depth / virtual_threads).min(out_depth / virtual_threads) / res_div,
    }
}

/// Build and validate a conv2d plan from explicit tile sizes (the
/// DSE tuner's path). Applies the same weight-context safety rule as
/// the default planner: a multi-group plan under 2 virtual threads
/// either double-buffers its weights (group fits half the buffer) or
/// drains the pipeline between groups.
fn conv2d_plan_from_choice(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    virtual_threads: usize,
    residual: bool,
    oc_t: usize,
    oh_t: usize,
    ow_t: usize,
) -> Result<Conv2dPlan, PlanError> {
    assert!(virtual_threads == 1 || virtual_threads == 2, "1 or 2 virtual threads");
    if oc_t == 0 || oh_t == 0 || ow_t == 0 {
        return Err(PlanError::InfeasibleSchedule("zero tile size".into()));
    }
    let icb = p.ic.div_ceil(cfg.gemm.block_in);
    let ocb = p.oc.div_ceil(cfg.gemm.block_out);
    let (oh, ow) = (p.out_h(), p.out_w());
    let pad = p.pad();
    let ConvBudgets { inp_depth, acc_depth, wgt_depth, inp_budget, acc_budget } =
        conv_budgets(cfg, virtual_threads, residual);

    // Clamp to the workload extent (a choice tuned on a same-shaped
    // layer may quote tiles larger than this layer's output).
    let oc_t = oc_t.min(ocb);
    let oh_t = oh_t.min(oh);
    let ow_t = ow_t.min(ow);

    let per_oc_tiles = icb * p.k * p.k;
    let (wgt_contexts, drain_groups) = if oc_t >= ocb {
        (1, false)
    } else if virtual_threads == 2 {
        if oc_t * per_oc_tiles <= wgt_depth / 2 {
            (2, false)
        } else {
            (1, true)
        }
    } else {
        (1, false)
    };
    if oc_t * per_oc_tiles > wgt_depth / wgt_contexts {
        return Err(PlanError::WeightsDontFit {
            tiles: oc_t * per_oc_tiles,
            depth: wgt_depth / wgt_contexts,
        });
    }
    if oc_t * per_oc_tiles > cfg.uop_depth() {
        return Err(PlanError::KernelDoesntFit {
            uops: oc_t * per_oc_tiles,
            depth: cfg.uop_depth(),
        });
    }

    let span = |t: usize| (t - 1) * p.s + p.k;
    if icb * span(oh_t) * span(ow_t) > inp_budget {
        return Err(PlanError::InfeasibleSchedule(format!(
            "strip input {} tiles exceeds per-context budget {inp_budget}",
            icb * span(oh_t) * span(ow_t)
        )));
    }
    if oc_t * oh_t * ow_t > acc_budget {
        return Err(PlanError::InfeasibleSchedule(format!(
            "strip accumulator {} tiles exceeds per-context budget {acc_budget}",
            oc_t * oh_t * ow_t
        )));
    }

    let plan = Conv2dPlan {
        icb,
        ocb,
        oc_t,
        oh_t,
        ow_t,
        contexts: virtual_threads,
        ih_span: span(oh_t),
        iw_tiles: span(ow_t),
        oh,
        ow,
        pad,
        wgt_contexts,
        drain_groups,
    };
    check_conv_widths(p, &plan, virtual_threads, inp_depth, acc_depth)?;
    Ok(plan)
}

fn plan_conv2d_default(
    cfg: &VtaConfig,
    p: &Conv2dParams,
    virtual_threads: usize,
    residual: bool,
) -> Result<Conv2dPlan, PlanError> {
    assert!(virtual_threads == 1 || virtual_threads == 2, "1 or 2 virtual threads");
    let icb = p.ic.div_ceil(cfg.gemm.block_in);
    let ocb = p.oc.div_ceil(cfg.gemm.block_out);
    let (oh, ow) = (p.out_h(), p.out_w());
    let pad = p.pad();
    let ConvBudgets { inp_depth, acc_depth, wgt_depth, inp_budget, acc_budget } =
        conv_budgets(cfg, virtual_threads, residual);

    // 1. Output-channel group size, limited by the weight buffer and
    //    the micro-op cache (main kernel must fit).
    let per_oc_tiles = icb * p.k * p.k;
    if per_oc_tiles > wgt_depth {
        return Err(PlanError::WeightsDontFit { tiles: per_oc_tiles, depth: wgt_depth });
    }
    let uop_budget = cfg.uop_depth() / 2; // leave room for other kernels
    let fit_oc = |budget: usize| ocb.min(budget / per_oc_tiles).min(uop_budget / per_oc_tiles);
    // If one group can't hold every output block, double-buffer the
    // weight buffer so group g+1's weights stream in while group g
    // computes (plan §Perf P2). Falls back to a drain between groups
    // when even one output block needs more than half the buffer.
    let mut oc_t = fit_oc(wgt_depth).max(1);
    let mut wgt_contexts = 1;
    let mut drain_groups = false;
    if oc_t < ocb && virtual_threads == 2 {
        let halved = fit_oc(wgt_depth / 2);
        // Double-buffering halves the resident group; only worth it when
        // per-strip GEMM work still dominates the strip's input-load time
        // (otherwise the smaller groups turn the layer load-latency-bound
        // — C12 on the Pynq point is the counter-example).
        let gemm_per_acc_tile = halved * per_oc_tiles; // cycles per output tile
        let load_per_acc_tile = (icb as f64 * cfg.dram.latency as f64
            / (oh * ow) as f64
            + (icb * cfg.inp_tile_bytes()) as f64 / cfg.dram.bytes_per_cycle)
            .ceil() as usize;
        if halved >= 1 && gemm_per_acc_tile >= 2 * load_per_acc_tile {
            oc_t = halved;
            wgt_contexts = 2;
        } else {
            drain_groups = true;
        }
    }
    if oc_t * per_oc_tiles > cfg.uop_depth() {
        return Err(PlanError::KernelDoesntFit {
            uops: oc_t * per_oc_tiles,
            depth: cfg.uop_depth(),
        });
    }

    // 2. Strip shape: start from full width, shrink until the input and
    //    accumulator budgets (per context) hold.
    let span = |t: usize| (t - 1) * p.s + p.k; // input extent for t outputs

    let mut ow_t = ow;
    let mut oh_t = oh.min(acc_budget / (oc_t * ow_t).max(1)).max(1);
    loop {
        let iw_tiles = span(ow_t);
        // Shrink oh_t until input fits.
        while oh_t > 1 && icb * span(oh_t) * iw_tiles > inp_budget {
            oh_t -= 1;
        }
        // Shrink oc_t while the acc budget can't hold even one row.
        while oc_t > 1 && oc_t * ow_t > acc_budget {
            oc_t -= 1;
        }
        let fits = icb * span(oh_t) * iw_tiles <= inp_budget
            && oc_t * oh_t * ow_t <= acc_budget;
        if fits {
            break;
        }
        if ow_t > 1 {
            ow_t = ow_t.div_ceil(2);
            oh_t = oh.min(acc_budget / (oc_t * ow_t).max(1)).max(1);
        } else {
            return Err(PlanError::InputsDontFit {
                tiles: icb * span(1) * span(1),
                depth: inp_budget,
            });
        }
    }
    // Re-tighten oh_t against the acc budget.
    oh_t = oh_t.min(acc_budget / (oc_t * ow_t)).max(1);

    let plan = Conv2dPlan {
        icb,
        ocb,
        oc_t,
        oh_t,
        ow_t,
        contexts: virtual_threads,
        ih_span: span(oh_t),
        iw_tiles: span(ow_t),
        oh,
        ow,
        pad,
        wgt_contexts,
        drain_groups,
    };

    check_conv_widths(p, &plan, virtual_threads, inp_depth, acc_depth)?;
    Ok(plan)
}

/// ISA field-width validation shared by the default and tuned conv2d
/// planners (11-bit uop indices, 11/10-bit factors, 14-bit loop
/// extents, 4-bit pads).
fn check_conv_widths(
    p: &Conv2dParams,
    plan: &Conv2dPlan,
    virtual_threads: usize,
    inp_depth: usize,
    acc_depth: usize,
) -> Result<(), PlanError> {
    check_width("uop acc index", plan.acc_tiles() + (virtual_threads - 1) * acc_depth / 2, 1 << 11)?;
    check_width("uop inp index", plan.inp_tiles() + (virtual_threads - 1) * inp_depth / 2, 1 << 11)?;
    check_width("uop wgt index", plan.wgt_tiles(p.k), 1 << 10)?;
    check_width("gemm lp0", plan.oh_t, 1 << 14)?;
    check_width("gemm lp1", plan.ow_t, 1 << 14)?;
    check_width("src factor0", p.s * plan.iw_tiles, 1 << 11)?;
    check_width("dst factor0", plan.ow_t, 1 << 11)?;
    check_width("pad", plan.pad, 1 << 4)?;
    Ok(())
}

fn check_width(what: &'static str, v: usize, limit: usize) -> Result<(), PlanError> {
    if v > limit {
        Err(PlanError::FieldWidth { what, v, bits: limit.trailing_zeros() })
    } else {
        Ok(())
    }
}

/// A dense matmul workload: `C[M,N] = A[M,K] x W[N,K]^T`, requantized.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatmulParams {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub requant: Requant,
}

impl MatmulParams {
    /// Integer ops (2 per MAC).
    pub fn ops(&self) -> u64 {
        2 * (self.m * self.k * self.n) as u64
    }
}

/// Resolved matmul tiling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MatmulPlan {
    pub kb: usize,
    pub nb: usize,
    /// M-rows (in BATCH units) per strip.
    pub m_t: usize,
    /// N blocks per group (weight-resident set).
    pub n_t: usize,
    pub contexts: usize,
}

/// Plan a matmul tiling.
pub fn plan_matmul(
    cfg: &VtaConfig,
    p: &MatmulParams,
    virtual_threads: usize,
) -> Result<MatmulPlan, PlanError> {
    plan_matmul_tuned(cfg, p, virtual_threads, None)
}

/// Plan a matmul tiling with an optional tuned [`ScheduleChoice`]
/// override (`Matmul { m_t, n_t }` caps the strip row-groups and the
/// weight-resident N blocks).
pub fn plan_matmul_tuned(
    cfg: &VtaConfig,
    p: &MatmulParams,
    virtual_threads: usize,
    choice: Option<&ScheduleChoice>,
) -> Result<MatmulPlan, PlanError> {
    let tuned = match choice {
        None => None,
        Some(ScheduleChoice::Matmul { m_t, n_t }) => Some((*m_t, *n_t)),
        Some(other) => return Err(PlanError::WrongSchedule { got: other.kind(), op: "dense" }),
    };
    if p.m % cfg.gemm.batch != 0 {
        return Err(PlanError::BadBatch { n: p.m, b: cfg.gemm.batch });
    }
    let kb = p.k.div_ceil(cfg.gemm.block_in);
    let nb = p.n.div_ceil(cfg.gemm.block_out);
    let wgt_depth = cfg.wgt_depth().min(1 << 10);
    if kb > wgt_depth {
        return Err(PlanError::WeightsDontFit { tiles: kb, depth: wgt_depth });
    }
    let m_rows = p.m / cfg.gemm.batch;
    let inp_budget = cfg.inp_depth().min(1 << 11) / virtual_threads;
    let acc_budget = (cfg.acc_depth().min(1 << 11) / virtual_threads)
        .min(cfg.out_depth().min(1 << 11) / virtual_threads);
    if kb > inp_budget {
        return Err(PlanError::InputsDontFit { tiles: kb, depth: inp_budget });
    }
    let (m_t, n_t) = match tuned {
        None => {
            let n_t = nb.min(wgt_depth / kb).min((cfg.uop_depth() / 2 / kb).max(1)).max(1);
            let m_t = m_rows.min(inp_budget / kb).min(acc_budget / n_t).max(1);
            (m_t, n_t)
        }
        Some((m_t, n_t)) => {
            if m_t == 0 || n_t == 0 {
                return Err(PlanError::InfeasibleSchedule("zero tile size".into()));
            }
            let m_t = m_t.min(m_rows);
            let n_t = n_t.min(nb);
            if n_t * kb > wgt_depth {
                return Err(PlanError::WeightsDontFit { tiles: n_t * kb, depth: wgt_depth });
            }
            if kb > cfg.uop_depth() {
                return Err(PlanError::KernelDoesntFit { uops: kb, depth: cfg.uop_depth() });
            }
            if m_t * kb > inp_budget {
                return Err(PlanError::InfeasibleSchedule(format!(
                    "strip input {} tiles exceeds per-context budget {inp_budget}",
                    m_t * kb
                )));
            }
            if m_t * n_t > acc_budget {
                return Err(PlanError::InfeasibleSchedule(format!(
                    "strip accumulator {} tiles exceeds per-context budget {acc_budget}",
                    m_t * n_t
                )));
            }
            (m_t, n_t)
        }
    };
    check_width("matmul lp0", m_t, 1 << 14)?;
    check_width("matmul lp1", n_t, 1 << 14)?;
    check_width("matmul src f0", kb, 1 << 11)?;
    check_width("matmul wgt f1", kb, 1 << 10)?;
    Ok(MatmulPlan { kb, nb, m_t, n_t, contexts: virtual_threads })
}

/// Resolved tiling of an elementwise tensor-ALU operator
/// ([`crate::compiler::alu`]): the flattened tensor, strip-mined over
/// register-file contexts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EltwisePlan {
    /// Total `BATCH x BLOCK_OUT` tiles covering the tensor.
    pub tiles: usize,
    /// Tiles per strip (per context; each operand occupies one
    /// `chunk`-sized span of the context's register-file half).
    pub chunk: usize,
    /// SRAM contexts (1 = serialized, 2 = store/compute overlap).
    pub contexts: usize,
}

/// Plan an elementwise ALU operator over `len` int8 elements with
/// `operands` input tensors resident per strip.
pub fn plan_eltwise(
    cfg: &VtaConfig,
    len: usize,
    operands: usize,
    virtual_threads: usize,
) -> Result<EltwisePlan, PlanError> {
    assert!(virtual_threads == 1 || virtual_threads == 2, "1 or 2 virtual threads");
    assert!(operands >= 1);
    let lanes = cfg.gemm.batch * cfg.gemm.block_out;
    let tiles = len.div_ceil(lanes).max(1);
    // Operands and results live in the register file; results are
    // mirrored into the output buffer at the same indices, so both
    // capacities bound the strip (per context).
    let acc_budget = (cfg.acc_depth().min(1 << 11) / virtual_threads)
        .min(cfg.out_depth().min(1 << 11) / virtual_threads);
    let chunk = (acc_budget / operands).min(tiles);
    if chunk == 0 {
        return Err(PlanError::RegisterFileDoesntFit { operands, budget: acc_budget });
    }
    check_width("eltwise strip", chunk, 1 << 14)?;
    Ok(EltwisePlan { tiles, chunk, contexts: virtual_threads })
}

/// Resolved tiling of the nearest-neighbor 2x upsampling operator
/// ([`crate::compiler::upsample`]): the input is viewed as rows of `w`
/// channel-block tiles (`BATCH x BLOCK_OUT` lanes each, the
/// output-buffer tiling), and whole rows are strip-mined over
/// register-file contexts — the strided duplicating stores need
/// row-aligned strips.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UpsamplePlan {
    /// Batch-row groups (N / BATCH).
    pub nb: usize,
    /// Channel blocks (`BLOCK_OUT` channels each) covering C.
    pub cb: usize,
    /// Input spatial size (each row is `w` tiles).
    pub h: usize,
    pub w: usize,
    /// Input rows per strip (per context).
    pub rows_per_strip: usize,
    /// SRAM contexts (1 = serialized, 2 = store/compute overlap).
    pub contexts: usize,
}

impl UpsamplePlan {
    /// Total input rows ((N/B) * CB * H) — the strip-mined unit.
    pub fn rows(&self) -> usize {
        self.nb * self.cb * self.h
    }

    /// Input tiles.
    pub fn in_tiles(&self) -> usize {
        self.rows() * self.w
    }

    /// Output tiles (every input tile is duplicated 2x2).
    pub fn out_tiles(&self) -> usize {
        4 * self.in_tiles()
    }
}

/// Plan a nearest-neighbor 2x upsampling over an `[n, c, h, w]` input.
/// Rows must fit whole in the per-context register-file budget (the
/// four duplicating stores of a row address it as one contiguous SRAM
/// span); tensors whose rows don't fit stay on the CPU.
pub fn plan_upsample2x(
    cfg: &VtaConfig,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    virtual_threads: usize,
) -> Result<UpsamplePlan, PlanError> {
    assert!(virtual_threads == 1 || virtual_threads == 2, "1 or 2 virtual threads");
    if n % cfg.gemm.batch != 0 {
        return Err(PlanError::BadBatch { n, b: cfg.gemm.batch });
    }
    let nb = n / cfg.gemm.batch;
    let cb = c.div_ceil(cfg.gemm.block_out);
    // Rows live in the register file and mirror into the out buffer at
    // the same indices, so both capacities bound the strip (per
    // context) — the same rule as `plan_eltwise`.
    let acc_budget = (cfg.acc_depth().min(1 << 11) / virtual_threads)
        .min(cfg.out_depth().min(1 << 11) / virtual_threads);
    if w == 0 || w > acc_budget {
        return Err(PlanError::UpsampleRowDoesntFit { tiles: w, budget: acc_budget });
    }
    let rows = nb * cb * h;
    let rows_per_strip = (acc_budget / w).min(rows.max(1));
    check_width("upsample strip", rows_per_strip * w, 1 << 14)?;
    check_width("upsample store rows", w, 1 << 16)?;
    Ok(UpsamplePlan { nb, cb, h, w, rows_per_strip, contexts: virtual_threads })
}
