//! Virtual threading (§4.3, Fig 14): latency hiding by interleaving
//! strips across SRAM contexts with explicit dependence push/pops.
//!
//! The paper's TVM pass lowers a 2-thread data-parallel schedule into a
//! single instruction stream whose dependence flags let the hardware
//! recover the parallelism. [`StripPipeline`] encapsulates exactly that
//! insertion pattern for the strip-structured kernels produced by
//! [`crate::compiler::conv2d`] and [`crate::compiler::matmul`]:
//!
//! ```text
//! per strip (context c = strip % threads):
//!   [if context reused]   next load pops   WAR  token from compute
//!   LOAD ... LOAD         last load pushes RAW  token to compute
//!   [if context reused]   first compute op pops WAR token from store
//!   GEMM(reset) GEMM      first compute op pops RAW token from loads
//!   [after main GEMM]     pushes WAR token back to the load module
//!   ALU ...               last ALU pushes RAW token to store
//!   STORE ... STORE       first store pops it; last store pushes the
//!                         WAR token a later strip's compute pops
//! ```
//!
//! With `threads = 1` every strip reuses context 0 and the same flags
//! degenerate into a full serialization of load → compute → store — the
//! "no latency hiding" baseline of Fig 15.

use crate::runtime::{CommandContext, CoreModule, RuntimeError};

/// Context-rotation and dependence-insertion state for one instruction
/// stream.
pub struct StripPipeline {
    threads: usize,
    used: [bool; 2],
    strip: usize,
}

/// Per-strip handle: which SRAM context to use and whether its previous
/// occupant must be synchronized against.
#[derive(Clone, Copy, Debug)]
pub struct StripToken {
    /// SRAM context index (0 or 1).
    pub context: usize,
    /// True when the context has a previous strip in flight.
    pub reused: bool,
}

impl StripPipeline {
    /// A pipeline with `threads` ∈ {1, 2} virtual threads.
    pub fn new(threads: usize) -> Self {
        assert!(threads == 1 || threads == 2);
        StripPipeline { threads, used: [false; 2], strip: 0 }
    }

    /// Number of virtual threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Start the next strip: rotate contexts.
    pub fn begin(&mut self) -> StripToken {
        let context = self.strip % self.threads;
        let reused = self.used[context];
        self.used[context] = true;
        self.strip += 1;
        StripToken { context, reused }
    }

    /// Call before emitting the strip's loads: WAR against the previous
    /// compute on this context.
    pub fn loads_prologue(
        &self,
        ctx: &mut CommandContext,
        tok: StripToken,
    ) -> Result<(), RuntimeError> {
        if tok.reused {
            ctx.dep_pop(CoreModule::Compute, CoreModule::Load)?;
        }
        Ok(())
    }

    /// Call after the strip's last load: RAW into compute.
    pub fn loads_epilogue(&self, ctx: &mut CommandContext) -> Result<(), RuntimeError> {
        ctx.dep_push(CoreModule::Load, CoreModule::Compute)?;
        ctx.dep_pop(CoreModule::Load, CoreModule::Compute)
    }

    /// Call before the strip's first accumulator-writing compute op:
    /// WAR against the previous store on this context.
    pub fn compute_prologue(
        &self,
        ctx: &mut CommandContext,
        tok: StripToken,
    ) -> Result<(), RuntimeError> {
        if tok.reused {
            ctx.dep_pop(CoreModule::Store, CoreModule::Compute)?;
        }
        Ok(())
    }

    /// Call right after the last input-reading compute op (the main
    /// GEMM): lets a later strip's loads overwrite this context.
    pub fn gemm_epilogue(&self, ctx: &mut CommandContext) -> Result<(), RuntimeError> {
        ctx.dep_push(CoreModule::Compute, CoreModule::Load)
    }

    /// Call after the last output-writing compute op: RAW into store.
    pub fn alu_epilogue(&self, ctx: &mut CommandContext) -> Result<(), RuntimeError> {
        ctx.dep_push(CoreModule::Compute, CoreModule::Store)?;
        ctx.dep_pop(CoreModule::Compute, CoreModule::Store)
    }

    /// Call after the strip's last store: WAR token a later strip's
    /// compute pops before overwriting this context's out/acc tiles.
    pub fn stores_epilogue(&self, ctx: &mut CommandContext) -> Result<(), RuntimeError> {
        ctx.dep_push(CoreModule::Store, CoreModule::Compute)
    }
}
