//! Heterogeneous graph executor (§5): VTA-resident nodes run on the
//! behavioral simulator through the full runtime/compiler stack;
//! CPU-resident nodes run either natively or on AOT-compiled XLA/PJRT
//! executables produced by the JAX build path (`python/compile/`).

mod cpu_ops;
mod executor;
pub mod pjrt;

pub use cpu_ops::{add_i8, dense_i8, global_avg_pool_i8, maxpool_i8, relu_i8};
pub use executor::{CpuBackend, ExecError, ExecReport, Executor, NodeReport};
pub use pjrt::{PjrtCache, PjrtError};

#[cfg(test)]
mod tests;
