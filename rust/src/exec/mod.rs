//! Heterogeneous graph executor (§5): VTA-resident nodes run on the
//! behavioral simulator through the full runtime/compiler stack;
//! CPU-resident nodes run either natively or on AOT-compiled XLA/PJRT
//! executables produced by the JAX build path (`python/compile/`).
//!
//! Three execution disciplines:
//!
//! * [`Executor`] — naive serial: every node back-to-back, re-lowering
//!   VTA nodes from scratch on every inference (the paper's Fig 16
//!   measurement discipline, and the serving layer's baseline).
//! * [`serve::ServingEngine`] — compile-once/run-many: a JIT
//!   [`serve::PlanCache`] of reusable compiled plans plus a pipelined,
//!   batched front-end that overlaps CPU wall time with simulated VTA
//!   time.
//! * [`serve::Scheduler`] — multi-device: a request queue with dynamic
//!   batching and least-loaded dispatch over a
//!   [`DevicePool`](crate::runtime::DevicePool) of accelerator
//!   replicas, with per-device plan caches driven in lockstep from a
//!   shared compile-once path.

mod cpu_ops;
mod executor;
pub mod pjrt;
pub mod serve;

pub use cpu_ops::{add_i8, dense_i8, global_avg_pool_i8, maxpool_i8, relu_i8};
pub use executor::{CpuBackend, ExecError, ExecReport, Executor, NodeReport};
pub use pjrt::{PjrtCache, PjrtError};
pub use serve::{
    open_loop, pipeline_schedule, run_pipeline_threaded, run_threaded, serve_trace, BatchRecord,
    BatchReport, Completion, LoadReport, LoadgenOptions, PipelineModel, PipelineOptions,
    PipelinePartition, PipelineReport, PipelineScheduler, PipelineStage, PipelineThreadedReport,
    PlanCache, PlanCacheStats, PlanKey, PoolHandle, PoolReport, QpsStep, Scheduler,
    SchedulerOptions, ServeReport, ServingEngine, StepReport, SubmitRejected, ThreadedOptions,
    ThreadedReport,
};

#[cfg(test)]
mod tests;
