//! Serving reports: per-request and per-batch outcomes of the
//! single-device engine.
//!
//! Latency percentiles delegate to the one shared interpolating
//! percentile implementation in [`crate::util`] — the same math the
//! bench harness's `BenchStats` uses, so serving reports and bench
//! output can never disagree about what "p99" means.

use super::super::executor::NodeReport;
use super::cache::PlanCacheStats;
use crate::util::{percentile_sorted, Tensor};
use std::time::Duration;

/// Report for one served request.
#[derive(Debug)]
pub struct ServeReport {
    /// Final output tensor.
    pub output: Tensor<i8>,
    /// Per-node records, indexed by node id.
    pub nodes: Vec<NodeReport>,
    /// Naive serial end-to-end model time (sum of all node durations).
    pub serial_seconds: f64,
    /// Pipelined model time for this single request (intra-request
    /// overlap only).
    pub pipelined_seconds: f64,
}

/// Report for a served batch.
#[derive(Debug)]
pub struct BatchReport {
    /// Per-request outputs, in request order.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request, per-node records.
    pub per_request: Vec<Vec<NodeReport>>,
    /// Naive serial end-to-end model time of the whole batch.
    pub serial_seconds: f64,
    /// Pipelined, double-buffered end-to-end model time of the batch.
    pub pipelined_seconds: f64,
    /// Per-request completion times under the pipelined schedule.
    pub completion_seconds: Vec<f64>,
    /// Plan-cache counters *for this batch* (end minus start).
    pub cache: PlanCacheStats,
    /// Real host wall time of serving the batch (includes compiles on
    /// cold caches).
    pub host_wall: Duration,
}

impl BatchReport {
    /// Requests per modeled second under the pipelined schedule.
    pub fn throughput(&self) -> f64 {
        if self.pipelined_seconds > 0.0 {
            self.outputs.len() as f64 / self.pipelined_seconds
        } else {
            0.0
        }
    }

    /// Serial ÷ pipelined model time.
    pub fn speedup(&self) -> f64 {
        if self.pipelined_seconds > 0.0 {
            self.serial_seconds / self.pipelined_seconds
        } else {
            1.0
        }
    }

    /// Latency percentile (`q` in [0, 1], interpolating) over
    /// per-request completion times (all requests arrive at t = 0).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let mut sorted = self.completion_seconds.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        percentile_sorted(&sorted, q)
    }
}
