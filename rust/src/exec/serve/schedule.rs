//! The pipelined timing model: replay measured per-node durations
//! against dependence + resource constraints.
//!
//! Per-node durations are *measured* (host wall for CPU nodes and
//! orchestration, simulated cycles ÷ clock for VTA nodes); the
//! pipelined schedule then replays those durations against resource
//! and dependence constraints, exactly like the simulator replays
//! dependence tokens against its module timelines.

use super::super::executor::NodeReport;
use crate::graph::{Graph, Placement};

/// Result of replaying measured node durations against the
/// two-resource (CPU / VTA) pipelined schedule.
#[derive(Clone, Debug)]
pub struct PipelineModel {
    /// End-to-end time of the whole batch under the pipelined,
    /// double-buffered schedule.
    pub makespan_seconds: f64,
    /// Per-request completion times (all requests arrive at t = 0).
    pub completion_seconds: Vec<f64>,
    /// End-to-end time of the naive serial discipline: every node of
    /// every request back-to-back.
    pub serial_seconds: f64,
}

/// Replay per-node durations against dependence + resource
/// constraints.
///
/// Model: two resources — the CPU (measured wall time) and the VTA
/// (simulated cycles ÷ clock). Within a request, a node starts when
/// its inputs are done *and* its resource is free; across requests,
/// double buffering admits request `r` once request `r - 2` has
/// completed (two requests in flight, mirroring the two SRAM contexts
/// of §4.3). Zero-duration nodes occupy nothing.
pub fn pipeline_schedule(g: &Graph, per_request: &[Vec<NodeReport>]) -> PipelineModel {
    let out_id = g.output().expect("non-empty graph");
    let mut cpu_free = 0.0f64;
    let mut vta_free = 0.0f64;
    let mut completion: Vec<f64> = Vec::with_capacity(per_request.len());
    let mut serial = 0.0f64;
    let mut makespan = 0.0f64;

    for (r, reports) in per_request.iter().enumerate() {
        debug_assert_eq!(reports.len(), g.nodes.len());
        let arrival = if r >= 2 { completion[r - 2] } else { 0.0 };
        let mut finish = vec![0.0f64; g.nodes.len()];
        for node in &g.nodes {
            let nr = &reports[node.id];
            let dur = nr.wall.as_secs_f64() + nr.sim_seconds;
            serial += dur;
            let ready = node.inputs.iter().map(|&i| finish[i]).fold(arrival, f64::max);
            let start = if node.placement == Placement::Vta {
                let s = ready.max(vta_free);
                vta_free = s + dur;
                s
            } else if dur > 0.0 {
                let s = ready.max(cpu_free);
                cpu_free = s + dur;
                s
            } else {
                ready
            };
            finish[node.id] = start + dur;
        }
        let done = finish[out_id];
        completion.push(done);
        makespan = makespan.max(done);
    }
    PipelineModel {
        makespan_seconds: makespan,
        completion_seconds: completion,
        serial_seconds: serial,
    }
}
