//! The fleet scheduler: the multi-device serving runtime of
//! [`super::super::Scheduler`] generalized to a
//! [`HeterogeneousPool`] — mixed-config replicas, cost-aware routing,
//! and **group-wise** lockstep plan caches.
//!
//! What changes relative to the homogeneous scheduler:
//!
//! * **Routing.** Requests carry a workload *class* (an index into the
//!   class graphs handed to [`FleetScheduler::run`]); a [`Router`]
//!   assigns each request to a config group at the head of the run, in
//!   submission order — the same sequence of decisions the threaded
//!   fleet runtime makes at submit time, so the two runtimes route
//!   identically by construction. Dispatch *within* the group is
//!   unchanged: least-loaded member, per-replica simulated clocks.
//! * **Per-group batching.** Each group batches its own routed
//!   substream. A batch additionally closes when the workload class
//!   changes — a batch executes one graph, so it can only hold
//!   same-class requests.
//! * **Group-wise lockstep caches.** Every replica still has its own
//!   [`PlanCache`], but the compile-once/byte-replicate discipline
//!   ([`CompiledNode::replicate_to`](crate::compiler::CompiledNode::replicate_to))
//!   now runs per config group: a plan is lowered once on the group's
//!   lead member and replicated onto the rest of the *group* only.
//!   Replication across groups is never attempted — compiled streams
//!   bake in config-dependent tiling, so each group compiles its own
//!   plans under its own [`PlanKey`] (the key carries the config
//!   fingerprint, so groups never collide in reporting either).
//!
//! Outputs are bit-identical to running every request on a
//! single-device [`ServingEngine`](super::super::ServingEngine) of its
//! routed group's config — execution is exact; only timing is modeled.

use super::super::super::executor::{lift_compile_err, CpuBackend, ExecError};
use super::super::cache::{PlanCache, PlanCacheStats, PlanKey};
use super::super::run::{plan_keys_for, run_graph, tuned_schedules_for, VtaNodeExec};
use super::super::schedule::pipeline_schedule;
use super::router::{RoutePolicy, Router};
use super::spec::FleetSpec;
use crate::arch::VtaConfig;
use crate::compiler::op::{config_fingerprint, execute_compiled, op_impl};
use crate::compiler::ScheduleChoice;
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph, Node, Placement};
use crate::metrics::PoolMetrics;
use crate::runtime::HeterogeneousPool;
use crate::sim::SimStats;
use crate::util::{percentile_sorted, Tensor};
use std::time::{Duration, Instant};

/// Knobs of the fleet serving runtime (the per-pool knobs of
/// [`SchedulerOptions`](super::super::SchedulerOptions), plus the
/// route policy; replica counts come from the [`FleetSpec`]).
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// How requests are assigned to config groups.
    pub policy: RoutePolicy,
    /// Maximum requests per dispatched batch.
    pub max_batch: usize,
    /// Dynamic-batching deadline in **simulated** seconds.
    pub batch_deadline: f64,
    /// Plan-cache capacity per replica (a group's caches run in
    /// lockstep, so every member of a group holds the same plans).
    pub cache_capacity: usize,
    /// Virtual threads VTA nodes are lowered with, ∈ {1, 2}.
    pub virtual_threads: usize,
    /// Device DRAM bytes per replica.
    pub dram_size: usize,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            policy: RoutePolicy::CostModel,
            max_batch: 8,
            batch_deadline: 1e-3,
            cache_capacity: 64,
            virtual_threads: 2,
            dram_size: 256 << 20,
        }
    }
}

/// One dispatched fleet batch, for reporting.
#[derive(Clone, Copy, Debug)]
pub struct FleetBatchRecord {
    /// Config group the batch was routed to.
    pub group: usize,
    /// Replica (global index) the batch ran on.
    pub device: usize,
    /// Workload class of every member.
    pub class: usize,
    /// Requests in the batch.
    pub size: usize,
    /// Simulated time the batch closed.
    pub ready: f64,
    /// Simulated time service began (`max(ready, device free)`).
    pub start: f64,
    /// Simulated time service completed.
    pub finish: f64,
}

/// Outcome of draining a mixed request stream through the fleet.
#[derive(Debug)]
pub struct FleetReport {
    /// Per-request outputs, in submission order.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request workload classes, in submission order.
    pub classes: Vec<usize>,
    /// Per-request routed config group, in submission order.
    pub routes: Vec<usize>,
    /// Per-request arrival times, in submission order.
    pub arrivals: Vec<f64>,
    /// Per-request completion times (simulated), in submission order.
    pub completions: Vec<f64>,
    /// Every dispatched batch, in dispatch order.
    pub batches: Vec<FleetBatchRecord>,
    /// Simulated busy seconds per replica (global index).
    pub device_busy: Vec<f64>,
    /// End of the simulated span: the last batch completion (0 with no
    /// requests).
    pub makespan_seconds: f64,
    /// Per-group plan-cache counters for this run (each group's lead
    /// member — within a group the caches run in lockstep, so the
    /// lead's counters are the group's).
    pub group_cache: Vec<PlanCacheStats>,
    /// Real host wall time of the drain (includes per-group compiles
    /// on cold caches).
    pub host_wall: Duration,
    /// Queue-depth samples and per-device counters (global replica
    /// indices).
    pub metrics: PoolMetrics,
}

impl FleetReport {
    /// Requests per modeled second over the whole span.
    pub fn throughput(&self) -> f64 {
        if self.makespan_seconds > 0.0 {
            self.outputs.len() as f64 / self.makespan_seconds
        } else {
            0.0
        }
    }

    /// Request latency (completion − arrival) percentile, `q` ∈
    /// [0, 1], interpolating — the shared [`percentile_sorted`].
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let mut lat: Vec<f64> = self
            .completions
            .iter()
            .zip(&self.arrivals)
            .map(|(c, a)| c - a)
            .collect();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        percentile_sorted(&lat, q)
    }

    /// Busy fraction of replica `d` (global index) over the simulated
    /// span.
    pub fn utilization(&self, d: usize) -> f64 {
        if self.makespan_seconds > 0.0 {
            (self.device_busy[d] / self.makespan_seconds).min(1.0)
        } else {
            0.0
        }
    }
}

/// The fleet serving runtime: routed queue → per-group dynamic
/// batches → least-loaded group members, over group-wise lockstep
/// plan caches.
pub struct FleetScheduler {
    pool: HeterogeneousPool,
    caches: Vec<PlanCache>,
    cpu: CpuBackend,
    opts: FleetOptions,
    /// Config fingerprint per group, in group order.
    group_fps: Vec<u64>,
    records: TuningRecords,
    /// Pending requests: (arrival, class, input), in submission order.
    queue: Vec<(f64, usize, Tensor<i8>)>,
}

impl FleetScheduler {
    /// Build a fleet over `spec` (which must pass
    /// [`FleetSpec::validate`]).
    pub fn new(spec: &FleetSpec, cpu: CpuBackend, opts: FleetOptions) -> Self {
        Self::with_records(spec, cpu, opts, TuningRecords::new())
    }

    /// Like [`Self::new`], seeded with a `vta dse` tuning-record store
    /// (consulted at compile time; records are keyed by config
    /// fingerprint, so each group picks up its own tuned schedules).
    pub fn with_records(
        spec: &FleetSpec,
        cpu: CpuBackend,
        opts: FleetOptions,
        records: TuningRecords,
    ) -> Self {
        if let Err(e) = spec.validate() {
            panic!("invalid fleet spec: {e}");
        }
        assert!(
            opts.virtual_threads == 1 || opts.virtual_threads == 2,
            "1 or 2 virtual threads"
        );
        assert!(opts.max_batch >= 1, "max_batch must be at least 1");
        assert!(
            opts.batch_deadline >= 0.0 && opts.batch_deadline.is_finite(),
            "batch_deadline must be a finite non-negative simulated time"
        );
        let cfgs = spec.configs();
        let pool = HeterogeneousPool::new(&cfgs, opts.dram_size);
        if let RoutePolicy::Static(g) = opts.policy {
            assert!(g < pool.group_count(), "static route to group {g} of {}", pool.group_count());
        }
        let caches = (0..pool.len()).map(|_| PlanCache::new(opts.cache_capacity)).collect();
        let group_fps = pool.groups().iter().map(|g| config_fingerprint(&g.cfg)).collect();
        FleetScheduler {
            pool,
            caches,
            cpu,
            opts,
            group_fps,
            records,
            queue: Vec::new(),
        }
    }

    /// Total replicas across all groups.
    pub fn devices(&self) -> usize {
        self.pool.len()
    }

    /// Number of config groups.
    pub fn group_count(&self) -> usize {
        self.pool.group_count()
    }

    /// The config of each group, in group order.
    pub fn group_configs(&self) -> Vec<VtaConfig> {
        self.pool.groups().iter().map(|g| g.cfg.clone()).collect()
    }

    /// Replica count of each group, in group order.
    pub fn group_devices(&self) -> Vec<usize> {
        self.pool.groups().iter().map(|g| g.members.len()).collect()
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Fresh pool counters with every device stamped with its config
    /// fingerprint, so mixed-fleet utilization stays attributable per
    /// variant.
    fn fresh_metrics(&self) -> PoolMetrics {
        let mut metrics = PoolMetrics::new(self.pool.len());
        for (d, counter) in metrics.devices.iter_mut().enumerate() {
            counter.config_fingerprint = self.group_fps[self.pool.group_of(d)];
        }
        metrics
    }

    /// Cumulative plan-cache counters of group `g` (its lead member —
    /// group lockstep makes it the group's).
    pub fn group_cache_stats(&self, g: usize) -> PlanCacheStats {
        self.caches[self.pool.groups()[g].members[0]].stats()
    }

    /// Enqueue a request of workload class `class` arriving at
    /// simulated time `arrival`.
    pub fn submit(&mut self, arrival: f64, class: usize, input: Tensor<i8>) {
        assert!(
            arrival >= 0.0 && arrival.is_finite(),
            "arrival must be a finite non-negative simulated time"
        );
        self.queue.push((arrival, class, input));
    }

    /// Drain the queue against `class_graphs` (request classes index
    /// into this slice): route every request to a config group, form
    /// per-group dynamic batches, dispatch them to least-loaded group
    /// members, execute every request exactly, and report modeled
    /// times + metrics.
    pub fn run(&mut self, class_graphs: &[&Graph]) -> Result<FleetReport, ExecError> {
        let ndev = self.pool.len();
        let ngroups = self.pool.group_count();
        let t0 = Instant::now();

        // Every group must be able to serve every class — a node
        // offloadable under one variant may not lower under another,
        // and routing must be free to send any class anywhere.
        let vt = self.opts.virtual_threads;
        for group in self.pool.groups() {
            for g in class_graphs {
                for node in g.nodes.iter().filter(|n| n.placement == Placement::Vta) {
                    if !op_impl(&node.op).offloadable(&group.cfg, node, vt) {
                        return Err(ExecError::NotOffloadable(node.name.clone(), node.op.kind()));
                    }
                }
            }
        }

        let stats0: Vec<PlanCacheStats> =
            (0..ngroups).map(|g| self.group_cache_stats(g)).collect();
        let n = self.queue.len();
        if n == 0 {
            return Ok(FleetReport {
                outputs: Vec::new(),
                classes: Vec::new(),
                routes: Vec::new(),
                arrivals: Vec::new(),
                completions: Vec::new(),
                batches: Vec::new(),
                device_busy: vec![0.0; ndev],
                makespan_seconds: 0.0,
                group_cache: vec![PlanCacheStats::default(); ngroups],
                host_wall: t0.elapsed(),
                metrics: self.fresh_metrics(),
            });
        }

        // Route in submission order — the same decision sequence the
        // threaded runtime makes at submit time, so both runtimes
        // agree on every request's group by construction.
        let group_cfgs = self.group_configs();
        let mut router = Router::new(self.opts.policy, &group_cfgs, class_graphs);
        let routes_by_submission: Vec<usize> =
            self.queue.iter().map(|&(_, class, _)| router.route(class)).collect();

        // Per-(group, class) compile-time context: plan keys and tuned
        // schedules are fingerprint-specific; stage order is per class.
        let stage_order: Vec<Vec<Vec<usize>>> = class_graphs.iter().map(|g| stages(g)).collect();
        let keys: Vec<Vec<Vec<Option<PlanKey>>>> = self
            .group_fps
            .iter()
            .map(|&fp| class_graphs.iter().map(|g| plan_keys_for(fp, vt, g)).collect())
            .collect();
        let schedules: Vec<Vec<Vec<Option<ScheduleChoice>>>> = self
            .group_fps
            .iter()
            .map(|&fp| {
                class_graphs.iter().map(|g| tuned_schedules_for(&self.records, fp, vt, g)).collect()
            })
            .collect();

        // Requests in arrival order (stable: equal arrivals keep
        // submission order), remembering the submission index so the
        // report lines up with the caller's inputs.
        let mut reqs: Vec<(usize, f64, usize, usize, Tensor<i8>)> = self
            .queue
            .drain(..)
            .enumerate()
            .map(|(i, (arrival, class, input))| (i, arrival, class, routes_by_submission[i], input))
            .collect();
        reqs.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite arrivals"));

        // Per-group dynamic batching over the routed substreams: close
        // on max_batch, on the deadline, on a class change (a batch
        // executes one graph), or at substream end.
        let maxb = self.opts.max_batch;
        let deadline = self.opts.batch_deadline;
        // (group, class, members, ready), in group-major formation order.
        let mut formed: Vec<(usize, usize, Vec<usize>, f64)> = Vec::new();
        for gi in 0..ngroups {
            let sub: Vec<usize> = (0..reqs.len()).filter(|&r| reqs[r].3 == gi).collect();
            if sub.is_empty() {
                continue;
            }
            let group_last_arrival = reqs[*sub.last().expect("non-empty substream")].1;
            let flush = |members: &mut Vec<usize>,
                         limit: f64,
                         formed: &mut Vec<(usize, usize, Vec<usize>, f64)>,
                         reqs: &[(usize, f64, usize, usize, Tensor<i8>)]| {
                let first_arrival = reqs[members[0]].1;
                let last_member_arrival = reqs[*members.last().expect("non-empty batch")].1;
                let ready = if members.len() >= maxb {
                    last_member_arrival
                } else {
                    (first_arrival + deadline).min(limit)
                };
                let class = reqs[members[0]].2;
                formed.push((gi, class, std::mem::take(members), ready));
            };
            let mut current: Vec<usize> = Vec::new();
            for &r in &sub {
                if !current.is_empty()
                    && (current.len() >= maxb
                        || reqs[r].2 != reqs[current[0]].2
                        || reqs[r].1 > reqs[current[0]].1 + deadline)
                {
                    // Closed by the arrival of `r`: the group knows no
                    // earlier-flushing request will extend this batch.
                    flush(&mut current, reqs[r].1.min(group_last_arrival), &mut formed, &reqs);
                }
                current.push(r);
            }
            if !current.is_empty() {
                flush(&mut current, group_last_arrival, &mut formed, &reqs);
            }
        }
        // Dispatch in ready order (stable sort: ties keep group-major
        // formation order — deterministic).
        formed.sort_by(|a, b| a.3.partial_cmp(&b.3).expect("finite ready times"));

        // Least-loaded member within the routed group, per-replica
        // simulated clocks (global indices).
        let mut free_at = vec![0.0f64; ndev];
        let mut busy = vec![0.0f64; ndev];
        let mut metrics = self.fresh_metrics();
        let mut batch_records = Vec::with_capacity(formed.len());
        let mut outputs: Vec<Option<Tensor<i8>>> = (0..n).map(|_| None).collect();
        let mut classes_out = vec![0usize; n];
        let mut arrivals = vec![0.0f64; n];
        let mut completions = vec![0.0f64; n];
        let mut dispatched = 0usize;

        for (gi, class, members, ready) in &formed {
            let g = class_graphs[*class];
            let group_members = self.pool.groups()[*gi].members.clone();
            let mut d = group_members[0];
            for &m in &group_members[1..] {
                if free_at[m] < free_at[d] {
                    d = m;
                }
            }
            let start = ready.max(free_at[d]);
            // Queue depth at the dispatch instant: requests that have
            // *arrived* by `start` and are not yet dispatched.
            let arrived = reqs.partition_point(|r| r.1 <= start);
            metrics.queue.record(start, arrived.saturating_sub(dispatched));

            // Execute every member exactly, on replica `d` of group
            // `gi`.
            let mut per_request = Vec::with_capacity(members.len());
            let mut batch_cycles = 0u64;
            for &r in members {
                let (submit_idx, arrival, req_class, _, ref input) = reqs[r];
                let (out, reports) = run_graph(
                    &mut FleetDeviceRun { sched: &mut *self, device: d, group: *gi },
                    g,
                    input,
                    &stage_order[*class],
                    &keys[*gi][*class],
                    &schedules[*gi][*class],
                )?;
                batch_cycles += reports
                    .iter()
                    .filter_map(|nr| nr.stats.as_ref())
                    .map(|s| s.total_cycles)
                    .sum::<u64>();
                outputs[submit_idx] = Some(out);
                classes_out[submit_idx] = req_class;
                arrivals[submit_idx] = arrival;
                per_request.push(reports);
            }

            // The batch occupies the replica for its pipelined
            // makespan; member completions are offsets within it.
            let model = pipeline_schedule(g, &per_request);
            for (k, &r) in members.iter().enumerate() {
                completions[reqs[r].0] = start + model.completion_seconds[k];
            }
            let finish = start + model.makespan_seconds;
            free_at[d] = finish;
            busy[d] += model.makespan_seconds;
            dispatched += members.len();
            metrics.devices[d].record_batch(members.len(), model.makespan_seconds, batch_cycles);
            batch_records.push(FleetBatchRecord {
                group: *gi,
                device: d,
                class: *class,
                size: members.len(),
                ready: *ready,
                start,
                finish,
            });
        }

        let makespan = batch_records.iter().map(|b| b.finish).fold(0.0f64, f64::max);
        let group_cache = (0..ngroups)
            .map(|g| {
                let s1 = self.group_cache_stats(g);
                PlanCacheStats {
                    hits: s1.hits - stats0[g].hits,
                    misses: s1.misses - stats0[g].misses,
                    evictions: s1.evictions - stats0[g].evictions,
                }
            })
            .collect();
        let mut routes_out = vec![0usize; n];
        for r in &reqs {
            routes_out[r.0] = r.3;
        }
        Ok(FleetReport {
            outputs: outputs.into_iter().map(|o| o.expect("every request served")).collect(),
            classes: classes_out,
            routes: routes_out,
            arrivals,
            completions,
            batches: batch_records,
            device_busy: busy,
            makespan_seconds: makespan,
            group_cache,
            host_wall: t0.elapsed(),
            metrics,
        })
    }

    /// The group-wise compile-once path: make `key`'s plan resident in
    /// **every member of group `gi`**, in lockstep.
    ///
    /// Hit: touch every member cache (identical LRU updates). Miss:
    /// every member cache evicts the same victims first (identical
    /// allocator frees), then the plan is lowered once on the group's
    /// lead member and byte-replicated onto the rest — identical
    /// allocator histories within the group put every member's copy at
    /// identical DRAM addresses, so the sealed streams replay
    /// verbatim. Other groups are untouched: replication across
    /// configs is never valid.
    ///
    /// Error paths preserve the group-lockstep invariant, exactly as
    /// in the homogeneous scheduler: a failed compile leaves the lead
    /// allocator untouched, and a failed replication unwinds the
    /// already-replicated copies and the source plan.
    fn ensure_compiled(
        &mut self,
        gi: usize,
        g: &Graph,
        node: &Node,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
    ) -> Result<(), ExecError> {
        let members = self.pool.groups()[gi].members.clone();
        let lead = members[0];
        if self.caches[lead].contains(key) {
            for &m in &members {
                let hit = self.caches[m].touch(key);
                debug_assert!(hit, "group plan caches fell out of lockstep");
            }
            return Ok(());
        }
        let entry = op_impl(&node.op);
        for &m in &members {
            self.caches[m].note_miss();
            self.caches[m].make_room(self.pool.device_mut(m))?;
        }
        let vt = self.opts.virtual_threads;
        let compiled = entry
            .compile(self.pool.device_mut(lead), g, node, vt, schedule.as_ref())
            .map_err(|e| lift_compile_err(&node.name, e))?;
        for di in 1..members.len() {
            let d = members[di];
            let (src, dst) = self.pool.pair_mut(lead, d);
            match compiled.replicate_to(src, dst) {
                Ok(clone) => self.caches[d].insert(key.clone(), clone),
                Err(e) => {
                    for &u in &members[1..di] {
                        let rt_u = self.pool.device_mut(u);
                        let _ = self.caches[u].remove(key, rt_u);
                    }
                    let _ = compiled.free(self.pool.device_mut(lead));
                    return Err(lift_compile_err(&node.name, e));
                }
            }
        }
        self.caches[lead].insert(key.clone(), compiled);
        Ok(())
    }
}

/// One dispatch's device view: the fleet scheduler plus the replica a
/// batch was assigned to and the config group it belongs to — the
/// fleet side of the shared graph walker
/// ([`super::super::run::run_graph`]). VTA nodes go through the
/// group-lockstep caches ([`FleetScheduler::ensure_compiled`]) and
/// execute on the chosen replica.
struct FleetDeviceRun<'a> {
    sched: &'a mut FleetScheduler,
    device: usize,
    group: usize,
}

impl VtaNodeExec for FleetDeviceRun<'_> {
    fn clock_hz(&self) -> f64 {
        self.sched.pool.config_of(self.device).clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.sched.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        let node = &g.nodes[id];
        let entry = op_impl(&node.op);
        self.sched.ensure_compiled(self.group, g, node, key, schedule)?;
        // Split borrows: the chosen replica executes a plan held by
        // its own (disjoint) cache.
        let rt = self.sched.pool.device_mut(self.device);
        let compiled =
            self.sched.caches[self.device].peek(key).expect("plan resident after ensure_compiled");
        execute_compiled(entry, compiled, rt, inputs).map_err(|e| lift_compile_err(&node.name, e))
    }
}
