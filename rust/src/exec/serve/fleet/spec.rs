//! The fleet specification: the deployable artifact connecting
//! `vta dse --fleet` to `vta serve --fleet`.
//!
//! A [`FleetSpec`] is an ordered list of [`FleetMember`]s — (hardware
//! variant, replica count) pairs. Member order is meaningful: it fixes
//! the config-group order of the pool
//! ([`HeterogeneousPool`](crate::runtime::HeterogeneousPool) groups by
//! first appearance), which in turn fixes [`RoutePolicy`] tie-breaks
//! (`RoutePolicy::Static(g)` and cost-model ties both resolve by group
//! index).
//!
//! The on-disk format is plain JSON through the same hand-rolled
//! subset the tuning-record store uses ([`crate::dse::records::json`]):
//!
//! ```json
//! {
//!   "version": 1,
//!   "members": [
//!     { "devices": 2, "config": { "gemm": { "batch": 1, ... }, ... } }
//!   ]
//! }
//! ```
//!
//! [`RoutePolicy`]: super::RoutePolicy

use crate::arch::{DramModel, GemmShape, VtaConfig};
use crate::dse::records::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// One config group of a fleet: `devices` identical replicas of `cfg`.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetMember {
    /// The hardware variant of this group.
    pub cfg: VtaConfig,
    /// Replica count (≥ 1).
    pub devices: usize,
}

/// An ordered fleet composition — the `dse --fleet` output and the
/// `serve --fleet` input.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    /// Config groups, in group order.
    pub members: Vec<FleetMember>,
}

impl FleetSpec {
    /// A fleet of the given members.
    pub fn new(members: Vec<FleetMember>) -> Self {
        FleetSpec { members }
    }

    /// The homogeneous special case: `devices` replicas of one config.
    pub fn homogeneous(cfg: &VtaConfig, devices: usize) -> Self {
        FleetSpec { members: vec![FleetMember { cfg: cfg.clone(), devices }] }
    }

    /// Total replicas across all members.
    pub fn total_devices(&self) -> usize {
        self.members.iter().map(|m| m.devices).sum()
    }

    /// One config per replica, in group order — the constructor input
    /// of [`HeterogeneousPool`](crate::runtime::HeterogeneousPool).
    /// Distinct members with equal configs collapse into one pool
    /// group; [`Self::validate`] rejects that, so a validated spec's
    /// member order *is* the pool's group order.
    pub fn configs(&self) -> Vec<VtaConfig> {
        let mut out = Vec::with_capacity(self.total_devices());
        for m in &self.members {
            for _ in 0..m.devices {
                out.push(m.cfg.clone());
            }
        }
        out
    }

    /// Structural checks: at least one member, every member has at
    /// least one replica and a sound config, and no two members share
    /// a config (duplicates would silently merge into one pool group,
    /// breaking the member-index ↔ group-index correspondence).
    pub fn validate(&self) -> Result<()> {
        if self.members.is_empty() {
            bail!("a fleet needs at least one member");
        }
        for (i, m) in self.members.iter().enumerate() {
            if m.devices < 1 {
                bail!("fleet member {i} has no replicas");
            }
            let errs = m.cfg.validate();
            if !errs.is_empty() {
                bail!("fleet member {i} config invalid: {}", errs.join("; "));
            }
            if self.members[..i].iter().any(|prev| prev.cfg == m.cfg) {
                bail!("fleet member {i} duplicates an earlier member's config");
            }
        }
        Ok(())
    }

    /// Serialize to the versioned JSON format.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"version\": 1,\n  \"members\": [");
        for (i, m) in self.members.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\n    {{ \"devices\": {}, \"config\": ", m.devices);
            write_config(&mut s, &m.cfg);
            s.push_str(" }");
        }
        s.push_str("\n  ]\n}\n");
        s
    }

    /// Parse the versioned JSON format (and [`Self::validate`] the
    /// result).
    pub fn from_json(text: &str) -> Result<Self> {
        let root = json::parse(text)?;
        let version = root.get("version").and_then(Value::as_u64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported fleet-spec version {version}");
        }
        let members_json =
            root.get("members").and_then(Value::as_array).context("missing \"members\" array")?;
        let mut members = Vec::with_capacity(members_json.len());
        for (i, m) in members_json.iter().enumerate() {
            let devices = m
                .get("devices")
                .and_then(Value::as_u64)
                .with_context(|| format!("member {i}: missing integer field \"devices\""))?
                as usize;
            let cfg_json = m.get("config").with_context(|| format!("member {i}: missing \"config\""))?;
            let cfg = parse_config(cfg_json).with_context(|| format!("member {i}: bad config"))?;
            members.push(FleetMember { cfg, devices });
        }
        let spec = FleetSpec { members };
        spec.validate()?;
        Ok(spec)
    }

    /// Write the spec to `path` as JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing fleet spec to {}", path.display()))
    }

    /// Load a spec from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fleet spec from {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Serialize one `VtaConfig` as a JSON object (floats via `{:?}` so
/// whole values keep a trailing `.0` and re-parse as floats).
fn write_config(s: &mut String, cfg: &VtaConfig) {
    let _ = write!(
        s,
        "{{ \"gemm\": {{ \"batch\": {}, \"block_in\": {}, \"block_out\": {} }}, \
           \"inp_bits\": {}, \"wgt_bits\": {}, \"acc_bits\": {}, \"out_bits\": {}, \
           \"inp_buf_bytes\": {}, \"wgt_buf_bytes\": {}, \"acc_buf_bytes\": {}, \
           \"out_buf_bytes\": {}, \"uop_buf_bytes\": {}, \"clock_hz\": {:?}, \
           \"dram\": {{ \"bytes_per_cycle\": {:?}, \"latency\": {} }}, \
           \"cmd_queue_depth\": {}, \"dep_queue_depth\": {}, \"alu_ii\": {}, \"alu_lanes\": {} }}",
        cfg.gemm.batch,
        cfg.gemm.block_in,
        cfg.gemm.block_out,
        cfg.inp_bits,
        cfg.wgt_bits,
        cfg.acc_bits,
        cfg.out_bits,
        cfg.inp_buf_bytes,
        cfg.wgt_buf_bytes,
        cfg.acc_buf_bytes,
        cfg.out_buf_bytes,
        cfg.uop_buf_bytes,
        cfg.clock_hz,
        cfg.dram.bytes_per_cycle,
        cfg.dram.latency,
        cfg.cmd_queue_depth,
        cfg.dep_queue_depth,
        cfg.alu_ii,
        cfg.alu_lanes,
    );
}

/// Parse one `VtaConfig` from its JSON object form.
fn parse_config(v: &Value) -> Result<VtaConfig> {
    let uint = |obj: &Value, name: &str| -> Result<usize> {
        obj.get(name)
            .and_then(Value::as_u64)
            .map(|n| n as usize)
            .with_context(|| format!("missing integer field {name:?}"))
    };
    let float = |obj: &Value, name: &str| -> Result<f64> {
        obj.get(name).and_then(Value::as_f64).with_context(|| format!("missing number field {name:?}"))
    };
    let gemm = v.get("gemm").context("missing \"gemm\"")?;
    let dram = v.get("dram").context("missing \"dram\"")?;
    Ok(VtaConfig {
        gemm: GemmShape {
            batch: uint(gemm, "batch")?,
            block_in: uint(gemm, "block_in")?,
            block_out: uint(gemm, "block_out")?,
        },
        inp_bits: uint(v, "inp_bits")?,
        wgt_bits: uint(v, "wgt_bits")?,
        acc_bits: uint(v, "acc_bits")?,
        out_bits: uint(v, "out_bits")?,
        inp_buf_bytes: uint(v, "inp_buf_bytes")?,
        wgt_buf_bytes: uint(v, "wgt_buf_bytes")?,
        acc_buf_bytes: uint(v, "acc_buf_bytes")?,
        out_buf_bytes: uint(v, "out_buf_bytes")?,
        uop_buf_bytes: uint(v, "uop_buf_bytes")?,
        clock_hz: float(v, "clock_hz")?,
        dram: DramModel {
            bytes_per_cycle: float(dram, "bytes_per_cycle")?,
            latency: dram
                .get("latency")
                .and_then(Value::as_u64)
                .context("missing integer field \"latency\"")?,
        },
        cmd_queue_depth: uint(v, "cmd_queue_depth")?,
        dep_queue_depth: uint(v, "dep_queue_depth")?,
        alu_ii: v.get("alu_ii").and_then(Value::as_u64).context("missing integer field \"alu_ii\"")?,
        alu_lanes: uint(v, "alu_lanes")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alt_cfg() -> VtaConfig {
        let mut c = VtaConfig::pynq();
        c.alu_ii = 1;
        c
    }

    #[test]
    fn fleet_spec_json_roundtrip_is_exact() {
        let spec = FleetSpec::new(vec![
            FleetMember { cfg: VtaConfig::pynq(), devices: 2 },
            FleetMember { cfg: alt_cfg(), devices: 1 },
        ]);
        spec.validate().unwrap();
        let text = spec.to_json();
        let back = FleetSpec::from_json(&text).unwrap();
        assert_eq!(back, spec);
        // Round-tripping again is byte-identical.
        assert_eq!(back.to_json(), text);
        assert_eq!(back.total_devices(), 3);
        assert_eq!(back.configs().len(), 3);
        assert_eq!(back.configs()[0], VtaConfig::pynq());
        assert_eq!(back.configs()[2], alt_cfg());
    }

    #[test]
    fn invalid_specs_are_rejected() {
        assert!(FleetSpec::new(vec![]).validate().is_err(), "empty fleet");
        let zero = FleetSpec::new(vec![FleetMember { cfg: VtaConfig::pynq(), devices: 0 }]);
        assert!(zero.validate().is_err(), "zero-replica member");
        let dup = FleetSpec::new(vec![
            FleetMember { cfg: VtaConfig::pynq(), devices: 1 },
            FleetMember { cfg: VtaConfig::pynq(), devices: 1 },
        ]);
        assert!(dup.validate().is_err(), "duplicate config");
        let mut bad = VtaConfig::pynq();
        bad.alu_ii = 0;
        let invalid = FleetSpec::new(vec![FleetMember { cfg: bad, devices: 1 }]);
        assert!(invalid.validate().is_err(), "invalid member config");
        assert!(FleetSpec::from_json("{\"version\": 2, \"members\": []}").is_err());
    }
}
