//! Heterogeneous fleet serving: one request stream over mixed-config
//! accelerator replicas.
//!
//! The homogeneous serving stack replicates a single `VtaConfig` N
//! times; this module serves divergent traffic across a
//! [`HeterogeneousPool`](crate::runtime::HeterogeneousPool) of
//! per-replica variants instead — wide-GEMM replicas for conv traffic,
//! ALU-rich replicas for eltwise-heavy style traffic — turning the DSE
//! frontier from a report into a deployable artifact.
//!
//! Pieces, in lifecycle order:
//!
//! * [`FleetSpec`] — the deployable composition: (config, replica
//!   count) members, as versioned JSON. Emitted by `vta dse --fleet`,
//!   consumed by `vta serve --fleet`.
//! * [`Router`] / [`RoutePolicy`] — the group chooser: cost-model
//!   scoring of each workload class against each config group
//!   (analytical roofline, [`graph_model_seconds`]), with round-robin
//!   and static-pin baselines so the routing win is measurable.
//! * [`FleetScheduler`] — the simulated-time fleet runtime: per-group
//!   dynamic batching, least-loaded dispatch within the routed group,
//!   group-wise lockstep plan caches. The deterministic oracle.
//! * [`run_fleet_threaded`] / [`serve_fleet_trace`] — the real-threads
//!   fleet runtime: per-group bounded queues and plan directories, one
//!   worker per replica. Bit-identical outputs and per-group cache
//!   counters against the oracle.
//!
//! The fleet *composition search* lives in [`crate::dse::fleet`].

mod router;
mod scheduler;
mod spec;
mod threaded;

pub use router::{
    graph_model_cycles, graph_model_seconds, modeled_fleet_makespan, node_model_cycles,
    RoutePolicy, Router,
};
pub use scheduler::{FleetBatchRecord, FleetOptions, FleetReport, FleetScheduler};
pub use spec::{FleetMember, FleetSpec};
pub use threaded::{
    run_fleet_threaded, serve_fleet_trace, FleetHandle, FleetThreadedOptions, FleetThreadedReport,
};
