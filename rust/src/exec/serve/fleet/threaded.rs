//! The real-threads fleet runtime: the threaded pool of
//! [`super::super::threaded`] generalized to a [`HeterogeneousPool`] —
//! one bounded request queue **per config group**, one plan directory
//! **per config group**, and the [`Router`] consulted at submit time.
//!
//! Structure:
//!
//! * **Routing at submit.** [`FleetHandle::submit`] /
//!   [`FleetHandle::try_submit`] take a workload class; the router
//!   picks the config group and the request lands in that group's
//!   queue. The route decision is a pure function of the class (plus
//!   the round-robin cursor, which advances in submission order), so
//!   the simulated [`FleetScheduler`](super::FleetScheduler) — which
//!   routes the same submission sequence — assigns every request to
//!   the same group. That is what lets the fleet oracle-equivalence
//!   suite compare the two runtimes group by group.
//! * **Per-group publish barriers.** Replication-by-replay (blueprint
//!   → [`materialize`](crate::compiler::PlanBlueprint::materialize))
//!   is only valid between replicas of one variant with identical
//!   allocator histories, so each group has its own
//!   [`PlanDirectory`] and event log; a group's workers share plans
//!   exactly as the homogeneous pool's workers do, and groups never
//!   exchange plans. Pool counters are therefore *per group*, and
//!   match the simulated fleet's per-group lockstep caches exactly.
//! * **Per-class graphs.** Workers execute through the same shared
//!   graph walker ([`run_graph`]); the request's class selects the
//!   graph and the per-(group, class) plan keys / tuned schedules.
//!
//! Outputs are bit-identical to the simulated fleet and to per-config
//! single-device engines — execution is exact on every variant.

use super::super::super::executor::{CpuBackend, ExecError};
use super::super::cache::{PlanCacheStats, PlanKey};
use super::super::run::{plan_keys_for, run_graph, tuned_schedules_for};
use super::super::threaded::{
    PlanDirectory, Replica, Request, RequestQueue, Response, SubmitRejected, WorkerExec,
};
use super::super::Completion;
use super::router::{RoutePolicy, Router};
use super::spec::FleetSpec;
use crate::compiler::op::{config_fingerprint, op_impl};
use crate::compiler::ScheduleChoice;
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph, Placement};
use crate::metrics::{ContentionStats, LatencyHistogram, ThreadCounter};
use crate::runtime::{HeterogeneousPool, VtaRuntime};
use crate::util::Tensor;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Configuration of one threaded fleet run (replica counts come from
/// the [`FleetSpec`]).
#[derive(Clone, Debug)]
pub struct FleetThreadedOptions {
    /// How requests are assigned to config groups.
    pub policy: RoutePolicy,
    /// Bounded request-queue capacity **per group** (admission
    /// control).
    pub queue_capacity: usize,
    /// Most requests a worker pulls per queue visit.
    pub max_batch: usize,
    /// Plan-directory capacity per group (compiled plans resident per
    /// replica).
    pub cache_capacity: usize,
    /// Virtual threads the plans are lowered with (1 or 2).
    pub virtual_threads: usize,
    /// Device DRAM bytes per replica.
    pub dram_size: usize,
    /// Start with workers gated: nothing is served until
    /// [`FleetHandle::resume`].
    pub start_paused: bool,
    /// Serialize plan compiles under each group's directory lock (the
    /// pre-concurrent behavior) — the `--serial-compile` A/B baseline.
    pub serial_compile: bool,
}

impl FleetThreadedOptions {
    /// Defaults matching the homogeneous threaded pool's.
    pub fn new(policy: RoutePolicy) -> Self {
        FleetThreadedOptions {
            policy,
            queue_capacity: 64,
            max_batch: 2,
            cache_capacity: 64,
            virtual_threads: 1,
            dram_size: 256 << 20,
            start_paused: false,
            serial_compile: false,
        }
    }
}

/// Everything a fleet worker thread borrows for its group (shared,
/// read-only or internally synchronized).
struct GroupShared<'a> {
    queue: &'a RequestQueue,
    directory: &'a PlanDirectory,
    graphs: &'a [&'a Graph],
    /// Per-class stage order (shared across groups).
    stage_order: &'a [Vec<Vec<usize>>],
    /// Per-class plan keys under this group's config fingerprint.
    keys: &'a [Vec<Option<PlanKey>>],
    /// Per-class tuned schedules under this group's fingerprint.
    schedules: &'a [Vec<Option<ScheduleChoice>>],
    virtual_threads: usize,
    max_batch: usize,
    clock_hz: f64,
    serial_compile: bool,
}

fn fleet_worker_loop(
    worker: usize,
    rt: &mut VtaRuntime,
    shared: &GroupShared<'_>,
    tx: mpsc::Sender<Response>,
) -> ThreadCounter {
    let mut ex = WorkerExec {
        replica: Replica { rt, plans: HashMap::new(), applied: 0 },
        directory: shared.directory,
        cpu: CpuBackend::Native,
        virtual_threads: shared.virtual_threads,
        clock_hz: shared.clock_hz,
        serial_compile: shared.serial_compile,
        claim_waits: 0,
    };
    let mut counter = ThreadCounter::default();
    while let Some(batch) = shared.queue.pop_batch(shared.max_batch) {
        let t0 = Instant::now();
        let batch_size = batch.len();
        for req in batch {
            let queue_wait = req.submitted.elapsed();
            let class = req.class;
            let s0 = Instant::now();
            let result = run_graph(
                &mut ex,
                shared.graphs[class],
                &req.input,
                &shared.stage_order[class],
                &shared.keys[class],
                &shared.schedules[class],
            )
            .map(|(out, _)| out);
            let response = Response {
                id: req.id,
                result,
                queue_wait,
                service: s0.elapsed(),
                worker,
                batch: batch_size,
            };
            if tx.send(response).is_err() {
                // Receiver gone: the fleet run is being torn down.
                counter.claim_waits = ex.claim_waits;
                return counter;
            }
        }
        counter.record_batch(batch_size, t0.elapsed());
    }
    counter.claim_waits = ex.claim_waits;
    counter
}

/// The driver's interface to a running threaded fleet: submit classed
/// requests (blocking or admission-controlled), poll completions, and
/// inspect live counters. Handed to the driver closure of
/// [`run_fleet_threaded`]; when the closure returns, every group queue
/// closes and the fleet drains.
pub struct FleetHandle<'s> {
    queues: &'s [RequestQueue],
    router: Router,
    rx: mpsc::Receiver<Response>,
    next_id: u64,
    accepted: u64,
    rejected_full: u64,
    rejected_shutdown: u64,
    outputs: Vec<Option<Tensor<i8>>>,
    completions: Vec<Option<Completion>>,
    classes: Vec<usize>,
    routes: Vec<usize>,
    received: u64,
    first_error: Option<ExecError>,
    queue_wait: LatencyHistogram,
    service: LatencyHistogram,
}

impl FleetHandle<'_> {
    fn record(&mut self, resp: Response) {
        let idx = resp.id as usize;
        match resp.result {
            Ok(out) => self.outputs[idx] = Some(out),
            Err(e) => {
                self.first_error.get_or_insert(e);
            }
        }
        self.queue_wait.record(resp.queue_wait.as_secs_f64());
        self.service.record(resp.service.as_secs_f64());
        self.completions[idx] = Some(Completion {
            id: resp.id,
            queue_wait: resp.queue_wait,
            service: resp.service,
            worker: resp.worker,
            batch: resp.batch,
        });
        self.received += 1;
    }

    /// Admission-controlled submit of a class-`class` request: routes,
    /// then rejects with a reason instead of blocking. Returns the
    /// request's submission id. The route decision (and the
    /// round-robin cursor) advances per attempt, accepted or not —
    /// matching the simulated fleet, which routes every submission.
    pub fn try_submit(&mut self, class: usize, input: Tensor<i8>) -> Result<u64, SubmitRejected> {
        let group = self.router.route(class);
        let id = self.next_id;
        match self.queues[group].try_push(Request {
            id,
            class,
            input,
            submitted: Instant::now(),
        }) {
            Ok(()) => {
                self.next_id += 1;
                self.accepted += 1;
                self.outputs.push(None);
                self.completions.push(None);
                self.classes.push(class);
                self.routes.push(group);
                Ok(id)
            }
            Err(e) => {
                match e {
                    SubmitRejected::QueueFull { .. } => self.rejected_full += 1,
                    SubmitRejected::ShuttingDown => self.rejected_shutdown += 1,
                }
                Err(e)
            }
        }
    }

    /// Blocking submit: routes, then waits for room in the routed
    /// group's queue (closed-loop replay).
    pub fn submit(&mut self, class: usize, input: Tensor<i8>) -> Result<u64, SubmitRejected> {
        let group = self.router.route(class);
        let id = self.next_id;
        match self.queues[group].push_wait(Request {
            id,
            class,
            input,
            submitted: Instant::now(),
        }) {
            Ok(()) => {
                self.next_id += 1;
                self.accepted += 1;
                self.outputs.push(None);
                self.completions.push(None);
                self.classes.push(class);
                self.routes.push(group);
                Ok(id)
            }
            Err(e) => {
                self.rejected_shutdown += 1;
                Err(e)
            }
        }
    }

    /// Drain every completion that has already arrived (non-blocking).
    /// Returns the newly observed completions, in arrival order.
    pub fn poll(&mut self) -> Vec<Completion> {
        let mut fresh = Vec::new();
        loop {
            let received = self.rx.try_recv();
            let resp = match received {
                Ok(resp) => resp,
                Err(_) => break,
            };
            let id = resp.id as usize;
            self.record(resp);
            if let Some(c) = &self.completions[id] {
                fresh.push(c.clone());
            }
        }
        fresh
    }

    /// Block until every accepted request has completed.
    pub fn wait_all(&mut self) {
        while self.received < self.accepted {
            match self.rx.recv() {
                Ok(resp) => self.record(resp),
                Err(_) => break,
            }
        }
    }

    /// Completion record of request `id`, if it has finished.
    pub fn completion(&self, id: u64) -> Option<&Completion> {
        self.completions.get(id as usize).and_then(|c| c.as_ref())
    }

    /// The group request `id` was routed to.
    pub fn route_of(&self, id: u64) -> Option<usize> {
        self.routes.get(id as usize).copied()
    }

    /// Requests admitted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Requests rejected by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_shutdown
    }

    /// Completions observed so far.
    pub fn completed(&self) -> u64 {
        self.received
    }

    /// Current bounded-queue depth of group `g`.
    pub fn queue_depth(&self, g: usize) -> usize {
        self.queues[g].depth()
    }

    /// Ungate a fleet started with `start_paused`.
    pub fn resume(&mut self) {
        for q in self.queues {
            q.resume();
        }
    }
}

/// Final report of one threaded fleet run.
#[derive(Debug)]
pub struct FleetThreadedReport {
    /// One output per accepted request, in submission order — the
    /// vector compared bit-for-bit against the simulated fleet's.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request timing, indexed like `outputs`.
    pub completions: Vec<Completion>,
    /// Per-request workload classes, in submission order.
    pub classes: Vec<usize>,
    /// Per-request routed config group, in submission order.
    pub routes: Vec<usize>,
    /// Per-group plan counters (hits + misses = the group's VTA-node
    /// lookups; misses = unique plans compiled, exactly once per
    /// group).
    pub group_cache: Vec<PlanCacheStats>,
    /// Per-worker counters, indexed by global replica index.
    pub threads: Vec<ThreadCounter>,
    /// Queue-wait distribution across all requests.
    pub queue_wait: LatencyHistogram,
    /// Service-time distribution across all requests.
    pub service: LatencyHistogram,
    /// Requests admitted.
    pub accepted: u64,
    /// Requests shed by admission control.
    pub rejected: u64,
    /// Contention observables aggregated across groups: queue-full
    /// rejections, compile-claim waits, directory short-lock
    /// acquisitions.
    pub contention: ContentionStats,
    /// Wall-clock span of the whole run (spawn → drained).
    pub wall: Duration,
}

impl FleetThreadedReport {
    /// Measured (not modeled) throughput: accepted requests over the
    /// run's wall-clock span.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.accepted as f64 / secs
        }
    }
}

/// Run a threaded fleet over `class_graphs`: spawn one worker per
/// replica of every group, hand the driver a [`FleetHandle`] to feed
/// the routed queues, then close, drain, join, and assemble the
/// [`FleetThreadedReport`]. Worker threads are scoped — the graphs,
/// the per-(group, class) plan keys, and the pool replicas are
/// borrowed, not cloned.
pub fn run_fleet_threaded<T>(
    spec: &FleetSpec,
    opts: &FleetThreadedOptions,
    records: &TuningRecords,
    class_graphs: &[&Graph],
    driver: impl FnOnce(&mut FleetHandle) -> T,
) -> Result<(T, FleetThreadedReport), ExecError> {
    if let Err(e) = spec.validate() {
        panic!("invalid fleet spec: {e}");
    }
    assert!(opts.virtual_threads == 1 || opts.virtual_threads == 2, "1 or 2 virtual threads");
    let t0 = Instant::now();
    let vt = opts.virtual_threads;
    let cfgs = spec.configs();
    let mut pool = HeterogeneousPool::new(&cfgs, opts.dram_size);
    let ngroups = pool.group_count();
    if let RoutePolicy::Static(g) = opts.policy {
        assert!(g < ngroups, "static route to group {g} of {ngroups}");
    }

    // Every group must be able to serve every class (routing is free
    // to send any class anywhere).
    for group in pool.groups() {
        for g in class_graphs {
            for node in g.nodes.iter().filter(|n| n.placement == Placement::Vta) {
                if !op_impl(&node.op).offloadable(&group.cfg, node, vt) {
                    return Err(ExecError::NotOffloadable(node.name.clone(), node.op.kind()));
                }
            }
        }
    }

    let group_cfgs: Vec<_> = pool.groups().iter().map(|g| g.cfg.clone()).collect();
    let group_of: Vec<usize> = (0..pool.len()).map(|i| pool.group_of(i)).collect();
    let stage_order: Vec<Vec<Vec<usize>>> = class_graphs.iter().map(|g| stages(g)).collect();
    let keys: Vec<Vec<Vec<Option<PlanKey>>>> = group_cfgs
        .iter()
        .map(|cfg| {
            let fp = config_fingerprint(cfg);
            class_graphs.iter().map(|g| plan_keys_for(fp, vt, g)).collect()
        })
        .collect();
    let schedules: Vec<Vec<Vec<Option<ScheduleChoice>>>> = group_cfgs
        .iter()
        .map(|cfg| {
            let fp = config_fingerprint(cfg);
            class_graphs.iter().map(|g| tuned_schedules_for(records, fp, vt, g)).collect()
        })
        .collect();

    let queues: Vec<RequestQueue> =
        (0..ngroups).map(|_| RequestQueue::new(opts.queue_capacity, opts.start_paused)).collect();
    let directories: Vec<PlanDirectory> =
        (0..ngroups).map(|_| PlanDirectory::new(opts.cache_capacity)).collect();
    let (tx, rx) = mpsc::channel::<Response>();

    let shareds: Vec<GroupShared<'_>> = (0..ngroups)
        .map(|gi| GroupShared {
            queue: &queues[gi],
            directory: &directories[gi],
            graphs: class_graphs,
            stage_order: &stage_order,
            keys: &keys[gi],
            schedules: &schedules[gi],
            virtual_threads: vt,
            max_batch: opts.max_batch,
            clock_hz: group_cfgs[gi].clock_hz,
            serial_compile: opts.serial_compile,
        })
        .collect();

    let (value, mut handle, counters) = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(pool.len());
        for (worker, rt) in pool.iter_mut().enumerate() {
            let tx = tx.clone();
            let shared = &shareds[group_of[worker]];
            joins.push(scope.spawn(move || fleet_worker_loop(worker, rt, shared, tx)));
        }
        drop(tx);

        let mut handle = FleetHandle {
            queues: &queues,
            router: Router::new(opts.policy, &group_cfgs, class_graphs),
            rx,
            next_id: 0,
            accepted: 0,
            rejected_full: 0,
            rejected_shutdown: 0,
            outputs: Vec::new(),
            completions: Vec::new(),
            classes: Vec::new(),
            routes: Vec::new(),
            received: 0,
            first_error: None,
            queue_wait: LatencyHistogram::default(),
            service: LatencyHistogram::default(),
        };
        let value = driver(&mut handle);

        // Graceful drain: stop admitting everywhere, serve what's
        // queued, join.
        for q in &queues {
            q.close();
        }
        let mut counters = Vec::with_capacity(joins.len());
        for join in joins {
            match join.join() {
                Ok(counter) => counters.push(counter),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        // Workers are gone; pick up every remaining response.
        loop {
            let received = handle.rx.try_recv();
            let resp = match received {
                Ok(resp) => resp,
                Err(_) => break,
            };
            handle.record(resp);
        }
        (value, handle, counters)
    });

    if let Some(e) = handle.first_error.take() {
        return Err(e);
    }
    let contention = ContentionStats {
        queue_full: handle.rejected_full,
        claim_waits: counters.iter().map(|c| c.claim_waits).sum(),
        directory_locks: directories.iter().map(|d| d.lock_acquisitions()).sum(),
    };
    let outputs: Vec<Tensor<i8>> = handle
        .outputs
        .into_iter()
        .map(|o| o.expect("every accepted request produced an output"))
        .collect();
    let completions: Vec<Completion> = handle
        .completions
        .into_iter()
        .map(|c| c.expect("every accepted request completed"))
        .collect();
    Ok((
        value,
        FleetThreadedReport {
            outputs,
            completions,
            classes: handle.classes,
            routes: handle.routes,
            group_cache: directories.iter().map(|d| d.stats()).collect(),
            threads: counters,
            queue_wait: handle.queue_wait,
            service: handle.service,
            accepted: handle.accepted,
            rejected: handle.rejected_full + handle.rejected_shutdown,
            contention,
            wall: t0.elapsed(),
        },
    ))
}

/// Closed-loop convenience: replay a classed request trace through a
/// threaded fleet (blocking submits — nothing is shed) and return the
/// drained report. The exact counterpart of feeding the same trace to
/// the simulated [`FleetScheduler`](super::FleetScheduler), which is
/// what the fleet oracle-equivalence suite does.
pub fn serve_fleet_trace(
    spec: &FleetSpec,
    opts: &FleetThreadedOptions,
    records: &TuningRecords,
    class_graphs: &[&Graph],
    trace: &[(usize, Tensor<i8>)],
) -> Result<FleetThreadedReport, ExecError> {
    let ((), report) = run_fleet_threaded(spec, opts, records, class_graphs, |handle| {
        for (class, input) in trace {
            handle.submit(*class, input.clone()).expect("queue open while driver runs");
        }
    })?;
    Ok(report)
}
