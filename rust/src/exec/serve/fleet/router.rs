//! Cost-aware request routing across the config groups of a fleet.
//!
//! The router decides **which group** serves a request; dispatch
//! *within* the group (least-loaded member) stays with the scheduler,
//! exactly like the homogeneous pool. Keeping the group decision a
//! pure function of the request's workload class — never of live load
//! — is what makes the simulated and threaded fleet runtimes route
//! identically by construction, so the oracle-equivalence suite can
//! compare them bit for bit.
//!
//! The cost model ([`graph_model_cycles`]) is the DSE family's
//! analytical roofline, applied per graph node: a VTA node costs the
//! max of its compute occupancy (GEMM ops through
//! [`GemmShape::ops_per_cycle`](crate::arch::GemmShape::ops_per_cycle),
//! tensor-ALU ops through `alu_lanes / alu_ii`) and its memory
//! occupancy (operand + weight + result bytes through the DRAM port),
//! plus the fixed DMA latency. Groups are compared in modeled
//! **seconds** (cycles ÷ the group's own clock), since fleet members
//! may clock differently.

use crate::arch::VtaConfig;
use crate::graph::{Graph, Node, Placement};
use anyhow::{bail, Result};

/// How requests are assigned to config groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Ignore cost: groups take turns in submission order (the
    /// baseline the cost model is measured against).
    RoundRobin,
    /// Each workload class goes to the group with the lowest modeled
    /// graph seconds (ties → lowest group index).
    CostModel,
    /// Every request goes to one fixed group (debugging / ablations).
    Static(usize),
}

impl RoutePolicy {
    /// Parse the CLI spelling: `roundrobin`, `cost`, or `static:G`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "roundrobin" => Ok(RoutePolicy::RoundRobin),
            "cost" => Ok(RoutePolicy::CostModel),
            other => match other.strip_prefix("static:") {
                Some(g) => Ok(RoutePolicy::Static(g.parse()?)),
                None => bail!("unknown route policy {other:?} (expected roundrobin|cost|static:G)"),
            },
        }
    }
}

/// Modeled cycles of one VTA-resident node on `cfg` — the roofline
/// max of compute and DRAM occupancy, plus the DMA latency.
pub fn node_model_cycles(cfg: &VtaConfig, g: &Graph, node: &Node) -> u64 {
    let ops = node.op.ops(&node.shape) as f64;
    let ops_per_cycle = match node.op.kind() {
        // GEMM-core operators (1 MAC = 2 ops, the peak-GOPS convention).
        "conv2d" | "dense" => cfg.gemm.ops_per_cycle() as f64,
        // Everything else runs on the tensor ALU: `alu_lanes` lanes,
        // one issue per `alu_ii` cycles.
        _ => cfg.alu_lanes as f64 / cfg.alu_ii as f64,
    };
    let compute = ops / ops_per_cycle;
    let in_elems: usize =
        node.inputs.iter().map(|&i| g.nodes[i].shape.iter().product::<usize>()).sum();
    let w_elems = g.weights(node.id).map(|w| w.len()).unwrap_or(0);
    let out_elems: usize = node.shape.iter().product();
    // int8 end to end: one byte per element through the shared port.
    let mem = (in_elems + w_elems + out_elems) as f64 / cfg.dram.bytes_per_cycle;
    compute.max(mem).ceil() as u64 + cfg.dram.latency
}

/// Modeled cycles of one whole graph on `cfg`: the sum over
/// VTA-resident nodes (CPU nodes cost the accelerator nothing here —
/// the model ranks *accelerator variants*, and CPU time is identical
/// across them).
pub fn graph_model_cycles(cfg: &VtaConfig, g: &Graph) -> u64 {
    g.nodes
        .iter()
        .filter(|n| n.placement == Placement::Vta)
        .map(|n| node_model_cycles(cfg, g, n))
        .fold(0u64, |a, c| a.saturating_add(c))
}

/// [`graph_model_cycles`] in seconds of the variant's own clock —
/// the unit fleet groups are compared in.
pub fn graph_model_seconds(cfg: &VtaConfig, g: &Graph) -> f64 {
    graph_model_cycles(cfg, g) as f64 / cfg.clock_hz
}

/// The group chooser: one per fleet run, consulted once per request
/// at submission, in submission order.
pub struct Router {
    policy: RoutePolicy,
    ngroups: usize,
    /// Per-class best group under the cost model (precomputed — the
    /// CostModel route is a pure function of the class).
    best_group: Vec<usize>,
    /// RoundRobin cursor.
    cursor: usize,
}

impl Router {
    /// Build a router over `cfgs` (one per config group, in group
    /// order) for the given workload classes. `Static(g)` must name an
    /// existing group.
    pub fn new(policy: RoutePolicy, cfgs: &[VtaConfig], class_graphs: &[&Graph]) -> Self {
        assert!(!cfgs.is_empty(), "a router needs at least one group");
        if let RoutePolicy::Static(g) = policy {
            assert!(g < cfgs.len(), "static route to group {g} of {}", cfgs.len());
        }
        let best_group = class_graphs
            .iter()
            .map(|g| {
                let mut best = 0usize;
                let mut best_secs = graph_model_seconds(&cfgs[0], g);
                for (gi, cfg) in cfgs.iter().enumerate().skip(1) {
                    let secs = graph_model_seconds(cfg, g);
                    if secs < best_secs {
                        best = gi;
                        best_secs = secs;
                    }
                }
                best
            })
            .collect();
        Router { policy, ngroups: cfgs.len(), best_group, cursor: 0 }
    }

    /// Number of config groups routed over.
    pub fn groups(&self) -> usize {
        self.ngroups
    }

    /// The cost model's per-class choice (regardless of the active
    /// policy — reporting / tests).
    pub fn best_group_for(&self, class: usize) -> usize {
        self.best_group[class]
    }

    /// Route the next request of `class`. Mutable: RoundRobin advances
    /// its cursor. Deterministic in (policy, class sequence).
    pub fn route(&mut self, class: usize) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let g = self.cursor % self.ngroups;
                self.cursor += 1;
                g
            }
            RoutePolicy::CostModel => self.best_group[class],
            RoutePolicy::Static(g) => g,
        }
    }

    /// Route a whole class sequence (the trace-replay convenience used
    /// by the DSE fleet scorer).
    pub fn route_trace(&mut self, classes: &[usize]) -> Vec<usize> {
        classes.iter().map(|&c| self.route(c)).collect()
    }
}

/// Modeled fleet makespan of a routed trace: each request (in order)
/// goes to the least-loaded replica of its routed group, loaded by its
/// class's modeled graph seconds on that group's variant; the makespan
/// is the heaviest replica. This is the quantity `dse --fleet`
/// optimizes and `serve --fleet --require-routing-win` gates on —
/// deliberately the same model on both sides, so the searched
/// composition and the serving-time routing agree about what "better"
/// means.
pub fn modeled_fleet_makespan(
    cfgs: &[VtaConfig],
    group_devices: &[usize],
    class_graphs: &[&Graph],
    classes: &[usize],
    routes: &[usize],
) -> f64 {
    assert_eq!(cfgs.len(), group_devices.len(), "one device count per group");
    assert_eq!(classes.len(), routes.len(), "one route per request");
    let secs: Vec<Vec<f64>> = cfgs
        .iter()
        .map(|cfg| class_graphs.iter().map(|g| graph_model_seconds(cfg, g)).collect())
        .collect();
    // Per-group per-member loads.
    let mut load: Vec<Vec<f64>> = group_devices.iter().map(|&n| vec![0.0f64; n]).collect();
    for (&class, &group) in classes.iter().zip(routes) {
        let members = &mut load[group];
        let mut d = 0usize;
        for i in 1..members.len() {
            if members[i] < members[d] {
                d = i;
            }
        }
        members[d] += secs[group][class];
    }
    load.iter().flatten().fold(0.0f64, |a, &l| a.max(l))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{partition, PartitionPolicy};
    use crate::graph::{Graph, Op};
    use crate::util::{Tensor, XorShiftRng};

    /// A tiny ALU-heavy graph (relu/add chain) and a conv-only graph.
    fn alu_graph(cfg: &VtaConfig) -> Graph {
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let r = g.add("relu", Op::Relu, &[x]).unwrap();
        let a = g.add("add", Op::Add, &[r, x]).unwrap();
        let _ = g.add("shr", Op::ShrImm { shift: 1 }, &[a]).unwrap();
        partition(&mut g, &PartitionPolicy::offload_all(cfg));
        g
    }

    fn conv_graph(cfg: &VtaConfig) -> Graph {
        use crate::compiler::{Conv2dParams, Requant};
        let mut g = Graph::new();
        let x = g.add("in", Op::Input { shape: vec![1, 16, 8, 8] }, &[]).unwrap();
        let p = Conv2dParams {
            h: 8,
            w: 8,
            ic: 16,
            oc: 16,
            k: 3,
            s: 1,
            requant: Requant { shift: 6, relu: false },
        };
        let c = g.add("conv", Op::Conv2d { p }, &[x]).unwrap();
        let mut rng = XorShiftRng::new(7);
        g.set_weights(c, Tensor::from_vec(&[16, 16, 3, 3], rng.vec_i8(16 * 16 * 9, -4, 4)).unwrap());
        partition(&mut g, &PartitionPolicy::paper(cfg));
        g
    }

    /// The two-variant fleet the examples and CI use: group 0 pares
    /// the tensor ALU down to 8 lanes (conv-focused — the GEMM core is
    /// untouched, so conv cycles tie with stock pynq and the
    /// cost-model tie-break keeps conv traffic here), group 1 is stock
    /// pynq (full 16-lane ALU — on the lanes-8 variant every ALU op is
    /// compute-bound, so eltwise traffic is strictly cheaper here).
    fn two_group_cfgs() -> [VtaConfig; 2] {
        let pynq = VtaConfig::pynq();
        let mut conv_tuned = pynq.clone();
        conv_tuned.alu_lanes = 8;
        [conv_tuned, pynq]
    }

    #[test]
    fn cost_model_prefers_the_right_group_per_class() {
        let cfgs = two_group_cfgs();
        let conv = conv_graph(&cfgs[0]);
        let alu_g = alu_graph(&cfgs[0]);
        let graphs: Vec<&Graph> = vec![&conv, &alu_g];
        let router = Router::new(RoutePolicy::CostModel, &cfgs, &graphs);
        // Conv class: GEMM cost ties, so the tie-break picks group 0.
        assert_eq!(
            graph_model_cycles(&cfgs[0], &conv),
            graph_model_cycles(&cfgs[1], &conv)
        );
        assert_eq!(router.best_group_for(0), 0);
        // ALU class: strictly cheaper on the full-width ALU group.
        assert!(graph_model_seconds(&cfgs[1], &alu_g) < graph_model_seconds(&cfgs[0], &alu_g));
        assert_eq!(router.best_group_for(1), 1);
    }

    #[test]
    fn policies_route_deterministically() {
        let cfgs = two_group_cfgs();
        let conv = conv_graph(&cfgs[0]);
        let alu_g = alu_graph(&cfgs[0]);
        let graphs: Vec<&Graph> = vec![&conv, &alu_g];
        let classes = [0usize, 1, 0, 1, 1];

        let mut rr = Router::new(RoutePolicy::RoundRobin, &cfgs, &graphs);
        assert_eq!(rr.route_trace(&classes), vec![0, 1, 0, 1, 0]);
        let mut cm = Router::new(RoutePolicy::CostModel, &cfgs, &graphs);
        assert_eq!(cm.route_trace(&classes), vec![0, 1, 0, 1, 1]);
        let mut st = Router::new(RoutePolicy::Static(1), &cfgs, &graphs);
        assert_eq!(st.route_trace(&classes), vec![1, 1, 1, 1, 1]);
    }

    #[test]
    fn cost_routing_beats_round_robin_on_the_modeled_makespan() {
        let cfgs = two_group_cfgs().to_vec();
        let devices = vec![1usize, 1];
        let conv = conv_graph(&cfgs[0]);
        let alu_g = alu_graph(&cfgs[0]);
        let graphs: Vec<&Graph> = vec![&conv, &alu_g];
        // Balanced mixed trace, ALU class first: round-robin's
        // index-parity grouping then lands the ALU requests on the
        // narrow-ALU group (a misrouting the cost model never makes).
        let classes: Vec<usize> = (0..16).map(|i| (i + 1) % 2).collect();

        let rr_routes =
            Router::new(RoutePolicy::RoundRobin, &cfgs, &graphs).route_trace(&classes);
        let cm_routes = Router::new(RoutePolicy::CostModel, &cfgs, &graphs).route_trace(&classes);
        let rr = modeled_fleet_makespan(&cfgs, &devices, &graphs, &classes, &rr_routes);
        let cm = modeled_fleet_makespan(&cfgs, &devices, &graphs, &classes, &cm_routes);
        assert!(
            cm < rr,
            "cost-model routing must beat round-robin: {cm} vs {rr}"
        );
    }

    #[test]
    fn route_policy_parses_cli_spellings() {
        assert_eq!(RoutePolicy::parse("roundrobin").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("cost").unwrap(), RoutePolicy::CostModel);
        assert_eq!(RoutePolicy::parse("static:2").unwrap(), RoutePolicy::Static(2));
        assert!(RoutePolicy::parse("fastest").is_err());
        assert!(RoutePolicy::parse("static:x").is_err());
    }
}
