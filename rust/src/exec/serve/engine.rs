//! The single-device serving engine: compile-once/run-many over one
//! runtime, with a pipelined, batched front-end.
//!
//! [`ServingEngine`] walks the partitioned graph in topological stages
//! and serves single requests ([`ServingEngine::run_one`]) or batches
//! ([`ServingEngine::run_batch`]), reporting **both** the naive-serial
//! end-to-end time (every node back-to-back, the
//! [`Executor`](crate::exec::Executor) discipline) and the
//! **pipelined** time under the two-resource overlap model of
//! [`super::schedule`]. The multi-device analogue — a request queue,
//! dynamic batching, and least-loaded dispatch over a device pool —
//! is [`super::Scheduler`].

use super::super::executor::{lift_compile_err, CpuBackend, ExecError};
use super::cache::{plan_key_for, PlanCache, PlanCacheStats, PlanKey};
use super::report::{BatchReport, ServeReport};
use super::run::{plan_keys_for, run_graph, tuned_schedules_for, VtaNodeExec};
use super::schedule::pipeline_schedule;
use crate::arch::VtaConfig;
use crate::compiler::op::{config_fingerprint, execute_compiled, op_impl};
use crate::compiler::ScheduleChoice;
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph, Node};
use crate::runtime::VtaRuntime;
use crate::sim::SimStats;
use crate::util::Tensor;
use std::collections::HashMap;
use std::time::Instant;

/// The batched, plan-caching serving engine.
pub struct ServingEngine {
    rt: VtaRuntime,
    cpu: CpuBackend,
    cache: PlanCache,
    virtual_threads: usize,
    config_fp: u64,
    /// Tuned schedules from `vta dse`, consulted at compile time. Fixed
    /// for the engine's lifetime, so [`PlanKey`] does not need to carry
    /// a schedule fingerprint — within one engine, (config, vt, op)
    /// still uniquely determines the compiled artifact.
    records: TuningRecords,
}

impl ServingEngine {
    /// Build an engine over a fresh runtime with `dram_size` bytes of
    /// device DRAM (compiled plans hold their buffers resident there),
    /// a CPU backend, `virtual_threads` ∈ {1, 2}, and a plan cache of
    /// `cache_capacity` entries.
    pub fn new(
        cfg: &VtaConfig,
        dram_size: usize,
        cpu: CpuBackend,
        virtual_threads: usize,
        cache_capacity: usize,
    ) -> Self {
        Self::with_records(
            cfg,
            dram_size,
            cpu,
            virtual_threads,
            cache_capacity,
            TuningRecords::new(),
        )
    }

    /// Like [`Self::new`], seeded with a tuning-record store (usually
    /// loaded from the JSON file `vta dse` persisted): every VTA node
    /// whose (config, operator) pair has a record compiles with the
    /// tuned schedule instead of the planner's greedy default, so
    /// tuned schedules survive restarts and serving traffic
    /// automatically runs the tuned plan.
    pub fn with_records(
        cfg: &VtaConfig,
        dram_size: usize,
        cpu: CpuBackend,
        virtual_threads: usize,
        cache_capacity: usize,
        records: TuningRecords,
    ) -> Self {
        assert!(
            virtual_threads == 1 || virtual_threads == 2,
            "1 or 2 virtual threads"
        );
        ServingEngine {
            rt: VtaRuntime::new(cfg, dram_size),
            cpu,
            cache: PlanCache::new(cache_capacity),
            virtual_threads,
            config_fp: config_fingerprint(cfg),
            records,
        }
    }

    /// Number of tuning records the engine consults.
    pub fn tuned_records(&self) -> usize {
        self.records.len()
    }

    /// The tuned schedule the engine would apply to `node`, if its
    /// record store has one for this (config, operator) pair.
    pub fn tuned_schedule(&self, node: &Node) -> Option<ScheduleChoice> {
        let entry = op_impl(&node.op);
        self.records.lookup(self.config_fp, self.virtual_threads, entry.schedule_fingerprint(node))
    }

    /// The schedule baked into the resident compiled plan for `key`
    /// (`None` = no resident plan, or the plan uses the default
    /// schedule). Tests / introspection.
    pub fn cached_schedule(&self, key: &PlanKey) -> Option<ScheduleChoice> {
        self.cache.peek(key).and_then(|node| node.schedule)
    }

    /// Cumulative plan-cache counters.
    pub fn cache_stats(&self) -> PlanCacheStats {
        self.cache.stats()
    }

    /// Number of resident compiled plans.
    pub fn cached_plans(&self) -> usize {
        self.cache.len()
    }

    /// Resident plans per operator kind.
    pub fn cached_kinds(&self) -> HashMap<&'static str, usize> {
        self.cache.kinds()
    }

    /// DRAM bytes held by resident plans.
    pub fn cache_dram_bytes(&self) -> usize {
        self.cache.dram_bytes()
    }

    /// The plan key the engine would use for `node` (any registered
    /// operator; tests / introspection).
    pub fn plan_key(&self, g: &Graph, node: &Node) -> PlanKey {
        plan_key_for(self.config_fp, self.virtual_threads, g, node)
    }

    /// Serve one request.
    pub fn run_one(&mut self, g: &Graph, input: &Tensor<i8>) -> Result<ServeReport, ExecError> {
        let stage_order = stages(g);
        let keys = plan_keys_for(self.config_fp, self.virtual_threads, g);
        let schedules = tuned_schedules_for(&self.records, self.config_fp, self.virtual_threads, g);
        let (output, nodes) = run_graph(self, g, input, &stage_order, &keys, &schedules)?;
        let model = pipeline_schedule(g, std::slice::from_ref(&nodes));
        Ok(ServeReport {
            output,
            nodes,
            serial_seconds: model.serial_seconds,
            pipelined_seconds: model.makespan_seconds,
        })
    }

    /// Serve a batch of requests, amortizing stage computation, plan
    /// keys (weight fingerprints), plan lookup, and constant packing
    /// across the batch. Outputs are bit-identical to serving each
    /// request alone (and to the serial [`crate::exec::Executor`]).
    pub fn run_batch(
        &mut self,
        g: &Graph,
        inputs: &[Tensor<i8>],
    ) -> Result<BatchReport, ExecError> {
        let stats0 = self.cache.stats();
        let t0 = Instant::now();
        let stage_order = stages(g);
        let keys = plan_keys_for(self.config_fp, self.virtual_threads, g);
        let schedules = tuned_schedules_for(&self.records, self.config_fp, self.virtual_threads, g);
        let mut outputs = Vec::with_capacity(inputs.len());
        let mut per_request = Vec::with_capacity(inputs.len());
        for input in inputs {
            let (out, nodes) = run_graph(self, g, input, &stage_order, &keys, &schedules)?;
            outputs.push(out);
            per_request.push(nodes);
        }
        let host_wall = t0.elapsed();
        let model = pipeline_schedule(g, &per_request);
        let s1 = self.cache.stats();
        Ok(BatchReport {
            outputs,
            per_request,
            serial_seconds: model.serial_seconds,
            pipelined_seconds: model.makespan_seconds,
            completion_seconds: model.completion_seconds,
            cache: PlanCacheStats {
                hits: s1.hits - stats0.hits,
                misses: s1.misses - stats0.misses,
                evictions: s1.evictions - stats0.evictions,
            },
            host_wall,
        })
    }
}

/// The engine's side of the shared graph walker
/// ([`super::run::run_graph`]): VTA nodes go through the plan cache's
/// closure-driven compile-on-miss path. Dispatch is op-generic — every
/// VTA node compiles and runs through its registered
/// [`VtaOp`](crate::compiler::VtaOp) implementation.
impl VtaNodeExec for ServingEngine {
    fn clock_hz(&self) -> f64 {
        self.rt.ctx.config().clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        let node = &g.nodes[id];
        let entry = op_impl(&node.op);
        let vt = self.virtual_threads;
        // Split borrows: the cache hands out a plan while the runtime
        // executes it.
        let rt = &mut self.rt;
        let compiled = self.cache.get_or_compile(rt, key, |rt| {
            entry
                .compile(rt, g, node, vt, schedule.as_ref())
                .map_err(|e| lift_compile_err(&node.name, e))
        })?;
        execute_compiled(entry, compiled, rt, inputs).map_err(|e| lift_compile_err(&node.name, e))
    }
}
