//! The multi-device scheduler: a request queue with dynamic batching
//! and least-loaded dispatch over a [`DevicePool`] of accelerator
//! replicas.
//!
//! Three moving parts:
//!
//! * **Dynamic batching.** Requests carry a simulated arrival time.
//!   The scheduler closes a batch when it reaches
//!   [`SchedulerOptions::max_batch`] requests, or when the oldest
//!   queued request has waited [`SchedulerOptions::batch_deadline`]
//!   simulated seconds (a partial batch also flushes when the request
//!   stream ends — waiting past the last arrival buys nothing).
//! * **Least-loaded dispatch.** Each replica keeps its own simulated
//!   clock (`free_at`); a closed batch goes to the replica that frees
//!   up earliest (ties → lowest index), starts at
//!   `max(batch ready, device free)`, and occupies the device for the
//!   batch's pipelined makespan ([`super::pipeline_schedule`]). With N
//!   replicas, N batches are genuinely in flight in simulated time —
//!   modeled throughput scales with pool size.
//! * **Lockstep plan caches — the shared compile-once path.** Every
//!   replica has a [`PlanCache`], but all caches see the *same*
//!   lookup/eviction sequence: on a pool-level miss every cache evicts
//!   the same victims first, then the plan is lowered **once** (on
//!   replica 0) and byte-replicated onto the others
//!   ([`CompiledNode::replicate_to`](crate::compiler::CompiledNode::replicate_to)
//!   — identical allocator histories guarantee identical DRAM
//!   addresses, so the sealed streams replay verbatim). A plan is
//!   compiled exactly once per pool, not once per device; any replica
//!   can then serve any request.
//!
//! Outputs are bit-identical to the single-device
//! [`ServingEngine`](super::ServingEngine) and to the serial
//! [`Executor`](crate::exec::Executor) — execution is exact; only the
//! timing is modeled.

use super::super::executor::{lift_compile_err, CpuBackend, ExecError};
use super::cache::{PlanCache, PlanCacheStats, PlanKey};
use super::run::{plan_keys_for, run_graph, tuned_schedules_for, VtaNodeExec};
use super::schedule::pipeline_schedule;
use crate::arch::VtaConfig;
use crate::compiler::op::{config_fingerprint, execute_compiled, op_impl};
use crate::compiler::ScheduleChoice;
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph, Node};
use crate::metrics::PoolMetrics;
use crate::runtime::DevicePool;
use crate::sim::SimStats;
use crate::util::{percentile_sorted, Tensor};
use std::time::{Duration, Instant};

/// Knobs of the multi-device serving runtime.
#[derive(Clone, Debug)]
pub struct SchedulerOptions {
    /// Pool replicas.
    pub devices: usize,
    /// Maximum requests per dispatched batch.
    pub max_batch: usize,
    /// Dynamic-batching deadline in **simulated** seconds: a partial
    /// batch is dispatched once its oldest request has waited this
    /// long.
    pub batch_deadline: f64,
    /// Plan-cache capacity per replica (caches run in lockstep, so
    /// every replica holds the same plans).
    pub cache_capacity: usize,
    /// Virtual threads VTA nodes are lowered with, ∈ {1, 2}.
    pub virtual_threads: usize,
    /// Device DRAM bytes per replica.
    pub dram_size: usize,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            devices: 1,
            max_batch: 8,
            batch_deadline: 1e-3,
            cache_capacity: 64,
            virtual_threads: 2,
            dram_size: 256 << 20,
        }
    }
}

/// One dispatched batch, for reporting.
#[derive(Clone, Copy, Debug)]
pub struct BatchRecord {
    /// Replica the batch ran on.
    pub device: usize,
    /// Requests in the batch.
    pub size: usize,
    /// Simulated time the batch closed (full, deadline, or stream
    /// end).
    pub ready: f64,
    /// Simulated time service began (`max(ready, device free)`).
    pub start: f64,
    /// Simulated time service completed.
    pub finish: f64,
}

/// Outcome of draining the request queue through the pool.
#[derive(Debug)]
pub struct PoolReport {
    /// Per-request outputs, in submission order.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request arrival times, in submission order.
    pub arrivals: Vec<f64>,
    /// Per-request completion times (simulated), in submission order.
    pub completions: Vec<f64>,
    /// Every dispatched batch, in dispatch order.
    pub batches: Vec<BatchRecord>,
    /// Simulated busy seconds per replica.
    pub device_busy: Vec<f64>,
    /// End of the simulated span: the last batch completion (0 with no
    /// requests).
    pub makespan_seconds: f64,
    /// Plan-cache counters for this run. This pool is one config group
    /// — all replicas run the same variant and their caches run in
    /// lockstep — so replica 0's counters are the pool's. (The fleet
    /// generalization reports one such entry per config group:
    /// [`FleetReport::group_cache`](super::fleet::FleetReport).)
    pub cache: PlanCacheStats,
    /// Real host wall time of the drain (includes pool-level compiles
    /// on cold caches).
    pub host_wall: Duration,
    /// Queue-depth samples and per-device counters, each stamped with
    /// this pool's config fingerprint.
    pub metrics: PoolMetrics,
}

impl PoolReport {
    /// Requests per modeled second over the whole span.
    pub fn throughput(&self) -> f64 {
        if self.makespan_seconds > 0.0 {
            self.outputs.len() as f64 / self.makespan_seconds
        } else {
            0.0
        }
    }

    /// Request latency (completion − arrival) percentile, `q` ∈
    /// [0, 1], interpolating — the shared [`percentile_sorted`].
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let mut lat: Vec<f64> = self
            .completions
            .iter()
            .zip(&self.arrivals)
            .map(|(c, a)| c - a)
            .collect();
        lat.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        percentile_sorted(&lat, q)
    }

    /// Busy fraction of replica `d` over the simulated span.
    pub fn utilization(&self, d: usize) -> f64 {
        if self.makespan_seconds > 0.0 {
            (self.device_busy[d] / self.makespan_seconds).min(1.0)
        } else {
            0.0
        }
    }
}

/// The multi-device serving runtime: queue → dynamic batches →
/// least-loaded replicas, over lockstep per-device plan caches.
pub struct Scheduler {
    pool: DevicePool,
    caches: Vec<PlanCache>,
    cpu: CpuBackend,
    opts: SchedulerOptions,
    config_fp: u64,
    records: TuningRecords,
    /// Pending requests: (arrival, input), in submission order.
    queue: Vec<(f64, Tensor<i8>)>,
}

impl Scheduler {
    /// Build a scheduler over `opts.devices` fresh replicas of `cfg`.
    pub fn new(cfg: &VtaConfig, cpu: CpuBackend, opts: SchedulerOptions) -> Self {
        Self::with_records(cfg, cpu, opts, TuningRecords::new())
    }

    /// Like [`Self::new`], seeded with a `vta dse` tuning-record store
    /// (consulted at compile time, exactly as in
    /// [`ServingEngine::with_records`](super::ServingEngine::with_records)).
    pub fn with_records(
        cfg: &VtaConfig,
        cpu: CpuBackend,
        opts: SchedulerOptions,
        records: TuningRecords,
    ) -> Self {
        assert!(
            opts.virtual_threads == 1 || opts.virtual_threads == 2,
            "1 or 2 virtual threads"
        );
        assert!(opts.max_batch >= 1, "max_batch must be at least 1");
        assert!(
            opts.batch_deadline >= 0.0 && opts.batch_deadline.is_finite(),
            "batch_deadline must be a finite non-negative simulated time"
        );
        let pool = DevicePool::new(cfg, opts.dram_size, opts.devices);
        let caches = (0..opts.devices).map(|_| PlanCache::new(opts.cache_capacity)).collect();
        Scheduler {
            pool,
            caches,
            cpu,
            opts,
            config_fp: config_fingerprint(cfg),
            records,
            queue: Vec::new(),
        }
    }

    /// Pool size.
    pub fn devices(&self) -> usize {
        self.pool.len()
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Fresh pool counters with every device stamped with the pool's
    /// (single) config fingerprint.
    fn fresh_metrics(&self) -> PoolMetrics {
        let mut metrics = PoolMetrics::new(self.pool.len());
        for counter in &mut metrics.devices {
            counter.config_fingerprint = self.config_fp;
        }
        metrics
    }

    /// Cumulative plan-cache counters (replica 0 — lockstep makes it
    /// the pool's).
    pub fn cache_stats(&self) -> PlanCacheStats {
        self.caches[0].stats()
    }

    /// Resident compiled plans per replica (identical across the pool
    /// by lockstep).
    pub fn cached_plans(&self) -> usize {
        self.caches[0].len()
    }

    /// DRAM bytes held by resident plans, per replica.
    pub fn cache_dram_bytes(&self) -> usize {
        self.caches[0].dram_bytes()
    }

    /// Enqueue a request arriving at simulated time `arrival`.
    pub fn submit(&mut self, arrival: f64, input: Tensor<i8>) {
        assert!(
            arrival >= 0.0 && arrival.is_finite(),
            "arrival must be a finite non-negative simulated time"
        );
        self.queue.push((arrival, input));
    }

    /// Drain the queue: form dynamic batches, dispatch them across the
    /// pool, execute every request exactly (bit-identical to the
    /// single-device engine), and report modeled times + metrics.
    pub fn run(&mut self, g: &Graph) -> Result<PoolReport, ExecError> {
        let ndev = self.pool.len();
        let t0 = Instant::now();
        let stats0 = self.caches[0].stats();
        let n = self.queue.len();
        if n == 0 {
            return Ok(PoolReport {
                outputs: Vec::new(),
                arrivals: Vec::new(),
                completions: Vec::new(),
                batches: Vec::new(),
                device_busy: vec![0.0; ndev],
                makespan_seconds: 0.0,
                cache: PlanCacheStats::default(),
                host_wall: t0.elapsed(),
                metrics: self.fresh_metrics(),
            });
        }

        let vt = self.opts.virtual_threads;
        let stage_order = stages(g);
        let keys = plan_keys_for(self.config_fp, vt, g);
        let schedules = tuned_schedules_for(&self.records, self.config_fp, vt, g);

        // Requests in arrival order (stable: equal arrivals keep
        // submission order), remembering the submission index so the
        // report lines up with the caller's inputs.
        let mut reqs: Vec<(usize, f64, Tensor<i8>)> = self
            .queue
            .drain(..)
            .enumerate()
            .map(|(i, (arrival, input))| (i, arrival, input))
            .collect();
        reqs.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite arrivals"));

        // Dynamic batching over the arrival-ordered stream: close on
        // max_batch, on the deadline, or at stream end.
        let maxb = self.opts.max_batch;
        let deadline = self.opts.batch_deadline;
        let last_arrival = reqs.last().expect("non-empty queue").1;
        let mut batches: Vec<Vec<usize>> = Vec::new();
        let mut current: Vec<usize> = Vec::new();
        for r in 0..reqs.len() {
            if !current.is_empty()
                && (current.len() >= maxb || reqs[r].1 > reqs[current[0]].1 + deadline)
            {
                batches.push(std::mem::take(&mut current));
            }
            current.push(r);
        }
        if !current.is_empty() {
            batches.push(current);
        }

        // Dispatch: least-loaded replica, per-device simulated clocks.
        let mut free_at = vec![0.0f64; ndev];
        let mut busy = vec![0.0f64; ndev];
        let mut metrics = self.fresh_metrics();
        let mut batch_records = Vec::with_capacity(batches.len());
        let mut outputs: Vec<Option<Tensor<i8>>> = (0..n).map(|_| None).collect();
        let mut arrivals = vec![0.0f64; n];
        let mut completions = vec![0.0f64; n];
        let mut dispatched = 0usize;

        for members in &batches {
            let first_arrival = reqs[members[0]].1;
            let last_member_arrival = reqs[*members.last().expect("non-empty batch")].1;
            let ready = if members.len() >= maxb {
                last_member_arrival
            } else {
                (first_arrival + deadline).min(last_arrival)
            };

            let mut d = 0;
            for i in 1..ndev {
                if free_at[i] < free_at[d] {
                    d = i;
                }
            }
            let start = ready.max(free_at[d]);
            // Queue depth at the dispatch instant: requests that have
            // *arrived* by `start` and are not yet dispatched (batch
            // starts are non-decreasing, so every earlier dispatch
            // covers only arrivals ≤ this one's start).
            let arrived = reqs.partition_point(|r| r.1 <= start);
            metrics.queue.record(start, arrived.saturating_sub(dispatched));

            // Execute every member exactly, on replica `d`.
            let mut per_request = Vec::with_capacity(members.len());
            let mut batch_cycles = 0u64;
            for &r in members {
                let (submit_idx, arrival, ref input) = reqs[r];
                let (out, reports) = run_graph(
                    &mut DeviceRun { sched: &mut *self, device: d },
                    g,
                    input,
                    &stage_order,
                    &keys,
                    &schedules,
                )?;
                batch_cycles += reports
                    .iter()
                    .filter_map(|nr| nr.stats.as_ref())
                    .map(|s| s.total_cycles)
                    .sum::<u64>();
                outputs[submit_idx] = Some(out);
                arrivals[submit_idx] = arrival;
                per_request.push(reports);
            }

            // The batch occupies the replica for its pipelined
            // makespan; member completions are offsets within it.
            let model = pipeline_schedule(g, &per_request);
            for (k, &r) in members.iter().enumerate() {
                completions[reqs[r].0] = start + model.completion_seconds[k];
            }
            let finish = start + model.makespan_seconds;
            free_at[d] = finish;
            busy[d] += model.makespan_seconds;
            dispatched += members.len();
            metrics.devices[d].record_batch(members.len(), model.makespan_seconds, batch_cycles);
            batch_records.push(BatchRecord {
                device: d,
                size: members.len(),
                ready,
                start,
                finish,
            });
        }

        let makespan = batch_records.iter().map(|b| b.finish).fold(0.0f64, f64::max);
        let s1 = self.caches[0].stats();
        Ok(PoolReport {
            outputs: outputs.into_iter().map(|o| o.expect("every request served")).collect(),
            arrivals,
            completions,
            batches: batch_records,
            device_busy: busy,
            makespan_seconds: makespan,
            cache: PlanCacheStats {
                hits: s1.hits - stats0.hits,
                misses: s1.misses - stats0.misses,
                evictions: s1.evictions - stats0.evictions,
            },
            host_wall: t0.elapsed(),
            metrics,
        })
    }

    /// The shared compile-once path: make `key`'s plan resident in
    /// **every** replica's cache, in lockstep.
    ///
    /// Hit: touch every cache (identical LRU updates). Miss: every
    /// cache evicts the same victims first (identical allocator
    /// frees), then the plan is lowered once on replica 0 and
    /// byte-replicated onto the rest — identical allocator histories
    /// put every replica's copy at identical DRAM addresses, so the
    /// sealed streams replay verbatim.
    ///
    /// Error paths preserve the lockstep invariant: a failed compile
    /// leaves replica 0's allocator untouched (the `compile_*` paths
    /// allocate atomically), and a failed replication unwinds — the
    /// already-replicated copies and the source plan are all freed —
    /// so every replica's allocator lands in the same state and the
    /// pool stays serviceable.
    fn ensure_compiled(
        &mut self,
        g: &Graph,
        node: &Node,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
    ) -> Result<(), ExecError> {
        if self.caches[0].contains(key) {
            for c in &mut self.caches {
                let hit = c.touch(key);
                debug_assert!(hit, "pool plan caches fell out of lockstep");
            }
            return Ok(());
        }
        let entry = op_impl(&node.op);
        for (c, rt) in self.caches.iter_mut().zip(self.pool.devices_mut()) {
            c.note_miss();
            c.make_room(rt)?;
        }
        let vt = self.opts.virtual_threads;
        let compiled = entry
            .compile(self.pool.device_mut(0), g, node, vt, schedule.as_ref())
            .map_err(|e| lift_compile_err(&node.name, e))?;
        for d in 1..self.pool.len() {
            let (src, dst) = self.pool.pair_mut(0, d);
            match compiled.replicate_to(src, dst) {
                Ok(clone) => self.caches[d].insert(key.clone(), clone),
                Err(e) => {
                    for u in 1..d {
                        let rt_u = self.pool.device_mut(u);
                        let _ = self.caches[u].remove(key, rt_u);
                    }
                    let _ = compiled.free(self.pool.device_mut(0));
                    return Err(lift_compile_err(&node.name, e));
                }
            }
        }
        self.caches[0].insert(key.clone(), compiled);
        Ok(())
    }
}

/// One dispatch's device view: the scheduler plus the replica a batch
/// was assigned to — the scheduler's side of the shared graph walker
/// ([`super::run::run_graph`]). VTA nodes go through the lockstep
/// caches ([`Scheduler::ensure_compiled`]) and execute on the chosen
/// replica.
struct DeviceRun<'a> {
    sched: &'a mut Scheduler,
    device: usize,
}

impl VtaNodeExec for DeviceRun<'_> {
    fn clock_hz(&self) -> f64 {
        self.sched.pool.config().clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.sched.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        let node = &g.nodes[id];
        let entry = op_impl(&node.op);
        self.sched.ensure_compiled(g, node, key, schedule)?;
        // Split borrows: the chosen replica executes a plan held by
        // its own (disjoint) cache.
        let rt = self.sched.pool.device_mut(self.device);
        let compiled =
            self.sched.caches[self.device].peek(key).expect("plan resident after ensure_compiled");
        execute_compiled(entry, compiled, rt, inputs).map_err(|e| lift_compile_err(&node.name, e))
    }
}
