//! Lock-free bounded MPMC primitives for the serving runtimes.
//!
//! [`ArrayQueue`] is a Vyukov-style bounded ring: one atomic sequence
//! number per slot arbitrates producers and consumers, so the
//! steady-state push/pop paths are a couple of CAS/stores with no
//! global lock. A separate exact occupancy counter is reserved *before*
//! a producer claims a slot, which keeps the admission bound precise
//! even though the ring itself rounds up to a power of two — the
//! admission-control tests assert rejection at exactly `capacity`.
//!
//! [`channel`] wraps a ring in disconnect-aware blocking endpoints
//! (sender count + receiver liveness, condvar parking for the blocking
//! edges only) — the drop-in replacement for the pipeline runtime's
//! `mpsc::sync_channel` stage handoffs. The threaded pool's
//! [`RequestQueue`](super::threaded) builds its own parking layer on
//! the ring directly because it adds close/pause semantics.
//!
//! ## Wakeup protocol (shared by the channel and the request queue)
//!
//! Parking must not lose wakeups without putting a lock on the hot
//! path. Both sides run the classic two-fence handshake:
//!
//! * a producer publishes its item, runs a `SeqCst` fence, then checks
//!   the waiter count — only when waiters exist does it take the park
//!   mutex and notify;
//! * a consumer registers as a waiter (under the park mutex), runs a
//!   `SeqCst` fence, re-checks the ring, and only then waits.
//!
//! The two fences totally order the publish/check against the
//! register/re-check: either the consumer's re-check sees the item, or
//! the producer sees the registered waiter and notifies.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// One ring slot: the sequence number encodes which lap the slot is on
/// and whether it currently holds a value (see [`ArrayQueue::try_push`]).
struct Slot<T> {
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free MPMC ring with an exact occupancy bound.
///
/// Non-blocking only; callers layer their own parking (see the module
/// docs). `len()` is a relaxed atomic read — the observability path
/// never contends with dispatch.
pub(crate) struct ArrayQueue<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    /// Logical capacity (exact admission bound; `slots.len()` may be
    /// larger after rounding to a power of two).
    capacity: usize,
    /// Next dequeue position.
    head: AtomicUsize,
    /// Next enqueue position.
    tail: AtomicUsize,
    /// Exact occupancy: reserved before a push claims a slot, released
    /// after a pop clears one. `len <= capacity` always.
    len: AtomicUsize,
}

// The UnsafeCell makes the type !Sync by default; slot hand-off is
// synchronized by the per-slot sequence numbers (acquire loads pair
// with the release stores below), so sharing is sound whenever the
// payload can move between threads.
unsafe impl<T: Send> Send for ArrayQueue<T> {}
unsafe impl<T: Send> Sync for ArrayQueue<T> {}

impl<T> ArrayQueue<T> {
    pub(crate) fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let n = capacity.next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..n)
            .map(|i| Slot { seq: AtomicUsize::new(i), val: UnsafeCell::new(MaybeUninit::uninit()) })
            .collect();
        ArrayQueue {
            slots,
            mask: n - 1,
            capacity,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            len: AtomicUsize::new(0),
        }
    }

    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    /// Exact occupancy (relaxed; includes pushes that reserved room
    /// but have not finished writing their slot yet).
    pub(crate) fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking push; returns the value when the queue is at
    /// capacity (backpressure — the caller decides to shed or park).
    pub(crate) fn try_push(&self, v: T) -> Result<(), T> {
        // Reserve occupancy first: after this CAS there are at most
        // `capacity` items outstanding (queued, mid-push, or mid-pop),
        // which guarantees the slot claimed below drains.
        let mut n = self.len.load(Ordering::Relaxed);
        loop {
            if n >= self.capacity {
                return Err(v);
            }
            match self.len.compare_exchange_weak(n, n + 1, Ordering::Acquire, Ordering::Relaxed) {
                Ok(_) => break,
                Err(cur) => n = cur,
            }
        }
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                // Slot free on this lap: claim the position.
                match self.tail.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.val.get()).write(v) };
                        slot.seq.store(pos.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(cur) => pos = cur,
                }
            } else if diff < 0 {
                // The previous lap's consumer is still clearing this
                // slot; the occupancy reservation guarantees it
                // finishes, so spin rather than fail.
                std::hint::spin_loop();
                pos = self.tail.load(Ordering::Relaxed);
            } else {
                // Another producer claimed `pos`; chase the tail.
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Non-blocking pop. `None` means empty *now* — possibly while a
    /// racing producer that already reserved occupancy is mid-write;
    /// parked callers are re-woken by that producer's notify, so the
    /// bounded retry below never loses an item.
    pub(crate) fn try_pop(&self) -> Option<T> {
        let mut spins = 0;
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos.wrapping_add(1) as isize;
            if diff == 0 {
                // Slot published on this lap: claim it.
                match self.head.compare_exchange_weak(
                    pos,
                    pos.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq.store(pos.wrapping_add(self.slots.len()), Ordering::Release);
                        self.len.fetch_sub(1, Ordering::Release);
                        return Some(v);
                    }
                    Err(cur) => pos = cur,
                }
            } else if diff < 0 {
                if self.len.load(Ordering::Acquire) == 0 {
                    return None; // drained
                }
                // A producer reserved room but has not published yet;
                // give it a short grace, then let the caller park.
                spins += 1;
                if spins > 64 {
                    return None;
                }
                std::hint::spin_loop();
                pos = self.head.load(Ordering::Relaxed);
            } else {
                // Another consumer claimed `pos`; chase the head.
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }
}

impl<T> Drop for ArrayQueue<T> {
    fn drop(&mut self) {
        // Exclusive access: no producer can be mid-write, so this
        // drains every remaining value.
        while self.try_pop().is_some() {}
    }
}

// ---------------------------------------------------------------------
// The disconnect-aware bounded channel (pipeline stage handoffs).
// ---------------------------------------------------------------------

struct ChanInner<T> {
    q: ArrayQueue<T>,
    /// Live sender endpoints; 0 = disconnected for the receiver.
    senders: AtomicUsize,
    /// Receiver endpoint still alive; false = disconnected for senders.
    recv_alive: AtomicBool,
    park: Mutex<()>,
    not_empty: Condvar,
    not_full: Condvar,
    pop_waiters: AtomicUsize,
    push_waiters: AtomicUsize,
}

impl<T> ChanInner<T> {
    fn park_lock(&self) -> MutexGuard<'_, ()> {
        self.park.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn wake_poppers(&self) {
        fence(Ordering::SeqCst);
        if self.pop_waiters.load(Ordering::Relaxed) > 0 {
            let _g = self.park_lock();
            self.not_empty.notify_all();
        }
    }

    fn wake_pushers(&self) {
        fence(Ordering::SeqCst);
        if self.push_waiters.load(Ordering::Relaxed) > 0 {
            let _g = self.park_lock();
            self.not_full.notify_all();
        }
    }
}

/// Sending half of a [`channel`]. Clonable; the channel disconnects
/// for the receiver when the last clone drops.
pub(crate) struct Sender<T> {
    inner: Arc<ChanInner<T>>,
}

/// Receiving half of a [`channel`]. Dropping it disconnects every
/// sender (their sends return the value back).
pub(crate) struct Receiver<T> {
    inner: Arc<ChanInner<T>>,
}

/// A bounded MPSC-style channel over the lock-free ring: `send` blocks
/// at capacity, `recv` blocks when empty, and both observe disconnect
/// exactly like `std::sync::mpsc::sync_channel` (which this replaces
/// on the pipeline's inter-stage hot path).
pub(crate) fn channel<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    let inner = Arc::new(ChanInner {
        q: ArrayQueue::new(capacity),
        senders: AtomicUsize::new(1),
        recv_alive: AtomicBool::new(true),
        park: Mutex::new(()),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        pop_waiters: AtomicUsize::new(0),
        push_waiters: AtomicUsize::new(0),
    });
    (Sender { inner: inner.clone() }, Receiver { inner })
}

impl<T> Sender<T> {
    /// Blocking send; `Err` returns the value when the receiver is
    /// gone (the pipeline's tear-down signal).
    pub(crate) fn send(&self, v: T) -> Result<(), T> {
        let inner = &*self.inner;
        let mut v = v;
        loop {
            if !inner.recv_alive.load(Ordering::SeqCst) {
                return Err(v);
            }
            match inner.q.try_push(v) {
                Ok(()) => {
                    inner.wake_poppers();
                    return Ok(());
                }
                Err(back) => v = back,
            }
            // Full: park until a pop frees room or the receiver drops.
            let mut g = inner.park_lock();
            inner.push_waiters.fetch_add(1, Ordering::Relaxed);
            fence(Ordering::SeqCst);
            let progress = !inner.recv_alive.load(Ordering::Relaxed)
                || inner.q.len() < inner.q.capacity();
            if !progress {
                g = inner.not_full.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            inner.push_waiters.fetch_sub(1, Ordering::Relaxed);
            drop(g);
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.senders.fetch_add(1, Ordering::SeqCst);
        Sender { inner: self.inner.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if self.inner.senders.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last sender: wake a receiver blocked on an empty queue so
            // it observes the disconnect.
            let _g = self.inner.park_lock();
            self.inner.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when every sender is gone *and* the
    /// queue has drained.
    pub(crate) fn recv(&self) -> Option<T> {
        let inner = &*self.inner;
        loop {
            if let Some(v) = inner.q.try_pop() {
                inner.wake_pushers();
                return Some(v);
            }
            if inner.senders.load(Ordering::SeqCst) == 0 {
                // No producer can publish after this point; one final
                // pop catches anything sent before the last drop.
                let v = inner.q.try_pop();
                if v.is_some() {
                    inner.wake_pushers();
                }
                return v;
            }
            let mut g = inner.park_lock();
            inner.pop_waiters.fetch_add(1, Ordering::Relaxed);
            fence(Ordering::SeqCst);
            let progress =
                !inner.q.is_empty() || inner.senders.load(Ordering::Relaxed) == 0;
            if !progress {
                g = inner.not_empty.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            inner.pop_waiters.fetch_sub(1, Ordering::Relaxed);
            drop(g);
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.inner.recv_alive.store(false, Ordering::SeqCst);
        let _g = self.inner.park_lock();
        self.inner.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn fifo_single_thread() {
        let q = ArrayQueue::new(4);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(q.try_pop(), Some(i));
        }
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn exact_capacity_bound_even_when_rounded() {
        // Logical capacity 3, ring rounds to 4 slots; the 4th push
        // must still be rejected.
        let q = ArrayQueue::new(3);
        assert_eq!(q.capacity(), 3);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.try_push(3).unwrap();
        assert_eq!(q.try_push(4), Err(4));
        assert_eq!(q.len(), 3);
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(4).unwrap();
        assert_eq!(q.try_push(5), Err(5));
    }

    #[test]
    fn wraparound_reuses_slots() {
        let q = ArrayQueue::new(2);
        for lap in 0..10 {
            q.try_push(2 * lap).unwrap();
            q.try_push(2 * lap + 1).unwrap();
            assert_eq!(q.try_pop(), Some(2 * lap));
            assert_eq!(q.try_pop(), Some(2 * lap + 1));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn drop_drains_remaining_items() {
        let hits = Arc::new(AtomicU64::new(0));
        struct Tick(Arc<AtomicU64>);
        impl Drop for Tick {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let q = ArrayQueue::new(8);
        for _ in 0..5 {
            assert!(q.try_push(Tick(hits.clone())).is_ok());
        }
        drop(q.try_pop()); // one popped + dropped
        drop(q); // four drained
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn mpmc_concurrent_sum_preserved() {
        const PRODUCERS: u64 = 4;
        const PER: u64 = 500;
        let q = Arc::new(ArrayQueue::new(8));
        let sum = Arc::new(AtomicU64::new(0));
        let taken = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for p in 0..PRODUCERS {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..PER {
                        let mut v = p * PER + i + 1;
                        loop {
                            match q.try_push(v) {
                                Ok(()) => break,
                                Err(back) => {
                                    v = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                });
            }
            for _ in 0..2 {
                let q = q.clone();
                let sum = sum.clone();
                let taken = taken.clone();
                s.spawn(move || loop {
                    match q.try_pop() {
                        Some(v) => {
                            sum.fetch_add(v, Ordering::Relaxed);
                            taken.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            if taken.load(Ordering::Relaxed) == PRODUCERS * PER {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        let n = PRODUCERS * PER;
        assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
    }

    #[test]
    fn channel_fifo_and_disconnect() {
        let (tx, rx) = channel::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None); // all senders gone, drained
    }

    #[test]
    fn channel_send_fails_after_receiver_drop() {
        let (tx, rx) = channel::<u32>(1);
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }

    #[test]
    fn channel_blocking_send_recv_across_threads() {
        let (tx, rx) = channel::<u64>(1);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(i).unwrap(); // blocks at capacity 1
            }
        });
        let mut got = Vec::new();
        while let Some(v) = rx.recv() {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn channel_clone_counts_senders() {
        let (tx, rx) = channel::<u32>(4);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(9).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Some(9));
        assert_eq!(rx.recv(), None);
    }
}
