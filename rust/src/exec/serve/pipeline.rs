//! Graph-level pipeline parallelism: one model split across pool
//! replicas, stage-per-replica, with multiple requests in flight.
//!
//! The paper's task-ISA keeps heterogeneous *modules* busy through
//! explicit pipeline parallelism (§2.3); this module lifts the same
//! idea one level up, to the serving pool: the ASAP levels of
//! [`crate::graph::stages`] are grouped into `K` **contiguous pipeline
//! stages**, each stage is owned by one pool replica, and the only
//! cross-device traffic is the stage-boundary tensor set handed off
//! through DRAM. With `M` requests streaming through, the pipelined
//! makespan approaches `max(stage)` per request instead of
//! `sum(stages)` — pool depth now buys *latency* on one model, not
//! just throughput across models, and a model whose resident plans
//! exceed one replica's DRAM becomes servable by splitting.
//!
//! Three layers, mirroring the pool scheduler's discipline split:
//!
//! * [`PipelinePartition`] — the stage partitioner. Levels are grouped
//!   by a dynamic program minimizing the *maximum* per-stage cost
//!   under the same roofline cost model the fleet router ranks
//!   variants with ([`node_model_cycles`]); the boundary live sets
//!   (`consumes` / `carries`) are computed exactly, so every stage
//!   knows precisely which tensors it must receive and forward.
//! * [`PipelineScheduler`] — the **simulated-time** discipline and the
//!   deterministic oracle: per-stage replicas with **independent**
//!   plan caches (each stage compiles only its own subgraph's plans —
//!   the plan-key space is partitioned by construction, so nothing is
//!   replicated pool-wide), the classic pipeline recurrence
//!   `finish[r][k] = max(handoff[r][k-1], finish[r-1][k]) + dur[r][k]`
//!   for modeled time, and per-stage occupancy / handoff counters.
//! * [`run_pipeline_threaded`] — the **real-threads** discipline: one
//!   OS worker per stage, linked by bounded channels carrying the
//!   boundary tensors; shutdown cascades by dropping senders. Workers
//!   execute through the same stage-restricted walker
//!   ([`run_graph_partial`](super::run)) over per-stage [`PlanCache`]s
//!   driven in the same FIFO order as the simulated oracle, so outputs
//!   *and* per-stage cache counters are bit-identical to it.

use super::super::executor::{lift_compile_err, CpuBackend, ExecError, NodeReport};
use super::cache::{PlanCache, PlanCacheStats, PlanKey};
use super::fleet::node_model_cycles;
use super::queue;
use super::run::{plan_keys_for, run_graph_partial, tuned_schedules_for, VtaNodeExec};
use crate::arch::VtaConfig;
use crate::compiler::op::{config_fingerprint, execute_compiled, op_impl};
use crate::compiler::ScheduleChoice;
use crate::dse::records::TuningRecords;
use crate::graph::{node_stages, stages, Graph, NodeId};
use crate::metrics::{PipelineMetrics, StageCounter};
use crate::runtime::{DevicePool, VtaRuntime};
use crate::sim::SimStats;
use crate::util::Tensor;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// The stage partitioner.
// ---------------------------------------------------------------------

/// One pipeline stage: a contiguous run of ASAP levels, owned by one
/// pool replica.
#[derive(Clone, Debug)]
pub struct PipelineStage {
    /// Stage index (replica index).
    pub index: usize,
    /// Half-open ASAP-level range `[lo, hi)` this stage owns.
    pub levels: (usize, usize),
    /// Node ids executed here, in dependence order.
    pub nodes: Vec<NodeId>,
    /// The stage's slice of the ASAP levels (the `level_order` the
    /// stage-restricted walker executes).
    pub level_order: Vec<Vec<NodeId>>,
    /// Live tensors this stage must *receive* from upstream: every
    /// value produced before `lo` that a node at level ≥ `lo` reads.
    /// Empty for stage 0.
    pub consumes: Vec<NodeId>,
    /// Live tensors this stage must *forward* downstream: every value
    /// produced before `hi` that a node at level ≥ `hi` reads (plus
    /// the graph output, which must reach the last stage). Includes
    /// pass-through values this stage merely relays. Empty for the
    /// last stage.
    pub carries: Vec<NodeId>,
    /// Roofline-modeled cycles of this stage's nodes
    /// ([`node_model_cycles`] summed over the stage).
    pub model_cycles: u64,
    /// [`Self::model_cycles`] in seconds of the config's clock.
    pub model_seconds: f64,
    /// Bytes handed off downstream per request (int8: one byte per
    /// element of every carried tensor).
    pub handoff_bytes: u64,
    /// Modeled seconds of the downstream DRAM handoff (store on the
    /// producer + load on the consumer through the shared port).
    pub handoff_seconds: f64,
}

/// A whole-graph pipeline split: contiguous stage ranges covering
/// every ASAP level, with exact boundary live sets.
#[derive(Clone, Debug)]
pub struct PipelinePartition {
    /// The stages, in pipeline order.
    pub stages: Vec<PipelineStage>,
}

impl PipelinePartition {
    /// Balance the graph's ASAP levels into (at most) `k` contiguous
    /// stages, minimizing the maximum roofline-modeled stage cost —
    /// the same cost model the fleet [`Router`](super::fleet::Router)
    /// ranks variants with, applied per stage. `k` clamps to the
    /// number of levels (a stage needs at least one level).
    pub fn balanced(cfg: &VtaConfig, g: &Graph, k: usize) -> Self {
        assert!(k >= 1, "a pipeline needs at least one stage");
        let level_order = stages(g);
        let nlevels = level_order.len().max(1);
        let k = k.min(nlevels);

        // Per-level roofline cost (every node: CPU-resident nodes go
        // through the same model — the balancer weighs *work*, and an
        // all-CPU stage must not look free).
        let cost: Vec<u64> = level_order
            .iter()
            .map(|lv| {
                lv.iter().map(|&id| node_model_cycles(cfg, g, &g.nodes[id])).sum::<u64>()
            })
            .collect();
        let mut prefix = vec![0u64; nlevels + 1];
        for (l, &c) in cost.iter().enumerate() {
            prefix[l + 1] = prefix[l].saturating_add(c);
        }
        let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // cost of levels [a, b)

        // DP over (stages used, levels covered): best[j][i] = minimal
        // achievable max-stage-cost splitting the first `i` levels into
        // `j` contiguous stages. O(K·L²) — L is graph depth, tiny.
        let mut best = vec![vec![u64::MAX; nlevels + 1]; k + 1];
        let mut cut = vec![vec![0usize; nlevels + 1]; k + 1];
        for i in 1..=nlevels {
            best[1][i] = seg(0, i);
        }
        for j in 2..=k {
            for i in j..=nlevels {
                for c in (j - 1)..i {
                    let m = best[j - 1][c].max(seg(c, i));
                    if m < best[j][i] {
                        best[j][i] = m;
                        cut[j][i] = c;
                    }
                }
            }
        }
        let mut cuts = Vec::with_capacity(k - 1);
        let mut i = nlevels;
        for j in (2..=k).rev() {
            let c = cut[j][i];
            cuts.push(c);
            i = c;
        }
        cuts.reverse();
        Self::from_cuts(cfg, g, &cuts)
    }

    /// Build a partition from explicit interior level boundaries:
    /// `cuts` must be strictly increasing, each in `1..levels`; stage
    /// `s` owns levels `[cuts[s-1], cuts[s])` (with 0 and the level
    /// count as the outer bounds). An empty `cuts` is the trivial
    /// 1-stage pipeline. Exposed so tests (and ablations) can pit a
    /// deliberately unbalanced split against [`Self::balanced`].
    pub fn from_cuts(cfg: &VtaConfig, g: &Graph, cuts: &[usize]) -> Self {
        let level_order = stages(g);
        let nlevels = level_order.len();
        let mut bounds = Vec::with_capacity(cuts.len() + 2);
        bounds.push(0usize);
        bounds.extend_from_slice(cuts);
        bounds.push(nlevels);
        for w in bounds.windows(2) {
            assert!(w[0] < w[1], "pipeline cuts must be strictly increasing level bounds");
        }
        assert!(*bounds.last().unwrap() == nlevels, "cuts must lie inside the level range");

        let lvl = node_stages(g);
        let out_id = g.output().expect("non-empty graph");
        // live(c) = values produced below cut `c` still needed at or
        // above it. The graph output gets a virtual consumer past the
        // last level so it always reaches the final stage.
        let live_at = |c: usize| -> Vec<NodeId> {
            let mut live: Vec<NodeId> = g
                .nodes
                .iter()
                .filter(|n| {
                    lvl[n.id] < c
                        && (n.id == out_id
                            || g.nodes.iter().any(|m| lvl[m.id] >= c && m.inputs.contains(&n.id)))
                })
                .map(|n| n.id)
                .collect();
            live.sort_unstable();
            live
        };

        let nstages = bounds.len() - 1;
        let stages = (0..nstages)
            .map(|s| {
                let (lo, hi) = (bounds[s], bounds[s + 1]);
                let slice = &level_order[lo..hi];
                let nodes: Vec<NodeId> = slice.iter().flatten().copied().collect();
                let consumes = if s == 0 { Vec::new() } else { live_at(lo) };
                let carries = if s + 1 == nstages { Vec::new() } else { live_at(hi) };
                let handoff_bytes: u64 = carries
                    .iter()
                    .map(|&id| g.nodes[id].shape.iter().product::<usize>() as u64)
                    .sum();
                let model_cycles: u64 =
                    nodes.iter().map(|&id| node_model_cycles(cfg, g, &g.nodes[id])).sum();
                // Handoff: the boundary set is stored by the producer
                // and loaded by the consumer through the DRAM port.
                let handoff_cycles = if carries.is_empty() {
                    0.0
                } else {
                    (handoff_bytes as f64 / cfg.dram.bytes_per_cycle).ceil()
                        + 2.0 * cfg.dram.latency as f64
                };
                PipelineStage {
                    index: s,
                    levels: (lo, hi),
                    nodes,
                    level_order: slice.to_vec(),
                    consumes,
                    carries,
                    model_cycles,
                    model_seconds: model_cycles as f64 / cfg.clock_hz,
                    handoff_bytes,
                    handoff_seconds: handoff_cycles / cfg.clock_hz,
                }
            })
            .collect();
        PipelinePartition { stages }
    }

    /// Stage count.
    pub fn len(&self) -> usize {
        self.stages.len()
    }

    /// True for the degenerate 1-stage pipeline.
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// The maximum roofline-modeled stage time — the pipeline's
    /// steady-state bottleneck (what per-request *throughput* tends to
    /// as the in-flight window deepens).
    pub fn bottleneck_seconds(&self) -> f64 {
        self.stages.iter().map(|s| s.model_seconds).fold(0.0, f64::max)
    }

    /// Roofline-modeled makespan of streaming `requests` requests
    /// through the pipeline (all arriving at t = 0): the classic
    /// recurrence — a stage starts request `r` when the request's
    /// handoff lands *and* the stage finished request `r-1`. Purely
    /// analytical (no measured durations), so it is deterministic; the
    /// balancer-beats-unbalanced assertions compare partitions on it.
    pub fn modeled_makespan(&self, requests: usize) -> f64 {
        let k = self.stages.len();
        if k == 0 || requests == 0 {
            return 0.0;
        }
        let mut prev = vec![0.0f64; k]; // finish[r-1][*]
        for _ in 0..requests {
            let mut cur = vec![0.0f64; k];
            for (s, stage) in self.stages.iter().enumerate() {
                let arrive = if s == 0 {
                    0.0
                } else {
                    cur[s - 1] + self.stages[s - 1].handoff_seconds
                };
                cur[s] = arrive.max(prev[s]) + stage.model_seconds;
            }
            prev = cur;
        }
        prev[k - 1]
    }

    /// One-line description per stage (CLI / bench reporting).
    pub fn describe(&self) -> Vec<String> {
        self.stages
            .iter()
            .map(|s| {
                format!(
                    "stage {}: levels {}..{}, {} node(s), modeled {:.2} ms, \
                     handoff {} tensor(s) / {} B",
                    s.index,
                    s.levels.0,
                    s.levels.1,
                    s.nodes.len(),
                    s.model_seconds * 1e3,
                    s.carries.len(),
                    s.handoff_bytes
                )
            })
            .collect()
    }
}

/// Assemble the live-out handoff of `stage` from the stage's value
/// table: carried tensors were either produced here or passed through
/// from the incoming handoff (both are `Some` in `values`).
fn carry_out(
    stage: &PipelineStage,
    values: &mut [Option<Tensor<i8>>],
) -> HashMap<NodeId, Tensor<i8>> {
    stage
        .carries
        .iter()
        .map(|&id| (id, values[id].take().expect("carried value produced or seeded")))
        .collect()
}

/// Stage duration charged to the owning replica: host wall plus
/// simulated accelerator time of every node executed (the same
/// accounting [`pipeline_schedule`](super::pipeline_schedule) uses per
/// node).
fn stage_duration(stage: &PipelineStage, reports: &[Option<NodeReport>]) -> (f64, u64) {
    let mut secs = 0.0;
    let mut cycles = 0u64;
    for &id in &stage.nodes {
        let r = reports[id].as_ref().expect("stage nodes executed");
        secs += r.wall.as_secs_f64() + r.sim_seconds;
        cycles += r.stats.as_ref().map(|s| s.total_cycles).unwrap_or(0);
    }
    (secs, cycles)
}

// ---------------------------------------------------------------------
// The simulated-time pipeline scheduler (the deterministic oracle).
// ---------------------------------------------------------------------

/// Knobs shared by both pipeline disciplines.
#[derive(Clone, Debug)]
pub struct PipelineOptions {
    /// Pipeline stages (= pool replicas, = worker threads).
    pub stages: usize,
    /// Plan-cache capacity per stage. Per-stage caches are
    /// **independent**, not lockstep: each stage compiles only its own
    /// subgraph's plans, so the [`PlanKey`] space is partitioned
    /// across stages by construction.
    pub cache_capacity: usize,
    /// Virtual threads VTA nodes are lowered with, ∈ {1, 2}.
    pub virtual_threads: usize,
    /// Device DRAM bytes per replica.
    pub dram_size: usize,
    /// Bounded inter-stage queue depth (threaded discipline): how many
    /// handoffs may wait between adjacent stages — the in-flight
    /// window that lets the pipeline fill.
    pub queue_capacity: usize,
}

impl PipelineOptions {
    /// Defaults for a `stages`-deep pipeline.
    pub fn new(stages: usize) -> Self {
        PipelineOptions {
            stages: stages.max(1),
            cache_capacity: 64,
            virtual_threads: 2,
            dram_size: 256 << 20,
            queue_capacity: 4,
        }
    }
}

/// Outcome of streaming a request trace through the pipeline
/// (simulated discipline).
#[derive(Debug)]
pub struct PipelineReport {
    /// Per-request outputs, in submission order — bit-identical to the
    /// single-replica engine's.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request modeled completion times (all arrivals at t = 0).
    pub completions: Vec<f64>,
    /// Modeled end of the stream: the last stage's last finish.
    pub makespan_seconds: f64,
    /// Per-stage plan-cache counters for this run (independent caches;
    /// the threaded discipline must land on identical values).
    pub cache: Vec<PlanCacheStats>,
    /// Per-stage occupancy / handoff counters.
    pub metrics: PipelineMetrics,
    /// Real host wall time of the drain.
    pub host_wall: Duration,
}

impl PipelineReport {
    /// Requests per modeled second over the stream.
    pub fn throughput(&self) -> f64 {
        if self.makespan_seconds > 0.0 {
            self.outputs.len() as f64 / self.makespan_seconds
        } else {
            0.0
        }
    }
}

/// The simulated-time pipeline runtime: `K` replicas, one stage each,
/// independent per-stage plan caches, modeled pipeline timing. Serves
/// as the deterministic oracle for [`run_pipeline_threaded`].
pub struct PipelineScheduler {
    pool: DevicePool,
    caches: Vec<PlanCache>,
    cpu: CpuBackend,
    opts: PipelineOptions,
    config_fp: u64,
    records: TuningRecords,
}

impl PipelineScheduler {
    /// Build over `opts.stages` fresh replicas of `cfg`.
    pub fn new(cfg: &VtaConfig, cpu: CpuBackend, opts: PipelineOptions) -> Self {
        Self::with_records(cfg, cpu, opts, TuningRecords::new())
    }

    /// Like [`Self::new`], seeded with a `vta dse` tuning-record store.
    pub fn with_records(
        cfg: &VtaConfig,
        cpu: CpuBackend,
        opts: PipelineOptions,
        records: TuningRecords,
    ) -> Self {
        assert!(
            opts.virtual_threads == 1 || opts.virtual_threads == 2,
            "1 or 2 virtual threads"
        );
        let pool = DevicePool::new(cfg, opts.dram_size, opts.stages.max(1));
        let caches = (0..opts.stages.max(1)).map(|_| PlanCache::new(opts.cache_capacity)).collect();
        PipelineScheduler {
            pool,
            caches,
            cpu,
            opts,
            config_fp: config_fingerprint(cfg),
            records,
        }
    }

    /// Stage count (= replicas).
    pub fn stages(&self) -> usize {
        self.pool.len()
    }

    /// Per-stage cumulative plan-cache counters.
    pub fn cache_stats(&self) -> Vec<PlanCacheStats> {
        self.caches.iter().map(|c| c.stats()).collect()
    }

    /// Stream `inputs` through the pipeline described by `partition`
    /// (which must have exactly [`Self::stages`] stages): every
    /// request's stage `k` executes on replica `k`, handoffs carry the
    /// exact boundary live set, and modeled times follow the pipeline
    /// recurrence. Outputs are bit-identical to the single-replica
    /// engine — execution is exact, only timing is modeled.
    pub fn run(
        &mut self,
        g: &Graph,
        partition: &PipelinePartition,
        inputs: &[Tensor<i8>],
    ) -> Result<PipelineReport, ExecError> {
        assert_eq!(
            partition.stages.len(),
            self.pool.len(),
            "partition stage count must match the pipeline pool"
        );
        let t0 = Instant::now();
        let k = partition.stages.len();
        let vt = self.opts.virtual_threads;
        let keys = plan_keys_for(self.config_fp, vt, g);
        let schedules = tuned_schedules_for(&self.records, self.config_fp, vt, g);
        let stats0 = self.cache_stats();
        let mut metrics = PipelineMetrics::new(k);
        for (counter, stage) in metrics.stages.iter_mut().zip(&partition.stages) {
            counter.nodes = stage.nodes.len() as u64;
        }

        let mut outputs = Vec::with_capacity(inputs.len());
        let mut dur = vec![vec![0.0f64; k]; inputs.len()];
        // Requests flow in order; stage k therefore sees the same FIFO
        // request sequence as a threaded stage worker — per-stage cache
        // counter equality with the threaded discipline is by
        // construction.
        for (r, input) in inputs.iter().enumerate() {
            let mut live: HashMap<NodeId, Tensor<i8>> = HashMap::new();
            for (s, stage) in partition.stages.iter().enumerate() {
                let (mut values, reports) = run_graph_partial(
                    &mut StageRun { sched: &mut *self, stage: s },
                    g,
                    (s == 0).then_some(input),
                    &stage.level_order,
                    &keys,
                    &schedules,
                    &live,
                )?;
                let (secs, cycles) = stage_duration(stage, &reports);
                dur[r][s] = secs;
                metrics.stages[s].record_request(
                    secs,
                    cycles,
                    stage.carries.len() as u64,
                    stage.handoff_bytes,
                );
                if s + 1 == k {
                    let out_id = g.output().expect("non-empty graph");
                    outputs.push(values[out_id].take().expect("output produced or carried"));
                } else {
                    live = carry_out(stage, &mut values);
                }
            }
        }

        // Modeled pipeline timing over the measured durations.
        let mut completions = vec![0.0f64; inputs.len()];
        let mut prev = vec![0.0f64; k];
        for (r, d) in dur.iter().enumerate() {
            let mut cur = vec![0.0f64; k];
            for s in 0..k {
                let arrive = if s == 0 {
                    0.0
                } else {
                    cur[s - 1] + partition.stages[s - 1].handoff_seconds
                };
                cur[s] = arrive.max(prev[s]) + d[s];
            }
            completions[r] = cur[k - 1];
            prev = cur;
        }
        let makespan = prev.last().copied().unwrap_or(0.0);

        let stats1 = self.cache_stats();
        let cache = stats0
            .iter()
            .zip(&stats1)
            .map(|(a, b)| PlanCacheStats {
                hits: b.hits - a.hits,
                misses: b.misses - a.misses,
                evictions: b.evictions - a.evictions,
            })
            .collect();
        Ok(PipelineReport {
            outputs,
            completions,
            makespan_seconds: makespan,
            cache,
            metrics,
            host_wall: t0.elapsed(),
        })
    }
}

/// One stage's device view: the scheduler plus the replica that owns
/// the stage — the pipeline's side of the shared graph walker. VTA
/// nodes go through the stage's own (independent) plan cache and
/// execute on the stage's replica.
struct StageRun<'a> {
    sched: &'a mut PipelineScheduler,
    stage: usize,
}

impl VtaNodeExec for StageRun<'_> {
    fn clock_hz(&self) -> f64 {
        self.sched.pool.config().clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.sched.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        let node = &g.nodes[id];
        let entry = op_impl(&node.op);
        let vt = self.sched.opts.virtual_threads;
        // Split borrows: the stage's cache and the stage's replica are
        // disjoint fields of the scheduler.
        let PipelineScheduler { pool, caches, .. } = &mut *self.sched;
        let rt = pool.device_mut(self.stage);
        let compiled = caches[self.stage].get_or_compile(rt, key, |rt| {
            entry
                .compile(rt, g, node, vt, schedule.as_ref())
                .map_err(|e| lift_compile_err(&node.name, e))
        })?;
        execute_compiled(entry, compiled, rt, inputs).map_err(|e| lift_compile_err(&node.name, e))
    }
}

// ---------------------------------------------------------------------
// The real-threads pipeline runtime.
// ---------------------------------------------------------------------

/// One request's handoff between adjacent stage workers: the boundary
/// live set (or the first error, which passes through untouched so the
/// pipeline drains instead of deadlocking).
type InterMsg = (usize, Instant, Result<HashMap<NodeId, Tensor<i8>>, ExecError>);

/// A finished request leaving the last stage: id, end-to-end wall
/// latency (submit → final stage, stamped at completion), and the
/// output or the first error it hit.
type DoneMsg = (usize, Duration, Result<Tensor<i8>, ExecError>);

/// Final report of one threaded pipeline run.
#[derive(Debug)]
pub struct PipelineThreadedReport {
    /// Per-request outputs, in submission order — the vector compared
    /// bit-for-bit against the simulated oracle's.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request end-to-end wall latency (submit → final stage).
    pub latencies: Vec<Duration>,
    /// Per-stage plan-cache counters (must equal the oracle's).
    pub cache: Vec<PlanCacheStats>,
    /// Per-stage occupancy / handoff counters (`busy_seconds` is
    /// measured wall here; the deterministic fields — requests,
    /// sim_cycles, handoff — must equal the oracle's).
    pub metrics: PipelineMetrics,
    /// Wall-clock span of the whole run (spawn → drained).
    pub wall: Duration,
}

impl PipelineThreadedReport {
    /// Measured throughput: requests over the run's wall span.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.outputs.len() as f64 / secs
        }
    }
}

/// A stage worker's executor: its replica, its own [`PlanCache`]
/// (independent per stage — same capacity and FIFO lookup order as the
/// simulated oracle's, so the counters match exactly), and a CPU
/// backend.
struct StageExec<'rt> {
    rt: &'rt mut VtaRuntime,
    cache: PlanCache,
    cpu: CpuBackend,
    virtual_threads: usize,
    clock_hz: f64,
}

impl VtaNodeExec for StageExec<'_> {
    fn clock_hz(&self) -> f64 {
        self.clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        let node = &g.nodes[id];
        let entry = op_impl(&node.op);
        let vt = self.virtual_threads;
        let rt = &mut *self.rt;
        let compiled = self.cache.get_or_compile(rt, key, |rt| {
            entry
                .compile(rt, g, node, vt, schedule.as_ref())
                .map_err(|e| lift_compile_err(&node.name, e))
        })?;
        execute_compiled(entry, compiled, rt, inputs).map_err(|e| lift_compile_err(&node.name, e))
    }
}

/// Everything a stage worker borrows from the run (shared, read-only).
struct PipelineShared<'a> {
    g: &'a Graph,
    partition: &'a PipelinePartition,
    keys: &'a [Option<PlanKey>],
    schedules: &'a [Option<ScheduleChoice>],
    virtual_threads: usize,
    cache_capacity: usize,
    clock_hz: f64,
}

/// The body shared by every stage worker: pull a handoff, execute the
/// stage, forward the next handoff (or the final value table to the
/// completion channel). Errors pass through without executing, so a
/// failed request drains the whole pipe instead of wedging it.
#[allow(clippy::too_many_arguments)]
fn stage_worker(
    stage_idx: usize,
    rt: &mut VtaRuntime,
    shared: &PipelineShared<'_>,
    rx: queue::Receiver<InterMsg>,
    tx_next: Option<queue::Sender<InterMsg>>,
    tx_done: Option<mpsc::Sender<DoneMsg>>,
) -> (StageCounter, PlanCacheStats) {
    let stage = &shared.partition.stages[stage_idx];
    let mut ex = StageExec {
        rt,
        cache: PlanCache::new(shared.cache_capacity),
        cpu: CpuBackend::Native,
        virtual_threads: shared.virtual_threads,
        clock_hz: shared.clock_hz,
    };
    let mut counter = StageCounter { nodes: stage.nodes.len() as u64, ..Default::default() };
    while let Some((req, submitted, payload)) = rx.recv() {
        let t0 = Instant::now();
        let outcome: Result<(Vec<Option<Tensor<i8>>>, u64), ExecError> =
            payload.and_then(|live| {
                let (values, reports) = run_graph_partial(
                    &mut ex,
                    shared.g,
                    // Input nodes live at level 0, so only stage 0 ever
                    // executes one; the driver seeds the request tensor
                    // as a live value keyed by the input node id.
                    None,
                    &stage.level_order,
                    shared.keys,
                    shared.schedules,
                    &live,
                )?;
                let (_, cycles) = stage_duration(stage, &reports);
                Ok((values, cycles))
            });
        let cycles = outcome.as_ref().map(|(_, c)| *c).unwrap_or(0);
        counter.record_request(
            t0.elapsed().as_secs_f64(),
            cycles,
            stage.carries.len() as u64,
            stage.handoff_bytes,
        );
        if let Some(tx) = &tx_next {
            // Interior stage: forward the live set — or the error,
            // untouched, so a failed request drains the pipe.
            let msg = outcome.map(|(mut values, _)| carry_out(stage, &mut values));
            if tx.send((req, submitted, msg)).is_err() {
                break; // downstream gone: the run is tearing down
            }
        } else {
            let tx = tx_done.as_ref().expect("last stage completes");
            let out = outcome.map(|(mut values, _)| {
                let out_id = shared.g.output().expect("non-empty graph");
                values[out_id].take().expect("output produced or carried")
            });
            if tx.send((req, submitted.elapsed(), out)).is_err() {
                break;
            }
        }
    }
    let stats = ex.cache.stats();
    (counter, stats)
}

/// Run the threaded pipeline: one OS worker per stage over `K`
/// replicas, adjacent stages linked by **bounded** channels
/// ([`PipelineOptions::queue_capacity`]) carrying the boundary live
/// set, multiple requests in flight (the driver keeps feeding while
/// every stage works its own request). Shutdown cascades: the driver
/// drops the first sender after the last request, each worker exits
/// when its upstream disconnects and drops its own sender in turn.
///
/// Outputs and per-stage cache counters are bit-identical to
/// [`PipelineScheduler::run`] on the same trace — the determinism
/// suite asserts it.
pub fn run_pipeline_threaded(
    cfg: &VtaConfig,
    opts: &PipelineOptions,
    records: &TuningRecords,
    g: &Graph,
    partition: &PipelinePartition,
    inputs: &[Tensor<i8>],
) -> Result<PipelineThreadedReport, ExecError> {
    assert!(
        opts.virtual_threads == 1 || opts.virtual_threads == 2,
        "1 or 2 virtual threads"
    );
    let k = partition.stages.len();
    assert!(k >= 1, "a pipeline needs at least one stage");
    let t0 = Instant::now();
    let config_fp = config_fingerprint(cfg);
    let keys = plan_keys_for(config_fp, opts.virtual_threads, g);
    let schedules = tuned_schedules_for(records, config_fp, opts.virtual_threads, g);
    let mut pool = DevicePool::new(cfg, opts.dram_size, k);
    let shared = PipelineShared {
        g,
        partition,
        keys: &keys,
        schedules: &schedules,
        virtual_threads: opts.virtual_threads,
        cache_capacity: opts.cache_capacity,
        clock_hz: cfg.clock_hz,
    };
    let cap = opts.queue_capacity.max(1);

    // Stage channels: tx[s] feeds stage s; the driver owns tx[0]. The
    // hot per-request handoffs ride the lock-free bounded channel of
    // [`super::queue`]; only the low-rate completion stream below
    // stays on `mpsc`.
    let mut txs = Vec::with_capacity(k);
    let mut rxs = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = queue::channel::<InterMsg>(cap);
        txs.push(tx);
        rxs.push(rx);
    }
    let (tx_done, rx_done) = mpsc::channel::<DoneMsg>();

    let in_id = g
        .nodes
        .iter()
        .find(|n| op_impl(&n.op).is_input())
        .map(|n| n.id)
        .expect("graph has an input node");

    let (mut per_stage, results) = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(k);
        // Give each worker its receiver and the *next* stage's sender;
        // the last stage gets the completion sender instead.
        let mut rx_iter = rxs.into_iter();
        for (s, rt) in pool.iter_mut().enumerate() {
            let rx = rx_iter.next().expect("one receiver per stage");
            let tx_next = if s + 1 < k { Some(txs[s + 1].clone()) } else { None };
            let tx_done = (s + 1 == k).then(|| tx_done.clone());
            let shared = &shared;
            joins.push(scope.spawn(move || stage_worker(s, rt, shared, rx, tx_next, tx_done)));
        }
        // The workers hold clones of the interior senders; drop the
        // originals so each channel closes when its upstream worker
        // exits.
        let tx0 = txs.remove(0);
        drop(txs);
        drop(tx_done);

        // Drive: feed every request into stage 0 (bounded — blocks
        // when the pipe is full, the in-flight window), draining
        // completions opportunistically so the result channel stays
        // short.
        let mut results: Vec<Option<(Duration, Result<Tensor<i8>, ExecError>)>> =
            (0..inputs.len()).map(|_| None).collect();
        for (req, input) in inputs.iter().enumerate() {
            let live: HashMap<NodeId, Tensor<i8>> =
                std::iter::once((in_id, input.clone())).collect();
            if tx0.send((req, Instant::now(), Ok(live))).is_err() {
                break; // stage 0 died; the join below repropagates
            }
            while let Ok((id, latency, out)) = rx_done.try_recv() {
                results[id] = Some((latency, out));
            }
        }
        drop(tx0); // begin the shutdown cascade
        while let Ok((id, latency, out)) = rx_done.recv() {
            results[id] = Some((latency, out));
        }

        let mut per_stage = Vec::with_capacity(k);
        for join in joins {
            match join.join() {
                Ok(pair) => per_stage.push(pair),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        (per_stage, results)
    });

    let metrics = PipelineMetrics {
        stages: per_stage.iter_mut().map(|(c, _)| std::mem::take(c)).collect(),
    };
    let cache = per_stage.into_iter().map(|(_, s)| s).collect();
    let mut outputs = Vec::with_capacity(inputs.len());
    let mut latencies = Vec::with_capacity(inputs.len());
    for slot in results {
        let (latency, out) = slot.expect("every request completed or errored");
        outputs.push(out?);
        latencies.push(latency);
    }
    Ok(PipelineThreadedReport {
        outputs,
        latencies,
        cache,
        metrics,
        wall: t0.elapsed(),
    })
}
