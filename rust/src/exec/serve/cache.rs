//! The plan cache: keys and the LRU of compiled plans.
//!
//! The §3.2 micro-kernel cache, extended to whole-node plans
//! (instruction streams + packed constants + DRAM residency) of any
//! registered operator. Besides the closure-driven single-device path
//! ([`PlanCache::get_or_compile`]), the cache exposes a decomposed
//! touch / note-miss / make-room / insert API (crate-private) that the
//! multi-device scheduler uses to drive one cache **per pool replica
//! in lockstep**: identical lookup and eviction sequences keep every
//! replica's DRAM allocator history identical, which is what lets a
//! plan compiled on one device byte-replicate onto the others
//! ([`crate::compiler::CompiledNode::replicate_to`]).
//!
//! The pipeline scheduler ([`super::pipeline`]) instead runs one fully
//! **independent** cache per stage: each graph node executes on exactly
//! one stage, so the [`PlanKey`] space partitions across the stages by
//! construction — no key is ever looked up on two stages, no plan is
//! shared or replicated between them, and the per-stage (hits, misses)
//! counters sum to exactly what a single-replica engine would count on
//! the whole graph.

use super::super::executor::ExecError;
use crate::compiler::op::op_impl;
use crate::compiler::CompiledNode;
use crate::graph::{Graph, Node};
use crate::runtime::VtaRuntime;
use std::collections::HashMap;

/// Key of one compiled plan: everything the lowered artifact depends
/// on. Two graph nodes with identical params *and* identical constants
/// legitimately share a plan; identical params with different weights
/// do not (the weight image is DRAM-resident inside the plan).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Hardware variant fingerprint
    /// ([`config_fingerprint`](super::config_fingerprint)).
    pub config_fp: u64,
    /// Virtual-thread count the plan was lowered with.
    pub virtual_threads: usize,
    /// Operator kind (the registry key).
    pub kind: &'static str,
    /// Operator fingerprint
    /// ([`VtaOp::fingerprint`](crate::compiler::VtaOp::fingerprint)):
    /// shape parameters + output shape + baked constants.
    pub op_fp: u64,
}

/// The plan key for `node` under a given config fingerprint and
/// virtual-thread count — shared by the single-device engine and the
/// pool scheduler so both always compute identical keys.
pub fn plan_key_for(config_fp: u64, virtual_threads: usize, g: &Graph, node: &Node) -> PlanKey {
    let entry = op_impl(&node.op);
    PlanKey { config_fp, virtual_threads, kind: entry.kind(), op_fp: entry.fingerprint(g, node) }
}

/// Cumulative plan-cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served by an already-compiled plan.
    pub hits: u64,
    /// Lookups that had to compile.
    pub misses: u64,
    /// Plans evicted (LRU) to make room.
    pub evictions: u64,
}

struct CacheEntry {
    node: CompiledNode,
    last_use: u64,
}

/// LRU cache of compiled plans — the §3.2 micro-kernel cache, extended
/// to whole-node plans (instruction streams + packed constants + DRAM
/// residency) of any registered operator.
pub struct PlanCache {
    capacity: usize,
    entries: HashMap<PlanKey, CacheEntry>,
    clock: u64,
    stats: PlanCacheStats,
    /// DRAM bytes held by resident plans, tracked incrementally on
    /// insert / evict / flush. Always equal to
    /// [`Self::recomputed_dram_bytes`] — the eviction-accounting
    /// regression tests assert it stays that way across
    /// evict → recompile cycles of the same key.
    resident_bytes: usize,
}

impl PlanCache {
    /// A cache holding at most `capacity` compiled plans.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "plan cache needs at least one slot");
        PlanCache {
            capacity,
            entries: HashMap::new(),
            clock: 0,
            stats: PlanCacheStats::default(),
            resident_bytes: 0,
        }
    }

    /// Cumulative counters.
    pub fn stats(&self) -> PlanCacheStats {
        self.stats
    }

    /// Number of resident plans.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plans are resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Maximum resident plans.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// True when `key` is resident (does not touch LRU state).
    pub fn contains(&self, key: &PlanKey) -> bool {
        self.entries.contains_key(key)
    }

    /// The resident plan for `key`, if any (does not touch LRU state;
    /// tests / introspection).
    pub fn peek(&self, key: &PlanKey) -> Option<&CompiledNode> {
        self.entries.get(key).map(|e| &e.node)
    }

    /// Resident plans per operator kind (reporting / tests).
    pub fn kinds(&self) -> HashMap<&'static str, usize> {
        let mut m = HashMap::new();
        for key in self.entries.keys() {
            *m.entry(key.kind).or_insert(0) += 1;
        }
        m
    }

    /// Total DRAM bytes held by resident plans (incrementally tracked;
    /// consistent across eviction + re-insert of the same key).
    pub fn dram_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// DRAM residency recomputed from scratch by summing every
    /// resident plan — the consistency oracle for [`Self::dram_bytes`]
    /// (tests / debugging; O(n) in resident plans).
    pub fn recomputed_dram_bytes(&self) -> usize {
        self.entries.values().map(|e| e.node.dram_bytes()).sum()
    }

    /// Hit path: if `key` is resident, bump its LRU position and the
    /// hit counter. Returns whether it was resident.
    pub(crate) fn touch(&mut self, key: &PlanKey) -> bool {
        if let Some(e) = self.entries.get_mut(key) {
            self.clock += 1;
            e.last_use = self.clock;
            self.stats.hits += 1;
            true
        } else {
            false
        }
    }

    /// Count one miss (the compile that follows is accounted even if
    /// it later fails — a lookup either hits or misses).
    pub(crate) fn note_miss(&mut self) {
        self.stats.misses += 1;
    }

    /// Evict least-recently-used plans — releasing their DRAM
    /// residency into `rt` — until an insert fits. Runs *before* the
    /// miss path compiles, so the evicted plans' DRAM is available to
    /// the new plan (and, on a pool, every replica's allocator sees
    /// the same free-then-allocate order).
    pub(crate) fn make_room(&mut self, rt: &mut VtaRuntime) -> Result<(), ExecError> {
        while self.entries.len() >= self.capacity {
            let victim =
                self.entries.iter().min_by_key(|(_, e)| e.last_use).map(|(k, _)| k.clone());
            let Some(vk) = victim else { break };
            let entry = self.entries.remove(&vk).expect("victim key resident");
            self.resident_bytes -= entry.node.dram_bytes();
            entry.node.free(rt).map_err(ExecError::PlanCache)?;
            self.stats.evictions += 1;
        }
        Ok(())
    }

    /// Insert a freshly compiled (or replicated) plan. The caller must
    /// have called [`Self::make_room`] first; a full cache here is a
    /// lockstep-protocol bug.
    pub(crate) fn insert(&mut self, key: PlanKey, node: CompiledNode) {
        debug_assert!(
            self.entries.len() < self.capacity,
            "insert without make_room: cache already at capacity"
        );
        self.clock += 1;
        self.resident_bytes += node.dram_bytes();
        self.entries.insert(key, CacheEntry { node, last_use: self.clock });
    }

    /// Remove `key`'s plan (if resident), releasing its DRAM into
    /// `rt` — the pool scheduler's error-path unwinding: when
    /// replication onto one replica fails, the copies already inserted
    /// on other replicas are removed again so every cache (and every
    /// allocator) lands back in the same state.
    pub(crate) fn remove(&mut self, key: &PlanKey, rt: &mut VtaRuntime) -> Result<(), ExecError> {
        if let Some(entry) = self.entries.remove(key) {
            self.resident_bytes -= entry.node.dram_bytes();
            entry.node.free(rt).map_err(ExecError::PlanCache)?;
        }
        Ok(())
    }

    /// Look up `key`, compiling (and inserting) on a miss. Evicts
    /// least-recently-used plans — releasing their DRAM residency —
    /// before the compile when the cache is full.
    pub fn get_or_compile<F>(
        &mut self,
        rt: &mut VtaRuntime,
        key: &PlanKey,
        compile: F,
    ) -> Result<&CompiledNode, ExecError>
    where
        F: FnOnce(&mut VtaRuntime) -> Result<CompiledNode, ExecError>,
    {
        if self.touch(key) {
            return Ok(&self.entries[key].node);
        }
        self.note_miss();
        self.make_room(rt)?;
        let node = compile(rt)?;
        self.insert(key.clone(), node);
        Ok(&self.entries[key].node)
    }

    /// Drop every resident plan, releasing its DRAM. Every plan is
    /// freed (and the residency accounting zeroed) even when one free
    /// fails; the first error is reported after the drain completes.
    pub fn flush(&mut self, rt: &mut VtaRuntime) -> Result<(), ExecError> {
        let mut first_err = None;
        for (_, entry) in self.entries.drain() {
            if let Err(e) = entry.node.free(rt) {
                first_err.get_or_insert(ExecError::PlanCache(e));
            }
        }
        self.resident_bytes = 0;
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
