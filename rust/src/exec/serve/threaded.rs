//! The real-threads serving runtime: one OS worker thread per
//! [`DevicePool`] replica, a bounded lock-free MPMC request queue with
//! backpressure, and cross-thread plan sharing — the promotion of the
//! simulated-time [`Scheduler`](super::Scheduler) (which stays on as
//! the deterministic oracle) to genuine task-level parallelism, the
//! paper's §3 runtime argument measured instead of modeled.
//!
//! ## Queue and admission control
//!
//! [`RequestQueue`] is an array-based lock-free MPMC ring
//! ([`super::queue::ArrayQueue`]: per-slot sequence numbers, bounded at
//! `queue_capacity`) with a condvar parking layer used **only** for
//! blocking waits — the hot push/pop path is compare-and-swap all the
//! way down, and the depth gauge ([`RequestQueue::len`]) is a relaxed
//! atomic load, so observability never contends with dispatch.
//! [`PoolHandle::try_submit`] rejects with a reason
//! ([`SubmitRejected::QueueFull`] / [`SubmitRejected::ShuttingDown`])
//! instead of blocking — the admission-control path an open-loop load
//! generator needs — while [`PoolHandle::submit`] blocks for
//! closed-loop trace replay. Workers pull *opportunistic batches* of up
//! to `max_batch` requests per queue visit; whatever remains at stream
//! end drains as a trailing partial batch. Shutdown closes the queue,
//! lets every worker drain what was already admitted, then joins.
//!
//! ## Plan sharing: reserve under the lock, lower outside it
//!
//! Sealed instruction streams bake DRAM addresses in, so a plan only
//! replays on a replica whose allocator history matches the compiling
//! replica's. The simulated scheduler guarantees that by driving every
//! per-replica [`PlanCache`](super::PlanCache) in lockstep from one
//! thread; across real threads the same invariant is kept by an
//! append-only **event log** in the shared [`PlanDirectory`] — but the
//! directory mutex is only a *publication* barrier, not a compile
//! barrier. A plan compile is split in two
//! ([`crate::compiler::PreparedPlan`]):
//!
//! * **Reserve** (short lock): the first worker to miss a key plans the
//!   operator and packs its constants *outside* any lock, then takes
//!   the directory mutex just long enough to count the miss, pick LRU
//!   victims, and append an `Install` carrying a [`PlanClaim`] — the
//!   plan's DRAM allocation requirements plus a not-yet-published
//!   blueprint slot. Log order is still total, so it remains the
//!   canonical allocator history.
//! * **Lower** (no lock): the owner allocates its own reservation (the
//!   replay of its own `Install`), emits the instruction streams, and
//!   publishes the device-independent [`PlanBlueprint`] on the claim.
//!   Distinct keys lower **concurrently** — a cold-start compile storm
//!   parallelizes across workers — while workers racing on the *same*
//!   key wait on the claim instead of recompiling.
//!
//! Every other replica materializes lazily: on its next directory
//! interaction it replays the pending events; an `Install` whose claim
//! is still in flight just *reserves* the layout (identical allocator
//! calls), and the blueprint is filled in at first use
//! ([`PlanBlueprint::materialize_reserved`] — addresses are enforced,
//! never assumed; a mismatch is
//! [`CompileError::ReplicaDiverged`](crate::compiler::CompileError)).
//! A failed lower logs a compensating `Evict`, so Install-then-Evict
//! replays as an allocator no-op on every replica.
//!
//! `serial_compile` ([`ThreadedOptions`]) is the A/B escape hatch: it
//! restores the old hold-the-lock-across-the-compile behavior so the
//! concurrent path's win stays measurable.
//!
//! ## Hit accounting without locks
//!
//! Steady-state requests touch only resident plans; their hit counters
//! are relaxed atomics (a pool-wide hit count and an LRU clock whose
//! stamps `fetch_max` into each claim's recency), so the hot path
//! acquires **no** mutex at all. Misses and evictions mutate under the
//! short directory lock. Pool-level `(hits, misses, evictions)` are
//! order-insensitive sums, so — like the simulated scheduler — a plan
//! compiles **once per pool** and the oracle-equivalence suite asserts
//! the counts match exactly.
//!
//! ## Oracle equivalence
//!
//! Workers execute requests through the *same* shared graph walker
//! ([`run_graph`]) as the engine and the simulated scheduler, so
//! outputs are bit-identical by construction, independent of thread
//! interleaving: plan execution is deterministic and per-replica.
//! `tests/threaded_oracle.rs` asserts it end to end across thread
//! counts, virtual-thread modes, and partition policies.

use super::super::executor::{lift_compile_err, CpuBackend, ExecError};
use super::cache::{PlanCacheStats, PlanKey};
use super::queue::ArrayQueue;
use super::run::{plan_keys_for, run_graph, tuned_schedules_for, VtaNodeExec};
use crate::arch::VtaConfig;
use crate::compiler::compiled::{alloc_group, free_group, free_reserved_layout};
use crate::compiler::op::{config_fingerprint, execute_compiled, op_impl};
use crate::compiler::{CompileError, CompiledNode, PlanBlueprint, ScheduleChoice};
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph};
use crate::metrics::{ContentionStats, LatencyHistogram, ThreadCounter};
use crate::runtime::{DevicePool, DramBuffer, VtaRuntime};
use crate::sim::SimStats;
use crate::util::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Configuration of one threaded pool run.
#[derive(Clone, Debug)]
pub struct ThreadedOptions {
    /// Worker threads — one per pool replica.
    pub threads: usize,
    /// Bounded request-queue capacity (admission control).
    pub queue_capacity: usize,
    /// Most requests a worker pulls per queue visit.
    pub max_batch: usize,
    /// Plan-directory capacity (compiled plans resident per replica).
    pub cache_capacity: usize,
    /// Virtual threads the plans are lowered with (1 or 2).
    pub virtual_threads: usize,
    /// Device DRAM bytes per replica.
    pub dram_size: usize,
    /// Start with workers gated: nothing is served until
    /// [`PoolHandle::resume`] (deterministic queue-full tests).
    pub start_paused: bool,
    /// Serialize plan compiles under the directory lock (the
    /// pre-concurrent behavior) instead of lowering distinct keys in
    /// parallel — the `--serial-compile` A/B baseline.
    pub serial_compile: bool,
}

impl ThreadedOptions {
    /// Defaults matching the simulated scheduler's test configuration.
    pub fn new(threads: usize) -> Self {
        ThreadedOptions {
            threads: threads.max(1),
            queue_capacity: 64,
            max_batch: 2,
            cache_capacity: 64,
            virtual_threads: 1,
            dram_size: 256 << 20,
            start_paused: false,
            serial_compile: false,
        }
    }
}

/// Why an admission-controlled submit was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum SubmitRejected {
    /// The bounded queue is at capacity — backpressure; retry later or
    /// count the request as shed.
    #[error("request queue full ({capacity} waiting)")]
    QueueFull {
        /// The queue's capacity at rejection time.
        capacity: usize,
    },
    /// The pool is draining; no new work is admitted.
    #[error("pool is shutting down")]
    ShuttingDown,
}

/// One admitted request, queued for a worker. Shared with the fleet
/// runtime ([`super::fleet`]), whose workers pick the graph by
/// `class`; the single-graph pool always submits class 0.
pub(crate) struct Request {
    pub(crate) id: u64,
    pub(crate) class: usize,
    pub(crate) input: Tensor<i8>,
    pub(crate) submitted: Instant,
}

/// One served request, reported back to the pool handle.
pub(crate) struct Response {
    pub(crate) id: u64,
    pub(crate) result: Result<Tensor<i8>, ExecError>,
    pub(crate) queue_wait: Duration,
    pub(crate) service: Duration,
    pub(crate) worker: usize,
    pub(crate) batch: usize,
}

/// Completion record of one request (timing only; outputs are
/// collected separately, in submission order).
#[derive(Clone, Debug)]
pub struct Completion {
    /// Submission id (dense, in admission order).
    pub id: u64,
    /// Time spent waiting in the bounded queue.
    pub queue_wait: Duration,
    /// Time spent executing the graph on the worker.
    pub service: Duration,
    /// Worker thread that served the request.
    pub worker: usize,
    /// Size of the batch the request was pulled in.
    pub batch: usize,
}

impl Completion {
    /// End-to-end latency: queue wait + service.
    pub fn latency(&self) -> Duration {
        self.queue_wait + self.service
    }
}

// ---------------------------------------------------------------------
// The bounded MPMC request queue.
// ---------------------------------------------------------------------

/// Bounded MPMC queue: producers reject or block at capacity, workers
/// pull opportunistic batches, close() drains gracefully. The fleet
/// runtime instantiates one per config group.
///
/// The data path is the lock-free [`ArrayQueue`]; the mutex + condvars
/// below exist **only** to park blocked pushers/poppers. Wakeups use
/// the classic two-fence protocol: a publisher fences and checks the
/// waiter count *after* its ring write, a waiter registers and fences
/// *before* re-checking the ring, so one side always observes the
/// other and no wakeup is lost.
pub(crate) struct RequestQueue {
    q: ArrayQueue<Request>,
    closed: AtomicBool,
    paused: AtomicBool,
    park: Mutex<()>,
    not_empty: Condvar,
    not_full: Condvar,
    pop_waiters: AtomicUsize,
    push_waiters: AtomicUsize,
}

impl RequestQueue {
    pub(crate) fn new(capacity: usize, paused: bool) -> Self {
        RequestQueue {
            q: ArrayQueue::new(capacity.max(1)),
            closed: AtomicBool::new(false),
            paused: AtomicBool::new(paused),
            park: Mutex::new(()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            pop_waiters: AtomicUsize::new(0),
            push_waiters: AtomicUsize::new(0),
        }
    }

    fn park_lock(&self) -> MutexGuard<'_, ()> {
        self.park.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn wake_poppers(&self) {
        fence(Ordering::SeqCst);
        if self.pop_waiters.load(Ordering::Relaxed) > 0 {
            let _g = self.park_lock();
            self.not_empty.notify_all();
        }
    }

    fn wake_pushers(&self) {
        fence(Ordering::SeqCst);
        if self.push_waiters.load(Ordering::Relaxed) > 0 {
            let _g = self.park_lock();
            self.not_full.notify_all();
        }
    }

    /// Admission-controlled push: never blocks, never takes a lock on
    /// the accept path.
    pub(crate) fn try_push(&self, req: Request) -> Result<(), SubmitRejected> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(SubmitRejected::ShuttingDown);
        }
        match self.q.try_push(req) {
            Ok(()) => {
                self.wake_poppers();
                Ok(())
            }
            Err(_) => Err(SubmitRejected::QueueFull { capacity: self.q.capacity() }),
        }
    }

    /// Blocking push: waits for room (closed-loop trace replay).
    pub(crate) fn push_wait(&self, req: Request) -> Result<(), SubmitRejected> {
        let mut req = req;
        loop {
            if self.closed.load(Ordering::SeqCst) {
                return Err(SubmitRejected::ShuttingDown);
            }
            match self.q.try_push(req) {
                Ok(()) => {
                    self.wake_poppers();
                    return Ok(());
                }
                Err(v) => req = v,
            }
            let g = self.park_lock();
            self.push_waiters.fetch_add(1, Ordering::Relaxed);
            fence(Ordering::SeqCst);
            let full = !self.closed.load(Ordering::Relaxed) && self.q.len() >= self.q.capacity();
            if full {
                let _g = self.not_full.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            self.push_waiters.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Pull up to `max` requests; blocks while the queue is empty (or
    /// paused) and open. `None` means closed *and* drained — the
    /// worker-exit signal. A non-full final pull is the trailing
    /// partial batch at stream end.
    pub(crate) fn pop_batch(&self, max: usize) -> Option<Vec<Request>> {
        let max = max.max(1);
        loop {
            if !self.paused.load(Ordering::SeqCst) {
                if let Some(first) = self.q.try_pop() {
                    let mut batch = Vec::with_capacity(max);
                    batch.push(first);
                    while batch.len() < max {
                        match self.q.try_pop() {
                            Some(req) => batch.push(req),
                            None => break,
                        }
                    }
                    self.wake_pushers();
                    return Some(batch);
                }
            }
            // `close()` clears the pause gate *before* raising `closed`
            // (both SeqCst), so observing `closed` here implies the
            // ring is really drained, not merely gated.
            if self.closed.load(Ordering::SeqCst) && self.q.is_empty() {
                return None;
            }
            let g = self.park_lock();
            self.pop_waiters.fetch_add(1, Ordering::Relaxed);
            fence(Ordering::SeqCst);
            let ready = self.closed.load(Ordering::Relaxed)
                || (!self.paused.load(Ordering::Relaxed) && !self.q.is_empty());
            if !ready {
                let _g = self.not_empty.wait(g).unwrap_or_else(|e| e.into_inner());
            }
            self.pop_waiters.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Instantaneous depth: one relaxed atomic load, so metrics
    /// sampling (queue gauges, live dashboards) never contends with
    /// dispatch.
    pub(crate) fn len(&self) -> usize {
        self.q.len()
    }

    pub(crate) fn depth(&self) -> usize {
        self.len()
    }

    /// Ungate paused workers.
    pub(crate) fn resume(&self) {
        self.paused.store(false, Ordering::SeqCst);
        let _g = self.park_lock();
        self.not_empty.notify_all();
    }

    /// Stop admitting; already-admitted requests still drain. Also
    /// ungates paused workers so shutdown cannot deadlock. The store
    /// order (gate first, then `closed`) is what `pop_batch`'s exit
    /// check relies on.
    pub(crate) fn close(&self) {
        self.paused.store(false, Ordering::SeqCst);
        self.closed.store(true, Ordering::SeqCst);
        let _g = self.park_lock();
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------
// The shared plan directory (publication barrier).
// ---------------------------------------------------------------------

/// What a claim's owner has gotten around to publishing.
pub(crate) enum ClaimState {
    /// The owner is still lowering.
    Pending,
    /// Published: replicas can materialize.
    Ready(Arc<PlanBlueprint>),
    /// The owner's lower failed (or unwound); waiters error out.
    Failed(String),
}

/// One in-flight-or-published plan: the DRAM allocation requirements
/// (known at reserve time, before any lowering) plus the blueprint
/// slot the owning worker fills in when its out-of-lock lower
/// finishes. Workers racing on the same key block on [`Self::wait_published`]
/// instead of recompiling; replicas replaying the log reserve
/// [`Self::reqs`] immediately and materialize lazily.
pub(crate) struct PlanClaim {
    reqs: Vec<(usize, usize)>,
    /// LRU recency stamp, advanced by relaxed `fetch_max` from the
    /// directory's atomic clock — the hit path touches no mutex.
    recency: AtomicU64,
    state: Mutex<ClaimState>,
    ready: Condvar,
}

impl PlanClaim {
    fn new(reqs: Vec<(usize, usize)>, stamp: u64) -> Self {
        PlanClaim {
            reqs,
            recency: AtomicU64::new(stamp),
            state: Mutex::new(ClaimState::Pending),
            ready: Condvar::new(),
        }
    }

    fn reqs(&self) -> &[(usize, usize)] {
        &self.reqs
    }

    fn lock_state(&self) -> MutexGuard<'_, ClaimState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Published and usable? (Eviction skips in-flight claims: their
    /// owner is about to need the reservation it logged.)
    fn is_ready(&self) -> bool {
        matches!(&*self.lock_state(), ClaimState::Ready(_))
    }

    /// Non-blocking peek — the event-replay path must never block on
    /// another worker's compile.
    fn published(&self) -> Option<Result<Arc<PlanBlueprint>, String>> {
        match &*self.lock_state() {
            ClaimState::Pending => None,
            ClaimState::Ready(bp) => Some(Ok(bp.clone())),
            ClaimState::Failed(msg) => Some(Err(msg.clone())),
        }
    }

    fn publish(&self, bp: Arc<PlanBlueprint>) {
        *self.lock_state() = ClaimState::Ready(bp);
        self.ready.notify_all();
    }

    /// Fail a still-pending claim (a published claim stays published).
    fn fail(&self, msg: String) {
        let mut st = self.lock_state();
        if matches!(&*st, ClaimState::Pending) {
            *st = ClaimState::Failed(msg);
        }
        drop(st);
        self.ready.notify_all();
    }

    /// Block until the owner publishes (or fails). The bool reports
    /// whether this call actually waited — the contention metric.
    fn wait_published(&self) -> Result<(Arc<PlanBlueprint>, bool), String> {
        let mut st = self.lock_state();
        let mut waited = false;
        loop {
            match &*st {
                ClaimState::Ready(bp) => return Ok((bp.clone(), waited)),
                ClaimState::Failed(msg) => return Err(msg.clone()),
                ClaimState::Pending => {
                    waited = true;
                    st = self.ready.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
    }
}

/// Drop guard around the owner's out-of-lock lower: if the worker
/// unwinds (error path that forgot to fail, or a panic) the claim is
/// failed so waiters never block forever.
struct ClaimGuard {
    claim: Arc<PlanClaim>,
    armed: bool,
}

impl ClaimGuard {
    fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for ClaimGuard {
    fn drop(&mut self) {
        if self.armed {
            self.claim.fail("owning worker unwound before publishing".to_string());
        }
    }
}

/// One entry of the canonical cache-mutation history.
#[derive(Clone)]
pub(crate) enum PlanEvent {
    Install(PlanKey, Arc<PlanClaim>),
    Evict(PlanKey),
}

struct DirectoryState {
    /// Pool-resident claims (in flight or published) — LRU victims
    /// come from here, by claim recency.
    resident: HashMap<PlanKey, Arc<PlanClaim>>,
    misses: u64,
    evictions: u64,
    /// Append-only event log — the canonical allocator history every
    /// replica replays. Grows with unique compiles + evictions, not
    /// with request volume.
    log: Vec<PlanEvent>,
}

/// The pool-shared plan directory: membership, LRU bookkeeping,
/// pool-level counters, and the event log. Its mutex is only the
/// *publication* barrier — reservations (the allocator-visible
/// decisions) serialize under it, but lowering happens outside, and
/// the steady-state hit path touches nothing but the atomics. The
/// fleet runtime instantiates one per config group: replication-by-
/// replay is only valid between replicas of one variant, so each
/// group keeps its own canonical history.
pub(crate) struct PlanDirectory {
    capacity: usize,
    /// Pool-level hit count (relaxed; hits commute).
    hits: AtomicU64,
    /// LRU clock; every hit or install takes a fresh stamp.
    clock: AtomicU64,
    /// Short-lock acquisitions (the contention observable).
    locks: AtomicU64,
    state: Mutex<DirectoryState>,
}

impl PlanDirectory {
    pub(crate) fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "plan directory needs at least one slot");
        PlanDirectory {
            capacity,
            hits: AtomicU64::new(0),
            clock: AtomicU64::new(0),
            locks: AtomicU64::new(0),
            state: Mutex::new(DirectoryState {
                resident: HashMap::new(),
                misses: 0,
                evictions: 0,
                log: Vec::new(),
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, DirectoryState> {
        self.locks.fetch_add(1, Ordering::Relaxed);
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn next_stamp(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Hit accounting — two relaxed atomic bumps, no mutex.
    fn count_hit(&self, claim: &PlanClaim) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        claim.recency.fetch_max(self.next_stamp(), Ordering::Relaxed);
    }

    pub(crate) fn stats(&self) -> PlanCacheStats {
        // Bypass `lock()`: bookkeeping reads shouldn't count as
        // hot-path lock traffic.
        let st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: st.misses,
            evictions: st.evictions,
        }
    }

    /// Short-lock acquisitions so far (misses, installs, evictions —
    /// never steady-state hits).
    pub(crate) fn lock_acquisitions(&self) -> u64 {
        self.locks.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------
// Worker side.
// ---------------------------------------------------------------------

/// How far one replica has taken a resident plan.
pub(crate) enum PlanState {
    /// Materialized and executable.
    Ready(CompiledNode),
    /// Layout allocated (the replay of the plan's `Install`), blueprint
    /// not yet published by the owner — filled in at first use.
    Reserved(Vec<DramBuffer>),
}

/// One replica-local plan: the shared claim plus this replica's copy.
pub(crate) struct PlanSlot {
    claim: Arc<PlanClaim>,
    state: PlanState,
}

/// One worker's view of its pool replica: the runtime plus the locally
/// materialized plans and the event-log cursor.
pub(crate) struct Replica<'rt> {
    pub(crate) rt: &'rt mut VtaRuntime,
    pub(crate) plans: HashMap<PlanKey, PlanSlot>,
    /// Log prefix already applied to this replica's allocator.
    pub(crate) applied: usize,
}

impl Replica<'_> {
    /// Apply a slice of canonical events in order. An `Install` whose
    /// blueprint is already published materializes fully; one still in
    /// flight (or failed) only reserves the layout — the identical
    /// allocator call sequence, which is all determinism needs. Evicts
    /// free whichever form the local copy is in, in layout order.
    fn apply(&mut self, events: &[PlanEvent]) -> Result<(), ExecError> {
        for event in events {
            match event {
                PlanEvent::Install(key, claim) => {
                    let state = match claim.published() {
                        Some(Ok(bp)) => PlanState::Ready(
                            bp.materialize(self.rt).map_err(ExecError::PlanCache)?,
                        ),
                        _ => PlanState::Reserved(
                            alloc_group(self.rt, claim.reqs()).map_err(ExecError::PlanCache)?,
                        ),
                    };
                    self.plans.insert(key.clone(), PlanSlot { claim: claim.clone(), state });
                }
                PlanEvent::Evict(key) => {
                    if let Some(slot) = self.plans.remove(key) {
                        match slot.state {
                            PlanState::Ready(node) => {
                                node.free(self.rt).map_err(ExecError::PlanCache)?;
                            }
                            PlanState::Reserved(bufs) => {
                                free_reserved_layout(self.rt, &bufs)
                                    .map_err(ExecError::PlanCache)?;
                            }
                        }
                    }
                }
            }
            self.applied += 1;
        }
        Ok(())
    }
}

/// The worker's side of the shared graph walker: VTA nodes resolve
/// through the local plan map, falling back to the directory protocol.
/// Shared with the fleet runtime, whose workers point `directory` at
/// their own group's directory.
pub(crate) struct WorkerExec<'rt, 'p> {
    pub(crate) replica: Replica<'rt>,
    pub(crate) directory: &'p PlanDirectory,
    pub(crate) cpu: CpuBackend,
    pub(crate) virtual_threads: usize,
    pub(crate) clock_hz: f64,
    /// Serialize compiles under the directory lock (A/B baseline).
    pub(crate) serial_compile: bool,
    /// Times this worker blocked on another worker's in-flight compile.
    pub(crate) claim_waits: u64,
}

impl WorkerExec<'_, '_> {
    /// Directory path for a key not resident locally: count the pool
    /// lookup, replay pending events, and — if the pool as a whole has
    /// never seen the key — reserve under the short lock and lower
    /// outside it (or, with `serial_compile`, do the whole thing under
    /// the lock).
    fn sync_plan(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
    ) -> Result<(), ExecError> {
        if self.serial_compile {
            return self.sync_plan_serial(g, id, key, schedule);
        }
        let node = &g.nodes[id];

        // First short lock: pool hit? Some worker already claimed this
        // key; its Install is in our unapplied suffix.
        {
            let mut st = self.directory.lock();
            if let Some(claim) = st.resident.get(key) {
                self.directory.count_hit(claim);
                let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
                drop(st);
                self.replica.apply(&pending)?;
                return Ok(());
            }
        }

        // Reserve half, outside any lock: planning and constant packing
        // need no device. Workers racing on the same key may duplicate
        // this much — never the lowering.
        let entry = op_impl(&node.op);
        let cfg = self.replica.rt.ctx.config().clone();
        let prep = entry
            .prepare(&cfg, g, node, self.virtual_threads, schedule.as_ref())
            .map_err(|e| lift_compile_err(&node.name, e))?;

        // Second short lock: publish the claim, or lose the install
        // race and become a pool hit.
        let (claim, pending) = {
            let mut st = self.directory.lock();
            if let Some(claim) = st.resident.get(key) {
                self.directory.count_hit(claim);
                let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
                drop(st);
                self.replica.apply(&pending)?;
                return Ok(());
            }
            st.misses += 1;
            Self::make_room(&mut st, self.directory.capacity);
            let claim = Arc::new(PlanClaim::new(prep.reqs().to_vec(), self.directory.next_stamp()));
            st.resident.insert(key.clone(), claim.clone());
            st.log.push(PlanEvent::Install(key.clone(), claim.clone()));
            // Snapshot stops *before* our own Install: the reservation
            // below is its replay.
            let pending: Vec<PlanEvent> = st.log[self.replica.applied..st.log.len() - 1].to_vec();
            (claim, pending)
        };
        let mut guard = ClaimGuard { claim: claim.clone(), armed: true };

        // Catch up, then reserve our own layout — the replay of the
        // Install we just logged.
        self.replica.apply(&pending)?;
        let bufs = match alloc_group(self.replica.rt, claim.reqs()) {
            Ok(bufs) => bufs,
            Err(e) => {
                // DRAM exhaustion while reserving. The logged Install
                // is one no replica can apply either (identical
                // allocator states fail identically), so the pool is
                // poisoned and the run will abort with its first
                // error; log the compensating Evict and wake waiters
                // so nothing blocks on the way down.
                {
                    let mut st = self.directory.lock();
                    st.resident.remove(key);
                    st.log.push(PlanEvent::Evict(key.clone()));
                }
                claim.fail(format!("layout reservation failed: {e}"));
                guard.disarm();
                return Err(lift_compile_err(&node.name, e));
            }
        };
        self.replica.applied += 1;

        // Lower with no lock held — the point of the whole exercise.
        // Workers on *other* keys are doing the same thing right now;
        // workers on *this* key are waiting on the claim.
        let lowered = prep.lower_into(self.replica.rt, &bufs).and_then(|compiled| {
            let bp = compiled.blueprint(self.replica.rt)?;
            Ok((compiled, bp))
        });
        match lowered {
            Ok((compiled, bp)) => {
                claim.publish(Arc::new(bp));
                guard.disarm();
                self.replica
                    .plans
                    .insert(key.clone(), PlanSlot { claim, state: PlanState::Ready(compiled) });
                Ok(())
            }
            Err(e) => {
                self.rollback_claim(key, &claim, &bufs, format!("{e}"))?;
                guard.disarm();
                Err(lift_compile_err(&node.name, e))
            }
        }
    }

    /// The pre-concurrent publish protocol: hold the directory lock
    /// across the entire compile. Kept behind `--serial-compile` as
    /// the A/B baseline the compile-storm bench measures against.
    fn sync_plan_serial(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
    ) -> Result<(), ExecError> {
        let node = &g.nodes[id];
        let mut st = self.directory.lock();
        if let Some(claim) = st.resident.get(key) {
            self.directory.count_hit(claim);
            let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
            drop(st);
            self.replica.apply(&pending)?;
            return Ok(());
        }

        // Pool miss. Evictions come first (mirroring the lockstep
        // caches' make_room-before-compile order) so the freed DRAM is
        // available to the new plan on every replica.
        st.misses += 1;
        Self::make_room(&mut st, self.directory.capacity);
        let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
        self.replica.apply(&pending)?;

        let entry = op_impl(&node.op);
        let cfg = self.replica.rt.ctx.config().clone();
        let prep = entry
            .prepare(&cfg, g, node, self.virtual_threads, schedule.as_ref())
            .map_err(|e| lift_compile_err(&node.name, e))?;
        let reqs = prep.reqs().to_vec();
        let compiled =
            prep.finish(self.replica.rt).map_err(|e| lift_compile_err(&node.name, e))?;
        // A failed compile above unwinds its allocations (alloc_group)
        // and publishes nothing: the canonical history is untouched and
        // the next lookup simply misses again.
        let blueprint =
            compiled.blueprint(self.replica.rt).map_err(|e| lift_compile_err(&node.name, e))?;
        let claim = Arc::new(PlanClaim::new(reqs, self.directory.next_stamp()));
        claim.publish(Arc::new(blueprint));
        st.resident.insert(key.clone(), claim.clone());
        st.log.push(PlanEvent::Install(key.clone(), claim.clone()));
        self.replica.applied += 1; // our own install is already in effect
        self.replica
            .plans
            .insert(key.clone(), PlanSlot { claim, state: PlanState::Ready(compiled) });
        Ok(())
    }

    /// LRU eviction to make room for one more claim. In-flight claims
    /// are never victims (their owner is mid-lower on the logged
    /// reservation); if everything resident is in flight the directory
    /// temporarily overshoots capacity instead of blocking.
    fn make_room(st: &mut DirectoryState, capacity: usize) {
        while st.resident.len() >= capacity {
            let victim = st
                .resident
                .iter()
                .filter(|(_, claim)| claim.is_ready())
                .min_by_key(|(_, claim)| claim.recency.load(Ordering::Relaxed))
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            st.resident.remove(&victim);
            st.evictions += 1;
            st.log.push(PlanEvent::Evict(victim));
        }
    }

    /// Unwind a failed out-of-lock lower: log the compensating Evict,
    /// catch up on events that landed since our Install, release our
    /// own reservation (the replay of that Evict), and wake waiters
    /// with the error. Every other replica replays Install-then-Evict
    /// — alloc-then-free of the same group, an exact allocator no-op —
    /// so the canonical history stays consistent and the pool keeps
    /// serving.
    fn rollback_claim(
        &mut self,
        key: &PlanKey,
        claim: &Arc<PlanClaim>,
        bufs: &[DramBuffer],
        msg: String,
    ) -> Result<(), ExecError> {
        let pending = {
            let mut st = self.directory.lock();
            let removed = st.resident.remove(key);
            debug_assert!(removed.is_some(), "in-flight claims are never evicted");
            let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
            st.log.push(PlanEvent::Evict(key.clone()));
            pending
        };
        self.replica.apply(&pending)?;
        free_group(self.replica.rt, bufs);
        self.replica.applied += 1;
        claim.fail(msg);
        Ok(())
    }

    /// Upgrade a locally Reserved slot to Ready: wait for the owner's
    /// blueprint (counting the wait), then fill the reservation in.
    /// On a failed claim the slot stays Reserved — the owner's
    /// rollback Evict frees it on our next replay.
    fn materialize_if_reserved(&mut self, name: &str, key: &PlanKey) -> Result<(), ExecError> {
        let claim = match self.replica.plans.get(key) {
            Some(slot) if matches!(slot.state, PlanState::Reserved(_)) => slot.claim.clone(),
            _ => return Ok(()),
        };
        let (bp, waited) = claim
            .wait_published()
            .map_err(|msg| lift_compile_err(name, CompileError::ClaimFailed(msg)))?;
        if waited {
            self.claim_waits += 1;
        }
        let slot = self.replica.plans.remove(key).expect("reserved slot still present");
        let PlanState::Reserved(bufs) = slot.state else {
            unreachable!("slot checked Reserved above")
        };
        let compiled =
            bp.materialize_reserved(self.replica.rt, &bufs).map_err(|e| lift_compile_err(name, e))?;
        self.replica
            .plans
            .insert(key.clone(), PlanSlot { claim: slot.claim, state: PlanState::Ready(compiled) });
        Ok(())
    }
}

impl VtaNodeExec for WorkerExec<'_, '_> {
    fn clock_hz(&self) -> f64 {
        self.clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        if let Some(slot) = self.replica.plans.get(key) {
            // Steady-state fast path: two relaxed atomic bumps, no
            // mutex anywhere.
            self.directory.count_hit(&slot.claim);
        } else {
            self.sync_plan(g, id, key, schedule)?;
        }
        let node = &g.nodes[id];
        self.materialize_if_reserved(&node.name, key)?;
        let entry = op_impl(&node.op);
        let slot = self.replica.plans.get(key).expect("plan resident after sync");
        let PlanState::Ready(compiled) = &slot.state else {
            unreachable!("slot materialized before execute")
        };
        execute_compiled(entry, compiled, self.replica.rt, inputs)
            .map_err(|e| lift_compile_err(&node.name, e))
    }
}

/// Everything a worker thread borrows from the pool run (shared,
/// read-only or internally synchronized).
struct PoolShared<'a> {
    queue: &'a RequestQueue,
    directory: &'a PlanDirectory,
    g: &'a Graph,
    stage_order: &'a [Vec<usize>],
    keys: &'a [Option<PlanKey>],
    schedules: &'a [Option<ScheduleChoice>],
    virtual_threads: usize,
    max_batch: usize,
    clock_hz: f64,
    serial_compile: bool,
}

fn worker_loop(
    worker: usize,
    rt: &mut VtaRuntime,
    shared: &PoolShared<'_>,
    tx: mpsc::Sender<Response>,
) -> ThreadCounter {
    let mut ex = WorkerExec {
        replica: Replica { rt, plans: HashMap::new(), applied: 0 },
        directory: shared.directory,
        cpu: CpuBackend::Native,
        virtual_threads: shared.virtual_threads,
        clock_hz: shared.clock_hz,
        serial_compile: shared.serial_compile,
        claim_waits: 0,
    };
    let mut counter = ThreadCounter::default();
    while let Some(batch) = shared.queue.pop_batch(shared.max_batch) {
        let t0 = Instant::now();
        let batch_size = batch.len();
        for req in batch {
            let queue_wait = req.submitted.elapsed();
            let s0 = Instant::now();
            let result = run_graph(
                &mut ex,
                shared.g,
                &req.input,
                shared.stage_order,
                shared.keys,
                shared.schedules,
            )
            .map(|(out, _)| out);
            let response = Response {
                id: req.id,
                result,
                queue_wait,
                service: s0.elapsed(),
                worker,
                batch: batch_size,
            };
            if tx.send(response).is_err() {
                // Receiver gone: the pool run is being torn down.
                counter.claim_waits = ex.claim_waits;
                return counter;
            }
        }
        counter.record_batch(batch_size, t0.elapsed());
    }
    counter.claim_waits = ex.claim_waits;
    counter
}

// ---------------------------------------------------------------------
// The pool handle and runner.
// ---------------------------------------------------------------------

/// The driver's interface to a running threaded pool: submit requests
/// (blocking or admission-controlled), poll completions, and inspect
/// live counters. Handed to the driver closure of [`run_threaded`];
/// when the closure returns, the queue closes and the pool drains.
pub struct PoolHandle<'s> {
    queue: &'s RequestQueue,
    rx: mpsc::Receiver<Response>,
    next_id: u64,
    accepted: u64,
    rejected_full: u64,
    rejected_shutdown: u64,
    outputs: Vec<Option<Tensor<i8>>>,
    completions: Vec<Option<Completion>>,
    received: u64,
    first_error: Option<ExecError>,
    queue_wait: LatencyHistogram,
    service: LatencyHistogram,
}

impl PoolHandle<'_> {
    fn record(&mut self, resp: Response) {
        let idx = resp.id as usize;
        match resp.result {
            Ok(out) => self.outputs[idx] = Some(out),
            Err(e) => {
                self.first_error.get_or_insert(e);
            }
        }
        self.queue_wait.record(resp.queue_wait.as_secs_f64());
        self.service.record(resp.service.as_secs_f64());
        self.completions[idx] = Some(Completion {
            id: resp.id,
            queue_wait: resp.queue_wait,
            service: resp.service,
            worker: resp.worker,
            batch: resp.batch,
        });
        self.received += 1;
    }

    /// Admission-controlled submit: rejects with a reason instead of
    /// blocking. Returns the request's submission id.
    pub fn try_submit(&mut self, input: Tensor<i8>) -> Result<u64, SubmitRejected> {
        let id = self.next_id;
        match self.queue.try_push(Request { id, class: 0, input, submitted: Instant::now() }) {
            Ok(()) => {
                self.next_id += 1;
                self.accepted += 1;
                self.outputs.push(None);
                self.completions.push(None);
                Ok(id)
            }
            Err(e) => {
                match e {
                    SubmitRejected::QueueFull { .. } => self.rejected_full += 1,
                    SubmitRejected::ShuttingDown => self.rejected_shutdown += 1,
                }
                Err(e)
            }
        }
    }

    /// Blocking submit: waits for queue room (closed-loop replay).
    pub fn submit(&mut self, input: Tensor<i8>) -> Result<u64, SubmitRejected> {
        let id = self.next_id;
        match self.queue.push_wait(Request { id, class: 0, input, submitted: Instant::now() }) {
            Ok(()) => {
                self.next_id += 1;
                self.accepted += 1;
                self.outputs.push(None);
                self.completions.push(None);
                Ok(id)
            }
            Err(e) => {
                self.rejected_shutdown += 1;
                Err(e)
            }
        }
    }

    /// Drain every completion that has already arrived (non-blocking).
    /// Returns the newly observed completions, in arrival order.
    pub fn poll(&mut self) -> Vec<Completion> {
        let mut fresh = Vec::new();
        loop {
            // Two steps (receive, then match) so the channel borrow ends
            // before `record` re-borrows self mutably.
            let received = self.rx.try_recv();
            let resp = match received {
                Ok(resp) => resp,
                Err(_) => break,
            };
            let id = resp.id as usize;
            self.record(resp);
            if let Some(c) = &self.completions[id] {
                fresh.push(c.clone());
            }
        }
        fresh
    }

    /// Block until every accepted request has completed.
    pub fn wait_all(&mut self) {
        while self.received < self.accepted {
            match self.rx.recv() {
                Ok(resp) => self.record(resp),
                Err(_) => break, // workers gone; remaining never arrive
            }
        }
    }

    /// Completion record of request `id`, if it has finished.
    pub fn completion(&self, id: u64) -> Option<&Completion> {
        self.completions.get(id as usize).and_then(|c| c.as_ref())
    }

    /// Requests admitted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Requests rejected by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_shutdown
    }

    /// Completions observed so far.
    pub fn completed(&self) -> u64 {
        self.received
    }

    /// Current bounded-queue depth (one relaxed atomic load).
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Ungate a pool started with `start_paused`.
    pub fn resume(&mut self) {
        self.queue.resume();
    }
}

/// Final report of one threaded pool run.
#[derive(Debug)]
pub struct ThreadedReport {
    /// One output per accepted request, in submission order — the
    /// vector compared bit-for-bit against the simulated oracle's.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request timing, indexed like `outputs`.
    pub completions: Vec<Completion>,
    /// Pool-level plan counters (hits + misses = VTA-node lookups;
    /// misses = unique plans compiled, exactly once per pool).
    pub cache: PlanCacheStats,
    /// Per-worker counters, indexed by worker thread.
    pub threads: Vec<ThreadCounter>,
    /// Queue-wait distribution across all requests.
    pub queue_wait: LatencyHistogram,
    /// Service-time distribution across all requests.
    pub service: LatencyHistogram,
    /// Requests admitted.
    pub accepted: u64,
    /// Requests shed by admission control.
    pub rejected: u64,
    /// Contention observables: queue-full rejections, compile-claim
    /// waits, directory short-lock acquisitions.
    pub contention: ContentionStats,
    /// Wall-clock span of the whole run (spawn → drained).
    pub wall: Duration,
}

impl ThreadedReport {
    /// Measured (not modeled) throughput: accepted requests over the
    /// run's wall-clock span.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.accepted as f64 / secs
        }
    }
}

/// Run a threaded pool over `g`: spawn one worker per replica, hand the
/// driver a [`PoolHandle`] to feed the queue, then close, drain, join,
/// and assemble the [`ThreadedReport`]. Worker threads are scoped — the
/// graph, the precomputed plan keys, and the pool replicas are borrowed,
/// not cloned.
pub fn run_threaded<T>(
    cfg: &VtaConfig,
    opts: &ThreadedOptions,
    records: &TuningRecords,
    g: &Graph,
    driver: impl FnOnce(&mut PoolHandle) -> T,
) -> Result<(T, ThreadedReport), ExecError> {
    assert!(opts.virtual_threads == 1 || opts.virtual_threads == 2, "1 or 2 virtual threads");
    let t0 = Instant::now();
    let config_fp = config_fingerprint(cfg);
    let stage_order = stages(g);
    let keys = plan_keys_for(config_fp, opts.virtual_threads, g);
    let schedules = tuned_schedules_for(records, config_fp, opts.virtual_threads, g);
    let threads = opts.threads.max(1);
    let mut pool = DevicePool::new(cfg, opts.dram_size, threads);
    let queue = RequestQueue::new(opts.queue_capacity, opts.start_paused);
    let directory = PlanDirectory::new(opts.cache_capacity);
    let clock_hz = cfg.clock_hz;
    let (tx, rx) = mpsc::channel::<Response>();

    let shared = PoolShared {
        queue: &queue,
        directory: &directory,
        g,
        stage_order: &stage_order,
        keys: &keys,
        schedules: &schedules,
        virtual_threads: opts.virtual_threads,
        max_batch: opts.max_batch,
        clock_hz,
        serial_compile: opts.serial_compile,
    };

    let (value, mut handle, counters) = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(threads);
        for (worker, rt) in pool.iter_mut().enumerate() {
            let tx = tx.clone();
            let shared = &shared;
            joins.push(scope.spawn(move || worker_loop(worker, rt, shared, tx)));
        }
        drop(tx);

        let mut handle = PoolHandle {
            queue: &queue,
            rx,
            next_id: 0,
            accepted: 0,
            rejected_full: 0,
            rejected_shutdown: 0,
            outputs: Vec::new(),
            completions: Vec::new(),
            received: 0,
            first_error: None,
            queue_wait: LatencyHistogram::default(),
            service: LatencyHistogram::default(),
        };
        let value = driver(&mut handle);

        // Graceful drain: stop admitting, serve what's queued, join.
        queue.close();
        let mut counters = Vec::with_capacity(joins.len());
        for join in joins {
            match join.join() {
                Ok(counter) => counters.push(counter),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        // Workers are gone; pick up every remaining response.
        loop {
            let received = handle.rx.try_recv();
            let resp = match received {
                Ok(resp) => resp,
                Err(_) => break,
            };
            handle.record(resp);
        }
        (value, handle, counters)
    });

    if let Some(e) = handle.first_error.take() {
        return Err(e);
    }
    let contention = ContentionStats {
        queue_full: handle.rejected_full,
        claim_waits: counters.iter().map(|c| c.claim_waits).sum(),
        directory_locks: directory.lock_acquisitions(),
    };
    let outputs: Vec<Tensor<i8>> = handle
        .outputs
        .into_iter()
        .map(|o| o.expect("every accepted request produced an output"))
        .collect();
    let completions: Vec<Completion> = handle
        .completions
        .into_iter()
        .map(|c| c.expect("every accepted request completed"))
        .collect();
    Ok((
        value,
        ThreadedReport {
            outputs,
            completions,
            cache: directory.stats(),
            threads: counters,
            queue_wait: handle.queue_wait,
            service: handle.service,
            accepted: handle.accepted,
            rejected: handle.rejected_full + handle.rejected_shutdown,
            contention,
            wall: t0.elapsed(),
        },
    ))
}

/// Closed-loop convenience: replay a request trace through a threaded
/// pool (blocking submits — nothing is shed) and return the drained
/// report. The exact counterpart of feeding the same trace to the
/// simulated [`Scheduler`](super::Scheduler), which is what the
/// oracle-equivalence suite does.
pub fn serve_trace(
    cfg: &VtaConfig,
    opts: &ThreadedOptions,
    records: &TuningRecords,
    g: &Graph,
    inputs: &[Tensor<i8>],
) -> Result<ThreadedReport, ExecError> {
    let ((), report) = run_threaded(cfg, opts, records, g, |handle| {
        for input in inputs {
            handle.submit(input.clone()).expect("queue open while driver runs");
        }
    })?;
    Ok(report)
}
