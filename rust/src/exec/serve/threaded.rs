//! The real-threads serving runtime: one OS worker thread per
//! [`DevicePool`] replica, a bounded MPMC request queue with
//! backpressure, and cross-thread plan sharing — the promotion of the
//! simulated-time [`Scheduler`](super::Scheduler) (which stays on as
//! the deterministic oracle) to genuine task-level parallelism, the
//! paper's §3 runtime argument measured instead of modeled.
//!
//! ## Queue and admission control
//!
//! [`RequestQueue`] is a `Mutex<VecDeque>` + two condvars bounded at
//! `queue_capacity`. [`PoolHandle::try_submit`] rejects with a reason
//! ([`SubmitRejected::QueueFull`] / [`SubmitRejected::ShuttingDown`])
//! instead of blocking — the admission-control path an open-loop load
//! generator needs — while [`PoolHandle::submit`] blocks for
//! closed-loop trace replay. Workers pull *opportunistic batches* of up
//! to `max_batch` requests per queue visit; whatever remains at stream
//! end drains as a trailing partial batch. Shutdown closes the queue,
//! lets every worker drain what was already admitted, then joins.
//!
//! ## Plan sharing: compile-on-first-miss with a publish barrier
//!
//! Sealed instruction streams bake DRAM addresses in, so a plan only
//! replays on a replica whose allocator history matches the compiling
//! replica's. The simulated scheduler guarantees that by driving every
//! per-replica [`PlanCache`](super::PlanCache) in lockstep from one
//! thread; across real threads the same invariant is kept by an
//! append-only **event log** in the shared [`PlanDirectory`]:
//!
//! * every cache mutation (install / evict) is an event appended under
//!   the directory mutex — the publish barrier; compiles are serialized
//!   by it, so the log order *is* the canonical allocator history;
//! * the first worker to miss a key applies any unapplied log prefix to
//!   its own replica, compiles, and publishes a device-independent
//!   [`PlanBlueprint`] (streams + layout + baked bytes);
//! * every other worker materializes lazily: on its next directory
//!   interaction it replays the pending events against its own replica,
//!   and because all replicas apply the same event sequence from
//!   identical fresh allocators, every allocation lands at the baked
//!   address (enforced, never assumed — a mismatch is
//!   [`CompileError::ReplicaDiverged`](crate::compiler::CompileError)).
//!
//! Pool-level hit/miss/eviction counters live in the directory, so —
//! like the simulated scheduler — a plan compiles **once per pool**,
//! and the oracle-equivalence suite asserts the counts match exactly.
//!
//! ## Oracle equivalence
//!
//! Workers execute requests through the *same* shared graph walker
//! ([`run_graph`]) as the engine and the simulated scheduler, so
//! outputs are bit-identical by construction, independent of thread
//! interleaving: plan execution is deterministic and per-replica.
//! `tests/threaded_oracle.rs` asserts it end to end across thread
//! counts, virtual-thread modes, and partition policies.

use super::super::executor::{lift_compile_err, CpuBackend, ExecError};
use super::cache::{PlanCacheStats, PlanKey};
use super::run::{plan_keys_for, run_graph, tuned_schedules_for, VtaNodeExec};
use crate::arch::VtaConfig;
use crate::compiler::op::{config_fingerprint, execute_compiled, op_impl};
use crate::compiler::{CompiledNode, PlanBlueprint, ScheduleChoice};
use crate::dse::records::TuningRecords;
use crate::graph::{stages, Graph};
use crate::metrics::{LatencyHistogram, ThreadCounter};
use crate::runtime::{DevicePool, VtaRuntime};
use crate::sim::SimStats;
use crate::util::Tensor;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Configuration of one threaded pool run.
#[derive(Clone, Debug)]
pub struct ThreadedOptions {
    /// Worker threads — one per pool replica.
    pub threads: usize,
    /// Bounded request-queue capacity (admission control).
    pub queue_capacity: usize,
    /// Most requests a worker pulls per queue visit.
    pub max_batch: usize,
    /// Plan-directory capacity (compiled plans resident per replica).
    pub cache_capacity: usize,
    /// Virtual threads the plans are lowered with (1 or 2).
    pub virtual_threads: usize,
    /// Device DRAM bytes per replica.
    pub dram_size: usize,
    /// Start with workers gated: nothing is served until
    /// [`PoolHandle::resume`] (deterministic queue-full tests).
    pub start_paused: bool,
}

impl ThreadedOptions {
    /// Defaults matching the simulated scheduler's test configuration.
    pub fn new(threads: usize) -> Self {
        ThreadedOptions {
            threads: threads.max(1),
            queue_capacity: 64,
            max_batch: 2,
            cache_capacity: 64,
            virtual_threads: 1,
            dram_size: 256 << 20,
            start_paused: false,
        }
    }
}

/// Why an admission-controlled submit was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum SubmitRejected {
    /// The bounded queue is at capacity — backpressure; retry later or
    /// count the request as shed.
    #[error("request queue full ({capacity} waiting)")]
    QueueFull {
        /// The queue's capacity at rejection time.
        capacity: usize,
    },
    /// The pool is draining; no new work is admitted.
    #[error("pool is shutting down")]
    ShuttingDown,
}

/// One admitted request, queued for a worker. Shared with the fleet
/// runtime ([`super::fleet`]), whose workers pick the graph by
/// `class`; the single-graph pool always submits class 0.
pub(crate) struct Request {
    pub(crate) id: u64,
    pub(crate) class: usize,
    pub(crate) input: Tensor<i8>,
    pub(crate) submitted: Instant,
}

/// One served request, reported back to the pool handle.
pub(crate) struct Response {
    pub(crate) id: u64,
    pub(crate) result: Result<Tensor<i8>, ExecError>,
    pub(crate) queue_wait: Duration,
    pub(crate) service: Duration,
    pub(crate) worker: usize,
    pub(crate) batch: usize,
}

/// Completion record of one request (timing only; outputs are
/// collected separately, in submission order).
#[derive(Clone, Debug)]
pub struct Completion {
    /// Submission id (dense, in admission order).
    pub id: u64,
    /// Time spent waiting in the bounded queue.
    pub queue_wait: Duration,
    /// Time spent executing the graph on the worker.
    pub service: Duration,
    /// Worker thread that served the request.
    pub worker: usize,
    /// Size of the batch the request was pulled in.
    pub batch: usize,
}

impl Completion {
    /// End-to-end latency: queue wait + service.
    pub fn latency(&self) -> Duration {
        self.queue_wait + self.service
    }
}

// ---------------------------------------------------------------------
// The bounded MPMC request queue.
// ---------------------------------------------------------------------

struct QueueState {
    buf: VecDeque<Request>,
    closed: bool,
    paused: bool,
}

/// Bounded MPMC queue: producers reject or block at capacity, workers
/// pull opportunistic batches, close() drains gracefully. The fleet
/// runtime instantiates one per config group.
pub(crate) struct RequestQueue {
    capacity: usize,
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl RequestQueue {
    pub(crate) fn new(capacity: usize, paused: bool) -> Self {
        RequestQueue {
            capacity: capacity.max(1),
            state: Mutex::new(QueueState { buf: VecDeque::new(), closed: false, paused }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Admission-controlled push: never blocks.
    pub(crate) fn try_push(&self, req: Request) -> Result<(), SubmitRejected> {
        let mut st = self.lock();
        if st.closed {
            return Err(SubmitRejected::ShuttingDown);
        }
        if st.buf.len() >= self.capacity {
            return Err(SubmitRejected::QueueFull { capacity: self.capacity });
        }
        st.buf.push_back(req);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for room (closed-loop trace replay).
    pub(crate) fn push_wait(&self, req: Request) -> Result<(), SubmitRejected> {
        let mut st = self.lock();
        while !st.closed && st.buf.len() >= self.capacity {
            st = self.not_full.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.closed {
            return Err(SubmitRejected::ShuttingDown);
        }
        st.buf.push_back(req);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pull up to `max` requests; blocks while the queue is empty (or
    /// paused) and open. `None` means closed *and* drained — the
    /// worker-exit signal. A non-full final pull is the trailing
    /// partial batch at stream end.
    pub(crate) fn pop_batch(&self, max: usize) -> Option<Vec<Request>> {
        let mut st = self.lock();
        loop {
            if !st.paused && !st.buf.is_empty() {
                let n = st.buf.len().min(max.max(1));
                let batch: Vec<Request> = st.buf.drain(..n).collect();
                drop(st);
                self.not_full.notify_all();
                return Some(batch);
            }
            if st.closed && st.buf.is_empty() {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub(crate) fn depth(&self) -> usize {
        self.lock().buf.len()
    }

    /// Ungate paused workers.
    pub(crate) fn resume(&self) {
        self.lock().paused = false;
        self.not_empty.notify_all();
    }

    /// Stop admitting; already-admitted requests still drain. Also
    /// ungates paused workers so shutdown cannot deadlock.
    pub(crate) fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        st.paused = false;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// ---------------------------------------------------------------------
// The shared plan directory (publish barrier).
// ---------------------------------------------------------------------

/// One entry of the canonical cache-mutation history.
#[derive(Clone)]
enum PlanEvent {
    Install(PlanKey, Arc<PlanBlueprint>),
    Evict(PlanKey),
}

struct DirectoryState {
    /// Pool-resident keys with their last-use clock (LRU victims).
    resident: HashMap<PlanKey, u64>,
    clock: u64,
    /// Append-only event log — the canonical allocator history every
    /// replica replays. Grows with unique compiles + evictions, not
    /// with request volume.
    log: Vec<PlanEvent>,
    stats: PlanCacheStats,
}

/// The pool-shared plan directory: membership, LRU bookkeeping,
/// pool-level counters, and the event log. Its mutex is the publish
/// barrier — compiles happen under it, so log order is total. The
/// fleet runtime instantiates one per config group: replication-by-
/// replay is only valid between replicas of one variant, so each
/// group keeps its own canonical history.
pub(crate) struct PlanDirectory {
    capacity: usize,
    state: Mutex<DirectoryState>,
}

impl PlanDirectory {
    pub(crate) fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "plan directory needs at least one slot");
        PlanDirectory {
            capacity,
            state: Mutex::new(DirectoryState {
                resident: HashMap::new(),
                clock: 0,
                log: Vec::new(),
                stats: PlanCacheStats::default(),
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, DirectoryState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Fast-path hit accounting for a key already materialized on the
    /// calling replica.
    fn count_local_hit(&self, key: &PlanKey) {
        let mut st = self.lock();
        st.stats.hits += 1;
        st.clock += 1;
        let clock = st.clock;
        if let Some(last_use) = st.resident.get_mut(key) {
            *last_use = clock;
        }
    }

    pub(crate) fn stats(&self) -> PlanCacheStats {
        self.lock().stats
    }
}

// ---------------------------------------------------------------------
// Worker side.
// ---------------------------------------------------------------------

/// One worker's view of its pool replica: the runtime plus the locally
/// materialized plans and the event-log cursor.
pub(crate) struct Replica<'rt> {
    pub(crate) rt: &'rt mut VtaRuntime,
    pub(crate) plans: HashMap<PlanKey, CompiledNode>,
    /// Log prefix already applied to this replica's allocator.
    pub(crate) applied: usize,
}

impl Replica<'_> {
    /// Apply a slice of canonical events in order: installs materialize
    /// the published blueprint (allocations must land at the baked
    /// addresses), evicts free the local copy.
    fn apply(&mut self, events: &[PlanEvent]) -> Result<(), ExecError> {
        for event in events {
            match event {
                PlanEvent::Install(key, blueprint) => {
                    let node = blueprint.materialize(self.rt).map_err(ExecError::PlanCache)?;
                    self.plans.insert(key.clone(), node);
                }
                PlanEvent::Evict(key) => {
                    if let Some(node) = self.plans.remove(key) {
                        node.free(self.rt).map_err(ExecError::PlanCache)?;
                    }
                }
            }
            self.applied += 1;
        }
        Ok(())
    }
}

/// The worker's side of the shared graph walker: VTA nodes resolve
/// through the local plan map, falling back to the directory protocol.
/// Shared with the fleet runtime, whose workers point `directory` at
/// their own group's directory.
pub(crate) struct WorkerExec<'rt, 'p> {
    pub(crate) replica: Replica<'rt>,
    pub(crate) directory: &'p PlanDirectory,
    pub(crate) cpu: CpuBackend,
    pub(crate) virtual_threads: usize,
    pub(crate) clock_hz: f64,
}

impl WorkerExec<'_, '_> {
    /// Directory path for a key not resident locally: count the pool
    /// lookup, replay pending events, and — if the pool as a whole has
    /// never seen the key — compile and publish under the barrier.
    fn sync_plan(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
    ) -> Result<(), ExecError> {
        let node = &g.nodes[id];
        let mut st = self.directory.lock();
        if st.resident.contains_key(key) {
            // Pool hit: some worker already published this plan; catch
            // up on the log (its Install is in our unapplied suffix).
            st.stats.hits += 1;
            st.clock += 1;
            let clock = st.clock;
            st.resident.insert(key.clone(), clock);
            let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
            drop(st);
            self.replica.apply(&pending)?;
            return Ok(());
        }

        // Pool miss: this worker compiles, holding the directory lock
        // as the publish barrier. Evictions come first (mirroring the
        // lockstep caches' make_room-before-compile order) so the freed
        // DRAM is available to the new plan on every replica.
        st.stats.misses += 1;
        while st.resident.len() >= self.directory.capacity {
            let victim = st
                .resident
                .iter()
                .min_by_key(|&(_, &last_use)| last_use)
                .map(|(k, _)| k.clone());
            let Some(victim) = victim else { break };
            st.resident.remove(&victim);
            st.stats.evictions += 1;
            st.log.push(PlanEvent::Evict(victim));
        }
        let pending: Vec<PlanEvent> = st.log[self.replica.applied..].to_vec();
        self.replica.apply(&pending)?;

        let entry = op_impl(&node.op);
        let compiled = entry
            .compile(self.replica.rt, g, node, self.virtual_threads, schedule.as_ref())
            .map_err(|e| lift_compile_err(&node.name, e))?;
        // A failed compile above unwinds its allocations (alloc_group)
        // and publishes nothing: the canonical history is untouched and
        // the next lookup simply misses again.
        let blueprint =
            compiled.blueprint(self.replica.rt).map_err(|e| lift_compile_err(&node.name, e))?;
        st.clock += 1;
        let clock = st.clock;
        st.resident.insert(key.clone(), clock);
        st.log.push(PlanEvent::Install(key.clone(), Arc::new(blueprint)));
        self.replica.applied += 1; // our own install is already in effect
        self.replica.plans.insert(key.clone(), compiled);
        Ok(())
    }
}

impl VtaNodeExec for WorkerExec<'_, '_> {
    fn clock_hz(&self) -> f64 {
        self.clock_hz
    }

    fn cpu_mut(&mut self) -> &mut CpuBackend {
        &mut self.cpu
    }

    fn exec_vta_node(
        &mut self,
        g: &Graph,
        id: usize,
        key: &PlanKey,
        schedule: Option<ScheduleChoice>,
        inputs: &[&Tensor<i8>],
    ) -> Result<(Tensor<i8>, SimStats), ExecError> {
        if self.replica.plans.contains_key(key) {
            // Fast path: no event replay needed; one short directory
            // lock to keep pool-level counters exact.
            self.directory.count_local_hit(key);
        } else {
            self.sync_plan(g, id, key, schedule)?;
        }
        let node = &g.nodes[id];
        let entry = op_impl(&node.op);
        let compiled = self.replica.plans.get(key).expect("plan resident after sync");
        execute_compiled(entry, compiled, self.replica.rt, inputs)
            .map_err(|e| lift_compile_err(&node.name, e))
    }
}

/// Everything a worker thread borrows from the pool run (shared,
/// read-only or internally synchronized).
struct PoolShared<'a> {
    queue: &'a RequestQueue,
    directory: &'a PlanDirectory,
    g: &'a Graph,
    stage_order: &'a [Vec<usize>],
    keys: &'a [Option<PlanKey>],
    schedules: &'a [Option<ScheduleChoice>],
    virtual_threads: usize,
    max_batch: usize,
    clock_hz: f64,
}

fn worker_loop(
    worker: usize,
    rt: &mut VtaRuntime,
    shared: &PoolShared<'_>,
    tx: mpsc::Sender<Response>,
) -> ThreadCounter {
    let mut ex = WorkerExec {
        replica: Replica { rt, plans: HashMap::new(), applied: 0 },
        directory: shared.directory,
        cpu: CpuBackend::Native,
        virtual_threads: shared.virtual_threads,
        clock_hz: shared.clock_hz,
    };
    let mut counter = ThreadCounter::default();
    while let Some(batch) = shared.queue.pop_batch(shared.max_batch) {
        let t0 = Instant::now();
        let batch_size = batch.len();
        for req in batch {
            let queue_wait = req.submitted.elapsed();
            let s0 = Instant::now();
            let result = run_graph(
                &mut ex,
                shared.g,
                &req.input,
                shared.stage_order,
                shared.keys,
                shared.schedules,
            )
            .map(|(out, _)| out);
            let response = Response {
                id: req.id,
                result,
                queue_wait,
                service: s0.elapsed(),
                worker,
                batch: batch_size,
            };
            if tx.send(response).is_err() {
                // Receiver gone: the pool run is being torn down.
                return counter;
            }
        }
        counter.record_batch(batch_size, t0.elapsed());
    }
    counter
}

// ---------------------------------------------------------------------
// The pool handle and runner.
// ---------------------------------------------------------------------

/// The driver's interface to a running threaded pool: submit requests
/// (blocking or admission-controlled), poll completions, and inspect
/// live counters. Handed to the driver closure of [`run_threaded`];
/// when the closure returns, the queue closes and the pool drains.
pub struct PoolHandle<'s> {
    queue: &'s RequestQueue,
    rx: mpsc::Receiver<Response>,
    next_id: u64,
    accepted: u64,
    rejected_full: u64,
    rejected_shutdown: u64,
    outputs: Vec<Option<Tensor<i8>>>,
    completions: Vec<Option<Completion>>,
    received: u64,
    first_error: Option<ExecError>,
    queue_wait: LatencyHistogram,
    service: LatencyHistogram,
}

impl PoolHandle<'_> {
    fn record(&mut self, resp: Response) {
        let idx = resp.id as usize;
        match resp.result {
            Ok(out) => self.outputs[idx] = Some(out),
            Err(e) => {
                self.first_error.get_or_insert(e);
            }
        }
        self.queue_wait.record(resp.queue_wait.as_secs_f64());
        self.service.record(resp.service.as_secs_f64());
        self.completions[idx] = Some(Completion {
            id: resp.id,
            queue_wait: resp.queue_wait,
            service: resp.service,
            worker: resp.worker,
            batch: resp.batch,
        });
        self.received += 1;
    }

    /// Admission-controlled submit: rejects with a reason instead of
    /// blocking. Returns the request's submission id.
    pub fn try_submit(&mut self, input: Tensor<i8>) -> Result<u64, SubmitRejected> {
        let id = self.next_id;
        match self.queue.try_push(Request { id, class: 0, input, submitted: Instant::now() }) {
            Ok(()) => {
                self.next_id += 1;
                self.accepted += 1;
                self.outputs.push(None);
                self.completions.push(None);
                Ok(id)
            }
            Err(e) => {
                match e {
                    SubmitRejected::QueueFull { .. } => self.rejected_full += 1,
                    SubmitRejected::ShuttingDown => self.rejected_shutdown += 1,
                }
                Err(e)
            }
        }
    }

    /// Blocking submit: waits for queue room (closed-loop replay).
    pub fn submit(&mut self, input: Tensor<i8>) -> Result<u64, SubmitRejected> {
        let id = self.next_id;
        match self.queue.push_wait(Request { id, class: 0, input, submitted: Instant::now() }) {
            Ok(()) => {
                self.next_id += 1;
                self.accepted += 1;
                self.outputs.push(None);
                self.completions.push(None);
                Ok(id)
            }
            Err(e) => {
                self.rejected_shutdown += 1;
                Err(e)
            }
        }
    }

    /// Drain every completion that has already arrived (non-blocking).
    /// Returns the newly observed completions, in arrival order.
    pub fn poll(&mut self) -> Vec<Completion> {
        let mut fresh = Vec::new();
        loop {
            // Two steps (receive, then match) so the channel borrow ends
            // before `record` re-borrows self mutably.
            let received = self.rx.try_recv();
            let resp = match received {
                Ok(resp) => resp,
                Err(_) => break,
            };
            let id = resp.id as usize;
            self.record(resp);
            if let Some(c) = &self.completions[id] {
                fresh.push(c.clone());
            }
        }
        fresh
    }

    /// Block until every accepted request has completed.
    pub fn wait_all(&mut self) {
        while self.received < self.accepted {
            match self.rx.recv() {
                Ok(resp) => self.record(resp),
                Err(_) => break, // workers gone; remaining never arrive
            }
        }
    }

    /// Completion record of request `id`, if it has finished.
    pub fn completion(&self, id: u64) -> Option<&Completion> {
        self.completions.get(id as usize).and_then(|c| c.as_ref())
    }

    /// Requests admitted so far.
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Requests rejected by admission control so far.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_shutdown
    }

    /// Completions observed so far.
    pub fn completed(&self) -> u64 {
        self.received
    }

    /// Current bounded-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    /// Ungate a pool started with `start_paused`.
    pub fn resume(&mut self) {
        self.queue.resume();
    }
}

/// Final report of one threaded pool run.
#[derive(Debug)]
pub struct ThreadedReport {
    /// One output per accepted request, in submission order — the
    /// vector compared bit-for-bit against the simulated oracle's.
    pub outputs: Vec<Tensor<i8>>,
    /// Per-request timing, indexed like `outputs`.
    pub completions: Vec<Completion>,
    /// Pool-level plan counters (hits + misses = VTA-node lookups;
    /// misses = unique plans compiled, exactly once per pool).
    pub cache: PlanCacheStats,
    /// Per-worker counters, indexed by worker thread.
    pub threads: Vec<ThreadCounter>,
    /// Queue-wait distribution across all requests.
    pub queue_wait: LatencyHistogram,
    /// Service-time distribution across all requests.
    pub service: LatencyHistogram,
    /// Requests admitted.
    pub accepted: u64,
    /// Requests shed by admission control.
    pub rejected: u64,
    /// Wall-clock span of the whole run (spawn → drained).
    pub wall: Duration,
}

impl ThreadedReport {
    /// Measured (not modeled) throughput: accepted requests over the
    /// run's wall-clock span.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.accepted as f64 / secs
        }
    }
}

/// Run a threaded pool over `g`: spawn one worker per replica, hand the
/// driver a [`PoolHandle`] to feed the queue, then close, drain, join,
/// and assemble the [`ThreadedReport`]. Worker threads are scoped — the
/// graph, the precomputed plan keys, and the pool replicas are borrowed,
/// not cloned.
pub fn run_threaded<T>(
    cfg: &VtaConfig,
    opts: &ThreadedOptions,
    records: &TuningRecords,
    g: &Graph,
    driver: impl FnOnce(&mut PoolHandle) -> T,
) -> Result<(T, ThreadedReport), ExecError> {
    assert!(opts.virtual_threads == 1 || opts.virtual_threads == 2, "1 or 2 virtual threads");
    let t0 = Instant::now();
    let config_fp = config_fingerprint(cfg);
    let stage_order = stages(g);
    let keys = plan_keys_for(config_fp, opts.virtual_threads, g);
    let schedules = tuned_schedules_for(records, config_fp, opts.virtual_threads, g);
    let threads = opts.threads.max(1);
    let mut pool = DevicePool::new(cfg, opts.dram_size, threads);
    let queue = RequestQueue::new(opts.queue_capacity, opts.start_paused);
    let directory = PlanDirectory::new(opts.cache_capacity);
    let clock_hz = cfg.clock_hz;
    let (tx, rx) = mpsc::channel::<Response>();

    let shared = PoolShared {
        queue: &queue,
        directory: &directory,
        g,
        stage_order: &stage_order,
        keys: &keys,
        schedules: &schedules,
        virtual_threads: opts.virtual_threads,
        max_batch: opts.max_batch,
        clock_hz,
    };

    let (value, mut handle, counters) = std::thread::scope(|scope| {
        let mut joins = Vec::with_capacity(threads);
        for (worker, rt) in pool.iter_mut().enumerate() {
            let tx = tx.clone();
            let shared = &shared;
            joins.push(scope.spawn(move || worker_loop(worker, rt, shared, tx)));
        }
        drop(tx);

        let mut handle = PoolHandle {
            queue: &queue,
            rx,
            next_id: 0,
            accepted: 0,
            rejected_full: 0,
            rejected_shutdown: 0,
            outputs: Vec::new(),
            completions: Vec::new(),
            received: 0,
            first_error: None,
            queue_wait: LatencyHistogram::default(),
            service: LatencyHistogram::default(),
        };
        let value = driver(&mut handle);

        // Graceful drain: stop admitting, serve what's queued, join.
        queue.close();
        let mut counters = Vec::with_capacity(joins.len());
        for join in joins {
            match join.join() {
                Ok(counter) => counters.push(counter),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        // Workers are gone; pick up every remaining response.
        loop {
            let received = handle.rx.try_recv();
            let resp = match received {
                Ok(resp) => resp,
                Err(_) => break,
            };
            handle.record(resp);
        }
        (value, handle, counters)
    });

    if let Some(e) = handle.first_error.take() {
        return Err(e);
    }
    let outputs: Vec<Tensor<i8>> = handle
        .outputs
        .into_iter()
        .map(|o| o.expect("every accepted request produced an output"))
        .collect();
    let completions: Vec<Completion> = handle
        .completions
        .into_iter()
        .map(|c| c.expect("every accepted request completed"))
        .collect();
    Ok((
        value,
        ThreadedReport {
            outputs,
            completions,
            cache: directory.stats(),
            threads: counters,
            queue_wait: handle.queue_wait,
            service: handle.service,
            accepted: handle.accepted,
            rejected: handle.rejected_full + handle.rejected_shutdown,
            wall: t0.elapsed(),
        },
    ))
}

/// Closed-loop convenience: replay a request trace through a threaded
/// pool (blocking submits — nothing is shed) and return the drained
/// report. The exact counterpart of feeding the same trace to the
/// simulated [`Scheduler`](super::Scheduler), which is what the
/// oracle-equivalence suite does.
pub fn serve_trace(
    cfg: &VtaConfig,
    opts: &ThreadedOptions,
    records: &TuningRecords,
    g: &Graph,
    inputs: &[Tensor<i8>],
) -> Result<ThreadedReport, ExecError> {
    let ((), report) = run_threaded(cfg, opts, records, g, |handle| {
        for input in inputs {
            handle.submit(input.clone()).expect("queue open while driver runs");
        }
    })?;
    Ok(report)
}
