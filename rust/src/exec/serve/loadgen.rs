//! Open-loop load generation against the threaded serving runtime:
//! Poisson arrivals at a target QPS, stepped ramps, and per-step
//! latency / SLO accounting.
//!
//! Open-loop means arrival times are drawn from the target process and
//! never wait for responses — the generator that exposes queueing
//! collapse, unlike closed-loop replay whose arrival rate self-throttles
//! to the service rate. Requests that find the bounded queue full are
//! **shed** (counted, not retried): admission control is part of the
//! system under test, and SLO attainment charges every shed request as
//! a miss.
//!
//! Inter-arrival gaps are exponential, `-ln(1 - u) / qps`, with `u`
//! from the deterministic [`XorShiftRng`] — the arrival *schedule* is
//! reproducible bit-for-bit for a given seed even though measured
//! latencies are not.

use super::threaded::PoolHandle;
use crate::util::{percentile_sorted, Tensor, XorShiftRng};
use std::time::{Duration, Instant};

/// The RNG seed of one ramp step's arrival stream: element `step_idx`
/// of the splitmix64 sequence seeded by `seed`. Splitmix64 mixes every
/// bit of `(seed, step_idx)` through two rounds of xor-shift-multiply,
/// so distinct steps get statistically independent streams — unlike
/// the previous `seed ^ (step_idx * constant)`, where step 0 was the
/// raw seed and XOR-of-multiples admitted cross-step stream collisions
/// for adversarial seeds.
pub(crate) fn step_seed(seed: u64, step_idx: u64) -> u64 {
    let mut z = seed.wrapping_add(step_idx.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `percentile_sorted`, except an empty sample set reports
/// [`f64::NAN`] ("no samples") instead of a fake `0.0` — an all-shed
/// step must not be indistinguishable from a zero-latency one.
pub(crate) fn percentile_or_nan(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        f64::NAN
    } else {
        percentile_sorted(sorted, p)
    }
}

/// The exponential inter-arrival gap (seconds) drawn from `rng` at
/// rate `qps`; `1 - u` is in `(0, 1]` so the log never sees zero.
/// Shared with the regression tests, which recompute a step's first
/// gap to assert the measured wall span excludes it.
pub(crate) fn arrival_gap(rng: &mut XorShiftRng, qps: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / qps
}

/// One step of a QPS ramp.
#[derive(Clone, Copy, Debug)]
pub struct QpsStep {
    /// Target offered rate (requests per second).
    pub qps: f64,
    /// Requests offered during this step.
    pub requests: usize,
}

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// The ramp: each step offers `requests` arrivals at `qps`.
    pub steps: Vec<QpsStep>,
    /// Latency SLO (seconds); a request attains it when
    /// `queue_wait + service <= slo`. Shed requests never attain.
    pub slo: f64,
    /// Seed of the arrival process (per-step streams derive from it).
    pub seed: u64,
}

impl LoadgenOptions {
    /// A ramp over `qps_points`, each offering `requests` arrivals.
    pub fn ramp(qps_points: &[f64], requests: usize, slo: f64) -> Self {
        LoadgenOptions {
            steps: qps_points.iter().map(|&qps| QpsStep { qps, requests }).collect(),
            slo,
            seed: 0x10ad,
        }
    }
}

/// Measured outcome of one ramp step.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// Target offered rate.
    pub qps: f64,
    /// Arrivals offered.
    pub offered: u64,
    /// Arrivals admitted by the bounded queue.
    pub accepted: u64,
    /// Arrivals shed by admission control.
    pub rejected: u64,
    /// p50 end-to-end latency (seconds) over accepted requests.
    /// [`f64::NAN`] when the step completed no requests (e.g. every
    /// arrival was shed) — "no samples", distinct from zero latency.
    pub p50: f64,
    /// p99 end-to-end latency (seconds); NaN when no samples.
    pub p99: f64,
    /// p99.9 end-to-end latency (seconds); NaN when no samples.
    pub p999: f64,
    /// Fraction of *offered* requests completed within the SLO.
    pub slo_attainment: f64,
    /// Completed requests over the step's wall span (includes drain).
    pub throughput_rps: f64,
    /// Wall span of the step: first arrival to last completion. The
    /// span opens at the first submit — idle time waiting out the
    /// first exponential gap is *not* load, and charging it would
    /// deflate `throughput_rps` at low QPS.
    pub wall: Duration,
}

impl StepReport {
    /// True when the step completed at least one request (the latency
    /// percentiles are real samples, not the no-sample NaN marker).
    pub fn has_samples(&self) -> bool {
        !self.p50.is_nan()
    }
}

/// Whole-ramp outcome.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// One report per ramp step, in ramp order.
    pub steps: Vec<StepReport>,
}

impl LoadReport {
    /// Total arrivals offered across the ramp.
    pub fn offered(&self) -> u64 {
        self.steps.iter().map(|s| s.offered).sum()
    }

    /// Total arrivals shed across the ramp.
    pub fn rejected(&self) -> u64 {
        self.steps.iter().map(|s| s.rejected).sum()
    }
}

/// Drive an open-loop ramp against a running pool. `make_input` builds
/// the request tensor for global arrival sequence number `i` (drawing
/// from a seeded RNG keeps the workload deterministic). The pool
/// quiesces between steps — each step's latencies are not polluted by
/// the previous step's backlog.
pub fn open_loop(
    handle: &mut PoolHandle<'_>,
    opts: &LoadgenOptions,
    mut make_input: impl FnMut(u64) -> Tensor<i8>,
) -> LoadReport {
    let mut report = LoadReport::default();
    let mut seq = 0u64;
    for (step_idx, step) in opts.steps.iter().enumerate() {
        let mut rng = XorShiftRng::new(step_seed(opts.seed, step_idx as u64));
        let qps = step.qps.max(1e-6);
        // Two clocks: `sched0` anchors the arrival *schedule* (gaps
        // are offsets from the step's start), while the measured span
        // opens at the first submit — `wall` is documented as "first
        // arrival to last completion", so the idle wait for the first
        // exponential gap must not count.
        let sched0 = Instant::now();
        let mut span_start: Option<Instant> = None;
        let mut next_arrival = Duration::ZERO;
        let mut ids = Vec::with_capacity(step.requests);
        let mut rejected = 0u64;

        for _ in 0..step.requests {
            // Exponential inter-arrival gap; 1 - u is in (0, 1].
            next_arrival += Duration::from_secs_f64(arrival_gap(&mut rng, qps));
            let elapsed = sched0.elapsed();
            if next_arrival > elapsed {
                std::thread::sleep(next_arrival - elapsed);
            }
            let input = make_input(seq);
            seq += 1;
            span_start.get_or_insert_with(Instant::now);
            match handle.try_submit(input) {
                Ok(id) => ids.push(id),
                Err(_) => rejected += 1,
            }
            // Keep draining completions so the response channel never
            // backs up behind the arrival loop.
            handle.poll();
        }

        // Quiesce: wait out this step's accepted requests.
        handle.wait_all();
        let wall = span_start.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);

        let mut latencies: Vec<f64> = ids
            .iter()
            .map(|&id| {
                handle
                    .completion(id)
                    .expect("accepted request completed after wait_all")
                    .latency()
                    .as_secs_f64()
            })
            .collect();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let offered = ids.len() as u64 + rejected;
        let attained = latencies.iter().filter(|&&l| l <= opts.slo).count() as u64;
        let secs = wall.as_secs_f64();
        report.steps.push(StepReport {
            qps: step.qps,
            offered,
            accepted: ids.len() as u64,
            rejected,
            p50: percentile_or_nan(&latencies, 0.50),
            p99: percentile_or_nan(&latencies, 0.99),
            p999: percentile_or_nan(&latencies, 0.999),
            slo_attainment: if offered == 0 { 1.0 } else { attained as f64 / offered as f64 },
            throughput_rps: if secs <= 0.0 { 0.0 } else { ids.len() as f64 / secs },
            wall,
        });
    }
    report
}
